"""Model/runtime shape specs shared by the L2 model and the AOT exporter.

The same numbers land in ``artifacts/<variant>/manifest.json`` which the rust
runtime reads, so this file is the single source of truth for shapes.
"""

import dataclasses
import math

VOCAB = 48  # char-level math vocab; must match rust/src/tokenizer


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Architecture + the fixed runtime shapes baked into the artifacts."""

    name: str
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    max_seq: int  # decode horizon == KV capacity == pos-emb table size
    slots: int  # decode slots per inference engine (S)
    p_max: int  # max prompt length accepted by prefill
    b_micro: int  # training microbatch rows
    # Training row length. Decoupled from the decode horizon: most rollouts
    # are much shorter than max_seq, so training at max_seq wastes compute
    # on padding (measured 2.7x on `small`); rows longer than t_train are
    # truncated (the paper's max-response-length cap plays the same role).
    t_train: int = 0  # 0 → clamped to max_seq in __post_init__
    vocab: int = VOCAB

    def __post_init__(self):
        t = self.t_train if self.t_train > 0 else self.max_seq
        object.__setattr__(self, "t_train", min(t, self.max_seq))

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_shapes(self):
        """Ordered (name, shape) list defining the flat parameter layout."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        shapes = [("tok_emb", (v, d)), ("pos_emb", (self.max_seq, d))]
        for i in range(self.n_layers):
            p = f"layer{i}."
            shapes += [
                (p + "ln1", (d,)),
                (p + "wq", (d, d)),
                (p + "wk", (d, d)),
                (p + "wv", (d, d)),
                (p + "wo", (d, d)),
                (p + "ln2", (d,)),
                (p + "w1", (d, ff)),
                (p + "b1", (ff,)),
                (p + "w2", (ff, d)),
                (p + "b2", (d,)),
            ]
        shapes.append(("lnf", (d,)))
        return shapes

    @property
    def n_params(self) -> int:
        return sum(math.prod(s) for _, s in self.param_shapes())

    @property
    def kv_elems(self) -> int:
        """Flat KV cache length: [L, 2, S, H, max_seq, d_head]."""
        return (
            self.n_layers * 2 * self.slots * self.n_heads * self.max_seq * self.d_head
        )

    def kv_shape(self):
        return (
            self.n_layers,
            2,
            self.slots,
            self.n_heads,
            self.max_seq,
            self.d_head,
        )


# Size presets. Paper models (1.5B/7B/8B/14B on 16-32 GPUs) are substituted
# by CPU-scale models; the paper's mechanisms are size-independent.
SPECS = {
    "tiny": ModelSpec("tiny", 64, 2, 2, 256, max_seq=96, slots=4, p_max=24, b_micro=4),
    "small": ModelSpec("small", 128, 4, 4, 512, max_seq=192, slots=8, p_max=32, b_micro=8, t_train=96),
    "base": ModelSpec("base", 256, 6, 8, 1024, max_seq=256, slots=8, p_max=32, b_micro=8, t_train=128),
    "large": ModelSpec("large", 512, 8, 8, 2048, max_seq=320, slots=8, p_max=32, b_micro=4, t_train=128),
    "xl": ModelSpec("xl", 768, 12, 12, 3072, max_seq=384, slots=8, p_max=48, b_micro=2, t_train=160),
}


def variant(base: str, **overrides) -> ModelSpec:
    """Derive a named variant (e.g. context-length sweep points for Fig 3)."""
    spec = SPECS[base]
    fields = dataclasses.asdict(spec)
    fields.update(overrides)
    if "name" not in overrides:
        tag = ",".join(f"{k}{v}" for k, v in sorted(overrides.items()))
        fields["name"] = f"{base}@{tag}"
    return ModelSpec(**fields)
