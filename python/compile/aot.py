"""AOT exporter: lower every L2 function to HLO **text** artifacts.

HLO text (NOT ``lowered.compile().serialize()``): jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the rust ``xla`` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage (from ``python/``):
    python -m compile.aot --out ../artifacts --size tiny --size small
    python -m compile.aot --out ../artifacts --size small \
        --override max_seq=256 --tag t256      # Fig-3 context sweep variant

Each variant directory gets ``manifest.json`` (shapes the rust runtime needs)
plus one ``<fn>.hlo.txt`` per artifact function.
"""

import argparse
import dataclasses
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .spec import SPECS, ModelSpec, variant

I32 = jnp.int32
F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    # return_tuple=False: every artifact has exactly ONE flat-array output
    # (see model.py "artifact wrappers"), so the entry root is the array
    # itself and PJRT hands the rust side a plain buffer it can feed back
    # into the next call (device-resident state threading).
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def _spec_fns(spec: ModelSpec):
    """(name, fn, example-arg shapes) for every artifact of one variant.

    All artifacts use the single-flat-output wrappers (model.py): train
    state f32[3N], engine state f32[S·V+KVN], grad f32[N+8].
    """
    n = spec.n_params
    sn, es = 3 * n, model.engine_state_elems(spec)
    gn = n + model.N_METRICS
    s, pmax, t, bm = spec.slots, spec.p_max, spec.t_train, spec.b_micro

    def sd(shape, dtype=F32):
        return jax.ShapeDtypeStruct(shape, dtype)

    return [
        ("init", functools.partial(model.init_state, spec), (sd((1,), I32),)),
        (
            "prefill",
            functools.partial(model.prefill_artifact, spec),
            (sd((n,)), sd((es,)), sd((pmax,), I32), sd((1,), I32), sd((1,), I32)),
        ),
        (
            "decode",
            functools.partial(model.decode_artifact, spec),
            (sd((n,)), sd((es,)), sd((s,), I32), sd((s,), I32)),
        ),
        (
            "replay",
            functools.partial(model.replay_artifact, spec),
            (sd((n,)), sd((es,)), sd((pmax,), I32), sd((1,), I32), sd((1,), I32), sd((1,), I32)),
        ),
        (
            "logprob",
            functools.partial(model.logprob_artifact, spec),
            (sd((sn,)), sd((bm, t), I32)),
        ),
        (
            "grad",
            functools.partial(model.grad_artifact, spec),
            (sd((sn,)), sd((bm, t), I32), sd((bm, t - 1)), sd((bm, t - 1)), sd((bm,))),
        ),
        (
            "sft_grad",
            functools.partial(model.sft_grad_artifact, spec),
            (sd((sn,)), sd((bm, t), I32), sd((bm, t - 1))),
        ),
        (
            "update",
            functools.partial(model.update_artifact, spec),
            (sd((sn,)), sd((gn,)), sd((1,), I32), sd((1,)), sd((1,))),
        ),
        ("accum", model.accum, (sd((gn,)), sd((gn,)), sd((1,)))),
        ("read_header", functools.partial(model.read_header, spec), (sd((es,)),)),
        ("read_metrics", functools.partial(model.read_metrics, spec), (sd((gn,)),)),
        ("read_params", functools.partial(model.read_params, spec), (sd((sn,)),)),
    ]


def export_variant(spec: ModelSpec, out_root: str, only=None, force=False):
    outdir = os.path.join(out_root, spec.name)
    os.makedirs(outdir, exist_ok=True)
    manifest = dataclasses.asdict(spec)
    manifest.update(
        n_params=spec.n_params,
        kv_elems=spec.kv_elems,
        d_head=spec.d_head,
        t_train=spec.t_train,
        kv_shape=list(spec.kv_shape()),
        state_elems=3 * spec.n_params,
        engine_state_elems=model.engine_state_elems(spec),
        grad_elems=spec.n_params + model.N_METRICS,
        n_metrics=model.N_METRICS,
        artifacts={},
    )
    for name, fn, args in _spec_fns(spec):
        path = os.path.join(outdir, f"{name}.hlo.txt")
        manifest["artifacts"][name] = os.path.basename(path)
        if only and name not in only:
            continue
        if os.path.exists(path) and not force:
            print(f"  [skip] {spec.name}/{name} (exists)")
            continue
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        print(f"  [ok] {spec.name}/{name}: {len(text)} chars")
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return outdir


def parse_override(kv: str):
    k, v = kv.split("=", 1)
    return k, int(v)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--size", action="append", default=[],
                    help="preset name (tiny/small/base/large/xl); repeatable")
    ap.add_argument("--override", action="append", default=[],
                    help="spec field override key=int (applied to every --size)")
    ap.add_argument("--tag", default=None,
                    help="variant name suffix: artifacts land in <size>@<tag>/")
    ap.add_argument("--only", action="append", default=[],
                    help="export only these artifact fns")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    sizes = args.size or ["tiny", "small"]
    overrides = dict(parse_override(kv) for kv in args.override)
    for size in sizes:
        if overrides:
            name = f"{size}@{args.tag}" if args.tag else None
            spec = variant(size, **({"name": name} if name else {}), **overrides)
        else:
            spec = SPECS[size]
        print(f"[aot] exporting {spec.name} (n_params={spec.n_params:,})")
        export_variant(spec, args.out, only=set(args.only) or None, force=args.force)


if __name__ == "__main__":
    main()
