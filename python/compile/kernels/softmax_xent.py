"""L1 Pallas kernel: fused token log-prob + entropy from logits.

Used by the ``logprob`` artifact (the veRL-style "cal logprob" stage): for
each position it computes, in one pass over the vocab tile,

  lp[b, t]  = log softmax(logits[b, t])[labels[b, t]]
  ent[b, t] = H(softmax(logits[b, t]))

Fusing the three reductions (max, logsumexp, p·logit sum) avoids three
separate HLO reduce passes over the logits. Inference-only (no VJP) — the
training path differentiates through the pure-jnp reference instead.

Shapes: logits ``[R, V]`` (rows = flattened B*T), labels ``[R]`` int32.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(logits_ref, labels_ref, lp_ref, ent_ref):
    x = logits_ref[0].astype(jnp.float32)  # [V]
    label = labels_ref[0]
    m = x.max()
    e = jnp.exp(x - m)
    z = e.sum()
    lse = m + jnp.log(z)
    p = e / z
    # entropy = lse - E_p[x]
    ent_ref[0] = lse - (p * x).sum()
    lp_ref[0] = x[label] - lse


def token_logprob_entropy(logits, labels, *, block_rows: int = 8):
    """Per-row token log-prob and entropy.

    ``logits``: [R, V] f32; ``labels``: [R] int32 → (lp [R], ent [R]).
    """
    r, v = logits.shape
    del block_rows  # one row per grid cell keeps the VMEM tile = one vocab row
    lp, ent = pl.pallas_call(
        _kernel,
        grid=(r,),
        in_specs=[
            pl.BlockSpec((1, v), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=(
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((r,), jnp.float32),
            jax.ShapeDtypeStruct((r,), jnp.float32),
        ),
        interpret=True,
    )(logits, labels.astype(jnp.int32))
    return lp, ent
