"""L1 Pallas kernel: causal flash attention, forward + custom-VJP backward.

TPU-style adaptation of the paper's GPU hot path (see DESIGN.md
§Hardware-Adaptation): the HBM<->VMEM schedule is expressed with BlockSpecs
(queries blocked by ``block_q``; keys/values streamed in ``block_k`` chunks
inside the kernel), online-softmax accumulators are carried in registers/VMEM,
and the inner products are MXU-shaped ``(block_q, d) @ (d, block_k)`` matmuls.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret mode lowers the identical schedule to plain HLO,
so the artifact stays executable from the rust runtime.

Shapes: q, k, v are ``[B, H, T, D]``; the wrapper collapses (B, H) into one
grid axis. All softmax math is f32 regardless of input dtype.
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

# Default tile sizes. 128 is the MXU-native dimension; clamped to the
# sequence length by the wrapper for short sequences.
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128

NEG_INF = -1e30


def _ceil_div(a: int, b: int) -> int:
    return (a + b - 1) // b


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *, sm_scale, block_k):
    """One (bh, q-block) grid cell: stream KV blocks with online softmax."""
    qi = pl.program_id(1)
    block_q, d = q_ref.shape[1], q_ref.shape[2]
    q = q_ref[0].astype(jnp.float32)  # [bq, d]

    q_offset = qi * block_q
    row = q_offset + lax.iota(jnp.int32, block_q)  # global query rows

    # Causal: only KV blocks whose first column <= last row of this q block.
    nk = lax.div(q_offset + block_q + block_k - 1, block_k)

    def body(j, carry):
        m_prev, l_prev, acc = carry
        col0 = j * block_k
        kblk = k_ref[0, pl.dslice(col0, block_k), :].astype(jnp.float32)
        vblk = v_ref[0, pl.dslice(col0, block_k), :].astype(jnp.float32)
        s = (q @ kblk.T) * sm_scale  # [bq, bk]
        col = col0 + lax.iota(jnp.int32, block_k)
        s = jnp.where(row[:, None] >= col[None, :], s, NEG_INF)

        m_cur = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_cur = l_prev * alpha + p.sum(axis=1)
        acc = acc * alpha[:, None] + p @ vblk
        return m_cur, l_cur, acc

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, d), jnp.float32)
    m, l, acc = lax.fori_loop(0, nk, body, (m0, l0, acc0))

    l_safe = jnp.where(l > 0.0, l, 1.0)
    o_ref[0] = (acc / l_safe[:, None]).astype(o_ref.dtype)
    lse_ref[0] = m + jnp.log(l_safe)


def _fwd(q, k, v, *, sm_scale, block_q, block_k):
    bh, t, d = q.shape
    grid = (bh, _ceil_div(t, block_q))
    out_shapes = (
        jax.ShapeDtypeStruct((bh, t, d), q.dtype),
        jax.ShapeDtypeStruct((bh, t), jnp.float32),
    )
    kernel = functools.partial(_fwd_kernel, sm_scale=sm_scale, block_k=block_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, t, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, t, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, block_q, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_q), lambda b, i: (b, i)),
        ),
        out_shape=out_shapes,
        interpret=True,
    )(q, k, v)


# ---------------------------------------------------------------------------
# backward: dq over q blocks, (dk, dv) over kv blocks
# ---------------------------------------------------------------------------


def _bwd_dq_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, *, sm_scale, block_k
):
    qi = pl.program_id(1)
    block_q, d = q_ref.shape[1], q_ref.shape[2]
    q = q_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    lse = lse_ref[0]
    delta = delta_ref[0]

    q_offset = qi * block_q
    row = q_offset + lax.iota(jnp.int32, block_q)
    nk = lax.div(q_offset + block_q + block_k - 1, block_k)

    def body(j, dq):
        col0 = j * block_k
        kblk = k_ref[0, pl.dslice(col0, block_k), :].astype(jnp.float32)
        vblk = v_ref[0, pl.dslice(col0, block_k), :].astype(jnp.float32)
        s = (q @ kblk.T) * sm_scale
        col = col0 + lax.iota(jnp.int32, block_k)
        mask = row[:, None] >= col[None, :]
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)  # [bq, bk]
        dp = do @ vblk.T  # [bq, bk]
        ds = p * (dp - delta[:, None])
        return dq + (ds @ kblk) * sm_scale

    dq = lax.fori_loop(0, nk, body, jnp.zeros((block_q, d), jnp.float32))
    dq_ref[0] = dq.astype(dq_ref.dtype)


def _bwd_dkv_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dk_ref, dv_ref,
    *, sm_scale, block_q, t,
):
    ki = pl.program_id(1)
    block_k, d = k_ref.shape[1], k_ref.shape[2]
    kblk = k_ref[0].astype(jnp.float32)
    vblk = v_ref[0].astype(jnp.float32)

    col0 = ki * block_k
    col = col0 + lax.iota(jnp.int32, block_k)
    nq_total = _ceil_div(t, block_q)
    # Causal: q blocks strictly before this kv block contribute nothing.
    j0 = lax.div(col0, block_q)

    def body(j, carry):
        dk, dv = carry
        row0 = j * block_q
        qblk = q_ref[0, pl.dslice(row0, block_q), :].astype(jnp.float32)
        doblk = do_ref[0, pl.dslice(row0, block_q), :].astype(jnp.float32)
        lse = lse_ref[0, pl.dslice(row0, block_q)]
        delta = delta_ref[0, pl.dslice(row0, block_q)]
        row = row0 + lax.iota(jnp.int32, block_q)
        s = (qblk @ kblk.T) * sm_scale  # [bq, bk]
        mask = row[:, None] >= col[None, :]
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
        dp = doblk @ vblk.T
        ds = p * (dp - delta[:, None])
        dv = dv + p.T @ doblk
        dk = dk + (ds.T @ qblk) * sm_scale
        return dk, dv

    z = jnp.zeros((block_k, d), jnp.float32)
    dk, dv = lax.fori_loop(j0, nq_total, body, (z, z))
    dk_ref[0] = dk.astype(dk_ref.dtype)
    dv_ref[0] = dv.astype(dv_ref.dtype)


def _bwd(sm_scale, block_q, block_k, res, do):
    q, k, v, o, lse = res
    bh, t, d = q.shape
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)

    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, sm_scale=sm_scale, block_k=block_k),
        grid=(bh, _ceil_div(t, block_q)),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, t, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, t, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, block_q, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_q), lambda b, i: (b, i)),
            pl.BlockSpec((1, block_q), lambda b, i: (b, i)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, d), q.dtype),
        interpret=True,
    )(q, k, v, do, lse, delta)

    dk, dv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, sm_scale=sm_scale, block_q=block_q, t=t),
        grid=(bh, _ceil_div(t, block_k)),
        in_specs=[
            pl.BlockSpec((1, t, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, t, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, t), lambda b, i: (b, 0)),
            pl.BlockSpec((1, t), lambda b, i: (b, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, block_k, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i: (b, i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((bh, t, d), k.dtype),
            jax.ShapeDtypeStruct((bh, t, d), v.dtype),
        ),
        interpret=True,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash_bhtd(q, k, v, sm_scale, block_q, block_k):
    o, _ = _fwd(q, k, v, sm_scale=sm_scale, block_q=block_q, block_k=block_k)
    return o


def _flash_bhtd_fwd(q, k, v, sm_scale, block_q, block_k):
    o, lse = _fwd(q, k, v, sm_scale=sm_scale, block_q=block_q, block_k=block_k)
    return o, (q, k, v, o, lse)


def _flash_bhtd_bwd(sm_scale, block_q, block_k, res, do):
    return _bwd(sm_scale, block_q, block_k, res, do)


_flash_bhtd.defvjp(_flash_bhtd_fwd, _flash_bhtd_bwd)


def flash_attention(q, k, v, *, block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K):
    """Causal flash attention over ``[B, H, T, D]`` tensors.

    Differentiable (custom VJP; backward is also a pair of Pallas kernels).
    Block sizes are clamped to the sequence length and to multiples that
    divide it (the wrapper pads T to a block multiple when needed).
    """
    b, h, t, d = q.shape
    block_q = max(1, min(block_q, t))
    block_k = max(1, min(block_k, t))
    pad = (-t) % block_q
    pad = max(pad, (-t) % block_k)
    # Pad to a common multiple of both blocks for simple grids.
    tp = t + (-t) % math.lcm(block_q, block_k) if pad else t
    sm_scale = 1.0 / math.sqrt(d)

    def collapse(x, tpad):
        x = x.reshape(b * h, t, d)
        if tpad != t:
            x = jnp.pad(x, ((0, 0), (0, tpad - t), (0, 0)))
        return x

    o = _flash_bhtd(collapse(q, tp), collapse(k, tp), collapse(v, tp),
                    sm_scale, block_q, block_k)
    return o[:, :t, :].reshape(b, h, t, d)
