"""L1 Pallas kernel: single-query decode attention over a KV cache.

This is the TPU rethink of vLLM's paged-attention decode kernel (DESIGN.md
§Hardware-Adaptation): each grid cell handles one (slot, head) pair, streams
the slot's cached keys/values in ``block_k`` chunks through VMEM, applies a
*length mask* (``position < length``) instead of CUDA's per-page indirection,
and keeps the online-softmax state in registers. Invalid slots (length 0)
produce zeros.

Shapes: q ``[S, H, D]`` (one new token per slot), k/v cache
``[S, H, Tmax, D]``, lengths ``[S]`` (valid cache entries per slot,
including the current token's k/v which the caller has already written).
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, sm_scale, block_k):
    d = q_ref.shape[1]
    q = q_ref[0].astype(jnp.float32)  # [d]
    length = len_ref[0]

    nk = lax.div(length + block_k - 1, block_k)

    def body(j, carry):
        m_prev, l_prev, acc = carry
        col0 = j * block_k
        kblk = k_ref[0, pl.dslice(col0, block_k), :].astype(jnp.float32)
        vblk = v_ref[0, pl.dslice(col0, block_k), :].astype(jnp.float32)
        s = (kblk @ q) * sm_scale  # [bk]
        col = col0 + lax.iota(jnp.int32, block_k)
        s = jnp.where(col < length, s, NEG_INF)
        m_cur = jnp.maximum(m_prev, s.max())
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur)
        l_cur = l_prev * alpha + p.sum()
        acc = acc * alpha + p @ vblk
        return m_cur, l_cur, acc

    m0 = jnp.float32(NEG_INF)
    l0 = jnp.float32(0.0)
    acc0 = jnp.zeros((d,), jnp.float32)
    _, l, acc = lax.fori_loop(0, nk, body, (m0, l0, acc0))

    l_safe = jnp.where(l > 0.0, l, 1.0)
    o_ref[0] = (acc / l_safe).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, lengths, *,
                     block_k: int = DEFAULT_BLOCK_K):
    """Masked single-query attention: out ``[S, H, D]``.

    ``lengths[s]`` is the number of valid cache positions for slot ``s``;
    slots with length 0 return zeros (inactive slots).
    """
    s, h, tmax, d = k_cache.shape
    assert q.shape == (s, h, d), (q.shape, (s, h, d))
    block_k = max(1, min(block_k, tmax))
    tp = tmax + (-tmax) % block_k
    sm_scale = 1.0 / math.sqrt(d)

    qf = q.reshape(s * h, d)
    kf = k_cache.reshape(s * h, tmax, d)
    vf = v_cache.reshape(s * h, tmax, d)
    if tp != tmax:
        kf = jnp.pad(kf, ((0, 0), (0, tp - tmax), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, tp - tmax), (0, 0)))
    lens = jnp.repeat(lengths.astype(jnp.int32), h)  # [S*H]

    out = pl.pallas_call(
        functools.partial(_decode_kernel, sm_scale=sm_scale, block_k=block_k),
        grid=(s * h,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, tp, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, tp, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s * h, d), q.dtype),
        interpret=True,
    )(qf, kf, vf, lens)
    return out.reshape(s, h, d)
