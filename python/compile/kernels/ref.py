"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

Every kernel in this package must match its oracle here to numerical
tolerance; ``python/tests/test_kernels.py`` sweeps shapes/dtypes with
hypothesis and asserts allclose.
"""

import jax.numpy as jnp


def causal_attention_ref(q, k, v):
    """Reference causal attention for ``[B, H, T, D]`` tensors (f32 math)."""
    b, h, t, d = q.shape
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) / jnp.sqrt(jnp.float32(d))
    mask = jnp.tril(jnp.ones((t, t), bool))
    s = jnp.where(mask[None, None], s, -1e30)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, lengths):
    """Reference masked single-query attention.

    q [S,H,D]; caches [S,H,Tmax,D]; lengths [S]. Slots with length 0 → 0.
    """
    s, h, tmax, d = k_cache.shape
    qf = q.astype(jnp.float32)
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    scores = jnp.einsum("shd,shtd->sht", qf, kf) / jnp.sqrt(jnp.float32(d))
    pos = jnp.arange(tmax)
    mask = pos[None, None, :] < lengths[:, None, None]
    scores = jnp.where(mask, scores, -1e30)
    m = scores.max(axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = p * mask
    denom = p.sum(axis=-1, keepdims=True)
    denom = jnp.where(denom > 0, denom, 1.0)
    p = p / denom
    return jnp.einsum("sht,shtd->shd", p, vf).astype(q.dtype)


def token_logprob_entropy_ref(logits, labels):
    """Reference fused log-prob + entropy. logits [R,V]; labels [R]."""
    x = logits.astype(jnp.float32)
    m = x.max(axis=-1, keepdims=True)
    lse = m[:, 0] + jnp.log(jnp.exp(x - m).sum(axis=-1))
    p = jnp.exp(x - lse[:, None])
    ent = lse - (p * x).sum(axis=-1)
    lp = jnp.take_along_axis(x, labels[:, None].astype(jnp.int32), axis=-1)[:, 0] - lse
    return lp, ent
