"""L2: the JAX model + GRPO training math, built over a FLAT parameter vector.

Everything here is traced once by ``aot.py`` and lowered to HLO text; at
runtime the rust coordinator only sees opaque artifacts with the signatures
documented in DESIGN.md. Params, Adam moments and gradients are each a single
f32[N] vector so the rust side manages exactly four device buffers;
un-flattening happens inside the traced functions (free after XLA fusion).

Architecture: pre-RMSNorm GPT — token + learned positional embeddings,
causal flash attention (L1 Pallas kernel), GELU MLP, tied LM head.
"""

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from .kernels.decode_attention import decode_attention
from .kernels.flash_attention import flash_attention
from .kernels.ref import causal_attention_ref
from .kernels.softmax_xent import token_logprob_entropy
from .spec import ModelSpec

# GRPO-clip hyperparameters (paper Table 3: clip ratio low 0.2 / high 0.28).
CLIP_LOW = 0.2
CLIP_HIGH = 0.28
ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8
WEIGHT_DECAY = 0.01


class Params(NamedTuple):
    """Structured view over the flat vector (names match spec.param_shapes)."""

    tensors: dict


def unflatten(spec: ModelSpec, flat):
    out = {}
    off = 0
    for name, shape in spec.param_shapes():
        n = math.prod(shape)
        out[name] = lax.dynamic_slice(flat, (off,), (n,)).reshape(shape)
        off += n
    return Params(out)


def flatten_tree(spec: ModelSpec, tensors: dict):
    parts = [tensors[name].reshape(-1) for name, _ in spec.param_shapes()]
    return jnp.concatenate(parts)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_params(spec: ModelSpec, seed):
    """Deterministic init → flat f32[N]. ``seed`` is an i32[1] array."""
    key = jax.random.PRNGKey(seed[0])
    tensors = {}
    resid_scale = 0.02 / math.sqrt(2.0 * spec.n_layers)
    for i, (name, shape) in enumerate(spec.param_shapes()):
        sub = jax.random.fold_in(key, i)
        base = name.split(".")[-1]
        if base in ("ln1", "ln2", "lnf"):
            tensors[name] = jnp.ones(shape, jnp.float32)
        elif base in ("b1", "b2"):
            tensors[name] = jnp.zeros(shape, jnp.float32)
        elif base in ("wo", "w2"):
            tensors[name] = jax.random.normal(sub, shape, jnp.float32) * resid_scale
        else:
            tensors[name] = jax.random.normal(sub, shape, jnp.float32) * 0.02
    return flatten_tree(spec, tensors)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _rmsnorm(x, scale):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + 1e-6) * scale


def _split_heads(x, n_heads):
    b, t, d = x.shape
    return x.reshape(b, t, n_heads, d // n_heads).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, t, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, h * dh)


def forward(spec: ModelSpec, params: Params, tokens, *, collect_kv=False,
            use_pallas=True):
    """Causal LM forward. tokens i32[B, T] → logits f32[B, T, V].

    ``collect_kv`` additionally returns per-layer (k, v) as [B, H, T, Dh]
    (used by prefill to populate the cache). ``use_pallas=False`` swaps the
    attention kernel for the jnp oracle (A/B in tests and perf ablation).
    """
    p = params.tensors
    b, t = tokens.shape
    x = p["tok_emb"][tokens] + p["pos_emb"][None, :t, :]
    kvs = []
    attn = flash_attention if use_pallas else causal_attention_ref
    for i in range(spec.n_layers):
        pre = f"layer{i}."
        xn = _rmsnorm(x, p[pre + "ln1"])
        q = _split_heads(xn @ p[pre + "wq"], spec.n_heads)
        k = _split_heads(xn @ p[pre + "wk"], spec.n_heads)
        v = _split_heads(xn @ p[pre + "wv"], spec.n_heads)
        o = attn(q, k, v)
        x = x + _merge_heads(o) @ p[pre + "wo"]
        if collect_kv:
            kvs.append((k, v))
        xn = _rmsnorm(x, p[pre + "ln2"])
        h = jax.nn.gelu(xn @ p[pre + "w1"] + p[pre + "b1"])
        x = x + (h @ p[pre + "w2"] + p[pre + "b2"])
    x = _rmsnorm(x, p["lnf"])
    logits = x @ p["tok_emb"].T
    if collect_kv:
        return logits, kvs
    return logits


# ---------------------------------------------------------------------------
# prefill / decode (the rollout path)
# ---------------------------------------------------------------------------


def prefill(spec: ModelSpec, flat_params, kv_flat, tokens, length, slot):
    """Prefill one slot's prompt into the KV cache.

    tokens i32[Pmax]; length i32[1] (valid prompt tokens); slot i32[1].
    Returns (kv_flat', last_logits f32[V]) where last_logits correspond to
    position ``length - 1`` (the next-token distribution for sampling).
    KV beyond ``length`` is garbage; decode masks by position < length.
    """
    params = unflatten(spec, flat_params)
    logits, kvs = forward(spec, params, tokens[None, :], collect_kv=True)
    kv = kv_flat.reshape(spec.kv_shape())
    s = slot[0]
    pmax = tokens.shape[0]
    for i, (k, v) in enumerate(kvs):
        # k, v: [1, H, Pmax, Dh] → write into kv[i, 0/1, s, :, :Pmax, :]
        upd_k = k[0][None, None, None]  # [1,1,1,H,Pmax,Dh]
        upd_v = v[0][None, None, None]
        kv = lax.dynamic_update_slice(kv, upd_k, (i, 0, s, 0, 0, 0))
        kv = lax.dynamic_update_slice(kv, upd_v, (i, 1, s, 0, 0, 0))
    last = lax.dynamic_slice(logits[0], (length[0] - 1, 0), (1, spec.vocab))[0]
    return kv.reshape(-1), last


def decode(spec: ModelSpec, flat_params, kv_flat, tokens, pos):
    """One decode step for all S slots.

    tokens i32[S] (last sampled token per slot); pos i32[S] (its absolute
    position). Writes this step's K/V at ``pos`` and attends over
    ``[0, pos]``. Inactive slots are computed anyway (constant step cost —
    the GPU idles the same way) and ignored by the caller.
    Returns (logits f32[S, V], kv_flat').
    """
    params = unflatten(spec, flat_params)
    p = params.tensors
    s = spec.slots
    x = p["tok_emb"][tokens] + p["pos_emb"][pos]  # [S, d]
    kv = kv_flat.reshape(spec.kv_shape())
    lengths = pos + 1

    def write_slot(cache, vec, pos_s):
        # cache [H, Tmax, Dh]; vec [H, Dh] → write at [:, pos_s, :]
        return lax.dynamic_update_slice(cache, vec[:, None, :], (0, pos_s, 0))

    for i in range(spec.n_layers):
        pre = f"layer{i}."
        xn = _rmsnorm(x, p[pre + "ln1"])
        q = (xn @ p[pre + "wq"]).reshape(s, spec.n_heads, spec.d_head)
        k = (xn @ p[pre + "wk"]).reshape(s, spec.n_heads, spec.d_head)
        v = (xn @ p[pre + "wv"]).reshape(s, spec.n_heads, spec.d_head)
        k_cache = jax.vmap(write_slot)(kv[i, 0], k, pos)
        v_cache = jax.vmap(write_slot)(kv[i, 1], v, pos)
        kv = kv.at[i, 0].set(k_cache)
        kv = kv.at[i, 1].set(v_cache)
        o = decode_attention(q, k_cache, v_cache, lengths)  # [S, H, Dh]
        x = x + o.reshape(s, spec.d_model) @ p[pre + "wo"]
        xn = _rmsnorm(x, p[pre + "ln2"])
        h = jax.nn.gelu(xn @ p[pre + "w1"] + p[pre + "b1"])
        x = x + (h @ p[pre + "w2"] + p[pre + "b2"])
    x = _rmsnorm(x, p["lnf"])
    logits = x @ p["tok_emb"].T
    return logits, kv.reshape(-1)


# ---------------------------------------------------------------------------
# log-probs (the "cal logprob" stage) and GRPO gradient
# ---------------------------------------------------------------------------


def _shift_logprobs_jnp(logits, tokens):
    """Differentiable per-token log-probs: lp[b, t] for predicting
    tokens[b, t+1] from position t. Returns [B, T-1]."""
    lp = jax.nn.log_softmax(logits[:, :-1, :], axis=-1)
    return jnp.take_along_axis(lp, tokens[:, 1:, None], axis=-1)[..., 0]


def logprob(spec: ModelSpec, flat_params, tokens):
    """Inference-only per-token log-prob + entropy via the fused L1 kernel.

    tokens i32[B, T] → (lp f32[B, T-1], ent f32[B, T-1]).
    """
    params = unflatten(spec, flat_params)
    logits = forward(spec, params, tokens)
    b, t, v = logits.shape
    rows = logits[:, :-1, :].reshape(b * (t - 1), v)
    labels = tokens[:, 1:].reshape(-1)
    lp, ent = token_logprob_entropy(rows, labels)
    return lp.reshape(b, t - 1), ent.reshape(b, t - 1)


def grpo_objective(spec: ModelSpec, flat_params, tokens, resp_mask, behav_lp, adv):
    """Sum (not mean) of the per-token GRPO-clip loss, Eq. 2-5 + Eq. 8.

    tokens i32[B, T]; resp_mask f32[B, T-1] (1 on response-token predictions);
    behav_lp f32[B, T-1] — the *cross-stage concatenated* behaviour log-probs
    L_i from the rollout buffer; adv f32[B] group-relative advantages.

    Returns (neg_objective_sum, aux). Token-mean aggregation happens at
    update time (rust divides by the total masked-token count across the
    whole batch — exact token-mean under gradient accumulation).
    """
    params = unflatten(spec, flat_params)
    logits = forward(spec, params, tokens)
    lp = _shift_logprobs_jnp(logits, tokens)  # [B, T-1]

    log_ratio = lp - behav_lp
    ratio = jnp.exp(log_ratio)  # Eq. 8
    a = adv[:, None]
    unclipped = ratio * a
    clipped = jnp.clip(ratio, 1.0 - CLIP_LOW, 1.0 + CLIP_HIGH) * a
    per_tok = jnp.minimum(unclipped, clipped)  # Eq. 3
    loss_sum = -(per_tok * resp_mask).sum()

    # Metrics (no gradient): entropy, mean/max ratio, clip fraction, k3-KL.
    sg = lax.stop_gradient
    probs = jax.nn.softmax(sg(logits[:, :-1, :]), axis=-1)
    ent_tok = -(probs * jnp.log(probs + 1e-9)).sum(-1)
    mask = resp_mask
    n_tok = mask.sum()
    r = sg(ratio)
    clip_hit = (jnp.abs(r - jnp.clip(r, 1.0 - CLIP_LOW, 1.0 + CLIP_HIGH)) > 0).astype(
        jnp.float32
    )
    lr_ = sg(log_ratio)
    k3 = jnp.exp(-lr_) - 1.0 + lr_
    aux = jnp.stack(
        [
            sg(loss_sum),
            (ent_tok * mask).sum(),
            (r * mask).sum(),
            (r * mask).max(),
            (clip_hit * mask).sum(),
            (k3 * mask).sum(),
            n_tok,
        ]
    )
    return loss_sum, aux


def grad(spec: ModelSpec, flat_params, tokens, resp_mask, behav_lp, adv):
    """GRPO gradient over one microbatch.

    Returns (grads f32[N] — gradient of the token-SUM loss, metrics f32[8]):
    metrics = [loss_sum, ent_sum, ratio_sum, ratio_max, clip_sum, kl_sum,
               token_count, grad_norm].
    """
    (loss, aux), g = jax.value_and_grad(
        lambda fp: grpo_objective(spec, fp, tokens, resp_mask, behav_lp, adv),
        has_aux=True,
    )(flat_params)
    gnorm = jnp.sqrt((g * g).sum())
    metrics = jnp.concatenate([aux, gnorm[None]])
    return g, metrics


def sft_objective(spec: ModelSpec, flat_params, tokens, resp_mask):
    """Supervised next-token xent (SUM over masked tokens) + aux.

    Used to produce the "basemodel": the paper RL-tunes pretrained LLMs, so
    we substitute a brief supervised warmup on easy tasks before RL.
    """
    params = unflatten(spec, flat_params)
    logits = forward(spec, params, tokens)
    lp = _shift_logprobs_jnp(logits, tokens)
    loss_sum = -(lp * resp_mask).sum()
    n_tok = resp_mask.sum()
    return loss_sum, lax.stop_gradient(jnp.stack([loss_sum, n_tok]))


def sft_grad(spec: ModelSpec, flat_params, tokens, resp_mask):
    """SFT gradient over one microbatch → (grads f32[N], metrics f32[3]):
    [loss_sum, token_count, grad_norm]."""
    (_, aux), g = jax.value_and_grad(
        lambda fp: sft_objective(spec, fp, tokens, resp_mask), has_aux=True
    )(flat_params)
    gnorm = jnp.sqrt((g * g).sum())
    return g, jnp.concatenate([aux, gnorm[None]])


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def adam_update(flat_params, m, v, grads, step, lr, grad_scale):
    """One Adam step with decoupled weight decay (Table 3).

    step i32[1] (1-based); lr f32[1]; grad_scale f32[1] — 1/total_tokens so
    accumulation + scaling == exact token-mean loss gradient.
    """
    g = grads * grad_scale[0]
    t = step[0].astype(jnp.float32)
    m2 = ADAM_B1 * m + (1.0 - ADAM_B1) * g
    v2 = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
    mhat = m2 / (1.0 - ADAM_B1**t)
    vhat = v2 / (1.0 - ADAM_B2**t)
    upd = mhat / (jnp.sqrt(vhat) + ADAM_EPS) + WEIGHT_DECAY * flat_params
    return flat_params - lr[0] * upd, m2, v2


def accum(a, b, scale):
    """a + scale·b — device-side gradient accumulation (scale f32[1])."""
    return a + scale[0] * b


# ---------------------------------------------------------------------------
# artifact wrappers — single flat-array in/out signatures
# ---------------------------------------------------------------------------
#
# PJRT (through the rust `xla` 0.1.6 crate) returns multi-output modules as
# ONE tuple buffer, which cannot be fed back as an input buffer; threading
# state through tuples would force full host round-trips of params/KV every
# step. So every artifact returns a SINGLE flat f32 array:
#
#   train state   f32[3N]           = params ++ adam_m ++ adam_v
#   engine state  f32[S·V + KVN]    = logits header ++ flat KV cache
#   grad output   f32[N + 8]        = grads ++ metrics tail
#
# The rust runtime keeps these as device buffers (`execute_b`) and reads only
# the tiny headers/tails via offset `copy_raw_to_host_sync`.

N_METRICS = 8  # metrics tail length on grad outputs


def state_params(spec: ModelSpec, state):
    """params slice of the train state f32[3N]."""
    return lax.dynamic_slice(state, (0,), (spec.n_params,))


def init_state(spec: ModelSpec, seed):
    """seed i32[1] → train state f32[3N] (params, m=0, v=0)."""
    p = init_params(spec, seed)
    zeros = jnp.zeros((2 * spec.n_params,), jnp.float32)
    return jnp.concatenate([p, zeros])


def engine_state_elems(spec: ModelSpec) -> int:
    return spec.slots * spec.vocab + spec.kv_elems


def _split_engine_state(spec: ModelSpec, es):
    header = spec.slots * spec.vocab
    return es[:header], es[header:]


def prefill_artifact(spec: ModelSpec, params, engine_state, tokens, length, slot):
    """Prefill one slot; logits land in header row `slot`.

    Takes bare params f32[N] (not the 3N train state): the inference
    engines receive weight syncs of just the parameter vector.
    """
    header, kv = _split_engine_state(spec, engine_state)
    kv2, last = prefill(spec, params, kv, tokens, length, slot)
    hdr = header.reshape(spec.slots, spec.vocab)
    hdr = lax.dynamic_update_slice(hdr, last[None, :], (slot[0], 0))
    return jnp.concatenate([hdr.reshape(-1), kv2])


def decode_artifact(spec: ModelSpec, params, engine_state, tokens, pos):
    """One decode step for all S slots; header = fresh logits [S, V].

    Takes bare params f32[N] — see prefill_artifact.
    """
    _, kv = _split_engine_state(spec, engine_state)
    logits, kv2 = decode(spec, params, kv, tokens, pos)
    return jnp.concatenate([logits.reshape(-1), kv2])


def logprob_artifact(spec: ModelSpec, state, tokens):
    """tokens i32[B,T] → f32[2, B, T-1]: [0]=log-probs, [1]=entropies."""
    params = state_params(spec, state)
    lp, ent = logprob(spec, params, tokens)
    return jnp.stack([lp, ent])


def grad_artifact(spec: ModelSpec, state, tokens, resp_mask, behav_lp, adv):
    """GRPO microbatch gradient → f32[8+N] = metrics ++ grads.

    Metrics come FIRST so the rust side can read them with a cheap
    offset-0 partial host copy while the gradient stays on device.
    """
    params = state_params(spec, state)
    g, metrics = grad(spec, params, tokens, resp_mask, behav_lp, adv)
    return jnp.concatenate([metrics, g])


def sft_grad_artifact(spec: ModelSpec, state, tokens, resp_mask):
    """SFT microbatch gradient → f32[N+8] = grads ++ padded metrics.

    Metrics head: [loss_sum, token_count, grad_norm, 0, 0, 0, 0, 0] — the
    same head length as `grad_artifact` so `accum`/`update` are shared.
    """
    params = state_params(spec, state)
    g, m3 = sft_grad(spec, params, tokens, resp_mask)
    pad = jnp.zeros((N_METRICS - 3,), jnp.float32)
    return jnp.concatenate([m3, pad, g])


def replay_artifact(spec: ModelSpec, params, engine_state, tokens, start, slot, last):
    """Chunked re-prefill: process up to Pmax RESUME tokens of one slot in a
    single call (vLLM re-prefills preempted/buffered requests in parallel
    chunks; replaying token-by-token through `decode` costs ~50x more).

    tokens i32[Pmax] (chunk; garbage beyond the real count is harmless — its
    KV lands at positions ≥ the current length, which decode's length mask
    never attends); start i32[1] — absolute position of tokens[0]; slot
    i32[1]. The header row `slot` receives the logits of the LAST chunk
    position (callers slice the (n-1)-th themselves via a second call with
    aligned chunks, or simply sample from the final full chunk).

    ``last`` i32[1] — index of the last REAL token in the chunk; the header
    row `slot` receives the logits after tokens[last] (padded tails of the
    final chunk would otherwise pollute the sampling logits).

    CALLER CONTRACT: start + Pmax must not exceed max_seq (XLA's
    dynamic_update_slice clamps out-of-range starts, which would shift the
    chunk onto valid cache); the rust engine falls back to per-token decode
    near the horizon.
    """
    p = unflatten(spec, params).tensors
    c = tokens.shape[0]
    header, kv_flat = _split_engine_state(spec, engine_state)
    kv = kv_flat.reshape(spec.kv_shape())
    s = slot[0]
    positions = start[0] + jnp.arange(c)
    x = p["tok_emb"][tokens] + p["pos_emb"][jnp.clip(positions, 0, spec.max_seq - 1)]
    # Per-query visible length: query i attends to cache positions < start+i+1.
    lengths = positions + 1

    for i in range(spec.n_layers):
        pre = f"layer{i}."
        xn = _rmsnorm(x, p[pre + "ln1"])
        q = (xn @ p[pre + "wq"]).reshape(c, spec.n_heads, spec.d_head)
        k = (xn @ p[pre + "wk"]).reshape(c, spec.n_heads, spec.d_head)
        v = (xn @ p[pre + "wv"]).reshape(c, spec.n_heads, spec.d_head)
        # Write the whole chunk's K/V into the slot cache at [start, start+c).
        k_slot = lax.dynamic_slice_in_dim(kv[i, 0], s, 1, axis=0)[0]  # [H,T,Dh]
        v_slot = lax.dynamic_slice_in_dim(kv[i, 1], s, 1, axis=0)[0]
        k_slot = lax.dynamic_update_slice(
            k_slot, k.transpose(1, 0, 2), (0, start[0], 0)
        )
        v_slot = lax.dynamic_update_slice(
            v_slot, v.transpose(1, 0, 2), (0, start[0], 0)
        )
        kv = lax.dynamic_update_slice(kv, k_slot[None, None, None], (i, 0, s, 0, 0, 0))
        kv = lax.dynamic_update_slice(kv, v_slot[None, None, None], (i, 1, s, 0, 0, 0))
        # Chunk queries attend over the slot cache with per-query lengths
        # (decode-attention kernel, one "slot" per chunk position).
        kc = jnp.broadcast_to(k_slot[None], (c,) + k_slot.shape)
        vc = jnp.broadcast_to(v_slot[None], (c,) + v_slot.shape)
        o = decode_attention(q, kc, vc, lengths)  # [c, H, Dh]
        x = x + o.reshape(c, spec.d_model) @ p[pre + "wo"]
        xn = _rmsnorm(x, p[pre + "ln2"])
        h = jax.nn.gelu(xn @ p[pre + "w1"] + p[pre + "b1"])
        x = x + (h @ p[pre + "w2"] + p[pre + "b2"])
    x = _rmsnorm(x, p["lnf"])
    logits = x @ p["tok_emb"].T  # [c, V]
    hdr = header.reshape(spec.slots, spec.vocab)
    last_logits = lax.dynamic_slice(logits, (last[0], 0), (1, spec.vocab))
    hdr = lax.dynamic_update_slice(hdr, last_logits, (s, 0))
    return jnp.concatenate([hdr.reshape(-1), kv.reshape(-1)])


def read_header(spec: ModelSpec, engine_state):
    """Extract the logits header f32[S·V] from the engine state.

    PJRT-CPU (xla_extension 0.5.1) does not implement CopyRawToHost, so
    partial host reads are impossible; instead these tiny `read_*`
    artifacts slice device-side and return small buffers that are read in
    full. The KV cache never crosses to the host.
    """
    return lax.dynamic_slice(engine_state, (0,), (spec.slots * spec.vocab,))


def read_metrics(spec: ModelSpec, grads_with_head):
    """Extract the metrics head f32[8] from a grad output."""
    return lax.dynamic_slice(grads_with_head, (0,), (N_METRICS,))


def read_params(spec: ModelSpec, state):
    """Extract params f32[N] from the train state (weight-sync payload)."""
    return state_params(spec, state)


def update_artifact(spec: ModelSpec, state, grads_with_head, step, lr, grad_scale):
    """Adam step on the packed train state → new state f32[3N]."""
    n = spec.n_params
    p = lax.dynamic_slice(state, (0,), (n,))
    m = lax.dynamic_slice(state, (n,), (n,))
    v = lax.dynamic_slice(state, (2 * n,), (n,))
    g = lax.dynamic_slice(grads_with_head, (N_METRICS,), (n,))
    p2, m2, v2 = adam_update(p, m, v, g, step, lr, grad_scale)
    return jnp.concatenate([p2, m2, v2])
