"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes/block sizes; assert_allclose against ref.py.
This is the core correctness signal for everything the rust runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.decode_attention import decode_attention
from compile.kernels.flash_attention import flash_attention
from compile.kernels.softmax_xent import token_logprob_entropy

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=20, deadline=None)


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(jax.random.PRNGKey(key), shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention forward
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    t=st.integers(1, 70),
    d=st.sampled_from([4, 16, 32]),
    block=st.sampled_from([(8, 8), (16, 8), (8, 16), (128, 128)]),
    seed=st.integers(0, 2**16),
)
def test_flash_fwd_matches_ref(b, h, t, d, block, seed):
    bq, bk = block
    q = rand(seed, (b, h, t, d))
    k = rand(seed + 1, (b, h, t, d))
    v = rand(seed + 2, (b, h, t, d))
    out = flash_attention(q, k, v, block_q=bq, block_k=bk)
    want = ref.causal_attention_ref(q, k, v)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


def test_flash_fwd_large_scores_stable():
    # Online softmax must survive large score magnitudes without overflow.
    q = rand(0, (1, 1, 32, 16), scale=30.0)
    k = rand(1, (1, 1, 32, 16), scale=30.0)
    v = rand(2, (1, 1, 32, 16))
    out = flash_attention(q, k, v, block_q=8, block_k=8)
    want = ref.causal_attention_ref(q, k, v)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(out, want, atol=1e-4, rtol=1e-4)


def test_flash_first_row_attends_only_self():
    # Row 0 may only see key 0, so its output must be exactly v[0].
    q = rand(3, (1, 2, 16, 8))
    k = rand(4, (1, 2, 16, 8))
    v = rand(5, (1, 2, 16, 8))
    out = flash_attention(q, k, v, block_q=4, block_k=4)
    np.testing.assert_allclose(out[:, :, 0, :], v[:, :, 0, :], atol=1e-5)


# ---------------------------------------------------------------------------
# flash attention backward (custom VJP, also Pallas)
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.integers(1, 2),
    h=st.integers(1, 2),
    t=st.integers(2, 40),
    d=st.sampled_from([4, 16]),
    seed=st.integers(0, 2**16),
)
def test_flash_grads_match_ref(b, h, t, d, seed):
    q = rand(seed, (b, h, t, d))
    k = rand(seed + 1, (b, h, t, d))
    v = rand(seed + 2, (b, h, t, d))
    w = rand(seed + 3, (b, h, t, d))  # random cotangent direction

    def f_pallas(q, k, v):
        return (flash_attention(q, k, v, block_q=16, block_k=8) * w).sum()

    def f_ref(q, k, v):
        return (ref.causal_attention_ref(q, k, v) * w).sum()

    g = jax.grad(f_pallas, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g, gr):
        np.testing.assert_allclose(a, b_, atol=5e-5, rtol=5e-4)


def test_flash_grad_under_jit():
    q, k, v = (rand(i, (1, 2, 24, 8)) for i in range(3))
    f = jax.jit(
        jax.grad(lambda q, k, v: flash_attention(q, k, v, block_q=8, block_k=8).sum())
    )
    fr = jax.grad(lambda q, k, v: ref.causal_attention_ref(q, k, v).sum())
    np.testing.assert_allclose(f(q, k, v), fr(q, k, v), atol=5e-5, rtol=5e-4)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    s=st.integers(1, 6),
    h=st.integers(1, 4),
    tmax=st.integers(1, 48),
    d=st.sampled_from([4, 16, 32]),
    bk=st.sampled_from([4, 8, 128]),
    seed=st.integers(0, 2**16),
    data=st.data(),
)
def test_decode_matches_ref(s, h, tmax, d, bk, seed, data):
    lengths = jnp.array(
        data.draw(st.lists(st.integers(0, tmax), min_size=s, max_size=s)), jnp.int32
    )
    q = rand(seed, (s, h, d))
    kc = rand(seed + 1, (s, h, tmax, d))
    vc = rand(seed + 2, (s, h, tmax, d))
    out = decode_attention(q, kc, vc, lengths, block_k=bk)
    want = ref.decode_attention_ref(q, kc, vc, lengths)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


def test_decode_zero_length_slot_is_zero():
    q = rand(0, (3, 2, 8))
    kc = rand(1, (3, 2, 16, 8))
    vc = rand(2, (3, 2, 16, 8))
    out = decode_attention(q, kc, vc, jnp.array([0, 5, 0], jnp.int32), block_k=4)
    assert np.abs(np.asarray(out[0])).max() == 0.0
    assert np.abs(np.asarray(out[2])).max() == 0.0


def test_decode_length_one_returns_v0():
    q = rand(0, (2, 2, 8))
    kc = rand(1, (2, 2, 16, 8))
    vc = rand(2, (2, 2, 16, 8))
    out = decode_attention(q, kc, vc, jnp.array([1, 1], jnp.int32), block_k=4)
    np.testing.assert_allclose(out, vc[:, :, 0, :], atol=1e-5)


def test_decode_ignores_cache_beyond_length():
    # Garbage beyond `length` must not leak into the output.
    q = rand(0, (1, 1, 8))
    kc = rand(1, (1, 1, 16, 8))
    vc = rand(2, (1, 1, 16, 8))
    kc2 = kc.at[:, :, 10:, :].set(1e4)
    vc2 = vc.at[:, :, 10:, :].set(-1e4)
    lens = jnp.array([10], jnp.int32)
    a = decode_attention(q, kc, vc, lens, block_k=4)
    b = decode_attention(q, kc2, vc2, lens, block_k=4)
    np.testing.assert_allclose(a, b, atol=1e-6)


# ---------------------------------------------------------------------------
# fused logprob + entropy
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    r=st.integers(1, 40),
    v=st.sampled_from([8, 48, 64]),
    scale=st.sampled_from([0.1, 1.0, 10.0]),
    seed=st.integers(0, 2**16),
)
def test_token_logprob_entropy_matches_ref(r, v, scale, seed):
    logits = rand(seed, (r, v), scale=scale)
    labels = jax.random.randint(jax.random.PRNGKey(seed + 9), (r,), 0, v)
    lp, ent = token_logprob_entropy(logits, labels)
    lpr, entr = ref.token_logprob_entropy_ref(logits, labels)
    # atol dominated: entropy of sharply-peaked rows is ~0 with f32 noise.
    np.testing.assert_allclose(lp, lpr, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(ent, entr, atol=1e-4, rtol=1e-4)


def test_entropy_bounds():
    # 0 <= H <= log V; uniform logits hit the upper bound.
    v = 48
    logits = jnp.zeros((4, v))
    _, ent = token_logprob_entropy(logits, jnp.zeros((4,), jnp.int32))
    np.testing.assert_allclose(ent, np.log(v), atol=1e-5)
    peaked = jnp.zeros((1, v)).at[0, 3].set(50.0)
    _, ent2 = token_logprob_entropy(peaked, jnp.array([3], jnp.int32))
    assert float(ent2[0]) < 1e-3
    lp, _ = token_logprob_entropy(peaked, jnp.array([3], jnp.int32))
    assert float(lp[0]) > -1e-3  # near-certain token → lp ≈ 0
