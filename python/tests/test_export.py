"""AOT export path: HLO text round-trips through the XLA client and the
numbers match direct execution — the same contract the rust runtime uses."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model
from compile.spec import SPECS, variant

SPEC = SPECS["tiny"]


def _text_parses(fn, *args) -> str:
    """Lower → HLO text → parse back with the HLO text parser.

    jaxlib 0.8 dropped HLO-proto execution from the python client, so the
    *numerical* round-trip (text → HloModuleProto → compile → execute) is
    covered by `rust/tests/runtime_integration.rs` against the actual
    consumer (xla_extension 0.5.1). Here we verify the text is well-formed
    and parseable — catching lowering regressions at pytest speed.
    """
    lowered = jax.jit(fn).lower(*args)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    hlo_module = xc._xla.hlo_module_from_text(text)  # raises on bad text
    assert hlo_module.as_serialized_hlo_module_proto()
    return text


def test_hlo_text_parses_accum():
    a = jnp.arange(8, dtype=jnp.float32)
    b = jnp.ones(8, jnp.float32)
    scale = jnp.array([2.0], jnp.float32)
    text = _text_parses(model.accum, a, b, scale)
    assert "f32[8]" in text


def test_hlo_text_parses_logprob():
    params = model.init_params(SPEC, jnp.array([7], jnp.int32))
    tokens = jax.random.randint(
        jax.random.PRNGKey(0), (SPEC.b_micro, SPEC.t_train), 0, SPEC.vocab
    )
    text = _text_parses(lambda p, t: model.logprob(SPEC, p, t), params, tokens)
    # Output tuple must carry lp and ent at [B, T-1].
    assert f"f32[{SPEC.b_micro},{SPEC.t_train - 1}]" in text


def test_hlo_text_parses_grad_and_has_single_flat_grad_output():
    params = model.init_params(SPEC, jnp.array([7], jnp.int32))
    t = SPEC.t_train
    tokens = jnp.zeros((SPEC.b_micro, t), jnp.int32)
    mask = jnp.ones((SPEC.b_micro, t - 1))
    lp = jnp.zeros((SPEC.b_micro, t - 1))
    adv = jnp.ones((SPEC.b_micro,))
    text = _text_parses(
        lambda p, tk, m, l, a: model.grad(SPEC, p, tk, m, l, a),
        params, tokens, mask, lp, adv,
    )
    assert f"f32[{SPEC.n_params}]" in text  # the flat gradient


def test_export_variant_writes_manifest(tmp_path):
    spec = variant("tiny", max_seq=64, name="tiny@test")
    aot.export_variant(spec, str(tmp_path), only={"accum"})
    mdir = tmp_path / "tiny@test"
    manifest = json.loads((mdir / "manifest.json").read_text())
    assert manifest["n_params"] == spec.n_params
    assert manifest["kv_elems"] == spec.kv_elems
    assert manifest["max_seq"] == 64
    assert (mdir / "accum.hlo.txt").exists()


def test_variant_overrides_affect_shapes():
    v = variant("tiny", max_seq=128)
    assert v.max_seq == 128
    assert v.kv_elems == SPEC.kv_elems * 128 // SPEC.max_seq
    assert v.n_params != SPEC.n_params  # pos_emb grows
