"""L2 model invariants: KV-cache decode vs full forward, GRPO math, Adam.

These run on the ``tiny`` spec — the same code path the artifacts are lowered
from, so passing here means the HLO the rust runtime executes is correct.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.spec import SPECS, ModelSpec, variant

jax.config.update("jax_platform_name", "cpu")

SPEC = SPECS["tiny"]


@pytest.fixture(scope="module")
def params():
    return model.init_params(SPEC, jnp.array([7], jnp.int32))


# ---------------------------------------------------------------------------
# parameter layout
# ---------------------------------------------------------------------------


def test_param_count_matches_layout(params):
    assert params.shape == (SPEC.n_params,)


def test_init_deterministic():
    a = model.init_params(SPEC, jnp.array([3], jnp.int32))
    b = model.init_params(SPEC, jnp.array([3], jnp.int32))
    c = model.init_params(SPEC, jnp.array([4], jnp.int32))
    np.testing.assert_array_equal(a, b)
    assert np.abs(np.asarray(a - c)).max() > 0


def test_flatten_unflatten_roundtrip(params):
    tree = model.unflatten(SPEC, params)
    flat2 = model.flatten_tree(SPEC, tree.tensors)
    np.testing.assert_array_equal(params, flat2)


def test_layernorm_initialized_to_ones(params):
    tree = model.unflatten(SPEC, params)
    np.testing.assert_array_equal(tree.tensors["lnf"], np.ones(SPEC.d_model))


# ---------------------------------------------------------------------------
# forward / prefill / decode consistency — KV-cache correctness
# ---------------------------------------------------------------------------


def test_pallas_forward_matches_ref_attention(params):
    tokens = jax.random.randint(jax.random.PRNGKey(0), (2, 20), 0, SPEC.vocab)
    tree = model.unflatten(SPEC, params)
    a = model.forward(SPEC, tree, tokens, use_pallas=True)
    b = model.forward(SPEC, tree, tokens, use_pallas=False)
    np.testing.assert_allclose(a, b, atol=3e-5, rtol=3e-5)


def test_prefill_then_decode_matches_forward(params):
    """The rollout hot path (prefill + per-token decode with KV cache) must
    produce the same logits as the teacher-forced full forward."""
    rng = np.random.default_rng(0)
    total = 12
    plen = 5
    tokens = jnp.array(rng.integers(0, SPEC.vocab, size=(total,)), jnp.int32)

    # Reference: full forward over the whole sequence.
    tree = model.unflatten(SPEC, params)
    ref_logits = model.forward(SPEC, tree, tokens[None, :])[0]  # [total, V]

    # Rollout path: prefill the prompt into slot 2, then decode one by one.
    kv = jnp.zeros((SPEC.kv_elems,), jnp.float32)
    prompt = jnp.zeros((SPEC.p_max,), jnp.int32).at[:plen].set(tokens[:plen])
    kv, last = model.prefill(
        SPEC, params, kv, prompt, jnp.array([plen], jnp.int32), jnp.array([2], jnp.int32)
    )
    np.testing.assert_allclose(last, ref_logits[plen - 1], atol=1e-4, rtol=1e-4)

    slot_tokens = jnp.zeros((SPEC.slots,), jnp.int32)
    slot_pos = jnp.zeros((SPEC.slots,), jnp.int32)
    for t in range(plen, total):
        slot_tokens = slot_tokens.at[2].set(tokens[t])
        slot_pos = slot_pos.at[2].set(t)
        logits, kv = model.decode(SPEC, params, kv, slot_tokens, slot_pos)
        np.testing.assert_allclose(
            logits[2], ref_logits[t], atol=2e-4, rtol=2e-4,
            err_msg=f"decode step {t}",
        )


def test_decode_slots_are_independent(params):
    """Writing one slot's KV must not perturb another slot's logits."""
    kv = jnp.zeros((SPEC.kv_elems,), jnp.float32)
    prompt = jnp.arange(SPEC.p_max, dtype=jnp.int32) % SPEC.vocab
    kv, _ = model.prefill(
        SPEC, params, kv, prompt, jnp.array([4], jnp.int32), jnp.array([0], jnp.int32)
    )
    toks = jnp.array([5, 0, 0, 0], jnp.int32)
    pos = jnp.array([4, 0, 0, 0], jnp.int32)
    logits_a, _ = model.decode(SPEC, params, kv, toks, pos)

    # Prefill a *different* prompt into slot 3, then repeat slot 0's decode.
    other = (prompt + 11) % SPEC.vocab
    kv2, _ = model.prefill(
        SPEC, params, kv, other, jnp.array([9], jnp.int32), jnp.array([3], jnp.int32)
    )
    logits_b, _ = model.decode(SPEC, params, kv2, toks, pos)
    np.testing.assert_allclose(logits_a[0], logits_b[0], atol=1e-5)


# ---------------------------------------------------------------------------
# logprob artifact
# ---------------------------------------------------------------------------


def test_logprob_matches_log_softmax(params):
    tokens = jax.random.randint(jax.random.PRNGKey(1), (3, 16), 0, SPEC.vocab)
    lp, ent = model.logprob(SPEC, params, tokens)
    tree = model.unflatten(SPEC, params)
    logits = model.forward(SPEC, tree, tokens)
    want = model._shift_logprobs_jnp(logits, tokens)
    np.testing.assert_allclose(lp, want, atol=2e-5, rtol=2e-5)
    assert lp.shape == (3, 15) and ent.shape == (3, 15)
    assert (np.asarray(ent) >= -1e-5).all()
    assert (np.asarray(ent) <= np.log(SPEC.vocab) + 1e-5).all()
    assert (np.asarray(lp) <= 1e-6).all()


# ---------------------------------------------------------------------------
# GRPO objective
# ---------------------------------------------------------------------------


def _grpo_inputs(params, b=3, t=None, seed=0):
    t = t or SPEC.t_train
    key = jax.random.PRNGKey(seed)
    tokens = jax.random.randint(key, (b, t), 0, SPEC.vocab)
    mask = jnp.zeros((b, t - 1)).at[:, 4:20].set(1.0)
    lp, _ = model.logprob(SPEC, params, tokens)
    adv = jnp.array([1.0, -0.5, 0.0][:b])
    return tokens, mask, lp, adv


def test_grpo_onpolicy_ratio_is_one(params):
    """behav_lp == current lp ⇒ every ratio is 1 and clip fraction is 0."""
    tokens, mask, lp, adv = _grpo_inputs(params)
    _, metrics = model.grad(SPEC, params, tokens, mask, lp, adv)
    n_tok = float(metrics[6])
    ratio_mean = float(metrics[2]) / n_tok
    clip_frac = float(metrics[4]) / n_tok
    assert abs(ratio_mean - 1.0) < 1e-4
    assert clip_frac == 0.0
    # on-policy loss_sum = -sum(adv per token) = -(1.0 - 0.5 + 0)*16 tokens
    assert abs(float(metrics[0]) - (-(1.0 - 0.5) * 16)) < 1e-3


def test_grpo_loss_ignores_masked_tokens(params):
    tokens, mask, lp, adv = _grpo_inputs(params)
    # Perturb behaviour log-probs OUTSIDE the mask: loss must not change.
    lp_perturbed = lp + (1.0 - mask) * 0.7
    g1, m1 = model.grad(SPEC, params, tokens, mask, lp, adv)
    g2, m2 = model.grad(SPEC, params, tokens, mask, lp_perturbed, adv)
    np.testing.assert_allclose(g1, g2, atol=1e-6)
    assert abs(float(m1[0]) - float(m2[0])) < 1e-5


def test_grpo_zero_advantage_zero_grad(params):
    tokens, mask, lp, _ = _grpo_inputs(params)
    adv = jnp.zeros((3,))
    g, metrics = model.grad(SPEC, params, tokens, mask, lp, adv)
    assert float(jnp.abs(g).max()) == 0.0
    assert float(metrics[7]) == 0.0  # grad_norm


def test_grpo_clipping_engages_off_policy(params):
    """Push behaviour lp far below current lp → ratios clip at 1+eps_high."""
    tokens, mask, lp, adv = _grpo_inputs(params)
    adv = jnp.array([1.0, 1.0, 1.0])
    behav = lp - 2.0  # ratio = e^2 ≈ 7.4 ≫ 1.28 everywhere in the mask
    _, metrics = model.grad(SPEC, params, tokens, mask, behav, adv)
    n_tok = float(metrics[6])
    assert float(metrics[4]) / n_tok == pytest.approx(1.0)  # all clipped
    # objective per token = clip(r)·A = 1.28 ⇒ loss_sum = -1.28·n_tok
    assert float(metrics[0]) == pytest.approx(-1.28 * n_tok, rel=1e-4)


def test_grpo_clipped_offpolicy_grad_is_zero_when_all_clipped(params):
    """When min(r·A, clip(r)·A) selects the constant clipped branch for every
    token, the gradient vanishes — PPO/GRPO's trust-region behaviour."""
    tokens, mask, lp, _ = _grpo_inputs(params)
    adv = jnp.ones((3,))
    g, _ = model.grad(SPEC, params, tokens, mask, lp - 2.0, adv)
    assert float(jnp.abs(g).max()) < 1e-7


def test_grpo_negative_advantage_unclipped_below(params):
    """For A<0 the min() keeps the *unclipped* branch when r > 1+eps (the
    pessimistic side), so the gradient does NOT vanish."""
    tokens, mask, lp, _ = _grpo_inputs(params)
    adv = -jnp.ones((3,))
    g, _ = model.grad(SPEC, params, tokens, mask, lp - 2.0, adv)
    assert float(jnp.abs(g).max()) > 0.0


# ---------------------------------------------------------------------------
# Adam + accumulation
# ---------------------------------------------------------------------------


def _adam_ref(p, m, v, g, t, lr, wd=model.WEIGHT_DECAY):
    b1, b2, eps = model.ADAM_B1, model.ADAM_B2, model.ADAM_EPS
    m2 = b1 * m + (1 - b1) * g
    v2 = b2 * v + (1 - b2) * g * g
    mhat = m2 / (1 - b1**t)
    vhat = v2 / (1 - b2**t)
    return p - lr * (mhat / (np.sqrt(vhat) + eps) + wd * p), m2, v2


def test_adam_matches_numpy_reference():
    rng = np.random.default_rng(0)
    n = 257
    p = rng.normal(size=n).astype(np.float32)
    m = rng.normal(size=n).astype(np.float32) * 0.1
    v = abs(rng.normal(size=n).astype(np.float32)) * 0.01
    g = rng.normal(size=n).astype(np.float32)
    for step in (1, 2, 10):
        got = model.adam_update(
            jnp.array(p), jnp.array(m), jnp.array(v), jnp.array(g),
            jnp.array([step], jnp.int32), jnp.array([1e-3]), jnp.array([1.0]),
        )
        want = _adam_ref(p, m, v, g, step, 1e-3)
        for a, b in zip(got, want):
            np.testing.assert_allclose(a, b, atol=1e-6, rtol=1e-5)


def test_adam_grad_scale_equals_prescaled_grads():
    rng = np.random.default_rng(1)
    n = 64
    p, m, v, g = (jnp.array(rng.normal(size=n), jnp.float32) for _ in range(4))
    a = model.adam_update(p, m, v, g, jnp.array([1], jnp.int32),
                          jnp.array([1e-3]), jnp.array([0.25]))
    b = model.adam_update(p, m, v, g * 0.25, jnp.array([1], jnp.int32),
                          jnp.array([1e-3]), jnp.array([1.0]))
    for x, y in zip(a, b):
        np.testing.assert_allclose(x, y, atol=1e-7)


def test_accum():
    a = jnp.arange(5, dtype=jnp.float32)
    b = jnp.ones(5, jnp.float32)
    out = model.accum(a, b, jnp.array([0.5]))
    np.testing.assert_allclose(out, np.arange(5) + 0.5)


# ---------------------------------------------------------------------------
# artifact wrappers (single flat-array signatures the rust runtime executes)
# ---------------------------------------------------------------------------


def test_init_state_packs_params_and_zero_moments(params):
    state = model.init_state(SPEC, jnp.array([7], jnp.int32))
    n = SPEC.n_params
    assert state.shape == (3 * n,)
    np.testing.assert_array_equal(state[:n], params)
    np.testing.assert_array_equal(state[n:], np.zeros(2 * n))


def test_prefill_decode_artifacts_match_logical_path(params):
    state = model.init_state(SPEC, jnp.array([7], jnp.int32))
    es = jnp.zeros((model.engine_state_elems(SPEC),), jnp.float32)
    prompt = (jnp.arange(SPEC.p_max, dtype=jnp.int32) % 7) + 4
    plen, slot = 6, 1

    es = model.prefill_artifact(
        SPEC, params, es, prompt, jnp.array([plen], jnp.int32), jnp.array([slot], jnp.int32)
    )
    header = SPEC.slots * SPEC.vocab
    hdr = es[:header].reshape(SPEC.slots, SPEC.vocab)

    # Logical path for comparison.
    kv = jnp.zeros((SPEC.kv_elems,), jnp.float32)
    kv, last = model.prefill(
        SPEC, params, kv, prompt, jnp.array([plen], jnp.int32), jnp.array([slot], jnp.int32)
    )
    np.testing.assert_allclose(hdr[slot], last, atol=1e-5)
    np.testing.assert_allclose(es[header:], kv, atol=1e-6)

    toks = jnp.zeros((SPEC.slots,), jnp.int32).at[slot].set(5)
    pos = jnp.zeros((SPEC.slots,), jnp.int32).at[slot].set(plen)
    es2 = model.decode_artifact(SPEC, params, es, toks, pos)
    logits_ref, _ = model.decode(SPEC, params, kv, toks, pos)
    hdr2 = es2[:header].reshape(SPEC.slots, SPEC.vocab)
    np.testing.assert_allclose(hdr2, logits_ref, atol=1e-5, rtol=1e-4)


def test_grad_artifact_tail_is_metrics(params):
    state = model.init_state(SPEC, jnp.array([7], jnp.int32))
    tokens, mask, lp, adv = _grpo_inputs(params)
    out = model.grad_artifact(SPEC, state, tokens, mask, lp, adv)
    assert out.shape == (SPEC.n_params + model.N_METRICS,)
    g, metrics = model.grad(SPEC, params, tokens, mask, lp, adv)
    np.testing.assert_allclose(out[model.N_METRICS :], g, atol=1e-6)
    np.testing.assert_allclose(out[: model.N_METRICS], metrics, atol=1e-5, rtol=1e-5)


def test_update_artifact_roundtrip(params):
    state = model.init_state(SPEC, jnp.array([7], jnp.int32))
    n = SPEC.n_params
    rng = np.random.default_rng(0)
    gt = jnp.array(rng.normal(size=n + model.N_METRICS), jnp.float32)
    out = model.update_artifact(
        SPEC, state, gt, jnp.array([1], jnp.int32), jnp.array([1e-3]), jnp.array([1.0])
    )
    p2, m2, v2 = model.adam_update(
        params, jnp.zeros(n), jnp.zeros(n), gt[model.N_METRICS :],
        jnp.array([1], jnp.int32), jnp.array([1e-3]), jnp.array([1.0]),
    )
    np.testing.assert_allclose(out[:n], p2, atol=1e-7)
    np.testing.assert_allclose(out[n : 2 * n], m2, atol=1e-7)
    np.testing.assert_allclose(out[2 * n :], v2, atol=1e-7)


def test_sft_grad_artifact_decreases_loss(params):
    state = model.init_state(SPEC, jnp.array([7], jnp.int32))
    tokens = jax.random.randint(jax.random.PRNGKey(5), (2, SPEC.t_train), 4, 14)
    mask = jnp.ones((2, SPEC.t_train - 1))
    out = model.sft_grad_artifact(SPEC, state, tokens, mask)
    n = SPEC.n_params
    g = out[model.N_METRICS :]
    loss0 = float(out[0])
    # A small step along -g must reduce the SFT loss.
    p2 = params - 0.1 * g / (jnp.linalg.norm(g) + 1e-9)
    loss1, _ = model.sft_objective(SPEC, p2, tokens, mask)
    assert float(loss1) < loss0
    # padded metric slots are zero
    np.testing.assert_array_equal(out[3 : model.N_METRICS], np.zeros(model.N_METRICS - 3))


# ---------------------------------------------------------------------------
# one tiny RL sanity step: gradient ascent on reward-weighted lp
# ---------------------------------------------------------------------------


def test_grpo_step_increases_positive_advantage_logprob(params):
    """After one SGD-like step on the GRPO objective, the log-prob of
    positively-advantaged trajectories must go up (and vice versa)."""
    tokens, mask, lp, _ = _grpo_inputs(params, b=2, seed=3)
    adv = jnp.array([1.0, -1.0])
    g, metrics = model.grad(SPEC, params, tokens, mask, lp, adv)
    new_params = params - 0.5 * g / (jnp.linalg.norm(g) + 1e-8)
    lp_new, _ = model.logprob(SPEC, new_params, tokens)
    d0 = float(((lp_new - lp) * mask)[0].sum())
    d1 = float(((lp_new - lp) * mask)[1].sum())
    assert d0 > 0, "positive-advantage sequence lp should increase"
    assert d1 < 0, "negative-advantage sequence lp should decrease"


def test_replay_chunk_matches_sequential_decode(params):
    """Chunked re-prefill (replay artifact) must reproduce exactly the KV
    state and next-token logits of feeding the same tokens one-by-one
    through decode — the resumption correctness contract."""
    es0 = jnp.zeros((model.engine_state_elems(SPEC),), jnp.float32)
    prompt = (jnp.arange(SPEC.p_max, dtype=jnp.int32) % 9) + 4
    plen, slot = 5, 1
    es = model.prefill_artifact(
        SPEC, params, es0, prompt, jnp.array([plen], jnp.int32), jnp.array([slot], jnp.int32)
    )

    resume = jnp.array([6, 7, 8, 9, 5, 6, 7], jnp.int32)
    n = resume.shape[0]

    # Path A: sequential decode feeding resume tokens.
    es_seq = es
    toks = jnp.zeros((SPEC.slots,), jnp.int32)
    pos = jnp.zeros((SPEC.slots,), jnp.int32)
    for i in range(n):
        toks = toks.at[slot].set(resume[i])
        pos = pos.at[slot].set(plen + i)
        es_seq = model.decode_artifact(SPEC, params, es_seq, toks, pos)
    header = SPEC.slots * SPEC.vocab
    logits_seq = es_seq[header:].reshape(SPEC.kv_shape()), es_seq[:header].reshape(
        SPEC.slots, SPEC.vocab
    )[slot]

    # Path B: one replay chunk (padded to p_max with garbage).
    chunk = jnp.zeros((SPEC.p_max,), jnp.int32).at[:n].set(resume)
    # Only feed the REAL tokens: replay uses the full chunk, so pass a chunk
    # of exactly n by placing resume at the END? No — replay writes c
    # positions from start; use a full chunk where the last real token is
    # at index n-1 and garbage follows. The garbage corrupts positions
    # >= plen+n which the length mask hides, but the header logits come
    # from chunk index -1 (garbage). So replay with an exact-size chunk:
    es_rep = model.replay_artifact(
        SPEC, params, es, chunk, jnp.array([plen], jnp.int32),
        jnp.array([slot], jnp.int32), jnp.array([n - 1], jnp.int32),
    )
    logits_rep = es_rep[:header].reshape(SPEC.slots, SPEC.vocab)[slot]

    np.testing.assert_allclose(logits_rep, logits_seq[1], atol=2e-4, rtol=2e-4)
    # KV for the replayed positions must match the sequential path.
    kv_seq = logits_seq[0]
    kv_rep = es_rep[header:].reshape(SPEC.kv_shape())
    np.testing.assert_allclose(
        kv_rep[:, :, slot, :, : plen + n, :],
        kv_seq[:, :, slot, :, : plen + n, :],
        atol=2e-4, rtol=2e-4,
    )
