#!/usr/bin/env bash
# Run the micro bench and record BENCH_micro.json at the repo root —
# the repo's perf trajectory file (EXPERIMENTS.md §Perf tracks the table).
#
# The L3 coordination rows (sampler, buffer ops, mock decode, engine step,
# event flush, dispatch clone) need no artifacts; the xla rows appear
# automatically when artifacts/<model>/ exists (COPRIS_BENCH_MODEL).
set -euo pipefail
ROOT="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"
cd "$ROOT"

MANIFEST=""
for c in Cargo.toml rust/Cargo.toml; do
  if [ -f "$c" ]; then
    MANIFEST="$c"
    break
  fi
done
if [ -z "$MANIFEST" ]; then
  echo "bench_micro: no Cargo.toml found under $ROOT" >&2
  exit 1
fi

export COPRIS_BENCH_JSON="$ROOT/BENCH_micro.json"
# The bench targets are harness=false binaries: `cargo bench --bench micro`
# runs micro.rs::main(), which prints the table and writes the JSON fresh.
cargo bench --manifest-path "$MANIFEST" --bench micro "$@"
# resume_affinity, kv_blocks and continuous_batching MERGE their rows into
# the same file idempotently (micro writes `rows` last, so
# bench::merge_bench_rows splices before the closing bracket, replacing any
# stale rows of the same bench).
cargo bench --manifest-path "$MANIFEST" --bench resume_affinity
cargo bench --manifest-path "$MANIFEST" --bench kv_blocks
cargo bench --manifest-path "$MANIFEST" --bench continuous_batching
cargo bench --manifest-path "$MANIFEST" --bench sampler_simd
# async_overlap contributes the serial / pipelined / fully-async wall-clock
# comparison rows (per-step wall + staleness/active cut counters).
cargo bench --manifest-path "$MANIFEST" --bench async_overlap
# slo_harness contributes the open-loop SLO scoreboard rows (three
# "kind":"deterministic" scenario rows gated exactly by
# scripts/bench_check.py, plus one timing row under the legacy ±band).
cargo bench --manifest-path "$MANIFEST" --bench slo_harness
# The CI bench job uploads this file as an artifact; fail loudly if a
# bench silently produced an empty rows[] so the gap can't reopen.
if grep -q '"rows":\[\]' "$COPRIS_BENCH_JSON"; then
  echo "bench_micro: ERROR — $COPRIS_BENCH_JSON has an empty rows[] array" >&2
  exit 1
fi
echo "bench_micro: wrote $COPRIS_BENCH_JSON"
