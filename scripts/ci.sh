#!/usr/bin/env bash
# Tier-1 verification (what .github/workflows/ci.yml runs):
#   cargo build --release --all-targets && cargo doc && cargo test -q
# --all-targets keeps benches/examples/bins compiling so they cannot rot;
# the rustdoc step runs with warnings-as-errors so crate docs (missing_docs
# in the documented module trees, broken intra-doc links — the anchors
# docs/ARCHITECTURE.md points at) cannot rot either.
#
# Modes:
#   scripts/ci.sh            full tier-1 (build + doc + test)
#   scripts/ci.sh --docs     rustdoc gate only (the CI `rustdoc` job)
#   scripts/ci.sh --bench    full tier-1, then refresh BENCH_micro.json
set -euo pipefail
ROOT="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"
cd "$ROOT"

MANIFEST=""
for c in Cargo.toml rust/Cargo.toml; do
  if [ -f "$c" ]; then
    MANIFEST="$c"
    break
  fi
done
if [ -z "$MANIFEST" ]; then
  echo "ci: no Cargo.toml found under $ROOT" >&2
  exit 1
fi

run_docs() {
  echo "== tier-1: cargo doc --no-deps (rustdoc warnings are errors) =="
  RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --manifest-path "$MANIFEST"
}

if [ "${1:-}" = "--docs" ]; then
  run_docs
  echo "ci: docs OK"
  exit 0
fi

echo "== tier-1: cargo build --release --all-targets =="
cargo build --release --all-targets --manifest-path "$MANIFEST"
run_docs
echo "== tier-1: cargo test -q =="
cargo test -q --manifest-path "$MANIFEST"

if [ "${1:-}" = "--bench" ]; then
  echo "== micro + resume_affinity benches → BENCH_micro.json =="
  "$ROOT/scripts/bench_micro.sh"
fi

echo "ci: OK"
