#!/usr/bin/env bash
# Tier-1 verification (what .github/workflows/ci.yml runs):
#   cargo build --release --all-targets && cargo doc && cargo clippy
#   && cargo test -q   (+ a separate `cargo fmt --check` gate)
# --all-targets keeps benches/examples/bins compiling so they cannot rot;
# the rustdoc step runs with warnings-as-errors so crate docs (missing_docs
# in the documented module trees, broken intra-doc links — the anchors
# docs/ARCHITECTURE.md points at) cannot rot either; the clippy step gates
# all targets at -D warnings (a short allow-list below silences the
# noisiest purely-stylistic lints so the gate stays about defects); the fmt
# step enforces rustfmt (settings in rustfmt.toml).
#
# Modes (exactly one, optional):
#   scripts/ci.sh            full tier-1 (build + doc + clippy + test)
#   scripts/ci.sh --fmt      rustfmt gate only (the CI `fmt` job)
#   scripts/ci.sh --docs     rustdoc gate only (the CI `rustdoc` job)
#   scripts/ci.sh --clippy   clippy gate only (the CI `clippy` job)
#   scripts/ci.sh --chaos    fault-injection tests, debug + release (the
#                            CI `chaos` job; release too — supervision
#                            runs catch_unwind/timing paths that behave
#                            differently without debug assertions)
#   scripts/ci.sh --bench    full tier-1, then refresh BENCH_micro.json
#   scripts/ci.sh --slo      open-loop loadgen + SLO harness gate (the CI
#                            `slo` job): bench_check.py self-test, the
#                            loadgen determinism suite under debug AND
#                            release sharing one golden trace file (the
#                            cross-profile bit-identity handshake), then
#                            the slo_harness bench run twice with
#                            bench_check.py --deterministic-only diffing
#                            run 1 against run 2 at zero tolerance
#   scripts/ci.sh --simd     sampler SIMD gate (the CI `simd` matrix job):
#                            runs the sampler/simd differential-fuzz suite
#                            and the engine stream goldens per SIMD_ARM —
#                            `native` builds with -C target-cpu=native so
#                            the avx2/avx512 arms actually dispatch,
#                            `scalar` forces COPRIS_SIMD=scalar to prove
#                            the forced-scalar escape hatch stays golden,
#                            `both` (default) runs the two in sequence
#   scripts/ci.sh --net      router/transport gate (the CI `net` job):
#                            local-vs-multi-process bit-identity goldens
#                            over real loopback sockets plus the
#                            killed-engine-host chaos tests, each run under
#                            a HARD `timeout` so a wedged socket or leaked
#                            link thread fails the gate instead of hanging
#                            it
#   scripts/ci.sh --async    fully-async stream gate (the CI `async` job):
#                            the staleness-0 bit-identity golden, the
#                            bounded-staleness property, and the async
#                            chaos conservation test, debug + release,
#                            under a HARD `timeout` so a stuck stream
#                            (lost batch-ready edge, refill deadlock)
#                            fails the gate instead of hanging it
# Unknown flags exit 2 with this usage instead of silently running full
# tier-1.
set -euo pipefail
ROOT="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"
cd "$ROOT"

usage() {
  echo "usage: scripts/ci.sh [--fmt|--docs|--clippy|--chaos|--bench|--slo|--simd|--net|--async]" >&2
  echo "  (no flag = full tier-1: build + doc + clippy + test)" >&2
  echo "  --simd honors SIMD_ARM=native|scalar|both (default both)" >&2
}

# Validate the mode BEFORE touching the environment: unknown flags exit 2
# with usage instead of silently running full tier-1.
MODE="${1:-}"
case "$MODE" in
  ""|--fmt|--docs|--clippy|--chaos|--bench|--slo|--simd|--net|--async) ;;
  *)
    echo "ci: unknown flag $MODE" >&2
    usage
    exit 2
    ;;
esac
if [ "$#" -gt 1 ]; then
  echo "ci: expected at most one mode flag, got: $*" >&2
  usage
  exit 2
fi

MANIFEST=""
for c in Cargo.toml rust/Cargo.toml; do
  if [ -f "$c" ]; then
    MANIFEST="$c"
    break
  fi
done
if [ -z "$MANIFEST" ]; then
  echo "ci: no Cargo.toml found under $ROOT" >&2
  exit 1
fi

run_fmt() {
  echo "== tier-1: cargo fmt --check =="
  if ! cargo fmt --manifest-path "$MANIFEST" --check; then
    echo "ci: formatting drift — run 'cargo fmt' and commit" >&2
    exit 1
  fi
}

run_docs() {
  echo "== tier-1: cargo doc --no-deps (rustdoc warnings are errors) =="
  RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --manifest-path "$MANIFEST"
}

run_clippy() {
  echo "== tier-1: cargo clippy --all-targets (-D warnings) =="
  # Stylistic lints allowed by policy (they fire on long-standing idioms in
  # this codebase — indexed lockstep loops over parallel slot arrays, the
  # paper's argument-heavy experiment constructors); everything else,
  # including every correctness/suspicious/perf lint, is an error.
  cargo clippy --all-targets --manifest-path "$MANIFEST" -- \
    -D warnings \
    -A clippy::needless_range_loop \
    -A clippy::too_many_arguments \
    -A clippy::type_complexity \
    -A clippy::unnecessary_map_or
}

run_chaos() {
  # Fault-injection suite: engine crash / panic / stall recovery golden
  # tests plus the fault-sweep property. Run under BOTH profiles: debug
  # catches invariant violations via debug_assert, release exercises the
  # real supervisor timing (backoff, stall watchdog) without them.
  echo "== chaos: cargo test --test chaos_recovery (debug) =="
  cargo test -q --manifest-path "$MANIFEST" --test chaos_recovery
  echo "== chaos: cargo test --test chaos_recovery (release) =="
  cargo test --release -q --manifest-path "$MANIFEST" --test chaos_recovery
}

run_slo() {
  # Open-loop loadgen + SLO harness gate, three layers:
  # 1. bench_check.py fixture self-test — the gate that gates must itself
  #    be gated.
  # 2. loadgen determinism suite twice sharing ONE golden trace file:
  #    the debug run writes the canonical trace (arrival schedules + sim
  #    report Debug renderings), the release run must reproduce it
  #    byte-for-byte — bit-identity across build profiles, not just
  #    within one.
  # 3. slo_harness bench twice into two fresh JSON files, then
  #    bench_check.py --deterministic-only diffs run 1 (as baseline)
  #    against run 2 at zero tolerance: every "kind":"deterministic"
  #    scenario row must agree bit-for-bit, no committed baseline needed.
  echo "== slo: bench_check.py --self-test =="
  python3 scripts/bench_check.py --self-test

  local trace
  trace="$(mktemp -t copris_loadgen_trace.XXXXXX)"
  # The test writes the golden on first run (file absent), compares after.
  rm -f "$trace"
  echo "== slo: loadgen_determinism (debug — writes golden trace) =="
  COPRIS_LOADGEN_TRACE="$trace" \
    cargo test -q --manifest-path "$MANIFEST" --test loadgen_determinism
  echo "== slo: loadgen_determinism (release — must match debug trace) =="
  COPRIS_LOADGEN_TRACE="$trace" \
    cargo test --release -q --manifest-path "$MANIFEST" --test loadgen_determinism
  rm -f "$trace"

  local run1 run2
  run1="$(mktemp -t copris_slo_run1.XXXXXX)"
  run2="$(mktemp -t copris_slo_run2.XXXXXX)"
  rm -f "$run1" "$run2"
  echo "== slo: slo_harness double run → exact deterministic-row diff =="
  COPRIS_BENCH_JSON="$run1" cargo bench --manifest-path "$MANIFEST" --bench slo_harness
  COPRIS_BENCH_JSON="$run2" cargo bench --manifest-path "$MANIFEST" --bench slo_harness
  python3 scripts/bench_check.py --deterministic-only --tolerance 0 \
    --baseline "$run1" --fresh "$run2"
  rm -f "$run1" "$run2"
}

# One SIMD verification arm: the sampler + simd unit suites (the
# scalar-vs-SIMD bit-identity fuzz oracle lives there) plus every engine
# stream golden, which pins token/log-prob bits end to end — if a SIMD
# kernel diverged from scalar by one bit, these fail.
simd_test_targets() {
  cargo test -q --manifest-path "$MANIFEST" --lib "$@" engine::sampler:: engine::simd::
  cargo test -q --manifest-path "$MANIFEST" "$@" \
    --test golden_determinism --test rollout_golden --test retained_golden \
    --test continuous_batching
}

run_simd() {
  local arm="${SIMD_ARM:-both}"
  case "$arm" in
    native|scalar|both) ;;
    *)
      echo "ci: SIMD_ARM must be native|scalar|both, got $arm" >&2
      exit 2
      ;;
  esac
  if [ "$arm" = "native" ] || [ "$arm" = "both" ]; then
    # target-cpu=native lets is_x86_feature_detected! actually resolve to
    # avx2/avx512 on capable runners; a separate target dir keeps the
    # differently-flagged artifacts from thrashing the default cache.
    echo "== simd: native arm (RUSTFLAGS=-C target-cpu=native) =="
    RUSTFLAGS="${RUSTFLAGS:-} -C target-cpu=native" \
      CARGO_TARGET_DIR="${CARGO_TARGET_DIR:-target}/simd-native" \
      simd_test_targets
  fi
  if [ "$arm" = "scalar" ] || [ "$arm" = "both" ]; then
    # Forced-scalar escape hatch: the same suites must stay golden when
    # dispatch is pinned below what the host supports.
    echo "== simd: forced-scalar arm (COPRIS_SIMD=scalar) =="
    COPRIS_SIMD=scalar simd_test_targets
  fi
}

run_net() {
  # Router/transport gate: the local-vs-tcp bit-identity goldens
  # (rust/tests/router_transport.rs — the tcp transport runs against real
  # engine-hosts over loopback, threads and a `copris engine-host`
  # subprocess) plus the killed-engine-host chaos tests. Compile first
  # WITHOUT the timeout (a cold build may legitimately take minutes), then
  # hard-cap each test binary run: networked tests must fail loudly on a
  # wedged socket or leaked link thread, never hang the pipeline.
  echo "== net: compiling test targets (uncapped) =="
  cargo test -q --no-run --manifest-path "$MANIFEST" \
    --test router_transport --test chaos_recovery
  echo "== net: router_transport — local vs multi-process bit-identity (10 min cap) =="
  timeout -k 10 600 \
    cargo test -q --manifest-path "$MANIFEST" --test router_transport
  echo "== net: chaos_recovery killed_engine_host (10 min cap) =="
  timeout -k 10 600 \
    cargo test -q --manifest-path "$MANIFEST" --test chaos_recovery killed_engine_host
}

run_async() {
  # Fully-async stream gate: staleness-0 bit-identity vs the pipelined
  # stage sequence, the bounded-staleness segment property, and trajectory
  # conservation when an engine dies mid-stream. Both profiles (debug for
  # the coordinator's debug_asserts, release for real drain/cut timing),
  # each under a HARD cap — a lost batch-ready edge or a refill deadlock
  # must fail loudly, never hang the pipeline.
  echo "== async: compiling test targets (uncapped) =="
  cargo test -q --no-run --manifest-path "$MANIFEST" \
    --test rollout_golden --test chaos_recovery
  cargo test --release -q --no-run --manifest-path "$MANIFEST" \
    --test rollout_golden --test chaos_recovery
  echo "== async: rollout_golden async_ goldens (debug, 10 min cap) =="
  timeout -k 10 600 \
    cargo test -q --manifest-path "$MANIFEST" --test rollout_golden async_
  echo "== async: chaos_recovery async-stream conservation (debug, 10 min cap) =="
  timeout -k 10 600 \
    cargo test -q --manifest-path "$MANIFEST" --test chaos_recovery async_stream
  echo "== async: rollout_golden async_ goldens (release, 10 min cap) =="
  timeout -k 10 600 \
    cargo test --release -q --manifest-path "$MANIFEST" --test rollout_golden async_
  echo "== async: chaos_recovery async-stream conservation (release, 10 min cap) =="
  timeout -k 10 600 \
    cargo test --release -q --manifest-path "$MANIFEST" --test chaos_recovery async_stream
}

run_full() {
  # NOTE: fmt stays a separate gate (scripts/ci.sh --fmt / the CI `fmt`
  # job, blocking) rather than part of full tier-1, so formatting drift
  # never masks build/test signal.
  echo "== tier-1: cargo build --release --all-targets =="
  cargo build --release --all-targets --manifest-path "$MANIFEST"
  run_docs
  run_clippy
  echo "== tier-1: cargo test -q =="
  cargo test -q --manifest-path "$MANIFEST"
  # The release-gated allocator guard test is dead code under the debug
  # profile `cargo test` uses; run it in release too (nearly free — the
  # --release --all-targets build above already compiled the test targets).
  echo "== tier-1: release-profile guard tests =="
  cargo test --release -q --manifest-path "$MANIFEST" release_of_free_block
}

# Single-case mode dispatch (the manifest probe above runs once for every
# mode; no duplicated dispatch tail).
case "$MODE" in
  --fmt)
    run_fmt
    echo "ci: fmt OK"
    ;;
  --docs)
    run_docs
    echo "ci: docs OK"
    ;;
  --clippy)
    run_clippy
    echo "ci: clippy OK"
    ;;
  --chaos)
    run_chaos
    echo "ci: chaos OK"
    ;;
  --simd)
    run_simd
    echo "ci: simd OK"
    ;;
  --bench)
    run_full
    echo "== micro + resume_affinity + kv_blocks + continuous_batching + sampler_simd + async_overlap + slo_harness benches → BENCH_micro.json =="
    "$ROOT/scripts/bench_micro.sh"
    echo "ci: OK"
    ;;
  --slo)
    run_slo
    echo "ci: slo OK"
    ;;
  --net)
    run_net
    echo "ci: net OK"
    ;;
  --async)
    run_async
    echo "ci: async OK"
    ;;
  "")
    run_full
    echo "ci: OK"
    ;;
esac
