#!/usr/bin/env bash
# Tier-1 verification (what .github/workflows/ci.yml runs):
#   cargo build --release --all-targets && cargo doc && cargo clippy && cargo test -q
# --all-targets keeps benches/examples/bins compiling so they cannot rot;
# the rustdoc step runs with warnings-as-errors so crate docs (missing_docs
# in the documented module trees, broken intra-doc links — the anchors
# docs/ARCHITECTURE.md points at) cannot rot either; the clippy step gates
# all targets at -D warnings (a short allow-list below silences the
# noisiest purely-stylistic lints so the gate stays about defects).
#
# Modes:
#   scripts/ci.sh            full tier-1 (build + doc + clippy + test)
#   scripts/ci.sh --docs     rustdoc gate only (the CI `rustdoc` job)
#   scripts/ci.sh --clippy   clippy gate only (the CI `clippy` job)
#   scripts/ci.sh --bench    full tier-1, then refresh BENCH_micro.json
set -euo pipefail
ROOT="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"
cd "$ROOT"

MANIFEST=""
for c in Cargo.toml rust/Cargo.toml; do
  if [ -f "$c" ]; then
    MANIFEST="$c"
    break
  fi
done
if [ -z "$MANIFEST" ]; then
  echo "ci: no Cargo.toml found under $ROOT" >&2
  exit 1
fi

run_docs() {
  echo "== tier-1: cargo doc --no-deps (rustdoc warnings are errors) =="
  RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --manifest-path "$MANIFEST"
}

run_clippy() {
  echo "== tier-1: cargo clippy --all-targets (-D warnings) =="
  # Stylistic lints allowed by policy (they fire on long-standing idioms in
  # this codebase — indexed lockstep loops over parallel slot arrays, the
  # paper's argument-heavy experiment constructors); everything else,
  # including every correctness/suspicious/perf lint, is an error.
  cargo clippy --all-targets --manifest-path "$MANIFEST" -- \
    -D warnings \
    -A clippy::needless_range_loop \
    -A clippy::too_many_arguments \
    -A clippy::type_complexity \
    -A clippy::unnecessary_map_or
}

if [ "${1:-}" = "--docs" ]; then
  run_docs
  echo "ci: docs OK"
  exit 0
fi

if [ "${1:-}" = "--clippy" ]; then
  run_clippy
  echo "ci: clippy OK"
  exit 0
fi

echo "== tier-1: cargo build --release --all-targets =="
cargo build --release --all-targets --manifest-path "$MANIFEST"
run_docs
run_clippy
echo "== tier-1: cargo test -q =="
cargo test -q --manifest-path "$MANIFEST"

if [ "${1:-}" = "--bench" ]; then
  echo "== micro + resume_affinity benches → BENCH_micro.json =="
  "$ROOT/scripts/bench_micro.sh"
fi

echo "ci: OK"
