#!/usr/bin/env bash
# Tier-1 verification (what .github/workflows/ci.yml runs):
#   cargo build --release --all-targets && cargo test -q
# --all-targets keeps benches/examples/bins compiling so they cannot rot.
#
# Optional: `scripts/ci.sh --bench` additionally runs the micro bench and
# refreshes BENCH_micro.json (the repo's perf trajectory file).
set -euo pipefail
ROOT="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"
cd "$ROOT"

MANIFEST=""
for c in Cargo.toml rust/Cargo.toml; do
  if [ -f "$c" ]; then
    MANIFEST="$c"
    break
  fi
done
if [ -z "$MANIFEST" ]; then
  echo "ci: no Cargo.toml found under $ROOT" >&2
  exit 1
fi

echo "== tier-1: cargo build --release --all-targets =="
cargo build --release --all-targets --manifest-path "$MANIFEST"
echo "== tier-1: cargo test -q =="
cargo test -q --manifest-path "$MANIFEST"

if [ "${1:-}" = "--bench" ]; then
  echo "== micro bench → BENCH_micro.json =="
  "$ROOT/scripts/bench_micro.sh"
fi

echo "ci: OK"
