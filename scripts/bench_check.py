#!/usr/bin/env python3
"""Bench regression guard (stdlib only).

Compares a freshly generated BENCH_micro.json against the committed
BENCH_baseline.json and fails (exit 1) when any timing regresses past the
tolerance, or when a baseline row disappeared from the fresh run (a bench
silently dropped is a regression too).

Rules:
  - rows are matched by their "path" field;
  - timing fields ("mean_s", "p95_s") regress when
        fresh > baseline * (1 + tolerance);
    improvements are reported but never fail;
  - deterministic counter fields listed in EXACT_FIELDS (simulated
    utilization, unit/token counts from the mock benches — same seeds,
    same counters on any hardware) must match the baseline exactly when
    both sides carry them;
  - fresh rows absent from the baseline are reported as NEW (seed them by
    copying the CI artifact over BENCH_baseline.json);
  - an EMPTY baseline rows[] while the fresh run has rows FAILS (exit 1)
    with a loud warning: an unseeded baseline gates nothing, and silently
    passing it is how regressions land unguarded. Seed it by copying a CI
    run's BENCH_micro artifact over BENCH_baseline.json.

Usage:
  scripts/bench_check.py [--baseline BENCH_baseline.json]
                         [--fresh BENCH_micro.json]
                         [--tolerance 0.30]
"""

import argparse
import json
import sys

TIMING_FIELDS = ("mean_s", "p95_s")
# Counter metrics that are deterministic given the benches' fixed seeds
# (mock backends, no thread races in the counted quantities).
EXACT_FIELDS = ("step_token_util", "units", "total_tokens")


def load_rows(path):
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except FileNotFoundError:
        print(f"bench_check: {path} not found", file=sys.stderr)
        sys.exit(1)
    except json.JSONDecodeError as e:
        print(f"bench_check: {path} is not valid JSON: {e}", file=sys.stderr)
        sys.exit(1)
    rows = doc.get("rows", [])
    return {r["path"]: r for r in rows if "path" in r}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument("--fresh", default="BENCH_micro.json")
    ap.add_argument("--tolerance", type=float, default=0.30)
    args = ap.parse_args()

    base = load_rows(args.baseline)
    fresh = load_rows(args.fresh)

    if not fresh:
        print(f"bench_check: {args.fresh} has no rows — did the benches run?")
        return 1
    if not base:
        # One loud line on stderr: an empty baseline while the fresh run
        # produced rows means the gate is checking nothing — that is a
        # failure, not a seeding grace period (the old PASS here let
        # regressions land unguarded indefinitely).
        print(
            f"bench_check: WARNING — {args.baseline} has no rows but "
            f"{args.fresh} has {len(fresh)}: the regression gate is "
            f"UNSEEDED and gating nothing; FAIL. Seed it with "
            f"`cp {args.fresh} {args.baseline}` (or copy the CI "
            f"BENCH_micro artifact over it) and commit to arm the "
            f"±{args.tolerance:.0%} gate.",
            file=sys.stderr,
        )
        return 1

    failures = []
    notes = []
    for path, brow in sorted(base.items()):
        frow = fresh.get(path)
        if frow is None:
            failures.append(f"MISSING  {path!r}: present in baseline, absent from fresh run")
            continue
        for field in TIMING_FIELDS:
            if field not in brow or field not in frow:
                continue
            b, f = float(brow[field]), float(frow[field])
            if b <= 0.0:
                continue
            ratio = f / b
            if ratio > 1.0 + args.tolerance:
                failures.append(
                    f"REGRESSED  {path!r} {field}: {f:.6f}s vs baseline "
                    f"{b:.6f}s ({ratio:.2f}x > {1 + args.tolerance:.2f}x)"
                )
            elif ratio < 1.0 - args.tolerance:
                notes.append(f"improved  {path!r} {field}: {ratio:.2f}x of baseline")
        for field in EXACT_FIELDS:
            if field not in brow or field not in frow:
                continue
            if frow[field] != brow[field]:
                failures.append(
                    f"DRIFTED  {path!r} {field}: {frow[field]!r} vs baseline "
                    f"{brow[field]!r} (deterministic counter must match exactly)"
                )
    for path in sorted(set(fresh) - set(base)):
        notes.append(f"new row  {path!r} (not in baseline — re-seed to start gating it)")

    for n in notes:
        print(f"bench_check: {n}")
    if failures:
        for f in failures:
            print(f"bench_check: {f}", file=sys.stderr)
        print(
            f"bench_check: FAIL — {len(failures)} regression(s) beyond "
            f"±{args.tolerance:.0%}",
            file=sys.stderr,
        )
        return 1
    print(
        f"bench_check: OK — {len(base)} baselined rows within "
        f"±{args.tolerance:.0%} ({len(set(fresh) - set(base))} new)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
