#!/usr/bin/env python3
"""Bench regression guard (stdlib only).

Compares a freshly generated BENCH_micro.json against the committed
BENCH_baseline.json and fails (exit 1) when any timing regresses past the
tolerance, when a deterministic row drifts at all, or when a baseline row
disappeared from the fresh run (a bench silently dropped is a regression
too).

Row kinds (per-row "kind" field):
  - "timing" (or kind absent — the legacy rows): hardware-dependent.
    Timing fields ("mean_s", "p95_s") regress when
        fresh > baseline * (1 + tolerance);
    improvements are reported but never fail. The deterministic counter
    fields in EXACT_FIELDS must still match exactly when both sides carry
    them. These rows need a SEEDED baseline (copy a CI BENCH_micro
    artifact over BENCH_baseline.json) before they gate anything.
  - "deterministic": seed-pinned counters/percentiles on a virtual clock
    (e.g. the slo_harness scenario rows). EVERY shared field except
    "path"/"kind" must match the baseline exactly — no tolerance band.
    Because two fresh runs of the same build must agree bit-for-bit,
    these rows are gateable immediately via --deterministic-only: run the
    bench twice and compare run 1 (as --baseline) against run 2, no
    committed baseline required.

Shared rules:
  - rows are matched by their "path" field;
  - fresh rows absent from the baseline are reported as NEW;
  - an EMPTY baseline rows[] while the fresh run has rows FAILS (exit 1)
    with a loud warning: an unseeded baseline gates nothing, and silently
    passing it is how regressions land unguarded.

Usage:
  scripts/bench_check.py [--baseline BENCH_baseline.json]
                         [--fresh BENCH_micro.json]
                         [--tolerance 0.30]
                         [--deterministic-only]
  scripts/bench_check.py --self-test
"""

import argparse
import json
import sys

TIMING_FIELDS = ("mean_s", "p95_s")
# Counter metrics that are deterministic given the benches' fixed seeds
# (mock backends, no thread races in the counted quantities) even inside
# otherwise timing-kind rows.
EXACT_FIELDS = ("step_token_util", "units", "total_tokens")
# Row-identity fields never compared as data.
META_FIELDS = ("path", "kind")


def load_rows(path):
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except FileNotFoundError:
        print(f"bench_check: {path} not found", file=sys.stderr)
        sys.exit(1)
    except json.JSONDecodeError as e:
        print(f"bench_check: {path} is not valid JSON: {e}", file=sys.stderr)
        sys.exit(1)
    rows = doc.get("rows", [])
    return {r["path"]: r for r in rows if "path" in r}


def is_deterministic(row):
    return row.get("kind") == "deterministic"


def compare(base, fresh, tolerance, deterministic_only=False):
    """Compare row dicts; returns (failures, notes) as string lists."""
    if deterministic_only:
        base = {p: r for p, r in base.items() if is_deterministic(r)}
        fresh = {p: r for p, r in fresh.items() if is_deterministic(r)}
    failures = []
    notes = []
    for path, brow in sorted(base.items()):
        frow = fresh.get(path)
        if frow is None:
            failures.append(f"MISSING  {path!r}: present in baseline, absent from fresh run")
            continue
        if is_deterministic(brow) or is_deterministic(frow):
            if brow.get("kind") != frow.get("kind"):
                failures.append(
                    f"KIND  {path!r}: baseline kind {brow.get('kind')!r} vs "
                    f"fresh {frow.get('kind')!r}"
                )
                continue
            # Every shared data field must match bit-for-bit; a field
            # present on only one side is drift too (a metric silently
            # appearing or vanishing).
            keys = (set(brow) | set(frow)) - set(META_FIELDS)
            for field in sorted(keys):
                if field not in brow or field not in frow:
                    failures.append(
                        f"DRIFTED  {path!r} {field}: present on only one side "
                        f"(deterministic rows must share every field)"
                    )
                elif frow[field] != brow[field]:
                    failures.append(
                        f"DRIFTED  {path!r} {field}: {frow[field]!r} vs baseline "
                        f"{brow[field]!r} (deterministic row must match exactly)"
                    )
            continue
        for field in TIMING_FIELDS:
            if field not in brow or field not in frow:
                continue
            b, f = float(brow[field]), float(frow[field])
            if b <= 0.0:
                continue
            ratio = f / b
            if ratio > 1.0 + tolerance:
                failures.append(
                    f"REGRESSED  {path!r} {field}: {f:.6f}s vs baseline "
                    f"{b:.6f}s ({ratio:.2f}x > {1 + tolerance:.2f}x)"
                )
            elif ratio < 1.0 - tolerance:
                notes.append(f"improved  {path!r} {field}: {ratio:.2f}x of baseline")
        for field in EXACT_FIELDS:
            if field not in brow or field not in frow:
                continue
            if frow[field] != brow[field]:
                failures.append(
                    f"DRIFTED  {path!r} {field}: {frow[field]!r} vs baseline "
                    f"{brow[field]!r} (deterministic counter must match exactly)"
                )
    for path in sorted(set(fresh) - set(base)):
        notes.append(f"new row  {path!r} (not in baseline — re-seed to start gating it)")
    return failures, notes


def self_test():
    """Exercise both row kinds through compare(); exit 0 iff all pass."""
    t_row = {"path": "micro/x", "mean_s": 1.0, "p95_s": 1.2, "units": 5}
    d_row = {
        "path": "slo poisson steady",
        "kind": "deterministic",
        "arrived": 200,
        "goodput_rps": 123.25,
    }
    checks = []

    def check(name, failures, want_fail_substr=None):
        if want_fail_substr is None:
            ok = not failures
            detail = failures
        else:
            ok = any(want_fail_substr in f for f in failures)
            detail = failures or ["<no failures>"]
        checks.append((name, ok, detail))

    base = {r["path"]: r for r in (t_row, d_row)}

    # Identical documents pass in both modes.
    f0, _ = compare(base, json.loads(json.dumps(base)), 0.30)
    check("identical docs pass", f0)
    f0, _ = compare(base, json.loads(json.dumps(base)), 0.0, deterministic_only=True)
    check("identical docs pass (deterministic-only)", f0)

    # Timing within the band passes; beyond it fails; improvements pass.
    fresh = json.loads(json.dumps(base))
    fresh["micro/x"]["mean_s"] = 1.25
    f1, _ = compare(base, fresh, 0.30)
    check("timing within band passes", f1)
    fresh["micro/x"]["mean_s"] = 1.5
    f2, _ = compare(base, fresh, 0.30)
    check("timing beyond band fails", f2, "REGRESSED")
    fresh["micro/x"]["mean_s"] = 0.4
    f3, notes3 = compare(base, fresh, 0.30)
    check("timing improvement passes", f3)
    checks.append(("improvement is noted", any("improved" in n for n in notes3), notes3))

    # Exact counter inside a timing row must not drift.
    fresh = json.loads(json.dumps(base))
    fresh["micro/x"]["units"] = 6
    f4, _ = compare(base, fresh, 0.30)
    check("timing-row exact counter drift fails", f4, "DRIFTED")

    # Deterministic rows: ANY field change fails, even a tiny float one
    # that a timing band would wave through.
    fresh = json.loads(json.dumps(base))
    fresh["slo poisson steady"]["goodput_rps"] = 123.26
    f5, _ = compare(base, fresh, 0.30)
    check("deterministic float drift fails", f5, "DRIFTED")
    f5d, _ = compare(base, fresh, 0.30, deterministic_only=True)
    check("deterministic drift fails in deterministic-only mode", f5d, "DRIFTED")

    # Deterministic rows: a vanishing or appearing field is drift.
    fresh = json.loads(json.dumps(base))
    del fresh["slo poisson steady"]["arrived"]
    f6, _ = compare(base, fresh, 0.30)
    check("deterministic missing field fails", f6, "only one side")

    # deterministic-only ignores timing rows entirely.
    fresh = json.loads(json.dumps(base))
    fresh["micro/x"]["mean_s"] = 99.0
    f7, _ = compare(base, fresh, 0.0, deterministic_only=True)
    check("deterministic-only ignores timing regressions", f7)

    # A missing baseline row fails in both modes.
    fresh = json.loads(json.dumps(base))
    del fresh["slo poisson steady"]
    f8, _ = compare(base, fresh, 0.30)
    check("missing row fails", f8, "MISSING")
    f8d, _ = compare(base, fresh, 0.30, deterministic_only=True)
    check("missing deterministic row fails in deterministic-only mode", f8d, "MISSING")

    bad = [(n, d) for n, ok, d in checks if not ok]
    for name, ok, _ in checks:
        print(f"bench_check self-test: {'ok  ' if ok else 'FAIL'} {name}")
    if bad:
        for name, detail in bad:
            print(f"bench_check self-test: FAILED {name}: {detail}", file=sys.stderr)
        return 1
    print(f"bench_check self-test: OK — {len(checks)} checks")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument("--fresh", default="BENCH_micro.json")
    ap.add_argument("--tolerance", type=float, default=0.30)
    ap.add_argument(
        "--deterministic-only",
        action="store_true",
        help="compare only kind=deterministic rows (two-fresh-run gating; "
        "no committed baseline needed)",
    )
    ap.add_argument("--self-test", action="store_true", help="run the built-in fixture checks")
    args = ap.parse_args()

    if args.self_test:
        return self_test()

    base = load_rows(args.baseline)
    fresh = load_rows(args.fresh)
    if args.deterministic_only:
        base = {p: r for p, r in base.items() if is_deterministic(r)}
        fresh = {p: r for p, r in fresh.items() if is_deterministic(r)}
        label = "deterministic rows"
    else:
        label = "rows"

    if not fresh:
        print(f"bench_check: {args.fresh} has no {label} — did the benches run?")
        return 1
    if not base:
        # One loud line on stderr: an empty baseline while the fresh run
        # produced rows means the gate is checking nothing — that is a
        # failure, not a seeding grace period (the old PASS here let
        # regressions land unguarded indefinitely).
        print(
            f"bench_check: WARNING — {args.baseline} has no {label} but "
            f"{args.fresh} has {len(fresh)}: the regression gate is "
            f"UNSEEDED and gating nothing; FAIL. Seed it with "
            f"`python3 scripts/seed_baseline.py --artifact {args.fresh}` "
            f"(validates the rows and records provenance; use a trusted CI "
            f"BENCH_micro artifact) and commit to arm the "
            f"±{args.tolerance:.0%} gate.",
            file=sys.stderr,
        )
        return 1

    failures, notes = compare(base, fresh, args.tolerance)
    for n in notes:
        print(f"bench_check: {n}")
    if failures:
        for f in failures:
            print(f"bench_check: {f}", file=sys.stderr)
        print(
            f"bench_check: FAIL — {len(failures)} regression(s) beyond "
            f"±{args.tolerance:.0%} (deterministic rows: exact)",
            file=sys.stderr,
        )
        return 1
    n_det = sum(1 for r in base.values() if is_deterministic(r))
    print(
        f"bench_check: OK — {len(base)} baselined {label} within "
        f"±{args.tolerance:.0%} ({n_det} deterministic, exact; "
        f"{len(set(fresh) - set(base))} new)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
