#!/usr/bin/env python3
"""Seed BENCH_baseline.json from a CI bench artifact (stdlib only).

The bench-regression gate (scripts/bench_check.py) compares fresh
BENCH_micro.json runs against the committed BENCH_baseline.json and FAILS
LOUDLY while the baseline is unseeded (rows[] empty). This script is the
seeding step: it validates a trusted run's BENCH_micro artifact — rows
present, every row carrying the "path" identity bench_check matches on —
and writes it over the baseline with provenance recorded, ready to commit.

Flow (documented in .github/workflows/ci.yml next to the bench-micro job):
  1. download the `BENCH_micro` artifact from a trusted bench-micro run on
     CI hardware (timings from laptops or busy containers make the ±30%
     band meaningless);
  2. python3 scripts/seed_baseline.py --artifact BENCH_micro.json
     (add --force when a previously seeded baseline is being re-seeded,
     e.g. after CI hardware changed or a new bench row landed);
  3. commit the updated BENCH_baseline.json — the gate is armed from the
     next CI run on.

An already-ARMED baseline (non-empty rows) is never overwritten without
--force: re-seeding resets the regression reference, which should be a
deliberate, reviewed act, not a side effect.

Usage:
  scripts/seed_baseline.py [--artifact BENCH_micro.json]
                           [--baseline BENCH_baseline.json]
                           [--force]
"""

import argparse
import json
import sys


def fail(msg):
    print(f"seed_baseline: {msg}", file=sys.stderr)
    sys.exit(1)


def load_doc(path):
    try:
        with open(path, encoding="utf-8") as f:
            return json.load(f)
    except FileNotFoundError:
        fail(f"{path} not found")
    except json.JSONDecodeError as e:
        fail(f"{path} is not valid JSON: {e}")


def validate_artifact(doc, path):
    """The artifact must hold gateable rows: a non-empty rows[] where every
    row is an object with the "path" identity field bench_check.py keys on.
    Seeding an empty or malformed artifact would disarm the gate while
    looking like it armed it — the exact failure mode the loud unseeded
    check exists to prevent."""
    rows = doc.get("rows")
    if not isinstance(rows, list) or not rows:
        fail(
            f"{path} has no rows — seed from a POPULATED BENCH_micro "
            f"artifact produced by scripts/bench_micro.sh on CI hardware, "
            f"not the placeholder committed in-tree"
        )
    for i, row in enumerate(rows):
        if not isinstance(row, dict) or not isinstance(row.get("path"), str) or not row["path"]:
            fail(f"{path} rows[{i}] has no string 'path' field: {row!r}")
    paths = [r["path"] for r in rows]
    dupes = sorted({p for p in paths if paths.count(p) > 1})
    if dupes:
        fail(f"{path} has duplicate row paths {dupes} — rows are matched by path")
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--artifact", default="BENCH_micro.json", help="CI bench artifact to seed from")
    ap.add_argument("--baseline", default="BENCH_baseline.json", help="baseline file to write")
    ap.add_argument(
        "--force",
        action="store_true",
        help="overwrite a baseline that already has rows (re-seeding)",
    )
    args = ap.parse_args()

    artifact = load_doc(args.artifact)
    rows = validate_artifact(artifact, args.artifact)

    try:
        with open(args.baseline, encoding="utf-8") as f:
            existing = json.load(f)
    except FileNotFoundError:
        existing = None
    except json.JSONDecodeError:
        existing = None  # corrupt baseline: overwriting it is an upgrade
    if existing is not None and existing.get("rows") and not args.force:
        fail(
            f"{args.baseline} is already seeded with {len(existing['rows'])} "
            f"row(s); re-seeding resets the regression reference — pass "
            f"--force if that is intended"
        )

    n_det = sum(1 for r in rows if r.get("kind") == "deterministic")
    doc = {
        "bench": "baseline",
        "generated_by": "scripts/seed_baseline.py",
        "seeded_from": args.artifact,
        # Carry the artifact's own provenance fields through so a committed
        # baseline says which bench run produced it.
        "source_generated_by": artifact.get("generated_by"),
        "source_status": artifact.get("status"),
        "rows": rows,
    }
    with open(args.baseline, "w", encoding="utf-8") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    print(
        f"seed_baseline: wrote {args.baseline} with {len(rows)} row(s) "
        f"({n_det} deterministic) from {args.artifact} — commit it to arm "
        f"the bench-regression gate"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
