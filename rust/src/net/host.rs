//! Engine-host process mode: an [`EnginePool`] behind a socket.
//!
//! `copris engine-host --listen ADDR` runs [`serve`]: accept a router
//! connection, handshake, spawn the engines, then pump frames both ways
//! until the router says goodbye (or the link drops). The host is
//! deliberately dumb — ALL scheduling intelligence (routing, retention
//! affinity, failure recovery) stays router-side; the host only
//! translates frames to channel sends and back:
//!
//! * `Hello { engine_base, seed }` → engines are spawned via
//!   [`EnginePool::spawn_supervised_at`] with POOL-GLOBAL ids
//!   `engine_base..engine_base+n` and the ROUTER's seed, so every event
//!   crosses the wire untranslated and each engine's RNG stream is
//!   bit-identical to the one a single local pool would give that id.
//!   This is the mechanism behind the local-vs-tcp golden pin.
//! * `Cmd { engine, cmd }` → `pool.send(engine - engine_base, cmd)`
//!   (the pool's sender array is locally indexed).
//! * pool events → `Event` frames, in channel order, over one writer.
//! * `Ping` → `Pong` (router heartbeats); `Goodbye`/EOF → orderly
//!   teardown (engines joined, socket closed).
//!
//! Chaos hooks: `crash_after_events` severs the link (and, with
//! `crash_exit`, kills the process with exit code 9) after forwarding
//! exactly N event frames — a deterministic "host died mid-stage" for
//! the chaos suite and CI.

use std::io::{BufReader, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::engine::{EngineOpts, EnginePool, MockBackend, SupervisorOpts, XlaBackend};
use crate::net::wire::{self, WireMsg, PROTO_VERSION};

/// Which backend the host builds inside each engine thread.
#[derive(Clone)]
pub enum HostBackend {
    /// Deterministic scripted mock (tests, goldens, chaos, benches).
    Mock {
        /// Scripted minimum response length.
        min_len: usize,
        /// Scripted response-length spread (length = min + hash % spread).
        spread: usize,
        /// Artificial per-decode-call delay in microseconds (0 = none);
        /// lets loopback benches model nontrivial step times.
        decode_delay_us: u64,
        /// Mock sequence horizon (slot capacity per sequence).
        max_seq: usize,
    },
    /// Real AOT-compiled model artifacts (see [`XlaBackend::open`]).
    Xla {
        /// Artifacts directory holding compiled model variants.
        artifacts_dir: String,
        /// Model variant name under the artifacts dir.
        model: String,
        /// Chunked-prefill replay flag (mirrors `engine.chunked_replay`).
        chunked_replay: bool,
        /// Initial parameter vector uploaded at engine build.
        init_params: Arc<Vec<f32>>,
    },
}

/// Everything a host needs to serve one router connection.
#[derive(Clone)]
pub struct HostConfig {
    /// Engines this host contributes to the fleet.
    pub engines: usize,
    /// Decode slots per engine (must match the rest of the fleet).
    pub slots: usize,
    /// Paged-KV + step-budget options for each engine.
    pub engine_opts: EngineOpts,
    /// Supervision policy (retry budget, backoff, stall watchdog).
    pub sup: SupervisorOpts,
    /// Backend each engine thread builds.
    pub backend: HostBackend,
    /// Chaos hook: sever the link after forwarding exactly N event
    /// frames (`None` = never).
    pub crash_after_events: Option<u64>,
    /// With `crash_after_events`: kill the whole process (exit code 9)
    /// instead of just severing — the subprocess-kill chaos test.
    pub crash_exit: bool,
}

/// Accept router connections and serve them sequentially (one at a
/// time — a host belongs to one router). With `once`, return after the
/// first connection ends; otherwise keep accepting until accept fails.
pub fn serve(listener: TcpListener, hc: HostConfig, once: bool) -> Result<()> {
    loop {
        let (stream, peer) = listener.accept().context("accepting router connection")?;
        eprintln!("engine-host: router connected from {peer}");
        match serve_connection(stream, hc.clone()) {
            Ok(()) => eprintln!("engine-host: router {peer} disconnected"),
            Err(e) => eprintln!("engine-host: connection from {peer} failed: {e:#}"),
        }
        if once {
            return Ok(());
        }
    }
}

/// Serve one router connection end-to-end: handshake, spawn the pool,
/// pump frames until Goodbye/EOF, tear down.
pub fn serve_connection(stream: TcpStream, hc: HostConfig) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut rd = BufReader::new(stream.try_clone().context("cloning host reader")?);

    // 1. Handshake: the router tells us our global id base and the seed.
    let hello = wire::read_msg(&mut rd).context("awaiting Hello")?;
    let WireMsg::Hello { proto, engine_base, seed } = hello else {
        bail!("expected Hello as the first frame");
    };
    ensure!(
        proto == PROTO_VERSION,
        "router speaks protocol v{proto}, this host speaks v{PROTO_VERSION}"
    );
    let base = usize::try_from(engine_base).context("engine base")?;

    // 2. Spawn the engines with their pool-global ids (see module docs).
    let mut pool = spawn_pool(&hc, base, seed)?;
    let ev_rx = pool.take_events();

    // 3. Ack with our capacity; the router sizes its routing table off
    //    this.
    let ack = WireMsg::HelloAck {
        proto: PROTO_VERSION,
        engines: hc.engines as u64,
        slots: hc.slots as u64,
    };
    {
        let mut w = stream.try_clone().context("cloning ack writer")?;
        wire::write_msg(&mut w, &ack).context("sending HelloAck")?;
    }

    // 4. Single writer thread owns the socket's write half; the event
    //    pump and the reader (for Pongs) both feed it pre-encoded frames
    //    through a channel, so frames never interleave.
    let (out_tx, out_rx) = channel::<Vec<u8>>();
    let writer = {
        let mut w = stream.try_clone().context("cloning frame writer")?;
        std::thread::Builder::new()
            .name("host-writer".into())
            .spawn(move || {
                while let Ok(frame) = out_rx.recv() {
                    // On a dead link keep draining silently so senders
                    // never observe an error (channel is unbounded).
                    let _ = w.write_all(&frame);
                }
            })
            .context("spawning host writer")?
    };

    // 5. Event pump: pool events → Event frames, in channel order.
    let pump = {
        let out_tx = out_tx.clone();
        let sever = stream.try_clone().context("cloning chaos stream")?;
        let crash_after = hc.crash_after_events;
        let crash_exit = hc.crash_exit;
        std::thread::Builder::new()
            .name("host-pump".into())
            .spawn(move || {
                let mut sent = 0u64;
                while let Ok(ev) = ev_rx.recv() {
                    if let Some(n) = crash_after {
                        if sent >= n {
                            // Deterministic chaos: exactly n event frames
                            // made it out, then the host "dies".
                            let _ = sever.shutdown(Shutdown::Both);
                            if crash_exit {
                                std::process::exit(9);
                            }
                            return;
                        }
                    }
                    let frame = wire::encode(&WireMsg::Event(ev));
                    sent += 1;
                    if out_tx.send(frame).is_err() {
                        return;
                    }
                }
            })
            .context("spawning host event pump")?
    };

    // 6. Reader loop on this thread: commands in, pongs out.
    let n = hc.engines;
    loop {
        match wire::read_msg(&mut rd) {
            Ok(WireMsg::Cmd { engine, cmd }) => {
                let e = usize::try_from(engine).unwrap_or(usize::MAX);
                if e < base || e >= base + n {
                    eprintln!("engine-host: cmd for engine {e} outside [{base}, {})", base + n);
                    continue;
                }
                pool.send(e - base, cmd);
            }
            Ok(WireMsg::Ping { seq }) => {
                let _ = out_tx.send(wire::encode(&WireMsg::Pong { seq }));
            }
            Ok(WireMsg::Goodbye) => break,
            Ok(_) => {
                eprintln!("engine-host: unexpected frame from router; closing");
                break;
            }
            Err(_) => break, // EOF or link error — either way, tear down
        }
    }

    // 7. Teardown: joining the pool drops the engines' event senders,
    //    which ends the pump; dropping our out_tx (after the pump's
    //    clone dies) ends the writer.
    drop(out_tx);
    pool.shutdown();
    let _ = pump.join();
    let _ = writer.join();
    let _ = stream.shutdown(Shutdown::Both);
    Ok(())
}

/// Spawn this host's engine pool at the router-assigned id base.
fn spawn_pool(hc: &HostConfig, base: usize, seed: u64) -> Result<EnginePool> {
    match &hc.backend {
        HostBackend::Mock { min_len, spread, decode_delay_us, max_seq } => {
            let (min_len, spread, delay, max_seq) = (*min_len, *spread, *decode_delay_us, *max_seq);
            let slots = hc.slots;
            EnginePool::spawn_supervised_at(
                base,
                hc.engines,
                hc.slots,
                hc.engine_opts,
                hc.sup,
                seed,
                move |_id| {
                    Box::new(move || {
                        let mut b = MockBackend::new(slots, max_seq);
                        b.min_len = min_len;
                        b.spread = spread.max(1);
                        if delay > 0 {
                            b.decode_delay = Some(std::time::Duration::from_micros(delay));
                        }
                        Ok(b)
                    })
                },
            )
        }
        HostBackend::Xla { artifacts_dir, model, chunked_replay, init_params } => {
            let (dir, variant) = (artifacts_dir.clone(), model.clone());
            let p = init_params.clone();
            let chunked = *chunked_replay;
            EnginePool::spawn_supervised_at(
                base,
                hc.engines,
                hc.slots,
                hc.engine_opts,
                hc.sup,
                seed,
                move |_id| {
                    let dir = dir.clone();
                    let variant = variant.clone();
                    let p = p.clone();
                    Box::new(move || {
                        let mut b = XlaBackend::open(&dir, &variant, &p)?;
                        b.chunked_replay = chunked;
                        Ok(b)
                    })
                },
            )
        }
    }
}
