//! Transport tier: the framed wire protocol ([`wire`]) and the
//! engine-host process mode ([`host`]) that together let the rollout
//! fleet span processes and machines. The router side lives in
//! [`crate::router`]; this module is everything below it — bytes on a
//! socket and the process that answers them.

pub mod host;
pub mod wire;
