//! Length-prefixed framed wire protocol for the router ↔ engine-host link.
//!
//! Hand-rolled, std-only serialization (no serde in the vendored crate
//! set): every frame is a little-endian `u32` payload length followed by
//! the payload, whose first byte is the message tag. Integers are
//! fixed-width little-endian; floats are carried as their IEEE-754 bit
//! patterns (`to_bits`/`from_bits`), which is what makes the local and
//! multi-process transports **bit-identical** — a logprob that crosses the
//! wire decodes to the exact same `f32` the engine sampled.
//!
//! The message set mirrors the in-process channel types verbatim:
//! [`EngineCmd`] frames flow router → host, [`EngineEvent`] frames host →
//! router, plus a tiny session layer (`Hello`/`HelloAck` handshake,
//! `Ping`/`Pong` heartbeats, `Goodbye` for orderly close). `Batch` events
//! nest recursively, so one engine step's events arrive in one frame and
//! unpack in the same order the in-process channel would deliver them.
//!
//! Decoding is defensive: every read is bounds-checked, frames are capped
//! at [`MAX_FRAME_LEN`], and unknown tags are errors — a corrupt or
//! foreign byte stream fails fast instead of desynchronizing the link.

use std::io::{Read, Write};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::engine::{
    EngineCmd, EngineEvent, FinishReason, SamplingParams, StepTrace, WorkItem, WorkResult,
};

/// Protocol version carried in the `Hello`/`HelloAck` handshake; bump on
/// any wire-format change so mismatched binaries refuse to pair instead of
/// mis-decoding each other.
pub const PROTO_VERSION: u32 = 2;

/// Upper bound on a single frame's payload (1 GiB). Big enough for a full
/// `SetParams` weight broadcast; small enough that a corrupt length prefix
/// fails immediately instead of attempting a absurd allocation.
pub const MAX_FRAME_LEN: usize = 1 << 30;

/// One framed message on the router ↔ engine-host link.
pub enum WireMsg {
    /// Router → host greeting, sent once per session before anything else.
    /// Assigns the host's engines their GLOBAL replica-id range (the host
    /// spawns engines `engine_base .. engine_base + n`, so every event it
    /// emits already carries pool-global engine ids) and the engine RNG
    /// seed, which must match the local-transport spawn for bit-identical
    /// sampled streams.
    Hello {
        /// Must equal [`PROTO_VERSION`] on both sides.
        proto: u32,
        /// First global engine id of this host's replica range.
        engine_base: u64,
        /// Engine RNG seed (same value `EnginePool::spawn*` takes).
        seed: u64,
    },
    /// Host → router handshake reply describing the replica range the host
    /// actually spawned.
    HelloAck {
        /// Must equal [`PROTO_VERSION`] on both sides.
        proto: u32,
        /// Number of engines this host runs.
        engines: u64,
        /// Decode slots per engine (must be uniform across the fleet).
        slots: u64,
    },
    /// Router → host: one engine command, addressed by GLOBAL engine id
    /// (the host subtracts its `engine_base`).
    Cmd {
        /// Global engine id the command targets.
        engine: u64,
        /// The command, exactly as the in-process pool would send it.
        cmd: EngineCmd,
    },
    /// Host → router: one engine event, engine ids already pool-global.
    Event(EngineEvent),
    /// Router → host heartbeat probe.
    Ping {
        /// Echo token; the matching `Pong` returns it.
        seq: u64,
    },
    /// Host → router heartbeat reply.
    Pong {
        /// The `Ping`'s echo token.
        seq: u64,
    },
    /// Orderly session close (either side). The router sends it after the
    /// final `Shutdown` commands; the host exits its serve loop on it.
    Goodbye,
}

// ---------------------------------------------------------------------------
// Primitive writers/readers
// ---------------------------------------------------------------------------

fn put_u8(buf: &mut Vec<u8>, v: u8) {
    buf.push(v);
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_i64(buf: &mut Vec<u8>, v: i64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(buf: &mut Vec<u8>, v: f32) {
    put_u32(buf, v.to_bits());
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    put_u64(buf, v.to_bits());
}

fn put_bool(buf: &mut Vec<u8>, v: bool) {
    put_u8(buf, v as u8);
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

fn put_vec_i32(buf: &mut Vec<u8>, v: &[i32]) {
    put_u32(buf, v.len() as u32);
    for x in v {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_vec_f32(buf: &mut Vec<u8>, v: &[f32]) {
    put_u32(buf, v.len() as u32);
    for x in v {
        put_f32(buf, *x);
    }
}

fn put_vec_u64(buf: &mut Vec<u8>, v: &[u64]) {
    put_u32(buf, v.len() as u32);
    for x in v {
        put_u64(buf, *x);
    }
}

fn put_opt_u64(buf: &mut Vec<u8>, v: Option<u64>) {
    match v {
        Some(x) => {
            put_u8(buf, 1);
            put_u64(buf, x);
        }
        None => put_u8(buf, 0),
    }
}

/// Bounds-checked cursor over one frame's payload.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).context("wire: length overflow")?;
        if end > self.buf.len() {
            bail!("wire: truncated frame ({} bytes needed at offset {})", n, self.pos);
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn i64(&mut self) -> Result<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn boolean(&mut self) -> Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            b => bail!("wire: bad bool byte {b}"),
        }
    }

    fn usize_(&mut self) -> Result<usize> {
        let v = self.u64()?;
        usize::try_from(v).context("wire: u64 does not fit usize")
    }

    fn string(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).context("wire: invalid utf-8 string")
    }

    fn vec_i32(&mut self) -> Result<Vec<i32>> {
        let n = self.u32()? as usize;
        let bytes = self.take(n.checked_mul(4).context("wire: vec len overflow")?)?;
        Ok(bytes.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn vec_f32(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let bytes = self.take(n.checked_mul(4).context("wire: vec len overflow")?)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
            .collect())
    }

    fn vec_u64(&mut self) -> Result<Vec<u64>> {
        let n = self.u32()? as usize;
        let bytes = self.take(n.checked_mul(8).context("wire: vec len overflow")?)?;
        Ok(bytes.chunks_exact(8).map(|c| u64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn opt_u64(&mut self) -> Result<Option<u64>> {
        Ok(match self.u8()? {
            0 => None,
            _ => Some(self.u64()?),
        })
    }

    fn done(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!("wire: {} trailing bytes after message", self.buf.len() - self.pos);
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Message tags
// ---------------------------------------------------------------------------

const TAG_HELLO: u8 = 1;
const TAG_HELLO_ACK: u8 = 2;
const TAG_CMD: u8 = 3;
const TAG_EVENT: u8 = 4;
const TAG_PING: u8 = 5;
const TAG_PONG: u8 = 6;
const TAG_GOODBYE: u8 = 7;

const CMD_ASSIGN: u8 = 0;
const CMD_SET_PARAMS: u8 = 1;
const CMD_STOP_GENERATION: u8 = 2;
const CMD_RELEASE_RETAINED: u8 = 3;
const CMD_RELEASE_PREFIX: u8 = 4;
const CMD_SHUTDOWN: u8 = 5;
const CMD_STOP_REQUEST: u8 = 6;

const EV_DONE: u8 = 0;
const EV_TRACE: u8 = 1;
const EV_FLUSHED: u8 = 2;
const EV_SHUTDOWN: u8 = 3;
const EV_ENGINE_FAILED: u8 = 4;
const EV_RETAINED_DROPPED: u8 = 5;
const EV_BATCH: u8 = 6;

const REASON_EOS: u8 = 0;
const REASON_LENGTH_CAP: u8 = 1;
const REASON_PREEMPTED: u8 = 2;
const REASON_STOPPED: u8 = 3;

/// `Batch` events nest; in practice one level deep (`pool::flush` never
/// nests), so a small cap suffices to keep a hostile stream from blowing
/// the decode stack.
const MAX_BATCH_DEPTH: u32 = 4;

// ---------------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------------

fn put_sampling(buf: &mut Vec<u8>, s: &SamplingParams) {
    put_f64(buf, s.temperature);
    put_f64(buf, s.top_p);
    put_i64(buf, s.top_k);
}

fn put_work_item(buf: &mut Vec<u8>, w: &WorkItem) {
    put_u64(buf, w.request_id);
    put_vec_i32(buf, &w.prompt);
    put_vec_i32(buf, &w.resume);
    put_u64(buf, w.max_total as u64);
    put_sampling(buf, &w.sampling);
    put_opt_u64(buf, w.retain);
    put_opt_u64(buf, w.prefix);
}

fn reason_tag(r: FinishReason) -> u8 {
    match r {
        FinishReason::Eos => REASON_EOS,
        FinishReason::LengthCap => REASON_LENGTH_CAP,
        FinishReason::Preempted => REASON_PREEMPTED,
        FinishReason::Stopped => REASON_STOPPED,
    }
}

fn put_work_result(buf: &mut Vec<u8>, r: &WorkResult) {
    put_u64(buf, r.request_id);
    put_vec_i32(buf, &r.new_tokens);
    put_vec_f32(buf, &r.new_logprobs);
    put_u8(buf, reason_tag(r.reason));
    put_u64(buf, r.replayed as u64);
    put_opt_u64(buf, r.retained);
    put_bool(buf, r.resumed_from_kv);
}

fn put_trace(buf: &mut Vec<u8>, t: &StepTrace) {
    put_u64(buf, t.engine as u64);
    put_f64(buf, t.t_wall);
    put_f64(buf, t.dur);
    put_u64(buf, t.active as u64);
    put_u64(buf, t.slots as u64);
    put_u64(buf, t.kv_tokens as u64);
    put_u64(buf, t.kv_blocks as u64);
    put_f64(buf, t.kv_frag);
    put_u64(buf, t.prefix_tokens_shared);
    put_u64(buf, t.cow_copies);
    put_u64(buf, t.preemptions);
    put_u64(buf, t.step_tokens as u64);
    put_u64(buf, t.step_budget as u64);
    put_u64(buf, t.prefill_chunks);
    put_f64(buf, t.prefill_stall_saved);
    put_u64(buf, t.retries);
    put_u64(buf, t.kv_bytes as u64);
    put_str(buf, t.sampler_dispatch);
    put_u64(buf, t.queued as u64);
}

fn put_cmd(buf: &mut Vec<u8>, cmd: &EngineCmd) {
    match cmd {
        EngineCmd::Assign(item) => {
            put_u8(buf, CMD_ASSIGN);
            put_work_item(buf, item);
        }
        EngineCmd::SetParams { version, params, invalidate_retained } => {
            put_u8(buf, CMD_SET_PARAMS);
            put_u64(buf, *version);
            put_vec_f32(buf, params);
            put_bool(buf, *invalidate_retained);
        }
        EngineCmd::StopGeneration { retain } => {
            put_u8(buf, CMD_STOP_GENERATION);
            put_bool(buf, *retain);
        }
        EngineCmd::ReleaseRetained { request_id, token } => {
            put_u8(buf, CMD_RELEASE_RETAINED);
            put_u64(buf, *request_id);
            put_u64(buf, *token);
        }
        EngineCmd::ReleasePrefix { key } => {
            put_u8(buf, CMD_RELEASE_PREFIX);
            put_u64(buf, *key);
        }
        EngineCmd::Shutdown => put_u8(buf, CMD_SHUTDOWN),
        EngineCmd::StopRequest { request_id, retain } => {
            put_u8(buf, CMD_STOP_REQUEST);
            put_u64(buf, *request_id);
            put_bool(buf, *retain);
        }
    }
}

fn put_event(buf: &mut Vec<u8>, ev: &EngineEvent) {
    match ev {
        EngineEvent::Done { engine, result } => {
            put_u8(buf, EV_DONE);
            put_u64(buf, *engine as u64);
            put_work_result(buf, result);
        }
        EngineEvent::Trace(t) => {
            put_u8(buf, EV_TRACE);
            put_trace(buf, t);
        }
        EngineEvent::Flushed { engine, retain_errors } => {
            put_u8(buf, EV_FLUSHED);
            put_u64(buf, *engine as u64);
            put_u64(buf, *retain_errors);
        }
        EngineEvent::ShutDown { engine } => {
            put_u8(buf, EV_SHUTDOWN);
            put_u64(buf, *engine as u64);
        }
        EngineEvent::EngineFailed { engine, error, inflight, retained } => {
            put_u8(buf, EV_ENGINE_FAILED);
            put_u64(buf, *engine as u64);
            put_str(buf, error);
            put_vec_u64(buf, inflight);
            put_vec_u64(buf, retained);
        }
        EngineEvent::RetainedDropped { engine, request_id } => {
            put_u8(buf, EV_RETAINED_DROPPED);
            put_u64(buf, *engine as u64);
            put_u64(buf, *request_id);
        }
        EngineEvent::Batch(evs) => {
            put_u8(buf, EV_BATCH);
            put_u32(buf, evs.len() as u32);
            for e in evs {
                put_event(buf, e);
            }
        }
    }
}

/// Encode one message as a complete frame (length prefix included), ready
/// for a single `write_all`.
pub fn encode(msg: &WireMsg) -> Vec<u8> {
    let mut buf = vec![0u8; 4]; // length prefix back-patched below
    match msg {
        WireMsg::Hello { proto, engine_base, seed } => {
            put_u8(&mut buf, TAG_HELLO);
            put_u32(&mut buf, *proto);
            put_u64(&mut buf, *engine_base);
            put_u64(&mut buf, *seed);
        }
        WireMsg::HelloAck { proto, engines, slots } => {
            put_u8(&mut buf, TAG_HELLO_ACK);
            put_u32(&mut buf, *proto);
            put_u64(&mut buf, *engines);
            put_u64(&mut buf, *slots);
        }
        WireMsg::Cmd { engine, cmd } => {
            put_u8(&mut buf, TAG_CMD);
            put_u64(&mut buf, *engine);
            put_cmd(&mut buf, cmd);
        }
        WireMsg::Event(ev) => {
            put_u8(&mut buf, TAG_EVENT);
            put_event(&mut buf, ev);
        }
        WireMsg::Ping { seq } => {
            put_u8(&mut buf, TAG_PING);
            put_u64(&mut buf, *seq);
        }
        WireMsg::Pong { seq } => {
            put_u8(&mut buf, TAG_PONG);
            put_u64(&mut buf, *seq);
        }
        WireMsg::Goodbye => put_u8(&mut buf, TAG_GOODBYE),
    }
    let len = (buf.len() - 4) as u32;
    buf[..4].copy_from_slice(&len.to_le_bytes());
    buf
}

// ---------------------------------------------------------------------------
// Decoding
// ---------------------------------------------------------------------------

fn get_sampling(r: &mut Reader) -> Result<SamplingParams> {
    Ok(SamplingParams { temperature: r.f64()?, top_p: r.f64()?, top_k: r.i64()? })
}

fn get_work_item(r: &mut Reader) -> Result<WorkItem> {
    Ok(WorkItem {
        request_id: r.u64()?,
        prompt: Arc::from(r.vec_i32()?.into_boxed_slice()),
        resume: r.vec_i32()?,
        max_total: {
            let v = r.u64()?;
            usize::try_from(v).context("wire: max_total does not fit usize")?
        },
        sampling: get_sampling(r)?,
        retain: r.opt_u64()?,
        prefix: r.opt_u64()?,
    })
}

fn get_reason(r: &mut Reader) -> Result<FinishReason> {
    Ok(match r.u8()? {
        REASON_EOS => FinishReason::Eos,
        REASON_LENGTH_CAP => FinishReason::LengthCap,
        REASON_PREEMPTED => FinishReason::Preempted,
        REASON_STOPPED => FinishReason::Stopped,
        t => bail!("wire: unknown finish reason tag {t}"),
    })
}

fn get_work_result(r: &mut Reader) -> Result<WorkResult> {
    Ok(WorkResult {
        request_id: r.u64()?,
        new_tokens: r.vec_i32()?,
        new_logprobs: r.vec_f32()?,
        reason: get_reason(r)?,
        replayed: r.usize_()?,
        retained: r.opt_u64()?,
        resumed_from_kv: r.boolean()?,
    })
}

/// `StepTrace::sampler_dispatch` is `&'static str`; the dispatch-arm name
/// set is closed, so decoding interns into it (unknown names degrade to
/// `""`, the "no trace observed" value — never an error, the field is
/// diagnostic).
fn intern_dispatch(s: &str) -> &'static str {
    match s {
        "scalar" => "scalar",
        "avx2" => "avx2",
        "avx512" => "avx512",
        _ => "",
    }
}

fn get_trace(r: &mut Reader) -> Result<StepTrace> {
    Ok(StepTrace {
        engine: r.usize_()?,
        t_wall: r.f64()?,
        dur: r.f64()?,
        active: r.usize_()?,
        slots: r.usize_()?,
        kv_tokens: r.usize_()?,
        kv_blocks: r.usize_()?,
        kv_frag: r.f64()?,
        prefix_tokens_shared: r.u64()?,
        cow_copies: r.u64()?,
        preemptions: r.u64()?,
        step_tokens: r.usize_()?,
        step_budget: r.usize_()?,
        prefill_chunks: r.u64()?,
        prefill_stall_saved: r.f64()?,
        retries: r.u64()?,
        kv_bytes: r.usize_()?,
        sampler_dispatch: intern_dispatch(&r.string()?),
        queued: r.usize_()?,
    })
}

fn get_cmd(r: &mut Reader) -> Result<EngineCmd> {
    Ok(match r.u8()? {
        CMD_ASSIGN => EngineCmd::Assign(get_work_item(r)?),
        CMD_SET_PARAMS => EngineCmd::SetParams {
            version: r.u64()?,
            params: Arc::new(r.vec_f32()?),
            invalidate_retained: r.boolean()?,
        },
        CMD_STOP_GENERATION => EngineCmd::StopGeneration { retain: r.boolean()? },
        CMD_RELEASE_RETAINED => {
            EngineCmd::ReleaseRetained { request_id: r.u64()?, token: r.u64()? }
        }
        CMD_RELEASE_PREFIX => EngineCmd::ReleasePrefix { key: r.u64()? },
        CMD_SHUTDOWN => EngineCmd::Shutdown,
        CMD_STOP_REQUEST => {
            EngineCmd::StopRequest { request_id: r.u64()?, retain: r.boolean()? }
        }
        t => bail!("wire: unknown command tag {t}"),
    })
}

fn get_event(r: &mut Reader, depth: u32) -> Result<EngineEvent> {
    Ok(match r.u8()? {
        EV_DONE => EngineEvent::Done { engine: r.usize_()?, result: get_work_result(r)? },
        EV_TRACE => EngineEvent::Trace(get_trace(r)?),
        EV_FLUSHED => EngineEvent::Flushed { engine: r.usize_()?, retain_errors: r.u64()? },
        EV_SHUTDOWN => EngineEvent::ShutDown { engine: r.usize_()? },
        EV_ENGINE_FAILED => EngineEvent::EngineFailed {
            engine: r.usize_()?,
            error: r.string()?,
            inflight: r.vec_u64()?,
            retained: r.vec_u64()?,
        },
        EV_RETAINED_DROPPED => {
            EngineEvent::RetainedDropped { engine: r.usize_()?, request_id: r.u64()? }
        }
        EV_BATCH => {
            if depth >= MAX_BATCH_DEPTH {
                bail!("wire: Batch nesting exceeds {MAX_BATCH_DEPTH}");
            }
            let n = r.u32()? as usize;
            // Order is load-bearing: the coordinator unpacks batches
            // front-to-back, and the wire must deliver exactly the
            // in-process channel order.
            let mut evs = Vec::with_capacity(n.min(1 << 16));
            for _ in 0..n {
                evs.push(get_event(r, depth + 1)?);
            }
            EngineEvent::Batch(evs)
        }
        t => bail!("wire: unknown event tag {t}"),
    })
}

/// Decode one frame payload (everything after the length prefix) into a
/// message, rejecting trailing bytes.
pub fn decode(payload: &[u8]) -> Result<WireMsg> {
    let mut r = Reader::new(payload);
    let msg = match r.u8()? {
        TAG_HELLO => {
            WireMsg::Hello { proto: r.u32()?, engine_base: r.u64()?, seed: r.u64()? }
        }
        TAG_HELLO_ACK => {
            WireMsg::HelloAck { proto: r.u32()?, engines: r.u64()?, slots: r.u64()? }
        }
        TAG_CMD => WireMsg::Cmd { engine: r.u64()?, cmd: get_cmd(&mut r)? },
        TAG_EVENT => WireMsg::Event(get_event(&mut r, 0)?),
        TAG_PING => WireMsg::Ping { seq: r.u64()? },
        TAG_PONG => WireMsg::Pong { seq: r.u64()? },
        TAG_GOODBYE => WireMsg::Goodbye,
        t => bail!("wire: unknown message tag {t}"),
    };
    r.done()?;
    Ok(msg)
}

/// Write one message as a single frame. One `write_all` per frame, so
/// concurrent writers serialized by a mutex never interleave partial
/// frames.
pub fn write_msg(w: &mut impl Write, msg: &WireMsg) -> std::io::Result<()> {
    w.write_all(&encode(msg))
}

/// Read one complete frame (blocking) and decode it. `Err` on EOF,
/// oversized frames, or malformed payloads — the caller treats any error
/// as a dead link.
pub fn read_msg(r: &mut impl Read) -> Result<WireMsg> {
    let mut len_buf = [0u8; 4];
    r.read_exact(&mut len_buf).context("wire: reading frame length")?;
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME_LEN {
        bail!("wire: frame length {len} exceeds cap {MAX_FRAME_LEN}");
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload).context("wire: reading frame payload")?;
    decode(&payload)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::prop_check;
    use crate::util::Rng;

    /// The round-trip oracle: `encode(decode(encode(x))) == encode(x)`.
    /// Byte-equality proves the codec is lossless without needing
    /// `PartialEq` on the engine types (floats compare by bit pattern,
    /// exactly the property the bit-identity goldens rely on). Operates
    /// on the encoded frame so the property inputs are `Debug`-printable
    /// (`EngineCmd` deliberately derives nothing).
    fn reencodes_identically(bytes: &[u8]) -> Result<(), String> {
        let decoded = decode(&bytes[4..]).map_err(|e| format!("decode failed: {e:#}"))?;
        let re = encode(&decoded);
        if re != bytes {
            return Err(format!("re-encode differs: {} vs {} bytes", re.len(), bytes.len()));
        }
        Ok(())
    }

    fn gen_sampling(rng: &mut Rng) -> SamplingParams {
        SamplingParams {
            temperature: rng.next_f64() * 2.0,
            top_p: rng.next_f64(),
            top_k: (rng.next_u64() % 64) as i64 - 1,
        }
    }

    fn gen_work_item(rng: &mut Rng) -> WorkItem {
        let p_len = (rng.next_u64() % 24) as usize;
        let prompt: Vec<i32> = (0..p_len).map(|_| (rng.next_u64() % 4096) as i32).collect();
        let r_len = (rng.next_u64() % 16) as usize;
        WorkItem {
            request_id: rng.next_u64(),
            prompt: Arc::from(prompt.into_boxed_slice()),
            resume: (0..r_len).map(|_| (rng.next_u64() % 4096) as i32).collect(),
            max_total: (rng.next_u64() % 512) as usize,
            sampling: gen_sampling(rng),
            retain: if rng.next_f64() < 0.5 { Some(rng.next_u64()) } else { None },
            prefix: if rng.next_f64() < 0.5 { Some(rng.next_u64()) } else { None },
        }
    }

    fn gen_work_result(rng: &mut Rng) -> WorkResult {
        let n = (rng.next_u64() % 32) as usize;
        WorkResult {
            request_id: rng.next_u64(),
            new_tokens: (0..n).map(|_| (rng.next_u64() % 4096) as i32).collect(),
            new_logprobs: (0..n).map(|_| -rng.next_f32()).collect(),
            reason: match rng.next_u64() % 4 {
                0 => FinishReason::Eos,
                1 => FinishReason::LengthCap,
                2 => FinishReason::Preempted,
                _ => FinishReason::Stopped,
            },
            replayed: (rng.next_u64() % 64) as usize,
            retained: if rng.next_f64() < 0.5 { Some(rng.next_u64()) } else { None },
            resumed_from_kv: rng.next_f64() < 0.5,
        }
    }

    fn gen_trace(rng: &mut Rng) -> StepTrace {
        StepTrace {
            engine: (rng.next_u64() % 16) as usize,
            t_wall: rng.next_f64() * 100.0,
            dur: rng.next_f64(),
            active: (rng.next_u64() % 8) as usize,
            slots: 8,
            kv_tokens: (rng.next_u64() % 4096) as usize,
            kv_blocks: (rng.next_u64() % 256) as usize,
            kv_frag: rng.next_f64(),
            prefix_tokens_shared: rng.next_u64() % 1024,
            cow_copies: rng.next_u64() % 64,
            preemptions: rng.next_u64() % 16,
            step_tokens: (rng.next_u64() % 256) as usize,
            step_budget: (rng.next_u64() % 512) as usize,
            prefill_chunks: rng.next_u64() % 64,
            prefill_stall_saved: rng.next_f64(),
            retries: rng.next_u64() % 8,
            kv_bytes: (rng.next_u64() % (1 << 20)) as usize,
            sampler_dispatch: ["scalar", "avx2", "avx512", ""][(rng.next_u64() % 4) as usize],
            queued: (rng.next_u64() % 32) as usize,
        }
    }

    fn gen_event(rng: &mut Rng, allow_batch: bool) -> EngineEvent {
        let arms = if allow_batch { 7 } else { 6 };
        match rng.next_u64() % arms {
            0 => EngineEvent::Done {
                engine: (rng.next_u64() % 8) as usize,
                result: gen_work_result(rng),
            },
            1 => EngineEvent::Trace(gen_trace(rng)),
            2 => EngineEvent::Flushed {
                engine: (rng.next_u64() % 8) as usize,
                retain_errors: rng.next_u64() % 8,
            },
            3 => EngineEvent::ShutDown { engine: (rng.next_u64() % 8) as usize },
            4 => EngineEvent::EngineFailed {
                engine: (rng.next_u64() % 8) as usize,
                error: format!("injected failure #{}", rng.next_u64() % 1000),
                inflight: (0..(rng.next_u64() % 8)).map(|_| rng.next_u64()).collect(),
                retained: (0..(rng.next_u64() % 8)).map(|_| rng.next_u64()).collect(),
            },
            5 => EngineEvent::RetainedDropped {
                engine: (rng.next_u64() % 8) as usize,
                request_id: rng.next_u64(),
            },
            _ => {
                let n = (rng.next_u64() % 6) as usize;
                EngineEvent::Batch((0..n).map(|_| gen_event(rng, false)).collect())
            }
        }
    }

    fn gen_cmd(rng: &mut Rng) -> EngineCmd {
        match rng.next_u64() % 7 {
            0 => EngineCmd::Assign(gen_work_item(rng)),
            1 => EngineCmd::SetParams {
                version: rng.next_u64(),
                params: Arc::new((0..(rng.next_u64() % 64)).map(|_| rng.next_f32()).collect()),
                invalidate_retained: rng.next_f64() < 0.5,
            },
            6 => EngineCmd::StopRequest {
                request_id: rng.next_u64(),
                retain: rng.next_f64() < 0.5,
            },
            2 => EngineCmd::StopGeneration { retain: rng.next_f64() < 0.5 },
            3 => EngineCmd::ReleaseRetained { request_id: rng.next_u64(), token: rng.next_u64() },
            4 => EngineCmd::ReleasePrefix { key: rng.next_u64() },
            _ => EngineCmd::Shutdown,
        }
    }

    #[test]
    fn prop_cmd_frames_round_trip() {
        prop_check(
            "cmd frames re-encode identically",
            128,
            |rng| encode(&WireMsg::Cmd { engine: rng.next_u64() % 64, cmd: gen_cmd(rng) }),
            |bytes| reencodes_identically(bytes),
        );
    }

    #[test]
    fn prop_event_frames_round_trip() {
        prop_check(
            "event frames (incl. Batch) re-encode identically",
            128,
            |rng| encode(&WireMsg::Event(gen_event(rng, true))),
            |bytes| reencodes_identically(bytes),
        );
    }

    #[test]
    fn session_frames_round_trip() {
        for msg in [
            WireMsg::Hello { proto: PROTO_VERSION, engine_base: 3, seed: 42 },
            WireMsg::HelloAck { proto: PROTO_VERSION, engines: 2, slots: 4 },
            WireMsg::Ping { seq: 7 },
            WireMsg::Pong { seq: 7 },
            WireMsg::Goodbye,
        ] {
            reencodes_identically(&encode(&msg)).unwrap();
        }
    }

    /// Arc<[i32]> prompts survive the wire with exact contents and the
    /// retain/prefix hints intact (the fields the affinity router depends
    /// on).
    #[test]
    fn work_item_fields_survive() {
        let item = WorkItem {
            request_id: 99,
            prompt: Arc::from(vec![1, 2, 3, -7].into_boxed_slice()),
            resume: vec![10, 11],
            max_total: 64,
            sampling: SamplingParams::greedy(),
            retain: Some(0xDEAD),
            prefix: Some(0xBEEF),
        };
        let bytes = encode(&WireMsg::Cmd { engine: 5, cmd: EngineCmd::Assign(item) });
        let WireMsg::Cmd { engine, cmd: EngineCmd::Assign(got) } = decode(&bytes[4..]).unwrap()
        else {
            panic!("wrong variant");
        };
        assert_eq!(engine, 5);
        assert_eq!(got.request_id, 99);
        assert_eq!(&got.prompt[..], &[1, 2, 3, -7]);
        assert_eq!(got.resume, vec![10, 11]);
        assert_eq!(got.max_total, 64);
        assert_eq!(got.retain, Some(0xDEAD));
        assert_eq!(got.prefix, Some(0xBEEF));
    }

    /// EngineFailed carries its full recovery payload across the wire.
    #[test]
    fn engine_failed_payload_survives() {
        let ev = EngineEvent::EngineFailed {
            engine: 3,
            error: "backend exploded".into(),
            inflight: vec![1, 2, 3],
            retained: vec![9, 8],
        };
        let bytes = encode(&WireMsg::Event(ev));
        let WireMsg::Event(EngineEvent::EngineFailed { engine, error, inflight, retained }) =
            decode(&bytes[4..]).unwrap()
        else {
            panic!("wrong variant");
        };
        assert_eq!(engine, 3);
        assert_eq!(error, "backend exploded");
        assert_eq!(inflight, vec![1, 2, 3]);
        assert_eq!(retained, vec![9, 8]);
    }

    /// Batch ordering is preserved over the wire: delivery order after
    /// decode matches in-process channel order (the coordinator's unpack
    /// loop depends on it — Done-before-Flushed within a step).
    #[test]
    fn prop_batch_order_preserved() {
        prop_check(
            "Batch event order survives the wire",
            64,
            |rng| {
                let n = 1 + (rng.next_u64() % 8) as usize;
                let ids: Vec<u64> = (0..n as u64).map(|i| rng.next_u64() ^ i).collect();
                let evs: Vec<EngineEvent> = ids
                    .iter()
                    .map(|&id| EngineEvent::RetainedDropped { engine: 0, request_id: id })
                    .collect();
                (ids, evs)
            },
            |(ids, evs)| {
                let bytes = encode(&WireMsg::Event(EngineEvent::Batch(evs.clone())));
                let WireMsg::Event(EngineEvent::Batch(got)) =
                    decode(&bytes[4..]).map_err(|e| e.to_string())?
                else {
                    return Err("wrong variant".into());
                };
                let got_ids: Vec<u64> = got
                    .iter()
                    .map(|e| match e {
                        EngineEvent::RetainedDropped { request_id, .. } => *request_id,
                        _ => u64::MAX,
                    })
                    .collect();
                if &got_ids != ids {
                    return Err(format!("order changed: {ids:?} -> {got_ids:?}"));
                }
                Ok(())
            },
        );
    }

    /// Logprob f32 bits cross the wire unchanged — the bit-identity pin.
    #[test]
    fn float_bits_exact() {
        let vals = [0.0f32, -0.0, 1.5, f32::MIN_POSITIVE, -1234.5678, f32::MAX];
        let ev = EngineEvent::Done {
            engine: 0,
            result: WorkResult {
                request_id: 1,
                new_tokens: vec![0; vals.len()],
                new_logprobs: vals.to_vec(),
                reason: FinishReason::Eos,
                replayed: 0,
                retained: None,
                resumed_from_kv: false,
            },
        };
        let bytes = encode(&WireMsg::Event(ev));
        let WireMsg::Event(EngineEvent::Done { result, .. }) = decode(&bytes[4..]).unwrap()
        else {
            panic!("wrong variant");
        };
        for (a, b) in vals.iter().zip(&result.new_logprobs) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    /// A truncated or garbage stream errors instead of desynchronizing.
    #[test]
    fn malformed_frames_rejected() {
        assert!(decode(&[]).is_err());
        assert!(decode(&[200]).is_err()); // unknown tag
        let mut bytes = encode(&WireMsg::Ping { seq: 1 });
        bytes.truncate(bytes.len() - 2); // truncated payload
        assert!(decode(&bytes[4..]).is_err());
        // Oversized declared length fails in read_msg before allocating.
        let mut huge = ((MAX_FRAME_LEN + 1) as u32).to_le_bytes().to_vec();
        huge.extend_from_slice(&[0; 8]);
        assert!(read_msg(&mut huge.as_slice()).is_err());
    }
}
