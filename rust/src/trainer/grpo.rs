//! The GRPO training loop body: cal-logprob pass, gradient accumulation,
//! Adam update, weight sync — with cross-stage IS correction toggleable
//! (w/ IS vs w/o IS, the §5.4.2 ablation).

use std::path::Path;
use std::sync::Arc;

use anyhow::Result;
use xla::PjRtBuffer;

use super::batch::{microbatches, pack_group_trajectories};
use crate::config::Config;
use crate::coordinator::Group;
use crate::model::{GradMetrics, ModelRuntime, TrainState};
use crate::tokenizer::Tokenizer;
use crate::util::StageTimer;

/// Scalar metrics for one training step.
#[derive(Clone, Debug, Default)]
pub struct StepMetrics {
    /// Optimizer step this update produced.
    pub step: i32,
    /// Mean verifiable reward over the batch.
    pub reward_mean: f64,
    /// Token-mean policy loss.
    pub loss: f64,
    /// Token-mean policy entropy (masked positions).
    pub entropy: f64,
    /// Token-mean IS ratio.
    pub ratio_mean: f64,
    /// Max IS ratio seen in the batch.
    pub ratio_max: f64,
    /// Fraction of tokens hitting the PPO clip.
    pub clip_frac: f64,
    /// Token-mean approximate KL to the behaviour policy.
    pub kl: f64,
    /// RMS of per-microbatch gradient norms (diagnostic).
    pub grad_norm: f64,
    /// Masked (response) tokens in the batch.
    pub n_tokens: usize,
    /// Fraction of masked tokens generated under an older policy version.
    pub offpolicy_frac: f64,
    /// Rows whose trajectory spans more than one policy version.
    pub cross_stage_rows: usize,
    /// Cal-logprob stage seconds (the veRL old-log-prob pass).
    pub t_cal_logprob: f64,
    /// Gradient accumulation stage seconds.
    pub t_grad: f64,
    /// Adam update stage seconds.
    pub t_update: f64,
    /// Trainer seconds actually overlapped by an in-flight rollout stage
    /// (stage-pipelined mode; clamped to stage-active time by the
    /// coordinator, set by the session, 0.0 when serial).
    pub t_overlap: f64,
}

/// Owns the training-side model runtime and device state.
pub struct Trainer {
    /// Artifact runtime the training calls execute on.
    pub rt: ModelRuntime,
    /// Device-resident packed train state (params + Adam moments + step).
    pub state: TrainState,
    /// Run configuration.
    pub cfg: Config,
    tokenizer: Tokenizer,
}

impl Trainer {
    /// Fresh trainer with randomly initialised state.
    pub fn new(cfg: Config, seed: i32) -> Result<Trainer> {
        let mut rt = ModelRuntime::open(&cfg.artifacts_dir, &cfg.model)?;
        rt.warmup(&["init", "logprob", "grad", "accum", "update", "read_metrics", "read_params"])?;
        let state = TrainState::init(&mut rt, seed)?;
        Ok(Trainer { rt, state, cfg, tokenizer: Tokenizer::new() })
    }

    /// Resume from a checkpoint.
    pub fn from_checkpoint(cfg: Config, path: &Path) -> Result<Trainer> {
        let mut rt = ModelRuntime::open(&cfg.artifacts_dir, &cfg.model)?;
        rt.warmup(&["logprob", "grad", "accum", "update", "read_metrics", "read_params"])?;
        let state = TrainState::load(&mut rt, path)?;
        Ok(Trainer { rt, state, cfg, tokenizer: Tokenizer::new() })
    }

    /// Host copy of current params (the weight-sync payload).
    pub fn params(&mut self) -> Result<Arc<Vec<f32>>> {
        Ok(Arc::new(self.rt.params_to_host(&self.state.buffer)?))
    }

    /// Current optimizer step (doubles as the policy version).
    pub fn step(&self) -> i32 {
        self.state.step
    }

    /// One GRPO update over B completed groups.
    ///
    /// `use_is == true` → Cross-stage IS Correction: behaviour log-probs are
    /// the buffered per-stage concat (Eq. 6/8). `false` → the "w/o IS"
    /// pseudo-on-policy ablation: the freshly recomputed log-probs stand in
    /// as behaviour, so every ratio starts at 1.
    pub fn train_step(&mut self, groups: &[Group], timer: &mut StageTimer) -> Result<StepMetrics> {
        let mut noop = || -> Result<()> { Ok(()) };
        self.train_step_hooked(groups, timer, &mut noop)
    }

    /// `train_step` with a between-microbatch hook: `pump` runs after every
    /// device call of the cal-logprob and gradient loops, so a
    /// stage-pipelined caller can service the overlapped rollout stage
    /// (refill, early termination) while the update computes.
    pub fn train_step_hooked(
        &mut self,
        groups: &[Group],
        timer: &mut StageTimer,
        pump: &mut dyn FnMut() -> Result<()>,
    ) -> Result<StepMetrics> {
        let use_is = self.cfg.rollout.importance_sampling;
        let spec = self.rt.spec.clone();
        // Rollouts were generated under policy versions ≤ the current step
        // (sync_weights uses version == trainer step).
        let current_version = self.state.step as u64;
        let batch = pack_group_trajectories(
            groups,
            &self.tokenizer,
            spec.t_train,
            current_version,
            self.cfg.train.adv_eps,
        );
        let mut m = StepMetrics {
            step: self.state.step + 1,
            reward_mean: batch.reward_mean,
            cross_stage_rows: batch.cross_stage_rows,
            ..Default::default()
        };
        if batch.total_masked_tokens == 0 {
            // Degenerate batch (all empty responses) — skip the update.
            return Ok(m);
        }

        let mbs = microbatches(&batch, spec.b_micro, spec.t_train);

        // --- cal-logprob stage (veRL old_log_prob pass; Table 2 column) ---
        let t0 = std::time::Instant::now();
        let mut recomputed: Vec<Vec<f32>> = Vec::with_capacity(mbs.len());
        let mut entropy_sum = 0.0f64;
        for mb in &mbs {
            let tokens: Vec<i32> = mb.iter().flat_map(|r| r.tokens.iter().copied()).collect();
            let (lp, ent) = self.rt.logprob(&self.state.buffer, &tokens)?;
            // Entropy over masked tokens only (metrics).
            for (row, r) in mb.iter().enumerate() {
                let w = spec.t_train - 1;
                for t in 0..w {
                    if r.resp_mask[t] > 0.0 {
                        entropy_sum += ent[row * w + t] as f64;
                    }
                }
            }
            recomputed.push(lp);
            pump()?;
        }
        m.t_cal_logprob = t0.elapsed().as_secs_f64();
        timer.add("cal_logprob", m.t_cal_logprob);

        // --- gradient accumulation (device-side) --------------------------
        let t0 = std::time::Instant::now();
        let mut acc: Option<PjRtBuffer> = None;
        let mut agg = GradAgg::default();
        for (i, mb) in mbs.iter().enumerate() {
            let w = spec.t_train - 1;
            let tokens: Vec<i32> = mb.iter().flat_map(|r| r.tokens.iter().copied()).collect();
            let mask: Vec<f32> = mb.iter().flat_map(|r| r.resp_mask.iter().copied()).collect();
            let adv: Vec<f32> = mb.iter().map(|r| r.advantage).collect();
            let behav: Vec<f32> = if use_is {
                mb.iter().flat_map(|r| r.behav_lp.iter().copied()).collect()
            } else {
                // Pseudo on-policy: recomputed current-policy log-probs.
                let mut v = recomputed[i].clone();
                // Zero outside the mask for cleanliness (masked anyway).
                for (j, x) in v.iter_mut().enumerate() {
                    let (row, t) = (j / w, j % w);
                    if mb[row].resp_mask[t] == 0.0 {
                        *x = 0.0;
                    }
                }
                v
            };
            let (gbuf, gm) = self.rt.grad(&self.state.buffer, &tokens, &mask, &behav, &adv)?;
            agg.add(&gm);
            acc = Some(match acc {
                None => gbuf,
                Some(prev) => self.rt.accum(&prev, &gbuf, 1.0)?,
            });
            pump()?;
        }
        m.t_grad = t0.elapsed().as_secs_f64();
        timer.add("grad", m.t_grad);

        // --- Adam update (token-mean via grad_scale) ----------------------
        let t0 = std::time::Instant::now();
        let n_tok = agg.token_count.max(1.0);
        let lr = self.cfg.train.lr as f32;
        self.state.apply_update(&mut self.rt, &acc.unwrap(), lr, 1.0 / n_tok as f32)?;
        m.t_update = t0.elapsed().as_secs_f64();
        timer.add("update", m.t_update);

        m.loss = agg.loss_sum / n_tok;
        m.entropy = entropy_sum / n_tok;
        m.ratio_mean = agg.ratio_sum / n_tok;
        m.ratio_max = agg.ratio_max;
        m.clip_frac = agg.clip_sum / n_tok;
        m.kl = agg.kl_sum / n_tok;
        m.grad_norm = agg.grad_norm_rms;
        m.n_tokens = batch.total_masked_tokens;
        m.offpolicy_frac =
            batch.total_offpolicy_tokens as f64 / batch.total_masked_tokens.max(1) as f64;
        Ok(m)
    }

    /// Checkpoint the packed train state.
    pub fn save(&mut self, path: &Path) -> Result<()> {
        self.state.save(&mut self.rt, path)
    }
}

/// Host-side aggregation of per-microbatch metric heads.
#[derive(Default)]
struct GradAgg {
    loss_sum: f64,
    ratio_sum: f64,
    ratio_max: f64,
    clip_sum: f64,
    kl_sum: f64,
    token_count: f64,
    grad_norm_rms: f64,
    n: usize,
}

impl GradAgg {
    fn add(&mut self, g: &GradMetrics) {
        self.loss_sum += g.loss_sum as f64;
        self.ratio_sum += g.ratio_sum as f64;
        self.ratio_max = self.ratio_max.max(g.ratio_max as f64);
        self.clip_sum += g.clip_sum as f64;
        self.kl_sum += g.kl_sum as f64;
        self.token_count += g.token_count as f64;
        // RMS over microbatch grad norms (diagnostic only).
        let n = self.n as f64;
        self.grad_norm_rms =
            ((self.grad_norm_rms.powi(2) * n + (g.grad_norm as f64).powi(2)) / (n + 1.0)).sqrt();
        self.n += 1;
    }
}
