//! Batch packing: trajectories → fixed-shape [B, T] token rows with
//! response masks, cross-stage behaviour log-probs and group advantages.

use crate::coordinator::{Group, Trajectory};
use crate::tasks::reward;
use crate::tokenizer::{Tokenizer, PAD};
use crate::util::stats::group_advantages;

/// One packed training row.
#[derive(Clone, Debug)]
pub struct PackedSeq {
    /// [T] — BOS+prompt ++ generated ++ PAD.
    pub tokens: Vec<i32>,
    /// [T-1] — 1.0 on positions predicting a generated token.
    pub resp_mask: Vec<f32>,
    /// [T-1] — behaviour log-prob of the predicted token (Eq. 6 concat),
    /// 0 outside the mask.
    pub behav_lp: Vec<f32>,
    /// Group-relative advantage (Eq. 5), broadcast over the row.
    pub advantage: f32,
    /// Verifiable reward of this trajectory.
    pub reward: f32,
    /// Tokens of this row generated under an older policy version.
    pub offpolicy_tokens: usize,
    /// Distinct policy versions that produced this trajectory.
    pub n_stages: usize,
}

/// A full training batch (B·G rows) ready for microbatching.
#[derive(Clone, Debug, Default)]
pub struct PackedBatch {
    /// Packed rows, one per trajectory.
    pub rows: Vec<PackedSeq>,
    /// Masked (response) tokens across all rows.
    pub total_masked_tokens: usize,
    /// Masked tokens generated under an older policy version.
    pub total_offpolicy_tokens: usize,
    /// Mean reward over all rows.
    pub reward_mean: f64,
    /// Rows spanning more than one policy version.
    pub cross_stage_rows: usize,
}

/// Pack a trajectory into a [T] row. Truncates to `t_train` (cannot happen
/// when t_train == max_seq, the artifact default).
pub fn pack_one(traj: &Trajectory, advantage: f32, rew: f32, t_train: usize, current_version: u64) -> PackedSeq {
    let plen = traj.prompt.len();
    let behav = traj.behavior_logprobs();
    let glen = traj.tokens.len().min(t_train.saturating_sub(plen));

    let mut tokens = vec![PAD; t_train];
    tokens[..plen].copy_from_slice(&traj.prompt);
    tokens[plen..plen + glen].copy_from_slice(&traj.tokens[..glen]);

    // Position t predicts tokens[t+1]; generated tokens live at indices
    // plen..plen+glen, so mask positions plen-1 .. plen+glen-2.
    let mut resp_mask = vec![0f32; t_train - 1];
    let mut behav_lp = vec![0f32; t_train - 1];
    for g in 0..glen {
        let t = plen - 1 + g;
        resp_mask[t] = 1.0;
        behav_lp[t] = behav[g];
    }
    PackedSeq {
        tokens,
        resp_mask,
        behav_lp,
        advantage,
        reward: rew,
        offpolicy_tokens: traj.offpolicy_tokens(current_version),
        n_stages: traj.n_stages(),
    }
}

/// Rewards + Eq. 5 advantages + packing for a batch of completed groups.
pub fn pack_group_trajectories(
    groups: &[Group],
    tokenizer: &Tokenizer,
    t_train: usize,
    current_version: u64,
    adv_eps: f64,
) -> PackedBatch {
    let mut out = PackedBatch::default();
    let mut reward_sum = 0.0;
    let mut n = 0usize;
    for g in groups {
        let rewards: Vec<f64> = g
            .done
            .iter()
            .map(|t| reward(&tokenizer.extract_answer(&t.tokens), &t.task.answer))
            .collect();
        let advs = group_advantages(&rewards, adv_eps);
        for (traj, (rew, adv)) in g.done.iter().zip(rewards.iter().zip(advs.iter())) {
            let row = pack_one(traj, *adv as f32, *rew as f32, t_train, current_version);
            out.total_masked_tokens += row.resp_mask.iter().filter(|&&m| m > 0.0).count();
            out.total_offpolicy_tokens += row.offpolicy_tokens;
            if row.n_stages > 1 {
                out.cross_stage_rows += 1;
            }
            reward_sum += rew;
            n += 1;
            out.rows.push(row);
        }
    }
    out.reward_mean = if n > 0 { reward_sum / n as f64 } else { 0.0 };
    out
}

/// Split rows into microbatches of exactly `b_micro`, padding the last
/// chunk with inert rows (all-zero mask, zero advantage → zero gradient).
pub fn microbatches(batch: &PackedBatch, b_micro: usize, t_train: usize) -> Vec<Vec<PackedSeq>> {
    let mut out = Vec::new();
    for chunk in batch.rows.chunks(b_micro) {
        let mut mb: Vec<PackedSeq> = chunk.to_vec();
        while mb.len() < b_micro {
            mb.push(PackedSeq {
                tokens: vec![PAD; t_train],
                resp_mask: vec![0.0; t_train - 1],
                behav_lp: vec![0.0; t_train - 1],
                advantage: 0.0,
                reward: 0.0,
                offpolicy_tokens: 0,
                n_stages: 0,
            });
        }
        out.push(mb);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Trajectory;
    use crate::tasks::Family;
    use crate::util::Rng;

    fn traj_with(prompt: Vec<i32>, gen: Vec<i32>, versions: &[(usize, u64)]) -> Trajectory {
        let task = Family::AddChain.generate(&mut Rng::new(1), 0);
        let mut t = Trajectory::new(1, 1, task, prompt, versions[0].1);
        let mut off = 0;
        for &(n, v) in versions {
            let lps: Vec<f32> = (0..n).map(|i| -0.1 * (off + i + 1) as f32).collect();
            t.append_stage(&gen[off..off + n], &lps, v);
            off += n;
        }
        t.complete = true;
        t
    }

    #[test]
    fn mask_and_behav_aligned() {
        let t = traj_with(vec![1, 5, 6], vec![7, 8, 2], &[(3, 4)]);
        let row = pack_one(&t, 1.0, 1.0, 12, 4);
        assert_eq!(row.tokens[..6], [1, 5, 6, 7, 8, 2]);
        assert_eq!(&row.tokens[6..], &[PAD; 6]);
        // plen=3: mask positions 2,3,4 predict generated tokens 7,8,2.
        let want_mask: Vec<f32> =
            (0..11).map(|t| if (2..5).contains(&t) { 1.0 } else { 0.0 }).collect();
        assert_eq!(row.resp_mask, want_mask);
        assert!((row.behav_lp[2] + 0.1).abs() < 1e-6);
        assert!((row.behav_lp[4] + 0.3).abs() < 1e-6);
        assert_eq!(row.behav_lp[5], 0.0);
        assert_eq!(row.offpolicy_tokens, 0);
    }

    #[test]
    fn cross_stage_offpolicy_counted() {
        let t = traj_with(vec![1, 4], vec![5, 6, 7, 2], &[(2, 3), (2, 5)]);
        let row = pack_one(&t, 0.5, 1.0, 10, 5);
        assert_eq!(row.offpolicy_tokens, 2);
        assert_eq!(row.n_stages, 2);
        // Behaviour lps are the CONCAT across stages (Eq. 6).
        assert!((row.behav_lp[1] + 0.1).abs() < 1e-6);
        assert!((row.behav_lp[4] + 0.4).abs() < 1e-6);
    }

    #[test]
    fn microbatches_pad_with_inert_rows() {
        let t = traj_with(vec![1, 4], vec![5, 2], &[(2, 0)]);
        let batch = PackedBatch {
            rows: vec![pack_one(&t, 1.0, 1.0, 8, 0); 3],
            ..Default::default()
        };
        let mbs = microbatches(&batch, 2, 8);
        assert_eq!(mbs.len(), 2);
        assert_eq!(mbs[1].len(), 2);
        let pad_row = &mbs[1][1];
        assert!(pad_row.resp_mask.iter().all(|&m| m == 0.0));
        assert_eq!(pad_row.advantage, 0.0);
    }

    #[test]
    fn truncation_respects_t_train() {
        let t = traj_with(vec![1, 4, 5], vec![6; 20], &[(20, 0)]);
        let row = pack_one(&t, 1.0, 0.0, 10, 0);
        assert_eq!(row.tokens.len(), 10);
        let masked = row.resp_mask.iter().filter(|&&m| m > 0.0).count();
        assert_eq!(masked, 7); // 10 - 3 prompt
    }
}
