//! GRPO trainer with Cross-stage Importance Sampling Correction (§4, Eq. 8).
//!
//! Per training step: verify rewards → group-relative advantages (Eq. 5) →
//! pack sequences → "cal logprob" pass (the veRL old-log-prob stage whose
//! cost Table 2 reports) → microbatched gradient accumulation (device-side)
//! → one Adam update → weight sync to the engines.

pub mod batch;
pub mod grpo;
pub mod metrics;
pub mod sft;

pub use batch::{pack_group_trajectories, PackedBatch, PackedSeq};
pub use grpo::{StepMetrics, Trainer};
pub use metrics::MetricsLog;
pub use sft::SftTrainer;
