//! Supervised warmup trainer ("basemodel" stage): the paper RL-tunes
//! pretrained LLMs, so before GRPO we teach the task format with plain
//! next-token cross-entropy on easy-level tasks. Also serves as the
//! e2e loss-curve driver (examples/train_full.rs).

use anyhow::Result;
use xla::PjRtBuffer;

use crate::model::{ModelRuntime, TrainState};
use crate::tasks::{Dataset, Task};
use crate::tokenizer::{Tokenizer, EOS, PAD};

/// Borrowed-view SFT trainer over the shared runtime and train state.
pub struct SftTrainer<'a> {
    /// Artifact runtime (shared with the GRPO trainer).
    pub rt: &'a mut ModelRuntime,
    /// Device train state (shared step counter with GRPO).
    pub state: &'a mut TrainState,
    /// SFT learning rate.
    pub lr: f32,
    tokenizer: Tokenizer,
}

/// Scalar metrics for one SFT step.
#[derive(Clone, Copy, Debug, Default)]
pub struct SftMetrics {
    /// Optimizer step this update produced.
    pub step: i32,
    /// Token-mean cross-entropy loss.
    pub loss: f64,
    /// Masked (answer) tokens in the step.
    pub n_tokens: usize,
    /// RMS gradient norm (diagnostic).
    pub grad_norm: f64,
}

impl<'a> SftTrainer<'a> {
    /// Borrow the runtime + state for a run of SFT steps.
    pub fn new(rt: &'a mut ModelRuntime, state: &'a mut TrainState, lr: f32) -> SftTrainer<'a> {
        SftTrainer { rt, state, lr, tokenizer: Tokenizer::new() }
    }

    /// Pack (prompt, answer) into one [T] row + [T-1] answer mask.
    pub fn pack(&self, task: &Task, t_train: usize) -> (Vec<i32>, Vec<f32>) {
        let mut seq = self.tokenizer.encode_prompt(&task.prompt);
        let plen = seq.len();
        seq.extend(self.tokenizer.encode(&task.answer));
        seq.push(EOS);
        seq.truncate(t_train);
        let alen = seq.len() - plen.min(seq.len());
        let mut tokens = vec![PAD; t_train];
        tokens[..seq.len()].copy_from_slice(&seq);
        let mut mask = vec![0f32; t_train - 1];
        for t in plen.saturating_sub(1)..plen + alen - 1 {
            mask[t] = 1.0;
        }
        (tokens, mask)
    }

    /// One SFT step over `steps_batches` microbatches drawn from `dataset`.
    pub fn step(&mut self, dataset: &mut Dataset, micro_batches: usize) -> Result<SftMetrics> {
        let spec = self.rt.spec.clone();
        let (b, t) = (spec.b_micro, spec.t_train);
        let mut acc: Option<PjRtBuffer> = None;
        let mut loss_sum = 0f64;
        let mut tok_sum = 0f64;
        let mut gn = 0f64;
        for _ in 0..micro_batches {
            let mut tokens = Vec::with_capacity(b * t);
            let mut mask = Vec::with_capacity(b * (t - 1));
            for _ in 0..b {
                let task = dataset.next_task();
                let (tk, mk) = self.pack(&task, t);
                tokens.extend(tk);
                mask.extend(mk);
            }
            let (gbuf, gm) = self.rt.sft_grad(&self.state.buffer, &tokens, &mask)?;
            loss_sum += gm.loss_sum as f64;
            tok_sum += gm.token_count as f64;
            gn = gn.max(gm.grad_norm as f64);
            acc = Some(match acc {
                None => gbuf,
                Some(prev) => self.rt.accum(&prev, &gbuf, 1.0)?,
            });
        }
        let scale = 1.0 / tok_sum.max(1.0) as f32;
        self.state.apply_update(self.rt, &acc.unwrap(), self.lr, scale)?;
        Ok(SftMetrics {
            step: self.state.step,
            loss: loss_sum / tok_sum.max(1.0),
            n_tokens: tok_sum as usize,
            grad_norm: gn,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasks::Family;
    use crate::util::Rng;

    // Packing is testable without a runtime; training itself is covered by
    // the artifact-backed integration tests.
    struct Fake;

    #[test]
    fn pack_masks_answer_and_eos() {
        let task = Family::Reverse.generate(&mut Rng::new(3), 0);
        let tk = Tokenizer::new();
        let prompt = tk.encode_prompt(&task.prompt);
        let answer = tk.encode(&task.answer);
        // Reproduce pack() logic without a ModelRuntime.
        let t_train = 32;
        let mut seq = prompt.clone();
        seq.extend(answer.iter());
        seq.push(EOS);
        let plen = prompt.len();
        let alen = seq.len() - plen;

        // Mask positions plen-1 .. plen+alen-2 predict the answer + EOS.
        let lo = plen - 1;
        let hi = plen + alen - 1;
        assert_eq!(hi - lo, alen);
        assert!(hi <= t_train - 1);
        // The predicted tokens are exactly answer ++ EOS.
        let predicted: Vec<i32> = (lo..hi).map(|t| seq[t + 1]).collect();
        let mut want = answer.clone();
        want.push(EOS);
        assert_eq!(predicted, want);
        let _ = Fake;
    }
}
