//! JSONL metrics logging for training runs (loss/reward curves, stage
//! timings, replay/retention accounting, paged-KV gauges) — consumed by
//! EXPERIMENTS.md and the figure benches. One JSON object per training
//! step; replay cost (`replayed_tokens`), the retention fast path's effect
//! (`retained_hits`/`retained_misses`/`replay_tokens_saved`), and the
//! block economy (`kv_blocks_peak`/`prefix_tokens_shared`/`cow_copies`/
//! `kv_frag`) are all logged so resume-affinity and kv-blocks bench deltas
//! are auditable per step.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use anyhow::{Context, Result};

use super::grpo::StepMetrics;
use crate::coordinator::RolloutStats;
use crate::util::json::Obj;

/// Per-step JSONL metrics sink (or a no-op when disabled).
pub struct MetricsLog {
    out: Option<BufWriter<File>>,
}

impl MetricsLog {
    /// Log to `path`, creating parent directories as needed.
    pub fn to_file(path: &Path) -> Result<MetricsLog> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let f = File::create(path).with_context(|| format!("creating {}", path.display()))?;
        Ok(MetricsLog { out: Some(BufWriter::new(f)) })
    }

    /// A sink that drops everything (the default for ad-hoc sessions).
    pub fn disabled() -> MetricsLog {
        MetricsLog { out: None }
    }

    /// Append one step's metrics as a single JSON line.
    pub fn log_step(
        &mut self,
        m: &StepMetrics,
        rollout: &RolloutStats,
        wall_total: f64,
    ) -> Result<()> {
        let Some(out) = self.out.as_mut() else { return Ok(()) };
        let line = Obj::new()
            .int("step", m.step as i64)
            .num("reward", m.reward_mean)
            .num("loss", m.loss)
            .num("entropy", m.entropy)
            .num("ratio_mean", m.ratio_mean)
            .num("ratio_max", m.ratio_max)
            .num("clip_frac", m.clip_frac)
            .num("kl", m.kl)
            .num("grad_norm", m.grad_norm)
            .int("n_tokens", m.n_tokens as i64)
            .num("offpolicy_frac", m.offpolicy_frac)
            .int("cross_stage_rows", m.cross_stage_rows as i64)
            .num("t_rollout", rollout.wall)
            .num("t_cal_logprob", m.t_cal_logprob)
            .num("t_grad", m.t_grad)
            .num("t_update", m.t_update)
            .num("t_total", wall_total)
            .num("utilization", rollout.mean_utilization())
            .int("preemptions", rollout.preemptions as i64)
            .int("replayed_tokens", rollout.replayed_tokens as i64)
            .int("partials_buffered", rollout.partials_buffered as i64)
            .int("resumed", rollout.resumed as i64)
            .int("retained_hits", rollout.retained_hits as i64)
            .int("retained_misses", rollout.retained_misses as i64)
            .int("replay_tokens_saved", rollout.replay_tokens_saved as i64)
            .int("kv_blocks_peak", rollout.kv_blocks_peak as i64)
            .int("kv_bytes_peak", rollout.kv_bytes_peak as i64)
            .str("sampler_dispatch", rollout.sampler_dispatch)
            .int("prefix_tokens_shared", rollout.prefix_tokens_shared as i64)
            .int("cow_copies", rollout.cow_copies as i64)
            .num("kv_frag", rollout.mean_kv_frag())
            .int("prefill_chunks", rollout.prefill_chunks as i64)
            .num("t_prefill_stall_saved", rollout.t_prefill_stall_saved)
            .num("step_token_util", rollout.step_token_util)
            .num("t_overlap", m.t_overlap)
            .num("overlap_secs", rollout.overlap_secs)
            .int("lagged_trajs", rollout.lagged_trajectories() as i64)
            .int("engine_failures", rollout.engine_failures as i64)
            .int("redispatched", rollout.redispatched_trajectories as i64)
            .int("retries", rollout.retries as i64)
            .int("retain_errors", rollout.retain_errors as i64)
            .int("requests_arrived", rollout.requests_arrived as i64)
            .int("requests_shed", rollout.requests_shed as i64)
            .int("queue_depth_peak", rollout.queue_depth_peak as i64)
            .int("staleness_terminations", rollout.staleness_terminations as i64)
            .int("active_terminations", rollout.active_terminations as i64)
            .int("staging_occupancy_peak", rollout.staging_occupancy_peak as i64)
            .num("slo_e2e_p50_ticks", rollout.slo_e2e_p50_ticks)
            .num("slo_e2e_p99_ticks", rollout.slo_e2e_p99_ticks)
            .num("goodput_rps", rollout.goodput_rps)
            .finish();
        writeln!(out, "{line}")?;
        out.flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn jsonl_lines_parse_back() {
        let dir = std::env::temp_dir().join("copris-test-metrics");
        let path = dir.join("m.jsonl");
        let mut log = MetricsLog::to_file(&path).unwrap();
        let m = StepMetrics { step: 3, reward_mean: 0.5, loss: -0.1, ..Default::default() };
        let r = RolloutStats::default();
        log.log_step(&m, &r, 1.23).unwrap();
        log.log_step(&m, &r, 4.56).unwrap();
        drop(log);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for l in lines {
            let v = json::parse(l).unwrap();
            assert_eq!(v.get("step").unwrap().as_f64(), Some(3.0));
            assert_eq!(v.get("reward").unwrap().as_f64(), Some(0.5));
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn disabled_log_is_noop() {
        let mut log = MetricsLog::disabled();
        let m = StepMetrics::default();
        log.log_step(&m, &RolloutStats::default(), 0.0).unwrap();
    }
}
