//! Pass@1 evaluation over the five held-out suites (Table 1 columns).
//!
//! Matches the paper's protocol shape: `samples_per_prompt` rollouts at
//! eval temperature (0.6), pass@1 = mean correctness over all samples.

use anyhow::Result;

use crate::config::EvalConfig;
use crate::coordinator::Coordinator;
use crate::engine::SamplingParams;
use crate::tasks::{eval_suites, reward, Suite};

#[derive(Clone, Debug)]
pub struct SuiteScore {
    pub name: &'static str,
    pub pass_at_1: f64,
    pub n_prompts: usize,
    pub n_samples: usize,
    pub mean_response_len: f64,
}

#[derive(Clone, Debug, Default)]
pub struct EvalReport {
    pub suites: Vec<SuiteScore>,
}

impl EvalReport {
    pub fn average(&self) -> f64 {
        if self.suites.is_empty() {
            return 0.0;
        }
        self.suites.iter().map(|s| s.pass_at_1).sum::<f64>() / self.suites.len() as f64
    }
}

/// Evaluate one suite through the engine pool (synchronous generation).
pub fn eval_suite(
    coord: &mut Coordinator,
    suite: &Suite,
    cfg: &EvalConfig,
    seed: u64,
) -> Result<SuiteScore> {
    let tasks = suite.tasks(cfg.prompts_per_suite, seed);
    let sampling = SamplingParams {
        temperature: cfg.temperature,
        top_p: cfg.top_p,
        top_k: -1,
    };
    let groups = coord.run_fixed_sync(&tasks, cfg.samples_per_prompt, sampling)?;
    let mut correct = 0.0;
    let mut total = 0usize;
    let mut len_sum = 0usize;
    let tk = coord.tokenizer().clone();
    for g in &groups {
        for t in &g.done {
            correct += reward(&tk.extract_answer(&t.tokens), &t.task.answer);
            len_sum += t.len();
            total += 1;
        }
    }
    Ok(SuiteScore {
        name: suite.name,
        pass_at_1: if total > 0 { correct / total as f64 } else { 0.0 },
        n_prompts: tasks.len(),
        n_samples: total,
        mean_response_len: if total > 0 { len_sum as f64 / total as f64 } else { 0.0 },
    })
}

/// Evaluate all five suites (the Table 1 row for one model).
pub fn eval_all(coord: &mut Coordinator, cfg: &EvalConfig, seed: u64) -> Result<EvalReport> {
    let mut report = EvalReport::default();
    for suite in eval_suites() {
        report.suites.push(eval_suite(coord, &suite, cfg, seed)?);
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn average_of_empty_report_is_zero() {
        assert_eq!(EvalReport::default().average(), 0.0);
    }

    #[test]
    fn average_is_mean_of_suites() {
        let r = EvalReport {
            suites: vec![
                SuiteScore { name: "a", pass_at_1: 0.2, n_prompts: 1, n_samples: 1, mean_response_len: 1.0 },
                SuiteScore { name: "b", pass_at_1: 0.6, n_prompts: 1, n_samples: 1, mean_response_len: 1.0 },
            ],
        };
        assert!((r.average() - 0.4).abs() < 1e-12);
    }
}
