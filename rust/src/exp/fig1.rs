//! Fig. 1 reproduction: the long-tail problem of synchronous rollout.
//! (a) response-length distribution within one batch; (b) per-engine
//! utilization trace showing the straggler-induced dips, vs CoPRIS.

use anyhow::Result;

use crate::config::RolloutMode;
use crate::exp::common::{arm_config, warmed_session};
use crate::tasks::Dataset;
use crate::util::stats::{ascii_histogram, Summary};

pub struct Fig1Report {
    pub lengths: Vec<usize>,
    pub sync_util: Vec<(f64, f64)>,   // (t, busy fraction) down-sampled
    pub copris_util: Vec<(f64, f64)>,
    pub sync_mean_util: f64,
    pub copris_mean_util: f64,
}

fn downsample(points: Vec<(f64, f64)>, n: usize) -> Vec<(f64, f64)> {
    if points.len() <= n {
        return points;
    }
    let stride = points.len() as f64 / n as f64;
    (0..n).map(|i| points[(i as f64 * stride) as usize]).collect()
}

pub fn run(model: &str, sft_steps: usize) -> Result<Fig1Report> {
    // Synchronous stage: all B·G at once, wait for stragglers.
    let mut cfg = arm_config(model, RolloutMode::Sync, 7);
    cfg.rollout.batch_prompts = 8;
    cfg.rollout.group_size = 4;
    let mut sess = warmed_session(cfg, sft_steps, false)?;
    let mut ds = Dataset::train(7);
    let out_sync = sess.coord.rollout_stage(&mut ds)?;
    let sync_util: Vec<(f64, f64)> = out_sync
        .stats
        .traces
        .iter()
        .map(|t| (t.t_wall, t.active as f64 / t.slots as f64))
        .collect();
    let lengths = out_sync.stats.response_lengths.clone();
    let sync_mean = out_sync.stats.mean_utilization();
    sess.shutdown();

    // CoPRIS stage at full-pool concurrency for contrast.
    let mut cfg = arm_config(model, RolloutMode::Copris, 7);
    cfg.rollout.batch_prompts = 8;
    cfg.rollout.group_size = 4;
    let mut sess = warmed_session(cfg, sft_steps, false)?;
    let mut ds = Dataset::train(7);
    let out_cop = sess.coord.rollout_stage(&mut ds)?;
    let copris_util: Vec<(f64, f64)> = out_cop
        .stats
        .traces
        .iter()
        .map(|t| (t.t_wall, t.active as f64 / t.slots as f64))
        .collect();
    let copris_mean = out_cop.stats.mean_utilization();
    sess.shutdown();

    Ok(Fig1Report {
        lengths,
        sync_util: downsample(sync_util, 48),
        copris_util: downsample(copris_util, 48),
        sync_mean_util: sync_mean,
        copris_mean_util: copris_mean,
    })
}

pub fn render(r: &Fig1Report) -> String {
    let mut out = String::new();
    let lens: Vec<f64> = r.lengths.iter().map(|&l| l as f64).collect();
    let s = Summary::of(&lens);
    out.push_str("== Fig 1a: response-length distribution (one sync batch) ==\n");
    out.push_str(&format!(
        "n={} mean={:.1} p50={:.0} p95={:.0} max={:.0}  (long tail: p95/p50 = {:.2}x)\n",
        s.n, s.mean, s.p50, s.p95, s.max,
        if s.p50 > 0.0 { s.p95 / s.p50 } else { 0.0 }
    ));
    for row in ascii_histogram(&lens, 10, 40) {
        out.push_str(&format!("  {row}\n"));
    }
    out.push_str("\n== Fig 1b: busy-slot fraction over the stage ==\n");
    out.push_str("   (sync dips to near-zero while stragglers finish; CoPRIS stays full)\n");
    let bar = |f: f64| "#".repeat((f * 30.0).round() as usize);
    out.push_str("  sync:\n");
    for (t, f) in &r.sync_util {
        out.push_str(&format!("   {t:7.3}s |{:<30}| {:.0}%\n", bar(*f), f * 100.0));
    }
    out.push_str("  copris:\n");
    for (t, f) in &r.copris_util {
        out.push_str(&format!("   {t:7.3}s |{:<30}| {:.0}%\n", bar(*f), f * 100.0));
    }
    out.push_str(&format!(
        "\nmean utilization: sync {:.1}%  vs  CoPRIS {:.1}%\n",
        r.sync_mean_util * 100.0,
        r.copris_mean_util * 100.0
    ));
    out
}
