//! Shared helpers for the experiment drivers (benches + examples).

use anyhow::Result;

use crate::config::{scaled_preset, Config, RolloutMode};
use crate::exp::{RlSession, RunSummary};

/// Environment-tunable experiment scale so `cargo bench` stays tractable on
/// this CPU substrate while remaining faithful in shape. Override with
/// `COPRIS_BENCH_STEPS`, `COPRIS_BENCH_SFT`, `COPRIS_BENCH_MODEL`.
pub fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

pub fn env_str(key: &str, default: &str) -> String {
    std::env::var(key).unwrap_or_else(|_| default.to_string())
}

pub fn artifacts_available(variant: &str) -> bool {
    std::path::Path::new("artifacts").join(variant).join("manifest.json").exists()
}

/// Standard experiment config for one arm.
pub fn arm_config(model: &str, mode: RolloutMode, seed: u64) -> Config {
    let mut cfg = scaled_preset(model);
    cfg.rollout.mode = mode;
    cfg.train.seed = seed;
    cfg
}

/// SFT-warm a model ONCE and cache the checkpoint under runs/ — every
/// experiment arm starts RL from the same "basemodel" (the paper RL-tunes
/// one pretrained checkpoint per model), and the warmup cost is paid once.
pub fn shared_warm_checkpoint(model: &str, sft_steps: usize) -> Result<std::path::PathBuf> {
    let path = std::path::PathBuf::from(format!("runs/warm-{model}-{sft_steps}.ckpt"));
    if path.exists() {
        return Ok(path);
    }
    eprintln!("[warmup] SFT-warming {model} for {sft_steps} steps (cached at {})", path.display());
    let cfg = scaled_preset(model);
    // SFT needs no engine pool: drive the trainer directly.
    let mut trainer = crate::trainer::Trainer::new(cfg.clone(), cfg.train.seed as i32)?;
    trainer.rt.warmup(&["sft_grad"])?;
    let mut ds = crate::tasks::Dataset::sft(cfg.train.seed);
    let lr = (cfg.train.lr * 3.0) as f32;
    for s in 0..sft_steps {
        let mut sft =
            crate::trainer::SftTrainer::new(&mut trainer.rt, &mut trainer.state, lr);
        let m = sft.step(&mut ds, 2)?;
        if s % 25 == 0 || s + 1 == sft_steps {
            eprintln!("[warmup {s:>4}] loss {:.4}", m.loss);
        }
    }
    trainer.save(&path)?;
    Ok(path)
}

/// Build + warm up a session from the shared checkpoint (falls back to
/// inline warmup when sft_steps == 0).
pub fn warmed_session(cfg: Config, sft_steps: usize, verbose: bool) -> Result<RlSession> {
    let ckpt = if sft_steps > 0 {
        Some(shared_warm_checkpoint(&cfg.model, sft_steps)?)
    } else {
        None
    };
    let mut sess = RlSession::build_with_checkpoint(cfg, ckpt.as_deref())?;
    sess.verbose = verbose;
    // Push the (possibly restored) weights to the engines.
    let params = sess.trainer.params()?;
    let version = sess.trainer.step() as u64;
    sess.coord.sync_weights(version, params);
    Ok(sess)
}

/// One full arm: warmup → RL train → eval; returns (summary, eval avg, suite scores).
pub struct ArmResult {
    pub summary: RunSummary,
    pub suite_scores: Vec<(String, f64)>,
    pub average: f64,
}

pub fn run_arm(cfg: Config, sft_steps: usize, rl_steps: usize, verbose: bool) -> Result<ArmResult> {
    let mut sess = warmed_session(cfg, sft_steps, verbose)?;
    let summary = sess.train(rl_steps)?;
    let report = sess.evaluate(2)?;
    let suite_scores =
        report.suites.iter().map(|s| (s.name.to_string(), s.pass_at_1)).collect();
    let average = report.average();
    sess.shutdown();
    Ok(ArmResult { summary, suite_scores, average })
}

pub fn fmt_pct(x: f64) -> String {
    format!("{:.1}", x * 100.0)
}
