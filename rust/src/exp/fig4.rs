//! Fig. 4 reproduction: ablation of Cross-stage Importance Sampling
//! Correction — w/ IS vs w/o IS training curves (AIME24*/AIME25* scores
//! over RL steps) at two model scales.

use anyhow::Result;

use crate::config::RolloutMode;
use crate::exp::common::{arm_config, warmed_session};

pub struct Curve {
    pub label: String,
    /// (step, aime24, aime25, reward, entropy, ratio_max)
    pub points: Vec<(usize, f64, f64, f64, f64, f64)>,
}

pub fn run_curve(
    model: &str,
    use_is: bool,
    sft: usize,
    rl_steps: usize,
    eval_every: usize,
) -> Result<Curve> {
    let mut cfg = arm_config(model, RolloutMode::Copris, 7);
    cfg.rollout.importance_sampling = use_is;
    let mut sess = warmed_session(cfg, sft, false)?;
    let mut points = Vec::new();
    let mut done = 0usize;
    while done < rl_steps {
        let chunk = eval_every.min(rl_steps - done);
        let mut reward = 0.0;
        let mut entropy = 0.0;
        let mut ratio_max: f64 = 0.0;
        for _ in 0..chunk {
            let (m, _) = sess.rl_step()?;
            reward = m.reward_mean;
            entropy = m.entropy;
            ratio_max = ratio_max.max(m.ratio_max);
        }
        done += chunk;
        let report = sess.evaluate(2)?;
        points.push((
            done,
            report.suites[0].pass_at_1,
            report.suites[1].pass_at_1,
            reward,
            entropy,
            ratio_max,
        ));
        eprintln!(
            "[fig4] {model} {} step {done}: aime24*={:.3} aime25*={:.3} reward={reward:.3}",
            if use_is { "w/ IS" } else { "w/o IS" },
            report.suites[0].pass_at_1,
            report.suites[1].pass_at_1,
        );
    }
    sess.shutdown();
    Ok(Curve {
        label: format!("{model} {}", if use_is { "w/ IS" } else { "w/o IS" }),
        points,
    })
}

pub fn run(models: &[&str], sft: usize, rl_steps: usize, eval_every: usize) -> Result<Vec<Curve>> {
    let mut curves = Vec::new();
    for m in models {
        curves.push(run_curve(m, true, sft, rl_steps, eval_every)?);
        curves.push(run_curve(m, false, sft, rl_steps, eval_every)?);
    }
    Ok(curves)
}

pub fn render(curves: &[Curve]) -> String {
    let mut out = String::from(
        "== Fig 4: Cross-stage IS Correction ablation ==\n\
         (per-curve: step → AIME24*, AIME25*, train reward, entropy, max ratio)\n",
    );
    for c in curves {
        out.push_str(&format!("\n--- {} ---\n", c.label));
        for (step, a24, a25, rew, ent, rmax) in &c.points {
            out.push_str(&format!(
                "  step {step:>4}: aime24* {:.3}  aime25* {:.3}  reward {:.3}  entropy {:.3}  ratio_max {:.2}\n",
                a24, a25, rew, ent, rmax
            ));
        }
    }
    out.push_str(
        "\npaper shape: w/ IS is consistently better/stabler; the gap widens on\n\
         the larger model (w/o IS shows volatile dynamics).\n",
    );
    out
}
