//! Table 1 reproduction: end-to-end comparison — Basemodel vs veRL (sync)
//! vs CoPRIS — pass@1 on the five suites, training wall-clock, speedup.

use anyhow::Result;

use crate::bench::render_table;
use crate::config::RolloutMode;
use crate::exp::common::{arm_config, fmt_pct, run_arm, warmed_session};

pub struct Table1Row {
    pub model: String,
    pub arm: &'static str,
    pub suites: Vec<(String, f64)>,
    pub average: f64,
    pub train_secs: f64,
    pub speedup: f64,
}

pub fn run(models: &[&str], sft_steps: usize, rl_steps: usize) -> Result<Vec<Table1Row>> {
    let mut rows = Vec::new();
    for model in models {
        eprintln!("[table1] {model}: basemodel eval");
        // Basemodel: SFT warmup only (the stand-in for the pretrained LLM).
        let mut sess =
            warmed_session(arm_config(model, RolloutMode::Sync, 7), sft_steps, false)?;
        let base = sess.evaluate(2)?;
        sess.shutdown();
        rows.push(Table1Row {
            model: model.to_string(),
            arm: "Basemodel",
            suites: base.suites.iter().map(|s| (s.name.to_string(), s.pass_at_1)).collect(),
            average: base.average(),
            train_secs: 0.0,
            speedup: 0.0,
        });

        eprintln!("[table1] {model}: veRL (sync) arm, {rl_steps} RL steps");
        let sync = run_arm(arm_config(model, RolloutMode::Sync, 7), sft_steps, rl_steps, false)?;
        let sync_secs = sync.summary.wall;
        rows.push(Table1Row {
            model: model.to_string(),
            arm: "veRL (sync)",
            suites: sync.suite_scores,
            average: sync.average,
            train_secs: sync_secs,
            speedup: 1.0,
        });

        eprintln!("[table1] {model}: CoPRIS arm, {rl_steps} RL steps");
        let cop =
            run_arm(arm_config(model, RolloutMode::Copris, 7), sft_steps, rl_steps, false)?;
        rows.push(Table1Row {
            model: model.to_string(),
            arm: "CoPRIS",
            suites: cop.suite_scores,
            average: cop.average,
            train_secs: cop.summary.wall,
            speedup: sync_secs / cop.summary.wall.max(1e-9),
        });
    }
    Ok(rows)
}

pub fn render(rows: &[Table1Row]) -> String {
    let mut out = String::from(
        "== Table 1: End-to-End Performance Comparison ==\n\
         (pass@1 percent on the five held-out suites; Training Time = RL wall seconds)\n\n",
    );
    let headers = [
        "Model", "Arm", "AIME24*", "AIME25*", "AMC*", "Minerva*", "Olympiad*",
        "Average", "Train s", "Speedup",
    ];
    let table_rows: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            let mut cells = vec![r.model.clone(), r.arm.to_string()];
            for (_, score) in &r.suites {
                cells.push(fmt_pct(*score));
            }
            while cells.len() < 7 {
                cells.push("-".into());
            }
            cells.push(fmt_pct(r.average));
            cells.push(if r.train_secs > 0.0 {
                format!("{:.1}", r.train_secs)
            } else {
                "-".into()
            });
            cells.push(if r.speedup > 0.0 && r.arm == "CoPRIS" {
                format!("{:.2}x", r.speedup)
            } else {
                "-".into()
            });
            cells
        })
        .collect();
    out.push_str(&render_table(&headers, &table_rows));
    out.push_str(
        "\npaper shape: CoPRIS 1.58-1.94x faster than veRL at comparable or better average.\n",
    );
    out
}
