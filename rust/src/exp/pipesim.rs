//! Serial vs stage-pipelined vs fully-async CoPRIS on the mock backend:
//! isolates the coordinator-level overlap win from trainer math (no
//! artifacts, no PJRT). The "trainer" is a simulated compute window (sleep
//! + weight sync) so the comparison measures exactly what the execution
//! mode changes: whether the engines generate through the update or sit
//! idle, and (async) whether batch boundaries still quiesce the stream.
//!
//! Shared by the `pipeline_overlap` / `async_overlap` bench targets and
//! the pipelined/async integration tests.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::{Config, ExecMode};
use crate::coordinator::{Coordinator, RolloutOutput};
use crate::engine::{EnginePool, MockBackend};
use crate::tasks::Dataset;

/// Mock decode horizon (matches the coordinator integration tests).
pub const MOCK_MAX_SEQ: usize = 96;

#[derive(Clone, Debug)]
pub struct PipeSimOpts {
    /// Rollout/engine settings (mode should be Copris; `pipeline` is taken
    /// from the `pipeline` argument of [`run`], not from here).
    pub cfg: Config,
    /// RL steps to simulate.
    pub steps: usize,
    /// Simulated per-step trainer compute (the window the pipelined run
    /// overlaps).
    pub train_secs: f64,
    /// Mock decode slots per engine.
    pub slots: usize,
    /// Scripted response length = min_len + hash % spread.
    pub min_len: usize,
    pub spread: usize,
    /// Per-decode-step latency — the "non-trivial decode delay" that makes
    /// overlap measurable.
    pub decode_delay: Duration,
}

impl Default for PipeSimOpts {
    fn default() -> Self {
        let mut cfg = Config::new("mock");
        cfg.rollout.batch_prompts = 2;
        cfg.rollout.group_size = 2;
        cfg.rollout.concurrency = 8;
        cfg.engine.engines = 1;
        cfg.train.seed = 11;
        PipeSimOpts {
            cfg,
            steps: 6,
            train_secs: 0.06,
            slots: 4,
            min_len: 20,
            spread: 20,
            decode_delay: Duration::from_millis(1),
        }
    }
}

/// Aggregate result of one simulated run.
#[derive(Clone, Debug, Default)]
pub struct PipeSimSummary {
    pub wall: f64,
    /// Trajectories harvested for training across all steps.
    pub samples: usize,
    /// Harvested groups across all steps (== steps × B on success).
    pub groups: usize,
    pub rollout_secs: f64,
    pub overlap_secs: f64,
    /// Harvested trajectories spanning more than one policy version.
    pub lagged_trajectories: usize,
    /// Partials parked in the buffer across all stages.
    pub partials_buffered: usize,
    /// Buffered partials popped and re-dispatched across all stages.
    pub resumed: usize,
    /// Resume tokens replayed (recompute cost) across all stages.
    pub replayed_tokens: u64,
    /// Resumes served from retained KV (affinity hits).
    pub retained_hits: usize,
    /// Affinity-routed resumes that fell back to replay.
    pub retained_misses: usize,
    /// Resume tokens never recomputed thanks to retained-KV hits.
    pub replay_tokens_saved: u64,
    /// Async: mandatory staleness-bound cuts across all sync windows.
    pub staleness_terminations: usize,
    /// Async: APRIL-style active cuts across all sync windows.
    pub active_terminations: usize,
}

fn spawn_coordinator(o: &PipeSimOpts) -> Result<Coordinator> {
    let slots = o.slots;
    let (min_len, spread, delay) = (o.min_len, o.spread, o.decode_delay);
    let pool = EnginePool::spawn_kv(
        o.cfg.engine.engines,
        slots,
        o.cfg.engine.kv_cache_config(),
        o.cfg.train.seed,
        move |_id| {
            Box::new(move || {
                let mut b = MockBackend::new(slots, MOCK_MAX_SEQ);
                b.min_len = min_len;
                b.spread = spread;
                b.decode_delay = Some(delay);
                Ok(b)
            })
        },
    )?;
    Ok(Coordinator::new(pool, o.cfg.clone(), MOCK_MAX_SEQ))
}

/// Run `o.steps` simulated RL steps, serial or stage-pipelined, and return
/// the summary plus every harvested stage output (for invariant checks).
/// Shim over [`run_mode`] kept for the pre-async callers.
pub fn run(o: &PipeSimOpts, pipeline: bool) -> Result<(PipeSimSummary, Vec<RolloutOutput>)> {
    run_mode(o, if pipeline { ExecMode::Pipelined } else { ExecMode::Serial })
}

/// Run `o.steps` simulated RL steps under the given execution mode and
/// return the summary plus every harvested batch (for invariant checks).
/// The async arm drives the full session protocol: one never-quiescing
/// stream, `take_async_batch` per step, `prepare_sync` under the
/// `o.cfg.rollout.max_staleness` bound, pump-through-the-train-window.
pub fn run_mode(o: &PipeSimOpts, mode: ExecMode) -> Result<(PipeSimSummary, Vec<RolloutOutput>)> {
    let mut coord = spawn_coordinator(o)?;
    let mut ds = Dataset::train(o.cfg.train.seed);
    let mut outs: Vec<RolloutOutput> = Vec::new();
    let mut version = 0u64;
    let t_run = Instant::now();

    // Simulated trainer update: compute window + weight sync. The mock
    // backend shifts its script on set_params, so syncs are observable.
    let mut train_and_sync = |coord: &mut Coordinator,
                              ds: &mut Dataset,
                              pumped: bool|
     -> Result<()> {
        let t0 = Instant::now();
        if pumped {
            // Pipelined/async: pump in-flight work between "microbatches".
            while t0.elapsed().as_secs_f64() < o.train_secs {
                if coord.async_active() {
                    coord.pump_async(ds, Instant::now())?;
                } else if coord.stage_active() {
                    coord.pump(ds, Instant::now())?;
                }
                std::thread::sleep(Duration::from_millis(2));
            }
        } else {
            std::thread::sleep(Duration::from_secs_f64(o.train_secs));
        }
        version += 1;
        if coord.async_active() {
            // Bounded-staleness protocol: cut over-staleness work, then
            // broadcast, then resume the paused refill under the new
            // version.
            coord.prepare_sync(version)?;
            coord.sync_weights(version, Arc::new(vec![version as f32 * 0.5 + 1.0]));
            coord.resume_refill(ds)?;
        } else {
            coord.sync_weights(version, Arc::new(vec![version as f32 * 0.5 + 1.0]));
        }
        Ok(())
    };

    match mode {
        ExecMode::Async => {
            coord.begin_async(&mut ds)?;
            for _ in 0..o.steps {
                while !coord.pump_async(&mut ds, Instant::now() + Duration::from_secs(60))? {}
                let out = coord.take_async_batch()?;
                let t_train = Instant::now();
                train_and_sync(&mut coord, &mut ds, true)?;
                coord.note_overlap(t_train.elapsed().as_secs_f64());
                outs.push(out);
            }
            // The still-streaming tail is abandoned, mirroring the
            // pipelined arm's final begun stage.
            coord.abort_stage()?;
        }
        ExecMode::Pipelined => {
            for _ in 0..o.steps {
                // Harvest the stage left in flight by the previous
                // iteration (first iteration: serial rollout).
                let out = if coord.stage_active() {
                    coord.run_stage_to_completion(&mut ds)?
                } else {
                    coord.rollout_stage(&mut ds)?
                };
                // Begin the next stage, then "train" while it generates; it
                // stays in flight across the loop boundary (mirrors
                // RlSession::rl_step_pipelined). The final begun stage is
                // abandoned at shutdown — only its dispatches are wasted, so
                // the serial-vs-pipelined comparison stays N stages vs N.
                coord.begin_stage(&mut ds)?;
                let t_train = Instant::now();
                train_and_sync(&mut coord, &mut ds, true)?;
                coord.note_overlap(t_train.elapsed().as_secs_f64());
                outs.push(out);
            }
        }
        ExecMode::Serial => {
            for _ in 0..o.steps {
                let out = coord.rollout_stage(&mut ds)?;
                train_and_sync(&mut coord, &mut ds, false)?;
                outs.push(out);
            }
        }
    }

    let mut s = PipeSimSummary { wall: t_run.elapsed().as_secs_f64(), ..Default::default() };
    for out in &outs {
        s.groups += out.groups.len();
        s.samples += out.stats.completed;
        s.rollout_secs += out.stats.wall;
        s.overlap_secs += out.stats.overlap_secs;
        s.lagged_trajectories += out.stats.lagged_trajectories();
        s.partials_buffered += out.stats.partials_buffered;
        s.resumed += out.stats.resumed;
        s.replayed_tokens += out.stats.replayed_tokens;
        s.retained_hits += out.stats.retained_hits;
        s.retained_misses += out.stats.retained_misses;
        s.replay_tokens_saved += out.stats.replay_tokens_saved;
        s.staleness_terminations += out.stats.staleness_terminations;
        s.active_terminations += out.stats.active_terminations;
    }
    coord.shutdown();
    Ok((s, outs))
}
