//! Fig. 3 reproduction: scalability of CoPRIS vs sync across (a) context
//! length and (b) model size. Reports effective throughput (samples/s
//! consumed by training) and the CoPRIS/sync speedup per point.

use anyhow::Result;

use crate::bench::render_table;
use crate::config::RolloutMode;
use crate::exp::common::{arm_config, artifacts_available, warmed_session};

pub struct Fig3Point {
    pub label: String,
    pub sync_tput: f64,
    pub copris_tput: f64,
    pub speedup: f64,
}

fn measure(model: &str, mode: RolloutMode, sft: usize, steps: usize) -> Result<f64> {
    let cfg = arm_config(model, mode, 7);
    let mut sess = warmed_session(cfg, sft, false)?;
    let summary = sess.train(steps)?;
    sess.shutdown();
    Ok(summary.throughput)
}

fn point(label: &str, model: &str, sft: usize, steps: usize) -> Result<Fig3Point> {
    eprintln!("[fig3] {label}: sync");
    let sync = measure(model, RolloutMode::Sync, sft, steps)?;
    eprintln!("[fig3] {label}: copris");
    let cop = measure(model, RolloutMode::Copris, sft, steps)?;
    Ok(Fig3Point {
        label: label.to_string(),
        sync_tput: sync,
        copris_tput: cop,
        speedup: cop / sync.max(1e-9),
    })
}

/// (a) context scaling: `small` variants at growing decode horizons
/// (requires `make artifacts-fig3`); (b) model-size scaling.
pub fn run(sft: usize, steps: usize) -> Result<(Vec<Fig3Point>, Vec<Fig3Point>)> {
    let mut ctx = Vec::new();
    for (label, variant) in [
        ("ctx 64", "small@t64"),
        ("ctx 128", "small@t128"),
        ("ctx 192", "small"),
        ("ctx 256", "small@t256"),
    ] {
        if !artifacts_available(variant) {
            eprintln!("[fig3] skipping {variant} (artifacts missing; run `make artifacts-fig3`)");
            continue;
        }
        ctx.push(point(label, variant, sft, steps)?);
    }

    let mut sizes = Vec::new();
    for (label, variant) in [("tiny 0.1M", "tiny"), ("small 0.9M", "small"), ("base 5M", "base"), ("large 25M", "large")] {
        if !artifacts_available(variant) {
            eprintln!("[fig3] skipping {variant} (artifacts missing)");
            continue;
        }
        sizes.push(point(label, variant, sft, steps)?);
    }
    Ok((ctx, sizes))
}

pub fn render(ctx: &[Fig3Point], sizes: &[Fig3Point]) -> String {
    let fmt = |points: &[Fig3Point]| {
        let headers = ["Point", "veRL tput (samp/s)", "CoPRIS tput", "Speedup"];
        let rows: Vec<Vec<String>> = points
            .iter()
            .map(|p| {
                vec![
                    p.label.clone(),
                    format!("{:.2}", p.sync_tput),
                    format!("{:.2}", p.copris_tput),
                    format!("{:.2}x", p.speedup),
                ]
            })
            .collect();
        render_table(&headers, &rows)
    };
    let mut out = String::from("== Fig 3a: context-length scaling ==\n");
    out.push_str(&fmt(ctx));
    out.push_str("\n== Fig 3b: model-size scaling ==\n");
    out.push_str(&fmt(sizes));
    out.push_str(
        "\npaper shape: speedup grows with context length (1.27x@8K → 2.26x@40K)\n\
         and stays >1.5x across model sizes.\n",
    );
    out
}
