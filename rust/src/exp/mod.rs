//! Experiment drivers: `session` wires pool + coordinator + trainer into a
//! full RL run; the numbered modules regenerate each paper table/figure and
//! are shared between `cargo bench` targets and `examples/`.

pub mod common;
pub mod fig1;
pub mod fig3;
pub mod fig4;
pub mod pipesim;
pub mod session;
pub mod table1;
pub mod table2;

pub use session::{RlSession, RunSummary};
