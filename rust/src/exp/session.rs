//! RlSession: the end-to-end RL post-training pipeline.
//!
//! rollout stage (engine pool, mode per config) → reward/advantage →
//! cal-logprob → GRPO update (w/ or w/o cross-stage IS) → weight sync →
//! repeat; periodic eval over the five suites.



use anyhow::{Context, Result};

use crate::config::Config;
use crate::coordinator::{Coordinator, RolloutStats};
use crate::engine::{EnginePool, XlaBackend};
use crate::eval::{eval_all, EvalReport};
use crate::tasks::Dataset;
use crate::trainer::{MetricsLog, SftTrainer, StepMetrics, Trainer};
use crate::util::StageTimer;

pub struct RlSession {
    pub coord: Coordinator,
    pub trainer: Trainer,
    pub dataset: Dataset,
    pub timer: StageTimer,
    pub log: MetricsLog,
    pub verbose: bool,
}

/// Aggregate summary of a training run (feeds Table 1 / Fig 3 rows).
#[derive(Clone, Debug, Default)]
pub struct RunSummary {
    pub steps: usize,
    pub wall: f64,
    /// Samples consumed per second (paper Fig. 3 "effective throughput").
    pub throughput: f64,
    pub final_reward: f64,
    pub mean_utilization: f64,
    pub rollout_secs: f64,
    pub cal_logprob_secs: f64,
    pub train_secs: f64,
    pub sync_secs: f64,
    pub preemptions: u64,
    pub replayed_tokens: u64,
    pub reward_curve: Vec<f64>,
    pub entropy_curve: Vec<f64>,
}

impl RlSession {
    /// Build the full stack from a config (trainer + engine pool + coord).
    pub fn build(cfg: Config) -> Result<RlSession> {
        Self::build_with_checkpoint(cfg, None)
    }

    /// Build with the trainer restored from a checkpoint (shared SFT warmup
    /// across experiment arms — see exp::common::shared_warm_checkpoint).
    pub fn build_with_checkpoint(
        cfg: Config,
        checkpoint: Option<&std::path::Path>,
    ) -> Result<RlSession> {
        let mut trainer = match checkpoint {
            Some(p) => Trainer::from_checkpoint(cfg.clone(), p)
                .with_context(|| format!("loading checkpoint {}", p.display()))?,
            None => Trainer::new(cfg.clone(), cfg.train.seed as i32)
                .context("building trainer")?,
        };
        let params = trainer.params()?;
        let spec = trainer.rt.spec.clone();
        let dir = cfg.artifacts_dir.clone();
        let variant = cfg.model.clone();
        let init_params = params.clone();
        let chunked_replay = cfg.engine.chunked_replay;
        let pool = EnginePool::spawn(
            cfg.engine.engines,
            spec.slots,
            cfg.engine.kv_budget_tokens,
            cfg.train.seed,
            move |_id| {
                let dir = dir.clone();
                let variant = variant.clone();
                let p = init_params.clone();
                Box::new(move || {
                    let mut b = XlaBackend::open(&dir, &variant, &p)?;
                    b.chunked_replay = chunked_replay;
                    Ok(b)
                })
            },
        )?;
        let mut coord = Coordinator::new(pool, cfg.clone(), spec.max_seq);
        coord.policy_version = trainer.step() as u64;
        let dataset = Dataset::train(cfg.train.seed);
        Ok(RlSession {
            coord,
            trainer,
            dataset,
            timer: StageTimer::new(),
            log: MetricsLog::disabled(),
            verbose: false,
        })
    }

    /// Supervised warmup on easy tasks (produces the "basemodel").
    pub fn sft_warmup(&mut self, steps: usize, micro_batches: usize) -> Result<f64> {
        let mut ds = Dataset::sft(self.trainer.cfg.train.seed);
        let lr = (self.trainer.cfg.train.lr * 3.0) as f32; // warmup can run hotter
        let mut last_loss = f64::NAN;
        for s in 0..steps {
            let mut sft =
                SftTrainer::new(&mut self.trainer.rt, &mut self.trainer.state, lr);
            let m = sft.step(&mut ds, micro_batches)?;
            last_loss = m.loss;
            if self.verbose && (s % 10 == 0 || s + 1 == steps) {
                eprintln!("[sft {s:>4}] loss {:.4}  tokens {}", m.loss, m.n_tokens);
            }
        }
        // Sync the warmed-up weights to the engines. The policy version
        // must track the optimizer step counter (SFT shares it) so the
        // trainer's off-policy accounting stays consistent.
        let params = self.trainer.params()?;
        let version = self.trainer.step() as u64;
        self.coord.sync_weights(version, params);
        Ok(last_loss)
    }

    /// One full RL step: rollout stage → GRPO update → weight sync.
    pub fn rl_step(&mut self) -> Result<(StepMetrics, RolloutStats)> {
        let t_all = std::time::Instant::now();
        let t0 = std::time::Instant::now();
        let out = self.coord.rollout_stage(&mut self.dataset)?;
        self.timer.add("rollout", t0.elapsed().as_secs_f64());

        let metrics = self.trainer.train_step(&out.groups, &mut self.timer)?;

        let t0 = std::time::Instant::now();
        let params = self.trainer.params()?;
        let version = self.trainer.step() as u64;
        self.coord.sync_weights(version, params);
        self.timer.add("sync", t0.elapsed().as_secs_f64());

        self.log.log_step(&metrics, &out.stats, t_all.elapsed().as_secs_f64())?;
        Ok((metrics, out.stats))
    }

    /// Run `steps` RL steps, returning the run summary.
    pub fn train(&mut self, steps: usize) -> Result<RunSummary> {
        let t0 = std::time::Instant::now();
        let mut summary = RunSummary { steps, ..Default::default() };
        let mut samples = 0usize;
        let mut util = Vec::new();
        for s in 0..steps {
            let (m, rs) = self.rl_step()?;
            samples += rs.completed;
            util.push(rs.mean_utilization());
            summary.preemptions += rs.preemptions;
            summary.replayed_tokens += rs.replayed_tokens;
            summary.reward_curve.push(m.reward_mean);
            summary.entropy_curve.push(m.entropy);
            summary.final_reward = m.reward_mean;
            if self.verbose {
                eprintln!(
                    "[rl {s:>4}] reward {:.3}  loss {:+.4}  ent {:.3}  ratio {:.3}  clip {:.3}  offpol {:.2}  rollout {:.2}s util {:.0}%",
                    m.reward_mean, m.loss, m.entropy, m.ratio_mean, m.clip_frac,
                    m.offpolicy_frac, rs.wall, rs.mean_utilization() * 100.0
                );
            }
            let every = self.trainer.cfg.train.checkpoint_every;
            if every > 0 && (s + 1) % every == 0 {
                let dir = self.trainer.cfg.train.checkpoint_dir.clone();
                let path = std::path::Path::new(&dir)
                    .join(format!("{}-step{}.ckpt", self.trainer.cfg.model, s + 1));
                self.trainer.save(&path)?;
            }
        }
        summary.wall = t0.elapsed().as_secs_f64();
        summary.throughput = samples as f64 / summary.wall.max(1e-9);
        summary.mean_utilization = crate::util::stats::mean(&util);
        summary.rollout_secs = self.timer.total("rollout");
        summary.cal_logprob_secs = self.timer.total("cal_logprob");
        summary.train_secs = self.timer.total("grad") + self.timer.total("update");
        summary.sync_secs = self.timer.total("sync");
        Ok(summary)
    }

    /// Evaluate the current policy on the five suites.
    pub fn evaluate(&mut self, seed: u64) -> Result<EvalReport> {
        let cfg = self.trainer.cfg.eval.clone();
        eval_all(&mut self.coord, &cfg, seed)
    }

    pub fn shutdown(self) {
        self.coord.shutdown();
    }
}
