//! RlSession: the end-to-end RL post-training pipeline.
//!
//! Serial (`rollout.pipeline = false`, the paper): rollout stage → reward/
//! advantage → cal-logprob → GRPO update (w/ or w/o cross-stage IS) →
//! weight sync → repeat; periodic eval over the five suites.
//!
//! Stage-pipelined (`rollout.pipeline = true`): stage t+1's rollout BEGINS
//! under policy v_t before the stage-t update runs, is pumped between
//! trainer microbatches (the engines generate on their own threads the
//! whole time), and weights sync mid-flight when the update lands —
//! in-flight trajectories simply gain another version segment, which the
//! cross-stage IS correction already models. The stage stays in flight
//! across the step boundary; the next step (or an eval's `abort_stage`)
//! picks it up.

use anyhow::{ensure, Context, Result};

use crate::config::{Config, ExecMode, TransportKind};
use crate::coordinator::{Coordinator, RolloutOutput, RolloutStats};
use crate::engine::{EnginePool, XlaBackend};
use crate::router::RouterPool;
use crate::eval::{eval_all, EvalReport};
use crate::tasks::Dataset;
use crate::trainer::{MetricsLog, SftTrainer, StepMetrics, Trainer};
use crate::util::StageTimer;

pub struct RlSession {
    pub coord: Coordinator,
    pub trainer: Trainer,
    pub dataset: Dataset,
    pub timer: StageTimer,
    pub log: MetricsLog,
    pub verbose: bool,
}

/// Aggregate summary of a training run (feeds Table 1 / Fig 3 rows).
#[derive(Clone, Debug, Default)]
pub struct RunSummary {
    pub steps: usize,
    pub wall: f64,
    /// Samples consumed per second (paper Fig. 3 "effective throughput").
    pub throughput: f64,
    pub final_reward: f64,
    pub mean_utilization: f64,
    pub rollout_secs: f64,
    pub cal_logprob_secs: f64,
    pub train_secs: f64,
    pub sync_secs: f64,
    pub preemptions: u64,
    pub replayed_tokens: u64,
    /// Resumes served from retained KV across the run (affinity hits).
    pub retained_hits: usize,
    /// Affinity-routed resumes that fell back to replay.
    pub retained_misses: usize,
    /// Resume tokens never recomputed thanks to retained-KV hits.
    pub replay_tokens_saved: u64,
    /// Peak KV blocks in use on any one engine across the run (paged KV).
    pub kv_blocks_peak: usize,
    /// Peak KV bytes resident on any one engine across the run — block
    /// peak mapped to real memory at the configured `engine.kv_dtype`.
    pub kv_bytes_peak: usize,
    /// Sampler SIMD arm the engines ran (`scalar` | `avx2` | `avx512`;
    /// `""` if no step trace was observed).
    pub sampler_dispatch: &'static str,
    /// Prompt tokens attached from shared group prefixes instead of
    /// freshly charged (paged KV; run total).
    pub prefix_tokens_shared: u64,
    /// Copy-on-write block copies (paged KV; run total).
    pub cow_copies: u64,
    /// Rollout seconds that overlapped trainer compute (pipelined mode).
    pub overlap_secs: f64,
    /// Harvested trajectories spanning more than one policy version.
    pub lagged_trajectories: usize,
    /// Buffered partials resumed across the run (prioritized resumption).
    pub resumed: usize,
    /// Chunked-ingestion backend calls across the run (continuous
    /// batching; 0 with `engine.step_token_budget = 0`).
    pub prefill_chunks: u64,
    /// Seconds of prefill-chunk compute overlapped with live decode lanes
    /// (stall the legacy admission prefill would have imposed).
    pub t_prefill_stall_saved: f64,
    /// Mean packed-step token utilization across budgeted stages (0.0
    /// when continuous batching is off).
    pub step_token_util: f64,
    /// Engine failures absorbed across the run (fatal backend errors,
    /// panics, exhausted retries, stall-watchdog declarations).
    pub engine_failures: usize,
    /// In-flight trajectories re-dispatched onto surviving engines after
    /// engine failures.
    pub redispatched_trajectories: usize,
    /// Transient backend errors retried in place across the run.
    pub retries: u64,
    /// Backend `retain_slot` errors swallowed at flush across the run.
    pub retain_errors: u64,
    /// Open-loop arrivals observed across the run (0 for the closed-loop
    /// training stages; populated when a stage runs under the SLO harness).
    pub requests_arrived: usize,
    /// Open-loop arrivals shed at the admission queue across the run.
    pub requests_shed: usize,
    /// Maximum admission-queue depth observed across the run.
    pub queue_depth_peak: usize,
    /// In-flight assignments force-cut at async weight syncs for
    /// exceeding `rollout.max_staleness` (0 outside async execution).
    pub staleness_terminations: usize,
    /// At-risk in-flight assignments cut by the active partial-rollout
    /// policy at async weight syncs.
    pub active_terminations: usize,
    /// Peak completed-but-unharvested groups staged ahead of the trainer
    /// (async execution's buffer-occupancy gauge).
    pub staging_occupancy_peak: usize,
    pub reward_curve: Vec<f64>,
    pub entropy_curve: Vec<f64>,
}

impl RlSession {
    /// Build the full stack from a config (trainer + engine pool + coord).
    pub fn build(cfg: Config) -> Result<RlSession> {
        Self::build_with_checkpoint(cfg, None)
    }

    /// Build with the trainer restored from a checkpoint (shared SFT warmup
    /// across experiment arms — see exp::common::shared_warm_checkpoint).
    pub fn build_with_checkpoint(
        cfg: Config,
        checkpoint: Option<&std::path::Path>,
    ) -> Result<RlSession> {
        let mut trainer = match checkpoint {
            Some(p) => Trainer::from_checkpoint(cfg.clone(), p)
                .with_context(|| format!("loading checkpoint {}", p.display()))?,
            None => Trainer::new(cfg.clone(), cfg.train.seed as i32)
                .context("building trainer")?,
        };
        let params = trainer.params()?;
        let spec = trainer.rt.spec.clone();
        let mut coord = match cfg.router.transport {
            TransportKind::Local => {
                let dir = cfg.artifacts_dir.clone();
                let variant = cfg.model.clone();
                let init_params = params.clone();
                let chunked_replay = cfg.engine.chunked_replay;
                let pool = EnginePool::spawn_supervised(
                    cfg.engine.engines,
                    spec.slots,
                    cfg.engine.engine_opts(),
                    cfg.engine.supervisor_opts(),
                    cfg.train.seed,
                    move |_id| {
                        let dir = dir.clone();
                        let variant = variant.clone();
                        let p = init_params.clone();
                        Box::new(move || {
                            let mut b = XlaBackend::open(&dir, &variant, &p)?;
                            b.chunked_replay = chunked_replay;
                            Ok(b)
                        })
                    },
                )?;
                Coordinator::new(pool, cfg.clone(), spec.max_seq)
            }
            TransportKind::Tcp => {
                let pool = RouterPool::connect(&cfg.router, cfg.train.seed)
                    .context("connecting engine-host fleet")?;
                ensure!(
                    pool.slots_per_engine == spec.slots,
                    "engine-hosts run {} slots/engine but the model artifact has {}",
                    pool.slots_per_engine,
                    spec.slots
                );
                eprintln!(
                    "router: tcp transport up — {} engines x {} slots across {} host(s)",
                    pool.engines(),
                    pool.slots_per_engine,
                    cfg.router.host_list().len()
                );
                let mut coord = Coordinator::new(pool, cfg.clone(), spec.max_seq);
                // Remote engines booted with their own init params; push the
                // trainer's actual weights before anything is in flight (the
                // local path skips this — its factories embed the params —
                // and a pre-dispatch broadcast cannot shift any golden).
                coord.sync_weights(trainer.step() as u64, params.clone());
                coord
            }
        };
        coord.policy_version = trainer.step() as u64;
        let dataset = Dataset::train(cfg.train.seed);
        Ok(RlSession {
            coord,
            trainer,
            dataset,
            timer: StageTimer::new(),
            log: MetricsLog::disabled(),
            verbose: false,
        })
    }

    /// Supervised warmup on easy tasks (produces the "basemodel").
    pub fn sft_warmup(&mut self, steps: usize, micro_batches: usize) -> Result<f64> {
        let mut ds = Dataset::sft(self.trainer.cfg.train.seed);
        let lr = (self.trainer.cfg.train.lr * 3.0) as f32; // warmup can run hotter
        let mut last_loss = f64::NAN;
        for s in 0..steps {
            let mut sft =
                SftTrainer::new(&mut self.trainer.rt, &mut self.trainer.state, lr);
            let m = sft.step(&mut ds, micro_batches)?;
            last_loss = m.loss;
            if self.verbose && (s % 10 == 0 || s + 1 == steps) {
                eprintln!("[sft {s:>4}] loss {:.4}  tokens {}", m.loss, m.n_tokens);
            }
        }
        // Sync the warmed-up weights to the engines. The policy version
        // must track the optimizer step counter (SFT shares it) so the
        // trainer's off-policy accounting stays consistent.
        let params = self.trainer.params()?;
        let version = self.trainer.step() as u64;
        self.coord.sync_weights(version, params);
        Ok(last_loss)
    }

    /// One full RL step, on the configured execution axis
    /// (`rollout.execution`, with the legacy `rollout.pipeline` bool
    /// mapping to pipelined). Serial: rollout stage → GRPO update → weight
    /// sync. Pipelined: train on the already-rolled batch while the next
    /// stage generates. Async: harvest from the continuous trajectory
    /// stream and sync under the bounded-staleness protocol.
    pub fn rl_step(&mut self) -> Result<(StepMetrics, RolloutStats)> {
        match self.trainer.cfg.rollout.exec_mode() {
            ExecMode::Async => self.rl_step_async(),
            ExecMode::Pipelined => self.rl_step_pipelined(),
            ExecMode::Serial => self.rl_step_serial(),
        }
    }

    /// Harvest this step's batch: the in-flight stage begun last step
    /// (pipelined), or a fresh serial stage.
    fn harvest_batch(&mut self) -> Result<RolloutOutput> {
        if self.coord.stage_active() {
            self.coord.run_stage_to_completion(&mut self.dataset)
        } else {
            self.coord.rollout_stage(&mut self.dataset)
        }
    }

    fn rl_step_serial(&mut self) -> Result<(StepMetrics, RolloutStats)> {
        let t_all = std::time::Instant::now();
        let t0 = std::time::Instant::now();
        let out = self.harvest_batch()?;
        self.timer.add("rollout", t0.elapsed().as_secs_f64());

        let metrics = self.trainer.train_step(&out.groups, &mut self.timer)?;

        let t0 = std::time::Instant::now();
        let params = self.trainer.params()?;
        let version = self.trainer.step() as u64;
        self.coord.sync_weights(version, params);
        self.timer.add("sync", t0.elapsed().as_secs_f64());

        self.log.log_step(&metrics, &out.stats, t_all.elapsed().as_secs_f64())?;
        Ok((metrics, out.stats))
    }

    /// Stage-pipelined step: the engines never sit idle through the
    /// cal-logprob → grad → update → sync chain. Stage t+1 runs under
    /// policy v_t until the update lands, then under v_{t+1} — its mixed-
    /// version trajectories are exactly what cross-stage IS corrects.
    fn rl_step_pipelined(&mut self) -> Result<(StepMetrics, RolloutStats)> {
        let t_all = std::time::Instant::now();

        // 1. This step's batch: the stage left in flight by the previous
        //    step, pumped through that step's update (first step: rolled
        //    out serially). Only this non-overlapped remainder counts as
        //    rollout wall for the step.
        let t0 = std::time::Instant::now();
        let out = self.harvest_batch()?;
        self.timer.add("rollout", t0.elapsed().as_secs_f64());

        // 2. Begin stage t+1 under the current policy BEFORE training, so
        //    the engines keep generating through the whole update.
        self.coord.begin_stage(&mut self.dataset)?;

        // 3. Train on stage t, pumping the in-flight stage between device
        //    microbatch calls (refill + early termination service; the
        //    engine threads decode regardless).
        let t_train = std::time::Instant::now();
        let mut metrics = {
            let coord = &mut self.coord;
            let dataset = &mut self.dataset;
            let mut pump = || -> Result<()> {
                if coord.stage_active() {
                    coord.pump(dataset, std::time::Instant::now())?;
                }
                Ok(())
            };
            self.trainer.train_step_hooked(&out.groups, &mut self.timer, &mut pump)?
        };

        // 4. Weight sync mid-flight: in-flight trajectories gain another
        //    version segment from here on.
        let t0 = std::time::Instant::now();
        let params = self.trainer.params()?;
        let version = self.trainer.step() as u64;
        self.coord.sync_weights(version, params);
        self.timer.add("sync", t0.elapsed().as_secs_f64());

        // Clamped by the coordinator to the stage's actual active time.
        metrics.t_overlap = self.coord.note_overlap(t_train.elapsed().as_secs_f64());

        // Stage t+1 stays in flight across the step boundary — the next
        // rl_step harvests it (an intervening evaluate aborts it into the
        // partial buffer instead). After the final step it is abandoned at
        // shutdown, costing only its dispatches, not a full stage
        // completion.
        self.log.log_step(&metrics, &out.stats, t_all.elapsed().as_secs_f64())?;
        Ok((metrics, out.stats))
    }

    /// Fully-async step (`rollout.execution = async`): the trajectory
    /// stream runs continuously across steps. This step (re)starts the
    /// stream if needed (first step, or after an eval aborted it), pumps
    /// until B groups are staged, harvests them WITHOUT quiescing the
    /// engines, trains while the stream keeps decoding, then performs the
    /// bounded-staleness weight sync: `prepare_sync` cuts in-flight
    /// assignments that would exceed `rollout.max_staleness` (plus the
    /// active policy's at-risk cuts), `sync_weights` broadcasts, and
    /// `resume_refill` re-enables dispatch under the new version — cut
    /// partials resume first and gain another IS segment.
    fn rl_step_async(&mut self) -> Result<(StepMetrics, RolloutStats)> {
        let t_all = std::time::Instant::now();
        let chunk = std::time::Duration::from_secs(3600);

        if !self.coord.async_active() {
            ensure!(
                !self.coord.stage_active(),
                "async step with a non-stream stage active"
            );
            self.coord.begin_async(&mut self.dataset)?;
        }

        // 1. Consume-when-ready: wait only until B groups are staged (the
        //    stream keeps every engine slot busy the whole time).
        let t0 = std::time::Instant::now();
        while !self.coord.pump_async(&mut self.dataset, std::time::Instant::now() + chunk)? {}
        let out = self.coord.take_async_batch()?;
        self.timer.add("rollout", t0.elapsed().as_secs_f64());

        // 2. Train while the stream decodes on, pumping between device
        //    microbatches (refill + event service).
        let t_train = std::time::Instant::now();
        let mut metrics = {
            let coord = &mut self.coord;
            let dataset = &mut self.dataset;
            let mut pump = || -> Result<()> {
                coord.pump_async(dataset, std::time::Instant::now())?;
                Ok(())
            };
            self.trainer.train_step_hooked(&out.groups, &mut self.timer, &mut pump)?
        };

        // 3. Bounded-staleness sync protocol.
        let t0 = std::time::Instant::now();
        let params = self.trainer.params()?;
        let version = self.trainer.step() as u64;
        self.coord.prepare_sync(version)?;
        self.coord.sync_weights(version, params);
        self.coord.resume_refill(&mut self.dataset)?;
        self.timer.add("sync", t0.elapsed().as_secs_f64());

        metrics.t_overlap = self.coord.note_overlap(t_train.elapsed().as_secs_f64());

        self.log.log_step(&metrics, &out.stats, t_all.elapsed().as_secs_f64())?;
        Ok((metrics, out.stats))
    }

    /// Run `steps` RL steps, returning the run summary.
    pub fn train(&mut self, steps: usize) -> Result<RunSummary> {
        let t0 = std::time::Instant::now();
        let mut summary = RunSummary { steps, ..Default::default() };
        let mut samples = 0usize;
        let mut util = Vec::new();
        let mut step_util = Vec::new();
        for s in 0..steps {
            let (m, rs) = self.rl_step()?;
            samples += rs.completed;
            util.push(rs.mean_utilization());
            summary.preemptions += rs.preemptions;
            summary.replayed_tokens += rs.replayed_tokens;
            summary.retained_hits += rs.retained_hits;
            summary.retained_misses += rs.retained_misses;
            summary.replay_tokens_saved += rs.replay_tokens_saved;
            summary.kv_blocks_peak = summary.kv_blocks_peak.max(rs.kv_blocks_peak);
            summary.kv_bytes_peak = summary.kv_bytes_peak.max(rs.kv_bytes_peak);
            if !rs.sampler_dispatch.is_empty() {
                summary.sampler_dispatch = rs.sampler_dispatch;
            }
            summary.prefix_tokens_shared += rs.prefix_tokens_shared;
            summary.cow_copies += rs.cow_copies;
            summary.overlap_secs += rs.overlap_secs;
            summary.lagged_trajectories += rs.lagged_trajectories();
            summary.resumed += rs.resumed;
            summary.prefill_chunks += rs.prefill_chunks;
            summary.t_prefill_stall_saved += rs.t_prefill_stall_saved;
            summary.engine_failures += rs.engine_failures;
            summary.redispatched_trajectories += rs.redispatched_trajectories;
            summary.retries += rs.retries;
            summary.retain_errors += rs.retain_errors;
            summary.requests_arrived += rs.requests_arrived;
            summary.requests_shed += rs.requests_shed;
            summary.queue_depth_peak = summary.queue_depth_peak.max(rs.queue_depth_peak);
            summary.staleness_terminations += rs.staleness_terminations;
            summary.active_terminations += rs.active_terminations;
            summary.staging_occupancy_peak =
                summary.staging_occupancy_peak.max(rs.staging_occupancy_peak);
            if rs.step_token_util > 0.0 {
                step_util.push(rs.step_token_util);
            }
            summary.reward_curve.push(m.reward_mean);
            summary.entropy_curve.push(m.entropy);
            summary.final_reward = m.reward_mean;
            if self.verbose {
                eprintln!(
                    "[rl {s:>4}] reward {:.3}  loss {:+.4}  ent {:.3}  ratio {:.3}  clip {:.3}  offpol {:.2}  rollout {:.2}s util {:.0}%",
                    m.reward_mean, m.loss, m.entropy, m.ratio_mean, m.clip_frac,
                    m.offpolicy_frac, rs.wall, rs.mean_utilization() * 100.0
                );
            }
            let every = self.trainer.cfg.train.checkpoint_every;
            if every > 0 && (s + 1) % every == 0 {
                let dir = self.trainer.cfg.train.checkpoint_dir.clone();
                let path = std::path::Path::new(&dir)
                    .join(format!("{}-step{}.ckpt", self.trainer.cfg.model, s + 1));
                self.trainer.save(&path)?;
            }
        }
        summary.wall = t0.elapsed().as_secs_f64();
        summary.throughput = samples as f64 / summary.wall.max(1e-9);
        summary.mean_utilization = crate::util::stats::mean(&util);
        summary.step_token_util =
            if step_util.is_empty() { 0.0 } else { crate::util::stats::mean(&step_util) };
        summary.rollout_secs = self.timer.total("rollout");
        summary.cal_logprob_secs = self.timer.total("cal_logprob");
        summary.train_secs = self.timer.total("grad") + self.timer.total("update");
        summary.sync_secs = self.timer.total("sync");
        Ok(summary)
    }

    /// Evaluate the current policy on the five suites. In pipelined runs
    /// a mid-flight stage is aborted first (partials drain into the buffer
    /// and resume under cross-stage IS when training continues), so eval
    /// always sees idle engines.
    pub fn evaluate(&mut self, seed: u64) -> Result<EvalReport> {
        if self.coord.stage_active() {
            self.coord.abort_stage()?;
        }
        let cfg = self.trainer.cfg.eval.clone();
        eval_all(&mut self.coord, &cfg, seed)
    }

    pub fn shutdown(self) {
        self.coord.shutdown();
    }
}
