//! Table 2 reproduction: concurrency-controlled generation ablation.
//! Sweeps the pool size N′ plus the naive-partial baseline; reports the
//! AIME24*/AIME25* scores and the per-step stage timings (step/rollout/
//! cal-logprob), matching the paper's columns.
//!
//! Concurrency is scaled to pool slots (paper: 512..2048 on 1536-capacity
//! engines → here fractions of engines×slots). A KV token budget below
//! capacity reproduces the memory-pressure recomputation at high N′.

use anyhow::Result;

use crate::bench::render_table;
use crate::config::RolloutMode;
use crate::exp::common::{arm_config, fmt_pct, warmed_session};

pub struct Table2Row {
    pub label: String,
    pub aime24: f64,
    pub aime25: f64,
    pub step_s: f64,
    pub rollout_s: f64,
    pub cal_logprob_s: f64,
    pub preemptions: u64,
    pub replayed: u64,
}

fn run_one(
    model: &str,
    mode: RolloutMode,
    concurrency: usize,
    sft_steps: usize,
    rl_steps: usize,
) -> Result<Table2Row> {
    let mut cfg = arm_config(model, mode, 7);
    cfg.rollout.concurrency = concurrency;
    // KV budget at 70% of per-engine capacity → high N' pays the paper's
    // memory-pressure preemption + re-prefill recomputation. Stated in
    // blocks (the token-denominated knob was removed): ceil(tokens /
    // engine.kv_block_size).
    let manifest = crate::runtime::Manifest::load(
        std::path::Path::new(&cfg.artifacts_dir).join(model).as_path(),
    )?;
    let budget_tokens = manifest.slots * manifest.max_seq * 7 / 10;
    cfg.engine.kv_budget_blocks = budget_tokens.div_ceil(cfg.engine.kv_block_size.max(1));
    let mut sess = warmed_session(cfg, sft_steps, false)?;
    let summary = sess.train(rl_steps)?;
    let report = sess.evaluate(2)?;
    let steps = rl_steps.max(1) as f64;
    let row = Table2Row {
        label: format!(
            "{} ({})",
            if mode == RolloutMode::NaivePartial { "Naive Partial Rollout" } else { "CoPRIS" },
            concurrency
        ),
        aime24: report.suites[0].pass_at_1,
        aime25: report.suites[1].pass_at_1,
        step_s: summary.wall / steps,
        rollout_s: summary.rollout_secs / steps,
        cal_logprob_s: summary.cal_logprob_secs / steps,
        preemptions: summary.preemptions,
        replayed: summary.replayed_tokens,
    };
    sess.shutdown();
    Ok(row)
}

/// Sweep: CoPRIS at fractions of the pool + naive partial at 1.5× batch
/// (paper: naive 1536 ≈ CoPRIS 1024's off-policy level).
pub fn run(model: &str, sft_steps: usize, rl_steps: usize) -> Result<Vec<Table2Row>> {
    // Pool is engines×slots = 16 by default; sweep like the paper's
    // {512, 1024, 1536, 2048} around the nominal 1024 ≙ 16.
    let sweeps = [8usize, 16, 24, 32];
    let naive_c = 24; // matches CoPRIS-16's off-policy level, like the paper
    let mut rows = Vec::new();
    eprintln!("[table2] naive partial ({naive_c})");
    rows.push(run_one(model, RolloutMode::NaivePartial, naive_c, sft_steps, rl_steps)?);
    for c in sweeps {
        eprintln!("[table2] copris N'={c}");
        rows.push(run_one(model, RolloutMode::Copris, c, sft_steps, rl_steps)?);
    }
    Ok(rows)
}

pub fn render(rows: &[Table2Row]) -> String {
    let mut out = String::from(
        "== Table 2: concurrency level vs performance and efficiency ==\n\
         (scores pass@1 %; times are per-step seconds on this substrate)\n\n",
    );
    let headers = [
        "Concurrency", "AIME24*", "AIME25*", "Step/s", "Rollout/s", "CalLogprob/s",
        "Preempt", "Replayed",
    ];
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.label.clone(),
                fmt_pct(r.aime24),
                fmt_pct(r.aime25),
                format!("{:.2}", r.step_s),
                format!("{:.2}", r.rollout_s),
                format!("{:.3}", r.cal_logprob_s),
                r.preemptions.to_string(),
                r.replayed.to_string(),
            ]
        })
        .collect();
    out.push_str(&render_table(&headers, &table));
    out.push_str(
        "\npaper shape: moderate N' fastest; too low starves slots, too high pays\n\
         preemption/replay overhead and off-policy drift; CoPRIS at matched\n\
         off-policy level beats naive partial rollout.\n",
    );
    out
}
