//! Parse `artifacts/<variant>/manifest.json` written by python/compile/aot.py
//! — the single source of truth for every shape the runtime needs.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::{self, Json};

/// Mirror of `python/compile/spec.py::ModelSpec` + derived sizes.
#[derive(Clone, Debug, PartialEq)]
pub struct Manifest {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub slots: usize,
    pub p_max: usize,
    pub b_micro: usize,
    pub d_head: usize,
    pub t_train: usize,
    pub n_params: usize,
    pub kv_elems: usize,
    pub state_elems: usize,
    pub engine_state_elems: usize,
    pub grad_elems: usize,
    pub n_metrics: usize,
    pub artifacts: BTreeMap<String, String>,
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let v = json::parse(text)?;
        let get_usize = |k: &str| -> Result<usize> {
            v.get(k)
                .and_then(Json::as_usize)
                .with_context(|| format!("manifest missing numeric field {k:?}"))
        };
        let mut artifacts = BTreeMap::new();
        if let Some(Json::Obj(m)) = v.get("artifacts") {
            for (k, val) in m {
                if let Some(s) = val.as_str() {
                    artifacts.insert(k.clone(), s.to_string());
                }
            }
        }
        Ok(Manifest {
            name: v
                .get("name")
                .and_then(Json::as_str)
                .context("manifest missing name")?
                .to_string(),
            vocab: get_usize("vocab")?,
            d_model: get_usize("d_model")?,
            n_layers: get_usize("n_layers")?,
            n_heads: get_usize("n_heads")?,
            d_ff: get_usize("d_ff")?,
            max_seq: get_usize("max_seq")?,
            slots: get_usize("slots")?,
            p_max: get_usize("p_max")?,
            b_micro: get_usize("b_micro")?,
            d_head: get_usize("d_head")?,
            t_train: get_usize("t_train")?,
            n_params: get_usize("n_params")?,
            kv_elems: get_usize("kv_elems")?,
            state_elems: get_usize("state_elems")?,
            engine_state_elems: get_usize("engine_state_elems")?,
            grad_elems: get_usize("grad_elems")?,
            n_metrics: get_usize("n_metrics")?,
            artifacts,
            dir: dir.to_path_buf(),
        })
    }

    /// Path of one artifact's HLO text.
    pub fn artifact_path(&self, name: &str) -> Result<PathBuf> {
        let file = self
            .artifacts
            .get(name)
            .with_context(|| format!("manifest {} has no artifact {name:?}", self.name))?;
        Ok(self.dir.join(file))
    }

    /// Size of the logits header at the front of the engine state.
    pub fn header_elems(&self) -> usize {
        self.slots * self.vocab
    }

    /// Max response tokens for a prompt of `prompt_len`.
    pub fn max_new_tokens(&self, prompt_len: usize) -> usize {
        self.max_seq.saturating_sub(prompt_len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"{
        "name": "tiny", "d_model": 64, "n_layers": 2, "n_heads": 2,
        "d_ff": 256, "max_seq": 96, "slots": 4, "p_max": 24, "b_micro": 4,
        "vocab": 48, "n_params": 108480, "kv_elems": 98304, "d_head": 32,
        "t_train": 96, "kv_shape": [2,2,4,2,96,32],
        "state_elems": 325440, "engine_state_elems": 98496,
        "grad_elems": 108488, "n_metrics": 8,
        "artifacts": {"init": "init.hlo.txt", "decode": "decode.hlo.txt"}
    }"#;

    #[test]
    fn parses_all_fields() {
        let m = Manifest::parse(DOC, Path::new("/tmp/x")).unwrap();
        assert_eq!(m.name, "tiny");
        assert_eq!(m.n_params, 108480);
        assert_eq!(m.state_elems, 3 * m.n_params);
        assert_eq!(m.engine_state_elems, m.slots * m.vocab + m.kv_elems);
        assert_eq!(m.header_elems(), 4 * 48);
        assert_eq!(m.max_new_tokens(20), 76);
        assert_eq!(
            m.artifact_path("init").unwrap(),
            PathBuf::from("/tmp/x/init.hlo.txt")
        );
        assert!(m.artifact_path("nope").is_err());
    }

    #[test]
    fn missing_field_is_error() {
        assert!(Manifest::parse(r#"{"name": "x"}"#, Path::new(".")).is_err());
    }
}
