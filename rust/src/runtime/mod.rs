//! PJRT runtime: load AOT HLO-text artifacts, compile, execute.
//!
//! Interchange format is HLO **text** (`HloModuleProto::from_text_file`):
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids (see aot.py / DESIGN.md).
//!
//! Every artifact has exactly ONE flat-array output, so `Executable::run1`
//! hands back a plain `PjRtBuffer` that can be threaded into the next call
//! via `execute_b` without host round-trips. Host reads happen only on
//! buffer *prefixes* (logits headers, metrics heads) via offset copies.
//!
//! Thread model: `PjRtClient` is `Rc`-based (not `Send`), so each engine /
//! trainer thread owns its own `Device`. Weights move between threads as
//! host `Vec<f32>` — the explicit "weight sync" stage real RL systems have.

pub mod manifest;

use std::path::Path;

use anyhow::{bail, Context, Result};

pub use manifest::Manifest;

/// One PJRT CPU device, thread-confined.
pub struct Device {
    client: xla::PjRtClient,
}

impl Device {
    pub fn cpu() -> Result<Device> {
        let client = xla::PjRtClient::cpu().map_err(wrap)?;
        Ok(Device { client })
    }

    pub fn load_hlo(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(wrap)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(wrap)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe, name: path.display().to_string() })
    }

    pub fn upload_f32(&self, data: &[f32]) -> Result<xla::PjRtBuffer> {
        self.client.buffer_from_host_buffer(data, &[data.len()], None).map_err(wrap)
    }

    pub fn upload_f32_2d(&self, data: &[f32], rows: usize, cols: usize) -> Result<xla::PjRtBuffer> {
        debug_assert_eq!(data.len(), rows * cols);
        self.client.buffer_from_host_buffer(data, &[rows, cols], None).map_err(wrap)
    }

    pub fn upload_i32(&self, data: &[i32]) -> Result<xla::PjRtBuffer> {
        self.client.buffer_from_host_buffer(data, &[data.len()], None).map_err(wrap)
    }

    pub fn upload_i32_2d(&self, data: &[i32], rows: usize, cols: usize) -> Result<xla::PjRtBuffer> {
        debug_assert_eq!(data.len(), rows * cols);
        self.client.buffer_from_host_buffer(data, &[rows, cols], None).map_err(wrap)
    }

    pub fn zeros_f32(&self, n: usize) -> Result<xla::PjRtBuffer> {
        self.upload_f32(&vec![0f32; n])
    }

    /// Read an entire f32 buffer to the host.
    ///
    /// PJRT-CPU 0.5.1 does not implement `CopyRawToHost`, so there are no
    /// partial reads — hot paths keep big buffers device-side and extract
    /// small windows with the `read_*` slice artifacts before reading.
    pub fn read_all_f32(&self, buf: &xla::PjRtBuffer, len: usize) -> Result<Vec<f32>> {
        let lit = buf.to_literal_sync().map_err(wrap)?;
        let v: Vec<f32> = lit.to_vec().map_err(wrap)?;
        if v.len() != len {
            bail!("read_all_f32: expected {len} elems, got {}", v.len());
        }
        Ok(v)
    }

    /// `read_all_f32` into a caller-owned buffer reused across calls.
    /// The xla 0.5.1 literal API only exposes an owning `to_vec`, so the
    /// transfer itself still materializes once; this variant removes the
    /// *second* buffer that per-step callers (decode logits) would
    /// otherwise reallocate every iteration.
    pub fn read_all_f32_into(
        &self,
        buf: &xla::PjRtBuffer,
        len: usize,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let lit = buf.to_literal_sync().map_err(wrap)?;
        let v: Vec<f32> = lit.to_vec().map_err(wrap)?;
        if v.len() != len {
            bail!("read_all_f32_into: expected {len} elems, got {}", v.len());
        }
        out.clear();
        out.extend_from_slice(&v);
        Ok(())
    }
}

/// A compiled artifact with a single array output.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    /// Execute on device buffers, returning the single output buffer.
    pub fn run1(&self, args: &[&xla::PjRtBuffer]) -> Result<xla::PjRtBuffer> {
        let mut outs = self.exe.execute_b(args).map_err(wrap)?;
        let mut replica = outs
            .drain(..)
            .next()
            .with_context(|| format!("{}: no replica output", self.name))?;
        if replica.len() != 1 {
            bail!("{}: expected 1 output buffer, got {}", self.name, replica.len());
        }
        Ok(replica.remove(0))
    }
}

/// Adapt xla::Error (not anyhow-compatible) via Display.
fn wrap(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("xla: {e}")
}
