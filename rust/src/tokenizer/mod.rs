//! Char-level tokenizer over the 48-symbol math vocabulary.
//!
//! The vocab size must match `python/compile/spec.py::VOCAB`; token ids are
//! stable because both sides derive them from the same ordered alphabet.

/// Special tokens.
pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
/// Separates chain-of-thought from the final answer in responses.
pub const ANS: i32 = 3;

/// Ordered alphabet for ids 4.. (index 0..=3 are specials).
const ALPHABET: &str = "0123456789+-*/%=()<>, rcsmx?";

/// Vocabulary size — must equal python/compile/spec.py::VOCAB.
pub const VOCAB: usize = 48;

#[derive(Clone, Debug)]
pub struct Tokenizer {
    to_id: [i32; 128],
    to_char: Vec<char>,
}

impl Default for Tokenizer {
    fn default() -> Self {
        Self::new()
    }
}

impl Tokenizer {
    pub fn new() -> Self {
        let mut to_id = [-1i32; 128];
        let mut to_char = vec!['\0'; VOCAB];
        to_char[ANS as usize] = '#'; // printable marker for decode()
        for (i, c) in ALPHABET.chars().enumerate() {
            let id = 4 + i as i32;
            assert!((id as usize) < VOCAB, "alphabet exceeds vocab");
            to_id[c as usize] = id;
            to_char[id as usize] = c;
        }
        Tokenizer { to_id, to_char }
    }

    pub fn vocab_size(&self) -> usize {
        VOCAB
    }

    /// Encode text (chars not in the alphabet are skipped).
    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.chars()
            .filter_map(|c| {
                if c == '#' {
                    Some(ANS)
                } else {
                    let u = c as usize;
                    if u < 128 && self.to_id[u] >= 0 { Some(self.to_id[u]) } else { None }
                }
            })
            .collect()
    }

    /// Encode a prompt with BOS prefix.
    pub fn encode_prompt(&self, text: &str) -> Vec<i32> {
        let mut v = vec![BOS];
        v.extend(self.encode(text));
        v
    }

    /// Decode ids to text; stops at EOS, skips PAD/BOS.
    pub fn decode(&self, ids: &[i32]) -> String {
        let mut out = String::new();
        for &id in ids {
            match id {
                EOS => break,
                PAD | BOS => continue,
                id if (id as usize) < VOCAB => {
                    let c = self.to_char[id as usize];
                    if c != '\0' {
                        out.push(c);
                    }
                }
                _ => {}
            }
        }
        out
    }

    /// The final answer segment of a response: text after the last '#'
    /// (ANS marker), trimmed. If no marker, the whole trimmed response.
    pub fn extract_answer(&self, response_ids: &[i32]) -> String {
        let text = self.decode(response_ids);
        match text.rfind('#') {
            Some(i) => text[i + 1..].trim().to_string(),
            None => text.trim().to_string(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocab_size_matches_python_spec() {
        assert_eq!(VOCAB, 48);
        // Alphabet + specials must fit.
        assert!(ALPHABET.chars().count() + 4 <= VOCAB);
    }

    #[test]
    fn alphabet_has_no_duplicates() {
        let mut seen = std::collections::HashSet::new();
        for c in ALPHABET.chars() {
            assert!(seen.insert(c), "duplicate char {c:?}");
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let tk = Tokenizer::new();
        let s = "12+34=46";
        assert_eq!(tk.decode(&tk.encode(s)), s);
    }

    #[test]
    fn prompt_has_bos_and_decode_skips_it() {
        let tk = Tokenizer::new();
        let ids = tk.encode_prompt("9*9=");
        assert_eq!(ids[0], BOS);
        assert_eq!(tk.decode(&ids), "9*9=");
    }

    #[test]
    fn decode_stops_at_eos() {
        let tk = Tokenizer::new();
        let mut ids = tk.encode("123");
        ids.push(EOS);
        ids.extend(tk.encode("junk"));
        assert_eq!(tk.decode(&ids), "123");
    }

    #[test]
    fn extract_answer_after_marker() {
        let tk = Tokenizer::new();
        let mut ids = tk.encode("10 9 8");
        ids.push(ANS);
        ids.extend(tk.encode(" 8 "));
        ids.push(EOS);
        assert_eq!(tk.extract_answer(&ids), "8");
    }

    #[test]
    fn extract_answer_without_marker_is_whole() {
        let tk = Tokenizer::new();
        let mut ids = tk.encode(" 42 ");
        ids.push(EOS);
        assert_eq!(tk.extract_answer(&ids), "42");
    }

    #[test]
    fn unknown_chars_are_skipped() {
        let tk = Tokenizer::new();
        assert_eq!(tk.decode(&tk.encode("1A2B3")), "123");
    }

    #[test]
    fn all_ids_below_vocab() {
        let tk = Tokenizer::new();
        for id in tk.encode_prompt("0123456789+-*/%=()<>, rcsmx?#") {
            assert!((0..VOCAB as i32).contains(&id));
        }
    }
}
