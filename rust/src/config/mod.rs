//! Typed configuration system: schema, a minimal TOML-subset parser, and
//! presets mirroring the paper's Table 3 (scaled to this substrate).

pub mod presets;
pub mod schema;
pub mod toml;

pub use presets::{paper_preset, preset, scaled_preset};
pub use schema::{
    Config, EngineConfig, EvalConfig, ExecMode, RolloutConfig, RolloutMode, RouterConfig,
    TrainConfig, TransportKind, WorkloadConfig, WorkloadKind,
};
