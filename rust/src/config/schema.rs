//! Configuration schema. Field names follow the paper's Table 3; values can
//! be loaded from a TOML file (`Config::from_toml_str`) and overridden from
//! the CLI (`Config::set`).

use anyhow::{bail, Context, Result};

use super::toml::{self, TomlValue};

/// Which rollout driver to use (§5 baselines + CoPRIS).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RolloutMode {
    /// Fully synchronous (veRL): submit B·G requests, wait for all.
    Sync,
    /// Naive partial rollout (Kimi-K1.5): fixed initial concurrency, no
    /// refill, early termination + buffering.
    NaivePartial,
    /// Concurrency-controlled partial rollout (the paper).
    Copris,
}

impl RolloutMode {
    /// Parse a CLI/TOML mode name (`sync`/`verl`, `naive`, `copris`).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "sync" | "verl" => RolloutMode::Sync,
            "naive" | "naive_partial" => RolloutMode::NaivePartial,
            "copris" => RolloutMode::Copris,
            _ => bail!("unknown rollout mode {s:?} (sync|naive|copris)"),
        })
    }
    /// Canonical mode name (round-trips through [`RolloutMode::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            RolloutMode::Sync => "sync",
            RolloutMode::NaivePartial => "naive_partial",
            RolloutMode::Copris => "copris",
        }
    }
}

/// How rollout and training interleave — the execution axis, orthogonal to
/// [`RolloutMode`] (which picks the scheduling policy WITHIN a stage).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ExecMode {
    /// Serial rollout → train → sync (the paper's baseline loop).
    #[default]
    Serial,
    /// Stage-pipelined: stage t+1's rollout overlaps the stage-t update,
    /// weights sync mid-flight (one step of lookahead).
    Pipelined,
    /// Fully async: one open-ended rollout stream; the trainer consumes a
    /// batch whenever B groups are complete and weight sync is a background
    /// broadcast bounded by `rollout.max_staleness`.
    Async,
}

impl ExecMode {
    /// Parse a CLI/TOML execution-mode name (`serial` | `pipelined` |
    /// `async`).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "serial" => ExecMode::Serial,
            "pipelined" | "pipeline" => ExecMode::Pipelined,
            "async" => ExecMode::Async,
            _ => bail!("unknown execution mode {s:?} (serial|pipelined|async)"),
        })
    }

    /// Canonical name (round-trips through [`ExecMode::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            ExecMode::Serial => "serial",
            ExecMode::Pipelined => "pipelined",
            ExecMode::Async => "async",
        }
    }
}

/// Rollout-stage configuration (paper Table 3, "Rollout Configuration").
#[derive(Clone, Debug)]
pub struct RolloutConfig {
    /// Which rollout driver runs the stage.
    pub mode: RolloutMode,
    /// Training batch size B: prompts per step (paper: 64).
    pub batch_prompts: usize,
    /// Rollouts per prompt G (paper: 8).
    pub group_size: usize,
    /// Concurrency pool size N' (paper: 1024). For `Sync` this is ignored;
    /// for `NaivePartial` it is the *initial* concurrency.
    pub concurrency: usize,
    /// Sampling temperature (paper: 1.0).
    pub temperature: f64,
    /// Sampling top-p (paper: 1.0).
    pub top_p: f64,
    /// Sampling top-k; -1 disables (paper: -1).
    pub top_k: i64,
    /// Cross-stage importance sampling correction on/off (§5.4.2 ablation).
    pub importance_sampling: bool,
    /// Cap on buffered-partial reuse: trajectories older than this many
    /// stages are discarded (staleness guard; paper keeps all).
    pub max_stage_lag: usize,
    /// Stage-pipelined execution: begin stage t+1's rollout before the
    /// stage-t update and pump it between trainer microbatches, syncing
    /// weights mid-flight (in-flight trajectories gain another version
    /// segment — handled by the cross-stage IS machinery). Off = serial
    /// rollout → train → sync, matching the paper. Legacy alias for
    /// `execution = "pipelined"`; [`RolloutConfig::exec_mode`] resolves the
    /// two (an explicit non-serial `execution` wins).
    pub pipeline: bool,
    /// Execution axis (`serial` | `pipelined` | `async`); also settable as
    /// `rollout.mode = pipelined|async` sugar (which picks CoPRIS
    /// scheduling plus this execution mode). See [`ExecMode`].
    pub execution: ExecMode,
    /// Async execution only: how many weight syncs one engine assignment
    /// may span before it is early-terminated into the partial buffer (its
    /// resume re-dispatches under the fresh policy; cross-stage IS corrects
    /// the spliced segments). 0 = every sync cuts all in-flight work, which
    /// is exactly stage-pipelined execution (pinned bit-identical by
    /// `tests/rollout_golden.rs`).
    pub max_staleness: usize,
    /// Async execution only: APRIL-style active partial rollout. At each
    /// sync, trajectories on their LAST allowed staleness window whose
    /// predicted remaining length (per-group EMA of observed decode
    /// lengths) exceeds the observed per-window decode progress are cut
    /// proactively, longest-predicted-remaining first, instead of being
    /// left to trip the mandatory bound a whole window later.
    pub active_termination: bool,
    /// KV retention + affinity resume routing (on by default): partials
    /// flushed at early termination / `abort_stage` keep their KV resident
    /// in the engine, and their resumption is routed back to that engine to
    /// skip re-prefill entirely. Bit-identical to the replay path (pinned
    /// by `rust/tests/retained_golden.rs`); fallback to replay on slot
    /// eviction, sync invalidation, or load imbalance is automatic.
    pub retain_kv: bool,
    /// Keep retained KV valid across weight syncs (off by default). Off: a
    /// sync invalidates every retained slot, so resumes re-prefill under
    /// the new policy exactly like the replay-only baseline. On: resumes
    /// continue from KV computed under the OLD policy — extra off-policy
    /// staleness, traded for zero recompute; the stale prefix's behaviour
    /// log-probs are already per-segment, so cross-stage IS still applies.
    pub retain_kv_across_sync: bool,
    /// Affinity routing gives up when the home engine's in-flight load
    /// exceeds the least-loaded engine's by more than this (the resume then
    /// dispatches least-loaded and the remote retained slot is released).
    pub affinity_max_imbalance: usize,
}

impl Default for RolloutConfig {
    fn default() -> Self {
        RolloutConfig {
            mode: RolloutMode::Copris,
            batch_prompts: 8,
            group_size: 4,
            concurrency: 16,
            temperature: 1.0,
            top_p: 1.0,
            top_k: -1,
            importance_sampling: true,
            max_stage_lag: usize::MAX,
            pipeline: false,
            execution: ExecMode::Serial,
            max_staleness: 1,
            active_termination: true,
            retain_kv: true,
            retain_kv_across_sync: false,
            affinity_max_imbalance: 4,
        }
    }
}

impl RolloutConfig {
    /// The effective execution mode: an explicit non-serial `execution`
    /// wins; otherwise the legacy `pipeline` bool maps to
    /// [`ExecMode::Pipelined`].
    pub fn exec_mode(&self) -> ExecMode {
        if self.execution != ExecMode::Serial {
            self.execution
        } else if self.pipeline {
            ExecMode::Pipelined
        } else {
            ExecMode::Serial
        }
    }
}

/// Inference-engine pool configuration (the vLLM stand-in).
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Number of engine threads ("GPUs").
    pub engines: usize,
    /// KV budget per engine in blocks of `kv_block_size` tokens
    /// (0 = unlimited). The token-denominated `kv_budget_tokens` knob was
    /// removed — `Config::set` and TOML reject it with a migration hint.
    /// Exceeding it sheds residency cheapest-first: shared-prefix registry
    /// entries, retained slots, then live preemption + re-prefill (the
    /// paper's recomputation overhead); fresh admission backpressures.
    pub kv_budget_blocks: usize,
    /// Tokens per KV block (vLLM-style paging granularity).
    pub kv_block_size: usize,
    /// Share a GRPO group's prompt-prefix KV blocks across its G samples
    /// (refcounted, copy-on-write; default on). No backend call changes:
    /// in deterministic configurations (greedy sampling, or a single
    /// engine with an unconstrained budget) token/logprob streams are
    /// bit-identical either way — pinned by
    /// `rust/tests/retained_golden.rs`. The knob also routes a group's
    /// samples to its home engine and changes budget-gated admission
    /// timing, so stochastic multi-engine runs may sample in a different
    /// order (same per-trajectory distribution, like any scheduling
    /// knob).
    pub prefix_sharing: bool,
    /// Element type KV blocks are stored at (`f32` | `f16` | `int8`,
    /// default `f32`). The block budget stays denominated in f32-sized
    /// blocks, so a narrower dtype multiplies the enforced block count
    /// (f16 2×, int8 4×) instead of shrinking memory: the same bytes hold
    /// more resident sequences. f32 streams are the goldens; f16 is
    /// bit-identical on this substrate's logit alphabet and int8 is
    /// deterministic with every argmax preserved (pinned engine-side).
    pub kv_dtype: crate::engine::KvDtype,
    /// Max new tokens per response (paper: 15360; scaled by model max_seq).
    pub max_new_tokens: usize,
    /// Resume buffered partials via the chunked `replay` artifact instead
    /// of per-token decode (measured slower here — see EXPERIMENTS §Perf).
    pub chunked_replay: bool,
    /// Continuous batching with chunked prefill: per-engine-step token
    /// budget. Each step packs one decode token per running sequence plus
    /// chunked prompt-prefill / resume-replay slices of admitted work, up
    /// to this many tokens — long prompts interleave with decoding
    /// instead of stalling co-resident sequences at admission. 0 (the
    /// default) keeps legacy slot admission: whole-prompt prefill at
    /// admission. Sensible values are ≥ slots-per-engine plus a chunk
    /// (e.g. 32–64 on this substrate); greedy token streams are
    /// bit-identical either way (pinned by
    /// `rust/tests/continuous_batching.rs`).
    pub step_token_budget: usize,
    /// Transient backend errors retried in place per failing engine step
    /// before the engine declares itself failed
    /// (`EngineEvent::EngineFailed`). Fatal errors and panics skip the
    /// retry budget entirely.
    pub max_retries: usize,
    /// Base backoff between transient retries in milliseconds, doubling
    /// per attempt. 0 = retry immediately.
    pub retry_backoff_ms: u64,
    /// Coordinator stall watchdog: with work outstanding and no engine
    /// event for this long, the engines still owing events are declared
    /// failed and their trajectories re-dispatched (a hung pool becomes a
    /// recoverable failure instead of a deadlock). Default matches the
    /// pre-supervision 120 s event timeout.
    pub stall_timeout_ms: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            engines: 2,
            kv_budget_blocks: 0,
            kv_block_size: crate::engine::DEFAULT_BLOCK_SIZE,
            prefix_sharing: true,
            kv_dtype: crate::engine::KvDtype::F32,
            max_new_tokens: 0,
            chunked_replay: false,
            step_token_budget: 0,
            max_retries: 3,
            retry_backoff_ms: 10,
            stall_timeout_ms: 120_000,
        }
    }
}

impl EngineConfig {
    /// The blocks-denominated budget (`kv_budget_blocks`; 0 = unlimited).
    /// The legacy token-denominated fallback is gone along with the
    /// `kv_budget_tokens` knob.
    pub fn budget_blocks(&self) -> usize {
        self.kv_budget_blocks
    }

    /// The paged-KV configuration the engine pool runs with
    /// (`EnginePool::spawn_kv`).
    pub fn kv_cache_config(&self) -> crate::engine::KvCacheConfig {
        crate::engine::KvCacheConfig {
            block_size: self.kv_block_size.max(1),
            budget_blocks: self.budget_blocks(),
            prefix_sharing: self.prefix_sharing,
            dtype: self.kv_dtype,
        }
    }

    /// Full engine scheduling options (`EnginePool::spawn_opts`): paged-KV
    /// config plus the continuous-batching step-token budget.
    pub fn engine_opts(&self) -> crate::engine::EngineOpts {
        crate::engine::EngineOpts {
            kv: self.kv_cache_config(),
            step_token_budget: self.step_token_budget,
        }
    }

    /// Supervision policy for the engine run loop
    /// (`EnginePool::spawn_supervised`): the transient-retry budget and
    /// backoff base.
    pub fn supervisor_opts(&self) -> crate::engine::SupervisorOpts {
        crate::engine::SupervisorOpts {
            max_retries: self.max_retries,
            retry_backoff_ms: self.retry_backoff_ms,
        }
    }
}

/// Training configuration (paper Table 3, "Training Configuration").
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// RL steps to run.
    pub steps: usize,
    /// Learning rate (paper: 1e-6 at 1.5B+; scaled default for our sizes).
    pub lr: f64,
    /// Group-advantage epsilon (Eq. 5 denominator guard).
    pub adv_eps: f64,
    /// Master seed (trainer init, dataset, engine RNGs).
    pub seed: u64,
    /// Checkpoint every N steps (0 = never).
    pub checkpoint_every: usize,
    /// Directory checkpoints are written to.
    pub checkpoint_dir: String,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 50,
            lr: 3e-4,
            adv_eps: 1e-6,
            seed: 0,
            checkpoint_every: 0,
            checkpoint_dir: "checkpoints".into(),
        }
    }
}

/// Evaluation configuration (paper Table 3, eval rows).
#[derive(Clone, Debug)]
pub struct EvalConfig {
    /// Samples per eval prompt (paper: 32; scaled).
    pub samples_per_prompt: usize,
    /// Eval temperature (paper: 0.6).
    pub temperature: f64,
    /// Eval top-p (paper: 1.0).
    pub top_p: f64,
    /// Prompts per suite.
    pub prompts_per_suite: usize,
}

impl Default for EvalConfig {
    fn default() -> Self {
        EvalConfig { samples_per_prompt: 4, temperature: 0.6, top_p: 1.0, prompts_per_suite: 16 }
    }
}

/// Which open-loop arrival process the SLO harness generates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkloadKind {
    /// Memoryless Poisson arrivals at `rate_rps`.
    Poisson,
    /// Interrupted-Poisson on/off bursts preserving the long-run rate.
    Bursty,
}

impl WorkloadKind {
    /// Parse a CLI/TOML workload name (`poisson` | `bursty`).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "poisson" => WorkloadKind::Poisson,
            "bursty" => WorkloadKind::Bursty,
            _ => bail!("unknown workload {s:?} (poisson|bursty)"),
        })
    }

    /// Canonical name (round-trips through [`WorkloadKind::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            WorkloadKind::Poisson => "poisson",
            WorkloadKind::Bursty => "bursty",
        }
    }
}

/// Open-loop workload / SLO-harness configuration (`copris slo`, the
/// `slo_harness` bench, and the chaos open-loop arm). All rates and
/// durations are VIRTUAL — the harness runs on the `loadgen` virtual
/// clock (1 tick = 1 µs of virtual time), so these knobs shape the
/// schedule, not the wall-clock runtime.
#[derive(Clone, Debug)]
pub struct WorkloadConfig {
    /// Arrival process (`poisson` | `bursty`).
    pub kind: WorkloadKind,
    /// Mean arrival rate in requests per virtual second.
    pub rate_rps: f64,
    /// Total arrivals per run.
    pub requests: usize,
    /// Bursty ON-phase length in virtual milliseconds.
    pub burst_on_ms: u64,
    /// Bursty OFF-phase length in virtual milliseconds.
    pub burst_off_ms: u64,
    /// Fraction of requests drawn from the interactive tenant class (the
    /// rest are bulk-rollout traffic).
    pub interactive_share: f64,
    /// Admission-queue capacity; arrivals beyond it are shed.
    pub queue_cap: usize,
    /// Virtual microseconds one engine step costs on the virtual clock.
    pub quantum_us: u64,
    /// Decode slots per simulated engine (the lockstep sim sizes its own
    /// MockBackends; the threaded paths use the artifact's slot count).
    pub slots_per_engine: usize,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            kind: WorkloadKind::Poisson,
            rate_rps: 400.0,
            requests: 300,
            burst_on_ms: 20,
            burst_off_ms: 80,
            interactive_share: 0.5,
            queue_cap: 64,
            quantum_us: 1_000,
            slots_per_engine: 4,
        }
    }
}

/// Which transport carries engine commands and events.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process engine threads over mpsc channels (the default; zero
    /// overhead, and the transport every golden test pins).
    #[default]
    Local,
    /// Framed TCP to `copris engine-host` processes (see `crate::net`).
    Tcp,
}

impl TransportKind {
    /// Parse a CLI/TOML transport name (`local` | `tcp`).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "local" => TransportKind::Local,
            "tcp" => TransportKind::Tcp,
            _ => bail!("unknown transport {s:?} (local|tcp)"),
        })
    }

    /// Canonical name (round-trips through [`TransportKind::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::Local => "local",
            TransportKind::Tcp => "tcp",
        }
    }
}

/// Router / transport-tier configuration (`[router]`). Only consulted
/// when `transport = "tcp"`; the `local` default leaves every existing
/// path byte-for-byte unchanged.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Transport (`local` | `tcp`).
    pub transport: TransportKind,
    /// Comma-separated `host:port` list of engine-hosts, dialed in order
    /// (the TOML subset is scalar-only, hence a string not an array).
    /// Each host's engines get the next contiguous global-id range.
    pub hosts: String,
    /// Heartbeat ping period in milliseconds (0 disables heartbeats —
    /// link errors still fail the host).
    pub heartbeat_ms: u64,
    /// Consecutive missed heartbeats before a host is declared dead and
    /// its replicas fail over.
    pub heartbeat_misses: u32,
    /// Connect + handshake timeout per host, in milliseconds.
    pub connect_timeout_ms: u64,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            transport: TransportKind::Local,
            hosts: String::new(),
            heartbeat_ms: 2_000,
            heartbeat_misses: 3,
            connect_timeout_ms: 5_000,
        }
    }
}

impl RouterConfig {
    /// The `hosts` string split into trimmed, non-empty addresses.
    pub fn host_list(&self) -> Vec<String> {
        self.hosts
            .split(',')
            .map(|h| h.trim().to_string())
            .filter(|h| !h.is_empty())
            .collect()
    }
}

/// Top-level configuration.
#[derive(Clone, Debug, Default)]
pub struct Config {
    /// Artifact variant directory name under `artifacts/` (e.g. "small").
    pub model: String,
    /// Root directory holding the AOT artifact variants.
    pub artifacts_dir: String,
    /// Rollout-stage settings.
    pub rollout: RolloutConfig,
    /// Engine-pool settings.
    pub engine: EngineConfig,
    /// Training settings.
    pub train: TrainConfig,
    /// Evaluation settings.
    pub eval: EvalConfig,
    /// Open-loop workload / SLO-harness settings.
    pub workload: WorkloadConfig,
    /// Router / transport-tier settings.
    pub router: RouterConfig,
}

impl Config {
    /// Default config for an artifact variant.
    pub fn new(model: &str) -> Self {
        Config {
            model: model.to_string(),
            artifacts_dir: "artifacts".into(),
            ..Default::default()
        }
    }

    /// Apply one `section.key=value` override (CLI `--set`).
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        let mut parts = key.splitn(2, '.');
        let (section, field) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
        let v = value;
        let parse_usize = || v.parse::<usize>().with_context(|| format!("{key}={v}"));
        let parse_f64 = || v.parse::<f64>().with_context(|| format!("{key}={v}"));
        let parse_bool = || match v {
            "true" | "1" | "on" => Ok(true),
            "false" | "0" | "off" => Ok(false),
            _ => bail!("bad bool {key}={v}"),
        };
        match (section, field) {
            ("model", "") | ("", "model") => self.model = v.into(),
            ("artifacts_dir", "") => self.artifacts_dir = v.into(),
            ("rollout", "mode") => match v {
                // Sugar: the mode names of the execution axis select CoPRIS
                // scheduling under that execution mode in one knob
                // (`rollout.mode = pipelined|async` — the Table-3 row).
                "pipelined" | "async" => {
                    self.rollout.mode = RolloutMode::Copris;
                    self.rollout.execution = ExecMode::parse(v)?;
                }
                _ => self.rollout.mode = RolloutMode::parse(v)?,
            },
            ("rollout", "execution") => self.rollout.execution = ExecMode::parse(v)?,
            ("rollout", "max_staleness") => self.rollout.max_staleness = parse_usize()?,
            ("rollout", "active_termination") => {
                self.rollout.active_termination = parse_bool()?
            }
            ("rollout", "batch_prompts") => self.rollout.batch_prompts = parse_usize()?,
            ("rollout", "group_size") => self.rollout.group_size = parse_usize()?,
            ("rollout", "concurrency") => self.rollout.concurrency = parse_usize()?,
            ("rollout", "temperature") => self.rollout.temperature = parse_f64()?,
            ("rollout", "top_p") => self.rollout.top_p = parse_f64()?,
            ("rollout", "top_k") => self.rollout.top_k = v.parse()?,
            ("rollout", "importance_sampling") => {
                self.rollout.importance_sampling = parse_bool()?
            }
            ("rollout", "max_stage_lag") => self.rollout.max_stage_lag = parse_usize()?,
            ("rollout", "pipeline") => self.rollout.pipeline = parse_bool()?,
            ("rollout", "retain_kv") => self.rollout.retain_kv = parse_bool()?,
            ("rollout", "retain_kv_across_sync") => {
                self.rollout.retain_kv_across_sync = parse_bool()?
            }
            ("rollout", "affinity_max_imbalance") => {
                self.rollout.affinity_max_imbalance = parse_usize()?
            }
            ("engine", "engines") => self.engine.engines = parse_usize()?,
            ("engine", "kv_budget_tokens") => {
                // Removed knob (deprecated since the paged-KV subsystem).
                // Reject with a migration hint instead of silently
                // converting so stale configs surface loudly.
                let tokens = parse_usize()?;
                bail!(
                    "engine.kv_budget_tokens was removed — the KV budget is \
                     blocks-denominated; set engine.kv_budget_blocks = \
                     ceil(tokens / engine.kv_block_size) instead (here: \
                     {tokens} tokens / {} tokens-per-block = {} blocks)",
                    self.engine.kv_block_size.max(1),
                    tokens.div_ceil(self.engine.kv_block_size.max(1)),
                );
            }
            ("engine", "kv_budget_blocks") => self.engine.kv_budget_blocks = parse_usize()?,
            ("engine", "kv_block_size") => {
                self.engine.kv_block_size = parse_usize()?;
                if self.engine.kv_block_size == 0 {
                    bail!("engine.kv_block_size must be >= 1");
                }
            }
            ("engine", "prefix_sharing") => self.engine.prefix_sharing = parse_bool()?,
            ("engine", "kv_dtype") => {
                self.engine.kv_dtype = crate::engine::KvDtype::parse(v)
                    .ok_or_else(|| anyhow::anyhow!("bad {key}={v} (f32|f16|int8)"))?
            }
            ("engine", "max_new_tokens") => self.engine.max_new_tokens = parse_usize()?,
            ("engine", "chunked_replay") => self.engine.chunked_replay = parse_bool()?,
            ("engine", "step_token_budget") => self.engine.step_token_budget = parse_usize()?,
            ("engine", "max_retries") => self.engine.max_retries = parse_usize()?,
            ("engine", "retry_backoff_ms") => self.engine.retry_backoff_ms = v.parse()?,
            ("engine", "stall_timeout_ms") => self.engine.stall_timeout_ms = v.parse()?,
            ("train", "steps") => self.train.steps = parse_usize()?,
            ("train", "lr") => self.train.lr = parse_f64()?,
            ("train", "adv_eps") => self.train.adv_eps = parse_f64()?,
            ("train", "seed") => self.train.seed = v.parse()?,
            ("train", "checkpoint_every") => self.train.checkpoint_every = parse_usize()?,
            ("train", "checkpoint_dir") => self.train.checkpoint_dir = v.into(),
            ("eval", "samples_per_prompt") => self.eval.samples_per_prompt = parse_usize()?,
            ("eval", "temperature") => self.eval.temperature = parse_f64()?,
            ("eval", "top_p") => self.eval.top_p = parse_f64()?,
            ("eval", "prompts_per_suite") => self.eval.prompts_per_suite = parse_usize()?,
            ("workload", "process") => self.workload.kind = WorkloadKind::parse(v)?,
            ("workload", "rate_rps") => {
                self.workload.rate_rps = parse_f64()?;
                if self.workload.rate_rps <= 0.0 {
                    bail!("workload.rate_rps must be > 0");
                }
            }
            ("workload", "requests") => self.workload.requests = parse_usize()?,
            ("workload", "burst_on_ms") => {
                self.workload.burst_on_ms = v.parse()?;
                if self.workload.burst_on_ms == 0 {
                    bail!("workload.burst_on_ms must be >= 1");
                }
            }
            ("workload", "burst_off_ms") => self.workload.burst_off_ms = v.parse()?,
            ("workload", "interactive_share") => {
                self.workload.interactive_share = parse_f64()?;
                if !(0.0..=1.0).contains(&self.workload.interactive_share) {
                    bail!("workload.interactive_share must be in [0, 1]");
                }
            }
            ("workload", "queue_cap") => {
                self.workload.queue_cap = parse_usize()?;
                if self.workload.queue_cap == 0 {
                    bail!("workload.queue_cap must be >= 1");
                }
            }
            ("workload", "quantum_us") => {
                self.workload.quantum_us = v.parse()?;
                if self.workload.quantum_us == 0 {
                    bail!("workload.quantum_us must be >= 1");
                }
            }
            ("workload", "slots_per_engine") => {
                self.workload.slots_per_engine = parse_usize()?;
                if self.workload.slots_per_engine == 0 {
                    bail!("workload.slots_per_engine must be >= 1");
                }
            }
            ("router", "transport") => {
                self.router.transport = TransportKind::parse(v)?;
                if self.router.transport == TransportKind::Tcp
                    && self.router.host_list().is_empty()
                {
                    eprintln!(
                        "config: router.transport=tcp needs router.hosts before the fleet \
                         can connect"
                    );
                }
            }
            ("router", "hosts") => self.router.hosts = v.into(),
            ("router", "heartbeat_ms") => self.router.heartbeat_ms = v.parse()?,
            ("router", "heartbeat_misses") => {
                self.router.heartbeat_misses = v.parse()?;
                if self.router.heartbeat_misses == 0 {
                    bail!("router.heartbeat_misses must be >= 1");
                }
            }
            ("router", "connect_timeout_ms") => {
                self.router.connect_timeout_ms = v.parse()?;
                if self.router.connect_timeout_ms == 0 {
                    bail!("router.connect_timeout_ms must be >= 1");
                }
            }
            _ => bail!("unknown config key {key:?}"),
        }
        Ok(())
    }

    /// Load from a TOML-subset document (sections + scalar keys).
    pub fn from_toml_str(text: &str) -> Result<Config> {
        let doc = toml::parse(text)?;
        let mut cfg = Config::new("small");
        for (section, kvs) in doc {
            for (k, v) in kvs {
                let key = if section.is_empty() { k.clone() } else { format!("{section}.{k}") };
                let sval = match &v {
                    TomlValue::Str(s) => s.clone(),
                    TomlValue::Int(i) => i.to_string(),
                    TomlValue::Float(f) => f.to_string(),
                    TomlValue::Bool(b) => b.to_string(),
                };
                cfg.set(&key, &sval)?;
            }
        }
        Ok(cfg)
    }

    /// Load a config from a TOML file on disk.
    pub fn from_toml_file(path: &str) -> Result<Config> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        Config::from_toml_str(&text)
    }

    /// Total decode-slot capacity of the pool given slots-per-engine.
    pub fn total_slots(&self, slots_per_engine: usize) -> usize {
        self.engine.engines * slots_per_engine
    }

    /// Pretty table (the `copris config` subcommand / Table 3 regeneration).
    pub fn render_table(&self) -> String {
        let r = &self.rollout;
        let t = &self.train;
        let e = &self.eval;
        let mut s = String::new();
        s.push_str("| Hyperparameter | Value |\n|---|---|\n");
        s.push_str("| **Rollout Configuration** | |\n");
        s.push_str(&format!("| Rollout mode | {} |\n", r.mode.name()));
        s.push_str(&format!("| Rollout batch size (B) | {} |\n", r.batch_prompts));
        s.push_str(&format!("| Number of samples per prompt (G) | {} |\n", r.group_size));
        s.push_str(&format!("| Rollout temperature | {} |\n", r.temperature));
        s.push_str(&format!("| Rollout top-p | {} |\n", r.top_p));
        s.push_str(&format!("| Rollout top-k | {} |\n", r.top_k));
        s.push_str(&format!("| Number of samples per eval prompt | {} |\n", e.samples_per_prompt));
        s.push_str(&format!("| Eval rollout temperature | {} |\n", e.temperature));
        s.push_str(&format!("| Eval rollout top-p | {} |\n", e.top_p));
        s.push_str("| **CoPRIS Specific Configuration** | |\n");
        s.push_str(&format!("| Concurrency pool size (N') | {} |\n", r.concurrency));
        s.push_str(&format!("| Importance sampling | {} |\n", r.importance_sampling));
        s.push_str(&format!("| Stage pipelining | {} |\n", r.pipeline));
        s.push_str(&format!("| Execution mode | {} |\n", r.exec_mode().name()));
        s.push_str(&format!("| Max staleness (syncs per assignment) | {} |\n", r.max_staleness));
        s.push_str(&format!("| Active termination (APRIL) | {} |\n", r.active_termination));
        s.push_str(&format!("| KV retention (affinity resume) | {} |\n", r.retain_kv));
        s.push_str(&format!("| Retain KV across sync | {} |\n", r.retain_kv_across_sync));
        let eng = &self.engine;
        s.push_str("| **Engine / Paged KV Cache** | |\n");
        s.push_str(&format!("| Engines | {} |\n", eng.engines));
        s.push_str(&format!("| KV block size (tokens) | {} |\n", eng.kv_block_size));
        // Both denominations, so block budgets stay auditable in tokens.
        let blocks = eng.budget_blocks();
        let budget = if blocks == 0 {
            "unlimited".to_string()
        } else {
            format!("{} blocks ({} tokens)", blocks, blocks * eng.kv_block_size)
        };
        s.push_str(&format!("| KV budget | {budget} |\n"));
        // Narrow dtypes multiply the enforced block count, not the bytes.
        let mult = eng.kv_dtype.capacity_multiplier();
        let dtype = if mult == 1 {
            eng.kv_dtype.name().to_string()
        } else {
            format!("{} ({}x effective blocks)", eng.kv_dtype.name(), mult)
        };
        s.push_str(&format!("| KV dtype | {dtype} |\n"));
        s.push_str(&format!("| Prompt prefix sharing (COW) | {} |\n", eng.prefix_sharing));
        let packing = if eng.step_token_budget == 0 {
            "off (slot admission)".to_string()
        } else {
            format!("{} tokens/step (chunked prefill)", eng.step_token_budget)
        };
        s.push_str(&format!("| Step token budget (continuous batching) | {packing} |\n"));
        s.push_str(&format!(
            "| Engine failover (retries/backoff/stall) | {}x / {} ms / {} ms |\n",
            eng.max_retries, eng.retry_backoff_ms, eng.stall_timeout_ms
        ));
        let rt = &self.router;
        s.push_str("| **Router / Transport** | |\n");
        let transport = match rt.transport {
            TransportKind::Local => "local (in-process)".to_string(),
            TransportKind::Tcp => {
                let hosts = rt.host_list();
                format!("tcp ({} host{})", hosts.len(), if hosts.len() == 1 { "" } else { "s" })
            }
        };
        s.push_str(&format!("| Transport | {transport} |\n"));
        let hb = if rt.heartbeat_ms == 0 {
            "off".to_string()
        } else {
            format!("{} ms x {} misses", rt.heartbeat_ms, rt.heartbeat_misses)
        };
        s.push_str(&format!("| Host heartbeat | {hb} |\n"));
        let w = &self.workload;
        s.push_str("| **Open-Loop Workload / SLO** | |\n");
        let process = match w.kind {
            WorkloadKind::Poisson => "poisson".to_string(),
            WorkloadKind::Bursty => {
                format!("bursty ({} ms on / {} ms off)", w.burst_on_ms, w.burst_off_ms)
            }
        };
        s.push_str(&format!("| Arrival process | {process} |\n"));
        s.push_str(&format!("| Offered rate (req/s) | {} |\n", w.rate_rps));
        s.push_str(&format!("| Requests per run | {} |\n", w.requests));
        s.push_str(&format!("| Interactive tenant share | {} |\n", w.interactive_share));
        s.push_str(&format!("| Admission queue cap | {} |\n", w.queue_cap));
        s.push_str(&format!("| Scheduler quantum (virtual us) | {} |\n", w.quantum_us));
        s.push_str(&format!("| Decode slots per engine | {} |\n", w.slots_per_engine));
        s.push_str("| **Training Configuration** | |\n");
        s.push_str(&format!("| Global batch size | {} |\n", r.batch_prompts));
        s.push_str("| Optimizer | Adam |\n");
        s.push_str(&format!("| Learning rate | {} |\n", t.lr));
        s.push_str("| Weight decay | 0.01 |\n");
        s.push_str("| Entropy coefficient | 0.0 |\n");
        s.push_str("| KL coefficient | 0.0 |\n");
        s.push_str("| Clip ratio low | 0.2 |\n");
        s.push_str("| Clip ratio high | 0.28 |\n");
        s.push_str("| Loss aggregation mode | token mean |\n");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_copris_with_is() {
        let c = Config::new("tiny");
        assert_eq!(c.rollout.mode, RolloutMode::Copris);
        assert!(c.rollout.importance_sampling);
    }

    #[test]
    fn set_overrides() {
        let mut c = Config::new("tiny");
        c.set("rollout.concurrency", "32").unwrap();
        c.set("rollout.mode", "sync").unwrap();
        c.set("train.lr", "1e-6").unwrap();
        c.set("rollout.importance_sampling", "off").unwrap();
        c.set("rollout.pipeline", "true").unwrap();
        assert_eq!(c.rollout.concurrency, 32);
        assert_eq!(c.rollout.mode, RolloutMode::Sync);
        assert_eq!(c.train.lr, 1e-6);
        assert!(!c.rollout.importance_sampling);
        assert!(c.rollout.pipeline);
    }

    #[test]
    fn pipeline_defaults_off_and_renders() {
        let c = Config::new("tiny");
        assert!(!c.rollout.pipeline);
        assert!(c.render_table().contains("Stage pipelining"));
    }

    #[test]
    fn retention_defaults_and_overrides() {
        let mut c = Config::new("tiny");
        // Defaults: retention on, never across syncs (golden-equivalent).
        assert!(c.rollout.retain_kv);
        assert!(!c.rollout.retain_kv_across_sync);
        assert!(c.rollout.affinity_max_imbalance > 0);
        assert!(c.render_table().contains("KV retention"));
        c.set("rollout.retain_kv", "off").unwrap();
        c.set("rollout.retain_kv_across_sync", "true").unwrap();
        c.set("rollout.affinity_max_imbalance", "9").unwrap();
        assert!(!c.rollout.retain_kv);
        assert!(c.rollout.retain_kv_across_sync);
        assert_eq!(c.rollout.affinity_max_imbalance, 9);
        // TOML path hits the same setters.
        let doc = "[rollout]\nretain_kv = false\nretain_kv_across_sync = true\n";
        let c2 = Config::from_toml_str(doc).unwrap();
        assert!(!c2.rollout.retain_kv);
        assert!(c2.rollout.retain_kv_across_sync);
    }

    #[test]
    fn paged_kv_defaults_and_overrides() {
        let mut c = Config::new("tiny");
        assert_eq!(c.engine.kv_block_size, crate::engine::DEFAULT_BLOCK_SIZE);
        assert!(c.engine.prefix_sharing, "prefix sharing is the default");
        assert_eq!(c.engine.budget_blocks(), 0, "default budget unlimited");
        c.set("engine.kv_block_size", "8").unwrap();
        c.set("engine.kv_budget_blocks", "12").unwrap();
        c.set("engine.prefix_sharing", "off").unwrap();
        assert_eq!(c.engine.budget_blocks(), 12);
        let kv = c.engine.kv_cache_config();
        assert_eq!(kv.block_size, 8);
        assert_eq!(kv.budget_blocks, 12);
        assert!(!kv.prefix_sharing);
        assert!(c.set("engine.kv_block_size", "0").is_err());
    }

    /// The removed token-denominated budget is rejected with a migration
    /// hint (including the converted block count), via both `set` and
    /// TOML; the blocks knob still renders both denominations.
    #[test]
    fn legacy_token_budget_rejected_with_migration_hint() {
        let mut c = Config::new("tiny");
        let err = c.set("engine.kv_budget_tokens", "100").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("kv_budget_blocks"), "hint names the new knob: {msg}");
        assert!(msg.contains("7 blocks"), "hint shows ceil(100/16): {msg}");
        assert_eq!(c.engine.budget_blocks(), 0, "rejected set leaves state unchanged");
        // The conversion hint respects an already-set block size.
        c.set("engine.kv_block_size", "32").unwrap();
        let msg = format!("{:#}", c.set("engine.kv_budget_tokens", "100").unwrap_err());
        assert!(msg.contains("4 blocks"), "ceil(100/32): {msg}");
        // TOML path rejects the key too.
        assert!(Config::from_toml_str("[engine]\nkv_budget_tokens = 48\n").is_err());
        // The blocks knob renders both denominations.
        let mut c2 = Config::new("tiny");
        c2.set("engine.kv_budget_blocks", "3").unwrap();
        let table = c2.render_table();
        assert!(table.contains("3 blocks (48 tokens)"), "{table}");
        assert!(table.contains("KV block size"), "{table}");
        assert!(table.contains("Prompt prefix sharing"), "{table}");
        let unlimited = Config::new("tiny").render_table();
        assert!(unlimited.contains("| KV budget | unlimited |"), "{unlimited}");
    }

    /// Async-execution knobs: serial default, `rollout.mode` sugar, the
    /// legacy `pipeline` bool as a pipelined alias, staleness/active-
    /// termination plumbing, and Table-3 rows.
    #[test]
    fn execution_mode_knobs_default_and_plumb_through() {
        let mut c = Config::new("tiny");
        assert_eq!(c.rollout.execution, ExecMode::Serial);
        assert_eq!(c.rollout.exec_mode(), ExecMode::Serial);
        assert_eq!(c.rollout.max_staleness, 1);
        assert!(c.rollout.active_termination);
        let table = c.render_table();
        assert!(table.contains("| Execution mode | serial |"), "{table}");
        assert!(table.contains("| Max staleness (syncs per assignment) | 1 |"), "{table}");
        assert!(table.contains("| Active termination (APRIL) | true |"), "{table}");

        // Legacy bool maps to pipelined via exec_mode().
        c.set("rollout.pipeline", "true").unwrap();
        assert_eq!(c.rollout.exec_mode(), ExecMode::Pipelined);
        // An explicit execution knob wins over the bool.
        c.set("rollout.execution", "async").unwrap();
        assert_eq!(c.rollout.exec_mode(), ExecMode::Async);
        assert!(c.render_table().contains("| Execution mode | async |"));

        // `rollout.mode` sugar: pipelined/async pick CoPRIS + execution.
        let mut c2 = Config::new("tiny");
        c2.set("rollout.mode", "async").unwrap();
        assert_eq!(c2.rollout.mode, RolloutMode::Copris);
        assert_eq!(c2.rollout.exec_mode(), ExecMode::Async);
        c2.set("rollout.mode", "pipelined").unwrap();
        assert_eq!(c2.rollout.exec_mode(), ExecMode::Pipelined);
        c2.set("rollout.mode", "sync").unwrap();
        assert_eq!(c2.rollout.mode, RolloutMode::Sync);

        c2.set("rollout.max_staleness", "0").unwrap();
        c2.set("rollout.active_termination", "off").unwrap();
        assert_eq!(c2.rollout.max_staleness, 0);
        assert!(!c2.rollout.active_termination);
        assert!(c2.set("rollout.execution", "warp").is_err());

        // TOML path hits the same setters.
        let doc = "[rollout]\nexecution = \"async\"\nmax_staleness = 3\n";
        let c3 = Config::from_toml_str(doc).unwrap();
        assert_eq!(c3.rollout.exec_mode(), ExecMode::Async);
        assert_eq!(c3.rollout.max_staleness, 3);
    }

    #[test]
    fn exec_mode_roundtrip() {
        for m in [ExecMode::Serial, ExecMode::Pipelined, ExecMode::Async] {
            assert_eq!(ExecMode::parse(m.name()).unwrap(), m);
        }
    }

    /// KV dtype knob: defaults to f32 (golden-equivalent), parses the
    /// dtype aliases, rejects junk, flows into the paged-KV config, and
    /// renders a Table-3 row with the effective-blocks multiplier.
    #[test]
    fn kv_dtype_defaults_f32_and_plumbs_through() {
        let mut c = Config::new("tiny");
        assert_eq!(c.engine.kv_dtype, crate::engine::KvDtype::F32);
        assert_eq!(c.engine.kv_cache_config().dtype, crate::engine::KvDtype::F32);
        assert!(c.render_table().contains("| KV dtype | f32 |"));
        c.set("engine.kv_dtype", "fp16").unwrap();
        assert_eq!(c.engine.kv_dtype, crate::engine::KvDtype::F16);
        c.set("engine.kv_dtype", "int8").unwrap();
        assert_eq!(c.engine.kv_cache_config().dtype, crate::engine::KvDtype::Int8);
        let table = c.render_table();
        assert!(table.contains("| KV dtype | int8 (4x effective blocks) |"), "{table}");
        assert!(c.set("engine.kv_dtype", "bf17").is_err());
        // TOML path hits the same setter.
        let doc = "[engine]\nkv_dtype = \"f16\"\n";
        let c2 = Config::from_toml_str(doc).unwrap();
        assert_eq!(c2.engine.kv_dtype, crate::engine::KvDtype::F16);
    }

    /// Continuous-batching knob: default off (slot admission), settable
    /// via CLI/TOML, flows into `engine_opts`, and renders a Table-3 row.
    #[test]
    fn step_token_budget_defaults_off_and_plumbs_through() {
        let mut c = Config::new("tiny");
        assert_eq!(c.engine.step_token_budget, 0, "default is legacy slot admission");
        assert_eq!(c.engine.engine_opts().step_token_budget, 0);
        let table = c.render_table();
        assert!(
            table.contains("| Step token budget (continuous batching) | off (slot admission) |"),
            "{table}"
        );
        c.set("engine.step_token_budget", "48").unwrap();
        assert_eq!(c.engine.step_token_budget, 48);
        let opts = c.engine.engine_opts();
        assert_eq!(opts.step_token_budget, 48);
        assert_eq!(opts.kv.block_size, c.engine.kv_block_size);
        let table = c.render_table();
        assert!(table.contains("48 tokens/step (chunked prefill)"), "{table}");
        // TOML path hits the same setter.
        let doc = "[engine]\nstep_token_budget = 32\n";
        let c2 = Config::from_toml_str(doc).unwrap();
        assert_eq!(c2.engine.step_token_budget, 32);
    }

    /// Failover knobs: paper-free defaults (3 retries, 10 ms backoff,
    /// 120 s stall watchdog), settable via CLI/TOML, flow into
    /// `supervisor_opts`, and render a table row.
    #[test]
    fn failover_knobs_default_and_plumb_through() {
        let mut c = Config::new("tiny");
        assert_eq!(c.engine.max_retries, 3);
        assert_eq!(c.engine.retry_backoff_ms, 10);
        assert_eq!(c.engine.stall_timeout_ms, 120_000, "default matches old event timeout");
        let sup = c.engine.supervisor_opts();
        assert_eq!(sup.max_retries, 3);
        assert_eq!(sup.retry_backoff_ms, 10);
        c.set("engine.max_retries", "5").unwrap();
        c.set("engine.retry_backoff_ms", "0").unwrap();
        c.set("engine.stall_timeout_ms", "250").unwrap();
        let sup = c.engine.supervisor_opts();
        assert_eq!(sup.max_retries, 5);
        assert_eq!(sup.retry_backoff_ms, 0);
        assert_eq!(c.engine.stall_timeout_ms, 250);
        let table = c.render_table();
        assert!(
            table.contains("| Engine failover (retries/backoff/stall) | 5x / 0 ms / 250 ms |"),
            "{table}"
        );
        // TOML path hits the same setters.
        let doc = "[engine]\nmax_retries = 1\nretry_backoff_ms = 7\nstall_timeout_ms = 9000\n";
        let c2 = Config::from_toml_str(doc).unwrap();
        assert_eq!(c2.engine.max_retries, 1);
        assert_eq!(c2.engine.retry_backoff_ms, 7);
        assert_eq!(c2.engine.stall_timeout_ms, 9000);
    }

    #[test]
    fn set_rejects_unknown_key() {
        let mut c = Config::new("tiny");
        assert!(c.set("rollout.nope", "1").is_err());
        assert!(c.set("train.lr", "abc").is_err());
    }

    #[test]
    fn from_toml() {
        let doc = r#"
            model = "small"
            [rollout]
            mode = "copris"
            batch_prompts = 16
            temperature = 0.9
            importance_sampling = true
            [train]
            steps = 100
            lr = 1e-4
        "#;
        let c = Config::from_toml_str(doc).unwrap();
        assert_eq!(c.model, "small");
        assert_eq!(c.rollout.batch_prompts, 16);
        assert_eq!(c.rollout.temperature, 0.9);
        assert_eq!(c.train.steps, 100);
        assert!((c.train.lr - 1e-4).abs() < 1e-12);
    }

    #[test]
    fn render_table_mentions_paper_rows() {
        let table = Config::new("small").render_table();
        for needle in ["Concurrency pool size", "Clip ratio low", "token mean"] {
            assert!(table.contains(needle), "missing {needle}");
        }
    }

    #[test]
    fn mode_roundtrip() {
        for m in [RolloutMode::Sync, RolloutMode::NaivePartial, RolloutMode::Copris] {
            assert_eq!(RolloutMode::parse(m.name()).unwrap(), m);
        }
    }

    /// Open-loop workload knobs: Poisson defaults, settable via CLI/TOML,
    /// validated ranges, and a Table-3 section in the rendered table.
    #[test]
    fn workload_knobs_default_and_plumb_through() {
        let mut c = Config::new("tiny");
        assert_eq!(c.workload.kind, WorkloadKind::Poisson);
        assert_eq!(c.workload.rate_rps, 400.0);
        assert_eq!(c.workload.requests, 300);
        assert_eq!(c.workload.queue_cap, 64);
        assert_eq!(c.workload.quantum_us, 1_000);
        assert_eq!(c.workload.slots_per_engine, 4);
        let table = c.render_table();
        assert!(table.contains("| **Open-Loop Workload / SLO** | |"), "{table}");
        assert!(table.contains("| Arrival process | poisson |"), "{table}");
        assert!(table.contains("| Offered rate (req/s) | 400 |"), "{table}");

        c.set("workload.process", "bursty").unwrap();
        c.set("workload.rate_rps", "1200").unwrap();
        c.set("workload.requests", "64").unwrap();
        c.set("workload.burst_on_ms", "10").unwrap();
        c.set("workload.burst_off_ms", "40").unwrap();
        c.set("workload.interactive_share", "0.25").unwrap();
        c.set("workload.queue_cap", "8").unwrap();
        c.set("workload.quantum_us", "500").unwrap();
        c.set("workload.slots_per_engine", "2").unwrap();
        assert_eq!(c.workload.kind, WorkloadKind::Bursty);
        assert_eq!(c.workload.rate_rps, 1200.0);
        assert_eq!(c.workload.requests, 64);
        assert_eq!(c.workload.burst_on_ms, 10);
        assert_eq!(c.workload.burst_off_ms, 40);
        assert_eq!(c.workload.interactive_share, 0.25);
        assert_eq!(c.workload.queue_cap, 8);
        assert_eq!(c.workload.quantum_us, 500);
        assert_eq!(c.workload.slots_per_engine, 2);
        let table = c.render_table();
        assert!(table.contains("| Arrival process | bursty (10 ms on / 40 ms off) |"), "{table}");

        // Validation: out-of-range values are rejected, state unchanged.
        assert!(c.set("workload.process", "uniform").is_err());
        assert!(c.set("workload.rate_rps", "0").is_err());
        assert!(c.set("workload.interactive_share", "1.5").is_err());
        assert!(c.set("workload.queue_cap", "0").is_err());
        assert!(c.set("workload.quantum_us", "0").is_err());
        assert!(c.set("workload.burst_on_ms", "0").is_err());
        assert!(c.set("workload.slots_per_engine", "0").is_err());

        // TOML path hits the same setters.
        let doc = "[workload]\nprocess = \"bursty\"\nrate_rps = 900\nrequests = 12\n";
        let c2 = Config::from_toml_str(doc).unwrap();
        assert_eq!(c2.workload.kind, WorkloadKind::Bursty);
        assert_eq!(c2.workload.rate_rps, 900.0);
        assert_eq!(c2.workload.requests, 12);
    }

    #[test]
    fn workload_kind_roundtrip() {
        for k in [WorkloadKind::Poisson, WorkloadKind::Bursty] {
            assert_eq!(WorkloadKind::parse(k.name()).unwrap(), k);
        }
    }

    /// Router knobs: local-transport defaults (golden-equivalent),
    /// settable via CLI/TOML, host-list parsing, validated ranges, and a
    /// Table-3 section in the rendered table.
    #[test]
    fn router_knobs_default_and_plumb_through() {
        let mut c = Config::new("tiny");
        assert_eq!(c.router.transport, TransportKind::Local);
        assert!(c.router.host_list().is_empty());
        assert_eq!(c.router.heartbeat_ms, 2_000);
        assert_eq!(c.router.heartbeat_misses, 3);
        assert_eq!(c.router.connect_timeout_ms, 5_000);
        let table = c.render_table();
        assert!(table.contains("| **Router / Transport** | |"), "{table}");
        assert!(table.contains("| Transport | local (in-process) |"), "{table}");
        assert!(table.contains("| Host heartbeat | 2000 ms x 3 misses |"), "{table}");

        c.set("router.hosts", "127.0.0.1:7101, 127.0.0.1:7102 ,").unwrap();
        c.set("router.transport", "tcp").unwrap();
        c.set("router.heartbeat_ms", "250").unwrap();
        c.set("router.heartbeat_misses", "2").unwrap();
        c.set("router.connect_timeout_ms", "800").unwrap();
        assert_eq!(c.router.transport, TransportKind::Tcp);
        assert_eq!(c.router.host_list(), vec!["127.0.0.1:7101", "127.0.0.1:7102"]);
        assert_eq!(c.router.heartbeat_ms, 250);
        assert_eq!(c.router.heartbeat_misses, 2);
        assert_eq!(c.router.connect_timeout_ms, 800);
        let table = c.render_table();
        assert!(table.contains("| Transport | tcp (2 hosts) |"), "{table}");
        assert!(table.contains("| Host heartbeat | 250 ms x 2 misses |"), "{table}");
        c.set("router.heartbeat_ms", "0").unwrap();
        assert!(c.render_table().contains("| Host heartbeat | off |"));

        // Validation: junk transports and zero guards are rejected.
        assert!(c.set("router.transport", "udp").is_err());
        assert!(c.set("router.heartbeat_misses", "0").is_err());
        assert!(c.set("router.connect_timeout_ms", "0").is_err());

        // TOML path hits the same setters (hosts stay a scalar string —
        // the TOML subset has no arrays).
        let doc =
            "[router]\ntransport = \"tcp\"\nhosts = \"a:1,b:2\"\nheartbeat_ms = 100\n";
        let c2 = Config::from_toml_str(doc).unwrap();
        assert_eq!(c2.router.transport, TransportKind::Tcp);
        assert_eq!(c2.router.host_list(), vec!["a:1", "b:2"]);
        assert_eq!(c2.router.heartbeat_ms, 100);
    }

    #[test]
    fn transport_kind_roundtrip() {
        for t in [TransportKind::Local, TransportKind::Tcp] {
            assert_eq!(TransportKind::parse(t.name()).unwrap(), t);
        }
    }
}
