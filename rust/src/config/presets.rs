//! Config presets. `paper_preset` mirrors Table 3 verbatim (for the
//! `copris config --preset paper` reproduction); `scaled_preset` maps those
//! settings onto this CPU substrate, preserving the ratios that matter:
//! concurrency N' >> B·G, eval temperature 0.6, clip (0.2, 0.28), GRPO G=8.

use super::schema::{Config, ExecMode, RolloutMode};

/// The paper's Table 3, verbatim. Not runnable on this substrate (batch 64
/// × 8 rollouts × 15360 tokens) — it documents the source configuration.
pub fn paper_preset() -> Config {
    let mut c = Config::new("small");
    c.rollout.batch_prompts = 64;
    c.rollout.group_size = 8;
    c.rollout.concurrency = 1024;
    c.rollout.temperature = 1.0;
    c.rollout.top_p = 1.0;
    c.rollout.top_k = -1;
    c.train.steps = 1000;
    c.train.lr = 1e-6;
    c.eval.samples_per_prompt = 32;
    c.eval.temperature = 0.6;
    c.eval.top_p = 1.0;
    c
}

/// Paper settings scaled to this substrate (2 engines × 8 slots default).
/// Ratios preserved: N'/(B·G) = 1024/512 = 2 → concurrency = 2·B·G is
/// capped by pool capacity; G=8 kept; eval temp 0.6 kept.
pub fn scaled_preset(model: &str) -> Config {
    let mut c = Config::new(model);
    c.rollout.batch_prompts = 6;
    c.rollout.group_size = 4;
    // N' defaults to the full pool (engines × slots); experiments sweep it.
    c.rollout.concurrency = 16;
    c.train.steps = 50;
    c.train.lr = 3e-4; // scaled for ~1M-param models (paper 1e-6 at 1.5B+)
    c.eval.samples_per_prompt = 2;
    c.eval.prompts_per_suite = 8;
    c.engine.engines = 2;
    c
}

/// Named presets for the CLI.
pub fn preset(name: &str) -> Option<Config> {
    match name {
        "paper" => Some(paper_preset()),
        "scaled-small" => Some(scaled_preset("small")),
        "scaled-tiny" => {
            let mut c = scaled_preset("tiny");
            c.rollout.batch_prompts = 4;
            c.rollout.group_size = 4;
            c.rollout.concurrency = 8;
            Some(c)
        }
        "sync-baseline" => {
            let mut c = scaled_preset("small");
            c.rollout.mode = RolloutMode::Sync;
            Some(c)
        }
        // CoPRIS with stage-pipelined execution: stage t+1 generates while
        // the stage-t update computes; weights sync mid-flight. Also runs
        // the engines with continuous batching + chunked prefill (the two
        // overlap layers compose: prompts interleave with decode inside
        // each engine step, rollout overlaps training across steps).
        "pipelined-small" => {
            let mut c = scaled_preset("small");
            c.rollout.pipeline = true;
            c.engine.step_token_budget = 48;
            Some(c)
        }
        // Fully-async CoPRIS: the trajectory stream never quiesces — the
        // trainer consumes a batch whenever B groups are staged and syncs
        // weights mid-flight under the bounded-staleness protocol
        // (max_staleness syncs per assignment; APRIL-style active cuts for
        // at-risk stragglers).
        "async-small" => {
            let mut c = scaled_preset("small");
            c.rollout.execution = ExecMode::Async;
            c.rollout.max_staleness = 1;
            c.rollout.active_termination = true;
            c.engine.step_token_budget = 48;
            Some(c)
        }
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_preset_matches_table3() {
        let c = paper_preset();
        assert_eq!(c.rollout.batch_prompts, 64);
        assert_eq!(c.rollout.group_size, 8);
        assert_eq!(c.rollout.concurrency, 1024);
        assert_eq!(c.train.lr, 1e-6);
        assert_eq!(c.eval.samples_per_prompt, 32);
        assert_eq!(c.eval.temperature, 0.6);
    }

    #[test]
    fn scaled_preserves_eval_temp_and_mode() {
        let c = scaled_preset("small");
        assert_eq!(c.eval.temperature, 0.6);
        assert_eq!(c.rollout.mode, RolloutMode::Copris);
    }

    #[test]
    fn preset_lookup() {
        assert!(preset("paper").is_some());
        assert!(preset("scaled-small").is_some());
        assert!(preset("sync-baseline").unwrap().rollout.mode == RolloutMode::Sync);
        let pipe = preset("pipelined-small").unwrap();
        assert!(pipe.rollout.pipeline);
        assert_eq!(pipe.rollout.mode, RolloutMode::Copris);
        assert!(
            pipe.engine.step_token_budget > 0,
            "pipelined preset runs the continuous-batching scheduler"
        );
        let asy = preset("async-small").unwrap();
        assert_eq!(asy.rollout.exec_mode(), ExecMode::Async);
        assert_eq!(asy.rollout.mode, RolloutMode::Copris);
        assert_eq!(asy.rollout.max_staleness, 1);
        assert!(asy.rollout.active_termination);
        assert!(preset("nope").is_none());
    }
}
