//! Minimal TOML-subset parser (sections, scalar key=value, comments).
//! Enough for config files; arrays/tables-of-tables are out of scope.

use anyhow::{bail, Result};

/// A scalar TOML value (the subset the config schema needs).
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    /// A double-quoted string.
    Str(String),
    /// An integer literal.
    Int(i64),
    /// A float literal.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
}

/// Returns (section → [(key, value)]); keys before any `[section]` land in "".
pub fn parse(text: &str) -> Result<Vec<(String, Vec<(String, TomlValue)>)>> {
    let mut out: Vec<(String, Vec<(String, TomlValue)>)> = vec![(String::new(), vec![])];
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(section) = line.strip_prefix('[') {
            let Some(name) = section.strip_suffix(']') else {
                bail!("line {}: unterminated section header", lineno + 1);
            };
            out.push((name.trim().to_string(), vec![]));
            continue;
        }
        let Some(eq) = line.find('=') else {
            bail!("line {}: expected key = value", lineno + 1);
        };
        let key = line[..eq].trim().to_string();
        let val = parse_value(line[eq + 1..].trim())
            .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
        out.last_mut().unwrap().1.push((key, val));
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // A '#' outside quotes starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue> {
    if let Some(body) = s.strip_prefix('"') {
        let Some(body) = body.strip_suffix('"') else {
            bail!("unterminated string {s:?}");
        };
        return Ok(TomlValue::Str(body.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("cannot parse value {s:?}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let doc = r#"
            top = 1
            [a]
            x = "hi"     # comment
            y = 2.5
            z = true
            [b]
            n = -3
        "#;
        let parsed = parse(doc).unwrap();
        assert_eq!(parsed[0].0, "");
        assert_eq!(parsed[0].1[0], ("top".into(), TomlValue::Int(1)));
        assert_eq!(parsed[1].0, "a");
        assert_eq!(parsed[1].1[0], ("x".into(), TomlValue::Str("hi".into())));
        assert_eq!(parsed[1].1[1], ("y".into(), TomlValue::Float(2.5)));
        assert_eq!(parsed[1].1[2], ("z".into(), TomlValue::Bool(true)));
        assert_eq!(parsed[2].1[0], ("n".into(), TomlValue::Int(-3)));
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let parsed = parse(r##"k = "a#b""##).unwrap();
        assert_eq!(parsed[0].1[0].1, TomlValue::Str("a#b".into()));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = parse("ok = 1\nbroken").unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn scientific_notation_floats() {
        let parsed = parse("lr = 1e-6").unwrap();
        assert_eq!(parsed[0].1[0].1, TomlValue::Float(1e-6));
    }
}
