//! Descriptive statistics for metrics and the bench harness.

/// Summary of a sample: mean/std/min/max/percentiles.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary::default();
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 0.50),
            p95: percentile_sorted(&sorted, 0.95),
            p99: percentile_sorted(&sorted, 0.99),
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted slice, q in [0, 1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
    }
}

/// Linear-interpolated percentile of an UNSORTED sample (copies and
/// sorts; use `percentile_sorted` on hot paths). NaN on empty input.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&s, q)
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { 0.0 } else { xs.iter().sum::<f64>() / xs.len() as f64 }
}

pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Group-relative advantages, GRPO Eq. 5: (R_i - mean) / (std + eps).
pub fn group_advantages(rewards: &[f64], eps: f64) -> Vec<f64> {
    let m = mean(rewards);
    let s = std_dev(rewards);
    rewards.iter().map(|r| (r - m) / (s + eps)).collect()
}

/// ASCII histogram rows (label, count, bar) — used by the Fig-1 bench.
pub fn ascii_histogram(xs: &[f64], bins: usize, width: usize) -> Vec<String> {
    if xs.is_empty() || bins == 0 {
        return vec![];
    }
    let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let span = (hi - lo).max(1e-12);
    let mut counts = vec![0usize; bins];
    for &x in xs {
        let b = (((x - lo) / span) * bins as f64) as usize;
        counts[b.min(bins - 1)] += 1;
    }
    let maxc = *counts.iter().max().unwrap() as f64;
    counts
        .iter()
        .enumerate()
        .map(|(i, &c)| {
            let a = lo + span * i as f64 / bins as f64;
            let b = lo + span * (i + 1) as f64 / bins as f64;
            let bar = "#".repeat(((c as f64 / maxc) * width as f64).round() as usize);
            format!("{a:8.1}-{b:8.1} | {c:5} | {bar}")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_constant_series() {
        let s = Summary::of(&[2.0; 10]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p50, 2.0);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 2.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let xs: Vec<f64> = (0..=100).map(|i| i as f64).collect();
        assert!((percentile_sorted(&xs, 0.5) - 50.0).abs() < 1e-9);
        assert!((percentile_sorted(&xs, 0.95) - 95.0).abs() < 1e-9);
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 1.0), 100.0);
    }

    #[test]
    fn percentile_sorts_unsorted_input() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert!((percentile(&xs, 0.5) - 3.0).abs() < 1e-9);
        assert!((percentile(&xs, 1.0) - 5.0).abs() < 1e-9);
        assert!(percentile(&[], 0.5).is_nan());
    }

    #[test]
    fn group_advantages_zero_mean_unit_scale() {
        let adv = group_advantages(&[1.0, 0.0, 1.0, 0.0], 1e-6);
        let m = mean(&adv);
        assert!(m.abs() < 1e-9);
        assert!(adv[0] > 0.0 && adv[1] < 0.0);
    }

    #[test]
    fn group_advantages_all_equal_rewards_are_zero() {
        // Degenerate group (all correct or all wrong) carries no signal.
        let adv = group_advantages(&[1.0; 8], 1e-6);
        assert!(adv.iter().all(|a| a.abs() < 1e-6));
    }

    #[test]
    fn histogram_shape() {
        let rows = ascii_histogram(&[1.0, 1.1, 5.0, 9.9], 3, 10);
        assert_eq!(rows.len(), 3);
        assert!(rows[0].contains('#'));
    }

    #[test]
    fn empty_inputs_do_not_panic() {
        assert_eq!(Summary::of(&[]).n, 0);
        assert!(percentile_sorted(&[], 0.5).is_nan());
        assert_eq!(mean(&[]), 0.0);
        assert!(ascii_histogram(&[], 4, 10).is_empty());
    }
}
