//! Substrate utilities hand-rolled for the offline environment:
//! PRNG, descriptive statistics, JSON writing, and wall-clock timers.

pub mod json;
pub mod rng;
pub mod stats;
pub mod timer;

pub use rng::Rng;
pub use stats::Summary;
pub use timer::StageTimer;
