//! Deterministic PRNG: SplitMix64 seeding + xoshiro256++ core.
//!
//! `rand`/`rand_chacha` are not in the vendored crate set, so this is the
//! project-wide randomness source. Streams are cheap to fork (`fork`), which
//! the engines/tasks use to stay deterministic under any thread interleaving.

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Derive an independent stream keyed by `tag` (like jax's fold_in).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let a = self.next_u64();
        Rng::new(a ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift with rejection for unbiasedness.
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n as u128);
            let lo = m as u64;
            if lo >= n || lo >= (u64::MAX - n + 1) % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Standard normal via Box–Muller.
    pub fn next_normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Pick an element index by unnormalized non-negative weights.
    pub fn pick_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forks_are_independent() {
        let mut root = Rng::new(7);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let av: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let bv: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(av, bv);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(1);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let x = r.below(7) as usize;
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(2);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn weighted_pick_respects_zero_weight() {
        let mut r = Rng::new(4);
        for _ in 0..200 {
            let i = r.pick_weighted(&[0.0, 1.0, 0.0]);
            assert_eq!(i, 1);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
