//! Stage timers: the per-step timing decomposition the paper reports in
//! Table 2 (rollout/s, cal-logprob/s, step/s) plus utilization traces.

use std::collections::BTreeMap;
use std::time::Instant;

/// Accumulates named stage durations within one (or many) training steps.
#[derive(Debug, Default, Clone)]
pub struct StageTimer {
    totals: BTreeMap<String, f64>,
    counts: BTreeMap<String, usize>,
}

impl StageTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under `stage`.
    pub fn time<T>(&mut self, stage: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(stage, t0.elapsed().as_secs_f64());
        out
    }

    pub fn add(&mut self, stage: &str, secs: f64) {
        *self.totals.entry(stage.to_string()).or_default() += secs;
        *self.counts.entry(stage.to_string()).or_default() += 1;
    }

    pub fn total(&self, stage: &str) -> f64 {
        self.totals.get(stage).copied().unwrap_or(0.0)
    }

    pub fn count(&self, stage: &str) -> usize {
        self.counts.get(stage).copied().unwrap_or(0)
    }

    pub fn mean(&self, stage: &str) -> f64 {
        let c = self.count(stage);
        if c == 0 { 0.0 } else { self.total(stage) / c as f64 }
    }

    pub fn stages(&self) -> impl Iterator<Item = (&str, f64)> {
        self.totals.iter().map(|(k, v)| (k.as_str(), *v))
    }

    pub fn merge(&mut self, other: &StageTimer) {
        for (k, v) in &other.totals {
            *self.totals.entry(k.clone()).or_default() += v;
        }
        for (k, c) in &other.counts {
            *self.counts.entry(k.clone()).or_default() += c;
        }
    }

    pub fn report(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.totals {
            out.push_str(&format!(
                "{k:>16}: {v:8.3}s  (n={}, mean {:.4}s)\n",
                self.counts[k],
                v / (self.counts[k].max(1)) as f64
            ));
        }
        out
    }
}

/// A wall-clock scope guard alternative for call sites that can't close over.
pub struct Scope {
    start: Instant,
}

impl Scope {
    pub fn start() -> Self {
        Scope { start: Instant::now() }
    }
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_totals_and_counts() {
        let mut t = StageTimer::new();
        t.add("rollout", 1.0);
        t.add("rollout", 2.0);
        t.add("train", 0.5);
        assert_eq!(t.total("rollout"), 3.0);
        assert_eq!(t.count("rollout"), 2);
        assert_eq!(t.mean("rollout"), 1.5);
        assert_eq!(t.total("missing"), 0.0);
    }

    #[test]
    fn time_closure_returns_value() {
        let mut t = StageTimer::new();
        let v = t.time("x", || 42);
        assert_eq!(v, 42);
        assert_eq!(t.count("x"), 1);
        assert!(t.total("x") >= 0.0);
    }

    #[test]
    fn merge_sums() {
        let mut a = StageTimer::new();
        a.add("s", 1.0);
        let mut b = StageTimer::new();
        b.add("s", 2.0);
        b.add("t", 1.0);
        a.merge(&b);
        assert_eq!(a.total("s"), 3.0);
        assert_eq!(a.count("s"), 2);
        assert_eq!(a.total("t"), 1.0);
    }
}
