//! Minimal JSON: a writer for metrics/JSONL logs and a parser for the
//! artifact `manifest.json` files (serde is not in the vendored crate set).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// JSON value tree (numbers kept as f64; manifests only use int/str/arr/obj).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

// ---------------------------------------------------------------------------
// writer
// ---------------------------------------------------------------------------

pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// Incremental single-object writer: `Obj::new().field("k", 1.0).finish()`.
pub struct Obj {
    buf: String,
    first: bool,
}

impl Obj {
    pub fn new() -> Self {
        Obj { buf: String::from("{"), first: true }
    }
    fn sep(&mut self) {
        if !self.first {
            self.buf.push(',');
        }
        self.first = false;
    }
    pub fn num(mut self, k: &str, v: f64) -> Self {
        self.sep();
        if v.is_finite() {
            let _ = write!(self.buf, "\"{}\":{}", escape(k), v);
        } else {
            let _ = write!(self.buf, "\"{}\":null", escape(k));
        }
        self
    }
    pub fn int(self, k: &str, v: i64) -> Self {
        let mut s = self;
        s.sep();
        let _ = write!(s.buf, "\"{}\":{}", escape(k), v);
        s
    }
    pub fn str(mut self, k: &str, v: &str) -> Self {
        self.sep();
        let _ = write!(self.buf, "\"{}\":\"{}\"", escape(k), escape(v));
        self
    }
    pub fn bool(mut self, k: &str, v: bool) -> Self {
        self.sep();
        let _ = write!(self.buf, "\"{}\":{}", escape(k), v);
        self
    }
    pub fn raw(mut self, k: &str, v: &str) -> Self {
        self.sep();
        let _ = write!(self.buf, "\"{}\":{}", escape(k), v);
        self
    }
    pub fn finish(mut self) -> String {
        self.buf.push('}');
        self.buf
    }
}

impl Default for Obj {
    fn default() -> Self {
        Self::new()
    }
}

// ---------------------------------------------------------------------------
// parser (recursive descent; enough for manifests + our own logs)
// ---------------------------------------------------------------------------

pub fn parse(s: &str) -> anyhow::Result<Json> {
    let bytes = s.as_bytes();
    let mut pos = 0usize;
    let v = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        anyhow::bail!("trailing garbage at byte {pos}");
    }
    Ok(v)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> anyhow::Result<Json> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        anyhow::bail!("unexpected end of input");
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => lit(b, pos, "true", Json::Bool(true)),
        b'f' => lit(b, pos, "false", Json::Bool(false)),
        b'n' => lit(b, pos, "null", Json::Null),
        _ => parse_num(b, pos),
    }
}

fn lit(b: &[u8], pos: &mut usize, word: &str, v: Json) -> anyhow::Result<Json> {
    if b[*pos..].starts_with(word.as_bytes()) {
        *pos += word.len();
        Ok(v)
    } else {
        anyhow::bail!("bad literal at byte {pos}")
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> anyhow::Result<Json> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos])?;
    Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow::anyhow!("bad number {s:?}: {e}"))?))
}

fn parse_string(b: &[u8], pos: &mut usize) -> anyhow::Result<String> {
    if *pos >= b.len() || b[*pos] != b'"' {
        anyhow::bail!("expected string at byte {pos}");
    }
    *pos += 1;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    break;
                }
                match b[*pos] {
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'u' => {
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])?;
                        let code = u32::from_str_radix(hex, 16)?;
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        *pos += 4;
                    }
                    c => out.push(c as char),
                }
                *pos += 1;
            }
            _ => {
                // Copy one UTF-8 scalar.
                let s = std::str::from_utf8(&b[*pos..])?;
                let c = s.chars().next().unwrap();
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
    anyhow::bail!("unterminated string")
}

fn parse_arr(b: &[u8], pos: &mut usize) -> anyhow::Result<Json> {
    *pos += 1; // '['
    let mut out = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Json::Arr(out));
    }
    loop {
        out.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(out));
            }
            _ => anyhow::bail!("expected , or ] at byte {pos}"),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> anyhow::Result<Json> {
    *pos += 1; // '{'
    let mut out = BTreeMap::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Json::Obj(out));
    }
    loop {
        skip_ws(b, pos);
        if *pos >= b.len() {
            anyhow::bail!("unterminated object");
        }
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            anyhow::bail!("expected : at byte {pos}");
        }
        *pos += 1;
        out.insert(key, parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(out));
            }
            _ => anyhow::bail!("expected , or }} at byte {pos}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writer_roundtrips_through_parser() {
        let s = Obj::new()
            .num("x", 1.5)
            .int("n", -3)
            .str("name", "a\"b\\c\n")
            .bool("ok", true)
            .finish();
        let v = parse(&s).unwrap();
        assert_eq!(v.get("x").unwrap().as_f64(), Some(1.5));
        assert_eq!(v.get("n").unwrap().as_f64(), Some(-3.0));
        assert_eq!(v.get("name").unwrap().as_str(), Some("a\"b\\c\n"));
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)));
    }

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
          "name": "tiny", "n_params": 108480,
          "kv_shape": [2, 2, 4, 2, 96, 32],
          "artifacts": {"init": "init.hlo.txt"}
        }"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("tiny"));
        assert_eq!(v.get("n_params").unwrap().as_usize(), Some(108480));
        assert_eq!(v.get("kv_shape").unwrap().as_arr().unwrap().len(), 6);
        assert_eq!(
            v.get("artifacts").unwrap().get("init").unwrap().as_str(),
            Some("init.hlo.txt")
        );
    }

    #[test]
    fn parses_nested_arrays_and_nulls() {
        let v = parse("[1, [2, null], {\"a\": false}]").unwrap();
        let arr = v.as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[1].as_arr().unwrap()[1], Json::Null);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{} x").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn non_finite_numbers_become_null() {
        let s = Obj::new().num("bad", f64::NAN).finish();
        let v = parse(&s).unwrap();
        assert_eq!(v.get("bad"), Some(&Json::Null));
    }
}
