//! CoPRIS — Concurrency-Controlled Partial Rollout with Importance Sampling.
//!
//! Rust reproduction of Qu et al. (2025), structured as three layers:
//! this crate is L3 (the coordinator — the paper's contribution), executing
//! AOT-compiled JAX/Pallas artifacts (L2/L1) through the PJRT C API.
//! `docs/ARCHITECTURE.md` (repo root) is the narrative companion: the layer
//! map, the stage state machine, the trajectory/IS lifecycle, and where KV
//! retention slots fit.
//!
//! Module map (see DESIGN.md for the full inventory):
//! - [`util`], [`cli`], [`config`], [`testkit`], [`bench`] — substrates that
//!   the offline crate set forces us to hand-roll.
//! - [`runtime`], [`model`] — PJRT artifact loading + typed model calls.
//! - [`tokenizer`], [`tasks`], [`eval`] — the verifiable-reward math
//!   workload standing in for DeepScaleR + the five benchmark suites.
//! - [`engine`] — the vLLM stand-in: slot-based continuous batching with a
//!   KV budget, preemption/re-prefill (recomputation) accounting, and the
//!   KV-retention ledger for affinity-resumed partials.
//! - [`coordinator`] — **the paper**: concurrency-controlled generation,
//!   early termination, the partial-trajectory buffer with stage-tagged
//!   log-probs, prioritized resumption with affinity-aware resume routing;
//!   sync / naive-partial baselines.
//! - [`net`], [`router`] — the transport tier: framed std-only wire
//!   protocol, `copris engine-host` process mode, and the `RouterPool` +
//!   routing table that let the rollout fleet span processes with health
//!   checks, draining, and failover (local in-process transport default).
//! - [`trainer`] — GRPO with cross-stage importance-sampling correction.
//! - [`exp`] — experiment drivers regenerating every paper table & figure.
//! - [`loadgen`] — open-loop traffic generation (seeded Poisson/bursty
//!   arrivals, heavy-tailed tenant mixes, virtual clock) and the SLO
//!   scoreboard (TTFT/ITL percentiles, goodput, shed/preemption rates).
//!
//! `missing_docs` is enforced (warnings-as-errors under `scripts/ci.sh`'s
//! rustdoc gate) for the module trees this repo's doc pass covers —
//! [`coordinator`], [`engine`], [`trainer`], [`config`], [`loadgen`]; the
//! remaining modules are explicitly allowed below until their pass lands.

#![warn(missing_docs)]

#[allow(missing_docs)]
pub mod bench;
#[allow(missing_docs)]
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod engine;
#[allow(missing_docs)]
pub mod eval;
#[allow(missing_docs)]
pub mod exp;
pub mod loadgen;
#[allow(missing_docs)]
pub mod model;
pub mod net;
pub mod router;
#[allow(missing_docs)]
pub mod runtime;
#[allow(missing_docs)]
pub mod tasks;
#[allow(missing_docs)]
pub mod testkit;
#[allow(missing_docs)]
pub mod tokenizer;
pub mod trainer;
#[allow(missing_docs)]
pub mod util;
