//! CoPRIS — Concurrency-Controlled Partial Rollout with Importance Sampling.
//!
//! Rust reproduction of Qu et al. (2025), structured as three layers:
//! this crate is L3 (the coordinator — the paper's contribution), executing
//! AOT-compiled JAX/Pallas artifacts (L2/L1) through the PJRT C API.
//!
//! Module map (see DESIGN.md for the full inventory):
//! - [`util`], [`cli`], [`config`], [`testkit`], [`bench`] — substrates that
//!   the offline crate set forces us to hand-roll.
//! - [`runtime`], [`model`] — PJRT artifact loading + typed model calls.
//! - [`tokenizer`], [`tasks`], [`eval`] — the verifiable-reward math
//!   workload standing in for DeepScaleR + the five benchmark suites.
//! - [`engine`] — the vLLM stand-in: slot-based continuous batching with a
//!   KV budget and preemption/re-prefill (recomputation) accounting.
//! - [`coordinator`] — **the paper**: concurrency-controlled generation,
//!   early termination, the partial-trajectory buffer with stage-tagged
//!   log-probs, prioritized resumption; sync / naive-partial baselines.
//! - [`trainer`] — GRPO with cross-stage importance-sampling correction.
//! - [`exp`] — experiment drivers regenerating every paper table & figure.

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod eval;
pub mod exp;
pub mod model;
pub mod runtime;
pub mod tasks;
pub mod testkit;
pub mod tokenizer;
pub mod trainer;
pub mod util;
