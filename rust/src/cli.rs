//! Minimal CLI argument parser (clap is not in the vendored crate set).
//!
//! Grammar: `copris <subcommand> [--key value | --key=value | --flag] [pos]`.
//! Flags listed in `bool_flags` take no value; `--set section.key=value`
//! may repeat and maps onto `Config::set`.

use std::collections::{HashMap, HashSet};

use anyhow::{bail, Result};

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    values: HashMap<String, Vec<String>>,
    flags: HashSet<String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(args: I, bool_flags: &[&str]) -> Result<Args> {
        let bools: HashSet<&str> = bool_flags.iter().copied().collect();
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some(eq) = body.find('=') {
                    let (k, v) = (&body[..eq], &body[eq + 1..]);
                    out.values.entry(k.to_string()).or_default().push(v.to_string());
                } else if bools.contains(body) {
                    out.flags.insert(body.to_string());
                } else {
                    match it.next() {
                        Some(v) => {
                            out.values.entry(body.to_string()).or_default().push(v)
                        }
                        None => bail!("flag --{body} expects a value"),
                    }
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).and_then(|v| v.last()).map(String::as_str)
    }

    pub fn get_all(&self, key: &str) -> &[String] {
        self.values.get(key).map(Vec::as_slice).unwrap_or(&[])
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.contains(key)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from), &["verbose", "no-is"]).unwrap()
    }

    #[test]
    fn positional_and_values() {
        let a = parse("train --model small --steps 10 extra");
        assert_eq!(a.positional, vec!["train", "extra"]);
        assert_eq!(a.get("model"), Some("small"));
        assert_eq!(a.get_usize("steps", 0).unwrap(), 10);
    }

    #[test]
    fn equals_form_and_repeats() {
        let a = parse("x --set a.b=1 --set c.d=2");
        assert_eq!(a.get_all("set"), &["a.b=1".to_string(), "c.d=2".to_string()]);
    }

    #[test]
    fn bool_flags_take_no_value() {
        let a = parse("run --verbose --model tiny");
        assert!(a.flag("verbose"));
        assert_eq!(a.get("model"), Some("tiny"));
        assert!(!a.flag("no-is"));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(["--steps".to_string()], &[]).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = parse("t");
        assert_eq!(a.get_usize("steps", 7).unwrap(), 7);
        assert_eq!(a.get_f64("lr", 0.5).unwrap(), 0.5);
    }
}
