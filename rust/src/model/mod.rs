//! Typed model runtime: wraps the per-variant artifact set with shape-safe
//! calls and owns device-resident state (train state, engine state).
//!
//! One `ModelRuntime` per thread (Device is thread-confined); engines load
//! only {prefill, decode}, the trainer loads the rest — artifacts compile
//! lazily on first use.

pub mod state;

use std::collections::HashMap;
use std::path::Path;

use anyhow::{ensure, Context, Result};
use xla::PjRtBuffer;

use crate::runtime::{Device, Executable, Manifest};

pub use state::TrainState;

pub struct ModelRuntime {
    pub spec: Manifest,
    pub device: Device,
    exes: HashMap<&'static str, Executable>,
    /// Cached device uploads of small i32 scalars (slot ids, prompt
    /// lengths, chunk end offsets). These repeat from tiny bounded value
    /// sets — slot < S, len ≤ p_max — so each value is uploaded once and
    /// reused; PJRT input buffers are immutable and non-donated here,
    /// exactly like the long-lived `params` buffer. Replay `start`
    /// positions are deliberately NOT cached (cardinality up to max_seq
    /// would grow the cache unboundedly over a run).
    i32_cache: HashMap<i32, PjRtBuffer>,
    /// Reusable host staging for padded token rows (prefill/replay).
    pad_scratch: Vec<i32>,
    /// Reusable host copy of the logits header (prefill/replay row reads).
    hdr_scratch: Vec<f32>,
}

/// Metrics head of grad/sft_grad outputs (indices into the first 8 floats).
#[derive(Clone, Copy, Debug, Default)]
pub struct GradMetrics {
    pub loss_sum: f32,
    pub ent_sum: f32,
    pub ratio_sum: f32,
    pub ratio_max: f32,
    pub clip_sum: f32,
    pub kl_sum: f32,
    pub token_count: f32,
    pub grad_norm: f32,
}

impl GradMetrics {
    pub fn from_head(head: &[f32]) -> GradMetrics {
        GradMetrics {
            loss_sum: head[0],
            ent_sum: head[1],
            ratio_sum: head[2],
            ratio_max: head[3],
            clip_sum: head[4],
            kl_sum: head[5],
            token_count: head[6],
            grad_norm: head[7],
        }
    }

    /// SFT metrics layout: [loss_sum, token_count, grad_norm, 0...].
    pub fn from_sft_head(head: &[f32]) -> GradMetrics {
        GradMetrics {
            loss_sum: head[0],
            token_count: head[1],
            grad_norm: head[2],
            ..Default::default()
        }
    }
}

impl ModelRuntime {
    /// Load the manifest for `variant` under `artifacts_dir`.
    pub fn open(artifacts_dir: &str, variant: &str) -> Result<ModelRuntime> {
        let dir = Path::new(artifacts_dir).join(variant);
        let spec = Manifest::load(&dir)?;
        let device = Device::cpu()?;
        Ok(ModelRuntime {
            spec,
            device,
            exes: HashMap::new(),
            i32_cache: HashMap::new(),
            pad_scratch: Vec::new(),
            hdr_scratch: Vec::new(),
        })
    }

    /// Ensure the device upload of scalar `v` is cached (see `i32_cache`).
    fn ensure_i32(&mut self, v: i32) -> Result<()> {
        if !self.i32_cache.contains_key(&v) {
            let b = self.device.upload_i32(&[v])?;
            self.i32_cache.insert(v, b);
        }
        Ok(())
    }

    fn exe(&mut self, name: &'static str) -> Result<&Executable> {
        if !self.exes.contains_key(name) {
            let path = self.spec.artifact_path(name)?;
            let exe = self
                .device
                .load_hlo(&path)
                .with_context(|| format!("loading artifact {name}"))?;
            self.exes.insert(name, exe);
        }
        Ok(&self.exes[name])
    }

    /// Pre-compile a set of artifacts (so timing runs exclude compile cost).
    pub fn warmup(&mut self, names: &[&'static str]) -> Result<()> {
        for n in names {
            self.exe(n)?;
        }
        Ok(())
    }

    // -- init / weights -----------------------------------------------------

    /// Fresh train state f32[3N] from a seed.
    pub fn init_state(&mut self, seed: i32) -> Result<PjRtBuffer> {
        let seed_buf = self.device.upload_i32(&[seed])?;
        self.exe("init")?.run1(&[&seed_buf])
    }

    /// Host copy of the parameter vector (first N of the train state) —
    /// the weight-sync payload broadcast to engines after each update.
    /// Slices device-side (`read_params` artifact) so the Adam moments
    /// never cross to the host.
    pub fn params_to_host(&mut self, state: &PjRtBuffer) -> Result<Vec<f32>> {
        let n = self.spec.n_params;
        let p = self.exe("read_params")?.run1(&[state])?;
        self.device.read_all_f32(&p, n)
    }

    /// Upload a parameter vector received via weight sync.
    pub fn upload_params(&self, params: &[f32]) -> Result<PjRtBuffer> {
        ensure!(params.len() == self.spec.n_params, "bad params length");
        self.device.upload_f32(params)
    }

    /// Fresh zeroed engine state (logits header ++ KV cache).
    pub fn fresh_engine_state(&self) -> Result<PjRtBuffer> {
        self.device.zeros_f32(self.spec.engine_state_elems)
    }

    // -- rollout path --------------------------------------------------------

    /// Prefill `prompt` (≤ p_max tokens) into `slot`; returns the new engine
    /// state and the next-token logits for that slot.
    pub fn prefill(
        &mut self,
        params: &PjRtBuffer,
        engine_state: &PjRtBuffer,
        prompt: &[i32],
        slot: usize,
    ) -> Result<(PjRtBuffer, Vec<f32>)> {
        let pmax = self.spec.p_max;
        ensure!(!prompt.is_empty() && prompt.len() <= pmax, "prompt len {} > p_max {pmax}", prompt.len());
        ensure!(slot < self.spec.slots, "slot {slot} out of range");
        self.pad_scratch.clear();
        self.pad_scratch.resize(pmax, 0);
        self.pad_scratch[..prompt.len()].copy_from_slice(prompt);
        let toks = self.device.upload_i32(&self.pad_scratch)?;
        self.ensure_i32(prompt.len() as i32)?;
        self.ensure_i32(slot as i32)?;
        self.exe("prefill")?;
        let out = {
            let exe = &self.exes["prefill"];
            let len = &self.i32_cache[&(prompt.len() as i32)];
            let slot_b = &self.i32_cache[&(slot as i32)];
            exe.run1(&[params, engine_state, &toks, len, slot_b])?
        };
        let v = self.spec.vocab;
        // The read_header artifact returns the full S×V header — PJRT-CPU
        // has no partial host reads (see Device::read_all_f32), so idle
        // rows come along; only the requested row is copied out.
        self.read_header_scratch(&out)?;
        let logits = self.hdr_scratch[slot * v..(slot + 1) * v].to_vec();
        Ok((out, logits))
    }

    /// One decode step over all S slots; returns (engine state, logits S×V).
    /// Cold-path convenience — per-step callers use `decode_into`.
    pub fn decode(
        &mut self,
        params: &PjRtBuffer,
        engine_state: &PjRtBuffer,
        tokens: &[i32],
        pos: &[i32],
    ) -> Result<(PjRtBuffer, Vec<f32>)> {
        let mut logits = Vec::new();
        let es = self.decode_into(params, engine_state, tokens, pos, &mut logits)?;
        Ok((es, logits))
    }

    /// One decode step writing the S×V logits into a caller-owned buffer
    /// reused across steps; returns the new engine state.
    ///
    /// PJRT 0.5.1 exposes no host→device in-place write, so the token/pos
    /// rows still pass through `buffer_from_host_buffer` each step — what
    /// this path eliminates is the per-step host churn: the logits Vec
    /// (S×V floats) is reused instead of reallocated, and small scalar
    /// arguments elsewhere in the rollout path come from `i32_cache`.
    pub fn decode_into(
        &mut self,
        params: &PjRtBuffer,
        engine_state: &PjRtBuffer,
        tokens: &[i32],
        pos: &[i32],
        logits: &mut Vec<f32>,
    ) -> Result<PjRtBuffer> {
        let s = self.spec.slots;
        ensure!(tokens.len() == s && pos.len() == s, "decode arg length");
        let t = self.device.upload_i32(tokens)?;
        let p = self.device.upload_i32(pos)?;
        let out = self.exe("decode")?.run1(&[params, engine_state, &t, &p])?;
        let h = self.exe("read_header")?.run1(&[&out])?;
        self.device.read_all_f32_into(&h, self.spec.header_elems(), logits)?;
        Ok(out)
    }

    /// Chunked re-prefill of resume tokens for one slot (≤ p_max per call;
    /// caller guarantees start + p_max ≤ max_seq — see replay_artifact).
    /// Returns the new engine state and the logits after the last real
    /// token (chunk index `n-1`).
    pub fn replay(
        &mut self,
        params: &PjRtBuffer,
        engine_state: &PjRtBuffer,
        chunk: &[i32],
        start: usize,
        slot: usize,
    ) -> Result<(PjRtBuffer, Vec<f32>)> {
        let pmax = self.spec.p_max;
        ensure!(!chunk.is_empty() && chunk.len() <= pmax, "replay chunk size");
        ensure!(start + pmax <= self.spec.max_seq, "replay too close to horizon");
        let n = chunk.len();
        self.pad_scratch.clear();
        self.pad_scratch.resize(pmax, 0);
        self.pad_scratch[..n].copy_from_slice(chunk);
        let toks = self.device.upload_i32(&self.pad_scratch)?;
        // `start` is uploaded fresh: its value set spans max_seq (see
        // i32_cache docs), and replay only runs at partial-resumption
        // admits — not the per-step hot path.
        let start_b = self.device.upload_i32(&[start as i32])?;
        self.ensure_i32(slot as i32)?;
        self.ensure_i32((n - 1) as i32)?;
        self.exe("replay")?;
        let out = {
            let exe = &self.exes["replay"];
            let slot_b = &self.i32_cache[&(slot as i32)];
            let last_b = &self.i32_cache[&((n - 1) as i32)];
            exe.run1(&[params, engine_state, &toks, &start_b, slot_b, last_b])?
        };
        let v = self.spec.vocab;
        self.read_header_scratch(&out)?;
        let logits = self.hdr_scratch[slot * v..(slot + 1) * v].to_vec();
        Ok((out, logits))
    }

    // -- training path -------------------------------------------------------

    /// Per-token log-probs + entropies under the current policy.
    /// `tokens` is a row-major [B, T] batch; returns (lp, ent), each
    /// row-major [B, T-1].
    pub fn logprob(&mut self, state: &PjRtBuffer, tokens: &[i32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let (b, t) = (self.spec.b_micro, self.spec.t_train);
        ensure!(tokens.len() == b * t, "logprob batch shape");
        let tb = self.device.upload_i32_2d(tokens, b, t)?;
        let out = self.exe("logprob")?.run1(&[state, &tb])?;
        let n = b * (t - 1);
        let all = self.device.read_all_f32(&out, 2 * n)?;
        Ok((all[..n].to_vec(), all[n..].to_vec()))
    }

    /// GRPO gradient over one microbatch. Returns the [8+N] grad buffer
    /// (device-resident) and the host metrics head.
    pub fn grad(
        &mut self,
        state: &PjRtBuffer,
        tokens: &[i32],
        resp_mask: &[f32],
        behav_lp: &[f32],
        adv: &[f32],
    ) -> Result<(PjRtBuffer, GradMetrics)> {
        let (b, t) = (self.spec.b_micro, self.spec.t_train);
        ensure!(tokens.len() == b * t, "grad tokens shape");
        ensure!(resp_mask.len() == b * (t - 1), "grad mask shape");
        ensure!(behav_lp.len() == b * (t - 1), "grad behav_lp shape");
        ensure!(adv.len() == b, "grad adv shape");
        let tb = self.device.upload_i32_2d(tokens, b, t)?;
        let mb = self.device.upload_f32_2d(resp_mask, b, t - 1)?;
        let lb = self.device.upload_f32_2d(behav_lp, b, t - 1)?;
        let ab = self.device.upload_f32(adv)?;
        let out = self.exe("grad")?.run1(&[state, &tb, &mb, &lb, &ab])?;
        let head = self.read_metrics(&out)?;
        Ok((out, GradMetrics::from_head(&head)))
    }

    /// SFT gradient over one microbatch (same output packing as `grad`).
    pub fn sft_grad(
        &mut self,
        state: &PjRtBuffer,
        tokens: &[i32],
        resp_mask: &[f32],
    ) -> Result<(PjRtBuffer, GradMetrics)> {
        let (b, t) = (self.spec.b_micro, self.spec.t_train);
        ensure!(tokens.len() == b * t && resp_mask.len() == b * (t - 1), "sft shapes");
        let tb = self.device.upload_i32_2d(tokens, b, t)?;
        let mb = self.device.upload_f32_2d(resp_mask, b, t - 1)?;
        let out = self.exe("sft_grad")?.run1(&[state, &tb, &mb])?;
        let head = self.read_metrics(&out)?;
        Ok((out, GradMetrics::from_sft_head(&head)))
    }

    /// Device-side slice reads (CopyRawToHost is unavailable on PJRT-CPU).
    /// The header lands in `hdr_scratch`, reused across calls.
    fn read_header_scratch(&mut self, engine_state: &PjRtBuffer) -> Result<()> {
        let h = self.exe("read_header")?.run1(&[engine_state])?;
        self.device.read_all_f32_into(&h, self.spec.header_elems(), &mut self.hdr_scratch)
    }

    fn read_metrics(&mut self, grads: &PjRtBuffer) -> Result<Vec<f32>> {
        let m = self.exe("read_metrics")?.run1(&[grads])?;
        self.device.read_all_f32(&m, self.spec.n_metrics)
    }

    /// a + scale·b over [8+N] grad buffers (device-side accumulation).
    pub fn accum(&mut self, a: &PjRtBuffer, b: &PjRtBuffer, scale: f32) -> Result<PjRtBuffer> {
        let s = self.device.upload_f32(&[scale])?;
        self.exe("accum")?.run1(&[a, b, &s])
    }

    /// Adam update: `grad_scale` should be 1/total_masked_tokens so the
    /// accumulated token-sum gradients become an exact token-mean step.
    pub fn update(
        &mut self,
        state: &PjRtBuffer,
        grads: &PjRtBuffer,
        step: i32,
        lr: f32,
        grad_scale: f32,
    ) -> Result<PjRtBuffer> {
        let sb = self.device.upload_i32(&[step])?;
        let lrb = self.device.upload_f32(&[lr])?;
        let gs = self.device.upload_f32(&[grad_scale])?;
        self.exe("update")?.run1(&[state, grads, &sb, &lrb, &gs])
    }
}
