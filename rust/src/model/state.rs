//! Device-resident training state (params ++ adam m ++ adam v) with
//! checkpoint save/load as raw little-endian f32 files.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{ensure, Context, Result};
use xla::PjRtBuffer;

use super::ModelRuntime;

/// Owns the packed train-state buffer plus the optimizer step counter.
pub struct TrainState {
    pub buffer: PjRtBuffer,
    pub step: i32,
}

impl TrainState {
    pub fn init(rt: &mut ModelRuntime, seed: i32) -> Result<TrainState> {
        Ok(TrainState { buffer: rt.init_state(seed)?, step: 0 })
    }

    /// Apply an accumulated gradient buffer (metrics head ++ grads).
    pub fn apply_update(
        &mut self,
        rt: &mut ModelRuntime,
        grads: &PjRtBuffer,
        lr: f32,
        grad_scale: f32,
    ) -> Result<()> {
        self.step += 1;
        self.buffer = rt.update(&self.buffer, grads, self.step, lr, grad_scale)?;
        Ok(())
    }

    /// Serialize the full 3N state + step to `path` (raw LE f32 + header).
    pub fn save(&self, rt: &mut ModelRuntime, path: &Path) -> Result<()> {
        let n = rt.spec.state_elems;
        let data = rt.device.read_all_f32(&self.buffer, n)?;
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        f.write_all(b"CPRS")?;
        f.write_all(&(self.step as u32).to_le_bytes())?;
        f.write_all(&(n as u64).to_le_bytes())?;
        for x in &data {
            f.write_all(&x.to_le_bytes())?;
        }
        Ok(())
    }

    /// Load a checkpoint written by `save`.
    pub fn load(rt: &mut ModelRuntime, path: &Path) -> Result<TrainState> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        ensure!(&magic == b"CPRS", "bad checkpoint magic");
        let mut b4 = [0u8; 4];
        f.read_exact(&mut b4)?;
        let step = u32::from_le_bytes(b4) as i32;
        let mut b8 = [0u8; 8];
        f.read_exact(&mut b8)?;
        let n = u64::from_le_bytes(b8) as usize;
        ensure!(n == rt.spec.state_elems, "checkpoint size {n} != spec {}", rt.spec.state_elems);
        let mut bytes = vec![0u8; n * 4];
        f.read_exact(&mut bytes)?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let buffer = rt.device.upload_f32(&data)?;
        Ok(TrainState { buffer, step })
    }
}
