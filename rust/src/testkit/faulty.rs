//! Deterministic fault injection for the engine/coordinator recovery
//! paths: [`FaultyBackend`] wraps any [`Backend`] and fires scripted
//! faults — fatal error, transient-then-recover, panic, stall — on the
//! Nth call of a given operation. Faults are keyed off per-op call
//! counters (and the test's deterministic RNG chooses the script), so a
//! failing chaos run reproduces bit-exactly.
//!
//! Faults fire BEFORE delegating to the inner backend, so a faulted call
//! leaves the inner backend's state untouched — a supervisor retry of the
//! same step re-runs against identical state and produces the identical
//! token stream (the property the transient-retry path depends on).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::engine::{Backend, BackendError};

/// Which backend operation a fault plan targets.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultOp {
    /// `decode` / `decode_into` (the per-step hot path).
    Decode,
    /// `prefill` / `prefill_chunk` (admission).
    Prefill,
    /// `replay` (chunked resume recompute).
    Replay,
    /// `retain_slot` (KV retention at flush).
    RetainSlot,
}

/// What happens when a plan fires.
#[derive(Clone, Copy, Debug)]
pub enum FaultKind {
    /// `BackendError::Fatal` — the engine fails immediately.
    Fatal,
    /// `BackendError::Transient` on `times` consecutive calls starting at
    /// `at_call`, then the op succeeds — exercises the in-place retry.
    Transient {
        /// Consecutive faulted calls before recovery.
        times: usize,
    },
    /// `panic!` — exercises the supervisor's `catch_unwind` path.
    Panic,
    /// Sleep this long, then proceed normally — exercises the
    /// coordinator's stall watchdog (the engine "wakes up" later and its
    /// late events must be discarded).
    Stall {
        /// Sleep duration in milliseconds.
        ms: u64,
    },
}

/// One scripted fault: fire `kind` on the `at_call`-th call (1-based) of
/// `op`, counted across the backend's lifetime.
#[derive(Clone, Copy, Debug)]
pub struct FaultPlan {
    /// Targeted operation.
    pub op: FaultOp,
    /// 1-based call number of `op` on which the fault fires.
    pub at_call: usize,
    /// Fault behaviour.
    pub kind: FaultKind,
}

/// A [`Backend`] wrapper that injects the scripted [`FaultPlan`]s and
/// delegates everything else unchanged.
pub struct FaultyBackend<B> {
    inner: B,
    plans: Vec<FaultPlan>,
    /// Per-op call counters, indexed by `FaultOp as usize`.
    counts: [usize; 4],
    injected: Arc<AtomicUsize>,
}

impl<B: Backend> FaultyBackend<B> {
    /// Wrap `inner` with the given fault script.
    pub fn new(inner: B, plans: Vec<FaultPlan>) -> FaultyBackend<B> {
        FaultyBackend { inner, plans, counts: [0; 4], injected: Arc::new(AtomicUsize::new(0)) }
    }

    /// Shared counter of faults actually fired (stalls included) —
    /// clone it before moving the backend into an engine thread to assert
    /// the script really ran.
    pub fn injected_handle(&self) -> Arc<AtomicUsize> {
        self.injected.clone()
    }

    /// Count one call of `op` and fire any matching plan. Runs before the
    /// delegate call so faulted calls never touch inner state.
    fn check(&mut self, op: FaultOp) -> Result<()> {
        let idx = op as usize;
        self.counts[idx] += 1;
        let n = self.counts[idx];
        for p in &self.plans {
            if p.op != op {
                continue;
            }
            match p.kind {
                FaultKind::Fatal if n == p.at_call => {
                    self.injected.fetch_add(1, Ordering::SeqCst);
                    return Err(anyhow::Error::new(BackendError::Fatal(format!(
                        "injected fatal fault on {op:?} call {n}"
                    ))));
                }
                FaultKind::Transient { times } if n >= p.at_call && n < p.at_call + times => {
                    self.injected.fetch_add(1, Ordering::SeqCst);
                    return Err(anyhow::Error::new(BackendError::Transient(format!(
                        "injected transient fault on {op:?} call {n}"
                    ))));
                }
                FaultKind::Panic if n == p.at_call => {
                    self.injected.fetch_add(1, Ordering::SeqCst);
                    panic!("injected panic on {op:?} call {n}");
                }
                FaultKind::Stall { ms } if n == p.at_call => {
                    self.injected.fetch_add(1, Ordering::SeqCst);
                    std::thread::sleep(Duration::from_millis(ms));
                }
                _ => {}
            }
        }
        Ok(())
    }
}

impl<B: Backend> Backend for FaultyBackend<B> {
    fn slots(&self) -> usize {
        self.inner.slots()
    }
    fn vocab(&self) -> usize {
        self.inner.vocab()
    }
    fn max_seq(&self) -> usize {
        self.inner.max_seq()
    }
    fn p_max(&self) -> usize {
        self.inner.p_max()
    }

    fn set_params(&mut self, params: &[f32]) -> Result<()> {
        self.inner.set_params(params)
    }

    fn prefill(&mut self, slot: usize, prompt: &[i32]) -> Result<Vec<f32>> {
        self.check(FaultOp::Prefill)?;
        self.inner.prefill(slot, prompt)
    }

    fn prefill_chunk(
        &mut self,
        slot: usize,
        chunk: &[i32],
        start: usize,
        last: bool,
    ) -> Result<Option<Vec<f32>>> {
        self.check(FaultOp::Prefill)?;
        self.inner.prefill_chunk(slot, chunk, start, last)
    }

    fn decode(&mut self, tokens: &[i32], pos: &[i32]) -> Result<Vec<f32>> {
        self.check(FaultOp::Decode)?;
        self.inner.decode(tokens, pos)
    }

    fn decode_into(&mut self, tokens: &[i32], pos: &[i32], out: &mut Vec<f32>) -> Result<()> {
        self.check(FaultOp::Decode)?;
        self.inner.decode_into(tokens, pos, out)
    }

    fn replay(&mut self, slot: usize, chunk: &[i32], start: usize) -> Result<Option<Vec<f32>>> {
        self.check(FaultOp::Replay)?;
        self.inner.replay(slot, chunk, start)
    }

    fn retain_slot(&mut self, slot: usize) -> Result<bool> {
        self.check(FaultOp::RetainSlot)?;
        self.inner.retain_slot(slot)
    }

    fn resume_retained(&mut self, slot: usize) -> Result<()> {
        self.inner.resume_retained(slot)
    }

    fn release_retained(&mut self, slot: usize) -> Result<()> {
        self.inner.release_retained(slot)
    }

    fn set_block_table(
        &mut self,
        slot: usize,
        blocks: &[u32],
        len_tokens: usize,
        block_size: usize,
    ) -> Result<()> {
        self.inner.set_block_table(slot, blocks, len_tokens, block_size)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::MockBackend;

    #[test]
    fn faults_fire_on_scripted_calls_only() {
        let mut b = FaultyBackend::new(
            MockBackend::new(2, 96),
            vec![
                FaultPlan { op: FaultOp::Decode, at_call: 2, kind: FaultKind::Fatal },
                FaultPlan {
                    op: FaultOp::Prefill,
                    at_call: 1,
                    kind: FaultKind::Transient { times: 2 },
                },
            ],
        );
        let injected = b.injected_handle();
        // Prefill call 1 and 2 are transient, 3 succeeds.
        let e1 = b.prefill(0, &[1, 5, 9]).unwrap_err();
        assert!(crate::engine::is_transient(&e1));
        let e2 = b.prefill(0, &[1, 5, 9]).unwrap_err();
        assert!(crate::engine::is_transient(&e2));
        b.prefill(0, &[1, 5, 9]).unwrap();
        // Decode call 1 is clean, call 2 fatal, call 3 clean again.
        let toks = vec![5i32; 2];
        let pos = vec![3i32; 2];
        b.decode(&toks, &pos).unwrap();
        let e = b.decode(&toks, &pos).unwrap_err();
        assert!(!crate::engine::is_transient(&e));
        assert!(e.to_string().contains("fatal"), "{e:#}");
        b.decode(&toks, &pos).unwrap();
        assert_eq!(injected.load(Ordering::SeqCst), 3);
    }

    /// A faulted call must not advance inner backend state: the retry
    /// after a transient decode fault yields exactly the logits the
    /// un-faulted call would have produced.
    #[test]
    fn faulted_calls_leave_inner_state_untouched() {
        let mut clean = MockBackend::new(1, 96);
        clean.prefill(0, &[1, 5, 9]).unwrap();
        let mut faulty = FaultyBackend::new(
            MockBackend::new(1, 96),
            vec![FaultPlan {
                op: FaultOp::Decode,
                at_call: 1,
                kind: FaultKind::Transient { times: 1 },
            }],
        );
        faulty.prefill(0, &[1, 5, 9]).unwrap();
        let toks = vec![5i32];
        let pos = vec![3i32];
        let want = clean.decode(&toks, &pos).unwrap();
        assert!(faulty.decode(&toks, &pos).is_err());
        let got = faulty.decode(&toks, &pos).unwrap(); // the retry
        assert_eq!(want, got, "retry after fault must be bit-identical");
    }
}
