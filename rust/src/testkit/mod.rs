//! Property-testing mini-framework (proptest is not in the vendored crate
//! set) + shared test helpers.
//!
//! `prop_check` runs `cases` random trials from a seeded generator; on
//! failure it reports the case index and root seed so the run is exactly
//! reproducible (override via env `COPRIS_PROP_SEED`).

use crate::util::Rng;

pub mod faulty;

/// Number of cases per property (kept modest; engines are in the loop).
pub const DEFAULT_CASES: usize = 64;

/// Run a property over generated inputs; panics with a reproducible report
/// on the first failure.
pub fn prop_check<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    generate: impl Fn(&mut Rng) -> T,
    check: impl Fn(&T) -> Result<(), String>,
) {
    let seed = std::env::var("COPRIS_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEEu64);
    let mut root = Rng::new(seed);
    for case in 0..cases {
        let mut rng = root.fork(case as u64);
        let input = generate(&mut rng);
        if let Err(msg) = check(&input) {
            panic!(
                "property {name:?} failed at case {case}/{cases} (seed {seed}):\n  \
                 input: {input:?}\n  {msg}\n  \
                 reproduce with COPRIS_PROP_SEED={seed}"
            );
        }
    }
}

/// Convenience assertion helpers returning Result<(), String>.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {}  ({a:?} vs {b:?})",
                stringify!($a),
                stringify!($b)
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        let counter = std::cell::Cell::new(0usize);
        prop_check(
            "sum-commutes",
            32,
            |rng| (rng.range_i64(-100, 100), rng.range_i64(-100, 100)),
            |(a, b)| {
                counter.set(counter.get() + 1);
                if a + b == b + a { Ok(()) } else { Err("math broke".into()) }
            },
        );
        count += counter.get();
        assert_eq!(count, 32);
    }

    #[test]
    #[should_panic(expected = "property \"always-fails\"")]
    fn failing_property_panics_with_seed() {
        prop_check("always-fails", 4, |rng| rng.below(10), |_| Err("nope".into()));
    }

    #[test]
    fn generation_is_deterministic_across_runs() {
        let collect = || {
            let mut v = Vec::new();
            prop_check(
                "collect",
                8,
                |rng| rng.next_u64(),
                |x| {
                    // Properties must not mutate, so we copy out via ptr trick:
                    // simplest is to recompute; here we just check determinism
                    // by re-deriving in the second closure call.
                    let _ = x;
                    Ok(())
                },
            );
            // Re-derive the same stream manually.
            let mut root = Rng::new(0xC0FFEE);
            for case in 0..8u64 {
                let mut rng = root.fork(case);
                v.push(rng.next_u64());
            }
            v
        };
        assert_eq!(collect(), collect());
    }
}
