//! Procedural task families with verifiable answers.
//!
//! Response length varies a lot across families/levels (Countdown answers
//! grow linearly with the operand) — that heterogeneity is what produces
//! the paper's Fig-1 long-tail rollout distribution on this substrate.

use crate::util::Rng;

/// A generated problem instance.
#[derive(Clone, Debug, PartialEq)]
pub struct Task {
    pub family: Family,
    pub level: u8,
    pub prompt: String,
    pub answer: String,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Family {
    /// "12+7+30=" → "49"  (multi-operand addition/subtraction)
    AddChain,
    /// "(13*7+5)%10=" → "1"  (modular arithmetic)
    ModArith,
    /// "c12>" → "12 11 10 ... 0"  (count down; long variable-length answers)
    Countdown,
    /// "r1234=" → "4321"  (string reversal)
    Reverse,
    /// "m17,25=" → "25"  (maximum of a list)
    MaxList,
}

impl Family {
    pub const ALL: [Family; 5] = [
        Family::AddChain,
        Family::ModArith,
        Family::Countdown,
        Family::Reverse,
        Family::MaxList,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Family::AddChain => "add_chain",
            Family::ModArith => "mod_arith",
            Family::Countdown => "countdown",
            Family::Reverse => "reverse",
            Family::MaxList => "max_list",
        }
    }

    /// Generate one instance at `level` (0 = easiest).
    pub fn generate(&self, rng: &mut Rng, level: u8) -> Task {
        let lv = level as i64;
        match self {
            Family::AddChain => {
                let terms = 2 + lv.min(3);
                let hi = [9, 20, 50, 99][level.min(3) as usize];
                let mut vals = Vec::new();
                let mut expr = String::new();
                let mut total: i64 = 0;
                for i in 0..terms {
                    let v = rng.range_i64(0, hi);
                    let sub = i > 0 && rng.next_f64() < 0.3 && total - v >= 0;
                    if i == 0 {
                        expr.push_str(&v.to_string());
                        total = v;
                    } else if sub {
                        expr.push('-');
                        expr.push_str(&v.to_string());
                        total -= v;
                    } else {
                        expr.push('+');
                        expr.push_str(&v.to_string());
                        total += v;
                    }
                    vals.push(v);
                }
                expr.push('=');
                Task { family: *self, level, prompt: expr, answer: total.to_string() }
            }
            Family::ModArith => {
                let hi = [9, 15, 30, 60][level.min(3) as usize];
                let a = rng.range_i64(1, hi);
                let b = rng.range_i64(1, hi.min(12));
                let c = rng.range_i64(0, hi);
                let m = rng.range_i64(2, 10);
                let val = (a * b + c).rem_euclid(m);
                Task {
                    family: *self,
                    level,
                    prompt: format!("({a}*{b}+{c})%{m}="),
                    answer: val.to_string(),
                }
            }
            Family::Countdown => {
                // Deep levels produce long answers — the dominant source of
                // the Fig-1 long-tail length heterogeneity on this substrate.
                let hi = [5, 9, 14, 22, 32, 44][level.min(5) as usize];
                let start = rng.range_i64(2, hi);
                let answer =
                    (0..=start).rev().map(|i| i.to_string()).collect::<Vec<_>>().join(" ");
                Task { family: *self, level, prompt: format!("c{start}>"), answer }
            }
            Family::Reverse => {
                let len = [3, 4, 6, 8][level.min(3) as usize];
                let digits: String =
                    (0..len).map(|_| char::from(b'0' + rng.below(10) as u8)).collect();
                let answer: String = digits.chars().rev().collect();
                Task { family: *self, level, prompt: format!("r{digits}="), answer }
            }
            Family::MaxList => {
                let n = 2 + (lv / 2).min(2);
                let hi = [9, 30, 99, 99][level.min(3) as usize];
                let vals: Vec<i64> = (0..n).map(|_| rng.range_i64(0, hi)).collect();
                let answer = vals.iter().max().unwrap().to_string();
                let list = vals.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(",");
                Task { family: *self, level, prompt: format!("m{list}="), answer }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tokenizer::Tokenizer;

    fn check_family(f: Family) {
        let mut rng = Rng::new(1);
        let tk = Tokenizer::new();
        for level in 0..4u8 {
            for _ in 0..50 {
                let t = f.generate(&mut rng, level);
                assert!(!t.prompt.is_empty() && !t.answer.is_empty());
                // Everything must round-trip through the tokenizer.
                assert_eq!(tk.decode(&tk.encode(&t.prompt)), t.prompt, "{t:?}");
                assert_eq!(tk.decode(&tk.encode(&t.answer)), t.answer, "{t:?}");
            }
        }
    }

    #[test]
    fn all_families_tokenizable() {
        for f in Family::ALL {
            check_family(f);
        }
    }

    #[test]
    fn add_chain_answers_are_correct_sums() {
        let mut rng = Rng::new(2);
        for _ in 0..100 {
            let t = Family::AddChain.generate(&mut rng, 2);
            // Re-evaluate the expression left to right.
            let expr = t.prompt.trim_end_matches('=');
            let mut total = 0i64;
            let mut num = String::new();
            let mut sign = 1i64;
            for c in expr.chars().chain(std::iter::once('+')) {
                if c.is_ascii_digit() {
                    num.push(c);
                } else {
                    total += sign * num.parse::<i64>().unwrap();
                    num.clear();
                    sign = if c == '-' { -1 } else { 1 };
                }
            }
            assert_eq!(total.to_string(), t.answer, "{}", t.prompt);
        }
    }

    #[test]
    fn mod_arith_in_range() {
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let t = Family::ModArith.generate(&mut rng, 3);
            let v: i64 = t.answer.parse().unwrap();
            assert!((0..10).contains(&v));
        }
    }

    #[test]
    fn countdown_lengths_grow_with_level() {
        let mut rng = Rng::new(4);
        let mean_len = |level: u8, rng: &mut Rng| -> f64 {
            (0..100)
                .map(|_| Family::Countdown.generate(rng, level).answer.len())
                .sum::<usize>() as f64
                / 100.0
        };
        let l0 = mean_len(0, &mut rng);
        let l3 = mean_len(3, &mut rng);
        assert!(l3 > l0 * 1.5, "length heterogeneity missing: {l0} vs {l3}");
    }

    #[test]
    fn countdown_is_correct_sequence() {
        let mut rng = Rng::new(5);
        let t = Family::Countdown.generate(&mut rng, 1);
        let start: i64 = t.prompt[1..t.prompt.len() - 1].parse().unwrap();
        let parts: Vec<i64> =
            t.answer.split(' ').map(|s| s.parse().unwrap()).collect();
        assert_eq!(parts[0], start);
        assert_eq!(*parts.last().unwrap(), 0);
        for w in parts.windows(2) {
            assert_eq!(w[0] - 1, w[1]);
        }
    }

    #[test]
    fn reverse_is_involution() {
        let mut rng = Rng::new(6);
        for _ in 0..50 {
            let t = Family::Reverse.generate(&mut rng, 2);
            let digits = &t.prompt[1..t.prompt.len() - 1];
            let rev: String = t.answer.chars().rev().collect();
            assert_eq!(digits, rev);
        }
    }

    #[test]
    fn max_list_is_max() {
        let mut rng = Rng::new(7);
        for _ in 0..50 {
            let t = Family::MaxList.generate(&mut rng, 3);
            let list = &t.prompt[1..t.prompt.len() - 1];
            let max = list.split(',').map(|s| s.parse::<i64>().unwrap()).max().unwrap();
            assert_eq!(max.to_string(), t.answer);
        }
    }

    #[test]
    fn generation_is_deterministic_per_seed() {
        let t1 = Family::ModArith.generate(&mut Rng::new(9), 1);
        let t2 = Family::ModArith.generate(&mut Rng::new(9), 1);
        assert_eq!(t1, t2);
    }
}
