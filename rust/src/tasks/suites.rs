//! The five held-out evaluation suites standing in for the paper's
//! AIME24 / AIME25 / AMC / MinervaMath / OlympiadBench.
//!
//! Each suite fixes a family + level band and a seed space disjoint from
//! training (`Dataset` uses xor-tagged seeds), so suite prompts are never
//! seen during RL.

use super::families::{Family, Task};
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct Suite {
    /// Paper benchmark this stands in for.
    pub name: &'static str,
    pub family: Family,
    pub levels: Vec<u8>,
    seed_tag: u64,
}

impl Suite {
    /// Deterministic prompt set of size `n`.
    pub fn tasks(&self, n: usize, seed: u64) -> Vec<Task> {
        let mut rng = Rng::new(seed ^ self.seed_tag ^ 0xe7a1_5u64);
        (0..n)
            .map(|_| {
                let l = self.levels[rng.below(self.levels.len() as u64) as usize];
                self.family.generate(&mut rng, l)
            })
            .collect()
    }
}

/// The five suites, difficulty-ordered like the paper's benchmarks
/// (AIME hardest → AMC/Minerva medium → Olympiad long-form).
pub fn eval_suites() -> Vec<Suite> {
    vec![
        Suite { name: "AIME24*", family: Family::ModArith, levels: vec![2, 3], seed_tag: 0xa124 },
        Suite { name: "AIME25*", family: Family::AddChain, levels: vec![2, 3], seed_tag: 0xa125 },
        Suite { name: "AMC*", family: Family::MaxList, levels: vec![1, 2], seed_tag: 0xacc },
        Suite { name: "Minerva*", family: Family::Reverse, levels: vec![1, 2], seed_tag: 0x31e6 },
        Suite { name: "Olympiad*", family: Family::Countdown, levels: vec![1, 2, 3], seed_tag: 0x01b1 },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn five_suites_with_unique_names_and_families() {
        let suites = eval_suites();
        assert_eq!(suites.len(), 5);
        let names: std::collections::HashSet<_> = suites.iter().map(|s| s.name).collect();
        assert_eq!(names.len(), 5);
        let fams: std::collections::HashSet<_> = suites.iter().map(|s| s.family).collect();
        assert_eq!(fams.len(), 5);
    }

    #[test]
    fn suite_tasks_deterministic() {
        let s = &eval_suites()[0];
        assert_eq!(s.tasks(10, 7), s.tasks(10, 7));
        assert_ne!(s.tasks(10, 7), s.tasks(10, 8));
    }

    #[test]
    fn suites_disjoint_from_each_other() {
        let suites = eval_suites();
        let a = suites[0].tasks(10, 7);
        let b = suites[1].tasks(10, 7);
        assert_ne!(a, b);
    }

    #[test]
    fn suite_levels_respected() {
        for s in eval_suites() {
            for t in s.tasks(30, 1) {
                assert!(s.levels.contains(&t.level), "{} level {}", s.name, t.level);
            }
        }
    }
}
