//! Procedural training dataset (the DeepScaleR-Preview stand-in):
//! an infinite, seeded stream of mixed-family tasks at training levels.

use super::families::{Family, Task};
use crate::util::Rng;

/// Seeded task stream. Train and eval use disjoint seed spaces so eval
/// suites are held out by construction.
#[derive(Clone, Debug)]
pub struct Dataset {
    rng: Rng,
    families: Vec<Family>,
    levels: Vec<u8>,
    served: usize,
}

impl Dataset {
    /// Training mixture: all families with Countdown over-weighted (its
    /// long answers reproduce the paper's rollout-dominant regime and the
    /// long-tail length distribution), levels 0..=3.
    pub fn train(seed: u64) -> Dataset {
        let mut families = Family::ALL.to_vec();
        families.extend([Family::Countdown, Family::Countdown]);
        Dataset {
            rng: Rng::new(seed ^ 0x7261_696e), // "rain" tag: train stream
            families,
            levels: vec![0, 1, 2, 3],
            served: 0,
        }
    }

    /// SFT warmup mixture: easy/medium levels with Countdown emphasized so
    /// the warmed policy LEARNS to emit long sequences — without this the
    /// basemodel answers in 1-3 tokens and the rollout stage degenerates
    /// (no long tail, no rollout-dominant regime to accelerate).
    pub fn sft(seed: u64) -> Dataset {
        let mut families = Family::ALL.to_vec();
        families.extend([Family::Countdown, Family::Countdown]);
        Dataset {
            rng: Rng::new(seed ^ 0x5f73_6674),
            families,
            levels: vec![0, 1, 2],
            served: 0,
        }
    }

    /// Custom mixture.
    pub fn with(seed: u64, families: Vec<Family>, levels: Vec<u8>) -> Dataset {
        assert!(!families.is_empty() && !levels.is_empty());
        Dataset { rng: Rng::new(seed), families, levels, served: 0 }
    }

    pub fn next_task(&mut self) -> Task {
        let f = self.families[self.rng.below(self.families.len() as u64) as usize];
        let l = self.levels[self.rng.below(self.levels.len() as u64) as usize];
        self.served += 1;
        f.generate(&mut self.rng, l)
    }

    pub fn batch(&mut self, n: usize) -> Vec<Task> {
        (0..n).map(|_| self.next_task()).collect()
    }

    pub fn served(&self) -> usize {
        self.served
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let a: Vec<_> = Dataset::train(1).batch(20);
        let b: Vec<_> = Dataset::train(1).batch(20);
        assert_eq!(a, b);
    }

    #[test]
    fn different_seeds_differ() {
        let a: Vec<_> = Dataset::train(1).batch(20);
        let b: Vec<_> = Dataset::train(2).batch(20);
        assert_ne!(a, b);
    }

    #[test]
    fn train_mixture_covers_all_families() {
        let mut ds = Dataset::train(3);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..200 {
            seen.insert(ds.next_task().family);
        }
        assert_eq!(seen.len(), Family::ALL.len());
    }

    #[test]
    fn sft_only_easy_levels() {
        let mut ds = Dataset::sft(4);
        for _ in 0..100 {
            assert!(ds.next_task().level <= 2);
        }
    }

    #[test]
    fn served_counter() {
        let mut ds = Dataset::train(5);
        ds.batch(7);
        assert_eq!(ds.served(), 7);
    }
}
