//! Verifiable-reward math tasks — the DeepScaleR-Preview stand-in.
//!
//! Each family generates (prompt, answer) pairs procedurally with a
//! difficulty level; the reward is rule-based exact match on the final
//! answer (paper §A.1: reward 1 at the last token iff correct, else 0).
//! `suites` defines the five held-out eval suites standing in for
//! AIME24 / AIME25 / AMC / MinervaMath / OlympiadBench.

pub mod dataset;
pub mod families;
pub mod suites;
pub mod verifier;

pub use dataset::Dataset;
pub use families::{Family, Task};
pub use suites::{eval_suites, Suite};
pub use verifier::{normalize_answer, reward};
