//! Rule-based reward: exact match of the extracted final answer
//! (paper §A.1 — reward 1 at the final token iff correct, else 0).

/// Canonical form: trim, collapse internal whitespace runs, strip a
/// leading '+' on signed integers.
pub fn normalize_answer(s: &str) -> String {
    let collapsed: Vec<&str> = s.split_whitespace().collect();
    let joined = collapsed.join(" ");
    joined.strip_prefix('+').unwrap_or(&joined).to_string()
}

/// 0/1 reward for a generated answer against the reference.
pub fn reward(generated: &str, reference: &str) -> f64 {
    if normalize_answer(generated) == normalize_answer(reference) { 1.0 } else { 0.0 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_match_scores_one() {
        assert_eq!(reward("42", "42"), 1.0);
        assert_eq!(reward("43", "42"), 0.0);
    }

    #[test]
    fn whitespace_is_normalized() {
        assert_eq!(reward("  10 9  8 ", "10 9 8"), 1.0);
        assert_eq!(normalize_answer("a\t b\n c"), "a b c");
    }

    #[test]
    fn leading_plus_is_stripped() {
        assert_eq!(reward("+5", "5"), 1.0);
    }

    #[test]
    fn empty_vs_nonempty() {
        assert_eq!(reward("", "0"), 0.0);
        assert_eq!(reward("", ""), 1.0);
    }

    #[test]
    fn prefix_is_not_enough() {
        assert_eq!(reward("4", "42"), 0.0);
        assert_eq!(reward("422", "42"), 0.0);
    }
}
