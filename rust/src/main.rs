//! `copris` — CLI for the CoPRIS reproduction.
//!
//! Subcommands:
//!   train       — SFT warmup + GRPO RL training (rollout mode per --set)
//!   eval        — evaluate a checkpoint (or fresh init) on the five suites
//!   config      — print a config preset as the paper's Table 3
//!   trace       — one rollout stage; print the Fig-1 long-tail diagnostics
//!   slo         — open-loop load generator + SLO scoreboard (lockstep sim)
//!   engine-host — serve rollout engines over TCP for a `transport = "tcp"`
//!                 router (multi-process fleet)
//!
//! Examples:
//!   copris train --model small --steps 40 --sft-steps 150 --mode copris
//!   copris train --model small --mode sync --set rollout.batch_prompts=8
//!   copris config --preset paper
//!   copris trace --model small --mode sync
//!   copris slo --workload poisson --rate 400 --requests 300 --seed 7
//!   copris engine-host --listen 127.0.0.1:7101 --engines 2 --backend mock

use anyhow::{bail, Context, Result};

use copris::cli::Args;
use copris::config::{preset, Config, RolloutMode};
use copris::exp::RlSession;
use copris::tasks::Dataset;
use copris::trainer::MetricsLog;
use copris::util::stats::ascii_histogram;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> ! {
    eprintln!(
        "usage: copris <train|eval|config|trace|slo|engine-host> [options]\n\
         common options:\n\
           --model <variant>        artifacts/<variant> (default small)\n\
           --artifacts <dir>        artifacts root (default artifacts)\n\
           --mode <sync|naive|copris>\n\
           --steps N  --sft-steps N --seed N  --verbose\n\
           --concurrency N          CoPRIS pool size N'\n\
           --no-is                  disable cross-stage IS correction\n\
           --pipeline               stage-pipelined execution (overlap\n\
                                    next rollout with the update)\n\
           --async                  fully-async execution: continuous\n\
                                    trajectory stream, consume-when-ready\n\
                                    batches, mid-flight weight sync\n\
           --max-staleness N        async only: weight syncs one engine\n\
                                    assignment may survive (0 = pipelined-\n\
                                    equivalent cut-all-at-sync)\n\
           --no-retain-kv           disable KV retention + affinity resume\n\
                                    routing (always re-prefill resumes)\n\
           --retain-kv-across-sync  keep retained KV valid across weight\n\
                                    syncs (stale-KV continuation; extra\n\
                                    off-policy staleness, zero recompute)\n\
           --no-prefix-sharing      disable paged-KV prompt-prefix sharing\n\
                                    across GRPO groups (private blocks per\n\
                                    sample)\n\
           --kv-block-size N        tokens per KV block (default 16); KV\n\
                                    budget via --set engine.kv_budget_blocks\n\
           --kv-dtype <f32|f16|int8> KV block storage dtype (default f32);\n\
                                    narrower dtypes multiply the effective\n\
                                    block budget (f16 2x, int8 4x)\n\
           --step-token-budget N    continuous batching: pack each engine\n\
                                    step with ≤ N tokens (decode lanes +\n\
                                    chunked prefill slices); 0 = legacy\n\
                                    slot admission (default)\n\
           --workload <poisson|bursty> open-loop arrival process (slo)\n\
           --rate R                 offered rate in req per virtual second\n\
           --requests N             arrivals per slo run; burst shape and\n\
                                    queue/quantum via --set workload.*\n\
           --metrics <path.jsonl>   write per-step metrics\n\
           --set section.key=value  any config override (repeatable)\n\
         engine-host options (multi-process fleet; router side sets\n\
         router.transport=tcp and router.hosts=h1:p1,h2:p2):\n\
           --listen <addr:port>     bind address (default 127.0.0.1:0;\n\
                                    the bound address is printed on stdout)\n\
           --engines N              engines this host serves (default 1)\n\
           --slots N                decode slots per engine (mock backend;\n\
                                    xla uses the artifact's slot count)\n\
           --backend <mock|xla>     backend per engine (default mock)\n\
           --mock-min-len N  --mock-spread N  --mock-decode-delay-us N\n\
           --mock-max-seq N         mock script knobs (defaults 2/12/0/96)\n\
           --once                   exit after the first router disconnects\n\
           --crash-after-events N   chaos: kill the process (exit 9) after\n\
                                    forwarding exactly N event frames\n\
           --preset <paper|scaled-small|scaled-tiny|sync-baseline|pipelined-small|async-small>"
    );
    std::process::exit(2);
}

fn build_config(args: &Args) -> Result<Config> {
    let mut cfg = match args.get("preset") {
        Some(p) => preset(p).with_context(|| format!("unknown preset {p:?}"))?,
        None => {
            let model = args.get("model").unwrap_or("small");
            copris::config::scaled_preset(model)
        }
    };
    if let Some(m) = args.get("model") {
        cfg.model = m.to_string();
    }
    if let Some(d) = args.get("artifacts") {
        cfg.artifacts_dir = d.to_string();
    }
    if let Some(m) = args.get("mode") {
        cfg.rollout.mode = RolloutMode::parse(m)?;
    }
    if let Some(c) = args.get("concurrency") {
        cfg.rollout.concurrency = c.parse()?;
    }
    if let Some(s) = args.get("steps") {
        cfg.train.steps = s.parse()?;
    }
    if let Some(s) = args.get("seed") {
        cfg.train.seed = s.parse()?;
    }
    if args.flag("no-is") {
        cfg.rollout.importance_sampling = false;
    }
    if args.flag("pipeline") {
        cfg.rollout.pipeline = true;
    }
    if args.flag("async") {
        cfg.set("rollout.execution", "async")?;
    }
    if let Some(s) = args.get("max-staleness") {
        cfg.set("rollout.max_staleness", s)?;
    }
    if args.flag("no-retain-kv") {
        cfg.rollout.retain_kv = false;
    }
    if args.flag("retain-kv-across-sync") {
        cfg.rollout.retain_kv_across_sync = true;
    }
    if args.flag("no-prefix-sharing") {
        cfg.engine.prefix_sharing = false;
    }
    if let Some(bs) = args.get("kv-block-size") {
        cfg.set("engine.kv_block_size", bs)?;
    }
    if let Some(d) = args.get("kv-dtype") {
        cfg.set("engine.kv_dtype", d)?;
    }
    if let Some(b) = args.get("step-token-budget") {
        cfg.set("engine.step_token_budget", b)?;
    }
    if let Some(w) = args.get("workload") {
        cfg.set("workload.process", w)?;
    }
    if let Some(r) = args.get("rate") {
        cfg.set("workload.rate_rps", r)?;
    }
    if let Some(n) = args.get("requests") {
        cfg.set("workload.requests", n)?;
    }
    for kv in args.get_all("set") {
        let (k, v) = kv
            .split_once('=')
            .with_context(|| format!("--set expects key=value, got {kv:?}"))?;
        cfg.set(k, v)?;
    }
    Ok(cfg)
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        usage();
    }
    let args = Args::parse(
        argv,
        &[
            "verbose",
            "no-is",
            "no-eval",
            "pipeline",
            "async",
            "no-retain-kv",
            "retain-kv-across-sync",
            "no-prefix-sharing",
            "once",
        ],
    )?;
    let cmd = args.positional.first().map(String::as_str).unwrap_or("");
    match cmd {
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "config" => cmd_config(&args),
        "trace" => cmd_trace(&args),
        "slo" => cmd_slo(&args),
        "engine-host" => cmd_engine_host(&args),
        _ => usage(),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = build_config(args)?;
    let sft_steps = args.get_usize("sft-steps", 100)?;
    let steps = cfg.train.steps;
    println!(
        "== copris train: model={} mode={} N'={} B={} G={} IS={} exec={} transport={} steps={steps} ==",
        cfg.model,
        cfg.rollout.mode.name(),
        cfg.rollout.concurrency,
        cfg.rollout.batch_prompts,
        cfg.rollout.group_size,
        cfg.rollout.importance_sampling,
        cfg.rollout.exec_mode().name(),
        cfg.router.transport.name(),
    );
    let mut sess = RlSession::build(cfg)?;
    sess.verbose = args.flag("verbose");
    if let Some(path) = args.get("metrics") {
        sess.log = MetricsLog::to_file(std::path::Path::new(path))?;
    }
    if sft_steps > 0 {
        println!("-- SFT warmup ({sft_steps} steps) --");
        let loss = sess.sft_warmup(sft_steps, 2)?;
        println!("   final sft loss: {loss:.4}");
    }
    if !args.flag("no-eval") {
        let base = sess.evaluate(1)?;
        println!("-- basemodel eval --");
        print_eval(&base);
    }
    println!("-- RL training ({steps} steps) --");
    let summary = sess.train(steps)?;
    println!(
        "done: wall {:.1}s  throughput {:.2} samples/s  final reward {:.3}  util {:.0}%",
        summary.wall,
        summary.throughput,
        summary.final_reward,
        summary.mean_utilization * 100.0
    );
    println!(
        "stage totals: rollout {:.1}s  cal_logprob {:.1}s  train {:.1}s  sync {:.1}s  preempt {}  replayed {}  overlap {:.1}s  lagged {}",
        summary.rollout_secs,
        summary.cal_logprob_secs,
        summary.train_secs,
        summary.sync_secs,
        summary.preemptions,
        summary.replayed_tokens,
        summary.overlap_secs,
        summary.lagged_trajectories
    );
    println!(
        "kv retention: hits {}  misses {}  replay tokens saved {}",
        summary.retained_hits, summary.retained_misses, summary.replay_tokens_saved
    );
    println!(
        "paged kv: peak blocks {}  peak bytes {}  prefix tokens shared {}  cow copies {}",
        summary.kv_blocks_peak,
        summary.kv_bytes_peak,
        summary.prefix_tokens_shared,
        summary.cow_copies
    );
    if !summary.sampler_dispatch.is_empty() {
        println!("sampler dispatch: {}", summary.sampler_dispatch);
    }
    println!(
        "continuous batching: prefill_chunks {}  step_token_util {:.2}  prefill_stall_saved {:.2}s  resumed {}",
        summary.prefill_chunks,
        summary.step_token_util,
        summary.t_prefill_stall_saved,
        summary.resumed
    );
    println!(
        "failover: engine_failures {}  redispatched {}  retries {}  retain_errors {}",
        summary.engine_failures,
        summary.redispatched_trajectories,
        summary.retries,
        summary.retain_errors
    );
    if !args.flag("no-eval") {
        let report = sess.evaluate(2)?;
        println!("-- final eval --");
        print_eval(&report);
    }
    sess.shutdown();
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let cfg = build_config(args)?;
    let mut sess = RlSession::build(cfg)?;
    let report = sess.evaluate(args.get_u64("eval-seed", 2)?)?;
    print_eval(&report);
    sess.shutdown();
    Ok(())
}

fn cmd_config(args: &Args) -> Result<()> {
    let name = args.get("preset").unwrap_or("paper");
    let Some(cfg) = preset(name) else { bail!("unknown preset {name:?}") };
    println!("# preset: {name}\n");
    println!("{}", cfg.render_table());
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<()> {
    let cfg = build_config(args)?;
    println!("== rollout trace: mode={} ==", cfg.rollout.mode.name());
    let mut sess = RlSession::build(cfg)?;
    sess.verbose = args.flag("verbose");
    let sft = args.get_usize("sft-steps", 30)?;
    if sft > 0 {
        sess.sft_warmup(sft, 1)?;
    }
    let mut ds = Dataset::train(7);
    let out = sess.coord.rollout_stage(&mut ds)?;
    let lens: Vec<f64> = out.stats.response_lengths.iter().map(|&l| l as f64).collect();
    println!(
        "stage: {:.2}s  completed {}  partials {}  util {:.0}%  peak inflight {}",
        out.stats.wall,
        out.stats.completed,
        out.stats.partials_buffered,
        out.stats.mean_utilization() * 100.0,
        out.stats.peak_inflight
    );
    println!("\nresponse-length distribution (Fig 1a analogue):");
    for row in ascii_histogram(&lens, 10, 40) {
        println!("  {row}");
    }
    println!("\nper-engine utilization tail (Fig 1b analogue):");
    for t in out.stats.traces.iter().rev().take(20).collect::<Vec<_>>().iter().rev() {
        println!(
            "  engine {} t={:.3}s active {}/{}",
            t.engine, t.t_wall, t.active, t.slots
        );
    }
    sess.shutdown();
    Ok(())
}

fn cmd_slo(args: &Args) -> Result<()> {
    let cfg = build_config(args)?;
    let sim = copris::loadgen::SimConfig::from_config(&cfg);
    println!(
        "== copris slo: {} rate={}rps requests={} engines={}x{} queue_cap={} quantum={}us seed={} ==",
        sim.process.name(),
        cfg.workload.rate_rps,
        sim.requests,
        sim.engines,
        sim.slots,
        sim.queue_cap,
        sim.quantum_ticks,
        sim.seed
    );
    let r = copris::loadgen::run_sim(&sim);
    let rep = &r.report;
    println!("| Metric | Value |\n|---|---|");
    println!("| Arrived / completed / shed | {} / {} / {} |", rep.arrived, rep.completed, rep.shed);
    println!(
        "| Completed interactive / bulk | {} / {} |",
        rep.completed_interactive, rep.completed_bulk
    );
    println!("| Tokens generated | {} |", rep.tokens_out);
    println!("| TTFT p50 / p99 (virtual us) | {:.0} / {:.0} |", rep.ttft_p50_ticks, rep.ttft_p99_ticks);
    println!("| ITL p50 / p99 (virtual us) | {:.0} / {:.0} |", rep.itl_p50_ticks, rep.itl_p99_ticks);
    println!("| E2E p50 / p99 (virtual us) | {:.0} / {:.0} |", rep.e2e_p50_ticks, rep.e2e_p99_ticks);
    println!("| Goodput (req/s) | {:.2} |", rep.goodput_rps);
    println!("| Shed rate | {:.4} |", rep.shed_rate);
    println!("| Preemption rate | {:.4} ({} preemptions) |", rep.preemption_rate, rep.preemptions);
    println!("| Queue depth peak | {} |", rep.queue_depth_peak);
    println!(
        "| Rounds / end tick | {} / {} |  (engine preemptions {}, completed_all {})",
        r.rounds, r.end_tick, r.engine_preemptions, r.completed_all
    );
    if !r.completed_all {
        bail!("lockstep sim tripped the livelock valve before draining");
    }
    Ok(())
}

fn cmd_engine_host(args: &Args) -> Result<()> {
    use copris::net::host::{serve, HostBackend, HostConfig};
    let cfg = build_config(args)?;
    let listen = args.get("listen").unwrap_or("127.0.0.1:0");
    let engines = args.get_usize("engines", 1)?;
    if engines == 0 {
        bail!("engine-host needs --engines >= 1");
    }
    let crash_after = match args.get("crash-after-events") {
        Some(s) => Some(s.parse::<u64>().with_context(|| format!("--crash-after-events {s}"))?),
        None => None,
    };
    let (backend, slots) = match args.get("backend").unwrap_or("mock") {
        // Mock knob defaults mirror MockBackend::new so an unconfigured
        // host scripts identically to an in-process pool.
        "mock" => {
            let slots = args.get_usize("slots", 4)?;
            let backend = HostBackend::Mock {
                min_len: args.get_usize("mock-min-len", 2)?,
                spread: args.get_usize("mock-spread", 12)?,
                decode_delay_us: args.get_u64("mock-decode-delay-us", 0)?,
                max_seq: args.get_usize("mock-max-seq", 96)?,
            };
            (backend, slots)
        }
        "xla" => {
            // The artifact fixes the slot count; trainer init supplies
            // placeholder params (the router broadcasts the real weights
            // right after connecting, before anything is in flight).
            let trainer = copris::trainer::Trainer::new(cfg.clone(), cfg.train.seed as i32)
                .context("building trainer for engine-host init params")?;
            let spec = trainer.rt.spec.clone();
            let backend = HostBackend::Xla {
                artifacts_dir: cfg.artifacts_dir.clone(),
                model: cfg.model.clone(),
                chunked_replay: cfg.engine.chunked_replay,
                init_params: trainer.params()?,
            };
            (backend, spec.slots)
        }
        other => bail!("unknown engine-host backend {other:?} (mock|xla)"),
    };
    if slots == 0 {
        bail!("engine-host needs --slots >= 1");
    }
    let hc = HostConfig {
        engines,
        slots,
        engine_opts: cfg.engine.engine_opts(),
        sup: cfg.engine.supervisor_opts(),
        backend,
        crash_after_events: crash_after,
        crash_exit: crash_after.is_some(),
    };
    let listener = std::net::TcpListener::bind(listen)
        .with_context(|| format!("binding engine-host on {listen}"))?;
    let addr = listener.local_addr().context("reading bound address")?;
    // Stdout, flushed: launchers (tests, scripts) parse this line to learn
    // the port when --listen ends in :0.
    println!("engine-host listening on {addr}");
    use std::io::Write;
    std::io::stdout().flush().ok();
    serve(listener, hc, args.flag("once"))
}

fn print_eval(report: &copris::eval::EvalReport) {
    for s in &report.suites {
        println!(
            "   {:<10} pass@1 {:.3}  ({} prompts × {} samples, mean len {:.1})",
            s.name,
            s.pass_at_1,
            s.n_prompts,
            s.n_samples / s.n_prompts.max(1),
            s.mean_response_len
        );
    }
    println!("   {:<10} {:.3}", "AVERAGE", report.average());
}
