//! Group bookkeeping: each prompt is sampled G times (GRPO groups). A group
//! is *complete* when all G trajectories reached a terminal state; early
//! termination fires when B groups are complete. Completed trajectories of
//! still-active groups remain here across stages (the second half of Eq. 7).
//!
//! The group id doubles as the **shared-prefix handle**
//! ([`crate::engine::WorkItem::prefix`]): all G samples carry it, so the
//! engine's paged KV cache charges the group's prompt-prefix blocks once.
//! [`GroupBook::record_complete`] returning `true` (the group just
//! completed) is the coordinator's signal to release the engines' prefix
//! registry entries for that id.

use std::collections::HashMap;

use anyhow::{ensure, Result};

use super::trajectory::Trajectory;
use crate::tasks::Task;

/// One GRPO prompt-group: G samples of the same task.
#[derive(Debug)]
pub struct Group {
    /// Group id (allocation order in the book).
    pub group_id: u64,
    /// The shared task all G samples answer.
    pub task: Task,
    /// Samples required for completion (G).
    pub target: usize,
    /// Completed trajectories (≤ target).
    pub done: Vec<Trajectory>,
    /// Samples dispatched and not yet failed/abandoned (done + in flight +
    /// buffered partials).
    pub dispatched: usize,
}

impl Group {
    /// Has the group collected all G terminal samples?
    pub fn is_complete(&self) -> bool {
        self.done.len() >= self.target
    }

    /// How many more samples need dispatching.
    pub fn deficit(&self) -> usize {
        self.target.saturating_sub(self.dispatched)
    }
}

/// Registry of every live group: open, complete-but-unharvested, and the
/// completion order the training batch is drawn in.
#[derive(Debug, Default)]
pub struct GroupBook {
    groups: HashMap<u64, Group>,
    /// Group ids in completion order (drained by take_completed).
    completed: Vec<u64>,
    next_id: u64,
}

impl GroupBook {
    /// Empty book.
    pub fn new() -> Self {
        Self::default()
    }

    /// Open a new group for `task` needing `target` samples; returns its id.
    pub fn new_group(&mut self, task: Task, target: usize) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.groups.insert(
            id,
            Group { group_id: id, task, target, done: Vec::new(), dispatched: 0 },
        );
        id
    }

    /// Look up a live group.
    pub fn get(&self, id: u64) -> Option<&Group> {
        self.groups.get(&id)
    }

    /// Record one sample dispatched for `group_id`.
    pub fn note_dispatch(&mut self, group_id: u64) {
        if let Some(g) = self.groups.get_mut(&group_id) {
            g.dispatched += 1;
        }
    }

    /// A dispatched sample was abandoned before producing any tokens
    /// (unstarted at early termination) — free the dispatch slot.
    pub fn note_abandoned(&mut self, group_id: u64) {
        if let Some(g) = self.groups.get_mut(&group_id) {
            g.dispatched = g.dispatched.saturating_sub(1);
        }
    }

    /// Record a terminal trajectory; returns true if its group just became
    /// complete.
    pub fn record_complete(&mut self, traj: Trajectory) -> Result<bool> {
        ensure!(traj.complete, "trajectory not terminal");
        let g = self
            .groups
            .get_mut(&traj.group_id)
            .ok_or_else(|| anyhow::anyhow!("unknown group {}", traj.group_id))?;
        let was_complete = g.is_complete();
        g.done.push(traj);
        let now_complete = g.is_complete();
        if now_complete && !was_complete {
            self.completed.push(g.group_id);
            return Ok(true);
        }
        Ok(false)
    }

    /// Complete-but-unharvested group count (the early-termination test).
    pub fn completed_count(&self) -> usize {
        self.completed.len()
    }

    /// Remove and return the first `b` completed groups (training batch).
    pub fn take_completed(&mut self, b: usize) -> Vec<Group> {
        let take: Vec<u64> = self.completed.drain(..b.min(self.completed.len())).collect();
        take.into_iter().filter_map(|id| self.groups.remove(&id)).collect()
    }

    /// Remove specific groups by id (eval uses a shared book with training;
    /// this takes exactly its own groups, complete or not).
    pub fn take_groups(&mut self, ids: &[u64]) -> Vec<Group> {
        self.completed.retain(|id| !ids.contains(id));
        ids.iter().filter_map(|id| self.groups.remove(id)).collect()
    }

    /// Groups still needing samples dispatched, most-started first (finish
    /// near-complete groups before opening new ones). Ties break by group
    /// id so dispatch order never depends on HashMap iteration order —
    /// required for the golden driver-equivalence tests.
    pub fn groups_with_deficit(&self) -> Vec<u64> {
        let mut v: Vec<(&u64, &Group)> =
            self.groups.iter().filter(|(_, g)| g.deficit() > 0 && !g.is_complete()).collect();
        v.sort_by_key(|(id, g)| (std::cmp::Reverse(g.dispatched), **id));
        v.iter().map(|(id, _)| **id).collect()
    }

    /// Live group count (open + complete-but-unharvested).
    pub fn active_groups(&self) -> usize {
        self.groups.len()
    }

    /// Completed-but-unharvested trajectories (Eq. 7 second component).
    pub fn parked_trajectories(&self) -> usize {
        self.groups
            .values()
            .filter(|g| !g.is_complete())
            .map(|g| g.done.len())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasks::Family;
    use crate::util::Rng;

    fn task(seed: u64) -> Task {
        Family::MaxList.generate(&mut Rng::new(seed), 1)
    }

    fn done_traj(id: u64, group: u64) -> Trajectory {
        let mut t = Trajectory::new(id, group, task(id), vec![1, 4], 0);
        t.append_stage(&[5, 2], &[-0.5, -0.1], 0);
        t.complete = true;
        t
    }

    #[test]
    fn group_completes_at_target() {
        let mut book = GroupBook::new();
        let g = book.new_group(task(1), 3);
        for i in 0..3 {
            book.note_dispatch(g);
            let became = book.record_complete(done_traj(i, g)).unwrap();
            assert_eq!(became, i == 2);
        }
        assert_eq!(book.completed_count(), 1);
        let taken = book.take_completed(5);
        assert_eq!(taken.len(), 1);
        assert_eq!(taken[0].done.len(), 3);
        assert_eq!(book.active_groups(), 0);
    }

    #[test]
    fn take_completed_preserves_completion_order() {
        let mut book = GroupBook::new();
        let g1 = book.new_group(task(1), 1);
        let g2 = book.new_group(task(2), 1);
        book.record_complete(done_traj(1, g2)).unwrap();
        book.record_complete(done_traj(2, g1)).unwrap();
        let taken = book.take_completed(1);
        assert_eq!(taken[0].group_id, g2);
        assert_eq!(book.completed_count(), 1);
    }

    #[test]
    fn deficit_tracking() {
        let mut book = GroupBook::new();
        let g = book.new_group(task(1), 4);
        assert_eq!(book.get(g).unwrap().deficit(), 4);
        book.note_dispatch(g);
        book.note_dispatch(g);
        assert_eq!(book.get(g).unwrap().deficit(), 2);
        book.note_abandoned(g);
        assert_eq!(book.get(g).unwrap().deficit(), 3);
    }

    #[test]
    fn groups_with_deficit_prefers_most_started() {
        let mut book = GroupBook::new();
        let g1 = book.new_group(task(1), 4);
        let g2 = book.new_group(task(2), 4);
        book.note_dispatch(g2);
        book.note_dispatch(g2);
        book.note_dispatch(g1);
        let order = book.groups_with_deficit();
        assert_eq!(order[0], g2);
        assert_eq!(order[1], g1);
    }

    #[test]
    fn parked_trajectories_counts_incomplete_groups_only() {
        let mut book = GroupBook::new();
        let g1 = book.new_group(task(1), 2);
        let g2 = book.new_group(task(2), 1);
        book.record_complete(done_traj(1, g1)).unwrap(); // parked (1/2)
        book.record_complete(done_traj(2, g2)).unwrap(); // complete group
        assert_eq!(book.parked_trajectories(), 1);
    }

    #[test]
    fn incomplete_trajectory_rejected() {
        let mut book = GroupBook::new();
        let g = book.new_group(task(1), 1);
        let t = Trajectory::new(9, g, task(9), vec![1], 0);
        assert!(book.record_complete(t).is_err());
    }
}
