//! Trajectories with stage-tagged behaviour log-probabilities (Eq. 6):
//! L_i = concat(L_i^(1), ..., L_i^(K)) — each segment generated under one
//! policy version and reused verbatim for cross-stage IS correction.

use crate::tasks::Task;

/// Tokens generated under a single policy version.
#[derive(Clone, Debug, PartialEq)]
pub struct Segment {
    /// Policy version (trainer step) the tokens were sampled under.
    pub policy_version: u64,
    /// Policy version current when the generating assignment was
    /// *dispatched*. Under synchronous/pipelined rollout this always
    /// equals `policy_version`; under fully-async rollout an assignment
    /// may survive weight syncs, so `policy_version - dispatch_version`
    /// counts the syncs this segment's assignment outlived — bounded by
    /// `rollout.max_staleness` (the driver force-terminates exceeders
    /// into the partial buffer before they can generate under a staler
    /// gap).
    pub dispatch_version: u64,
    /// Behaviour log-prob of each token in this segment.
    pub logprobs: Vec<f32>,
}

impl Segment {
    /// Syncs the generating assignment survived before these tokens were
    /// harvested (0 under sync/pipelined execution).
    pub fn staleness(&self) -> u64 {
        self.policy_version.saturating_sub(self.dispatch_version)
    }
}

/// One rollout trajectory: a prompt plus tokens accumulated across one or
/// more stages, each stage's log-probs kept as a version-tagged [`Segment`].
#[derive(Clone, Debug)]
pub struct Trajectory {
    /// Unique id (the engine request id).
    pub id: u64,
    /// GRPO group this sample belongs to.
    pub group_id: u64,
    /// The task being solved (prompt text + verifiable answer).
    pub task: Task,
    /// Shared with every `WorkItem` dispatched for this trajectory — an
    /// `Arc` so buffered-partial re-dispatch never deep-copies the prompt.
    pub prompt: std::sync::Arc<[i32]>,
    /// All generated tokens so far (across stages).
    pub tokens: Vec<i32>,
    /// Stage-tagged log-prob segments; concat length == tokens length.
    pub segments: Vec<Segment>,
    /// Terminal (EOS or length cap)?
    pub complete: bool,
    /// Stage (policy version) at first dispatch.
    pub born_version: u64,
}

impl Trajectory {
    /// Fresh trajectory born at `version` with no generated tokens yet.
    pub fn new(id: u64, group_id: u64, task: Task, prompt: Vec<i32>, version: u64) -> Self {
        Trajectory {
            id,
            group_id,
            task,
            prompt: prompt.into(),
            tokens: Vec::new(),
            segments: Vec::new(),
            complete: false,
            born_version: version,
        }
    }

    /// Append one stage's generation (paper: buffer stores log-probs under
    /// the policy that generated each subsequence). Dispatch version ==
    /// policy version: the sync/pipelined case where every harvest happens
    /// under the version that dispatched it.
    pub fn append_stage(&mut self, tokens: &[i32], logprobs: &[f32], version: u64) {
        self.append_stage_spanning(tokens, logprobs, version, version);
    }

    /// Append one stage's generation where the assignment was dispatched
    /// under `dispatch_version` but harvested under `policy_version`
    /// (fully-async rollout: the assignment survived
    /// `policy_version - dispatch_version` weight syncs).
    pub fn append_stage_spanning(
        &mut self,
        tokens: &[i32],
        logprobs: &[f32],
        dispatch_version: u64,
        policy_version: u64,
    ) {
        assert_eq!(tokens.len(), logprobs.len(), "token/logprob length mismatch");
        if tokens.is_empty() {
            return;
        }
        self.tokens.extend_from_slice(tokens);
        // Merge into the last segment if the policy version matches (same
        // stage can touch a trajectory twice via preemption + re-admission).
        // The merged segment keeps its ORIGINAL (oldest) dispatch version —
        // conservative for the staleness bound: the kept gap is ≥ the new
        // tokens' true gap, and it already passed the bound when first
        // appended, so `policy_version - dispatch_version ≤ max_staleness`
        // still holds for the merged segment.
        if let Some(last) = self.segments.last_mut() {
            if last.policy_version == policy_version {
                last.logprobs.extend_from_slice(logprobs);
                return;
            }
        }
        self.segments.push(Segment {
            policy_version,
            dispatch_version,
            logprobs: logprobs.to_vec(),
        });
    }

    /// Eq. 6: the concatenated behaviour log-probs L_i.
    pub fn behavior_logprobs(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.tokens.len());
        for s in &self.segments {
            out.extend_from_slice(&s.logprobs);
        }
        out
    }

    /// Number of distinct policy versions that produced this trajectory.
    pub fn n_stages(&self) -> usize {
        self.segments.len()
    }

    /// Off-policy tokens w.r.t. `current`: generated under older policies.
    pub fn offpolicy_tokens(&self, current: u64) -> usize {
        self.segments
            .iter()
            .filter(|s| s.policy_version < current)
            .map(|s| s.logprobs.len())
            .sum()
    }

    /// Generated token count (across all stages; prompt excluded).
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    /// Has nothing been generated yet?
    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Structural invariant: segments concat to exactly the token count.
    pub fn invariant_ok(&self) -> bool {
        self.segments.iter().map(|s| s.logprobs.len()).sum::<usize>() == self.tokens.len()
            && !self.segments.iter().any(|s| s.logprobs.is_empty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasks::Family;
    use crate::util::Rng;

    fn traj() -> Trajectory {
        let task = Family::ModArith.generate(&mut Rng::new(1), 1);
        Trajectory::new(1, 10, task, vec![1, 5, 6], 3)
    }

    #[test]
    fn append_concat_matches_eq6() {
        let mut t = traj();
        t.append_stage(&[4, 5], &[-0.1, -0.2], 3);
        t.append_stage(&[6], &[-0.3], 4);
        t.append_stage(&[7, 8], &[-0.4, -0.5], 5);
        assert_eq!(t.tokens, vec![4, 5, 6, 7, 8]);
        assert_eq!(t.behavior_logprobs(), vec![-0.1, -0.2, -0.3, -0.4, -0.5]);
        assert_eq!(t.n_stages(), 3);
        assert!(t.invariant_ok());
    }

    #[test]
    fn same_version_appends_merge() {
        let mut t = traj();
        t.append_stage(&[4], &[-0.1], 3);
        t.append_stage(&[5], &[-0.2], 3); // preempt + re-admit same stage
        assert_eq!(t.n_stages(), 1);
        assert_eq!(t.behavior_logprobs(), vec![-0.1, -0.2]);
    }

    #[test]
    fn spanning_append_tracks_staleness() {
        let mut t = traj();
        // Dispatched under v3, harvested under v3: on-policy segment.
        t.append_stage_spanning(&[4], &[-0.1], 3, 3);
        // Same assignment survived one sync: harvested under v4 — a new
        // segment with a staleness gap of 1.
        t.append_stage_spanning(&[5], &[-0.2], 3, 4);
        assert_eq!(t.n_stages(), 2);
        assert_eq!(
            t.segments.iter().map(Segment::staleness).collect::<Vec<_>>(),
            vec![0, 1]
        );
        // Re-dispatch at v4 harvested under v4: merges on policy version,
        // keeping the segment's original (oldest) dispatch version.
        t.append_stage_spanning(&[6], &[-0.3], 4, 4);
        assert_eq!(t.n_stages(), 2);
        assert_eq!(t.segments.last().unwrap().dispatch_version, 3);
        assert_eq!(t.behavior_logprobs(), vec![-0.1, -0.2, -0.3]);
        assert!(t.invariant_ok());
    }

    #[test]
    fn empty_append_is_noop() {
        let mut t = traj();
        t.append_stage(&[], &[], 9);
        assert_eq!(t.n_stages(), 0);
        assert!(t.invariant_ok());
    }

    #[test]
    fn offpolicy_token_counting() {
        let mut t = traj();
        t.append_stage(&[4, 5, 6], &[-0.1; 3], 3);
        t.append_stage(&[7], &[-0.2], 5);
        assert_eq!(t.offpolicy_tokens(5), 3);
        assert_eq!(t.offpolicy_tokens(6), 4);
        assert_eq!(t.offpolicy_tokens(3), 0);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn mismatched_lengths_panic() {
        traj().append_stage(&[4, 5], &[-0.1], 1);
    }
}
