//! The unified session API: a [`StagePlan`] declares what one coordinator
//! stage should deliver — a training batch (serial or detached for
//! pipelined pumping), the fully-async trajectory stream, a fixed-prompt
//! eval set, or an open-loop SLO run — and [`Coordinator::run`] executes
//! it, returning a [`StageOutcome`] arm matching the plan.
//!
//! This collapses the historical entry-point zoo (`rollout_stage`,
//! `run_fixed_sync`, `run_open_loop`, raw `begin_stage`/`pump`/
//! `finish_stage` sequencing, `begin_async`) into one declarative path;
//! the old names survive as thin shims over `run` so existing callers and
//! the frozen reference goldens compile unchanged.

use anyhow::{Context, Result};

use super::groups::Group;
use super::rollout::{Coordinator, OpenLoopOutput, OpenLoopRequest, RolloutOutput};
use crate::engine::{PoolApi, SamplingParams};
use crate::tasks::{Dataset, Task};

/// Declarative description of one coordinator stage. Build with the
/// constructors ([`training`](StagePlan::training),
/// [`async_stream`](StagePlan::async_stream), [`eval`](StagePlan::eval),
/// [`open_loop`](StagePlan::open_loop)), refine with the builder methods,
/// execute with [`Coordinator::run`].
#[derive(Debug)]
pub struct StagePlan {
    kind: PlanKind,
    /// Start the stage and return [`StageOutcome::Started`] instead of
    /// pumping to completion — the caller drives `pump`/`finish_stage`
    /// (or the async harvest/sync API) itself.
    detach: bool,
}

#[derive(Debug)]
enum PlanKind {
    /// One training stage in the configured `rollout.mode`
    /// (sync / naive-partial / copris): B completed groups.
    Training,
    /// The fully-async trajectory stream (`rollout.execution = async`);
    /// always detached — batches are harvested with `take_async_batch`.
    AsyncStream,
    /// Fixed-prompt eval: `samples` rollouts per task, until idle.
    Eval {
        tasks: Vec<Task>,
        samples: usize,
        sampling: SamplingParams,
    },
    /// Open-loop SLO stage over a virtual-clock arrival schedule.
    OpenLoop {
        schedule: Vec<OpenLoopRequest>,
        queue_cap: usize,
        quantum_ticks: u64,
        sampling: SamplingParams,
    },
}

impl StagePlan {
    /// A training stage run to completion (pair with
    /// [`detached`](Self::detached) for pipelined callers that pump
    /// between trainer microbatches).
    pub fn training() -> StagePlan {
        StagePlan { kind: PlanKind::Training, detach: false }
    }

    /// The fully-async trajectory stream. Always detached: `run` starts
    /// the stream and returns [`StageOutcome::Started`]; harvest with
    /// `take_async_batch`, sync mid-stream with `prepare_sync` /
    /// `sync_weights` / `resume_refill`, end with `abort_stage`.
    pub fn async_stream() -> StagePlan {
        StagePlan { kind: PlanKind::AsyncStream, detach: true }
    }

    /// A fixed-prompt eval stage: `samples` rollouts per task (greedy
    /// defaults; override with [`sampling`](Self::sampling)).
    pub fn eval(tasks: &[Task], samples: usize) -> StagePlan {
        StagePlan {
            kind: PlanKind::Eval {
                tasks: tasks.to_vec(),
                samples,
                sampling: SamplingParams::default(),
            },
            detach: false,
        }
    }

    /// An open-loop SLO stage over `schedule` (sorted by arrival tick).
    /// Defaults: unbounded admission queue, 1000 virtual ticks per engine
    /// step; override with [`queue_cap`](Self::queue_cap) and
    /// [`quantum_ticks`](Self::quantum_ticks).
    pub fn open_loop(schedule: Vec<OpenLoopRequest>) -> StagePlan {
        StagePlan {
            kind: PlanKind::OpenLoop {
                schedule,
                queue_cap: usize::MAX,
                quantum_ticks: 1_000,
                sampling: SamplingParams::greedy(),
            },
            detach: false,
        }
    }

    /// Return [`StageOutcome::Started`] right after stage begin instead of
    /// pumping to completion (training plans; async streams always are).
    pub fn detached(mut self) -> StagePlan {
        self.detach = true;
        self
    }

    /// Sampling parameters for eval / open-loop plans (training stages
    /// sample per `cfg.rollout`; this is a no-op for them).
    pub fn sampling(mut self, s: SamplingParams) -> StagePlan {
        match &mut self.kind {
            PlanKind::Eval { sampling, .. } | PlanKind::OpenLoop { sampling, .. } => *sampling = s,
            PlanKind::Training | PlanKind::AsyncStream => {}
        }
        self
    }

    /// Admission-queue bound for open-loop plans (arrivals past it are
    /// shed); no-op for other plans.
    pub fn queue_cap(mut self, cap: usize) -> StagePlan {
        if let PlanKind::OpenLoop { queue_cap, .. } = &mut self.kind {
            *queue_cap = cap;
        }
        self
    }

    /// Virtual ticks the open-loop clock advances per live engine step;
    /// no-op for other plans.
    pub fn quantum_ticks(mut self, ticks: u64) -> StagePlan {
        if let PlanKind::OpenLoop { quantum_ticks, .. } = &mut self.kind {
            *quantum_ticks = ticks;
        }
        self
    }
}

/// What [`Coordinator::run`] delivered — one arm per plan kind.
#[derive(Debug)]
pub enum StageOutcome {
    /// Training plan run to completion: B completed groups + stats.
    Batch(RolloutOutput),
    /// Eval plan: one completed group per task, in task order.
    Eval(Vec<Group>),
    /// Open-loop plan: groups, stats and the SLO report.
    OpenLoop(OpenLoopOutput),
    /// Detached training stage or async stream started — drive it through
    /// the stage/stream API and harvest yourself.
    Started,
}

impl<P: PoolApi> Coordinator<P> {
    /// Execute one [`StagePlan`] — the unified session entry point. Plans
    /// that generate from the dataset (training, async stream) need
    /// `dataset`; eval and open-loop plans carry their own work lists and
    /// accept `None`.
    pub fn run(
        &mut self,
        plan: StagePlan,
        dataset: Option<&mut Dataset>,
    ) -> Result<StageOutcome> {
        match plan.kind {
            PlanKind::Training => {
                let ds = dataset.context("training plan needs a dataset")?;
                self.begin_stage(ds)?;
                if plan.detach {
                    return Ok(StageOutcome::Started);
                }
                Ok(StageOutcome::Batch(self.run_stage_to_completion(ds)?))
            }
            PlanKind::AsyncStream => {
                let ds = dataset.context("async-stream plan needs a dataset")?;
                self.begin_async(ds)?;
                Ok(StageOutcome::Started)
            }
            PlanKind::Eval { tasks, samples, sampling } => {
                Ok(StageOutcome::Eval(self.fixed_stage(&tasks, samples, sampling)?))
            }
            PlanKind::OpenLoop { schedule, queue_cap, quantum_ticks, sampling } => {
                Ok(StageOutcome::OpenLoop(self.open_loop_stage(
                    &schedule,
                    queue_cap,
                    quantum_ticks,
                    sampling,
                )?))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_refinements_land_on_the_right_plans() {
        let p = StagePlan::open_loop(vec![]).queue_cap(7).quantum_ticks(42);
        let PlanKind::OpenLoop { queue_cap, quantum_ticks, .. } = &p.kind else {
            panic!("open_loop plan expected");
        };
        assert_eq!(*queue_cap, 7);
        assert_eq!(*quantum_ticks, 42);

        // Cross-kind refinements are explicit no-ops, not panics.
        let t = StagePlan::training().queue_cap(9).sampling(SamplingParams::greedy());
        assert!(matches!(t.kind, PlanKind::Training));
        assert!(!t.detach);
        assert!(t.detached().detach);
        assert!(StagePlan::async_stream().detach, "async streams start detached");
    }
}
