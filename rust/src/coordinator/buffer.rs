//! The partial-trajectory buffer B (Eq. 7) with prioritized resumption:
//! unfinished trajectories wait here between stages, oldest policy first,
//! and are re-dispatched before any fresh prompt in the next rollout stage.

use std::collections::VecDeque;

use super::trajectory::Trajectory;

/// The buffer B of unfinished trajectories, ordered oldest-policy-first.
///
/// When a buffered partial's KV is retained in an engine, the coordinator
/// tracks that (engine, token) affinity in its own map keyed by trajectory
/// id (`Coordinator::retained_at`) — the buffer itself stays a pure
/// trajectory store so the frozen reference coordinator can share it.
#[derive(Debug, Default)]
pub struct PartialBuffer {
    items: VecDeque<Trajectory>,
    /// Trajectories whose oldest segment lags the current policy by more
    /// than this many versions are evicted (staleness guard; the paper
    /// keeps everything — default usize::MAX).
    pub max_stage_lag: usize,
}

impl PartialBuffer {
    /// Empty buffer with the given staleness guard.
    pub fn new(max_stage_lag: usize) -> Self {
        PartialBuffer { items: VecDeque::new(), max_stage_lag }
    }

    /// Insert a partial, keeping oldest-born-version-first order (stable
    /// within a version).
    pub fn push(&mut self, traj: Trajectory) {
        debug_assert!(traj.invariant_ok(), "broken trajectory invariant");
        debug_assert!(!traj.complete, "complete trajectory does not belong in the buffer");
        // Keep ordered by born_version (oldest first) for prioritized
        // resumption; stable within a version.
        let idx = self
            .items
            .iter()
            .position(|t| t.born_version > traj.born_version)
            .unwrap_or(self.items.len());
        self.items.insert(idx, traj);
    }

    /// Prioritized resumption: pop the most off-policy (oldest) partial.
    pub fn pop(&mut self) -> Option<Trajectory> {
        self.items.pop_front()
    }

    /// Drop partials that exceed the staleness guard at `current_version`,
    /// returning them (their groups need replacement samples).
    pub fn evict_stale(&mut self, current_version: u64) -> Vec<Trajectory> {
        if self.max_stage_lag == usize::MAX {
            return vec![];
        }
        let lag = self.max_stage_lag as u64;
        let mut evicted = Vec::new();
        self.items.retain_mut(|t| {
            let stale = current_version.saturating_sub(t.born_version) > lag;
            if stale {
                evicted.push(t.clone());
            }
            !stale
        });
        evicted
    }

    /// Buffered partial count.
    pub fn len(&self) -> usize {
        self.items.len()
    }

    /// Is the buffer empty?
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Total buffered tokens (the re-prefill/recompute debt — what a
    /// retained-KV resume avoids paying).
    pub fn token_count(&self) -> usize {
        self.items.iter().map(|t| t.len()).sum()
    }

    /// Iterate buffered partials oldest-policy-first.
    pub fn iter(&self) -> impl Iterator<Item = &Trajectory> {
        self.items.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasks::Family;
    use crate::util::Rng;

    fn traj(id: u64, version: u64, n_tokens: usize) -> Trajectory {
        let task = Family::Reverse.generate(&mut Rng::new(id), 1);
        let mut t = Trajectory::new(id, id, task, vec![1, 4], version);
        if n_tokens > 0 {
            t.append_stage(&vec![5; n_tokens], &vec![-0.5; n_tokens], version);
        }
        t
    }

    #[test]
    fn pop_is_oldest_version_first() {
        let mut b = PartialBuffer::new(usize::MAX);
        b.push(traj(1, 5, 2));
        b.push(traj(2, 3, 2));
        b.push(traj(3, 4, 2));
        b.push(traj(4, 3, 2));
        let order: Vec<u64> = std::iter::from_fn(|| b.pop()).map(|t| t.id).collect();
        assert_eq!(order, vec![2, 4, 3, 1]); // version 3 (FIFO), then 4, 5
    }

    #[test]
    fn token_count_sums() {
        let mut b = PartialBuffer::new(usize::MAX);
        b.push(traj(1, 1, 3));
        b.push(traj(2, 1, 5));
        assert_eq!(b.token_count(), 8);
        assert_eq!(b.len(), 2);
    }

    #[test]
    fn evict_stale_respects_lag() {
        let mut b = PartialBuffer::new(2);
        b.push(traj(1, 1, 1)); // lag 4 at version 5 → stale
        b.push(traj(2, 4, 1)); // lag 1 → kept
        let evicted = b.evict_stale(5);
        assert_eq!(evicted.len(), 1);
        assert_eq!(evicted[0].id, 1);
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn no_eviction_when_unbounded() {
        let mut b = PartialBuffer::new(usize::MAX);
        b.push(traj(1, 0, 1));
        assert!(b.evict_stale(1_000_000).is_empty());
        assert_eq!(b.len(), 1);
    }
}
