//! The partial-trajectory buffer B (Eq. 7) with prioritized resumption:
//! unfinished trajectories wait here between stages, oldest policy first,
//! and are re-dispatched before any fresh prompt in the next rollout stage.
//! Also home to the [`LenPredictor`] the fully-async mode's active
//! partial-rollout policy consults when choosing which at-risk in-flight
//! trajectories to early-terminate.

use std::collections::HashMap;
use std::collections::VecDeque;

use super::trajectory::Trajectory;

/// Response-length predictor for APRIL-style active partial rollout:
/// per-group EMAs of completed response lengths with a global fallback, so
/// the async coordinator can estimate how much decoding an in-flight
/// trajectory still owes (predicted group length minus tokens generated)
/// before deciding to early-terminate it at a staleness boundary. Samples
/// of one GRPO group share a prompt, making the group EMA the natural
/// granularity; a group with no completions yet falls back to the global
/// EMA, and a cold predictor (no completions at all) predicts 0 — the
/// active policy then never fires, degrading gracefully to the mandatory
/// staleness cut alone.
#[derive(Debug, Default)]
pub struct LenPredictor {
    groups: HashMap<u64, f64>,
    global: Option<f64>,
    /// EMA smoothing factor in (0, 1]; higher = faster adaptation.
    alpha: f64,
}

impl LenPredictor {
    /// Fresh predictor with the given EMA smoothing factor (clamped into
    /// (0, 1]; 0.3 is a reasonable default for per-stage batch sizes).
    pub fn new(alpha: f64) -> Self {
        LenPredictor {
            groups: HashMap::new(),
            global: None,
            alpha: alpha.clamp(f64::MIN_POSITIVE, 1.0),
        }
    }

    /// Record a completed trajectory's response length for its group.
    pub fn observe(&mut self, group_id: u64, len: usize) {
        let x = len as f64;
        let g = self.groups.entry(group_id).or_insert(x);
        *g += self.alpha * (x - *g);
        let gl = self.global.get_or_insert(x);
        *gl += self.alpha * (x - *gl);
    }

    /// Predicted total response length for a trajectory of `group_id`
    /// (group EMA, else global EMA, else 0.0 when cold).
    pub fn predict(&self, group_id: u64) -> f64 {
        self.groups
            .get(&group_id)
            .copied()
            .or(self.global)
            .unwrap_or(0.0)
    }

    /// Drop a finished group's EMA (its prompt will not recur).
    pub fn forget_group(&mut self, group_id: u64) {
        self.groups.remove(&group_id);
    }
}

/// The buffer B of unfinished trajectories, ordered oldest-policy-first.
///
/// When a buffered partial's KV is retained in an engine, the coordinator
/// tracks that (engine, token) affinity in its own map keyed by trajectory
/// id (`Coordinator::retained_at`) — the buffer itself stays a pure
/// trajectory store so the frozen reference coordinator can share it.
#[derive(Debug, Default)]
pub struct PartialBuffer {
    items: VecDeque<Trajectory>,
    /// Trajectories whose oldest segment lags the current policy by more
    /// than this many versions are evicted (staleness guard; the paper
    /// keeps everything — default usize::MAX).
    pub max_stage_lag: usize,
}

impl PartialBuffer {
    /// Empty buffer with the given staleness guard.
    pub fn new(max_stage_lag: usize) -> Self {
        PartialBuffer { items: VecDeque::new(), max_stage_lag }
    }

    /// Insert a partial, keeping oldest-born-version-first order (stable
    /// within a version).
    pub fn push(&mut self, traj: Trajectory) {
        debug_assert!(traj.invariant_ok(), "broken trajectory invariant");
        debug_assert!(!traj.complete, "complete trajectory does not belong in the buffer");
        // Keep ordered by born_version (oldest first) for prioritized
        // resumption; stable within a version.
        let idx = self
            .items
            .iter()
            .position(|t| t.born_version > traj.born_version)
            .unwrap_or(self.items.len());
        self.items.insert(idx, traj);
    }

    /// Prioritized resumption: pop the most off-policy (oldest) partial.
    pub fn pop(&mut self) -> Option<Trajectory> {
        self.items.pop_front()
    }

    /// Drop partials that exceed the staleness guard at `current_version`,
    /// returning them (their groups need replacement samples).
    pub fn evict_stale(&mut self, current_version: u64) -> Vec<Trajectory> {
        if self.max_stage_lag == usize::MAX {
            return vec![];
        }
        let lag = self.max_stage_lag as u64;
        let mut evicted = Vec::new();
        self.items.retain_mut(|t| {
            let stale = current_version.saturating_sub(t.born_version) > lag;
            if stale {
                evicted.push(t.clone());
            }
            !stale
        });
        evicted
    }

    /// Buffered partial count.
    pub fn len(&self) -> usize {
        self.items.len()
    }

    /// Is the buffer empty?
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Total buffered tokens (the re-prefill/recompute debt — what a
    /// retained-KV resume avoids paying).
    pub fn token_count(&self) -> usize {
        self.items.iter().map(|t| t.len()).sum()
    }

    /// Iterate buffered partials oldest-policy-first.
    pub fn iter(&self) -> impl Iterator<Item = &Trajectory> {
        self.items.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasks::Family;
    use crate::util::Rng;

    fn traj(id: u64, version: u64, n_tokens: usize) -> Trajectory {
        let task = Family::Reverse.generate(&mut Rng::new(id), 1);
        let mut t = Trajectory::new(id, id, task, vec![1, 4], version);
        if n_tokens > 0 {
            t.append_stage(&vec![5; n_tokens], &vec![-0.5; n_tokens], version);
        }
        t
    }

    #[test]
    fn pop_is_oldest_version_first() {
        let mut b = PartialBuffer::new(usize::MAX);
        b.push(traj(1, 5, 2));
        b.push(traj(2, 3, 2));
        b.push(traj(3, 4, 2));
        b.push(traj(4, 3, 2));
        let order: Vec<u64> = std::iter::from_fn(|| b.pop()).map(|t| t.id).collect();
        assert_eq!(order, vec![2, 4, 3, 1]); // version 3 (FIFO), then 4, 5
    }

    #[test]
    fn token_count_sums() {
        let mut b = PartialBuffer::new(usize::MAX);
        b.push(traj(1, 1, 3));
        b.push(traj(2, 1, 5));
        assert_eq!(b.token_count(), 8);
        assert_eq!(b.len(), 2);
    }

    #[test]
    fn evict_stale_respects_lag() {
        let mut b = PartialBuffer::new(2);
        b.push(traj(1, 1, 1)); // lag 4 at version 5 → stale
        b.push(traj(2, 4, 1)); // lag 1 → kept
        let evicted = b.evict_stale(5);
        assert_eq!(evicted.len(), 1);
        assert_eq!(evicted[0].id, 1);
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn len_predictor_group_then_global_fallback() {
        let mut p = LenPredictor::new(0.5);
        assert_eq!(p.predict(1), 0.0, "cold predictor predicts 0");
        p.observe(1, 10);
        assert!((p.predict(1) - 10.0).abs() < 1e-9);
        assert!((p.predict(99) - 10.0).abs() < 1e-9, "global fallback");
        p.observe(1, 20); // EMA: 10 + 0.5 * (20 - 10) = 15
        assert!((p.predict(1) - 15.0).abs() < 1e-9);
        p.forget_group(1);
        assert!(p.predict(1) > 0.0, "forgotten group falls back to global");
    }

    #[test]
    fn no_eviction_when_unbounded() {
        let mut b = PartialBuffer::new(usize::MAX);
        b.push(traj(1, 0, 1));
        assert!(b.evict_stale(1_000_000).is_empty());
        assert_eq!(b.len(), 1);
    }
}
