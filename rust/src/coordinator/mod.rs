//! The CoPRIS coordinator — the paper's system contribution (§4):
//!
//! - **Concurrency-Controlled Generation**: keep exactly N′ rollout
//!   requests in flight; refill the moment one finishes.
//! - **Early Termination**: stop all engines once B prompt-groups have
//!   collected their G trajectories.
//! - **Buffering of Partial Trajectories** (Eq. 6–7): unfinished
//!   trajectories keep their per-stage log-prob segments; completed
//!   trajectories of still-active groups stay in the group book.
//! - **Prioritized Resumption**: buffered partials dispatch before fresh
//!   prompts in the next stage — with **affinity-aware resume routing**:
//!   when a partial's KV is still retained on the engine that generated it
//!   (`rollout.retain_kv`), the resume is routed back there and skips
//!   re-prefill entirely, falling back to replay on eviction, weight-sync
//!   invalidation, or load imbalance (`rollout.affinity_max_imbalance`).
//! - **Shared-prefix group dispatch** (`engine.prefix_sharing`): every
//!   sample of a GRPO group carries the group id as a prefix handle and is
//!   routed to the group's home engine, so the engines' paged KV cache
//!   (`engine::kvcache`) charges the prompt-prefix blocks once per group
//!   (refcounted, copy-on-write); resumes route by block residency, and
//!   the registry entry is released when the group completes.
//!
//! Baselines implemented by the same driver: fully-synchronous (veRL) and
//! naive partial rollout (Kimi-K1.5-style fixed initial concurrency).
//!
//! Since the stage-pipelining PR, stage execution is a reentrant state
//! machine ([`driver::StageDriver`]) polled via non-blocking pool reads —
//! `begin_stage` / `pump` / `finish_stage` — so a stage can overlap trainer
//! compute (`rollout.pipeline`). The pre-refactor blocking coordinator is
//! frozen in [`reference`] as the golden-equivalence oracle.

pub mod buffer;
pub mod driver;
pub mod groups;
pub mod plan;
pub mod reference;
pub mod rollout;
pub mod trajectory;

pub use buffer::{LenPredictor, PartialBuffer};
pub use driver::{StageDriver, StageGoal, StagePhase, StagePolicy};
pub use groups::{Group, GroupBook};
pub use plan::{StageOutcome, StagePlan};
pub use reference::ReferenceCoordinator;
pub use rollout::{Coordinator, OpenLoopOutput, OpenLoopRequest, RolloutOutput, RolloutStats};
pub use trajectory::{Segment, Trajectory};
