//! The reentrant stage state machine. A [`StageDriver`] is the per-stage
//! control block the [`Coordinator`](super::Coordinator) polls through
//! `begin_stage` / `pump` / `stage_is_done` / `finish_stage`: dispatch
//! policy, refill, early termination and drain are explicit states driven
//! by non-blocking pool event reads, so a stage can be advanced
//! incrementally — the substrate for stage-pipelined execution
//! (`rollout.pipeline`), where the next stage's rollout is pumped between
//! trainer microbatches while the update for the previous one computes.
//!
//! Sync (veRL), NaivePartial (Kimi-K1.5), CoPRIS and the fixed-prompt eval
//! path are all parameterizations of this one driver ([`StagePolicy`]);
//! none of them has its own event loop anymore.
//!
//! Note on admission timing: with continuous batching enabled
//! (`engine.step_token_budget > 0`), an engine accepting a dispatch only
//! reserves a slot — the prompt is ingested in budgeted chunks over later
//! steps, so a dispatch no longer implies a same-step first token. The
//! driver is agnostic to this (it already tolerates arbitrary delays
//! between dispatch and the first event); only stats change:
//! `RolloutStats` gains `prefill_chunks`, `t_prefill_stall_saved`, and
//! `step_token_util`.

use std::collections::HashSet;
use std::time::{Duration, Instant};

use crate::engine::SamplingParams;

use super::rollout::RolloutStats;

/// Fallback watchdog interval (matches the pre-refactor 120 s recv
/// timeout). The live value comes from `engine.stall_timeout_ms`; this
/// constant is its default. A stage with work in flight that sees no
/// engine event for this long routes the stalled engines into the
/// failure/re-dispatch path instead of hanging.
pub const EVENT_TIMEOUT: Duration = Duration::from_secs(120);

/// What a stage is trying to deliver.
#[derive(Clone, Debug)]
pub enum StageGoal {
    /// Training stage: `b` complete groups, tasks drawn from the dataset.
    Batch {
        /// Complete prompt-groups required (the paper's B).
        b: usize,
    },
    /// Eval stage: fixed task list dispatched upfront, runs until idle.
    /// Owns exactly its own trajectories — never touches the shared
    /// partial buffer (`run_fixed_sync` tracks its group ids itself).
    Fixed,
    /// Open-loop SLO stage: arrivals come from a pre-generated
    /// virtual-clock schedule through a bounded admission queue instead
    /// of a fixed work list; runs until every admitted request completes
    /// (`Coordinator::run_open_loop` tracks its group ids itself, like
    /// `Fixed`).
    OpenLoop,
    /// Fully-async streaming: no terminal goal — trajectories accumulate
    /// in the group book continuously and the trainer harvests batches
    /// with `Coordinator::take_async_batch` whenever enough groups are
    /// ready. The stage never reaches `Done` through `goal_met`; it ends
    /// only via `abort_stage` (which drains in-flight work into the
    /// partial buffer like any early termination).
    Stream,
}

/// Dispatch-policy parameters. The three rollout modes and eval differ
/// only in these values:
///
/// | mode         | target  | continuous | use_buffer | drain | until_idle | inline_preempt |
/// |--------------|---------|------------|------------|-------|------------|----------------|
/// | Sync         | None    | —          | no         | no    | yes        | no             |
/// | NaivePartial | Some(N')| no (waves) | yes        | yes   | no         | no             |
/// | Copris       | Some(N')| yes        | yes        | yes   | no         | no             |
/// | eval (fixed) | None    | —          | no         | no    | yes        | yes            |
#[derive(Clone, Copy, Debug)]
pub struct StagePolicy {
    /// In-flight refill target N' (None → dispatch-once, no refill).
    pub target: Option<usize>,
    /// Refill after every event (CoPRIS) vs only when a wave exhausts
    /// with the batch incomplete (NaivePartial re-wave fallback).
    pub continuous: bool,
    /// Prioritized resumption: pop buffered partials when refilling.
    pub use_buffer: bool,
    /// Early-terminate + drain partials into the buffer once the goal is
    /// met with work still in flight.
    pub drain: bool,
    /// Goal test: wait for in-flight work to hit zero (Sync, eval) instead
    /// of counting completed groups.
    pub until_idle: bool,
    /// Re-dispatch preempted trajectories inline instead of parking them
    /// in the shared buffer. Eval stages set this so carried-over TRAINING
    /// partials are never popped (and generated) under an eval run.
    pub inline_preempt: bool,
}

/// Explicit stage phases.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StagePhase {
    /// Event loop: goal not met yet.
    Running,
    /// StopGeneration broadcast; waiting for every engine's Flushed marker.
    Draining,
    /// Goal met, engines quiesced — `finish_stage` may harvest.
    Done,
}

/// Per-stage control block (one per active stage, owned by the
/// coordinator). Holds everything the pre-refactor blocking loop kept on
/// its call stack, so the stage survives returning to the caller.
pub struct StageDriver {
    /// What the stage delivers (training batch vs fixed eval set).
    pub goal: StageGoal,
    /// Dispatch-policy parameters (see the mode table above).
    pub policy: StagePolicy,
    /// Sampling parameters every dispatch of this stage uses.
    pub sampling: SamplingParams,
    /// Current phase of the state machine.
    pub phase: StagePhase,
    /// Statistics accumulated so far this stage.
    pub stats: RolloutStats,
    /// Stage start (wall-clock accounting).
    pub t0: Instant,
    /// Engines whose Flushed marker arrived while draining. A drain is
    /// complete when every engine is flushed OR dead — a set (not a
    /// count) so failed engines can be excluded from the wait.
    pub flushed: HashSet<usize>,
    /// NaivePartial wave allowance (None = unlimited). Decremented on
    /// every dispatch; `Some(0)` blocks refill until the next re-wave.
    pub wave_remaining: Option<usize>,
    /// Last engine event seen (wedge watchdog).
    pub last_event: Instant,
    /// When the stage reached `Done` (wall-clock + overlap accounting:
    /// time between Done and `finish_stage` is idle, not stage work).
    pub done_at: Option<Instant>,
    /// Refill suspended (fully-async mode: set by `prepare_sync` so no
    /// dispatch can race the in-progress weight broadcast, cleared by
    /// `resume_refill` once the new params are installed).
    pub refill_paused: bool,
}

impl StageDriver {
    /// Fresh control block in the `Running` phase.
    pub fn new(goal: StageGoal, policy: StagePolicy, sampling: SamplingParams) -> StageDriver {
        let now = Instant::now();
        StageDriver {
            goal,
            policy,
            sampling,
            phase: StagePhase::Running,
            stats: RolloutStats::default(),
            t0: now,
            flushed: HashSet::new(),
            wave_remaining: None,
            last_event: now,
            done_at: None,
            refill_paused: false,
        }
    }

    /// Has the stage met its goal and quiesced?
    pub fn is_done(&self) -> bool {
        self.phase == StagePhase::Done
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_driver_starts_running() {
        let d = StageDriver::new(
            StageGoal::Batch { b: 4 },
            StagePolicy {
                target: Some(8),
                continuous: true,
                use_buffer: true,
                drain: true,
                until_idle: false,
                inline_preempt: false,
            },
            SamplingParams::default(),
        );
        assert_eq!(d.phase, StagePhase::Running);
        assert!(!d.is_done());
        assert!(d.flushed.is_empty());
        assert!(d.wave_remaining.is_none());
    }
}
