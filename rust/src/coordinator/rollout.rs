//! Rollout stage driver: concurrency-controlled dispatch over the engine
//! pool, early termination, partial buffering, prioritized resumption —
//! plus the sync (veRL) and naive-partial baselines in the same loop.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::buffer::PartialBuffer;
use super::groups::{Group, GroupBook};
use super::trajectory::Trajectory;
use crate::config::{Config, RolloutMode};
use crate::engine::{EngineCmd, EngineEvent, EnginePool, FinishReason, SamplingParams, StepTrace, WorkItem};
use crate::tasks::{Dataset, Task};
use crate::tokenizer::Tokenizer;

/// Per-stage rollout statistics (feeds Fig. 1, Table 2, Fig. 3).
#[derive(Clone, Debug, Default)]
pub struct RolloutStats {
    pub wall: f64,
    /// Completed trajectories harvested this stage.
    pub completed: usize,
    /// Partials placed in the buffer at early termination.
    pub partials_buffered: usize,
    /// Buffered partials resumed this stage.
    pub resumed: usize,
    pub preemptions: u64,
    /// Resume tokens replayed (the recomputation overhead).
    pub replayed_tokens: u64,
    /// Per-engine-step utilization samples.
    pub traces: Vec<StepTrace>,
    /// Response length of every trajectory completed this stage.
    pub response_lengths: Vec<usize>,
    /// Peak concurrent in-flight requests observed.
    pub peak_inflight: usize,
}

impl RolloutStats {
    /// Mean busy-slot fraction across engine steps (GPU utilization proxy).
    pub fn mean_utilization(&self) -> f64 {
        if self.traces.is_empty() {
            return 0.0;
        }
        self.traces.iter().map(|t| t.active as f64 / t.slots as f64).sum::<f64>()
            / self.traces.len() as f64
    }
}

/// Output of one rollout stage: exactly B complete groups + stats.
#[derive(Debug)]
pub struct RolloutOutput {
    pub groups: Vec<Group>,
    pub stats: RolloutStats,
}

/// In-flight bookkeeping: trajectory + which engine has it.
struct InFlight {
    traj: Trajectory,
    engine: usize,
}

/// The CoPRIS coordinator (also drives the sync / naive-partial baselines).
pub struct Coordinator {
    pub pool: EnginePool,
    pub cfg: Config,
    pub buffer: PartialBuffer,
    book: GroupBook,
    inflight: HashMap<u64, InFlight>,
    engine_load: Vec<usize>,
    next_traj_id: u64,
    /// Current policy version (== trainer step); bumped by `sync_weights`.
    pub policy_version: u64,
    tokenizer: Tokenizer,
    /// Remaining dispatch allowance for NaivePartial (None = unlimited).
    wave_remaining: Option<usize>,
    /// Engines' decode horizon (manifest.max_seq).
    max_seq: usize,
}

impl Coordinator {
    /// `max_seq` is the engines' decode horizon (manifest.max_seq).
    pub fn new(pool: EnginePool, cfg: Config, max_seq: usize) -> Coordinator {
        let engines = pool.engines();
        let buffer = PartialBuffer::new(cfg.rollout.max_stage_lag);
        Coordinator {
            pool,
            cfg,
            buffer,
            book: GroupBook::new(),
            inflight: HashMap::new(),
            engine_load: vec![0; engines],
            next_traj_id: 0,
            policy_version: 0,
            tokenizer: Tokenizer::new(),
            wave_remaining: None,
            max_seq,
        }
    }

    /// Total-length cap for a work item (paper: max response length).
    fn max_total_for(&self, prompt_len: usize) -> usize {
        let cap = if self.cfg.engine.max_new_tokens > 0 {
            prompt_len + self.cfg.engine.max_new_tokens
        } else {
            usize::MAX
        };
        cap.min(self.max_seq)
    }

    pub fn tokenizer(&self) -> &Tokenizer {
        &self.tokenizer
    }

    /// Weight sync: broadcast new params and bump the policy version.
    pub fn sync_weights(&mut self, version: u64, params: Arc<Vec<f32>>) {
        self.policy_version = version;
        self.pool.broadcast_params(version, params);
    }

    fn total_inflight(&self) -> usize {
        self.inflight.len()
    }

    fn least_loaded_engine(&self) -> usize {
        self.engine_load
            .iter()
            .enumerate()
            .min_by_key(|(_, l)| **l)
            .map(|(i, _)| i)
            .unwrap_or(0)
    }

    fn dispatch(&mut self, traj: Trajectory, sampling: SamplingParams) {
        let engine = self.least_loaded_engine();
        let item = WorkItem {
            request_id: traj.id,
            // Arc clone — re-dispatching a buffered partial shares the
            // prompt with the trajectory instead of deep-copying it.
            prompt: traj.prompt.clone(),
            resume: traj.tokens.clone(),
            max_total: self.max_total_for(traj.prompt.len()),
            sampling,
        };
        self.engine_load[engine] += 1;
        self.inflight.insert(traj.id, InFlight { traj, engine });
        self.pool.send(engine, EngineCmd::Assign(item));
        if let Some(w) = self.wave_remaining.as_mut() {
            *w = w.saturating_sub(1);
        }
    }

    /// Make a fresh trajectory for `group_id` and dispatch it.
    fn dispatch_fresh(&mut self, group_id: u64, task: &Task, sampling: SamplingParams) {
        let prompt = self.tokenizer.encode_prompt(&task.prompt);
        let id = self.next_traj_id;
        self.next_traj_id += 1;
        let traj = Trajectory::new(id, group_id, task.clone(), prompt, self.policy_version);
        self.book.note_dispatch(group_id);
        self.dispatch(traj, sampling);
    }

    /// Dispatch policy for one refill opportunity. Returns false when
    /// nothing can/should be dispatched right now.
    fn refill_one(&mut self, dataset: &mut Dataset, sampling: SamplingParams) -> bool {
        if let Some(0) = self.wave_remaining {
            return false; // naive-partial wave exhausted — no refill
        }
        // Prioritized resumption: buffered partials first (paper §4).
        if let Some(t) = self.buffer.pop() {
            self.dispatch(t, sampling);
            return true;
        }
        // Then groups that still need samples, most-started first.
        if let Some(gid) = self.book.groups_with_deficit().first().copied() {
            let task = self.book.get(gid).unwrap().task.clone();
            self.dispatch_fresh(gid, &task, sampling);
            return true;
        }
        // Otherwise open a new group from the dataset (over-generation).
        let task = dataset.next_task();
        let gid = self.book.new_group(task.clone(), self.cfg.rollout.group_size);
        self.dispatch_fresh(gid, &task, sampling);
        true
    }

    /// Run one rollout stage in the configured mode; returns exactly
    /// B = `batch_prompts` completed groups.
    pub fn rollout_stage(&mut self, dataset: &mut Dataset) -> Result<RolloutOutput> {
        let cfg = self.cfg.rollout.clone();
        let sampling = SamplingParams {
            temperature: cfg.temperature,
            top_p: cfg.top_p,
            top_k: cfg.top_k,
        };
        let b = cfg.batch_prompts;
        let mut stats = RolloutStats::default();
        let t0 = Instant::now();

        // Staleness guard (off by default, matching the paper).
        for stale in self.buffer.evict_stale(self.policy_version) {
            self.book.note_abandoned(stale.group_id);
        }

        // Stage-initial dispatch plan.
        let concurrency = match cfg.mode {
            RolloutMode::Sync => {
                // Submit exactly the B·G fresh requests of this batch.
                self.wave_remaining = None;
                for _ in 0..b {
                    let task = dataset.next_task();
                    let gid = self.book.new_group(task.clone(), cfg.group_size);
                    for _ in 0..cfg.group_size {
                        self.dispatch_fresh(gid, &task, sampling);
                    }
                }
                usize::MAX // no refill happens: no deficits, no new groups
            }
            RolloutMode::NaivePartial => {
                // One fixed wave of `concurrency` requests, buffered
                // partials first, no refill afterwards.
                self.wave_remaining = Some(cfg.concurrency);
                cfg.concurrency
            }
            RolloutMode::Copris => {
                self.wave_remaining = None;
                cfg.concurrency
            }
        };

        // For partial modes: fill up to the concurrency target.
        if cfg.mode != RolloutMode::Sync {
            while self.total_inflight() < concurrency {
                if !self.refill_one(dataset, sampling) {
                    break;
                }
            }
        }
        stats.peak_inflight = self.total_inflight();

        // Event loop until the termination condition.
        loop {
            let done_enough = match cfg.mode {
                RolloutMode::Sync => self.total_inflight() == 0,
                _ => self.book.completed_count() >= b,
            };
            if done_enough {
                break;
            }
            // Naive-partial fallback: wave exhausted but batch incomplete →
            // issue another wave (the paper's setting makes this rare).
            if cfg.mode == RolloutMode::NaivePartial
                && self.total_inflight() == 0
                && self.book.completed_count() < b
            {
                self.wave_remaining = Some(cfg.concurrency);
                while self.total_inflight() < cfg.concurrency {
                    if !self.refill_one(dataset, sampling) {
                        break;
                    }
                }
            }

            let ev = self
                .pool
                .events
                .recv_timeout(Duration::from_secs(120))
                .context("rollout: engine event timeout")?;
            self.handle_event(ev, &mut stats, false)?;

            // CoPRIS refill: keep exactly N' in flight (Fig. 2).
            if cfg.mode == RolloutMode::Copris {
                while self.total_inflight() < concurrency {
                    if !self.refill_one(dataset, sampling) {
                        break;
                    }
                }
                stats.peak_inflight = stats.peak_inflight.max(self.total_inflight());
            }
        }

        // Early termination: halt engines, drain partials into the buffer.
        if cfg.mode != RolloutMode::Sync && self.total_inflight() > 0 {
            self.drain_partials(&mut stats)?;
        }
        self.wave_remaining = None;

        let groups = self.book.take_completed(b);
        stats.completed = groups.iter().map(|g| g.done.len()).sum();
        stats.wall = t0.elapsed().as_secs_f64();
        Ok(RolloutOutput { groups, stats })
    }

    /// Handle one engine event (recursing into `Batch` — engines deliver a
    /// whole step's events in one channel send). `draining` switches
    /// Stopped/Preempted handling to "buffer it" (early-termination flush).
    /// Returns the number of `Flushed` markers seen, so `drain_partials`
    /// can count engine flushes even when they arrive inside a batch.
    fn handle_event(
        &mut self,
        ev: EngineEvent,
        stats: &mut RolloutStats,
        draining: bool,
    ) -> Result<usize> {
        match ev {
            EngineEvent::Batch(evs) => {
                let mut flushed = 0;
                for e in evs {
                    flushed += self.handle_event(e, stats, draining)?;
                }
                return Ok(flushed);
            }
            EngineEvent::Trace(t) => stats.traces.push(t),
            EngineEvent::Flushed { .. } => return Ok(1),
            EngineEvent::ShutDown { .. } => {}
            EngineEvent::Done { engine, result } => {
                let Some(inf) = self.inflight.remove(&result.request_id) else {
                    bail!("unknown request {} from engine {engine}", result.request_id);
                };
                self.engine_load[inf.engine] = self.engine_load[inf.engine].saturating_sub(1);
                let mut traj = inf.traj;
                traj.append_stage(&result.new_tokens, &result.new_logprobs, self.policy_version);
                stats.replayed_tokens += result.replayed as u64;
                match result.reason {
                    FinishReason::Eos | FinishReason::LengthCap => {
                        traj.complete = true;
                        stats.response_lengths.push(traj.len());
                        self.book.record_complete(traj)?;
                    }
                    FinishReason::Preempted => {
                        stats.preemptions += 1;
                        if draining {
                            self.park_partial(traj, stats);
                        } else {
                            // Immediate re-queue with resumption priority.
                            self.buffer.push(traj);
                        }
                    }
                    FinishReason::Stopped => {
                        self.park_partial(traj, stats);
                    }
                }
            }
        }
        Ok(0)
    }

    fn park_partial(&mut self, traj: Trajectory, stats: &mut RolloutStats) {
        if traj.is_empty() {
            // Nothing generated: not a partial — free the dispatch slot.
            self.book.note_abandoned(traj.group_id);
        } else {
            stats.partials_buffered += 1;
            self.buffer.push(traj);
        }
    }

    /// Early termination: StopGeneration to all engines, collect every
    /// in-flight trajectory (partials → buffer; unstarted → abandoned).
    fn drain_partials(&mut self, stats: &mut RolloutStats) -> Result<()> {
        self.pool.stop_generation_all();
        let mut flushed = 0usize;
        let engines = self.pool.engines();
        while flushed < engines {
            let ev = self
                .pool
                .events
                .recv_timeout(Duration::from_secs(120))
                .context("drain: engine event timeout")?;
            flushed += self.handle_event(ev, stats, true)?;
        }
        // Anything still in the inflight map was queued but never started.
        let leftovers: Vec<u64> = self.inflight.keys().copied().collect();
        for id in leftovers {
            let inf = self.inflight.remove(&id).unwrap();
            self.engine_load[inf.engine] = self.engine_load[inf.engine].saturating_sub(1);
            self.park_partial(inf.traj, stats);
        }
        stats.resumed = 0; // set by caller if needed
        Ok(())
    }

    /// Fixed-prompt synchronous generation (evaluation path): `samples`
    /// rollouts per task at `sampling`; returns one completed group per
    /// task. Uses a private GroupBook so training state is untouched.
    pub fn run_fixed_sync(
        &mut self,
        tasks: &[Task],
        samples: usize,
        sampling: SamplingParams,
    ) -> Result<Vec<Group>> {
        anyhow::ensure!(self.inflight.is_empty(), "run_fixed_sync with work in flight");
        let mut ids = Vec::new();
        for task in tasks {
            let gid = self.book.new_group(task.clone(), samples);
            ids.push(gid);
            for _ in 0..samples {
                self.dispatch_fresh(gid, task, sampling);
            }
        }
        let mut stats = RolloutStats::default();
        while self.total_inflight() > 0 {
            let ev = self
                .pool
                .events
                .recv_timeout(Duration::from_secs(120))
                .context("eval: engine event timeout")?;
            self.handle_event(ev, &mut stats, false)?;
            // Preempted eval rollouts must be re-dispatched (not buffered).
            while let Some(t) = self.buffer.pop() {
                self.dispatch(t, sampling);
            }
        }
        // Take exactly OUR groups (the book may hold surplus completed
        // training groups carried across stages — leave those alone).
        let mut taken = self.book.take_groups(&ids);
        let index: HashMap<u64, usize> =
            ids.iter().enumerate().map(|(i, id)| (*id, i)).collect();
        let mut slots: Vec<Option<Group>> = (0..ids.len()).map(|_| None).collect();
        for g in taken.drain(..) {
            let i = index[&g.group_id];
            slots[i] = Some(g);
        }
        let mut out = Vec::new();
        for s in slots {
            let g = s.context("eval group missing")?;
            anyhow::ensure!(g.is_complete(), "eval group incomplete");
            out.push(g);
        }
        Ok(out)
    }

    /// Buffered partial count (off-policy debt carried to the next stage).
    pub fn buffered(&self) -> usize {
        self.buffer.len()
    }

    pub fn shutdown(self) {
        self.pool.shutdown();
    }
}
