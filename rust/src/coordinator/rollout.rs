//! The CoPRIS coordinator over the reentrant stage state machine
//! ([`StageDriver`]): concurrency-controlled dispatch over the engine
//! pool, early termination, partial buffering, prioritized resumption.
//!
//! A stage is advanced with `begin_stage` → `pump(deadline)` (repeatedly,
//! never blocking past the deadline) → `finish_stage`. The blocking
//! `rollout_stage` / `run_fixed_sync` entry points are thin wrappers that
//! pump to completion, so serial callers are unchanged while
//! stage-pipelined callers (`rollout.pipeline`) interleave pumps with
//! trainer work and sync weights mid-flight — in-flight trajectories just
//! gain another version segment (`append_stage` + cross-stage IS already
//! model exactly that).
//!
//! Sync (veRL) and naive-partial baselines, CoPRIS, and fixed-prompt eval
//! are all policy parameterizations of the one driver (see
//! [`StagePolicy`]). The pre-refactor blocking loop survives verbatim in
//! [`super::reference::ReferenceCoordinator`] as the golden oracle.

use std::collections::{HashMap, VecDeque};
use std::sync::Arc;
use std::sync::mpsc::RecvTimeoutError;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use super::buffer::{LenPredictor, PartialBuffer};
use super::driver::{StageDriver, StageGoal, StagePhase, StagePolicy};
use super::groups::{Group, GroupBook};
use super::trajectory::Trajectory;
use crate::config::{Config, RolloutMode};
use crate::engine::{
    EngineCmd, EngineEvent, FinishReason, PoolApi, SamplingParams, StepTrace, WorkItem,
};
use crate::router::{ReplicaHealth, RetainedRef, RouterPool, RoutingTable};
use crate::loadgen::{SloCollector, SloReport, TenantClass};
use crate::tasks::{Dataset, Family, Task};
use crate::tokenizer::Tokenizer;

/// Deadline chunk used by the blocking wrappers; the in-driver stall
/// watchdog (`engine.stall_timeout_ms`) catches wedged engines long
/// before this elapses.
const PUMP_CHUNK: Duration = Duration::from_secs(3600);

/// Per-stage rollout statistics (feeds Fig. 1, Table 2, Fig. 3).
#[derive(Clone, Debug, Default)]
pub struct RolloutStats {
    /// Stage wall-clock seconds (start → quiesce, not harvest).
    pub wall: f64,
    /// Completed trajectories harvested this stage.
    pub completed: usize,
    /// Partials placed in the buffer at early termination.
    pub partials_buffered: usize,
    /// Buffered partials resumed (popped and re-dispatched) this stage.
    pub resumed: usize,
    /// Live-slot preemptions under KV pressure.
    pub preemptions: u64,
    /// Resume tokens replayed (the recomputation overhead).
    pub replayed_tokens: u64,
    /// Resumes served from retained KV (affinity hits: the whole resume
    /// prefix skipped replay).
    pub retained_hits: usize,
    /// Affinity-routed resumes that fell back to replay (retained slot
    /// evicted or invalidated between stop and resume).
    pub retained_misses: usize,
    /// Resume tokens NOT recomputed thanks to retained-KV hits — the
    /// replay work the affinity fast path avoided.
    pub replay_tokens_saved: u64,
    /// Peak KV blocks in use on any one engine during the stage (the
    /// paged residency the blocks-denominated budget governs; shared
    /// blocks count once).
    pub kv_blocks_peak: usize,
    /// Peak KV bytes resident on any one engine during the stage —
    /// `kv_blocks_peak` mapped to real memory at the configured
    /// `engine.kv_dtype` (per-block scale metadata included for int8).
    pub kv_bytes_peak: usize,
    /// Sampler SIMD arm the engines ran (`scalar` | `avx2` | `avx512`,
    /// detected once per engine; `""` until the first step trace lands).
    /// All engines of a pool share one process, hence one arm.
    pub sampler_dispatch: &'static str,
    /// Prompt tokens attached from a shared group prefix instead of
    /// freshly charged, across all engines this stage.
    pub prefix_tokens_shared: u64,
    /// Copy-on-write block copies across all engines this stage (the cost
    /// side of prefix sharing: one partial-tail copy per diverging
    /// sample).
    pub cow_copies: u64,
    /// Chunked-ingestion backend calls (prompt prefill chunks + resume
    /// replay slices) across all engines this stage — 0 when
    /// `engine.step_token_budget` is 0 (legacy slot admission).
    pub prefill_chunks: u64,
    /// Seconds of prefill/replay-chunk compute that ran in steps where
    /// live decode lanes also progressed — the stall legacy admission
    /// prefill would have serialized in front of those decodes.
    pub t_prefill_stall_saved: f64,
    /// Mean packed-step token utilization (step tokens / step budget)
    /// across this stage's engine steps; 0.0 when the budget is off.
    pub step_token_util: f64,
    /// Engine failures absorbed this stage: fatal backend errors, panics,
    /// exhausted transient-retry budgets, and stall-watchdog declarations.
    pub engine_failures: usize,
    /// In-flight trajectories re-dispatched onto surviving engines after
    /// an engine failure (drain-phase losses re-park as partials instead
    /// and are not counted here).
    pub redispatched_trajectories: usize,
    /// Transient backend errors retried in place across all engines this
    /// stage (`engine.max_retries` bounds the per-step budget).
    pub retries: u64,
    /// Backend `retain_slot` errors swallowed at flush this stage — each
    /// one flushed its slot plainly instead of retaining KV for affinity
    /// resume (correctness unaffected; the resume replays).
    pub retain_errors: u64,
    /// Per-engine-step utilization samples.
    pub traces: Vec<StepTrace>,
    /// Response length of every trajectory completed this stage.
    pub response_lengths: Vec<usize>,
    /// Peak concurrent in-flight requests observed (updated on every
    /// refill wave, including naive-partial re-waves).
    pub peak_inflight: usize,
    /// Seconds of this stage's lifetime that overlapped trainer compute
    /// (stage-pipelined mode; 0.0 when serial). Clamped to `wall`.
    pub overlap_secs: f64,
    /// Histogram of harvested-trajectory version lag (last segment's
    /// policy version − born version); bucket 4 is "4+". Serial runs put
    /// everything resumed across one sync in bucket 1; pipelined runs
    /// surface lag > 0 from mid-flight weight syncs.
    pub version_lag_hist: [usize; 5],
    /// In-flight trajectories force-cut at a weight sync because their
    /// assignment had exceeded `rollout.max_staleness` syncs (fully-async
    /// mode; the cut partial lands in the buffer for IS-corrected resume).
    pub staleness_terminations: usize,
    /// At-risk in-flight trajectories (exactly at the staleness bound)
    /// early-terminated by the active partial-rollout policy because their
    /// predicted remaining decode exceeded the per-sync-window decode
    /// budget (fully-async mode with `rollout.active_termination`).
    pub active_terminations: usize,
    /// Peak completed-but-unharvested groups observed in the staging book
    /// between async harvests (buffer-occupancy gauge; 0 outside async).
    pub staging_occupancy_peak: usize,
    /// Open-loop arrivals observed this stage (0 for closed-loop stages —
    /// these SLO fields are populated only by `run_open_loop`).
    pub requests_arrived: usize,
    /// Open-loop arrivals shed at admission (bounded-queue tail drop —
    /// the structured overload signal).
    pub requests_shed: usize,
    /// Peak open-loop admission-queue depth observed.
    pub queue_depth_peak: usize,
    /// End-to-end (arrival → completion) latency p50 in virtual ticks
    /// (1 tick = 1 µs of virtual time; 0.0 for closed-loop stages).
    pub slo_e2e_p50_ticks: f64,
    /// End-to-end latency p99 in virtual ticks.
    pub slo_e2e_p99_ticks: f64,
    /// Completed requests per virtual second over the open-loop horizon.
    pub goodput_rps: f64,
}

impl RolloutStats {
    /// Mean busy-slot fraction across engine steps (GPU utilization proxy).
    pub fn mean_utilization(&self) -> f64 {
        if self.traces.is_empty() {
            return 0.0;
        }
        self.traces.iter().map(|t| t.active as f64 / t.slots as f64).sum::<f64>()
            / self.traces.len() as f64
    }

    /// Harvested trajectories that span more than one policy version.
    pub fn lagged_trajectories(&self) -> usize {
        self.version_lag_hist[1..].iter().sum()
    }

    /// Mean internal fragmentation of the engines' KV block chains across
    /// the stage's step traces (0.0 when nothing was resident).
    pub fn mean_kv_frag(&self) -> f64 {
        let mut n = 0usize;
        let mut sum = 0.0f64;
        for t in &self.traces {
            if t.kv_blocks > 0 {
                n += 1;
                sum += t.kv_frag;
            }
        }
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }
}

/// Output of one rollout stage: exactly B complete groups + stats.
#[derive(Debug)]
pub struct RolloutOutput {
    /// The B completed prompt-groups (training batch).
    pub groups: Vec<Group>,
    /// Stage statistics.
    pub stats: RolloutStats,
}

/// One scheduled arrival for [`Coordinator::run_open_loop`]: the
/// workload-generator output (`loadgen`) lowered to concrete dispatch
/// material. Arrival ticks are virtual (the coordinator advances its
/// virtual clock one quantum per engine step trace).
#[derive(Clone, Debug)]
pub struct OpenLoopRequest {
    /// Absolute virtual arrival tick.
    pub arrival_tick: u64,
    /// Traffic class (SLO accounting only; does not affect scheduling).
    pub class: TenantClass,
    /// Prompt tokens (must respect the engines' prompt limit).
    pub prompt: Vec<i32>,
    /// Target output length; the dispatch caps `max_total` at
    /// `prompt.len() + out_len` so EOS-free backends terminate exactly
    /// there.
    pub out_len: usize,
}

/// Output of one open-loop stage: one completed single-sample group per
/// admitted request, the stage stats (SLO aggregates included), and the
/// full SLO report.
#[derive(Debug)]
pub struct OpenLoopOutput {
    /// Completed groups, one per admitted (non-shed) request, in
    /// admission order.
    pub groups: Vec<Group>,
    /// Stage statistics with the open-loop SLO fields populated.
    pub stats: RolloutStats,
    /// The detailed SLO scoreboard for the run.
    pub report: SloReport,
}

/// In-flight bookkeeping: trajectory + which engine has it + the
/// retained-KV affinity hint the dispatch carried, if any (hit/miss
/// accounting, and affinity restoration when a hinted dispatch is dropped
/// unstarted at stage end — the retained slot is still valid then).
struct InFlight {
    traj: Trajectory,
    engine: usize,
    retain: Option<u64>,
    /// Policy version at dispatch — the leftover affinity restore is
    /// suppressed when a sync has invalidated retention since then.
    version: u64,
}

/// Latest cumulative engine-lifetime gauges observed per engine (from step
/// traces); `finish_stage` reports per-stage deltas against the
/// `begin_stage` snapshot.
#[derive(Clone, Copy, Debug, Default)]
struct EngineCounters {
    prefix_tokens_shared: u64,
    cow_copies: u64,
    prefill_chunks: u64,
    prefill_stall_saved: f64,
    retries: u64,
}

/// Fold harvested groups into the version-lag histogram (last segment's
/// policy version − born version; bucket 4 is "4+").
fn note_version_lags(groups: &[Group], stats: &mut RolloutStats) {
    for g in groups {
        for t in &g.done {
            let lag = t
                .segments
                .last()
                .map(|s| s.policy_version.saturating_sub(t.born_version))
                .unwrap_or(0) as usize;
            stats.version_lag_hist[lag.min(stats.version_lag_hist.len() - 1)] += 1;
        }
    }
}

/// The CoPRIS coordinator (also drives the sync / naive-partial baselines,
/// the fully-async stream, and fixed-prompt eval, all through the one
/// [`StageDriver`]). Generic over the pool poll/cmd surface ([`PoolApi`]);
/// the default parameter keeps every existing `Coordinator` mention
/// meaning "coordinator over a [`RouterPool`]".
pub struct Coordinator<P: PoolApi = RouterPool> {
    /// The engine fleet this coordinator dispatches to — in-process
    /// threads (`local` transport) or `copris engine-host` processes
    /// (`tcp`), behind the same poll/cmd API either way.
    pub pool: P,
    /// Full run configuration (rollout policy knobs live under
    /// `cfg.rollout`).
    pub cfg: Config,
    /// Buffer of unfinished partial trajectories (Eq. 7).
    pub buffer: PartialBuffer,
    book: GroupBook,
    inflight: HashMap<u64, InFlight>,
    /// Per-replica routing state — load, health/drain ladder, retained-KV
    /// affinity, prefix homes (see [`RoutingTable`]). Deaths persist
    /// across stages (the replica is gone) and dead replicas' late events
    /// are discarded (a stalled engine the watchdog buried can wake up
    /// and flush). On group completion every engine listed in the group's
    /// prefix homes gets `EngineCmd::ReleasePrefix` so registry entries
    /// don't linger until the next weight sync.
    table: RoutingTable,
    /// Latest cumulative engine gauges observed per engine (from step
    /// traces)…
    kv_seen: Vec<EngineCounters>,
    /// …and the snapshot taken at `begin_stage`, so `finish_stage` can
    /// report per-stage deltas of the engines' lifetime counters.
    kv_base: Vec<EngineCounters>,
    next_traj_id: u64,
    /// Per-trajectory total-length caps for open-loop requests, whose
    /// sampled output lengths override the global `max_new_tokens` cap.
    /// Consulted by `dispatch` (including preemption/failure
    /// re-dispatches); populated and cleared by `run_open_loop`.
    max_total_override: HashMap<u64, usize>,
    /// Current policy version (== trainer step); bumped by `sync_weights`.
    pub policy_version: u64,
    tokenizer: Tokenizer,
    /// Engines' decode horizon (manifest.max_seq).
    max_seq: usize,
    /// Active stage control block (None between stages).
    driver: Option<StageDriver>,
    /// Response-length EMAs feeding the active partial-rollout policy
    /// (fully-async mode); observed on every completion in every mode.
    len_pred: LenPredictor,
    /// New tokens harvested since the last `prepare_sync` (per-window
    /// decode throughput numerator for the active policy).
    window_tokens: u64,
    /// EMA of per-in-flight-slot tokens decoded per sync window — the
    /// decode budget an at-risk trajectory's predicted remaining length is
    /// weighed against.
    window_decode_ema: f64,
}

impl Coordinator {
    /// `max_seq` is the engines' decode horizon (manifest.max_seq).
    /// Accepts an [`EnginePool`](crate::engine::EnginePool) directly (the
    /// `local` transport, what every existing call site passes) or a
    /// pre-built [`RouterPool`] (the `tcp` transport).
    pub fn new(pool: impl Into<RouterPool>, cfg: Config, max_seq: usize) -> Coordinator {
        Coordinator::from_pool(pool.into(), cfg, max_seq)
    }
}

impl<P: PoolApi> Coordinator<P> {
    /// Generic constructor over any [`PoolApi`] implementation — what
    /// `Coordinator::new` lowers to after wrapping its argument in a
    /// [`RouterPool`].
    pub fn from_pool(pool: P, cfg: Config, max_seq: usize) -> Coordinator<P> {
        let engines = pool.engines();
        let buffer = PartialBuffer::new(cfg.rollout.max_stage_lag);
        Coordinator {
            pool,
            cfg,
            buffer,
            book: GroupBook::new(),
            inflight: HashMap::new(),
            table: RoutingTable::new(engines),
            kv_seen: vec![EngineCounters::default(); engines],
            kv_base: vec![EngineCounters::default(); engines],
            next_traj_id: 0,
            max_total_override: HashMap::new(),
            policy_version: 0,
            tokenizer: Tokenizer::new(),
            max_seq,
            driver: None,
            len_pred: LenPredictor::new(0.3),
            window_tokens: 0,
            window_decode_ema: 0.0,
        }
    }

    /// Total-length cap for a work item (paper: max response length).
    fn max_total_for(&self, prompt_len: usize) -> usize {
        let cap = if self.cfg.engine.max_new_tokens > 0 {
            prompt_len + self.cfg.engine.max_new_tokens
        } else {
            usize::MAX
        };
        cap.min(self.max_seq)
    }

    /// The tokenizer shared with dispatch (prompt encoding).
    pub fn tokenizer(&self) -> &Tokenizer {
        &self.tokenizer
    }

    /// Weight sync: broadcast new params and bump the policy version.
    /// Legal mid-stage (stage-pipelined mode): trajectories completing
    /// afterwards are tagged with the new version, giving them another
    /// IS segment.
    ///
    /// Unless `rollout.retain_kv_across_sync` is set, the sync invalidates
    /// all retained KV — both the engines' ledgers and this coordinator's
    /// affinity map — because retained prefixes were computed under the old
    /// params; subsequent resumes re-prefill under the new policy, exactly
    /// like the replay-only baseline.
    pub fn sync_weights(&mut self, version: u64, params: Arc<Vec<f32>>) {
        self.policy_version = version;
        let invalidate = !self.cfg.rollout.retain_kv_across_sync;
        if invalidate {
            self.table.retained_at.clear();
        }
        self.pool.broadcast_params(version, params, invalidate);
    }

    fn total_inflight(&self) -> usize {
        self.inflight.len()
    }

    /// Active stage control block (panics when no stage is active — every
    /// caller is behind a `driver.is_some()` guard).
    fn drv(&self) -> &StageDriver {
        self.driver.as_ref().expect("no active rollout stage")
    }

    fn drv_mut(&mut self) -> &mut StageDriver {
        self.driver.as_mut().expect("no active rollout stage")
    }

    /// Engines still alive (not declared failed; draining counts).
    fn live_engines(&self) -> usize {
        self.table.live()
    }

    /// Residency-aware routing — the placement decision lives in
    /// [`RoutingTable::route`] (retained-KV affinity, then prefix home,
    /// then least loaded, each residency route behind the
    /// `rollout.affinity_max_imbalance` guard). This wrapper applies the
    /// decision's side effect: an abandoned retained slot is released
    /// remotely so it stops charging that engine's KV.
    /// Returns `(engine, retain_hint)`.
    fn route(&mut self, traj: &Trajectory) -> (usize, Option<u64>) {
        let d = self.table.route(
            traj.id,
            traj.group_id,
            self.cfg.rollout.retain_kv,
            self.cfg.engine.prefix_sharing,
            self.cfg.rollout.affinity_max_imbalance,
        );
        if let Some(r) = d.release {
            self.pool.send(
                r.engine,
                EngineCmd::ReleaseRetained { request_id: traj.id, token: r.token },
            );
        }
        (d.engine, d.retain)
    }

    fn dispatch(&mut self, traj: Trajectory, sampling: SamplingParams) {
        let (engine, retain) = self.route(&traj);
        // Shared-prefix handle: every sample of a group carries the group
        // id, so the engine charges the prompt blocks once per group.
        let prefix = if self.cfg.engine.prefix_sharing { Some(traj.group_id) } else { None };
        if prefix.is_some() {
            // First recorder == the group's home engine (route() reads [0]).
            self.table.note_prefix_home(traj.group_id, engine);
        }
        // Open-loop requests carry their own sampled length cap; everything
        // else uses the global `max_new_tokens` policy.
        let max_total = self
            .max_total_override
            .get(&traj.id)
            .copied()
            .unwrap_or_else(|| self.max_total_for(traj.prompt.len()));
        let item = WorkItem {
            request_id: traj.id,
            // Arc clone — re-dispatching a buffered partial shares the
            // prompt with the trajectory instead of deep-copying it.
            prompt: traj.prompt.clone(),
            resume: traj.tokens.clone(),
            max_total,
            sampling,
            retain,
            prefix,
        };
        self.table.load[engine] += 1;
        let version = self.policy_version;
        self.inflight.insert(traj.id, InFlight { traj, engine, retain, version });
        self.pool.send(engine, EngineCmd::Assign(item));
        if let Some(d) = self.driver.as_mut() {
            if let Some(w) = d.wave_remaining.as_mut() {
                *w = w.saturating_sub(1);
            }
        }
    }

    /// Make a fresh trajectory for `group_id` and dispatch it.
    fn dispatch_fresh(&mut self, group_id: u64, task: &Task, sampling: SamplingParams) {
        let prompt = self.tokenizer.encode_prompt(&task.prompt);
        let id = self.next_traj_id;
        self.next_traj_id += 1;
        let traj = Trajectory::new(id, group_id, task.clone(), prompt, self.policy_version);
        self.book.note_dispatch(group_id);
        self.dispatch(traj, sampling);
    }

    /// Dispatch policy for one refill opportunity. Returns false when
    /// nothing can/should be dispatched right now.
    fn refill_one(&mut self, dataset: Option<&mut Dataset>, sampling: SamplingParams) -> bool {
        if self.drv().refill_paused {
            return false; // async weight broadcast in progress — no refill
        }
        if let Some(0) = self.drv().wave_remaining {
            return false; // naive-partial wave exhausted — no refill
        }
        // Prioritized resumption: buffered partials first (paper §4).
        if self.drv().policy.use_buffer {
            if let Some(t) = self.buffer.pop() {
                self.drv_mut().stats.resumed += 1;
                self.dispatch(t, sampling);
                return true;
            }
        }
        // Then groups that still need samples, most-started first.
        if let Some(gid) = self.book.groups_with_deficit().first().copied() {
            let task = self.book.get(gid).unwrap().task.clone();
            self.dispatch_fresh(gid, &task, sampling);
            return true;
        }
        // Otherwise open a new group from the dataset (over-generation).
        let Some(ds) = dataset else { return false };
        let task = ds.next_task();
        let gid = self.book.new_group(task.clone(), self.cfg.rollout.group_size);
        self.dispatch_fresh(gid, &task, sampling);
        true
    }

    /// Refill up to `target` in flight and record the peak.
    fn fill_to_target(
        &mut self,
        dataset: &mut Option<&mut Dataset>,
        sampling: SamplingParams,
        target: usize,
    ) {
        while self.total_inflight() < target {
            if !self.refill_one(dataset.as_deref_mut(), sampling) {
                break;
            }
        }
        let n = self.total_inflight();
        let d = self.drv_mut();
        d.stats.peak_inflight = d.stats.peak_inflight.max(n);
    }

    // -- stage state machine ------------------------------------------------

    /// Begin a training stage in the configured rollout mode: staleness
    /// guard, policy selection, stage-initial dispatch. Non-blocking —
    /// follow with `pump` until done, then `finish_stage`.
    pub fn begin_stage(&mut self, dataset: &mut Dataset) -> Result<()> {
        ensure!(self.driver.is_none(), "rollout stage already active");
        ensure!(
            self.live_engines() > 0,
            "rollout: degraded — no live engines (all {} failed in earlier stages)",
            self.pool.engines()
        );
        // Paged-KV delta baseline: engine counters are cumulative, stage
        // stats report the difference from here.
        self.kv_base.clone_from(&self.kv_seen);
        let cfg = self.cfg.rollout.clone();
        let sampling = SamplingParams {
            temperature: cfg.temperature,
            top_p: cfg.top_p,
            top_k: cfg.top_k,
        };

        // Staleness guard (off by default, matching the paper). Evicted
        // partials will never resume — free their retained slots too.
        for stale in self.buffer.evict_stale(self.policy_version) {
            if let Some(r) = self.table.retained_at.remove(&stale.id) {
                self.pool.send(
                    r.engine,
                    EngineCmd::ReleaseRetained { request_id: stale.id, token: r.token },
                );
            }
            self.book.note_abandoned(stale.group_id);
        }

        let policy = match cfg.mode {
            // Fully synchronous: B·G fresh requests, wait for all.
            RolloutMode::Sync => StagePolicy {
                target: None,
                continuous: false,
                use_buffer: false,
                drain: false,
                until_idle: true,
                inline_preempt: false,
            },
            // One fixed wave, buffered partials first, no refill; re-wave
            // only if the wave exhausts with the batch incomplete.
            RolloutMode::NaivePartial => StagePolicy {
                target: Some(cfg.concurrency),
                continuous: false,
                use_buffer: true,
                drain: true,
                until_idle: false,
                inline_preempt: false,
            },
            // CoPRIS: keep exactly N' in flight (Fig. 2).
            RolloutMode::Copris => StagePolicy {
                target: Some(cfg.concurrency),
                continuous: true,
                use_buffer: true,
                drain: true,
                until_idle: false,
                inline_preempt: false,
            },
        };
        let mut driver =
            StageDriver::new(StageGoal::Batch { b: cfg.batch_prompts }, policy, sampling);
        if cfg.mode == RolloutMode::NaivePartial {
            driver.wave_remaining = Some(cfg.concurrency);
        }
        self.driver = Some(driver);

        // Stage-initial dispatch plan.
        match cfg.mode {
            RolloutMode::Sync => {
                for _ in 0..cfg.batch_prompts {
                    let task = dataset.next_task();
                    let gid = self.book.new_group(task.clone(), cfg.group_size);
                    for _ in 0..cfg.group_size {
                        self.dispatch_fresh(gid, &task, sampling);
                    }
                }
                let n = self.total_inflight();
                self.drv_mut().stats.peak_inflight = n;
            }
            RolloutMode::NaivePartial | RolloutMode::Copris => {
                let mut ds = Some(dataset);
                self.fill_to_target(&mut ds, sampling, cfg.concurrency);
            }
        }
        Ok(())
    }

    /// Is a stage (training or eval) currently active?
    pub fn stage_active(&self) -> bool {
        self.driver.is_some()
    }

    /// Has the active stage met its goal and quiesced (ready to finish)?
    pub fn stage_is_done(&self) -> bool {
        self.driver.as_ref().is_some_and(|d| d.is_done())
    }

    /// Credit trainer-overlap seconds to the active stage's stats
    /// (stage-pipelined accounting; no-op between stages). Clamped to the
    /// stage's actual active time — a stage that reached Done early in the
    /// update window is not credited for the rest of it. Returns the
    /// seconds actually credited.
    pub fn note_overlap(&mut self, secs: f64) -> f64 {
        let Some(d) = self.driver.as_mut() else { return 0.0 };
        let active = d
            .done_at
            .unwrap_or_else(Instant::now)
            .duration_since(d.t0)
            .as_secs_f64();
        let room = (active - d.stats.overlap_secs).max(0.0);
        let credit = secs.min(room);
        d.stats.overlap_secs += credit;
        credit
    }

    /// Advance the active training stage without blocking past `deadline`:
    /// process pool events, refill per policy, early-terminate and drain
    /// when the goal is met. Returns Ok(true) once the stage is done
    /// (call `finish_stage` to harvest). With `deadline <= now` this
    /// drains already-queued events only — the stage-pipelined caller's
    /// between-microbatch pump.
    pub fn pump(&mut self, dataset: &mut Dataset, deadline: Instant) -> Result<bool> {
        self.pump_inner(Some(dataset), deadline)
    }

    fn pump_inner(&mut self, mut dataset: Option<&mut Dataset>, deadline: Instant) -> Result<bool> {
        ensure!(self.driver.is_some(), "pump with no active rollout stage");
        loop {
            match self.drv().phase {
                StagePhase::Done => return Ok(true),
                StagePhase::Running => {
                    // Fully-async stream: hand control back as soon as a
                    // full batch is staged (the stream itself never
                    // completes — Ok(true) here means "batch ready").
                    if matches!(self.drv().goal, StageGoal::Stream) && self.async_batch_ready() {
                        return Ok(true);
                    }
                    if self.goal_met() {
                        if self.drv().policy.drain && self.total_inflight() > 0 {
                            // Early termination: halt engines (retaining
                            // flushed slots' KV when configured), then
                            // collect partials in the Draining phase.
                            self.pool.stop_generation_all_with(self.cfg.rollout.retain_kv);
                            let d = self.drv_mut();
                            d.phase = StagePhase::Draining;
                            d.flushed.clear();
                            continue;
                        }
                        let d = self.drv_mut();
                        d.phase = StagePhase::Done;
                        d.done_at = Some(Instant::now());
                        return Ok(true);
                    }
                    // Naive-partial fallback: wave exhausted but batch
                    // incomplete → issue another wave (rare in the paper's
                    // setting).
                    let policy = self.drv().policy;
                    if let Some(target) = policy.target {
                        if !policy.continuous && self.total_inflight() == 0 {
                            let sampling = self.drv().sampling;
                            self.drv_mut().wave_remaining = Some(target);
                            self.fill_to_target(&mut dataset, sampling, target);
                        }
                    }
                    match self.next_event(deadline)? {
                        Some(ev) => {
                            self.handle_event(ev, false)?;
                            // CoPRIS refill: keep exactly N' in flight.
                            let policy = self.drv().policy;
                            if policy.continuous {
                                if let Some(target) = policy.target {
                                    let sampling = self.drv().sampling;
                                    self.fill_to_target(&mut dataset, sampling, target);
                                }
                            }
                        }
                        None => return Ok(false), // deadline reached
                    }
                }
                StagePhase::Draining => {
                    while !self.drain_complete() {
                        match self.next_event(deadline)? {
                            Some(ev) => self.handle_event(ev, true)?,
                            None => {
                                // Deadline reached — or the watchdog just
                                // buried a stalled engine; re-check
                                // completion before parking again.
                                if self.drain_complete() {
                                    break;
                                }
                                return Ok(false);
                            }
                        }
                    }
                    // Anything still in the inflight map was queued but
                    // never started (engines drop unstarted queue items on
                    // StopGeneration).
                    let mut leftovers: Vec<u64> = self.inflight.keys().copied().collect();
                    leftovers.sort_unstable();
                    for id in leftovers {
                        let inf = self.inflight.remove(&id).unwrap();
                        self.table.load[inf.engine] =
                            self.table.load[inf.engine].saturating_sub(1);
                        let parked = self.park_partial(inf.traj);
                        // A hinted dispatch dropped unstarted still has its
                        // retained slot resident (only BUSY slots flush on
                        // StopGeneration) and the trajectory is unchanged —
                        // restore the affinity entry so the slot is neither
                        // orphaned (charging KV forever) nor replayed past.
                        // EXCEPT when a mid-flight sync invalidated
                        // retention since the dispatch: the engine-side
                        // slot is already gone, and resurrecting the entry
                        // would contradict the invalidation policy. (If
                        // the engine evicted it for other reasons, the
                        // restored hint is stale and falls back to replay
                        // in-engine — harmless.)
                        if let Some(token) = inf.retain {
                            // A dead engine's retained slot died with it —
                            // neither restorable nor releasable.
                            let invalidated = self.table.dead[inf.engine]
                                || (!self.cfg.rollout.retain_kv_across_sync
                                    && self.policy_version != inf.version);
                            if parked && !invalidated {
                                self.table
                                    .retained_at
                                    .insert(id, RetainedRef { engine: inf.engine, token });
                            } else if !invalidated {
                                self.pool.send(
                                    inf.engine,
                                    EngineCmd::ReleaseRetained { request_id: id, token },
                                );
                            }
                        }
                    }
                    let d = self.drv_mut();
                    d.phase = StagePhase::Done;
                    d.done_at = Some(Instant::now());
                    return Ok(true);
                }
            }
        }
    }

    /// Stage termination test under the active policy.
    fn goal_met(&self) -> bool {
        let d = self.drv();
        if d.policy.until_idle {
            return self.total_inflight() == 0;
        }
        match &d.goal {
            StageGoal::Batch { b } => self.book.completed_count() >= *b,
            StageGoal::Fixed | StageGoal::OpenLoop => self.total_inflight() == 0,
            // The async stream has no terminal goal — it ends only via
            // `abort_stage` (which forces the drain path directly).
            StageGoal::Stream => false,
        }
    }

    /// Drain completion: every engine has either delivered its `Flushed`
    /// marker or died (dead engines flush nothing).
    fn drain_complete(&self) -> bool {
        (0..self.pool.engines()).all(|e| self.table.dead[e] || self.drv().flushed.contains(&e))
    }

    /// Declare `engine` dead and recover its work. Idempotent: a late
    /// `EngineFailed` event for an engine the watchdog already buried is
    /// a no-op.
    fn fail_engine(&mut self, engine: usize, error: &str) -> Result<()> {
        if self.table.dead[engine] {
            return Ok(());
        }
        self.table.dead[engine] = true;
        self.drv_mut().stats.engine_failures += 1;
        eprintln!("coordinator: engine {engine} failed: {error}");
        self.recover_failed(engine, error)
    }

    /// Recovery for an engine already marked dead: drop its routing state
    /// (retained-KV affinity, prefix homes), then re-dispatch the
    /// in-flight trajectories it took down onto survivors — resuming from
    /// the tokens already appended, the same replay path a buffered
    /// partial takes. During a drain the lost work stays in `inflight`
    /// instead: the leftover loop re-parks it as partials. With no
    /// survivors the stage fails with a structured degraded error rather
    /// than hanging (a vacuous drain still completes: leftovers park).
    fn recover_failed(&mut self, engine: usize, error: &str) -> Result<()> {
        self.table.drop_replica_routes(engine);
        let draining = self.drv().phase == StagePhase::Draining;
        if self.live_engines() == 0 && !draining {
            bail!(
                "rollout: degraded — all {} engines failed (last: engine {engine}: {error})",
                self.pool.engines()
            );
        }
        if draining || self.live_engines() == 0 {
            return Ok(());
        }
        // The inflight map is authoritative for what the engine owed —
        // it includes queued-but-unstarted dispatches the failure event's
        // own in-flight list may not.
        let mut lost: Vec<u64> = self
            .inflight
            .iter()
            .filter(|(_, inf)| inf.engine == engine)
            .map(|(id, _)| *id)
            .collect();
        lost.sort_unstable();
        let sampling = self.drv().sampling;
        for id in lost {
            let inf = self.inflight.remove(&id).unwrap();
            self.table.load[inf.engine] = self.table.load[inf.engine].saturating_sub(1);
            self.drv_mut().stats.redispatched_trajectories += 1;
            // Recovery is not new work: don't charge it against a
            // naive-partial wave allowance.
            let wave = self.drv().wave_remaining;
            self.dispatch(inf.traj, sampling);
            self.drv_mut().wave_remaining = wave;
        }
        Ok(())
    }

    /// Stall watchdog: no engine event for `stall` with work outstanding.
    /// Every live engine that still owes events (in-flight load while
    /// Running, an unflushed drain while Draining) is declared dead and
    /// recovered; if none does, the stall is a coordinator bug and
    /// surfaces as the legacy timeout error.
    fn watchdog_fire(&mut self, stall: Duration) -> Result<()> {
        let draining = self.drv().phase == StagePhase::Draining;
        let stalled: Vec<usize> = (0..self.pool.engines())
            .filter(|e| !self.table.dead[*e])
            .filter(|e| {
                if draining {
                    !self.drv().flushed.contains(e)
                } else {
                    self.table.load[*e] > 0
                }
            })
            .collect();
        if stalled.is_empty() {
            bail!("rollout: engine event timeout ({:.0}s without events)", stall.as_secs_f64());
        }
        // Mark ALL stalled engines dead before recovering any, so
        // re-dispatch never routes one stalled engine's work at another.
        for &e in &stalled {
            self.table.dead[e] = true;
            self.drv_mut().stats.engine_failures += 1;
            eprintln!(
                "coordinator: engine {e} stalled ({:.0}s without events) — declared dead",
                stall.as_secs_f64()
            );
        }
        for &e in &stalled {
            self.recover_failed(e, "stalled past watchdog")?;
        }
        Ok(())
    }

    /// Next pool event: non-blocking if `deadline` has passed, otherwise
    /// waits up to the deadline, bounded by the stall watchdog
    /// (`engine.stall_timeout_ms`). Returns `Ok(None)` at the deadline
    /// AND after a watchdog firing — callers re-check their phase
    /// condition before waiting again. A disconnected pool (every engine
    /// thread gone) is the degraded terminal state.
    fn next_event(&mut self, deadline: Instant) -> Result<Option<EngineEvent>> {
        match self.pool.try_next_checked() {
            Ok(Some(ev)) => {
                self.drv_mut().last_event = Instant::now();
                return Ok(Some(ev));
            }
            Ok(None) => {}
            Err(_) => bail!("rollout: degraded — engine pool disconnected"),
        }
        let stall = Duration::from_millis(self.cfg.engine.stall_timeout_ms.max(1));
        loop {
            let now = Instant::now();
            if now >= deadline {
                return Ok(None);
            }
            let idle = now.duration_since(self.drv().last_event);
            if idle >= stall {
                self.watchdog_fire(stall)?;
                self.drv_mut().last_event = Instant::now();
                return Ok(None);
            }
            let wait = (stall - idle).min(deadline - now);
            match self.pool.next_before(now + wait) {
                Ok(ev) => {
                    self.drv_mut().last_event = Instant::now();
                    return Ok(Some(ev));
                }
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => {
                    bail!("rollout: degraded — engine pool disconnected")
                }
            }
        }
    }

    /// Harvest a finished training stage: exactly B completed groups +
    /// stats (wall, version-lag histogram, overlap clamp).
    pub fn finish_stage(&mut self) -> Result<RolloutOutput> {
        ensure!(
            self.driver.as_ref().is_some_and(|d| d.is_done()),
            "finish_stage before the stage is done"
        );
        let drv = self.driver.take().unwrap();
        let StageGoal::Batch { b } = drv.goal else {
            bail!("finish_stage on a fixed (eval) or streaming stage");
        };
        let mut stats = drv.stats;
        let groups = self.book.take_completed(b);
        stats.completed = groups.iter().map(|g| g.done.len()).sum();
        note_version_lags(&groups, &mut stats);
        // Wall ends when the stage quiesced, not when the (possibly later)
        // harvest happens — a pipelined stage sits Done-but-unharvested
        // until the next step picks it up.
        let end = drv.done_at.unwrap_or_else(Instant::now);
        stats.wall = end.duration_since(drv.t0).as_secs_f64();
        stats.overlap_secs = stats.overlap_secs.min(stats.wall);
        self.harvest_engine_deltas(&mut stats);
        Ok(RolloutOutput { groups, stats })
    }

    /// Fold per-stage/per-window deltas of the engines' cumulative gauges
    /// into `stats` (paged-KV sharing, chunked prefill, retries) plus the
    /// mean packed-step token utilization, then re-baseline `kv_base` so
    /// the next async window reports fresh deltas.
    fn harvest_engine_deltas(&mut self, stats: &mut RolloutStats) {
        stats.prefix_tokens_shared = self
            .kv_seen
            .iter()
            .zip(&self.kv_base)
            .map(|(s, b)| s.prefix_tokens_shared.saturating_sub(b.prefix_tokens_shared))
            .sum();
        stats.cow_copies = self
            .kv_seen
            .iter()
            .zip(&self.kv_base)
            .map(|(s, b)| s.cow_copies.saturating_sub(b.cow_copies))
            .sum();
        stats.prefill_chunks = self
            .kv_seen
            .iter()
            .zip(&self.kv_base)
            .map(|(s, b)| s.prefill_chunks.saturating_sub(b.prefill_chunks))
            .sum();
        stats.t_prefill_stall_saved = self
            .kv_seen
            .iter()
            .zip(&self.kv_base)
            .map(|(s, b)| (s.prefill_stall_saved - b.prefill_stall_saved).max(0.0))
            .sum();
        stats.retries = self
            .kv_seen
            .iter()
            .zip(&self.kv_base)
            .map(|(s, b)| s.retries.saturating_sub(b.retries))
            .sum();
        // Mean packed-step token utilization over the stage's budgeted
        // engine steps (0.0 when the continuous-batching budget is off).
        let mut util_sum = 0.0f64;
        let mut util_n = 0usize;
        for t in &stats.traces {
            if t.step_budget > 0 {
                util_sum += t.step_tokens as f64 / t.step_budget as f64;
                util_n += 1;
            }
        }
        stats.step_token_util = if util_n == 0 { 0.0 } else { util_sum / util_n as f64 };
        self.kv_base.clone_from(&self.kv_seen);
    }

    /// Pump the active stage to completion and harvest it (blocking).
    pub fn run_stage_to_completion(&mut self, dataset: &mut Dataset) -> Result<RolloutOutput> {
        while !self.pump(dataset, Instant::now() + PUMP_CHUNK)? {}
        self.finish_stage()
    }

    /// Abort the active stage without harvesting: early-terminate the
    /// engines, drain partials into the buffer, keep completed groups in
    /// the book for the next stage. Nothing is lost — partials resume
    /// later under cross-stage IS, exactly like any early termination.
    /// Used before eval in pipelined runs: far cheaper than running the
    /// stage to completion just to idle the engines.
    pub fn abort_stage(&mut self) -> Result<()> {
        ensure!(self.driver.is_some(), "abort_stage with no active stage");
        if self.drv().phase == StagePhase::Running {
            if self.total_inflight() > 0 {
                self.pool.stop_generation_all_with(self.cfg.rollout.retain_kv);
                let d = self.drv_mut();
                d.phase = StagePhase::Draining;
                d.flushed.clear();
            } else {
                let d = self.drv_mut();
                d.phase = StagePhase::Done;
                d.done_at = Some(Instant::now());
            }
        }
        while !self.pump_inner(None, Instant::now() + PUMP_CHUNK)? {}
        self.driver = None;
        Ok(())
    }

    /// Run one rollout stage in the configured mode; returns exactly
    /// B = `batch_prompts` completed groups. (Blocking wrapper over the
    /// state machine — the serial path.)
    pub fn rollout_stage(&mut self, dataset: &mut Dataset) -> Result<RolloutOutput> {
        self.begin_stage(dataset)?;
        self.run_stage_to_completion(dataset)
    }

    /// Deprecated shim over the unified session API — prefer
    /// [`Coordinator::run`] with
    /// [`StagePlan::eval`](super::plan::StagePlan::eval). Kept so existing
    /// callers and the frozen reference goldens compile unchanged.
    pub fn run_fixed_sync(
        &mut self,
        tasks: &[Task],
        samples: usize,
        sampling: SamplingParams,
    ) -> Result<Vec<Group>> {
        self.fixed_stage(tasks, samples, sampling)
    }

    /// Deprecated shim over the unified session API — prefer
    /// [`Coordinator::run`] with
    /// [`StagePlan::open_loop`](super::plan::StagePlan::open_loop).
    pub fn run_open_loop(
        &mut self,
        schedule: &[OpenLoopRequest],
        queue_cap: usize,
        quantum_ticks: u64,
        sampling: SamplingParams,
    ) -> Result<OpenLoopOutput> {
        self.open_loop_stage(schedule, queue_cap, quantum_ticks, sampling)
    }

    // -- fully-async streaming ---------------------------------------------

    /// Begin the fully-async trajectory stream (`rollout.execution =
    /// async`): a [`StageGoal::Stream`] stage with CoPRIS dispatch policy
    /// that never completes — trajectories accumulate in the group book and
    /// the trainer harvests with [`take_async_batch`](Self::take_async_batch)
    /// whenever [`async_batch_ready`](Self::async_batch_ready). Weight syncs
    /// happen mid-stream through [`prepare_sync`](Self::prepare_sync) /
    /// `sync_weights` / [`resume_refill`](Self::resume_refill). End the
    /// stream with `abort_stage` (drains in-flight work into the partial
    /// buffer).
    pub fn begin_async(&mut self, dataset: &mut Dataset) -> Result<()> {
        ensure!(self.driver.is_none(), "rollout stage already active");
        ensure!(
            self.cfg.rollout.mode == RolloutMode::Copris,
            "rollout.execution=async requires rollout.mode=copris (got {:?})",
            self.cfg.rollout.mode
        );
        ensure!(
            self.live_engines() > 0,
            "rollout: degraded — no live engines (all {} failed in earlier stages)",
            self.pool.engines()
        );
        self.kv_base.clone_from(&self.kv_seen);
        self.window_tokens = 0;
        self.window_decode_ema = 0.0;
        let cfg = self.cfg.rollout.clone();
        let sampling = SamplingParams {
            temperature: cfg.temperature,
            top_p: cfg.top_p,
            top_k: cfg.top_k,
        };
        for stale in self.buffer.evict_stale(self.policy_version) {
            if let Some(r) = self.table.retained_at.remove(&stale.id) {
                self.pool.send(
                    r.engine,
                    EngineCmd::ReleaseRetained { request_id: stale.id, token: r.token },
                );
            }
            self.book.note_abandoned(stale.group_id);
        }
        let policy = StagePolicy {
            target: Some(cfg.concurrency),
            continuous: true,
            use_buffer: true,
            drain: true,
            until_idle: false,
            inline_preempt: false,
        };
        self.driver = Some(StageDriver::new(StageGoal::Stream, policy, sampling));
        let mut ds = Some(dataset);
        self.fill_to_target(&mut ds, sampling, cfg.concurrency);
        Ok(())
    }

    /// Is the fully-async stream active?
    pub fn async_active(&self) -> bool {
        matches!(self.driver.as_ref().map(|d| &d.goal), Some(StageGoal::Stream))
    }

    /// Does the staging book hold a full training batch (B completed
    /// groups) ready for [`take_async_batch`](Self::take_async_batch)?
    pub fn async_batch_ready(&self) -> bool {
        self.book.completed_count() >= self.cfg.rollout.batch_prompts
    }

    /// Advance the async stream without blocking past `deadline`. Returns
    /// Ok(true) as soon as a full batch is staged (possibly without
    /// touching the pool); Ok(false) at the deadline.
    pub fn pump_async(&mut self, dataset: &mut Dataset, deadline: Instant) -> Result<bool> {
        ensure!(self.async_active(), "pump_async without an async stream");
        self.pump_inner(Some(dataset), deadline)
    }

    /// Harvest B completed groups from the staging book WITHOUT ending the
    /// stream: in-flight trajectories keep decoding. Stats cover the
    /// window since the previous harvest (or stream begin) — wall,
    /// engine-gauge deltas and the version-lag histogram all re-baseline
    /// here.
    pub fn take_async_batch(&mut self) -> Result<RolloutOutput> {
        ensure!(self.async_active(), "take_async_batch without an async stream");
        let b = self.cfg.rollout.batch_prompts;
        ensure!(
            self.book.completed_count() >= b,
            "take_async_batch before a full batch is staged ({} of {b} groups ready)",
            self.book.completed_count()
        );
        let groups = self.book.take_completed(b);
        let now = Instant::now();
        let d = self.drv_mut();
        let mut stats = std::mem::take(&mut d.stats);
        stats.wall = now.duration_since(d.t0).as_secs_f64();
        d.t0 = now;
        stats.completed = groups.iter().map(|g| g.done.len()).sum();
        stats.overlap_secs = stats.overlap_secs.min(stats.wall);
        note_version_lags(&groups, &mut stats);
        self.harvest_engine_deltas(&mut stats);
        Ok(RolloutOutput { groups, stats })
    }

    /// Staleness enforcement ahead of a mid-stream weight sync to
    /// `next_version`, with `S = rollout.max_staleness`:
    ///
    /// - **mandatory cut** — any in-flight assignment whose dispatch
    ///   version would lag `next_version` by MORE than S is early-
    ///   terminated now (its partial lands in the buffer for IS-corrected
    ///   resume under the new policy);
    /// - **active cut** (APRIL-style, `rollout.active_termination`) — an
    ///   assignment exactly AT the bound is also terminated when its
    ///   predicted remaining decode (group length EMA minus tokens held)
    ///   exceeds the per-window decode EMA: it would not finish before the
    ///   next sync forces it out anyway, so cutting it now frees the slot
    ///   for work that can.
    ///
    /// With S = 0 every in-flight assignment is cut, through the same
    /// broadcast-flush drain the pipelined mode uses at stage end — which
    /// is why staleness-0 async is bit-identical to pipelined execution.
    /// Refill pauses until [`resume_refill`](Self::resume_refill) so no
    /// dispatch races the weight broadcast; call this, then
    /// `sync_weights(next_version, …)`, then `resume_refill`.
    pub fn prepare_sync(&mut self, next_version: u64) -> Result<()> {
        ensure!(self.async_active(), "prepare_sync without an async stream");
        self.drv_mut().refill_paused = true;
        let s = self.cfg.rollout.max_staleness as u64;

        // Per-window decode EMA: tokens harvested since the last sync,
        // normalized per in-flight slot — what an average slot manages to
        // decode between consecutive syncs.
        let per_slot = self.window_tokens as f64 / self.inflight.len().max(1) as f64;
        self.window_decode_ema = if self.window_decode_ema == 0.0 {
            per_slot
        } else {
            self.window_decode_ema + 0.3 * (per_slot - self.window_decode_ema)
        };
        self.window_tokens = 0;

        let mut cut: Vec<u64> = Vec::new();
        let mut mandatory = 0usize;
        let mut active = 0usize;
        for (id, inf) in &self.inflight {
            let lag = next_version.saturating_sub(inf.version);
            if lag > s {
                cut.push(*id);
                mandatory += 1;
            } else if self.cfg.rollout.active_termination && lag == s && s > 0 {
                let predicted = self.len_pred.predict(inf.traj.group_id);
                let remaining = predicted - inf.traj.len() as f64;
                if predicted > 0.0
                    && self.window_decode_ema > 0.0
                    && remaining > self.window_decode_ema
                {
                    cut.push(*id);
                    active += 1;
                }
            }
        }
        cut.sort_unstable();
        {
            let d = self.drv_mut();
            d.stats.staleness_terminations += mandatory;
            d.stats.active_terminations += active;
        }
        if cut.is_empty() {
            return Ok(());
        }
        let retain = self.cfg.rollout.retain_kv;
        if cut.len() == self.inflight.len() {
            // Cutting everything (always the case at S = 0): reuse the
            // broadcast-flush drain machinery — the exact path the
            // pipelined mode quiesces through, which keeps staleness-0
            // async bit-identical to it. The stream resumes Running
            // afterwards instead of finishing.
            self.pool.stop_generation_all_with(retain);
            let d = self.drv_mut();
            d.phase = StagePhase::Draining;
            d.flushed.clear();
            while !self.pump_inner(None, Instant::now() + PUMP_CHUNK)? {}
            let d = self.drv_mut();
            d.phase = StagePhase::Running;
            d.done_at = None;
        } else {
            // Targeted per-request stops. Track which engine each stop was
            // sent to: failure recovery may re-dispatch a cut trajectory
            // onto a survivor, in which case the stop is re-issued there.
            let mut sent: HashMap<u64, usize> = HashMap::new();
            loop {
                let pending: Vec<u64> = cut
                    .iter()
                    .copied()
                    .filter(|id| self.inflight.contains_key(id))
                    .collect();
                if pending.is_empty() {
                    break;
                }
                for id in pending {
                    let engine = self.inflight[&id].engine;
                    if sent.insert(id, engine) != Some(engine) {
                        self.pool
                            .send(engine, EngineCmd::StopRequest { request_id: id, retain });
                    }
                }
                match self.next_event(Instant::now() + PUMP_CHUNK)? {
                    Some(ev) => self.handle_event(ev, false)?,
                    // Watchdog fired — loop re-checks survivors.
                    None => {}
                }
            }
        }
        Ok(())
    }

    /// Re-enable dispatch after a mid-stream weight sync and refill to the
    /// concurrency target — cut partials resume first (prioritized
    /// resumption), now under the new policy version.
    pub fn resume_refill(&mut self, dataset: &mut Dataset) -> Result<()> {
        ensure!(self.async_active(), "resume_refill without an async stream");
        self.drv_mut().refill_paused = false;
        let sampling = self.drv().sampling;
        let target = self.drv().policy.target.unwrap_or(self.cfg.rollout.concurrency);
        let mut ds = Some(dataset);
        self.fill_to_target(&mut ds, sampling, target);
        Ok(())
    }

    /// Handle one engine event (recursing into `Batch` — engines deliver a
    /// whole step's events in one channel send). `draining` switches
    /// Stopped/Preempted handling to "buffer it" (early-termination flush).
    /// Flushed markers land in the driver's `flushed` set, so the Draining
    /// phase tracks engine flushes even when they arrive inside a batch.
    fn handle_event(&mut self, ev: EngineEvent, draining: bool) -> Result<()> {
        if let EngineEvent::Batch(evs) = ev {
            for e in evs {
                self.handle_event(e, draining)?;
            }
            return Ok(());
        }
        // Late events from an engine already declared dead — a stalled
        // engine the watchdog buried can wake up and deliver its backlog.
        // Its work was already re-dispatched or re-parked; processing
        // these would double-deliver (or bail on an unknown request id).
        let from = match &ev {
            EngineEvent::Trace(t) => Some(t.engine),
            EngineEvent::Flushed { engine, .. }
            | EngineEvent::ShutDown { engine }
            | EngineEvent::RetainedDropped { engine, .. }
            | EngineEvent::Done { engine, .. } => Some(*engine),
            EngineEvent::EngineFailed { .. } | EngineEvent::Batch(_) => None,
        };
        if let Some(e) = from {
            if self.table.dead[e] {
                return Ok(());
            }
        }
        match ev {
            EngineEvent::Batch(_) => unreachable!("batches are unpacked above"),
            EngineEvent::EngineFailed { engine, error, .. } => {
                self.fail_engine(engine, &error)?;
            }
            EngineEvent::Trace(t) => {
                // The engine's prefix/COW/chunk counters are cumulative
                // over its lifetime; remember the latest so finish_stage
                // can report per-stage deltas against the begin_stage
                // snapshot.
                // Latest KV-block residency per replica — the routing
                // table's observability gauge (never a routing input).
                if let Some(g) = self.table.kv_blocks.get_mut(t.engine) {
                    *g = t.kv_blocks;
                }
                if let Some(seen) = self.kv_seen.get_mut(t.engine) {
                    seen.prefix_tokens_shared =
                        seen.prefix_tokens_shared.max(t.prefix_tokens_shared);
                    seen.cow_copies = seen.cow_copies.max(t.cow_copies);
                    seen.prefill_chunks = seen.prefill_chunks.max(t.prefill_chunks);
                    seen.prefill_stall_saved =
                        seen.prefill_stall_saved.max(t.prefill_stall_saved);
                    seen.retries = seen.retries.max(t.retries);
                }
                let d = self.drv_mut();
                d.stats.kv_blocks_peak = d.stats.kv_blocks_peak.max(t.kv_blocks);
                d.stats.kv_bytes_peak = d.stats.kv_bytes_peak.max(t.kv_bytes);
                d.stats.sampler_dispatch = t.sampler_dispatch;
                d.stats.traces.push(t);
            }
            EngineEvent::Flushed { engine, retain_errors } => {
                let d = self.drv_mut();
                d.stats.retain_errors += retain_errors;
                d.flushed.insert(engine);
            }
            EngineEvent::ShutDown { .. } => {}
            EngineEvent::RetainedDropped { engine, request_id } => {
                // The engine evicted/released that retained slot; stop
                // routing the partial by affinity. Only clear an entry that
                // still points AT that engine: a delayed drop from an old
                // home engine (imbalance fallback → ReleaseRetained → the
                // partial re-retained elsewhere meanwhile) must not erase
                // the newer entry. Same-engine drops can never be stale —
                // each engine's events arrive in emission order, so its
                // drop is always processed before any later retention it
                // grants for the same request. (Entries already gone —
                // coordinator-initiated releases — are a harmless no-op.)
                if self.table.retained_at.get(&request_id).is_some_and(|r| r.engine == engine) {
                    self.table.retained_at.remove(&request_id);
                }
            }
            EngineEvent::Done { engine, result } => {
                let Some(inf) = self.inflight.remove(&result.request_id) else {
                    bail!("unknown request {} from engine {engine}", result.request_id);
                };
                self.table.load[inf.engine] = self.table.load[inf.engine].saturating_sub(1);
                let mut traj = inf.traj;
                // Resume length BEFORE this assignment's tokens append —
                // exactly what a replay would have recomputed.
                let resumed_len = traj.len() as u64;
                // The segment spans dispatch → now: it remembers the policy
                // version its assignment was dispatched under (staleness
                // accounting) alongside the version it was harvested under
                // (IS correction).
                traj.append_stage_spanning(
                    &result.new_tokens,
                    &result.new_logprobs,
                    inf.version,
                    self.policy_version,
                );
                self.window_tokens += result.new_tokens.len() as u64;
                self.drv_mut().stats.replayed_tokens += result.replayed as u64;
                if inf.retain.is_some() {
                    let d = self.drv_mut();
                    // A hit only counts when the resumed assignment actually
                    // produced tokens: a same-step preemption of a retained
                    // resume consumes the KV without generating anything, so
                    // its prefix will be replayed after all — crediting it
                    // as "saved" would double-book those tokens.
                    if result.resumed_from_kv && !result.new_tokens.is_empty() {
                        d.stats.retained_hits += 1;
                        d.stats.replay_tokens_saved += resumed_len;
                    } else {
                        d.stats.retained_misses += 1;
                    }
                }
                match result.reason {
                    FinishReason::Eos | FinishReason::LengthCap => {
                        traj.complete = true;
                        let gid = traj.group_id;
                        self.len_pred.observe(gid, traj.len());
                        self.drv_mut().stats.response_lengths.push(traj.len());
                        let group_complete = self.book.record_complete(traj)?;
                        if group_complete {
                            self.len_pred.forget_group(gid);
                            // No more samples will attach this group's
                            // prompt blocks: release its registry entries
                            // (engines that never saw the group — or
                            // already pressure-evicted the entry — ignore
                            // the command).
                            if let Some(homes) = self.table.prefix_homes.remove(&gid) {
                                for e in homes {
                                    self.pool.send(e, EngineCmd::ReleasePrefix { key: gid });
                                }
                            }
                        }
                        // Async staging-occupancy gauge: how far ahead of
                        // the trainer the stream has run.
                        if matches!(self.drv().goal, StageGoal::Stream) {
                            let n = self.book.completed_count();
                            let d = self.drv_mut();
                            d.stats.staging_occupancy_peak =
                                d.stats.staging_occupancy_peak.max(n);
                        }
                    }
                    FinishReason::Preempted => {
                        self.drv_mut().stats.preemptions += 1;
                        if draining {
                            self.park_partial(traj);
                        } else if self.drv().policy.inline_preempt {
                            // Eval stages own their trajectories: immediate
                            // re-dispatch, never through the shared buffer
                            // (which holds carried-over TRAINING partials).
                            let sampling = self.drv().sampling;
                            self.dispatch(traj, sampling);
                        } else {
                            // Immediate re-queue with resumption priority.
                            self.buffer.push(traj);
                        }
                    }
                    FinishReason::Stopped => {
                        let id = traj.id;
                        let parked = self.park_partial(traj);
                        if let Some(token) = result.retained {
                            if parked {
                                // Remember where the KV lives so the next
                                // dispatch can route the resume home.
                                self.table.retained_at.insert(id, RetainedRef { engine, token });
                            } else {
                                // Abandoned (empty) partial — the engine
                                // retained for nothing; free the slot.
                                // (Unreachable in practice: retention
                                // requires ≥ 1 generated token.)
                                self.pool.send(
                                    engine,
                                    EngineCmd::ReleaseRetained { request_id: id, token },
                                );
                            }
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Park a flushed/preempted partial in the buffer; returns false when
    /// it was empty and abandoned instead (dispatch slot freed).
    fn park_partial(&mut self, traj: Trajectory) -> bool {
        if traj.is_empty() {
            // Nothing generated: not a partial — free the dispatch slot.
            self.book.note_abandoned(traj.group_id);
            false
        } else {
            self.drv_mut().stats.partials_buffered += 1;
            self.buffer.push(traj);
            true
        }
    }

    /// Fixed-prompt synchronous generation (evaluation path): `samples`
    /// rollouts per task at `sampling`; returns one completed group per
    /// task, in task order. Runs as a `StageGoal::Fixed` stage on the same
    /// driver, with inline preemption re-dispatch so buffered TRAINING
    /// partials are never generated under eval. (Implementation of the
    /// eval arm of [`Coordinator::run`]; `run_fixed_sync` is its shim.)
    pub(crate) fn fixed_stage(
        &mut self,
        tasks: &[Task],
        samples: usize,
        sampling: SamplingParams,
    ) -> Result<Vec<Group>> {
        ensure!(self.driver.is_none(), "run_fixed_sync with a stage active");
        ensure!(self.inflight.is_empty(), "run_fixed_sync with work in flight");
        ensure!(
            self.live_engines() > 0,
            "rollout: degraded — no live engines (all {} failed in earlier stages)",
            self.pool.engines()
        );
        let policy = StagePolicy {
            target: None,
            continuous: false,
            use_buffer: false,
            drain: false,
            until_idle: true,
            inline_preempt: true,
        };
        self.driver = Some(StageDriver::new(StageGoal::Fixed, policy, sampling));
        let mut ids = Vec::new();
        for task in tasks {
            let gid = self.book.new_group(task.clone(), samples);
            ids.push(gid);
            for _ in 0..samples {
                self.dispatch_fresh(gid, task, sampling);
            }
        }
        while !self.pump_inner(None, Instant::now() + PUMP_CHUNK)? {}
        self.driver = None;

        // Take exactly OUR groups (the book may hold surplus completed
        // training groups carried across stages — leave those alone).
        let mut taken = self.book.take_groups(&ids);
        let index: HashMap<u64, usize> =
            ids.iter().enumerate().map(|(i, id)| (*id, i)).collect();
        let mut slots: Vec<Option<Group>> = (0..ids.len()).map(|_| None).collect();
        for g in taken.drain(..) {
            let i = index[&g.group_id];
            slots[i] = Some(g);
        }
        let mut out = Vec::new();
        for s in slots {
            let g = s.context("eval group missing")?;
            ensure!(g.is_complete(), "eval group incomplete");
            out.push(g);
        }
        Ok(out)
    }

    /// Recursive event pre-scan for the open-loop stage: advances the
    /// virtual clock (one quantum per live engine step trace) and feeds
    /// the SLO collector, WITHOUT consuming the event — `handle_event`
    /// still runs afterwards. Mirrors `handle_event`'s dead-engine
    /// discard so a buried engine's late results never double-finish a
    /// request the pool already re-dispatched.
    fn scan_open_loop_event(
        ev: &EngineEvent,
        quantum_ticks: u64,
        dead: &[bool],
        engine_steps: &mut [u64],
        vnow: &mut u64,
        idx_of_traj: &HashMap<u64, u64>,
        collector: &mut SloCollector,
    ) {
        match ev {
            EngineEvent::Batch(evs) => {
                for e in evs {
                    Self::scan_open_loop_event(
                        e,
                        quantum_ticks,
                        dead,
                        engine_steps,
                        vnow,
                        idx_of_traj,
                        collector,
                    );
                }
            }
            EngineEvent::Trace(t) => {
                if dead[t.engine] {
                    return;
                }
                engine_steps[t.engine] += 1;
                *vnow = (*vnow).max(engine_steps[t.engine] * quantum_ticks);
            }
            EngineEvent::Done { engine, result } => {
                if dead[*engine] {
                    return;
                }
                let Some(&idx) = idx_of_traj.get(&result.request_id) else { return };
                collector.add_tokens(idx, result.new_tokens.len());
                match result.reason {
                    FinishReason::Eos | FinishReason::LengthCap => collector.on_finish(idx, *vnow),
                    FinishReason::Preempted => collector.on_preempt(idx),
                    FinishReason::Stopped => {}
                }
            }
            _ => {}
        }
    }

    /// Open-loop SLO stage over the live (threaded) engine pool: requests
    /// from a pre-generated virtual-clock `schedule` flow through a
    /// bounded admission queue (capacity `queue_cap`; fresh arrivals past
    /// the bound are SHED — the structured overload signal) into normal
    /// dispatch, capped at `rollout.concurrency` in flight. Runs as a
    /// [`StageGoal::OpenLoop`] stage with inline preemption re-dispatch,
    /// so preempted requests resume without touching the training buffer
    /// and are never shed. The virtual clock advances `quantum_ticks` per
    /// live engine step trace; arrival injection, admission, and SLO
    /// timestamps all read it, never the wall clock.
    ///
    /// This arm trades the lockstep sim's bit-exact determinism
    /// ([`crate::loadgen::sim`]) for real pool concurrency — engine
    /// failures, supervision, and re-dispatch included — so its
    /// guarantees are structural: every admitted request completes
    /// exactly once, shed + completed = arrived, and the SLO report is
    /// complete even when engines die mid-run.
    pub(crate) fn open_loop_stage(
        &mut self,
        schedule: &[OpenLoopRequest],
        queue_cap: usize,
        quantum_ticks: u64,
        sampling: SamplingParams,
    ) -> Result<OpenLoopOutput> {
        ensure!(self.driver.is_none(), "run_open_loop with a stage active");
        ensure!(self.inflight.is_empty(), "run_open_loop with work in flight");
        ensure!(queue_cap > 0, "run_open_loop needs a non-zero queue cap");
        ensure!(quantum_ticks > 0, "run_open_loop needs a non-zero quantum");
        ensure!(
            self.live_engines() > 0,
            "rollout: degraded — no live engines (all {} failed in earlier stages)",
            self.pool.engines()
        );
        ensure!(
            schedule.windows(2).all(|w| w[0].arrival_tick <= w[1].arrival_tick),
            "open-loop schedule must be sorted by arrival tick"
        );
        for r in schedule {
            ensure!(!r.prompt.is_empty(), "open-loop request with empty prompt");
            ensure!(r.out_len > 0, "open-loop request with zero out_len");
        }
        let policy = StagePolicy {
            target: None,
            continuous: false,
            use_buffer: false,
            drain: false,
            until_idle: true,
            inline_preempt: true,
        };
        self.driver = Some(StageDriver::new(StageGoal::OpenLoop, policy, sampling));
        let t0 = Instant::now();
        let target = self.cfg.rollout.concurrency.max(1);

        let mut collector = SloCollector::new();
        let mut queue: VecDeque<usize> = VecDeque::new();
        let mut idx_of_traj: HashMap<u64, u64> = HashMap::new();
        let mut gids: Vec<u64> = Vec::new();
        let mut engine_steps = vec![0u64; self.pool.engines()];
        let mut vnow: u64 = 0;
        let mut next_arr = 0usize;
        let mut admitted = 0usize;

        loop {
            // Inject every arrival due by the virtual now; tail-drop past
            // the queue bound.
            while next_arr < schedule.len() && schedule[next_arr].arrival_tick <= vnow {
                let idx = next_arr;
                next_arr += 1;
                let r = &schedule[idx];
                collector.on_arrival(idx as u64, r.class, r.arrival_tick);
                if queue.len() >= queue_cap {
                    collector.on_shed(idx as u64);
                } else {
                    queue.push_back(idx);
                }
            }
            collector.note_queue_depth(queue.len());

            // Admit up to the concurrency target. Each admitted request is
            // its own single-sample group; the stub task is never graded.
            while !queue.is_empty() && self.total_inflight() < target {
                let idx = queue.pop_front().unwrap();
                let r = &schedule[idx];
                let task = Task {
                    family: Family::AddChain,
                    level: 0,
                    prompt: String::new(),
                    answer: String::new(),
                };
                let gid = self.book.new_group(task.clone(), 1);
                gids.push(gid);
                self.book.note_dispatch(gid);
                let id = self.next_traj_id;
                self.next_traj_id += 1;
                self.max_total_override.insert(id, (r.prompt.len() + r.out_len).min(self.max_seq));
                idx_of_traj.insert(id, idx as u64);
                let traj = Trajectory::new(id, gid, task, r.prompt.clone(), self.policy_version);
                collector.on_dispatch(idx as u64, vnow);
                self.dispatch(traj, sampling);
                admitted += 1;
            }

            if next_arr >= schedule.len() && queue.is_empty() && self.total_inflight() == 0 {
                break;
            }
            if self.total_inflight() == 0 && queue.is_empty() {
                // Idle gap — fast-forward straight to the next arrival.
                vnow = vnow.max(schedule[next_arr].arrival_tick);
                continue;
            }
            if let Some(ev) = self.next_event(Instant::now() + PUMP_CHUNK)? {
                Self::scan_open_loop_event(
                    &ev,
                    quantum_ticks,
                    &self.table.dead,
                    &mut engine_steps,
                    &mut vnow,
                    &idx_of_traj,
                    &mut collector,
                );
                self.handle_event(ev, false)?;
            }
        }

        let drv = self.driver.take().expect("open-loop driver active");
        let mut stats = drv.stats;
        stats.wall = t0.elapsed().as_secs_f64();
        let report = collector.report(vnow.max(1));
        stats.completed = report.completed;
        stats.requests_arrived = report.arrived;
        stats.requests_shed = report.shed;
        stats.queue_depth_peak = report.queue_depth_peak;
        stats.slo_e2e_p50_ticks = report.e2e_p50_ticks;
        stats.slo_e2e_p99_ticks = report.e2e_p99_ticks;
        stats.goodput_rps = report.goodput_rps;
        self.max_total_override.clear();

        // Conservation: exactly one completed group per admitted request.
        let groups = self.book.take_groups(&gids);
        ensure!(
            groups.len() == admitted,
            "open-loop run lost groups: {} of {admitted}",
            groups.len()
        );
        for g in &groups {
            ensure!(g.is_complete(), "open-loop group incomplete");
        }
        ensure!(
            report.completed == admitted,
            "open-loop completed {} != admitted {admitted}",
            report.completed
        );
        Ok(OpenLoopOutput { groups, stats, report })
    }

    /// Buffered partial count (off-policy debt carried to the next stage).
    pub fn buffered(&self) -> usize {
        self.buffer.len()
    }

    /// Buffered partials whose KV is still retained on some engine (test /
    /// diagnostics: the affinity map size).
    pub fn retained_partials(&self) -> usize {
        self.table.retained_at.len()
    }

    /// Start draining a replica: it keeps its in-flight work but receives
    /// no new dispatches until [`Coordinator::undrain_engine`]. Advisory —
    /// when every live replica drains, routing overrides the flags (work
    /// must land somewhere). Returns false for a dead replica.
    pub fn drain_engine(&mut self, engine: usize) -> bool {
        self.table.set_draining(engine, true)
    }

    /// Return a draining replica to full routing rotation. Returns false
    /// for a dead replica (death is terminal).
    pub fn undrain_engine(&mut self, engine: usize) -> bool {
        self.table.set_draining(engine, false);
        !self.table.dead[engine]
    }

    /// Health/drain snapshot across the fleet (Healthy | Draining | Dead).
    pub fn replica_health(&self) -> Vec<ReplicaHealth> {
        self.table.health()
    }

    /// Shut the engine pool down (joins every engine thread).
    pub fn shutdown(self) {
        self.pool.shutdown();
    }
}
