//! **Frozen pre-refactor coordinator** — the monolithic blocking
//! `rollout_stage` exactly as it stood before the reentrant
//! [`StageDriver`](super::driver::StageDriver) rewrite, kept verbatim as a
//! golden oracle: `tests/rollout_golden.rs` runs this and the state-machine
//! driver side by side on the mock backend and asserts bit-identical
//! sync/naive/copris stage outputs (same pattern as the sampler's
//! allocating reference in `engine/sampler.rs`).
//!
//! Known bugs preserved on purpose (they ARE the pre-refactor behaviour;
//! both are fixed in the live driver and pinned by tests):
//! - `run_fixed_sync` re-dispatches *any* buffered partial, stealing
//!   carried-over training partials into the eval run.
//! - `RolloutStats::resumed` is never incremented.
//!
//! Do not "fix" or modernise this file — its value is that it does not
//! change. The only sanctioned edits are mechanical API-compat shims when
//! a shared type grows (each behaviour-preserving, marked `API-compat`):
//! `WorkItem::retain: None` (never uses the retention fast path),
//! `broadcast_params(.., true)` (always invalidates retained KV — there is
//! none), and ignore arms for `EngineEvent::RetainedDropped` (never
//! received: this coordinator never retains) and
//! `EngineEvent::EngineFailed` (pre-refactor behaviour on engine death was
//! the recv-timeout bail below — ignoring the richer event preserves it).

#![allow(missing_docs)] // frozen pre-refactor code — not part of the doc pass

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::buffer::PartialBuffer;
use super::groups::{Group, GroupBook};
use super::rollout::{RolloutOutput, RolloutStats};
use super::trajectory::Trajectory;
use crate::config::{Config, RolloutMode};
use crate::engine::{EngineCmd, EngineEvent, EnginePool, FinishReason, SamplingParams, WorkItem};
use crate::tasks::{Dataset, Task};
use crate::tokenizer::Tokenizer;

/// In-flight bookkeeping: trajectory + which engine has it.
struct InFlight {
    traj: Trajectory,
    engine: usize,
}

/// The pre-refactor blocking coordinator (test oracle only).
pub struct ReferenceCoordinator {
    pub pool: EnginePool,
    pub cfg: Config,
    pub buffer: PartialBuffer,
    book: GroupBook,
    inflight: HashMap<u64, InFlight>,
    engine_load: Vec<usize>,
    next_traj_id: u64,
    pub policy_version: u64,
    tokenizer: Tokenizer,
    wave_remaining: Option<usize>,
    max_seq: usize,
}

impl ReferenceCoordinator {
    pub fn new(pool: EnginePool, cfg: Config, max_seq: usize) -> ReferenceCoordinator {
        let engines = pool.engines();
        let buffer = PartialBuffer::new(cfg.rollout.max_stage_lag);
        ReferenceCoordinator {
            pool,
            cfg,
            buffer,
            book: GroupBook::new(),
            inflight: HashMap::new(),
            engine_load: vec![0; engines],
            next_traj_id: 0,
            policy_version: 0,
            tokenizer: Tokenizer::new(),
            wave_remaining: None,
            max_seq,
        }
    }

    fn max_total_for(&self, prompt_len: usize) -> usize {
        let cap = if self.cfg.engine.max_new_tokens > 0 {
            prompt_len + self.cfg.engine.max_new_tokens
        } else {
            usize::MAX
        };
        cap.min(self.max_seq)
    }

    pub fn tokenizer(&self) -> &Tokenizer {
        &self.tokenizer
    }

    pub fn sync_weights(&mut self, version: u64, params: Arc<Vec<f32>>) {
        self.policy_version = version;
        self.pool.broadcast_params(version, params, true); // API-compat
    }

    fn total_inflight(&self) -> usize {
        self.inflight.len()
    }

    fn least_loaded_engine(&self) -> usize {
        self.engine_load
            .iter()
            .enumerate()
            .min_by_key(|(_, l)| **l)
            .map(|(i, _)| i)
            .unwrap_or(0)
    }

    fn dispatch(&mut self, traj: Trajectory, sampling: SamplingParams) {
        let engine = self.least_loaded_engine();
        let item = WorkItem {
            request_id: traj.id,
            prompt: traj.prompt.clone(),
            resume: traj.tokens.clone(),
            max_total: self.max_total_for(traj.prompt.len()),
            sampling,
            retain: None, // API-compat: the reference always replays
            prefix: None, // API-compat: the reference never shares prefixes
        };
        self.engine_load[engine] += 1;
        self.inflight.insert(traj.id, InFlight { traj, engine });
        self.pool.send(engine, EngineCmd::Assign(item));
        if let Some(w) = self.wave_remaining.as_mut() {
            *w = w.saturating_sub(1);
        }
    }

    fn dispatch_fresh(&mut self, group_id: u64, task: &Task, sampling: SamplingParams) {
        let prompt = self.tokenizer.encode_prompt(&task.prompt);
        let id = self.next_traj_id;
        self.next_traj_id += 1;
        let traj = Trajectory::new(id, group_id, task.clone(), prompt, self.policy_version);
        self.book.note_dispatch(group_id);
        self.dispatch(traj, sampling);
    }

    fn refill_one(&mut self, dataset: &mut Dataset, sampling: SamplingParams) -> bool {
        if let Some(0) = self.wave_remaining {
            return false;
        }
        if let Some(t) = self.buffer.pop() {
            self.dispatch(t, sampling);
            return true;
        }
        if let Some(gid) = self.book.groups_with_deficit().first().copied() {
            let task = self.book.get(gid).unwrap().task.clone();
            self.dispatch_fresh(gid, &task, sampling);
            return true;
        }
        let task = dataset.next_task();
        let gid = self.book.new_group(task.clone(), self.cfg.rollout.group_size);
        self.dispatch_fresh(gid, &task, sampling);
        true
    }

    /// One blocking rollout stage in the configured mode (pre-refactor).
    pub fn rollout_stage(&mut self, dataset: &mut Dataset) -> Result<RolloutOutput> {
        let cfg = self.cfg.rollout.clone();
        let sampling = SamplingParams {
            temperature: cfg.temperature,
            top_p: cfg.top_p,
            top_k: cfg.top_k,
        };
        let b = cfg.batch_prompts;
        let mut stats = RolloutStats::default();
        let t0 = Instant::now();

        for stale in self.buffer.evict_stale(self.policy_version) {
            self.book.note_abandoned(stale.group_id);
        }

        let concurrency = match cfg.mode {
            RolloutMode::Sync => {
                self.wave_remaining = None;
                for _ in 0..b {
                    let task = dataset.next_task();
                    let gid = self.book.new_group(task.clone(), cfg.group_size);
                    for _ in 0..cfg.group_size {
                        self.dispatch_fresh(gid, &task, sampling);
                    }
                }
                usize::MAX
            }
            RolloutMode::NaivePartial => {
                self.wave_remaining = Some(cfg.concurrency);
                cfg.concurrency
            }
            RolloutMode::Copris => {
                self.wave_remaining = None;
                cfg.concurrency
            }
        };

        if cfg.mode != RolloutMode::Sync {
            while self.total_inflight() < concurrency {
                if !self.refill_one(dataset, sampling) {
                    break;
                }
            }
        }
        stats.peak_inflight = self.total_inflight();

        loop {
            let done_enough = match cfg.mode {
                RolloutMode::Sync => self.total_inflight() == 0,
                _ => self.book.completed_count() >= b,
            };
            if done_enough {
                break;
            }
            if cfg.mode == RolloutMode::NaivePartial
                && self.total_inflight() == 0
                && self.book.completed_count() < b
            {
                self.wave_remaining = Some(cfg.concurrency);
                while self.total_inflight() < cfg.concurrency {
                    if !self.refill_one(dataset, sampling) {
                        break;
                    }
                }
            }

            let ev = self
                .pool
                .events
                .recv_timeout(Duration::from_secs(120))
                .context("rollout: engine event timeout")?;
            self.handle_event(ev, &mut stats, false)?;

            if cfg.mode == RolloutMode::Copris {
                while self.total_inflight() < concurrency {
                    if !self.refill_one(dataset, sampling) {
                        break;
                    }
                }
                stats.peak_inflight = stats.peak_inflight.max(self.total_inflight());
            }
        }

        if cfg.mode != RolloutMode::Sync && self.total_inflight() > 0 {
            self.drain_partials(&mut stats)?;
        }
        self.wave_remaining = None;

        let groups = self.book.take_completed(b);
        stats.completed = groups.iter().map(|g| g.done.len()).sum();
        stats.wall = t0.elapsed().as_secs_f64();
        Ok(RolloutOutput { groups, stats })
    }

    fn handle_event(
        &mut self,
        ev: EngineEvent,
        stats: &mut RolloutStats,
        draining: bool,
    ) -> Result<usize> {
        match ev {
            EngineEvent::Batch(evs) => {
                let mut flushed = 0;
                for e in evs {
                    flushed += self.handle_event(e, stats, draining)?;
                }
                return Ok(flushed);
            }
            EngineEvent::Trace(t) => stats.traces.push(t),
            EngineEvent::Flushed { .. } => return Ok(1),
            EngineEvent::ShutDown { .. } => {}
            EngineEvent::RetainedDropped { .. } => {} // API-compat: never retains
            EngineEvent::EngineFailed { .. } => {} // API-compat: no supervision pre-refactor
            EngineEvent::Done { engine, result } => {
                let Some(inf) = self.inflight.remove(&result.request_id) else {
                    bail!("unknown request {} from engine {engine}", result.request_id);
                };
                self.engine_load[inf.engine] = self.engine_load[inf.engine].saturating_sub(1);
                let mut traj = inf.traj;
                traj.append_stage(&result.new_tokens, &result.new_logprobs, self.policy_version);
                stats.replayed_tokens += result.replayed as u64;
                match result.reason {
                    FinishReason::Eos | FinishReason::LengthCap => {
                        traj.complete = true;
                        stats.response_lengths.push(traj.len());
                        self.book.record_complete(traj)?;
                    }
                    FinishReason::Preempted => {
                        stats.preemptions += 1;
                        if draining {
                            self.park_partial(traj, stats);
                        } else {
                            self.buffer.push(traj);
                        }
                    }
                    FinishReason::Stopped => {
                        self.park_partial(traj, stats);
                    }
                }
            }
        }
        Ok(0)
    }

    fn park_partial(&mut self, traj: Trajectory, stats: &mut RolloutStats) {
        if traj.is_empty() {
            self.book.note_abandoned(traj.group_id);
        } else {
            stats.partials_buffered += 1;
            self.buffer.push(traj);
        }
    }

    fn drain_partials(&mut self, stats: &mut RolloutStats) -> Result<()> {
        self.pool.stop_generation_all();
        let mut flushed = 0usize;
        let engines = self.pool.engines();
        while flushed < engines {
            let ev = self
                .pool
                .events
                .recv_timeout(Duration::from_secs(120))
                .context("drain: engine event timeout")?;
            flushed += self.handle_event(ev, stats, true)?;
        }
        let leftovers: Vec<u64> = self.inflight.keys().copied().collect();
        for id in leftovers {
            let inf = self.inflight.remove(&id).unwrap();
            self.engine_load[inf.engine] = self.engine_load[inf.engine].saturating_sub(1);
            self.park_partial(inf.traj, stats);
        }
        stats.resumed = 0; // the pre-refactor "set by caller" that nobody set
        Ok(())
    }

    /// Pre-refactor eval path — including the bug where buffered TRAINING
    /// partials are popped and generated under the eval run.
    pub fn run_fixed_sync(
        &mut self,
        tasks: &[Task],
        samples: usize,
        sampling: SamplingParams,
    ) -> Result<Vec<Group>> {
        anyhow::ensure!(self.inflight.is_empty(), "run_fixed_sync with work in flight");
        let mut ids = Vec::new();
        for task in tasks {
            let gid = self.book.new_group(task.clone(), samples);
            ids.push(gid);
            for _ in 0..samples {
                self.dispatch_fresh(gid, task, sampling);
            }
        }
        let mut stats = RolloutStats::default();
        while self.total_inflight() > 0 {
            let ev = self
                .pool
                .events
                .recv_timeout(Duration::from_secs(120))
                .context("eval: engine event timeout")?;
            self.handle_event(ev, &mut stats, false)?;
            while let Some(t) = self.buffer.pop() {
                self.dispatch(t, sampling);
            }
        }
        let mut taken = self.book.take_groups(&ids);
        let index: HashMap<u64, usize> =
            ids.iter().enumerate().map(|(i, id)| (*id, i)).collect();
        let mut slots: Vec<Option<Group>> = (0..ids.len()).map(|_| None).collect();
        for g in taken.drain(..) {
            let i = index[&g.group_id];
            slots[i] = Some(g);
        }
        let mut out = Vec::new();
        for s in slots {
            let g = s.context("eval group missing")?;
            anyhow::ensure!(g.is_complete(), "eval group incomplete");
            out.push(g);
        }
        Ok(out)
    }

    pub fn buffered(&self) -> usize {
        self.buffer.len()
    }

    pub fn shutdown(self) {
        self.pool.shutdown();
    }
}
