//! The routing table: per-replica placement state the coordinator consults
//! on every dispatch.
//!
//! Generalizes what used to be four loose fields on `Coordinator`
//! (`engine_load`, `dead`, `retained_at`, `prefix_homes`) into one
//! structure, and adds the health/drain state machine the multi-process
//! transport needs. Replica state is a one-way ladder:
//!
//! ```text
//!   Healthy ⇄ Draining        (set_draining — reversible, operator-driven)
//!      \         /
//!       v       v
//!         Dead                (mark_dead — terminal; EngineFailed or the
//!                              stall watchdog/heartbeat declared it)
//! ```
//!
//! Routing policy (unchanged from the pre-router coordinator, which is
//! what keeps the rollout goldens bit-identical): best residency first —
//! retained-KV affinity, then the group's prefix-home engine, then least
//! loaded — with every residency route yielding when the target's load
//! exceeds the least-loaded replica's by more than the imbalance guard.
//! Draining replicas are simply excluded from all three routes (they
//! finish what they have and receive nothing new); dead replicas are
//! excluded and their residency entries dropped. KV-block residency per
//! replica is tracked as an observability gauge (fed from step traces),
//! deliberately NOT as a routing input — load stays the balance criterion
//! so adding the gauge cannot shift golden-pinned decisions.

use std::collections::HashMap;

/// Where a buffered partial's KV is retained: the replica that generated
/// it and the retention token its `Stopped` flush returned. The
/// coordinator half of the retention ledger — a routing HINT, never a
/// correctness dependency (stale hints fall back to replay in-engine).
#[derive(Clone, Copy, Debug)]
pub struct RetainedRef {
    /// Replica (pool-global engine id) holding the retained KV.
    pub engine: usize,
    /// Retention token the engine's flush returned.
    pub token: u64,
}

/// One replica's position in the health/drain state machine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplicaHealth {
    /// Routable: receives new dispatches.
    Healthy,
    /// Alive but excluded from new dispatches; in-flight work finishes
    /// normally. Reversible.
    Draining,
    /// Declared failed (EngineFailed event, stall watchdog, or remote
    /// heartbeat loss). Terminal; late events are discarded upstream.
    Dead,
}

/// The decision `route` returns: where to dispatch, with which retained-KV
/// resume hint, and which abandoned retained slot (if any) the caller must
/// release remotely so it stops charging that replica's KV budget.
#[derive(Clone, Copy, Debug)]
pub struct RouteDecision {
    /// Target replica (pool-global engine id).
    pub engine: usize,
    /// Retention token to pass as the work item's resume hint.
    pub retain: Option<u64>,
    /// A retained slot the route abandoned (imbalance fallback on a live
    /// replica): the caller sends `ReleaseRetained` for it.
    pub release: Option<RetainedRef>,
}

/// Per-replica routing state (see module docs). Fields are public to the
/// coordinator, which updates load/death inline with its event loop; the
/// placement *decision* lives here in [`RoutingTable::route`].
#[derive(Debug, Default)]
pub struct RoutingTable {
    /// In-flight dispatch count per replica (the balance criterion).
    pub load: Vec<usize>,
    /// Terminal death flags (EngineFailed / watchdog / heartbeat).
    pub dead: Vec<bool>,
    /// Reversible drain flags (operator-driven; excluded from routing).
    pub draining: Vec<bool>,
    /// KV blocks resident per replica, from the latest step trace — an
    /// observability gauge, not a routing input (see module docs).
    pub kv_blocks: Vec<usize>,
    /// Affinity map: buffered-partial trajectory id → retained slot. An
    /// entry exists iff the partial's last `Stopped` flush retained KV
    /// and no sync/eviction/route has cleared it since.
    pub retained_at: HashMap<u64, RetainedRef>,
    /// Replicas that received dispatches for a group, in first-dispatch
    /// order — `[0]` is the group's HOME, where its prompt blocks were
    /// first registered; later samples (and resumed partials) prefer it
    /// so the prefix refcount actually shares. Usually one entry; more
    /// under imbalance spill.
    pub prefix_homes: HashMap<u64, Vec<usize>>,
}

impl RoutingTable {
    /// Fresh table for `n` replicas, all healthy and idle.
    pub fn new(n: usize) -> RoutingTable {
        RoutingTable {
            load: vec![0; n],
            dead: vec![false; n],
            draining: vec![false; n],
            kv_blocks: vec![0; n],
            retained_at: HashMap::new(),
            prefix_homes: HashMap::new(),
        }
    }

    /// Number of replicas the table tracks.
    pub fn replicas(&self) -> usize {
        self.load.len()
    }

    /// Replicas still alive (not declared failed). Draining replicas
    /// count — they are alive, just not routable.
    pub fn live(&self) -> usize {
        self.dead.iter().filter(|d| !**d).count()
    }

    /// One replica's health state.
    pub fn health_of(&self, e: usize) -> ReplicaHealth {
        if self.dead[e] {
            ReplicaHealth::Dead
        } else if self.draining[e] {
            ReplicaHealth::Draining
        } else {
            ReplicaHealth::Healthy
        }
    }

    /// Health snapshot across the fleet.
    pub fn health(&self) -> Vec<ReplicaHealth> {
        (0..self.replicas()).map(|e| self.health_of(e)).collect()
    }

    /// Set or clear a replica's drain flag. No-op on a dead replica (a
    /// death is terminal). Returns whether the flag now holds.
    pub fn set_draining(&mut self, e: usize, draining: bool) -> bool {
        if self.dead[e] {
            return false;
        }
        self.draining[e] = draining;
        draining
    }

    /// Is `e` routable (alive and not draining)?
    fn routable(&self, e: usize) -> bool {
        !self.dead[e] && !self.draining[e]
    }

    /// Least-loaded routable replica. When EVERY live replica is
    /// draining, drains are overridden (work must land somewhere and
    /// draining is advisory); falls back to replica 0 only when all are
    /// dead — unreachable in practice, the coordinator bails degraded
    /// first.
    pub fn least_loaded(&self) -> usize {
        let pick = |accept: &dyn Fn(usize) -> bool| {
            self.load
                .iter()
                .enumerate()
                .filter(|(i, _)| accept(*i))
                .min_by_key(|(_, l)| **l)
                .map(|(i, _)| i)
        };
        pick(&|i| self.routable(i)).or_else(|| pick(&|i| !self.dead[i])).unwrap_or(0)
    }

    /// Residency-aware placement, best residency first (module docs):
    /// retained-KV affinity, then group prefix home, then least loaded —
    /// each residency route guarded by `max_imbalance` against the
    /// least-loaded replica. Consumes the trajectory's `retained_at`
    /// entry either way (an abandoned slot comes back in
    /// [`RouteDecision::release`] for the caller to free remotely).
    pub fn route(
        &mut self,
        traj_id: u64,
        group_id: u64,
        retain_kv: bool,
        prefix_sharing: bool,
        max_imbalance: usize,
    ) -> RouteDecision {
        let least = self.least_loaded();
        let mut release = None;
        if let Some(r) = self.retained_at.remove(&traj_id) {
            if retain_kv
                && self.routable(r.engine)
                && self.load[r.engine] <= self.load[least] + max_imbalance
            {
                return RouteDecision { engine: r.engine, retain: Some(r.token), release: None };
            }
            // Imbalance/drain fallback: the abandoned retained slot must
            // be released remotely so it stops charging that replica's KV
            // — unless the replica is dead (its entries died with it;
            // this arm only covers races with a queued event).
            if !self.dead[r.engine] {
                release = Some(r);
            }
        }
        if prefix_sharing {
            let home = self.prefix_homes.get(&group_id).and_then(|h| h.first()).copied();
            if let Some(home) = home {
                if self.routable(home) && self.load[home] <= self.load[least] + max_imbalance {
                    return RouteDecision { engine: home, retain: None, release };
                }
            }
        }
        RouteDecision { engine: least, retain: None, release }
    }

    /// Record that `engine` served a dispatch for `group_id` (prefix-home
    /// bookkeeping; first recorder becomes the group's home).
    pub fn note_prefix_home(&mut self, group_id: u64, engine: usize) {
        let homes = self.prefix_homes.entry(group_id).or_default();
        if !homes.contains(&engine) {
            homes.push(engine);
        }
    }

    /// Drop every routing entry pointing at a dead replica: retained-KV
    /// affinity hints and prefix-home listings. Load for the replica is
    /// NOT cleared here — the coordinator reconciles it against its own
    /// in-flight ledger during recovery.
    pub fn drop_replica_routes(&mut self, engine: usize) {
        self.retained_at.retain(|_, r| r.engine != engine);
        for homes in self.prefix_homes.values_mut() {
            homes.retain(|e| *e != engine);
        }
        self.prefix_homes.retain(|_, h| !h.is_empty());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table_with_load(load: &[usize]) -> RoutingTable {
        let mut t = RoutingTable::new(load.len());
        t.load = load.to_vec();
        t
    }

    #[test]
    fn least_loaded_skips_dead_and_draining() {
        let mut t = table_with_load(&[0, 0, 5]);
        t.dead[0] = true;
        assert_eq!(t.least_loaded(), 1);
        t.set_draining(1, true);
        // Only replica 2 is routable despite its load.
        assert_eq!(t.least_loaded(), 2);
    }

    #[test]
    fn all_live_draining_overrides_drain() {
        let mut t = table_with_load(&[3, 1]);
        t.set_draining(0, true);
        t.set_draining(1, true);
        // Advisory drain yields: work still lands on the least loaded.
        assert_eq!(t.least_loaded(), 1);
    }

    #[test]
    fn retained_affinity_wins_within_imbalance() {
        let mut t = table_with_load(&[2, 0]);
        t.retained_at.insert(7, RetainedRef { engine: 0, token: 99 });
        let d = t.route(7, 1, true, false, 4);
        assert_eq!(d.engine, 0);
        assert_eq!(d.retain, Some(99));
        assert!(d.release.is_none());
        // Entry consumed.
        assert!(t.retained_at.is_empty());
    }

    #[test]
    fn imbalance_fallback_releases_remote_slot() {
        let mut t = table_with_load(&[9, 0]);
        t.retained_at.insert(7, RetainedRef { engine: 0, token: 99 });
        let d = t.route(7, 1, true, false, 2);
        assert_eq!(d.engine, 1);
        assert_eq!(d.retain, None);
        let rel = d.release.expect("abandoned slot must be released");
        assert_eq!((rel.engine, rel.token), (0, 99));
    }

    #[test]
    fn draining_home_is_skipped() {
        let mut t = table_with_load(&[0, 3]);
        t.retained_at.insert(7, RetainedRef { engine: 0, token: 1 });
        t.note_prefix_home(5, 0);
        t.set_draining(0, true);
        // Retained affinity on a draining replica yields (and releases)…
        let d = t.route(7, 5, true, true, 8);
        assert_eq!(d.engine, 1);
        assert!(d.release.is_some());
        // …and so does the prefix home.
        let d2 = t.route(8, 5, true, true, 8);
        assert_eq!(d2.engine, 1);
    }

    #[test]
    fn prefix_home_routes_group_within_imbalance() {
        let mut t = table_with_load(&[1, 0]);
        t.note_prefix_home(5, 0);
        assert_eq!(t.route(42, 5, true, true, 4).engine, 0);
        // Guard trips when the gap exceeds the imbalance cap.
        t.load[0] = 6;
        assert_eq!(t.route(43, 5, true, true, 4).engine, 1);
    }

    #[test]
    fn dead_replica_routes_dropped() {
        let mut t = RoutingTable::new(2);
        t.retained_at.insert(1, RetainedRef { engine: 0, token: 5 });
        t.retained_at.insert(2, RetainedRef { engine: 1, token: 6 });
        t.note_prefix_home(9, 0);
        t.note_prefix_home(9, 1);
        t.dead[0] = true;
        t.drop_replica_routes(0);
        assert!(!t.retained_at.contains_key(&1));
        assert!(t.retained_at.contains_key(&2));
        assert_eq!(t.prefix_homes.get(&9).unwrap(), &vec![1]);
        // A dead replica cannot be drained and stays Dead.
        assert!(!t.set_draining(0, true));
        assert_eq!(t.health_of(0), ReplicaHealth::Dead);
        assert_eq!(t.health(), vec![ReplicaHealth::Dead, ReplicaHealth::Healthy]);
    }
}
