//! Router tier: one poll/dispatch surface over N rollout replicas, local
//! or multi-process.
//!
//! [`RouterPool`] presents the exact API [`EnginePool`] exposes —
//! `try_next_checked` / `next_before` / `send` / `broadcast_params` /
//! `stop_generation_all_with` / `shutdown` — so `Coordinator`,
//! `StageDriver`, and `run_open_loop` run unchanged on top of either
//! transport:
//!
//! * **local** (default): wraps an in-process [`EnginePool`] with zero
//!   added indirection — commands and events keep flowing over the same
//!   mpsc channels, which is why tier-1 and every golden test are
//!   untouched by this tier existing.
//! * **tcp**: connects to `copris engine-host` processes over the framed
//!   wire protocol ([`crate::net::wire`]). Each host serves a contiguous
//!   range of pool-global engine ids; events arrive already carrying
//!   global ids, so the event loop upstairs cannot tell the transports
//!   apart — the correctness pin is bit-identical greedy streams across
//!   both.
//!
//! Failure taxonomy is UNIFIED with the in-process pool: a lost host —
//! heartbeat timeout, socket error, EOF — synthesizes one
//! `EngineEvent::EngineFailed { inflight: [], retained: [] }` per replica
//! it carried (plus `ShutDown`), which lands in the same coordinator
//! recovery path a supervised engine crash takes. The empty in-flight
//! snapshot is safe by design: the coordinator's own in-flight ledger is
//! authoritative for what a dead replica owed (it includes
//! queued-but-unstarted dispatches no failure event could know about),
//! so recovery re-dispatches everything regardless of the payload. No
//! second "remote-dead" code path exists in the rollout loop.
//!
//! The placement half of the router — the routing table generalizing
//! retained-KV affinity, prefix homes, per-replica load, and the
//! health/drain ladder — lives in [`table`]; the coordinator owns one and
//! consults it on every dispatch.

pub mod table;

use std::io::BufReader;
use std::net::{Shutdown, SocketAddr, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::config::RouterConfig;
use crate::engine::{EngineCmd, EngineEvent, EnginePool, PoolApi};
use crate::net::wire::{self, WireMsg, PROTO_VERSION};

pub use table::{ReplicaHealth, RetainedRef, RouteDecision, RoutingTable};

/// Sleep slice for heartbeat/stop-flag polling (keeps shutdown latency
/// bounded without a condvar).
const HB_POLL: Duration = Duration::from_millis(20);

/// One engine-fleet handle with the `EnginePool` poll API, over either
/// transport (see module docs).
pub struct RouterPool {
    inner: Inner,
    /// Decode slots per engine (capacity accounting; uniform fleet-wide).
    pub slots_per_engine: usize,
}

enum Inner {
    Local(EnginePool),
    Remote(RemotePool),
}

impl From<EnginePool> for RouterPool {
    /// Wrap an in-process pool as the `local` transport. This is the
    /// conversion every existing `Coordinator::new(pool, ..)` call site
    /// goes through implicitly.
    fn from(pool: EnginePool) -> RouterPool {
        RouterPool { slots_per_engine: pool.slots_per_engine, inner: Inner::Local(pool) }
    }
}

impl RouterPool {
    /// Connect the `tcp` transport: dial every host in `cfg.hosts` (in
    /// order), handshake, and assign each a contiguous global engine-id
    /// range. Fails fast on unreachable hosts, protocol-version mismatch,
    /// or a non-uniform slots-per-engine fleet.
    pub fn connect(cfg: &RouterConfig, seed: u64) -> Result<RouterPool> {
        let remote = RemotePool::connect(cfg, seed)?;
        let slots = remote.slots_per_engine;
        Ok(RouterPool { inner: Inner::Remote(remote), slots_per_engine: slots })
    }

    /// Transport name for logs/stats (`"local"` | `"tcp"`).
    pub fn transport_name(&self) -> &'static str {
        match &self.inner {
            Inner::Local(_) => "local",
            Inner::Remote(_) => "tcp",
        }
    }

    /// Number of replicas across the fleet.
    pub fn engines(&self) -> usize {
        match &self.inner {
            Inner::Local(p) => p.engines(),
            Inner::Remote(p) => p.total_engines,
        }
    }

    /// Total decode slots across the fleet.
    pub fn total_slots(&self) -> usize {
        self.engines() * self.slots_per_engine
    }

    /// Per-replica liveness from the TRANSPORT's view (local engines are
    /// always "alive" here — their deaths surface as events; remote
    /// replicas flip false when their host's link is declared lost).
    pub fn link_alive(&self) -> Vec<bool> {
        match &self.inner {
            Inner::Local(p) => vec![true; p.engines()],
            Inner::Remote(p) => {
                let mut v = Vec::with_capacity(p.total_engines);
                for l in &p.links {
                    let a = l.alive.load(Ordering::SeqCst);
                    for _ in 0..l.engines {
                        v.push(a);
                    }
                }
                v
            }
        }
    }

    /// Non-blocking poll; collapses "empty" and "disconnected" into
    /// `None` (see [`EnginePool::try_next`]).
    pub fn try_next(&self) -> Option<EngineEvent> {
        match &self.inner {
            Inner::Local(p) => p.try_next(),
            Inner::Remote(p) => p.events.try_recv().ok(),
        }
    }

    /// Non-blocking poll distinguishing "nothing queued" from "every
    /// replica gone" (see [`EnginePool::try_next_checked`]).
    pub fn try_next_checked(&self) -> Result<Option<EngineEvent>, RecvTimeoutError> {
        match &self.inner {
            Inner::Local(p) => p.try_next_checked(),
            Inner::Remote(p) => match p.events.try_recv() {
                Ok(e) => Ok(Some(e)),
                Err(TryRecvError::Empty) => Ok(None),
                Err(TryRecvError::Disconnected) => Err(RecvTimeoutError::Disconnected),
            },
        }
    }

    /// Bounded wait for the next event (see [`EnginePool::next_before`]).
    pub fn next_before(&self, deadline: Instant) -> Result<EngineEvent, RecvTimeoutError> {
        match &self.inner {
            Inner::Local(p) => p.next_before(deadline),
            Inner::Remote(p) => {
                let now = Instant::now();
                if deadline <= now {
                    return p.events.try_recv().map_err(|e| match e {
                        TryRecvError::Empty => RecvTimeoutError::Timeout,
                        TryRecvError::Disconnected => RecvTimeoutError::Disconnected,
                    });
                }
                p.events.recv_timeout(deadline - now)
            }
        }
    }

    /// Send one command to one replica (global engine id). Like the
    /// in-process pool, delivery to a dead replica is silently dropped —
    /// its absence surfaces through events.
    pub fn send(&self, engine: usize, cmd: EngineCmd) {
        match &self.inner {
            Inner::Local(p) => p.send(engine, cmd),
            Inner::Remote(p) => p.send(engine, cmd),
        }
    }

    /// Weight sync to every replica (see [`EnginePool::broadcast_params`]).
    pub fn broadcast_params(
        &self,
        version: u64,
        params: Arc<Vec<f32>>,
        invalidate_retained: bool,
    ) {
        match &self.inner {
            Inner::Local(p) => p.broadcast_params(version, params, invalidate_retained),
            Inner::Remote(p) => {
                for e in 0..p.total_engines {
                    p.send(
                        e,
                        EngineCmd::SetParams {
                            version,
                            params: params.clone(),
                            invalidate_retained,
                        },
                    );
                }
            }
        }
    }

    /// Early-terminate every replica without retaining KV.
    pub fn stop_generation_all(&self) {
        self.stop_generation_all_with(false);
    }

    /// Early-terminate every replica; with `retain`, flushed slots keep
    /// their KV resident for affinity resume.
    pub fn stop_generation_all_with(&self, retain: bool) {
        match &self.inner {
            Inner::Local(p) => p.stop_generation_all_with(retain),
            Inner::Remote(p) => {
                for e in 0..p.total_engines {
                    p.send(e, EngineCmd::StopGeneration { retain });
                }
            }
        }
    }

    /// Orderly teardown: local joins engine threads; tcp sends every
    /// replica `Shutdown` plus a `Goodbye`, severs the sockets, and joins
    /// the link threads.
    pub fn shutdown(self) {
        match self.inner {
            Inner::Local(p) => p.shutdown(),
            Inner::Remote(p) => p.shutdown(),
        }
    }
}

impl PoolApi for RouterPool {
    fn engines(&self) -> usize {
        RouterPool::engines(self)
    }
    fn total_slots(&self) -> usize {
        RouterPool::total_slots(self)
    }
    fn send(&self, engine: usize, cmd: EngineCmd) {
        RouterPool::send(self, engine, cmd)
    }
    fn try_next(&self) -> Option<EngineEvent> {
        RouterPool::try_next(self)
    }
    fn try_next_checked(&self) -> Result<Option<EngineEvent>, RecvTimeoutError> {
        RouterPool::try_next_checked(self)
    }
    fn next_before(&self, deadline: Instant) -> Result<EngineEvent, RecvTimeoutError> {
        RouterPool::next_before(self, deadline)
    }
    fn broadcast_params(&self, version: u64, params: Arc<Vec<f32>>, invalidate_retained: bool) {
        RouterPool::broadcast_params(self, version, params, invalidate_retained)
    }
    fn stop_generation_all_with(&self, retain: bool) {
        RouterPool::stop_generation_all_with(self, retain)
    }
    fn shutdown(self) {
        RouterPool::shutdown(self)
    }
}

/// One connected engine-host: the socket, its global engine-id range, and
/// the reader/heartbeat threads watching it.
struct HostLink {
    addr: String,
    base: usize,
    engines: usize,
    stream: TcpStream,
    /// Write half, shared by the dispatch path and the heartbeat thread;
    /// frames are single `write_all`s under this lock so they never
    /// interleave.
    writer: Arc<Mutex<TcpStream>>,
    /// Flips false exactly once, when the link is declared lost.
    alive: Arc<AtomicBool>,
    /// Set by `shutdown()` so link threads exit without synthesizing
    /// failures for an orderly close.
    closing: Arc<AtomicBool>,
    reader: Option<JoinHandle<()>>,
    heartbeat: Option<JoinHandle<()>>,
}

/// The `tcp` transport: N host links multiplexed into one event channel.
struct RemotePool {
    links: Vec<HostLink>,
    events: Receiver<EngineEvent>,
    total_engines: usize,
    slots_per_engine: usize,
}

/// Declare a host's replicas failed (idempotent): one `EngineFailed` with
/// an EMPTY in-flight snapshot per replica, then `ShutDown`. Safe because
/// the coordinator's own in-flight ledger is authoritative during
/// recovery (module docs) — this is the satellite that keeps remote death
/// on the exact same code path as an in-process engine crash.
fn fail_link(
    ev_tx: &Sender<EngineEvent>,
    alive: &AtomicBool,
    base: usize,
    engines: usize,
    addr: &str,
    reason: &str,
) {
    if !alive.swap(false, Ordering::SeqCst) {
        return; // already declared (reader and heartbeat can race here)
    }
    eprintln!("router: host {addr} lost — {reason}");
    for e in base..base + engines {
        let _ = ev_tx.send(EngineEvent::EngineFailed {
            engine: e,
            error: format!("engine-host {addr} lost: {reason}"),
            inflight: Vec::new(),
            retained: Vec::new(),
        });
        let _ = ev_tx.send(EngineEvent::ShutDown { engine: e });
    }
}

/// Do all engine ids inside `ev` fall into `[base, base+n)`? A host that
/// reports ids outside its assigned range is a protocol violation (it
/// would corrupt another host's routing state upstairs).
fn event_engines_in_range(ev: &EngineEvent, base: usize, n: usize) -> bool {
    let ok = |e: usize| e >= base && e < base + n;
    match ev {
        EngineEvent::Done { engine, .. }
        | EngineEvent::Flushed { engine, .. }
        | EngineEvent::ShutDown { engine }
        | EngineEvent::EngineFailed { engine, .. }
        | EngineEvent::RetainedDropped { engine, .. } => ok(*engine),
        EngineEvent::Trace(t) => ok(t.engine),
        EngineEvent::Batch(evs) => evs.iter().all(|e| event_engines_in_range(e, base, n)),
    }
}

/// Sever every link's socket and join its threads. `closing` is set first
/// so the readers treat the resulting errors as an orderly close, not a
/// host death. Used by `shutdown` and by `connect`'s error path — a failed
/// fleet bring-up must not leak link threads or socket clones (a leaked
/// reader clone would keep the host's socket open and its serve loop
/// blocked forever).
fn sever_and_join(links: &mut [HostLink]) {
    for l in links.iter() {
        l.closing.store(true, Ordering::SeqCst);
        let _ = l.stream.shutdown(Shutdown::Both);
    }
    for l in links.iter_mut() {
        if let Some(h) = l.reader.take() {
            let _ = h.join();
        }
        if let Some(h) = l.heartbeat.take() {
            let _ = h.join();
        }
    }
}

impl RemotePool {
    fn connect(cfg: &RouterConfig, seed: u64) -> Result<RemotePool> {
        let hosts = cfg.host_list();
        ensure!(!hosts.is_empty(), "router.transport=tcp requires router.hosts");
        let (ev_tx, ev_rx) = channel::<EngineEvent>();
        let mut links: Vec<HostLink> = Vec::new();
        let mut base = 0usize;
        let mut slots_per_engine = 0usize;
        for addr in &hosts {
            match connect_host(cfg, addr, base, seed, &ev_tx, &mut slots_per_engine) {
                Ok(link) => {
                    base += link.engines;
                    links.push(link);
                }
                Err(e) => {
                    sever_and_join(&mut links);
                    return Err(e);
                }
            }
        }
        drop(ev_tx); // receivers disconnect exactly when every link thread exits
        Ok(RemotePool { links, events: ev_rx, total_engines: base, slots_per_engine })
    }

    fn link_for(&self, engine: usize) -> Option<&HostLink> {
        self.links.iter().find(|l| engine >= l.base && engine < l.base + l.engines)
    }

    fn send(&self, engine: usize, cmd: EngineCmd) {
        let Some(link) = self.link_for(engine) else { return };
        if !link.alive.load(Ordering::SeqCst) {
            return; // dead host: drop silently, like the in-process pool
        }
        let frame = wire::encode(&WireMsg::Cmd { engine: engine as u64, cmd });
        let mut w = link.writer.lock().unwrap();
        use std::io::Write;
        let _ = w.write_all(&frame);
    }

    fn shutdown(mut self) {
        for l in &self.links {
            l.closing.store(true, Ordering::SeqCst);
        }
        for l in &self.links {
            if l.alive.load(Ordering::SeqCst) {
                for e in l.base..l.base + l.engines {
                    self.send(e, EngineCmd::Shutdown);
                }
                let mut w = l.writer.lock().unwrap();
                let _ = wire::write_msg(&mut *w, &WireMsg::Goodbye);
            }
        }
        // Severing after the farewells still delivers everything already
        // queued (FIN follows data); our blocked readers unblock at once.
        sever_and_join(&mut self.links);
    }
}

/// Dial, handshake, and watch one engine-host: returns the link with its
/// reader (and, if enabled, heartbeat) thread already running.
/// `slots_per_engine` carries the fleet-uniformity check across calls
/// (0 = first host sets it).
fn connect_host(
    cfg: &RouterConfig,
    addr: &str,
    base: usize,
    seed: u64,
    ev_tx: &Sender<EngineEvent>,
    slots_per_engine: &mut usize,
) -> Result<HostLink> {
    let connect_timeout = Duration::from_millis(cfg.connect_timeout_ms.max(1));
    let sock_addr: SocketAddr = addr
        .to_socket_addrs()
        .with_context(|| format!("resolving engine-host {addr}"))?
        .next()
        .with_context(|| format!("engine-host {addr} resolved to no address"))?;
    let stream = TcpStream::connect_timeout(&sock_addr, connect_timeout)
        .with_context(|| format!("connecting engine-host {addr}"))?;
    stream.set_nodelay(true).ok();
    // Bound writes too: a wedged host must surface as a link
    // error (and then a heartbeat death), never block dispatch.
    stream.set_write_timeout(Some(connect_timeout)).ok();
    let mut handshake = stream.try_clone().context("cloning host stream")?;
    wire::write_msg(
        &mut handshake,
        &WireMsg::Hello { proto: PROTO_VERSION, engine_base: base as u64, seed },
    )
    .with_context(|| format!("greeting engine-host {addr}"))?;
    // The handshake is the one read on this thread: bound it so a
    // hung host fails the connect instead of wedging the caller.
    stream.set_read_timeout(Some(connect_timeout)).ok();
    let ack = wire::read_msg(&mut handshake)
        .with_context(|| format!("awaiting HelloAck from {addr}"))?;
    stream.set_read_timeout(None).ok();
    let WireMsg::HelloAck { proto, engines, slots } = ack else {
        bail!("engine-host {addr}: expected HelloAck");
    };
    ensure!(
        proto == PROTO_VERSION,
        "engine-host {addr}: protocol v{proto}, this router speaks v{PROTO_VERSION}"
    );
    let engines = usize::try_from(engines).context("host engine count")?;
    let slots = usize::try_from(slots).context("host slot count")?;
    ensure!(engines >= 1, "engine-host {addr} reports zero engines");
    ensure!(slots >= 1, "engine-host {addr} reports zero slots");
    if *slots_per_engine == 0 {
        *slots_per_engine = slots;
    } else {
        ensure!(
            slots == *slots_per_engine,
            "engine-host {addr} runs {slots} slots/engine, fleet runs {slots_per_engine} \
             — slots must be uniform"
        );
    }
    let alive = Arc::new(AtomicBool::new(true));
    let closing = Arc::new(AtomicBool::new(false));
    let last_pong = Arc::new(Mutex::new(Instant::now()));
    let writer = Arc::new(Mutex::new(stream.try_clone().context("cloning writer")?));

    let reader = {
        let rd = stream.try_clone().context("cloning reader")?;
        let ev_tx = ev_tx.clone();
        let (alive, closing, last_pong) = (alive.clone(), closing.clone(), last_pong.clone());
        let (addr, n) = (addr.to_string(), engines);
        std::thread::Builder::new()
            .name(format!("router-read-{base}"))
            .spawn(move || {
                let mut rd = BufReader::new(rd);
                loop {
                    match wire::read_msg(&mut rd) {
                        Ok(WireMsg::Event(ev)) => {
                            if !event_engines_in_range(&ev, base, n) {
                                fail_link(
                                    &ev_tx,
                                    &alive,
                                    base,
                                    n,
                                    &addr,
                                    "event outside assigned engine range",
                                );
                                return;
                            }
                            if ev_tx.send(ev).is_err() {
                                return; // router side torn down
                            }
                        }
                        Ok(WireMsg::Pong { .. }) => {
                            *last_pong.lock().unwrap() = Instant::now();
                        }
                        Ok(_) => {
                            fail_link(&ev_tx, &alive, base, n, &addr, "unexpected frame from host");
                            return;
                        }
                        Err(e) => {
                            if !closing.load(Ordering::SeqCst) {
                                fail_link(
                                    &ev_tx,
                                    &alive,
                                    base,
                                    n,
                                    &addr,
                                    &format!("link error: {e:#}"),
                                );
                            }
                            return;
                        }
                    }
                }
            })
            .context("spawning router reader")?
    };

    let heartbeat = if cfg.heartbeat_ms > 0 {
        let ev_tx = ev_tx.clone();
        let (alive, closing, last_pong) = (alive.clone(), closing.clone(), last_pong.clone());
        let writer = writer.clone();
        let hb_stream = stream.try_clone().context("cloning heartbeat stream")?;
        let (addr, n) = (addr.to_string(), engines);
        let period = Duration::from_millis(cfg.heartbeat_ms);
        let deadline = period * cfg.heartbeat_misses.max(1);
        Some(
            std::thread::Builder::new()
                .name(format!("router-hb-{base}"))
                .spawn(move || {
                    let mut seq = 0u64;
                    loop {
                        // Sleep one period in small slices so
                        // shutdown never waits a full beat.
                        let mut slept = Duration::ZERO;
                        while slept < period {
                            if closing.load(Ordering::SeqCst) || !alive.load(Ordering::SeqCst) {
                                return;
                            }
                            let step = HB_POLL.min(period - slept);
                            std::thread::sleep(step);
                            slept += step;
                        }
                        seq += 1;
                        {
                            let mut w = writer.lock().unwrap();
                            let _ = wire::write_msg(&mut *w, &WireMsg::Ping { seq });
                        }
                        let age = last_pong.lock().unwrap().elapsed();
                        if age > deadline {
                            fail_link(
                                &ev_tx,
                                &alive,
                                base,
                                n,
                                &addr,
                                &format!(
                                    "heartbeat timeout ({}ms without a pong)",
                                    age.as_millis()
                                ),
                            );
                            // Sever so the reader unblocks too.
                            let _ = hb_stream.shutdown(Shutdown::Both);
                            return;
                        }
                    }
                })
                .context("spawning router heartbeat")?,
        )
    } else {
        None
    };

    Ok(HostLink {
        addr: addr.to_string(),
        base,
        engines,
        stream,
        writer,
        alive,
        closing,
        reader: Some(reader),
        heartbeat,
    })
}
