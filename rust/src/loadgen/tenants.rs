//! Tenant classes: interactive-eval vs bulk-rollout traffic.
//!
//! RL post-training gateways serve two very different tenants at once —
//! small latency-sensitive eval/interactive probes and heavy-tailed bulk
//! rollout generation. The mix matters: bulk stragglers are what evict
//! and preempt interactive work, which is exactly the contention the SLO
//! harness is supposed to expose.

use super::lengths::BoundedPareto;
use crate::util::Rng;

/// Traffic class a request belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TenantClass {
    /// Latency-sensitive interactive/eval traffic: short prompts, short
    /// bounded outputs.
    Interactive,
    /// Throughput-oriented bulk rollout traffic: heavier-tailed prompts
    /// and long-tailed outputs.
    Bulk,
}

impl TenantClass {
    /// Canonical lowercase name (report rows, JSONL fields).
    pub fn name(&self) -> &'static str {
        match self {
            TenantClass::Interactive => "interactive",
            TenantClass::Bulk => "bulk",
        }
    }
}

/// Per-class prompt/output length profile.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TenantProfile {
    /// Prompt-length distribution (tokens).
    pub prompt: BoundedPareto,
    /// Output-length distribution (tokens); enforced exactly through the
    /// work item's `max_total` length cap.
    pub output: BoundedPareto,
}

/// A fully sampled request: class plus concrete lengths.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RequestSpec {
    /// Traffic class the request was drawn from.
    pub class: TenantClass,
    /// Prompt length in tokens.
    pub prompt_len: usize,
    /// Target output length in tokens.
    pub out_len: usize,
}

/// The two-class tenant mix every open-loop run samples requests from.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TenantMix {
    /// Probability a request is [`TenantClass::Interactive`]; the rest
    /// are [`TenantClass::Bulk`].
    pub interactive_share: f64,
    /// Interactive profile.
    pub interactive: TenantProfile,
    /// Bulk profile.
    pub bulk: TenantProfile,
}

impl TenantMix {
    /// The default mix the SLO harness runs, scaled to MockBackend-sized
    /// sequences: interactive = short/nearly-uniform, bulk = heavy tail
    /// (alpha 1.2 outputs) so stragglers actually appear at test scale.
    pub fn default_mix(interactive_share: f64) -> TenantMix {
        assert!(
            (0.0..=1.0).contains(&interactive_share),
            "interactive_share must be in [0, 1]"
        );
        TenantMix {
            interactive_share,
            interactive: TenantProfile {
                prompt: BoundedPareto::new(4, 16, 2.5),
                output: BoundedPareto::new(4, 24, 2.5),
            },
            bulk: TenantProfile {
                prompt: BoundedPareto::new(8, 48, 1.8),
                output: BoundedPareto::new(8, 96, 1.2),
            },
        }
    }

    /// Sample one request spec (class, then lengths from its profile).
    pub fn sample(&self, rng: &mut Rng) -> RequestSpec {
        let class = if rng.next_f64() < self.interactive_share {
            TenantClass::Interactive
        } else {
            TenantClass::Bulk
        };
        let p = match class {
            TenantClass::Interactive => self.interactive,
            TenantClass::Bulk => self.bulk,
        };
        RequestSpec {
            class,
            prompt_len: p.prompt.sample(rng),
            out_len: p.output.sample(rng),
        }
    }

    /// Largest possible prompt length under either profile (engine
    /// `p_max` sizing).
    pub fn max_prompt(&self) -> usize {
        self.interactive.prompt.hi.max(self.bulk.prompt.hi)
    }

    /// Largest possible output length under either profile (EOS
    /// suppression sizing: the mock's scripted length must exceed this).
    pub fn max_output(&self) -> usize {
        self.interactive.output.hi.max(self.bulk.output.hi)
    }

    /// Largest possible total sequence (prompt + output) under either
    /// profile (backend `max_seq` sizing).
    pub fn max_total(&self) -> usize {
        let i = self.interactive.prompt.hi + self.interactive.output.hi;
        let b = self.bulk.prompt.hi + self.bulk.output.hi;
        i.max(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampling_respects_profiles_and_replays() {
        let mix = TenantMix::default_mix(0.5);
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        for _ in 0..1000 {
            let s = mix.sample(&mut a);
            assert_eq!(s, mix.sample(&mut b));
            let p = match s.class {
                TenantClass::Interactive => mix.interactive,
                TenantClass::Bulk => mix.bulk,
            };
            assert!((p.prompt.lo..=p.prompt.hi).contains(&s.prompt_len));
            assert!((p.output.lo..=p.output.hi).contains(&s.out_len));
        }
    }

    #[test]
    fn extreme_shares_collapse_to_one_class() {
        let mut rng = Rng::new(1);
        let all_bulk = TenantMix::default_mix(0.0);
        let all_inter = TenantMix::default_mix(1.0);
        for _ in 0..200 {
            assert_eq!(all_bulk.sample(&mut rng).class, TenantClass::Bulk);
            assert_eq!(all_inter.sample(&mut rng).class, TenantClass::Interactive);
        }
    }

    #[test]
    fn sizing_helpers_cover_both_profiles() {
        let mix = TenantMix::default_mix(0.5);
        assert_eq!(mix.max_prompt(), 48);
        assert_eq!(mix.max_output(), 96);
        assert_eq!(mix.max_total(), 48 + 96);
    }
}
