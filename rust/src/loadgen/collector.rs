//! SLO metrics collection: per-request lifecycle ledger → percentile
//! report.
//!
//! The collector is timestamp-agnostic: callers feed it virtual-clock
//! ticks (the lockstep sim, which can timestamp every token) or coarser
//! completion ticks (the threaded coordinator path, which sees tokens
//! only at finish). All latencies are integer tick counts rendered as
//! `f64`, so a fixed-seed run produces bit-identical percentiles — the
//! property the `deterministic` bench rows and `scripts/ci.sh --slo`
//! double-run diff gate on.

use std::collections::HashMap;

use super::clock::TICKS_PER_SEC;
use super::tenants::TenantClass;
use crate::util::stats;

/// Lifecycle ledger for one open-loop request.
#[derive(Clone, Debug)]
pub struct RequestRecord {
    /// Request id (harness-scoped, unique per run).
    pub id: u64,
    /// Traffic class.
    pub class: TenantClass,
    /// Arrival tick.
    pub arrival: u64,
    /// First-dispatch tick; `None` while queued or if shed.
    pub dispatched: Option<u64>,
    /// First generated-token tick (TTFT anchor).
    pub first_token: Option<u64>,
    /// Most recent generated-token tick (ITL anchor).
    pub last_token: Option<u64>,
    /// Completion tick.
    pub finished: Option<u64>,
    /// Generated tokens observed.
    pub tokens: usize,
    /// Times the request was preempted after dispatch.
    pub preemptions: u32,
    /// Shed at admission (bounded queue full; never dispatched).
    pub shed: bool,
}

/// Accumulates request lifecycles and inter-token gaps for one run.
#[derive(Clone, Debug, Default)]
pub struct SloCollector {
    records: Vec<RequestRecord>,
    index: HashMap<u64, usize>,
    itl_ticks: Vec<f64>,
    queue_depth_peak: usize,
}

impl SloCollector {
    /// Empty collector.
    pub fn new() -> SloCollector {
        SloCollector::default()
    }

    fn rec(&mut self, id: u64) -> &mut RequestRecord {
        let i = *self.index.get(&id).expect("slo: event for unknown request id");
        &mut self.records[i]
    }

    /// A request arrived at `tick`. Must precede every other event for
    /// `id`.
    pub fn on_arrival(&mut self, id: u64, class: TenantClass, tick: u64) {
        let i = self.records.len();
        assert!(self.index.insert(id, i).is_none(), "slo: duplicate arrival for {id}");
        self.records.push(RequestRecord {
            id,
            class,
            arrival: tick,
            dispatched: None,
            first_token: None,
            last_token: None,
            finished: None,
            tokens: 0,
            preemptions: 0,
            shed: false,
        });
    }

    /// The request was shed at admission (queue full) — the structured
    /// overload signal.
    pub fn on_shed(&mut self, id: u64) {
        let r = self.rec(id);
        assert!(r.dispatched.is_none(), "slo: shed after dispatch for {id}");
        r.shed = true;
    }

    /// The request was handed to an engine (first dispatch only; resumes
    /// after preemption do not reset it).
    pub fn on_dispatch(&mut self, id: u64, tick: u64) {
        let r = self.rec(id);
        if r.dispatched.is_none() {
            r.dispatched = Some(tick);
        }
    }

    /// One newly generated token was observed at `tick`. The first call
    /// anchors TTFT; later calls record inter-token gaps (which span
    /// preemption stalls — that is the point).
    pub fn on_token(&mut self, id: u64, tick: u64) {
        let prev = {
            let r = self.rec(id);
            let prev = r.last_token;
            if prev.is_none() {
                r.first_token = Some(tick);
            }
            r.last_token = Some(tick);
            r.tokens += 1;
            prev
        };
        if let Some(p) = prev {
            self.itl_ticks.push(tick.saturating_sub(p) as f64);
        }
    }

    /// Count `n` tokens without timing (coordinator path: the token batch
    /// is only visible at completion, so no TTFT/ITL anchors are set).
    pub fn add_tokens(&mut self, id: u64, n: usize) {
        self.rec(id).tokens += n;
    }

    /// The request was preempted (it will be re-queued and resumed).
    pub fn on_preempt(&mut self, id: u64) {
        self.rec(id).preemptions += 1;
    }

    /// The request completed at `tick`.
    pub fn on_finish(&mut self, id: u64, tick: u64) {
        let r = self.rec(id);
        assert!(!r.shed, "slo: finish for shed request {id}");
        assert!(r.finished.is_none(), "slo: duplicate finish for {id}");
        r.finished = Some(tick);
    }

    /// Record the admission-queue depth after an injection round.
    pub fn note_queue_depth(&mut self, depth: usize) {
        self.queue_depth_peak = self.queue_depth_peak.max(depth);
    }

    /// All request records, in arrival order.
    pub fn records(&self) -> &[RequestRecord] {
        &self.records
    }

    /// Aggregate the ledger into an [`SloReport`] over `horizon_ticks`
    /// of virtual time (used to normalize goodput).
    pub fn report(&self, horizon_ticks: u64) -> SloReport {
        let pct = |xs: &[f64], q: f64| if xs.is_empty() { 0.0 } else { stats::percentile(xs, q) };
        let ttft: Vec<f64> = self
            .records
            .iter()
            .filter_map(|r| r.first_token.map(|t| (t - r.arrival) as f64))
            .collect();
        let e2e: Vec<f64> = self
            .records
            .iter()
            .filter_map(|r| r.finished.map(|t| (t - r.arrival) as f64))
            .collect();
        let arrived = self.records.len();
        let shed = self.records.iter().filter(|r| r.shed).count();
        let completed = e2e.len();
        let completed_interactive = self
            .records
            .iter()
            .filter(|r| r.finished.is_some() && r.class == TenantClass::Interactive)
            .count();
        let tokens_out: usize = self.records.iter().map(|r| r.tokens).sum();
        let preemptions: u64 = self.records.iter().map(|r| r.preemptions as u64).sum();
        let horizon_s = horizon_ticks.max(1) as f64 / TICKS_PER_SEC as f64;
        SloReport {
            arrived,
            shed,
            completed,
            completed_interactive,
            completed_bulk: completed - completed_interactive,
            tokens_out,
            ttft_p50_ticks: pct(&ttft, 0.50),
            ttft_p99_ticks: pct(&ttft, 0.99),
            itl_p50_ticks: pct(&self.itl_ticks, 0.50),
            itl_p99_ticks: pct(&self.itl_ticks, 0.99),
            e2e_p50_ticks: pct(&e2e, 0.50),
            e2e_p99_ticks: pct(&e2e, 0.99),
            goodput_rps: completed as f64 / horizon_s,
            shed_rate: if arrived == 0 { 0.0 } else { shed as f64 / arrived as f64 },
            preemption_rate: if completed == 0 {
                0.0
            } else {
                preemptions as f64 / completed as f64
            },
            preemptions,
            queue_depth_peak: self.queue_depth_peak,
            horizon_ticks,
        }
    }
}

/// Aggregated SLO scoreboard for one open-loop run. All percentile
/// fields are virtual ticks (1 tick = 1 µs); zero when the underlying
/// series is empty (e.g. ITL on the coordinator path).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SloReport {
    /// Requests that arrived.
    pub arrived: usize,
    /// Requests shed at admission (bounded-queue tail drop).
    pub shed: usize,
    /// Requests that completed.
    pub completed: usize,
    /// Interactive-class completions.
    pub completed_interactive: usize,
    /// Bulk-class completions.
    pub completed_bulk: usize,
    /// Generated tokens across all requests.
    pub tokens_out: usize,
    /// Time-to-first-token p50.
    pub ttft_p50_ticks: f64,
    /// Time-to-first-token p99.
    pub ttft_p99_ticks: f64,
    /// Inter-token latency p50.
    pub itl_p50_ticks: f64,
    /// Inter-token latency p99 (spans preemption stalls).
    pub itl_p99_ticks: f64,
    /// End-to-end (arrival → finish) latency p50.
    pub e2e_p50_ticks: f64,
    /// End-to-end latency p99.
    pub e2e_p99_ticks: f64,
    /// Completed requests per virtual second over the horizon.
    pub goodput_rps: f64,
    /// Shed fraction of arrivals.
    pub shed_rate: f64,
    /// Preemptions per completed request.
    pub preemption_rate: f64,
    /// Total preemption events.
    pub preemptions: u64,
    /// Peak admission-queue depth observed.
    pub queue_depth_peak: usize,
    /// Virtual horizon goodput was normalized over.
    pub horizon_ticks: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_to_report_basic_flow() {
        let mut c = SloCollector::new();
        c.on_arrival(1, TenantClass::Interactive, 100);
        c.on_dispatch(1, 150);
        c.on_token(1, 200); // TTFT = 100
        c.on_token(1, 260); // ITL = 60
        c.on_token(1, 300); // ITL = 40
        c.on_finish(1, 300);
        c.on_arrival(2, TenantClass::Bulk, 120);
        c.on_shed(2);
        c.note_queue_depth(3);
        c.note_queue_depth(1);
        let r = c.report(TICKS_PER_SEC); // 1 virtual second
        assert_eq!(r.arrived, 2);
        assert_eq!(r.shed, 1);
        assert_eq!(r.completed, 1);
        assert_eq!(r.completed_interactive, 1);
        assert_eq!(r.completed_bulk, 0);
        assert_eq!(r.tokens_out, 3);
        assert_eq!(r.ttft_p50_ticks, 100.0);
        assert_eq!(r.itl_p50_ticks, 50.0);
        assert_eq!(r.e2e_p99_ticks, 200.0);
        assert_eq!(r.goodput_rps, 1.0);
        assert_eq!(r.shed_rate, 0.5);
        assert_eq!(r.queue_depth_peak, 3);
    }

    #[test]
    fn preemption_gap_lands_in_itl_tail() {
        let mut c = SloCollector::new();
        c.on_arrival(7, TenantClass::Bulk, 0);
        c.on_dispatch(7, 0);
        c.on_token(7, 10);
        c.on_preempt(7);
        c.on_token(7, 510); // 500-tick stall across the preemption
        c.on_finish(7, 510);
        let r = c.report(1000);
        assert_eq!(r.preemptions, 1);
        assert_eq!(r.preemption_rate, 1.0);
        assert_eq!(r.itl_p99_ticks, 500.0);
    }

    #[test]
    fn empty_series_report_is_all_zeros() {
        let r = SloCollector::new().report(1000);
        assert_eq!(r.arrived, 0);
        assert_eq!(r.ttft_p99_ticks, 0.0);
        assert_eq!(r.itl_p50_ticks, 0.0);
        assert_eq!(r.goodput_rps, 0.0);
        assert_eq!(r.shed_rate, 0.0);
    }
}
