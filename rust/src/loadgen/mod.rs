//! Open-loop traffic generation and SLO accounting.
//!
//! Every other harness in the repo is closed-loop: a fixed batch is
//! dispatched and drained, so the system's own speed sets the offered
//! load and tail latency is unobservable. This module supplies the
//! missing scoreboard — the open-loop, SLO-measured evaluation RollPacker
//! and Laminar use and that CoPRIS's long-tail-mitigation claim is only
//! meaningful against:
//!
//! - [`clock`] — the virtual clock (ticks = virtual µs) that removes
//!   wall time entirely, making fixed-seed runs bit-deterministic;
//! - [`arrivals`] — seeded Poisson and interrupted-Poisson (bursty)
//!   arrival schedules;
//! - [`lengths`] — bounded-Pareto heavy-tailed length sampling with
//!   analytic quantiles/mean for property testing;
//! - [`tenants`] — the interactive-eval vs bulk-rollout traffic mix;
//! - [`collector`] — the per-request lifecycle ledger aggregated into
//!   TTFT/ITL/e2e percentiles, goodput, shed and preemption rates;
//! - [`sim`] — the single-threaded lockstep simulator tier-1 and
//!   `benches/slo_harness.rs` run.
//!
//! The threaded counterpart lives in
//! [`Coordinator::run_open_loop`](crate::coordinator::Coordinator::run_open_loop),
//! which drives the real engine pool (including fault injection) off the
//! same schedule types with structural rather than bit-exact guarantees.
//! See docs/ARCHITECTURE.md §"Open-loop load and SLO accounting".

pub mod arrivals;
pub mod clock;
pub mod collector;
pub mod lengths;
pub mod sim;
pub mod tenants;

pub use arrivals::{ArrivalGen, ArrivalProcess};
pub use clock::{VirtualClock, TICKS_PER_SEC};
pub use collector::{RequestRecord, SloCollector, SloReport};
pub use lengths::BoundedPareto;
pub use sim::{run_sim, SimConfig, SimResult};
pub use tenants::{RequestSpec, TenantClass, TenantMix, TenantProfile};
