//! Seeded open-loop arrival processes on the virtual clock.
//!
//! Open-loop means arrivals do not wait for the system: the schedule is
//! fixed up front by the process + seed, and a slow scheduler simply
//! builds queue depth (or sheds) instead of silently throttling the
//! workload — the property that makes tail-latency numbers honest.
//! Both processes are generated from the deterministic [`crate::util::Rng`]
//! and quantized to whole ticks, so a `(process, seed)` pair replays a
//! byte-identical schedule on every host and profile.

use super::clock::TICKS_PER_SEC;
use crate::util::Rng;

/// Which arrival process generates the request schedule.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalProcess {
    /// Memoryless Poisson arrivals: exponential inter-arrival gaps with
    /// mean `1/rate_rps` virtual seconds.
    Poisson {
        /// Mean arrival rate in requests per virtual second.
        rate_rps: f64,
    },
    /// Interrupted-Poisson bursty arrivals: a square wave alternates ON
    /// phases (Poisson at a peak rate) and OFF phases (silence). The peak
    /// rate is scaled by `(on + off) / on` so the long-run average stays
    /// `rate_rps` — bursty and Poisson runs are load-comparable.
    Bursty {
        /// Long-run mean arrival rate in requests per virtual second.
        rate_rps: f64,
        /// ON-phase length in ticks (arrivals flow).
        on_ticks: u64,
        /// OFF-phase length in ticks (no arrivals).
        off_ticks: u64,
    },
}

impl ArrivalProcess {
    /// Canonical lowercase name (bench row labels, CLI echo).
    pub fn name(&self) -> &'static str {
        match self {
            ArrivalProcess::Poisson { .. } => "poisson",
            ArrivalProcess::Bursty { .. } => "bursty",
        }
    }
}

/// Seeded generator of strictly increasing absolute arrival ticks.
#[derive(Clone, Debug)]
pub struct ArrivalGen {
    process: ArrivalProcess,
    rng: Rng,
    cursor: u64,
}

impl ArrivalGen {
    /// New generator; an identical `(process, seed)` pair replays an
    /// identical schedule. Rates must be positive and the bursty ON
    /// phase non-empty.
    pub fn new(process: ArrivalProcess, seed: u64) -> ArrivalGen {
        match process {
            ArrivalProcess::Poisson { rate_rps } => {
                assert!(rate_rps > 0.0, "poisson rate_rps must be > 0");
            }
            ArrivalProcess::Bursty { rate_rps, on_ticks, .. } => {
                assert!(rate_rps > 0.0, "bursty rate_rps must be > 0");
                assert!(on_ticks > 0, "bursty on_ticks must be > 0");
            }
        }
        ArrivalGen { process, rng: Rng::new(seed).fork(0xA221_7A1), cursor: 0 }
    }

    /// One exponential inter-arrival gap at `rate_rps`, quantized to a
    /// whole number of ticks and clamped to >= 1 so the cursor strictly
    /// increases (generation always terminates).
    fn exp_ticks(rng: &mut Rng, rate_rps: f64) -> u64 {
        let mean_ticks = TICKS_PER_SEC as f64 / rate_rps;
        let u = rng.next_f64(); // [0, 1) — ln(1 - u) is finite
        let dt = -(1.0 - u).ln() * mean_ticks;
        (dt.round() as u64).max(1)
    }

    /// Absolute tick of the next arrival (strictly increasing).
    pub fn next_arrival(&mut self) -> u64 {
        match self.process {
            ArrivalProcess::Poisson { rate_rps } => {
                self.cursor += Self::exp_ticks(&mut self.rng, rate_rps);
            }
            ArrivalProcess::Bursty { rate_rps, on_ticks, off_ticks } => {
                // Thinning for the inhomogeneous process: draw candidates
                // at the peak ON rate everywhere and keep only those that
                // land in an ON phase (acceptance probability 0 in OFF).
                // Every candidate advances the cursor by >= 1 tick, so the
                // loop cannot livelock.
                let period = on_ticks + off_ticks;
                let peak = rate_rps * period as f64 / on_ticks as f64;
                loop {
                    self.cursor += Self::exp_ticks(&mut self.rng, peak);
                    if self.cursor % period < on_ticks {
                        break;
                    }
                }
            }
        }
        self.cursor
    }

    /// The next `n` arrival ticks as a schedule.
    pub fn schedule(&mut self, n: usize) -> Vec<u64> {
        (0..n).map(|_| self.next_arrival()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_same_seed_replays_identically() {
        let p = ArrivalProcess::Poisson { rate_rps: 500.0 };
        let a = ArrivalGen::new(p, 7).schedule(2000);
        let b = ArrivalGen::new(p, 7).schedule(2000);
        assert_eq!(a, b);
        let c = ArrivalGen::new(p, 8).schedule(2000);
        assert_ne!(a, c, "different seeds should diverge");
    }

    #[test]
    fn poisson_is_strictly_increasing_with_sane_mean() {
        let p = ArrivalProcess::Poisson { rate_rps: 1000.0 };
        let ticks = ArrivalGen::new(p, 42).schedule(4000);
        for w in ticks.windows(2) {
            assert!(w[1] > w[0], "arrival ticks must strictly increase");
        }
        // Mean gap should be near 1e6/1000 = 1000 ticks (generous ±15%).
        let mean = ticks[ticks.len() - 1] as f64 / ticks.len() as f64;
        assert!((mean - 1000.0).abs() < 150.0, "mean gap {mean} far from 1000");
    }

    #[test]
    fn bursty_respects_off_phases_and_long_run_rate() {
        let p = ArrivalProcess::Bursty {
            rate_rps: 1000.0,
            on_ticks: 20_000,
            off_ticks: 80_000,
        };
        let ticks = ArrivalGen::new(p, 3).schedule(4000);
        for &t in &ticks {
            assert!(t % 100_000 < 20_000, "arrival at {t} lands in an OFF phase");
        }
        for w in ticks.windows(2) {
            assert!(w[1] > w[0]);
        }
        // Long-run average preserved: mean gap ~ 1000 ticks (±20%).
        let mean = ticks[ticks.len() - 1] as f64 / ticks.len() as f64;
        assert!((mean - 1000.0).abs() < 200.0, "long-run mean gap {mean} far from 1000");
    }
}
