//! Heavy-tailed length sampling: bounded Pareto with analytic moments.
//!
//! Real serving traces are dominated by a power-law tail of long
//! generations (the long-tail stragglers CoPRIS's partial rollout is
//! built to absorb), so the harness samples prompt/output lengths from a
//! bounded Pareto. The distribution exposes its analytic quantile and
//! mean, which is what lets `tests/loadgen_determinism.rs` check the
//! empirical sample against closed-form targets instead of golden blobs.

use crate::util::Rng;

/// Bounded Pareto (power law truncated to `[lo, hi]`) over integer
/// token lengths.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BoundedPareto {
    /// Inclusive lower bound `L` (tokens).
    pub lo: usize,
    /// Inclusive upper bound `H` (tokens).
    pub hi: usize,
    /// Tail index `alpha`; smaller means heavier tail.
    pub alpha: f64,
}

impl BoundedPareto {
    /// New distribution; requires `0 < lo <= hi` and `alpha > 0`.
    pub fn new(lo: usize, hi: usize, alpha: f64) -> BoundedPareto {
        assert!(lo > 0, "bounded pareto lo must be > 0");
        assert!(lo <= hi, "bounded pareto needs lo <= hi");
        assert!(alpha > 0.0, "bounded pareto alpha must be > 0");
        BoundedPareto { lo, hi, alpha }
    }

    /// Analytic quantile (inverse CDF) at `u` in `[0, 1)`, as the
    /// continuous value before integer quantization.
    pub fn quantile(&self, u: f64) -> f64 {
        let l = self.lo as f64;
        let h = self.hi as f64;
        let r = (l / h).powf(self.alpha); // (L/H)^alpha in (0, 1]
        l / (1.0 - u * (1.0 - r)).powf(1.0 / self.alpha)
    }

    /// Analytic mean of the continuous distribution.
    pub fn mean(&self) -> f64 {
        let l = self.lo as f64;
        let h = self.hi as f64;
        let a = self.alpha;
        if l == h {
            return l;
        }
        if (a - 1.0).abs() < 1e-9 {
            // alpha = 1 limit: E[X] = ln(H/L) * (L*H) / (H - L).
            return (h / l).ln() * l * h / (h - l);
        }
        let la = l.powf(a);
        let scale = la / (1.0 - (l / h).powf(a));
        scale * (a / (a - 1.0)) * (1.0 / l.powf(a - 1.0) - 1.0 / h.powf(a - 1.0))
    }

    /// One sample, rounded to a whole token count and clamped to
    /// `[lo, hi]`.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let x = self.quantile(rng.next_f64());
        (x.round() as usize).clamp(self.lo, self.hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_stay_in_bounds_and_replay() {
        let d = BoundedPareto::new(8, 96, 1.2);
        let mut a = Rng::new(11);
        let mut b = Rng::new(11);
        for _ in 0..2000 {
            let x = d.sample(&mut a);
            assert!((8..=96).contains(&x));
            assert_eq!(x, d.sample(&mut b), "same seed must replay");
        }
    }

    #[test]
    fn quantile_is_monotone_and_anchored() {
        let d = BoundedPareto::new(4, 64, 2.0);
        assert!((d.quantile(0.0) - 4.0).abs() < 1e-9);
        let mut prev = 0.0;
        for i in 0..100 {
            let q = d.quantile(i as f64 / 100.0);
            assert!(q >= prev);
            assert!(q <= 64.0 + 1e-9);
            prev = q;
        }
    }

    #[test]
    fn analytic_mean_matches_numeric_integration() {
        // Trapezoid over the quantile function equals the mean; checks the
        // closed form (including the alpha=1 branch) against integration.
        for &(lo, hi, alpha) in &[(8usize, 96usize, 1.2f64), (4, 64, 1.0), (10, 40, 2.5)] {
            let d = BoundedPareto::new(lo, hi, alpha);
            let n = 200_000;
            let num: f64 =
                (0..n).map(|i| d.quantile((i as f64 + 0.5) / n as f64)).sum::<f64>() / n as f64;
            let rel = (num - d.mean()).abs() / d.mean();
            assert!(rel < 0.01, "mean mismatch for alpha={alpha}: {num} vs {}", d.mean());
        }
    }
}
