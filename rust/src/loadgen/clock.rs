//! The virtual clock every open-loop run is driven by.
//!
//! Ticks are virtual microseconds. Nothing in `loadgen` ever reads the
//! wall clock: arrival schedules, admission, token emission and the SLO
//! percentiles are all timestamped on this counter, so a fixed-seed run
//! is bit-deterministic in CI regardless of host speed — the property the
//! tier-1 gate and the `deterministic` bench rows rely on.

/// Virtual ticks per virtual second (1 tick = 1 virtual microsecond).
pub const TICKS_PER_SEC: u64 = 1_000_000;

/// Monotonic virtual clock. Time only moves when the harness says so:
/// one engine lockstep round costs a configured quantum, and idle gaps
/// fast-forward straight to the next scheduled arrival.
#[derive(Clone, Copy, Debug, Default)]
pub struct VirtualClock {
    now: u64,
}

impl VirtualClock {
    /// A clock at tick 0.
    pub fn new() -> VirtualClock {
        VirtualClock { now: 0 }
    }

    /// Current tick.
    pub fn now(&self) -> u64 {
        self.now
    }

    /// Advance by `ticks`.
    pub fn advance(&mut self, ticks: u64) {
        self.now += ticks;
    }

    /// Fast-forward to absolute tick `t`; a `t` in the past is a no-op
    /// (the clock never runs backwards).
    pub fn advance_to(&mut self, t: u64) {
        self.now = self.now.max(t);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advances_and_never_rewinds() {
        let mut c = VirtualClock::new();
        assert_eq!(c.now(), 0);
        c.advance(250);
        assert_eq!(c.now(), 250);
        c.advance_to(1000);
        assert_eq!(c.now(), 1000);
        c.advance_to(10);
        assert_eq!(c.now(), 1000, "advance_to must not rewind");
        c.advance(0);
        assert_eq!(c.now(), 1000);
    }
}
