//! Single-threaded lockstep open-loop simulation.
//!
//! This is the bit-deterministic arm of the harness: a pool of
//! [`Engine`]s over [`MockBackend`]s stepped in lockstep on the
//! [`VirtualClock`] (one round = one configured quantum), fed by a
//! seeded arrival schedule through a bounded admission queue. Because
//! there are no threads and no wall-clock reads, a fixed
//! [`SimConfig`] replays the exact same token-by-token schedule — and
//! therefore the exact same [`SloReport`] — on every run, every host,
//! and every build profile. The threaded coordinator path
//! (`Coordinator::run_open_loop`) trades that bit-exactness for real
//! concurrency; tier-1 and the bench gate use this one.
//!
//! Output lengths are enforced exactly: the mock's scripted EOS length
//! is pinned above every sampled output length (`min_len` past the mix
//! maximum, `spread` 1), so each request terminates by `LengthCap` at
//! precisely `prompt_len + out_len` tokens — including across
//! preemptions, since the cap counts prompt + resume + new tokens.

use std::collections::{HashMap, VecDeque};
use std::sync::Arc;

use super::arrivals::{ArrivalGen, ArrivalProcess};
use super::clock::VirtualClock;
use super::collector::{SloCollector, SloReport};
use super::tenants::{RequestSpec, TenantMix};
use crate::engine::{
    Engine, EngineEvent, EngineOpts, FinishReason, KvCacheConfig, MockBackend, SamplingParams,
    WorkItem,
};
use crate::util::Rng;

/// Configuration of one lockstep open-loop run.
#[derive(Clone, Debug, PartialEq)]
pub struct SimConfig {
    /// Engines stepped in lockstep.
    pub engines: usize,
    /// Decode slots per engine.
    pub slots: usize,
    /// Per-engine KV budget in blocks (0 = unlimited) — the pressure
    /// source for shedding/preemption scenarios.
    pub kv_budget_blocks: usize,
    /// Tokens per KV block.
    pub kv_block_size: usize,
    /// Continuous-batching step-token budget (0 = legacy slot admission).
    pub step_token_budget: usize,
    /// Admission-queue capacity; fresh arrivals beyond it are shed (tail
    /// drop). Preempted resumes re-queue at the FRONT and are never shed.
    pub queue_cap: usize,
    /// Virtual ticks one lockstep engine round costs.
    pub quantum_ticks: u64,
    /// Total arrivals to generate.
    pub requests: usize,
    /// Master seed (arrival schedule, tenant mix, engine RNGs).
    pub seed: u64,
    /// Arrival process.
    pub process: ArrivalProcess,
    /// Tenant mix requests are sampled from.
    pub mix: TenantMix,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            engines: 2,
            slots: 4,
            kv_budget_blocks: 0,
            kv_block_size: 16,
            step_token_budget: 0,
            queue_cap: 64,
            quantum_ticks: 1_000,
            requests: 200,
            seed: 0,
            process: ArrivalProcess::Poisson { rate_rps: 200.0 },
            mix: TenantMix::default_mix(0.5),
        }
    }
}

impl SimConfig {
    /// Build a sim config from the typed [`Config`](crate::config::Config)
    /// `[workload]` section (plus the engine-pool KV knobs), so the
    /// `copris slo` subcommand and the bench rows share one mapping.
    pub fn from_config(cfg: &crate::config::Config) -> SimConfig {
        use crate::config::WorkloadKind;
        let w = &cfg.workload;
        let process = match w.kind {
            WorkloadKind::Poisson => ArrivalProcess::Poisson { rate_rps: w.rate_rps },
            WorkloadKind::Bursty => ArrivalProcess::Bursty {
                rate_rps: w.rate_rps,
                on_ticks: w.burst_on_ms * 1_000,
                off_ticks: w.burst_off_ms * 1_000,
            },
        };
        SimConfig {
            engines: cfg.engine.engines.max(1),
            slots: w.slots_per_engine,
            kv_budget_blocks: cfg.engine.budget_blocks(),
            kv_block_size: cfg.engine.kv_block_size,
            step_token_budget: cfg.engine.step_token_budget,
            queue_cap: w.queue_cap,
            quantum_ticks: w.quantum_us,
            requests: w.requests,
            seed: cfg.train.seed,
            process,
            mix: TenantMix::default_mix(w.interactive_share),
        }
    }
}

/// Result of a lockstep run: the SLO scoreboard plus run-shape counters.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// The SLO report over the run's virtual horizon.
    pub report: SloReport,
    /// Lockstep engine rounds executed.
    pub rounds: u64,
    /// Final virtual tick.
    pub end_tick: u64,
    /// Sum of per-engine live-slot preemption counters (engine view;
    /// should equal `report.preemptions`).
    pub engine_preemptions: u64,
    /// Every non-shed arrival completed before the round cap (false only
    /// if the safety cap tripped — a livelock, which tests treat as a
    /// failure).
    pub completed_all: bool,
}

/// A queued (or re-queued) request waiting for an engine slot.
struct Queued {
    id: u64,
    prompt: Arc<[i32]>,
    resume: Vec<i32>,
    max_total: usize,
}

/// Run one lockstep open-loop simulation to completion.
pub fn run_sim(cfg: &SimConfig) -> SimResult {
    assert!(cfg.engines > 0 && cfg.slots > 0, "sim needs engines and slots");
    assert!(cfg.queue_cap > 0, "sim needs a non-zero admission queue");
    assert!(cfg.quantum_ticks > 0, "sim needs a non-zero round quantum");

    // Seed fan-out: independent streams for arrivals and the tenant mix
    // so changing one knob cannot silently reshuffle the other.
    let mut root = Rng::new(cfg.seed);
    let mut gen = ArrivalGen::new(cfg.process, root.next_u64());
    let mut mix_rng = root.fork(0x7E4A);

    // The full arrival schedule up front — open loop means the workload
    // never reacts to the system.
    let schedule: Vec<(u64, RequestSpec)> =
        (0..cfg.requests).map(|_| (gen.next_arrival(), cfg.mix.sample(&mut mix_rng))).collect();

    // Engines sized so no sampled request can violate submit()'s
    // invariants, with EOS pushed past every sampled output length so the
    // LengthCap is the only terminator (exact output lengths).
    let backend_max_seq = cfg.mix.max_total() + 8;
    let mut engines: Vec<Engine<MockBackend>> = (0..cfg.engines)
        .map(|id| {
            let mut b = MockBackend::new(cfg.slots, backend_max_seq);
            b.p_max = cfg.mix.max_prompt();
            b.min_len = cfg.mix.max_output() + 1;
            b.spread = 1;
            let opts = EngineOpts {
                kv: KvCacheConfig {
                    block_size: cfg.kv_block_size,
                    budget_blocks: cfg.kv_budget_blocks,
                    prefix_sharing: false,
                    ..KvCacheConfig::default()
                },
                step_token_budget: cfg.step_token_budget,
            };
            let seed = cfg.seed ^ (id as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            Engine::with_opts(id, b, opts, seed)
        })
        .collect();

    let mut clock = VirtualClock::new();
    let mut collector = SloCollector::new();
    let mut queue: VecDeque<Queued> = VecDeque::new();
    // Per-assignment generated-token counts (diffed for emission ticks)
    // and tokens accumulated across preemptions (the resume prefix).
    let mut progress: HashMap<u64, usize> = HashMap::new();
    let mut acc: HashMap<u64, Vec<i32>> = HashMap::new();
    let mut meta: HashMap<u64, (Arc<[i32]>, usize)> = HashMap::new();

    let mut next_arr = 0usize;
    let mut rounds = 0u64;
    let mut inflight = 0usize; // admitted (queued or on an engine), not yet finished
    let round_cap = 1_000 + cfg.requests as u64 * (cfg.mix.max_total() as u64 + 8) * 4;
    let mut events: Vec<EngineEvent> = Vec::new();

    loop {
        // 1. Inject every arrival due by now; shed past the queue bound.
        while next_arr < schedule.len() && schedule[next_arr].0 <= clock.now() {
            let (tick, spec) = schedule[next_arr];
            let id = next_arr as u64;
            next_arr += 1;
            collector.on_arrival(id, spec.class, tick);
            if queue.len() >= cfg.queue_cap {
                collector.on_shed(id);
                continue;
            }
            let prompt: Arc<[i32]> = (0..spec.prompt_len)
                .map(|t| 1 + ((id as usize + t) % 40) as i32)
                .collect::<Vec<i32>>()
                .into();
            let max_total = spec.prompt_len + spec.out_len;
            meta.insert(id, (prompt.clone(), max_total));
            queue.push_back(Queued { id, prompt, resume: Vec::new(), max_total });
            inflight += 1;
        }
        collector.note_queue_depth(queue.len());

        // 2. Admit: feed least-loaded engines one pending item at a time;
        // an engine whose own admission is backpressured (queued() > 0,
        // e.g. KV-budget headroom) is skipped, which is exactly the
        // bounded-backpressure path.
        while !queue.is_empty() {
            let target = engines
                .iter()
                .enumerate()
                .filter(|(_, e)| e.free_slots() > 0 && e.queued() == 0)
                .min_by_key(|(i, e)| (e.busy(), *i))
                .map(|(i, _)| i);
            let Some(ei) = target else { break };
            let q = queue.pop_front().unwrap();
            collector.on_dispatch(q.id, clock.now());
            engines[ei]
                .submit(WorkItem {
                    request_id: q.id,
                    prompt: q.prompt,
                    resume: q.resume,
                    max_total: q.max_total,
                    sampling: SamplingParams::greedy(),
                    retain: None,
                    prefix: None,
                })
                .expect("sim sized the backend for every sampled request");
        }

        // 3. Idle? Fast-forward to the next arrival or finish.
        let any_work = engines.iter().any(|e| e.has_work());
        if !any_work && queue.is_empty() {
            if next_arr >= schedule.len() {
                break;
            }
            clock.advance_to(schedule[next_arr].0);
            continue;
        }

        // 4. One lockstep round: the quantum elapses, every engine with
        // work takes one step, and newly generated tokens are stamped at
        // the round boundary.
        clock.advance(cfg.quantum_ticks);
        rounds += 1;
        let now = clock.now();
        for e in engines.iter_mut() {
            if !e.has_work() {
                continue;
            }
            events.clear();
            e.step(&mut events).expect("mock engine step cannot fail");
            for (rid, len) in e.slot_progress() {
                let prev = progress.get(&rid).copied().unwrap_or(0);
                for _ in prev..len {
                    collector.on_token(rid, now);
                }
                if len > prev {
                    progress.insert(rid, len);
                }
            }
            for ev in events.drain(..) {
                let EngineEvent::Done { result, .. } = ev else { continue };
                let rid = result.request_id;
                let prev = progress.remove(&rid).unwrap_or(0);
                for _ in prev..result.new_tokens.len() {
                    collector.on_token(rid, now);
                }
                let stored = acc.entry(rid).or_default();
                stored.extend_from_slice(&result.new_tokens);
                match result.reason {
                    FinishReason::Eos | FinishReason::LengthCap => {
                        let (prompt, max_total) = &meta[&rid];
                        debug_assert_eq!(
                            prompt.len() + acc[&rid].len(),
                            *max_total,
                            "LengthCap must terminate at exactly the sampled length"
                        );
                        collector.on_finish(rid, now);
                        inflight -= 1;
                    }
                    FinishReason::Preempted => {
                        collector.on_preempt(rid);
                        let (prompt, max_total) = meta[&rid].clone();
                        // Front of the queue: preempted work is never
                        // shed and resumes before fresh arrivals.
                        queue.push_front(Queued {
                            id: rid,
                            prompt,
                            resume: acc[&rid].clone(),
                            max_total,
                        });
                    }
                    FinishReason::Stopped => {
                        unreachable!("sim never issues StopGeneration")
                    }
                }
            }
        }

        if rounds >= round_cap {
            break; // livelock safety valve; surfaces as !completed_all
        }
    }

    let report = collector.report(clock.now().max(1));
    let engine_preemptions: u64 = engines.iter().map(|e| e.preemptions()).sum();
    let completed_all = inflight == 0 && report.completed + report.shed == report.arrived;
    SimResult { report, rounds, end_tick: clock.now(), engine_preemptions, completed_all }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unloaded_run_completes_everything_unshed() {
        let cfg = SimConfig {
            requests: 60,
            process: ArrivalProcess::Poisson { rate_rps: 50.0 },
            ..SimConfig::default()
        };
        let r = run_sim(&cfg);
        assert!(r.completed_all);
        assert_eq!(r.report.arrived, 60);
        assert_eq!(r.report.shed, 0);
        assert_eq!(r.report.completed, 60);
        assert!(r.report.ttft_p50_ticks > 0.0);
        assert!(r.report.goodput_rps > 0.0);
    }

    #[test]
    fn same_seed_replays_bit_identically() {
        let cfg = SimConfig { requests: 120, seed: 9, ..SimConfig::default() };
        let a = run_sim(&cfg);
        let b = run_sim(&cfg);
        assert_eq!(a.report, b.report);
        assert_eq!(a.rounds, b.rounds);
        assert_eq!(a.end_tick, b.end_tick);
    }

    #[test]
    fn from_config_maps_the_workload_section() {
        let mut c = crate::config::Config::new("tiny");
        c.set("workload.process", "bursty").unwrap();
        c.set("workload.rate_rps", "800").unwrap();
        c.set("workload.burst_on_ms", "5").unwrap();
        c.set("workload.burst_off_ms", "15").unwrap();
        c.set("workload.requests", "42").unwrap();
        c.set("workload.queue_cap", "7").unwrap();
        c.set("workload.quantum_us", "250").unwrap();
        c.set("workload.slots_per_engine", "3").unwrap();
        c.set("train.seed", "11").unwrap();
        let s = SimConfig::from_config(&c);
        assert_eq!(
            s.process,
            ArrivalProcess::Bursty { rate_rps: 800.0, on_ticks: 5_000, off_ticks: 15_000 }
        );
        assert_eq!(s.requests, 42);
        assert_eq!(s.queue_cap, 7);
        assert_eq!(s.quantum_ticks, 250);
        assert_eq!(s.slots, 3);
        assert_eq!(s.seed, 11);
        assert_eq!(s.engines, c.engine.engines);
    }

    #[test]
    fn overload_sheds_but_conserves_every_request() {
        let cfg = SimConfig {
            engines: 1,
            slots: 2,
            queue_cap: 4,
            requests: 150,
            process: ArrivalProcess::Poisson { rate_rps: 5_000.0 },
            ..SimConfig::default()
        };
        let r = run_sim(&cfg);
        assert!(r.completed_all, "bounded queue must not deadlock under overload");
        assert!(r.report.shed > 0, "sustained overload over a 4-deep queue must shed");
        assert_eq!(r.report.completed + r.report.shed, r.report.arrived);
        assert!(r.report.queue_depth_peak <= 4);
    }
}
