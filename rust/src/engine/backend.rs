//! Generation backends: the real PJRT-driven `XlaBackend` and a scripted
//! `MockBackend` for deterministic coordinator/engine tests without
//! artifacts.

use anyhow::Result;
use xla::PjRtBuffer;

use super::kvcache::{f16_bits_to_f32, f32_to_f16_bits, int8_roundtrip, int8_row_scale, KvDtype};
use crate::model::ModelRuntime;
use crate::tokenizer;

/// Classified backend failure, consumed by the engine-thread supervisor
/// (`pool::run_loop`). Backends that can tell a recoverable hiccup (device
/// transport timeout, transient allocation pressure) from a wedged device
/// wrap their errors in this type; everything else — including plain
/// `anyhow` errors — is treated as [`BackendError::Fatal`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum BackendError {
    /// Retryable: the supervisor retries the engine step in place with
    /// bounded exponential backoff (`engine.max_retries` attempts,
    /// `engine.retry_backoff_ms` base) before giving up.
    Transient(String),
    /// Non-retryable: the engine declares itself failed immediately
    /// (`EngineEvent::EngineFailed`).
    Fatal(String),
}

impl std::fmt::Display for BackendError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BackendError::Transient(msg) => write!(f, "transient backend error: {msg}"),
            BackendError::Fatal(msg) => write!(f, "fatal backend error: {msg}"),
        }
    }
}

impl std::error::Error for BackendError {}

/// True when `err`'s chain contains a [`BackendError::Transient`] — the
/// supervisor's retry classification. Anything unclassified is fatal: a
/// backend that cannot vouch for its own state must not be blindly
/// re-driven.
pub fn is_transient(err: &anyhow::Error) -> bool {
    err.chain()
        .any(|c| matches!(c.downcast_ref::<BackendError>(), Some(BackendError::Transient(_))))
}

/// Abstracts prefill/decode so the engine loop and the whole coordinator
/// stack are testable without PJRT (see `MockBackend`).
pub trait Backend {
    /// Concurrent decode slots this backend batches over.
    fn slots(&self) -> usize;
    /// Vocabulary size (logit-row width).
    fn vocab(&self) -> usize;
    /// Decode horizon: max absolute sequence length (prompt + response).
    fn max_seq(&self) -> usize;
    /// Max prompt (and replay-chunk) length per prefill call.
    fn p_max(&self) -> usize;
    /// Weight sync: install a new parameter vector.
    fn set_params(&mut self, params: &[f32]) -> Result<()>;
    /// Prefill `prompt` into `slot`; returns next-token logits [V].
    fn prefill(&mut self, slot: usize, prompt: &[i32]) -> Result<Vec<f32>>;
    /// One decode step over all slots; returns logits [S*V] row-major.
    /// Cold-path convenience — the engine's hot loop uses `decode_into`.
    fn decode(&mut self, tokens: &[i32], pos: &[i32]) -> Result<Vec<f32>>;
    /// One decode step writing logits [S*V] into a caller-owned buffer
    /// that is reused across steps (resized on first use, then constant
    /// capacity). Backends override this to avoid re-allocating the S×V
    /// output every step; the default falls back to `decode`.
    fn decode_into(&mut self, tokens: &[i32], pos: &[i32], out: &mut Vec<f32>) -> Result<()> {
        let logits = self.decode(tokens, pos)?;
        out.clear();
        out.extend_from_slice(&logits);
        Ok(())
    }
    /// Chunked re-prefill of ≤ p_max resume tokens for one slot (vLLM-style
    /// parallel recompute). Returns Some(next-token logits) when supported;
    /// None → the engine falls back to per-token decode replay.
    fn replay(&mut self, _slot: usize, _chunk: &[i32], _start: usize) -> Result<Option<Vec<f32>>> {
        Ok(None)
    }
    /// Chunked prompt ingestion (continuous batching): feed the prompt
    /// slice covering positions `[start, start + chunk.len())` to `slot`.
    /// Chunks of one prompt arrive strictly in order and each is ≤ p_max
    /// tokens; `start == 0` begins a fresh prompt, discarding any
    /// partially staged one (a mid-prefill preemption leaves staged chunks
    /// behind — the next occupant's first chunk resets them). With `last`,
    /// the prompt is complete: the backend executes the prefill and
    /// returns the next-token logits `[V]`, bit-identical to what
    /// [`Backend::prefill`] returns for the whole prompt.
    ///
    /// Backends without an incremental prefill kernel stage chunks
    /// host-side and run one prefill on the final chunk; the engine's
    /// step-token budget (not this call) is what spreads prompt ingestion
    /// across steps on such backends. The default errors — only backends
    /// that opt in may be driven with `engine.step_token_budget > 0`.
    fn prefill_chunk(
        &mut self,
        slot: usize,
        chunk: &[i32],
        start: usize,
        last: bool,
    ) -> Result<Option<Vec<f32>>> {
        let _ = (slot, chunk, start, last);
        anyhow::bail!("backend does not support chunked prefill (engine.step_token_budget)")
    }
    /// KV retention: keep `slot`'s resident KV valid after the sequence is
    /// flushed, so a later [`Backend::resume_retained`] can continue
    /// decoding from it with zero replay. Returns `Ok(false)` when the
    /// backend cannot guarantee retention (the engine then flushes plainly
    /// and the resume takes the replay path).
    ///
    /// Contract the engine upholds while a slot is retained: lockstep
    /// decode steps stage the slot at its *pending feed position* with a
    /// dummy token, and the resume's first real feed lands on that same
    /// position — so a backend whose decode writes-then-attends at the fed
    /// position never exposes the dummy write (it is overwritten before it
    /// can be attended). Positions `< pos` are never written while
    /// retained.
    fn retain_slot(&mut self, _slot: usize) -> Result<bool> {
        Ok(false)
    }
    /// Re-activate a slot previously accepted by [`Backend::retain_slot`]:
    /// restore whatever per-slot decode state the backend keeps outside
    /// the KV itself (the mock restores its script cursor; the PJRT
    /// backend's state is entirely device-resident, so this is a no-op).
    fn resume_retained(&mut self, _slot: usize) -> Result<()> {
        Ok(())
    }
    /// Drop retained state for `slot` (eviction/invalidation). Must be
    /// safe to call for slots that were never retained.
    fn release_retained(&mut self, _slot: usize) -> Result<()> {
        Ok(())
    }
    /// Mirror `slot`'s logical KV block chain (see `engine::kvcache`) to
    /// the backend: `blocks` covers `len_tokens` resident tokens in
    /// `block_size`-token pages. Called only when the chain *changes*
    /// (admission, a fresh block at a boundary, a copy-on-write tail
    /// replacement, or a free — an empty table). The default ignores it;
    /// `MockBackend` enforces the mapping invariants bit-exactly,
    /// `XlaBackend` keeps a device-side table staged for a future paged
    /// decode artifact (the current slot-contiguous AOT kernel implies the
    /// identity layout, so nothing is re-addressed yet).
    fn set_block_table(
        &mut self,
        _slot: usize,
        _blocks: &[u32],
        _len_tokens: usize,
        _block_size: usize,
    ) -> Result<()> {
        Ok(())
    }
    /// Install the KV storage dtype (`engine.kv_dtype`). Called once at
    /// engine construction, before any prefill. Infallible by design: a
    /// backend that cannot store narrow KV simply keeps f32 behavior (the
    /// default ignores the hint) — the *budget* arithmetic lives entirely
    /// engine-side ([`super::kvcache::KvCacheConfig::effective_budget_blocks`]).
    /// `MockBackend` models the lossiness deterministically
    /// (quantize→dequantize on every emitted logit row); `XlaBackend`
    /// stages the dtype for the device-side cache.
    fn set_kv_dtype(&mut self, _dtype: KvDtype) {}
}

// ---------------------------------------------------------------------------
// XlaBackend
// ---------------------------------------------------------------------------

/// PJRT-backed engine: device-resident engine state (logits header ++ KV)
/// threaded through the decode artifact; weights installed via host sync.
pub struct XlaBackend {
    rt: ModelRuntime,
    params: PjRtBuffer,
    engine_state: PjRtBuffer,
    /// Device-side KV block table per slot (host mirror). The engine keeps
    /// the authoritative paged accounting (`engine::kvcache`); this table
    /// is the per-slot chain a paged decode artifact would consume. The
    /// current slot-contiguous AOT kernel addresses KV by (slot, position)
    /// directly, so the table is tracked-but-not-yet-consumed.
    block_tables: Vec<Vec<u32>>,
    /// Host-side packed staging for chunked prefill: per-slot prompt
    /// chunks accumulate here and execute as ONE padded prefill launch on
    /// the final chunk (the AOT prefill artifact has a fixed p_max layout,
    /// so there is nothing to gain from partial launches — the engine's
    /// step-token budget is what interleaves ingestion with decode).
    /// Buffers are reused across prompts, so steady-state chunk staging
    /// does not allocate once per-slot capacity has warmed up.
    prefill_staged: Vec<Vec<i32>>,
    /// Use the chunked `replay` artifact for resumption instead of
    /// per-token decode. MEASURED SLOWER on this substrate (see
    /// EXPERIMENTS.md §Perf): per-token replay rides along in batched
    /// decode steps whose idle-slot compute is already paid, while the
    /// chunked artifact adds dedicated serial work. Kept for saturated
    /// regimes; off by default.
    pub chunked_replay: bool,
    /// KV storage dtype staged for the device-side cache. The current
    /// slot-contiguous AOT artifacts keep f32 KV, so (like the block
    /// tables) the dtype is tracked-but-not-yet-consumed; the engine-side
    /// budget arithmetic is what widens capacity today.
    kv_dtype: KvDtype,
}

impl XlaBackend {
    /// Build from an artifacts dir + variant, with initial params.
    pub fn open(artifacts_dir: &str, variant: &str, params: &[f32]) -> Result<XlaBackend> {
        let mut rt = ModelRuntime::open(artifacts_dir, variant)?;
        rt.warmup(&["prefill", "decode", "read_header"])?;
        let params_buf = rt.upload_params(params)?;
        let engine_state = rt.fresh_engine_state()?;
        let slots = rt.spec.slots;
        Ok(XlaBackend {
            rt,
            params: params_buf,
            engine_state,
            block_tables: vec![Vec::new(); slots],
            prefill_staged: vec![Vec::new(); slots],
            chunked_replay: false,
            kv_dtype: KvDtype::F32,
        })
    }

    /// The loaded artifact manifest (slots, vocab, max_seq, …).
    pub fn spec(&self) -> &crate::runtime::Manifest {
        &self.rt.spec
    }

    /// The device-side block table currently installed for `slot`
    /// (diagnostics / artifact-gated tests).
    pub fn block_table(&self, slot: usize) -> &[u32] {
        &self.block_tables[slot]
    }

    /// The KV dtype staged for the device cache (diagnostics).
    pub fn kv_dtype(&self) -> KvDtype {
        self.kv_dtype
    }
}

impl Backend for XlaBackend {
    fn slots(&self) -> usize {
        self.rt.spec.slots
    }
    fn vocab(&self) -> usize {
        self.rt.spec.vocab
    }
    fn max_seq(&self) -> usize {
        self.rt.spec.max_seq
    }
    fn p_max(&self) -> usize {
        self.rt.spec.p_max
    }

    fn set_params(&mut self, params: &[f32]) -> Result<()> {
        self.params = self.rt.upload_params(params)?;
        Ok(())
    }

    fn prefill(&mut self, slot: usize, prompt: &[i32]) -> Result<Vec<f32>> {
        self.prefill_staged[slot].clear();
        let (es, logits) = self.rt.prefill(&self.params, &self.engine_state, prompt, slot)?;
        self.engine_state = es;
        Ok(logits)
    }

    // Chunked prefill: stage chunks host-side in the packed per-slot
    // layout, execute one prefill launch when the prompt completes. The
    // returned logits are bit-identical to a whole-prompt `prefill` by
    // construction (same artifact, same input).
    fn prefill_chunk(
        &mut self,
        slot: usize,
        chunk: &[i32],
        start: usize,
        last: bool,
    ) -> Result<Option<Vec<f32>>> {
        if start == 0 {
            self.prefill_staged[slot].clear();
        }
        anyhow::ensure!(
            start == self.prefill_staged[slot].len(),
            "slot {slot}: prefill chunk starts at {start}, staged {}",
            self.prefill_staged[slot].len()
        );
        self.prefill_staged[slot].extend_from_slice(chunk);
        if !last {
            return Ok(None);
        }
        let prompt = std::mem::take(&mut self.prefill_staged[slot]);
        let (es, logits) = self.rt.prefill(&self.params, &self.engine_state, &prompt, slot)?;
        self.engine_state = es;
        // Hand the (now empty) buffer back so its capacity is reused.
        self.prefill_staged[slot] = prompt;
        self.prefill_staged[slot].clear();
        Ok(Some(logits))
    }

    fn decode(&mut self, tokens: &[i32], pos: &[i32]) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.decode_into(tokens, pos, &mut out)?;
        Ok(out)
    }

    fn decode_into(&mut self, tokens: &[i32], pos: &[i32], out: &mut Vec<f32>) -> Result<()> {
        let es = self.rt.decode_into(&self.params, &self.engine_state, tokens, pos, out)?;
        self.engine_state = es;
        Ok(())
    }

    fn replay(&mut self, slot: usize, chunk: &[i32], start: usize) -> Result<Option<Vec<f32>>> {
        if !self.chunked_replay || start + self.rt.spec.p_max > self.rt.spec.max_seq {
            return Ok(None); // per-token fallback (default; see field docs)
        }
        let (es, logits) =
            self.rt.replay(&self.params, &self.engine_state, chunk, start, slot)?;
        self.engine_state = es;
        Ok(Some(logits))
    }

    // KV retention: the per-slot KV lives inside the device-resident
    // `engine_state` buffer and nothing host-side needs saving, so
    // retention is free. Validity rests on the engine's retained-slot
    // position discipline (see `Backend::retain_slot`): while retained,
    // lockstep decodes only write the slot's pending feed position, which
    // the resume overwrites before attending to it; the retained prefix at
    // positions `< pos` is never touched. That write-then-attend contract
    // is verified against the real kernel by the artifact-gated
    // `xla_retained_resume_matches_uninterrupted_stream` test in
    // rust/tests/e2e_tiny.rs (mock-backed golden tests cannot cover it).
    fn retain_slot(&mut self, _slot: usize) -> Result<bool> {
        Ok(true)
    }
    fn resume_retained(&mut self, _slot: usize) -> Result<()> {
        Ok(())
    }
    fn release_retained(&mut self, _slot: usize) -> Result<()> {
        Ok(())
    }

    // Paged KV: keep the per-slot block table resident device-side (host
    // mirror until a paged decode artifact consumes it). The buffer is
    // reused across installs so the decode hot path stays allocation-free
    // once per-slot capacity has warmed up.
    fn set_block_table(
        &mut self,
        slot: usize,
        blocks: &[u32],
        _len_tokens: usize,
        _block_size: usize,
    ) -> Result<()> {
        let t = &mut self.block_tables[slot];
        t.clear();
        t.extend_from_slice(blocks);
        Ok(())
    }

    fn set_kv_dtype(&mut self, dtype: KvDtype) {
        self.kv_dtype = dtype;
    }
}

// ---------------------------------------------------------------------------
// MockBackend
// ---------------------------------------------------------------------------

/// Deterministic scripted backend. Each request's response length is a hash
/// of its prompt (heterogeneous — reproduces the long-tail); the "model"
/// emits near-one-hot logits over digit tokens, then EOS at the scripted
/// length. `params_epoch` shifts the script so weight syncs are observable.
pub struct MockBackend {
    slots: usize,
    vocab: usize,
    max_seq: usize,
    /// Max prompt / chunk length per prefill call (default 24; benches
    /// crank it up for long-prompt continuous-batching mixes).
    pub p_max: usize,
    /// Per-slot: (prompt_hash, generated_count) driving the script.
    slot_script: Vec<(u64, usize)>,
    /// Retained-slot script stash: the mock's "KV" is its script cursor,
    /// which `decode_into` advances for every slot every step — `retain`
    /// snapshots it and `resume_retained` restores it. Keyed by slot.
    /// Crucially the stash keeps the hash computed under the epoch the
    /// sequence was generated with, so resuming retained state across a
    /// weight sync continues the OLD script — exactly the stale-KV
    /// semantics a real backend has.
    retained_script: std::collections::HashMap<usize, (u64, usize)>,
    /// Chunked-prefill staging: per-slot prompt chunks accumulated so far.
    /// Every chunk boundary is validated bit-exactly (strictly in-order
    /// ingestion; `start == 0` resets — mid-prefill preemption semantics).
    prefill_staged: Vec<Vec<i32>>,
    /// Prompt length of each slot's last completed prefill (replay-slice
    /// boundary validation: a slice must start at plen + tokens already
    /// replayed).
    slot_plen: Vec<usize>,
    /// Ingestion cursor stash, keyed by slot: (prompt hash, resume tokens
    /// replayed so far). Like `retained_script`, this exists because the
    /// lockstep `decode_into` advances EVERY slot's live cursor each step,
    /// so a slot whose resume is being slice-replayed across several
    /// engine steps drifts in between slices — the stash, not the live
    /// cursor, is the source of truth for the next slice. The final slice
    /// (and the final prompt chunk) writes the live cursor too, so decode
    /// picks up exactly where ingestion ended.
    ingest: std::collections::HashMap<usize, (u64, usize)>,
    /// Per-slot installed KV block table (paged-KV enforcement state).
    blk_tables: Vec<Vec<u32>>,
    /// Resident token count the last install of each slot claimed.
    blk_lens: Vec<usize>,
    /// Block size learned from the first install (0 = none yet); installs
    /// must never change it.
    blk_size: usize,
    /// Count of block-table installs (paged-KV assertions in tests).
    pub block_table_installs: u64,
    /// Epoch derived from the last `set_params` (shifts every script).
    pub params_epoch: u64,
    /// Scripted length = min_len + hash % spread.
    pub min_len: usize,
    /// Scripted length spread (see `min_len`).
    pub spread: usize,
    /// Count of decode calls (cost accounting in tests).
    pub decode_calls: usize,
    /// Count of prefill calls (cost accounting in tests).
    pub prefill_calls: usize,
    /// Count of retained-slot resumes (fast-path assertions in tests).
    pub resume_retained_calls: usize,
    /// Count of `prefill_chunk` calls (continuous-batching cost
    /// accounting in tests).
    pub prefill_chunk_calls: usize,
    /// Count of accepted `replay` slices.
    pub replay_calls: usize,
    /// Accept chunked `replay` slices (mirrors `XlaBackend.chunked_replay`;
    /// off = decline with `None`, so resumes ride per-token decode replay
    /// exactly like the legacy path).
    pub chunked_replay: bool,
    /// Artificial per-decode latency (tests that need slow engines).
    pub decode_delay: Option<std::time::Duration>,
    /// Artificial per-token prefill/replay-slice latency (continuous-
    /// batching benches: simulates the prefill compute that stalls
    /// co-resident decodes under slot admission).
    pub prefill_delay_per_token: Option<std::time::Duration>,
    /// KV storage dtype the mock models. Lossy dtypes apply a
    /// deterministic quantize→dequantize round-trip to every emitted
    /// logit row — the mock's "KV" is its script cursor, so perturbing
    /// the logits it derives from that cursor is the faithful analogue of
    /// reading attention outputs back through a narrow cache. The mock's
    /// logit alphabet (-20/6/10) is exactly representable in binary16, so
    /// f16 streams are bit-identical to f32 (that IS the f16 golden);
    /// int8 perturbs values deterministically but preserves every argmax.
    kv_dtype: KvDtype,
}

impl MockBackend {
    /// Build a mock with `slots` decode slots and a `max_seq` horizon.
    pub fn new(slots: usize, max_seq: usize) -> MockBackend {
        MockBackend {
            slots,
            vocab: tokenizer::VOCAB,
            max_seq,
            p_max: 24,
            slot_script: vec![(0, 0); slots],
            retained_script: std::collections::HashMap::new(),
            prefill_staged: vec![Vec::new(); slots],
            slot_plen: vec![0; slots],
            ingest: std::collections::HashMap::new(),
            blk_tables: vec![Vec::new(); slots],
            blk_lens: vec![0; slots],
            blk_size: 0,
            block_table_installs: 0,
            params_epoch: 0,
            min_len: 2,
            spread: 12,
            decode_calls: 0,
            prefill_calls: 0,
            resume_retained_calls: 0,
            prefill_chunk_calls: 0,
            replay_calls: 0,
            chunked_replay: false,
            decode_delay: None,
            prefill_delay_per_token: None,
            kv_dtype: KvDtype::F32,
        }
    }

    /// The KV dtype the mock is modeling (test assertions).
    pub fn kv_dtype(&self) -> KvDtype {
        self.kv_dtype
    }

    fn hash(xs: &[i32], epoch: u64) -> u64 {
        let mut h = 0xcbf29ce484222325u64 ^ epoch.wrapping_mul(0x100000001b3);
        for &x in xs {
            h ^= x as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        // splitmix finalizer: FNV alone mixes small ints poorly.
        h = (h ^ (h >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        h = (h ^ (h >> 27)).wrapping_mul(0x94D049BB133111EB);
        h ^ (h >> 31)
    }

    /// Scripted response length for a prompt under the current params.
    pub fn scripted_len(&self, prompt: &[i32]) -> usize {
        let h = Self::hash(prompt, self.params_epoch);
        self.min_len + (h % self.spread as u64) as usize
    }

    /// Write one scripted logit row in place (the decode hot path —
    /// no allocation).
    fn logits_for_into(&self, h: u64, step: usize, scripted: usize, row: &mut [f32]) {
        debug_assert_eq!(row.len(), self.vocab);
        row.fill(-20.0);
        if step >= scripted {
            row[tokenizer::EOS as usize] = 10.0;
        } else {
            // Deterministic digit stream (ids 4..14 are '0'..'9').
            let tok = 4 + ((h >> (step % 48)) % 10) as usize;
            row[tok] = 10.0;
            // A second mode with some mass keeps sampling non-trivial.
            row[(tok + 1) % 14] = 6.0;
        }
        self.apply_kv_quantization(row);
    }

    /// Model the narrow-KV read path: a deterministic quantize→dequantize
    /// round-trip over the emitted row (no-op at f32). See the `kv_dtype`
    /// field docs for why this is the faithful mock analogue.
    fn apply_kv_quantization(&self, row: &mut [f32]) {
        match self.kv_dtype {
            KvDtype::F32 => {}
            KvDtype::F16 => {
                for v in row.iter_mut() {
                    *v = f16_bits_to_f32(f32_to_f16_bits(*v));
                }
            }
            KvDtype::Int8 => {
                let scale = int8_row_scale(row);
                for v in row.iter_mut() {
                    *v = int8_roundtrip(*v, scale);
                }
            }
        }
    }

    fn logits_for(&self, h: u64, step: usize, scripted: usize) -> Vec<f32> {
        let mut row = vec![0f32; self.vocab];
        self.logits_for_into(h, step, scripted, &mut row);
        row
    }
}

impl Backend for MockBackend {
    fn slots(&self) -> usize {
        self.slots
    }
    fn vocab(&self) -> usize {
        self.vocab
    }
    fn max_seq(&self) -> usize {
        self.max_seq
    }
    fn p_max(&self) -> usize {
        self.p_max
    }

    fn set_params(&mut self, params: &[f32]) -> Result<()> {
        // Any weight change bumps the epoch (length/content script shifts).
        self.params_epoch = params.first().map(|x| x.to_bits() as u64).unwrap_or(0);
        Ok(())
    }

    fn prefill(&mut self, slot: usize, prompt: &[i32]) -> Result<Vec<f32>> {
        self.prefill_calls += 1;
        if let Some(d) = self.prefill_delay_per_token {
            std::thread::sleep(d * prompt.len() as u32);
        }
        self.prefill_staged[slot].clear();
        let h = Self::hash(prompt, self.params_epoch);
        self.slot_script[slot] = (h, 0);
        self.slot_plen[slot] = prompt.len();
        self.ingest.insert(slot, (h, 0));
        Ok(self.logits_for(h, 0, self.min_len + (h % self.spread as u64) as usize))
    }

    /// Chunked prompt ingestion with bit-exact boundary validation: chunks
    /// must be non-empty, ≤ p_max, strictly in order (`start` == tokens
    /// staged so far; `start == 0` resets the stage — the mid-prefill
    /// preemption contract), and the accumulated prompt may never exceed
    /// p_max. The final chunk computes the script hash over the FULL
    /// staged prompt and returns exactly the logits `prefill` would.
    fn prefill_chunk(
        &mut self,
        slot: usize,
        chunk: &[i32],
        start: usize,
        last: bool,
    ) -> Result<Option<Vec<f32>>> {
        use anyhow::ensure;
        ensure!(!chunk.is_empty(), "slot {slot}: empty prefill chunk");
        ensure!(chunk.len() <= self.p_max, "slot {slot}: chunk exceeds p_max");
        if let Some(d) = self.prefill_delay_per_token {
            std::thread::sleep(d * chunk.len() as u32);
        }
        if start == 0 {
            self.prefill_staged[slot].clear();
        }
        ensure!(
            start == self.prefill_staged[slot].len(),
            "slot {slot}: chunk starts at {start} but {} tokens are staged (boundary drift)",
            self.prefill_staged[slot].len()
        );
        ensure!(
            start + chunk.len() <= self.p_max,
            "slot {slot}: staged prompt would exceed p_max"
        );
        self.prefill_staged[slot].extend_from_slice(chunk);
        self.prefill_chunk_calls += 1;
        if !last {
            return Ok(None);
        }
        let plen = self.prefill_staged[slot].len();
        let h = Self::hash(&self.prefill_staged[slot], self.params_epoch);
        self.prefill_staged[slot].clear();
        self.slot_script[slot] = (h, 0);
        self.slot_plen[slot] = plen;
        self.ingest.insert(slot, (h, 0));
        Ok(Some(self.logits_for(h, 0, self.min_len + (h % self.spread as u64) as usize)))
    }

    /// Chunked resume replay (opt-in via `chunked_replay`, like the PJRT
    /// backend). A slice must start exactly at `plen + replayed` for the
    /// slot's in-flight ingestion — validated against the drift-immune
    /// `ingest` stash, NOT the live cursor (see the field docs).
    fn replay(&mut self, slot: usize, chunk: &[i32], start: usize) -> Result<Option<Vec<f32>>> {
        use anyhow::ensure;
        if !self.chunked_replay {
            return Ok(None);
        }
        ensure!(!chunk.is_empty(), "slot {slot}: empty replay slice");
        ensure!(chunk.len() <= self.p_max, "slot {slot}: replay slice exceeds p_max");
        if let Some(d) = self.prefill_delay_per_token {
            std::thread::sleep(d * chunk.len() as u32);
        }
        let (h, fed) = *self
            .ingest
            .get(&slot)
            .ok_or_else(|| anyhow::anyhow!("slot {slot}: replay before prefill"))?;
        ensure!(
            start == self.slot_plen[slot] + fed,
            "slot {slot}: replay slice starts at {start}, expected {} (plen {} + fed {fed})",
            self.slot_plen[slot] + fed,
            self.slot_plen[slot]
        );
        let fed = fed + chunk.len();
        self.ingest.insert(slot, (h, fed));
        // Sync the live cursor too: if this was the final slice, the
        // slot's next decode step continues from position `fed`.
        self.slot_script[slot] = (h, fed);
        self.replay_calls += 1;
        Ok(Some(self.logits_for(h, fed, self.min_len + (h % self.spread as u64) as usize)))
    }

    fn decode(&mut self, tokens: &[i32], pos: &[i32]) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.decode_into(tokens, pos, &mut out)?;
        Ok(out)
    }

    fn decode_into(&mut self, tokens: &[i32], pos: &[i32], out: &mut Vec<f32>) -> Result<()> {
        let _ = (tokens, pos);
        if let Some(d) = self.decode_delay {
            std::thread::sleep(d);
        }
        self.decode_calls += 1;
        let v = self.vocab;
        let n = self.slots * v;
        if out.len() != n {
            out.clear();
            out.resize(n, 0.0); // first step only; every element is
                                // overwritten by logits_for_into below
        }
        for s in 0..self.slots {
            let (h, count) = self.slot_script[s];
            let scripted = self.min_len + (h % self.spread as u64) as usize;
            self.logits_for_into(h, count + 1, scripted, &mut out[s * v..(s + 1) * v]);
            self.slot_script[s].1 = count + 1;
        }
        Ok(())
    }

    fn retain_slot(&mut self, slot: usize) -> Result<bool> {
        // Snapshot the script cursor — the lockstep decode keeps advancing
        // `slot_script` for every slot, so the live cursor drifts while the
        // slot is retained and the stash is the source of truth.
        self.retained_script.insert(slot, self.slot_script[slot]);
        Ok(true)
    }

    fn resume_retained(&mut self, slot: usize) -> Result<()> {
        let (h, count) = self
            .retained_script
            .remove(&slot)
            .ok_or_else(|| anyhow::anyhow!("slot {slot} has no retained script"))?;
        self.slot_script[slot] = (h, count);
        // Any in-flight ingestion cursor belonged to a previous occupant.
        self.ingest.remove(&slot);
        self.prefill_staged[slot].clear();
        self.resume_retained_calls += 1;
        Ok(())
    }

    fn release_retained(&mut self, slot: usize) -> Result<()> {
        self.retained_script.remove(&slot);
        Ok(())
    }

    /// Paged-KV enforcement: the mock validates every install bit-exactly
    /// against the block-mapping contract before accepting it. A violation
    /// is a hard error (fatal to the engine thread, so tests fail loudly):
    /// - the block size is constant across all installs;
    /// - a non-empty table covers exactly ceil(len / block_size) blocks,
    ///   with no block id appearing twice in one chain;
    /// - relative to the slot's previous table, an install is either a
    ///   reset (empty), a fresh install after a reset, or append-only
    ///   growth where at most the previous *partial* tail block was
    ///   replaced (the copy-on-write rule) — the shared prefix of full
    ///   blocks is immutable.
    fn set_block_table(
        &mut self,
        slot: usize,
        blocks: &[u32],
        len_tokens: usize,
        block_size: usize,
    ) -> Result<()> {
        use anyhow::ensure;
        ensure!(block_size >= 1, "block_size 0");
        if self.blk_size == 0 {
            self.blk_size = block_size;
        }
        ensure!(
            self.blk_size == block_size,
            "block size changed mid-run: {} -> {block_size}",
            self.blk_size
        );
        if blocks.is_empty() {
            ensure!(len_tokens == 0, "empty table claims {len_tokens} tokens");
            // A reset releases the slot: discard any partially staged
            // prompt and in-flight ingestion cursor (mid-chunk preemption
            // / flush — the next occupant starts from a clean stage).
            self.prefill_staged[slot].clear();
            self.ingest.remove(&slot);
        } else {
            ensure!(len_tokens > 0, "non-empty table with 0 tokens");
            let want = len_tokens.div_ceil(block_size);
            ensure!(
                blocks.len() == want,
                "table covers {} blocks, {len_tokens} tokens need {want}"
            );
            // No chain may reference a block twice (O(n²) over short
            // chains; no allocation — hot-path installs stay alloc-free).
            for (i, &b) in blocks.iter().enumerate() {
                ensure!(
                    !blocks[..i].contains(&b),
                    "block {b} appears twice in slot {slot}'s chain"
                );
            }
            let prev = &self.blk_tables[slot];
            if !prev.is_empty() {
                let prev_len = self.blk_lens[slot];
                ensure!(
                    len_tokens >= prev_len,
                    "slot {slot} table shrank: {prev_len} -> {len_tokens} tokens"
                );
                ensure!(blocks.len() >= prev.len(), "slot {slot} chain shrank");
                let frozen = prev.len() - 1;
                ensure!(
                    blocks[..frozen] == prev[..frozen],
                    "slot {slot}: shared full-block prefix mutated"
                );
                let tail_replaced = blocks[frozen] != prev[frozen];
                ensure!(
                    !tail_replaced || prev_len % block_size != 0,
                    "slot {slot}: full (immutable) tail block replaced"
                );
            }
        }
        let t = &mut self.blk_tables[slot];
        if t.capacity() < blocks.len() {
            // First growth per slot; pre-reserve the horizon so later
            // installs never reallocate (alloc-free steady state).
            let cap = self.max_seq.div_ceil(block_size) + 1;
            t.reserve(cap.max(blocks.len()) - t.len());
        }
        t.clear();
        t.extend_from_slice(blocks);
        self.blk_lens[slot] = len_tokens;
        self.block_table_installs += 1;
        Ok(())
    }

    fn set_kv_dtype(&mut self, dtype: KvDtype) {
        self.kv_dtype = dtype;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_lengths_are_heterogeneous_and_deterministic() {
        let be = MockBackend::new(4, 96);
        let a = be.scripted_len(&[1, 5, 9]);
        let b = be.scripted_len(&[1, 5, 9]);
        let c = be.scripted_len(&[2, 5, 9]);
        assert_eq!(a, b);
        // Across many prompts, lengths must vary.
        let lens: std::collections::HashSet<usize> =
            (0..40).map(|i| be.scripted_len(&[i, i + 1])).collect();
        assert!(lens.len() > 3, "lengths {lens:?}");
        let _ = c;
    }

    #[test]
    fn mock_emits_eos_at_scripted_length() {
        let mut be = MockBackend::new(1, 96);
        let prompt = [1, 7, 7];
        let scripted = be.scripted_len(&prompt);
        let mut logits = be.prefill(0, &prompt).unwrap();
        let mut produced = 0usize;
        loop {
            let (argmax, _) = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, v)| (i, *v))
                .unwrap();
            if argmax == tokenizer::EOS as usize {
                break;
            }
            produced += 1;
            assert!(produced <= scripted, "overran script");
            logits = be.decode(&[0], &[0]).unwrap();
        }
        assert_eq!(produced, scripted);
    }

    /// `decode_into` must produce exactly the rows `decode` produced (same
    /// script state sequence) while reusing the caller's buffer.
    #[test]
    fn decode_into_matches_decode_bitwise() {
        let mut a = MockBackend::new(3, 96);
        let mut b = MockBackend::new(3, 96);
        for s in 0..3 {
            a.prefill(s, &[1, s as i32 + 4]).unwrap();
            b.prefill(s, &[1, s as i32 + 4]).unwrap();
        }
        let toks = [0i32; 3];
        let pos = [0i32; 3];
        let mut buf = Vec::new();
        for step in 0..20 {
            let want = a.decode(&toks, &pos).unwrap();
            let cap_before = if step > 0 { buf.capacity() } else { 0 };
            b.decode_into(&toks, &pos, &mut buf).unwrap();
            assert_eq!(want, buf, "step {step} diverged");
            if step > 0 {
                assert_eq!(buf.capacity(), cap_before, "buffer regrew at step {step}");
            }
        }
    }

    /// The retention stash must survive both cursor drift (lockstep decode
    /// advances every slot) and a weight sync (epoch shift): resuming
    /// restores exactly the cursor captured at retain time — the mock
    /// analogue of stale KV staying bound to the params that produced it.
    #[test]
    fn retained_script_survives_drift_and_syncs() {
        let mut be = MockBackend::new(2, 96);
        be.min_len = 10;
        be.spread = 1;
        be.prefill(0, &[1, 7, 7]).unwrap();
        let stash = be.slot_script[0];
        be.retain_slot(0).unwrap();
        let mut buf = Vec::new();
        for _ in 0..5 {
            be.decode_into(&[0, 0], &[0, 0], &mut buf).unwrap();
        }
        assert_ne!(be.slot_script[0], stash, "live cursor should drift");
        be.set_params(&[2.0]).unwrap(); // epoch shift
        be.resume_retained(0).unwrap();
        assert_eq!(be.slot_script[0], stash, "stash restores the old script");
        assert_eq!(be.resume_retained_calls, 1);
        assert!(be.resume_retained(0).is_err(), "stash is consumed on resume");
    }

    /// The mock's bit-exact block-mapping enforcement: legal lifecycles
    /// (install → append-grow → COW of a partial tail → reset) pass;
    /// ceil-coverage violations, duplicate blocks, full-tail mutation, and
    /// block-size drift are hard errors.
    #[test]
    fn mock_enforces_block_table_contract() {
        let mut be = MockBackend::new(2, 96);
        // Fresh install: 5 tokens over blocks of 4 → 2 blocks.
        be.set_block_table(0, &[7, 3], 5, 4).unwrap();
        // Within-block growth + one appended block.
        be.set_block_table(0, &[7, 3, 9], 9, 4).unwrap();
        // COW: the last block was partial (9 % 4 != 0) → replaceable.
        be.set_block_table(0, &[7, 3, 11], 10, 4).unwrap();
        // Reset, then a fresh chain.
        be.set_block_table(0, &[], 0, 4).unwrap();
        be.set_block_table(0, &[1], 4, 4).unwrap();
        assert_eq!(be.block_table_installs, 5);

        // Violations:
        assert!(be.set_block_table(1, &[2, 2], 8, 4).is_err(), "duplicate block");
        assert!(be.set_block_table(1, &[2], 5, 4).is_err(), "under-covered len");
        assert!(be.set_block_table(1, &[2], 4, 8).is_err(), "block size drift");
        be.set_block_table(1, &[2], 4, 4).unwrap(); // 4 tokens: FULL block
        assert!(
            be.set_block_table(1, &[5, 6], 5, 4).is_err(),
            "full tail block is immutable (COW applies to partial tails only)"
        );
        assert!(be.set_block_table(1, &[2], 3, 4).is_err(), "table shrank");
    }

    /// The mock's f16 KV model is bit-identical to f32 (the logit alphabet
    /// is exactly binary16-representable), while int8 perturbs rows
    /// deterministically yet preserves every argmax — the invariants the
    /// engine-level quantized-KV goldens build on.
    #[test]
    fn mock_kv_quantization_is_deterministic_and_argmax_preserving() {
        let prompt = [1, 7, 3];
        let mk = |dtype: KvDtype| {
            let mut be = MockBackend::new(2, 96);
            be.set_kv_dtype(dtype);
            let mut rows = vec![be.prefill(0, &prompt).unwrap()];
            for _ in 0..6 {
                rows.push(be.decode(&[0, 0], &[0, 0]).unwrap());
            }
            rows
        };
        let f32_rows = mk(KvDtype::F32);
        let f16_rows = mk(KvDtype::F16);
        let int8_rows = mk(KvDtype::Int8);
        let int8_again = mk(KvDtype::Int8);
        for (i, (a, b)) in f32_rows.iter().zip(&f16_rows).enumerate() {
            let (av, bv): (Vec<u32>, Vec<u32>) =
                (a.iter().map(|v| v.to_bits()).collect(), b.iter().map(|v| v.to_bits()).collect());
            assert_eq!(av, bv, "f16 row {i} must be bit-identical to f32");
        }
        let amax = |r: &[f32]| {
            r.iter().enumerate().max_by(|x, y| x.1.partial_cmp(y.1).unwrap()).unwrap().0
        };
        for (i, (a, b)) in f32_rows.iter().zip(&int8_rows).enumerate() {
            assert_eq!(amax(a), amax(b), "int8 row {i} argmax drifted");
            assert!(a.iter().zip(b.iter()).any(|(x, y)| x != y), "int8 row {i} unperturbed");
            // Round-trip error is bounded by half a quantization step.
            let step = 20.0 / 127.0;
            for (x, y) in a.iter().zip(b.iter()) {
                assert!((x - y).abs() <= step / 2.0 + 1e-6, "row {i}: {x} vs {y}");
            }
        }
        for (a, b) in int8_rows.iter().zip(&int8_again) {
            assert_eq!(a, b, "int8 quantization must be deterministic");
        }
    }

    #[test]
    fn weight_sync_changes_script() {
        let mut be = MockBackend::new(1, 96);
        let l1 = be.scripted_len(&[3, 4, 5]);
        be.set_params(&[1.25]).unwrap();
        let epoch_changed = be.params_epoch != 0;
        assert!(epoch_changed);
        // Not guaranteed different for every prompt, but for most.
        let diffs = (0..50)
            .filter(|&i| {
                let mut b2 = MockBackend::new(1, 96);
                let a = b2.scripted_len(&[i]);
                b2.set_params(&[1.25]).unwrap();
                b2.scripted_len(&[i]) != a
            })
            .count();
        assert!(diffs > 25, "{diffs}");
        let _ = l1;
    }

    #[test]
    fn transient_classification_survives_context_wrapping() {
        use anyhow::Context;
        let t: anyhow::Error = anyhow::Error::new(BackendError::Transient("hiccup".into()));
        assert!(is_transient(&t));
        let wrapped = Result::<(), _>::Err(t).context("during decode step").unwrap_err();
        assert!(is_transient(&wrapped), "context wrapping must not hide the classification");
        let f = anyhow::Error::new(BackendError::Fatal("device lost".into()));
        assert!(!is_transient(&f));
        let plain = anyhow::anyhow!("unclassified");
        assert!(!is_transient(&plain), "unclassified errors are fatal");
    }
}
