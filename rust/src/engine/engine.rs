//! The engine proper: S decode slots driven in lockstep (continuous
//! batching), an admission queue, KV-budget preemption, and partial-result
//! flushing for early termination.
//!
//! `Engine` is synchronous and backend-generic so the full coordinator
//! stack is testable with `MockBackend`; `pool.rs` wraps it in a thread and
//! channels for production use.
//!
//! The decode step is the innermost loop of the whole system, so it is
//! steady-state allocation-free and O(1) in its bookkeeping: `tokens`/`pos`
//! staging and the S×V logits buffer persist across steps
//! (`Backend::decode_into`), sampling runs through a persistent
//! [`SamplerScratch`], per-slot output vectors are pre-reserved at
//! admission, and `busy`/`kv_tokens` are incremental counters maintained on
//! admit/finish/preempt instead of O(S) slot scans per query.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{ensure, Result};

use super::backend::Backend;
use super::sampler::{sample_token_with, SamplerScratch, SamplingParams};
use crate::tokenizer;
use crate::util::Rng;

/// A unit of generation work. `resume` carries previously generated tokens
/// of a buffered partial trajectory; the engine replays them through decode
/// to rebuild KV state — the *recomputation cost* of off-policy partials
/// the paper's §5.4.1 ablates.
///
/// The prompt is shared (`Arc`) with the coordinator's `Trajectory`, so
/// re-dispatching a buffered partial never deep-copies the prompt.
#[derive(Clone, Debug)]
pub struct WorkItem {
    pub request_id: u64,
    pub prompt: std::sync::Arc<[i32]>,
    pub resume: Vec<i32>,
    /// Cap on total sequence length (prompt + replay + new tokens).
    pub max_total: usize,
    pub sampling: SamplingParams,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Sampled EOS — trajectory complete.
    Eos,
    /// Hit the length cap — complete (graded as-is, like the paper's
    /// truncated responses).
    LengthCap,
    /// Evicted under KV pressure; coordinator should re-queue.
    Preempted,
    /// Early termination flush — partial, goes to the CoPRIS buffer.
    Stopped,
}

impl FinishReason {
    /// Did the trajectory reach a terminal state (vs partial)?
    pub fn is_complete(&self) -> bool {
        matches!(self, FinishReason::Eos | FinishReason::LengthCap)
    }
}

/// New tokens generated under THIS engine assignment (excludes replayed
/// resume tokens — the coordinator owns the full trajectory).
#[derive(Clone, Debug)]
pub struct WorkResult {
    pub request_id: u64,
    pub new_tokens: Vec<i32>,
    pub new_logprobs: Vec<f32>,
    pub reason: FinishReason,
    /// Resume tokens replayed before new generation began (recompute cost).
    pub replayed: usize,
}

/// Per-decode-step utilization sample (Fig. 1b data).
#[derive(Clone, Debug)]
pub struct StepTrace {
    pub engine: usize,
    /// Seconds since engine start.
    pub t_wall: f64,
    /// Decode step duration (seconds).
    pub dur: f64,
    /// Busy slots this step.
    pub active: usize,
    pub slots: usize,
    /// KV tokens resident after this step.
    pub kv_tokens: usize,
    /// Cumulative preemption count.
    pub preemptions: u64,
}

#[derive(Clone, Debug)]
pub enum EngineEvent {
    Done { engine: usize, result: WorkResult },
    Trace(StepTrace),
    /// All slots flushed after StopGeneration.
    Flushed { engine: usize },
    ShutDown { engine: usize },
    /// One step's events delivered in a single channel send (see
    /// `pool::flush`); the coordinator unpacks in `handle_event`.
    Batch(Vec<EngineEvent>),
}

/// Commands from the coordinator (used by the threaded pool).
pub enum EngineCmd {
    Assign(WorkItem),
    SetParams { version: u64, params: std::sync::Arc<Vec<f32>> },
    StopGeneration,
    Shutdown,
}

struct BusySlot {
    item: WorkItem,
    generated: Vec<i32>,
    logprobs: Vec<f32>,
    /// Resume tokens fed so far.
    replay_fed: usize,
    /// Token to feed at the next decode step, at position `pos`.
    next_token: i32,
    pos: i32,
    /// Admission order (LIFO preemption victim selection, like vLLM).
    admitted_seq: u64,
}

enum SlotState {
    Idle,
    Busy(Box<BusySlot>),
}

pub struct Engine<B: Backend> {
    pub id: usize,
    backend: B,
    slots: Vec<SlotState>,
    pending: VecDeque<WorkItem>,
    rng: Rng,
    /// KV token budget (0 = unlimited). Exceeding it preempts LIFO.
    pub kv_budget: usize,
    admission_counter: u64,
    preemptions: u64,
    t0: Instant,
    /// Cumulative decode steps (cost accounting).
    pub decode_steps: u64,
    /// Cumulative replayed (recomputed) tokens.
    pub replayed_tokens: u64,
    // -- incremental bookkeeping (invariants maintained by occupy/vacate) --
    /// Busy slot count (== slots.iter().filter(Busy).count()).
    busy_count: usize,
    /// KV tokens resident (== Σ busy slots (pos + 1)).
    kv_resident: usize,
    // -- persistent step scratch (no per-step heap allocation) --------------
    step_tokens: Vec<i32>,
    step_pos: Vec<i32>,
    logits_buf: Vec<f32>,
    scratch: SamplerScratch,
}

impl<B: Backend> Engine<B> {
    pub fn new(id: usize, backend: B, kv_budget: usize, seed: u64) -> Engine<B> {
        let s = backend.slots();
        let mut slots = Vec::with_capacity(s);
        for _ in 0..s {
            slots.push(SlotState::Idle);
        }
        Engine {
            id,
            backend,
            slots,
            pending: VecDeque::new(),
            rng: Rng::new(seed ^ (id as u64).wrapping_mul(0x9E3779B97F4A7C15)),
            kv_budget,
            admission_counter: 0,
            preemptions: 0,
            t0: Instant::now(),
            decode_steps: 0,
            replayed_tokens: 0,
            busy_count: 0,
            kv_resident: 0,
            step_tokens: vec![0; s],
            step_pos: vec![0; s],
            logits_buf: Vec::new(),
            scratch: SamplerScratch::new(),
        }
    }

    pub fn backend(&self) -> &B {
        &self.backend
    }

    pub fn busy(&self) -> usize {
        self.busy_count
    }

    pub fn queued(&self) -> usize {
        self.pending.len()
    }

    pub fn free_slots(&self) -> usize {
        self.slots.len() - self.busy_count
    }

    pub fn has_work(&self) -> bool {
        self.busy_count > 0 || !self.pending.is_empty()
    }

    pub fn preemptions(&self) -> u64 {
        self.preemptions
    }

    /// Tokens resident in the KV cache across busy slots (O(1) counter).
    pub fn kv_tokens(&self) -> usize {
        self.kv_resident
    }

    /// Install `b` into slot `i`, maintaining the incremental counters.
    fn occupy(&mut self, i: usize, b: Box<BusySlot>) {
        debug_assert!(matches!(self.slots[i], SlotState::Idle));
        self.busy_count += 1;
        self.kv_resident += b.pos as usize + 1;
        self.slots[i] = SlotState::Busy(b);
    }

    /// Clear slot `i`, maintaining the incremental counters.
    fn vacate(&mut self, i: usize) -> Option<Box<BusySlot>> {
        match std::mem::replace(&mut self.slots[i], SlotState::Idle) {
            SlotState::Busy(b) => {
                self.busy_count -= 1;
                self.kv_resident -= b.pos as usize + 1;
                Some(b)
            }
            SlotState::Idle => None,
        }
    }

    /// Queue a work item (admitted to a slot on the next step).
    pub fn submit(&mut self, item: WorkItem) -> Result<()> {
        ensure!(!item.prompt.is_empty(), "empty prompt");
        ensure!(item.prompt.len() <= self.backend.p_max(), "prompt exceeds p_max");
        ensure!(item.max_total <= self.backend.max_seq(), "max_total exceeds horizon");
        self.pending.push_back(item);
        Ok(())
    }

    /// Weight sync.
    pub fn set_params(&mut self, params: &[f32]) -> Result<()> {
        self.backend.set_params(params)
    }

    /// Early termination: flush every busy slot as a partial and drop the
    /// admission queue back to the caller (unstarted items are NOT partial
    /// trajectories — the coordinator re-queues them as fresh work).
    pub fn stop_generation(&mut self, events: &mut Vec<EngineEvent>) -> Vec<WorkItem> {
        for i in 0..self.slots.len() {
            if let Some(b) = self.vacate(i) {
                events.push(EngineEvent::Done {
                    engine: self.id,
                    result: finish(*b, FinishReason::Stopped),
                });
            }
        }
        let unstarted: Vec<WorkItem> = self.pending.drain(..).collect();
        events.push(EngineEvent::Flushed { engine: self.id });
        unstarted
    }

    /// One scheduler iteration: admit pending work, enforce the KV budget,
    /// run one decode step, process sampled tokens. Steady state (all slots
    /// mid-generation) performs no heap allocation in engine/sampler code.
    pub fn step(&mut self, events: &mut Vec<EngineEvent>) -> Result<()> {
        self.admit(events)?;
        self.enforce_kv_budget(events);
        if self.busy_count == 0 {
            return Ok(());
        }

        let s = self.slots.len();
        let v = self.backend.vocab();
        for (i, slot) in self.slots.iter().enumerate() {
            match slot {
                SlotState::Busy(b) => {
                    self.step_tokens[i] = b.next_token;
                    self.step_pos[i] = b.pos;
                }
                SlotState::Idle => {
                    self.step_tokens[i] = 0;
                    self.step_pos[i] = 0;
                }
            }
        }

        let t_step = Instant::now();
        self.backend.decode_into(&self.step_tokens, &self.step_pos, &mut self.logits_buf)?;
        let dur = t_step.elapsed().as_secs_f64();
        self.decode_steps += 1;

        for i in 0..s {
            let SlotState::Busy(b) = &mut self.slots[i] else { continue };
            b.pos += 1;
            self.kv_resident += 1;
            if b.replay_fed < b.item.resume.len() {
                // We just fed resume[replay_fed]; keep replaying.
                b.replay_fed += 1;
                self.replayed_tokens += 1;
                if b.replay_fed < b.item.resume.len() {
                    b.next_token = b.item.resume[b.replay_fed];
                    continue;
                }
                // Replay complete: this step's logits sample the first new
                // token (fall through).
            }
            let row = &self.logits_buf[i * v..(i + 1) * v];
            let (tok, lp) =
                sample_token_with(row, &b.item.sampling, &mut self.rng, &mut self.scratch);
            b.generated.push(tok);
            b.logprobs.push(lp);
            let total_len = b.item.prompt.len() + b.item.resume.len() + b.generated.len();
            let reason = if tok == tokenizer::EOS {
                Some(FinishReason::Eos)
            } else if total_len >= b.item.max_total {
                Some(FinishReason::LengthCap)
            } else {
                None
            };
            match reason {
                Some(r) => {
                    let b = self.vacate(i).expect("busy slot");
                    events.push(EngineEvent::Done { engine: self.id, result: finish(*b, r) });
                }
                None => b.next_token = tok,
            }
        }

        events.push(EngineEvent::Trace(StepTrace {
            engine: self.id,
            t_wall: self.t0.elapsed().as_secs_f64(),
            dur,
            active: self.busy_count,
            slots: s,
            kv_tokens: self.kv_resident,
            preemptions: self.preemptions,
        }));
        Ok(())
    }

    fn admit(&mut self, events: &mut Vec<EngineEvent>) -> Result<()> {
        for i in 0..self.slots.len() {
            if self.pending.is_empty() {
                break;
            }
            if matches!(self.slots[i], SlotState::Busy(_)) {
                continue;
            }
            let item = self.pending.pop_front().unwrap();
            self.admission_counter += 1;
            let seq = self.admission_counter;
            let plen = item.prompt.len();
            if plen >= item.max_total {
                // No room to generate anything: report an empty LengthCap.
                events.push(EngineEvent::Done {
                    engine: self.id,
                    result: WorkResult {
                        request_id: item.request_id,
                        new_tokens: vec![],
                        new_logprobs: vec![],
                        reason: FinishReason::LengthCap,
                        replayed: 0,
                    },
                });
                continue;
            }
            let logits = self.backend.prefill(i, &item.prompt)?;
            // Reserve the worst-case output length up front so the decode
            // loop's push() never reallocates mid-generation.
            let out_cap = item.max_total.saturating_sub(plen);
            let mut busy = BusySlot {
                generated: Vec::with_capacity(out_cap),
                logprobs: Vec::with_capacity(out_cap),
                replay_fed: 0,
                next_token: 0,
                pos: plen as i32,
                admitted_seq: seq,
                item,
            };
            if busy.item.resume.is_empty() {
                // Sample the first new token from the prefill logits.
                let (tok, lp) = sample_token_with(
                    &logits,
                    &busy.item.sampling,
                    &mut self.rng,
                    &mut self.scratch,
                );
                busy.generated.push(tok);
                busy.logprobs.push(lp);
                if tok == tokenizer::EOS {
                    events.push(EngineEvent::Done {
                        engine: self.id,
                        result: finish(busy, FinishReason::Eos),
                    });
                    continue;
                }
                if plen + 1 >= busy.item.max_total {
                    events.push(EngineEvent::Done {
                        engine: self.id,
                        result: finish(busy, FinishReason::LengthCap),
                    });
                    continue;
                }
                busy.next_token = tok;
            } else {
                // Chunked replay (vLLM-style parallel re-prefill of the
                // buffered partial); falls back to per-token decode when
                // the backend declines (mock backend, near-horizon).
                let resume = busy.item.resume.clone();
                let pmax = self.backend.p_max();
                let mut fed = 0usize;
                let mut last_logits: Option<Vec<f32>> = None;
                while fed < resume.len() {
                    let end = (fed + pmax).min(resume.len());
                    match self.backend.replay(i, &resume[fed..end], plen + fed)? {
                        Some(logits) => {
                            last_logits = Some(logits);
                            fed = end;
                        }
                        None => break,
                    }
                }
                self.replayed_tokens += fed as u64;
                busy.replay_fed = fed;
                busy.pos = (plen + fed) as i32;
                if fed == resume.len() {
                    // Replay complete: sample the next new token now.
                    let logits = last_logits.expect("non-empty resume");
                    let (tok, lp) = sample_token_with(
                        &logits,
                        &busy.item.sampling,
                        &mut self.rng,
                        &mut self.scratch,
                    );
                    busy.generated.push(tok);
                    busy.logprobs.push(lp);
                    let total = plen + resume.len() + 1;
                    if tok == tokenizer::EOS {
                        events.push(EngineEvent::Done {
                            engine: self.id,
                            result: finish(busy, FinishReason::Eos),
                        });
                        continue;
                    }
                    if total >= busy.item.max_total {
                        events.push(EngineEvent::Done {
                            engine: self.id,
                            result: finish(busy, FinishReason::LengthCap),
                        });
                        continue;
                    }
                    busy.next_token = tok;
                } else {
                    busy.next_token = resume[fed];
                }
            }
            self.occupy(i, Box::new(busy));
        }
        Ok(())
    }

    /// Preempt latest-admitted slots (LIFO, like vLLM) while over budget.
    /// O(S) victim scan per eviction against O(1) counters — the old
    /// version rescanned every slot for `kv_tokens()`/`busy()` on every
    /// loop iteration (O(S²) per enforcement pass).
    fn enforce_kv_budget(&mut self, events: &mut Vec<EngineEvent>) {
        if self.kv_budget == 0 {
            return;
        }
        while self.kv_resident > self.kv_budget && self.busy_count > 1 {
            let victim = self
                .slots
                .iter()
                .enumerate()
                .filter_map(|(i, s)| match s {
                    SlotState::Busy(b) => Some((i, b.admitted_seq)),
                    SlotState::Idle => None,
                })
                .max_by_key(|&(_, seq)| seq)
                .map(|(i, _)| i)
                .unwrap();
            if let Some(b) = self.vacate(victim) {
                self.preemptions += 1;
                events.push(EngineEvent::Done {
                    engine: self.id,
                    result: finish(*b, FinishReason::Preempted),
                });
            }
        }
    }
}

fn finish(b: BusySlot, reason: FinishReason) -> WorkResult {
    WorkResult {
        request_id: b.item.request_id,
        new_tokens: b.generated,
        new_logprobs: b.logprobs,
        reason,
        replayed: b.replay_fed,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::backend::MockBackend;

    fn item(id: u64, prompt: Vec<i32>) -> WorkItem {
        WorkItem {
            request_id: id,
            prompt: prompt.into(),
            resume: vec![],
            max_total: 96,
            sampling: SamplingParams::greedy(),
        }
    }

    fn run_to_completion(
        eng: &mut Engine<MockBackend>,
        max_steps: usize,
    ) -> Vec<WorkResult> {
        let mut out = Vec::new();
        for _ in 0..max_steps {
            if !eng.has_work() {
                break;
            }
            let mut ev = Vec::new();
            eng.step(&mut ev).unwrap();
            for e in ev {
                if let EngineEvent::Done { result, .. } = e {
                    out.push(result);
                }
            }
        }
        out
    }

    /// Recompute the counters from first principles (test-only O(S) scan).
    fn scan_counters(eng: &Engine<MockBackend>) -> (usize, usize) {
        let busy = eng.slots.iter().filter(|s| matches!(s, SlotState::Busy(_))).count();
        let kv = eng
            .slots
            .iter()
            .map(|s| match s {
                SlotState::Busy(b) => b.pos as usize + 1,
                SlotState::Idle => 0,
            })
            .sum();
        (busy, kv)
    }

    #[test]
    fn greedy_generation_matches_script() {
        let be = MockBackend::new(4, 96);
        let prompt = vec![1, 9, 9];
        let want_len = be.scripted_len(&prompt);
        let mut eng = Engine::new(0, be, 0, 1);
        eng.submit(item(1, prompt)).unwrap();
        let results = run_to_completion(&mut eng, 200);
        assert_eq!(results.len(), 1);
        let r = &results[0];
        assert_eq!(r.reason, FinishReason::Eos);
        // scripted_len digits + the EOS token itself
        assert_eq!(r.new_tokens.len(), want_len + 1);
        assert_eq!(*r.new_tokens.last().unwrap(), tokenizer::EOS);
        assert_eq!(r.new_logprobs.len(), r.new_tokens.len());
    }

    #[test]
    fn multiple_slots_progress_concurrently() {
        let be = MockBackend::new(4, 96);
        let mut eng = Engine::new(0, be, 0, 1);
        for i in 0..4 {
            eng.submit(item(i, vec![1, i as i32 + 4, 7])).unwrap();
        }
        let results = run_to_completion(&mut eng, 300);
        assert_eq!(results.len(), 4);
        let mut ids: Vec<u64> = results.iter().map(|r| r.request_id).collect();
        ids.sort();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn queue_admits_when_slots_free() {
        let be = MockBackend::new(2, 96);
        let mut eng = Engine::new(0, be, 0, 1);
        for i in 0..6 {
            eng.submit(item(i, vec![1, i as i32 + 4])).unwrap();
        }
        assert_eq!(eng.queued(), 6);
        let results = run_to_completion(&mut eng, 500);
        assert_eq!(results.len(), 6);
        assert_eq!(eng.queued(), 0);
    }

    #[test]
    fn length_cap_respected() {
        let mut be = MockBackend::new(1, 96);
        be.min_len = 50;
        be.spread = 1; // script wants 50 tokens
        let mut eng = Engine::new(0, be, 0, 1);
        let mut it = item(7, vec![1, 5, 6]);
        it.max_total = 10; // 3 prompt + 7 generated
        eng.submit(it).unwrap();
        let results = run_to_completion(&mut eng, 100);
        assert_eq!(results[0].reason, FinishReason::LengthCap);
        assert_eq!(results[0].new_tokens.len(), 7);
    }

    #[test]
    fn stop_generation_flushes_partials() {
        let mut be = MockBackend::new(2, 96);
        be.min_len = 40;
        be.spread = 1;
        let mut eng = Engine::new(0, be, 0, 1);
        eng.submit(item(1, vec![1, 4])).unwrap();
        eng.submit(item(2, vec![1, 5])).unwrap();
        let mut ev = Vec::new();
        for _ in 0..5 {
            eng.step(&mut ev).unwrap();
        }
        ev.clear();
        let unstarted = eng.stop_generation(&mut ev);
        assert!(unstarted.is_empty());
        let partials: Vec<&WorkResult> = ev
            .iter()
            .filter_map(|e| match e {
                EngineEvent::Done { result, .. } => Some(result),
                _ => None,
            })
            .collect();
        assert_eq!(partials.len(), 2);
        for p in partials {
            assert_eq!(p.reason, FinishReason::Stopped);
            assert!(!p.new_tokens.is_empty());
            assert!(p.new_tokens.len() < 40);
        }
        assert!(matches!(ev.last(), Some(EngineEvent::Flushed { .. })));
        assert_eq!(eng.busy(), 0);
        assert_eq!(eng.kv_tokens(), 0);
    }

    #[test]
    fn stop_returns_unstarted_queue() {
        let be = MockBackend::new(1, 96);
        let mut eng = Engine::new(0, be, 0, 1);
        for i in 0..5 {
            eng.submit(item(i, vec![1, i as i32 + 4])).unwrap();
        }
        let mut ev = Vec::new();
        eng.step(&mut ev).unwrap(); // admits exactly 1
        ev.clear();
        let unstarted = eng.stop_generation(&mut ev);
        assert_eq!(unstarted.len(), 4);
    }

    #[test]
    fn resume_replays_then_continues() {
        let be = MockBackend::new(1, 96);
        let prompt = vec![1, 8, 8];
        let mut eng = Engine::new(0, be, 0, 1);
        let mut it = item(3, prompt);
        it.resume = vec![5, 6, 7]; // 3 tokens to replay
        eng.submit(it).unwrap();
        let results = run_to_completion(&mut eng, 200);
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].replayed, 3);
        assert!(!results[0].new_tokens.is_empty());
        assert_eq!(eng.replayed_tokens, 3);
    }

    #[test]
    fn kv_budget_triggers_lifo_preemption() {
        let mut be = MockBackend::new(4, 96);
        be.min_len = 60;
        be.spread = 1; // long outputs to build KV pressure
        let mut eng = Engine::new(0, be, 30, 1); // tight budget
        for i in 0..4 {
            eng.submit(item(i, vec![1, i as i32 + 4, 9, 9])).unwrap();
        }
        let mut preempted = Vec::new();
        for _ in 0..40 {
            let mut ev = Vec::new();
            eng.step(&mut ev).unwrap();
            for e in ev {
                if let EngineEvent::Done { result, .. } = e {
                    if result.reason == FinishReason::Preempted {
                        preempted.push(result.request_id);
                    }
                }
            }
        }
        assert!(!preempted.is_empty(), "tight budget must preempt");
        assert!(eng.preemptions() as usize >= preempted.len());
        // LIFO: the latest admissions (higher ids) are evicted first.
        assert!(preempted.contains(&3) || preempted.contains(&2), "{preempted:?}");
        // Under a tight budget the engine converges to few busy slots (a
        // single long sequence may legitimately exceed the budget alone —
        // the last slot is never preempted).
        assert!(eng.busy() <= 2, "busy {}", eng.busy());
    }

    /// The incremental busy/kv counters must agree with a from-scratch slot
    /// scan at every point of a run that exercises admission, decode,
    /// finish, preemption, and stop_generation.
    #[test]
    fn incremental_counters_match_slot_scans() {
        let mut be = MockBackend::new(4, 96);
        be.min_len = 30;
        be.spread = 6;
        let mut eng = Engine::new(0, be, 40, 9); // budget tight enough to preempt
        for i in 0..8 {
            eng.submit(item(i, vec![1, i as i32 + 4, 9])).unwrap();
        }
        let mut ev = Vec::new();
        for _ in 0..60 {
            eng.step(&mut ev).unwrap();
            let (busy, kv) = scan_counters(&eng);
            assert_eq!(eng.busy(), busy, "busy counter drifted");
            assert_eq!(eng.kv_tokens(), kv, "kv counter drifted");
            ev.clear();
            if !eng.has_work() {
                break;
            }
        }
        eng.stop_generation(&mut ev);
        let (busy, kv) = scan_counters(&eng);
        assert_eq!((eng.busy(), eng.kv_tokens()), (busy, kv));
        assert_eq!((busy, kv), (0, 0));
    }

    #[test]
    fn immediate_eos_on_prefill_is_handled() {
        let mut be = MockBackend::new(1, 96);
        be.min_len = 0;
        be.spread = 1; // script = EOS immediately
        let mut eng = Engine::new(0, be, 0, 1);
        eng.submit(item(1, vec![1, 4])).unwrap();
        let results = run_to_completion(&mut eng, 10);
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].reason, FinishReason::Eos);
        assert_eq!(results[0].new_tokens, vec![tokenizer::EOS]);
    }

    #[test]
    fn trace_reports_active_slots() {
        let be = MockBackend::new(4, 96);
        let mut eng = Engine::new(0, be, 0, 1);
        eng.submit(item(1, vec![1, 4])).unwrap();
        let mut ev = Vec::new();
        eng.step(&mut ev).unwrap();
        let trace = ev
            .iter()
            .find_map(|e| match e {
                EngineEvent::Trace(t) => Some(t.clone()),
                _ => None,
            })
            .expect("trace emitted");
        assert_eq!(trace.slots, 4);
        assert!(trace.active <= 1); // may have finished already
        assert!(trace.dur >= 0.0);
    }

    #[test]
    fn rejects_oversized_prompt() {
        let be = MockBackend::new(1, 96); // p_max = 24
        let mut eng = Engine::new(0, be, 0, 1);
        assert!(eng.submit(item(1, vec![1; 25])).is_err());
    }
}
