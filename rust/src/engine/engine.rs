//! The engine proper: S decode slots driven in lockstep (continuous
//! batching), an admission queue, KV-budget preemption, partial-result
//! flushing for early termination, and a KV-retention ledger for
//! affinity-resumed partials.
//!
//! `Engine` is synchronous and backend-generic so the full coordinator
//! stack is testable with `MockBackend`; `pool.rs` wraps it in a thread and
//! channels for production use.
//!
//! The decode step is the innermost loop of the whole system, so it is
//! steady-state allocation-free and O(1) in its bookkeeping: `tokens`/`pos`
//! staging and the S×V logits buffer persist across steps
//! (`Backend::decode_into`), sampling runs through a persistent
//! [`SamplerScratch`], per-slot output vectors are pre-reserved at
//! admission, and `busy`/`kv_tokens` are incremental counters maintained on
//! admit/finish/preempt instead of O(S) slot scans per query.
//!
//! # KV retention (the resume-affinity fast path)
//!
//! Early termination normally discards a flushed slot's KV, so resuming the
//! buffered partial later re-prefills every generated token (the paper's
//! recomputation overhead, §5.4.1). With retention, `stop_generation`
//! leaves the slot in `SlotState::Retained`: the KV stays resident (still
//! charged against `kv_budget`), the `Stopped` result carries a retention
//! token, and a future [`WorkItem`] presenting that token resumes decoding
//! directly from the retained state — zero replayed tokens. The ledger is
//! strictly best-effort:
//!
//! - retained slots are evicted LIFO under KV-budget pressure (before any
//!   live sequence is preempted — they are a cache, not work) and when the
//!   admission queue needs a slot;
//! - a weight sync invalidates all retained state unless the coordinator
//!   opts into cross-sync retention (`SetParams::invalidate_retained`);
//! - a resume whose token no longer names a live retained entry — or whose
//!   backend-side restore fails — silently falls back to the ordinary
//!   replay path, so correctness never depends on the coordinator's
//!   affinity map (or the backend's ledger) being current.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{ensure, Result};

use super::backend::Backend;
use super::sampler::{sample_token_with, SamplerScratch, SamplingParams};
use crate::tokenizer;
use crate::util::Rng;

/// A unit of generation work. `resume` carries previously generated tokens
/// of a buffered partial trajectory; the engine replays them through decode
/// to rebuild KV state — the *recomputation cost* of off-policy partials
/// the paper's §5.4.1 ablates — unless `retain` names a live retained slot,
/// in which case the resident KV is reused and nothing is replayed.
///
/// The prompt is shared (`Arc`) with the coordinator's `Trajectory`, so
/// re-dispatching a buffered partial never deep-copies the prompt.
#[derive(Clone, Debug)]
pub struct WorkItem {
    /// Coordinator-side trajectory id; echoed back in [`WorkResult`].
    pub request_id: u64,
    /// Prompt tokens (shared with the coordinator's trajectory).
    pub prompt: std::sync::Arc<[i32]>,
    /// Previously generated tokens to rebuild KV state for (empty for
    /// fresh work).
    pub resume: Vec<i32>,
    /// Cap on total sequence length (prompt + replay + new tokens).
    pub max_total: usize,
    /// Sampling parameters for this request.
    pub sampling: SamplingParams,
    /// Affinity hint: a retention token from a previous `Stopped` flush on
    /// THIS engine ([`WorkResult::retained`]). When it still names a live
    /// retained slot matching `request_id` and `resume.len()`, the engine
    /// resumes from resident KV with zero replay; otherwise it silently
    /// falls back to the replay path. `None` = plain dispatch.
    pub retain: Option<u64>,
}

/// Why a slot's result was reported back to the coordinator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Sampled EOS — trajectory complete.
    Eos,
    /// Hit the length cap — complete (graded as-is, like the paper's
    /// truncated responses).
    LengthCap,
    /// Evicted under KV pressure; coordinator should re-queue.
    Preempted,
    /// Early termination flush — partial, goes to the CoPRIS buffer.
    Stopped,
}

impl FinishReason {
    /// Did the trajectory reach a terminal state (vs partial)?
    pub fn is_complete(&self) -> bool {
        matches!(self, FinishReason::Eos | FinishReason::LengthCap)
    }
}

/// New tokens generated under THIS engine assignment (excludes replayed
/// resume tokens — the coordinator owns the full trajectory).
#[derive(Clone, Debug)]
pub struct WorkResult {
    /// The [`WorkItem::request_id`] this result answers.
    pub request_id: u64,
    /// Tokens generated under this assignment (excludes replayed prefix).
    pub new_tokens: Vec<i32>,
    /// Behaviour log-prob of each new token (same length as `new_tokens`).
    pub new_logprobs: Vec<f32>,
    /// Why the slot was released.
    pub reason: FinishReason,
    /// Resume tokens actually recomputed before new generation began (the
    /// recompute cost; 0 when the resume was served from retained KV).
    pub replayed: usize,
    /// Set on `Stopped` flushes whose KV stayed resident in the engine:
    /// the retention token the coordinator must echo in
    /// [`WorkItem::retain`] to resume from the retained slot.
    pub retained: Option<u64>,
    /// True when this assignment resumed from retained KV (affinity hit —
    /// the whole `resume` prefix was NOT replayed).
    pub resumed_from_kv: bool,
}

/// Per-decode-step utilization sample (Fig. 1b data).
#[derive(Clone, Debug)]
pub struct StepTrace {
    /// Engine id the sample came from.
    pub engine: usize,
    /// Seconds since engine start.
    pub t_wall: f64,
    /// Decode step duration (seconds).
    pub dur: f64,
    /// Busy slots this step.
    pub active: usize,
    /// Total decode slots.
    pub slots: usize,
    /// KV tokens resident after this step (live + retained).
    pub kv_tokens: usize,
    /// Cumulative preemption count.
    pub preemptions: u64,
}

/// Events flowing from engine threads back to the coordinator.
#[derive(Clone, Debug)]
pub enum EngineEvent {
    /// A slot finished (terminal, preempted, or flushed).
    Done {
        /// Engine id that produced the result.
        engine: usize,
        /// The slot's output.
        result: WorkResult,
    },
    /// Per-step utilization sample.
    Trace(StepTrace),
    /// All slots flushed after StopGeneration.
    Flushed {
        /// Engine id that finished flushing.
        engine: usize,
    },
    /// Engine thread exited.
    ShutDown {
        /// Engine id that shut down.
        engine: usize,
    },
    /// A retained slot was dropped (budget/admission eviction or explicit
    /// release) — the coordinator clears its affinity entry so future
    /// resumes of that request dispatch by load instead of affinity.
    RetainedDropped {
        /// Engine id that dropped the retained slot.
        engine: usize,
        /// Request whose retained KV is gone.
        request_id: u64,
    },
    /// One step's events delivered in a single channel send (see
    /// `pool::flush`); the coordinator unpacks in `handle_event`.
    Batch(Vec<EngineEvent>),
}

/// Commands from the coordinator (used by the threaded pool).
pub enum EngineCmd {
    /// Queue a work item for admission.
    Assign(WorkItem),
    /// Weight sync: install a new parameter vector.
    SetParams {
        /// Policy version the params correspond to (trainer step).
        version: u64,
        /// The full parameter vector (shared across engines).
        params: std::sync::Arc<Vec<f32>>,
        /// Drop all retained KV first: retained prefixes were computed
        /// under the OLD params, so unless the coordinator explicitly
        /// opts into stale-KV continuation (`rollout.retain_kv_across_sync`)
        /// they must not survive the sync.
        invalidate_retained: bool,
    },
    /// Early termination: flush every busy slot as a partial; when `retain`
    /// is set, leave each flushed slot's KV resident for affinity resume.
    StopGeneration {
        /// Retain flushed slots' KV (see [`Engine::stop_generation`]).
        retain: bool,
    },
    /// Drop one retained slot (the coordinator decided the partial will
    /// resume elsewhere, or never).
    ReleaseRetained {
        /// Request whose retained slot should be freed.
        request_id: u64,
        /// Retention token (stale tokens are ignored).
        token: u64,
    },
    /// Terminate the engine thread.
    Shutdown,
}

struct BusySlot {
    item: WorkItem,
    generated: Vec<i32>,
    logprobs: Vec<f32>,
    /// Resume tokens fed so far (mechanical replay cursor; starts at
    /// `resume.len()` for retained-KV resumes, which feed nothing).
    replay_fed: usize,
    /// Resume tokens actually recomputed this assignment (the true replay
    /// cost — 0 for retained-KV resumes).
    replayed: usize,
    /// This assignment began from a retained slot (metrics).
    resumed_from_kv: bool,
    /// Token to feed at the next decode step, at position `pos`.
    next_token: i32,
    pos: i32,
    /// Admission order (LIFO preemption victim selection, like vLLM).
    admitted_seq: u64,
}

/// Ledger entry for a flushed slot whose KV stayed resident. Everything a
/// later resume needs to continue decoding without replay: the pending
/// next-token feed and its position, plus the validation triple
/// (request id, token, generated length) the resume item must match.
struct RetainedSlot {
    request_id: u64,
    /// Monotonic retention token; the coordinator must echo it in
    /// [`WorkItem::retain`] (guards against slot reuse between stop and
    /// resume).
    token: u64,
    /// Pending feed position (the KV holds positions `0..pos`).
    pos: i32,
    /// Last sampled token — not yet fed; the resume's first decode feeds
    /// it at `pos`, exactly where the busy slot left off.
    next_token: i32,
    /// Total generated tokens at flush time (`resume.len() + new`); a
    /// resume item must present exactly this many resume tokens.
    generated_len: usize,
    /// Original admission order (LIFO eviction among retained slots).
    admitted_seq: u64,
}

enum SlotState {
    Idle,
    Busy(Box<BusySlot>),
    Retained(RetainedSlot),
}

/// One inference engine: S decode slots over a [`Backend`], an admission
/// queue, KV budget enforcement, and the retention ledger.
pub struct Engine<B: Backend> {
    /// Engine id (stamped on every event).
    pub id: usize,
    backend: B,
    slots: Vec<SlotState>,
    pending: VecDeque<WorkItem>,
    rng: Rng,
    /// KV token budget (0 = unlimited). Exceeding it evicts retained slots
    /// first, then preempts live slots LIFO.
    pub kv_budget: usize,
    admission_counter: u64,
    retain_counter: u64,
    preemptions: u64,
    t0: Instant,
    /// Cumulative decode steps (cost accounting).
    pub decode_steps: u64,
    /// Cumulative replayed (recomputed) tokens.
    pub replayed_tokens: u64,
    /// Cumulative resumes served from retained KV (affinity hits).
    pub retained_resumes: u64,
    /// Cumulative retained-slot drops (budget/admission eviction, release,
    /// weight-sync invalidation).
    pub retained_evictions: u64,
    // -- incremental bookkeeping (invariants maintained by occupy/vacate) --
    /// Busy slot count (== slots.iter().filter(Busy).count()).
    busy_count: usize,
    /// Retained slot count (== slots.iter().filter(Retained).count()).
    retained_count: usize,
    /// KV tokens resident (== Σ busy (pos + 1) + Σ retained (pos + 1)).
    kv_resident: usize,
    // -- persistent step scratch (no per-step heap allocation) --------------
    step_tokens: Vec<i32>,
    step_pos: Vec<i32>,
    logits_buf: Vec<f32>,
    scratch: SamplerScratch,
}

impl<B: Backend> Engine<B> {
    /// Build an engine with `kv_budget` tokens of KV (0 = unlimited) and a
    /// per-engine-derived RNG seed.
    pub fn new(id: usize, backend: B, kv_budget: usize, seed: u64) -> Engine<B> {
        let s = backend.slots();
        let mut slots = Vec::with_capacity(s);
        for _ in 0..s {
            slots.push(SlotState::Idle);
        }
        Engine {
            id,
            backend,
            slots,
            pending: VecDeque::new(),
            rng: Rng::new(seed ^ (id as u64).wrapping_mul(0x9E3779B97F4A7C15)),
            kv_budget,
            admission_counter: 0,
            retain_counter: 0,
            preemptions: 0,
            t0: Instant::now(),
            decode_steps: 0,
            replayed_tokens: 0,
            retained_resumes: 0,
            retained_evictions: 0,
            busy_count: 0,
            retained_count: 0,
            kv_resident: 0,
            step_tokens: vec![0; s],
            step_pos: vec![0; s],
            logits_buf: Vec::new(),
            scratch: SamplerScratch::new(),
        }
    }

    /// The generation backend (test inspection).
    pub fn backend(&self) -> &B {
        &self.backend
    }

    /// Actively decoding slots (O(1) counter).
    pub fn busy(&self) -> usize {
        self.busy_count
    }

    /// Slots holding retained KV for flushed partials (O(1) counter).
    pub fn retained(&self) -> usize {
        self.retained_count
    }

    /// Work items waiting for admission.
    pub fn queued(&self) -> usize {
        self.pending.len()
    }

    /// Slots neither busy nor retained.
    pub fn free_slots(&self) -> usize {
        self.slots.len() - self.busy_count - self.retained_count
    }

    /// Is there anything to decode or admit? (Retained slots alone are not
    /// work — the engine idles on its command channel with KV parked.)
    pub fn has_work(&self) -> bool {
        self.busy_count > 0 || !self.pending.is_empty()
    }

    /// Cumulative live-slot preemptions.
    pub fn preemptions(&self) -> u64 {
        self.preemptions
    }

    /// Tokens resident in the KV cache across busy AND retained slots
    /// (O(1) counter).
    pub fn kv_tokens(&self) -> usize {
        self.kv_resident
    }

    /// Install `b` into slot `i`, maintaining the incremental counters.
    fn occupy(&mut self, i: usize, b: Box<BusySlot>) {
        debug_assert!(matches!(self.slots[i], SlotState::Idle));
        self.busy_count += 1;
        self.kv_resident += b.pos as usize + 1;
        self.slots[i] = SlotState::Busy(b);
    }

    /// Clear a busy slot `i`, maintaining the incremental counters.
    fn vacate(&mut self, i: usize) -> Option<Box<BusySlot>> {
        match std::mem::replace(&mut self.slots[i], SlotState::Idle) {
            SlotState::Busy(b) => {
                self.busy_count -= 1;
                self.kv_resident -= b.pos as usize + 1;
                Some(b)
            }
            other => {
                self.slots[i] = other;
                None
            }
        }
    }

    /// Drop retained slot `i` back to Idle, releasing its KV charge and
    /// telling the coordinator (so stale affinity entries get cleared).
    fn drop_retained_slot(&mut self, i: usize, events: &mut Vec<EngineEvent>) {
        let SlotState::Retained(_) = self.slots[i] else { return };
        let SlotState::Retained(rs) = std::mem::replace(&mut self.slots[i], SlotState::Idle)
        else {
            unreachable!()
        };
        self.retained_count -= 1;
        self.kv_resident -= rs.pos as usize + 1;
        self.retained_evictions += 1;
        let _ = self.backend.release_retained(i);
        events.push(EngineEvent::RetainedDropped { engine: self.id, request_id: rs.request_id });
    }

    /// Drop ALL retained slots (weight-sync invalidation: the retained KV
    /// prefixes were computed under the old params).
    pub fn invalidate_retained(&mut self, events: &mut Vec<EngineEvent>) {
        for i in 0..self.slots.len() {
            if matches!(self.slots[i], SlotState::Retained(_)) {
                self.drop_retained_slot(i, events);
            }
        }
    }

    /// Explicit coordinator-side release of one retained slot (the partial
    /// is resuming on another engine, or was evicted from the buffer).
    /// Stale (request, token) pairs are ignored.
    pub fn release_retained_request(
        &mut self,
        request_id: u64,
        token: u64,
        events: &mut Vec<EngineEvent>,
    ) {
        let found = self.slots.iter().position(|s| {
            matches!(s, SlotState::Retained(rs)
                if rs.request_id == request_id && rs.token == token)
        });
        if let Some(i) = found {
            self.drop_retained_slot(i, events);
        }
    }

    /// Queue a work item (admitted to a slot on the next step).
    pub fn submit(&mut self, item: WorkItem) -> Result<()> {
        ensure!(!item.prompt.is_empty(), "empty prompt");
        ensure!(item.prompt.len() <= self.backend.p_max(), "prompt exceeds p_max");
        ensure!(item.max_total <= self.backend.max_seq(), "max_total exceeds horizon");
        self.pending.push_back(item);
        Ok(())
    }

    /// Weight sync.
    pub fn set_params(&mut self, params: &[f32]) -> Result<()> {
        self.backend.set_params(params)
    }

    /// Early termination: flush every busy slot as a partial and drop the
    /// admission queue back to the caller (unstarted items are NOT partial
    /// trajectories — the coordinator re-queues them as fresh work).
    ///
    /// With `retain`, a flushed slot that is fully caught up (its replay —
    /// if any — finished and it generated at least one token) keeps its KV
    /// resident as `SlotState::Retained`; its `Stopped` result carries
    /// the retention token ([`WorkResult::retained`]). Slots stopped
    /// mid-replay flush plainly — their KV covers only part of the resume
    /// prefix, which the simple (token, length) validation cannot describe.
    pub fn stop_generation(
        &mut self,
        events: &mut Vec<EngineEvent>,
        retain: bool,
    ) -> Vec<WorkItem> {
        for i in 0..self.slots.len() {
            // All busy/kv counter maintenance goes through vacate(); the
            // retain branch re-installs the identical KV charge below.
            let Some(b) = self.vacate(i) else { continue };
            let caught_up = b.replay_fed >= b.item.resume.len() && !b.generated.is_empty();
            let can_retain =
                retain && caught_up && self.backend.retain_slot(i).unwrap_or(false);
            if can_retain {
                self.retain_counter += 1;
                let token = self.retain_counter;
                let rs = RetainedSlot {
                    request_id: b.item.request_id,
                    token,
                    pos: b.pos,
                    next_token: b.next_token,
                    generated_len: b.item.resume.len() + b.generated.len(),
                    admitted_seq: b.admitted_seq,
                };
                // The retained slot keeps the vacated slot's exact KV
                // residency charged against the budget.
                self.retained_count += 1;
                self.kv_resident += rs.pos as usize + 1;
                let mut result = finish(*b, FinishReason::Stopped);
                result.retained = Some(token);
                events.push(EngineEvent::Done { engine: self.id, result });
                self.slots[i] = SlotState::Retained(rs);
            } else {
                events.push(EngineEvent::Done {
                    engine: self.id,
                    result: finish(*b, FinishReason::Stopped),
                });
            }
        }
        let unstarted: Vec<WorkItem> = self.pending.drain(..).collect();
        events.push(EngineEvent::Flushed { engine: self.id });
        unstarted
    }

    /// One scheduler iteration: admit pending work, enforce the KV budget,
    /// run one decode step, process sampled tokens. Steady state (all slots
    /// mid-generation) performs no heap allocation in engine/sampler code.
    pub fn step(&mut self, events: &mut Vec<EngineEvent>) -> Result<()> {
        self.admit(events)?;
        self.enforce_kv_budget(events);
        if self.busy_count == 0 {
            return Ok(());
        }

        let s = self.slots.len();
        let v = self.backend.vocab();
        for (i, slot) in self.slots.iter().enumerate() {
            match slot {
                SlotState::Busy(b) => {
                    self.step_tokens[i] = b.next_token;
                    self.step_pos[i] = b.pos;
                }
                SlotState::Idle => {
                    self.step_tokens[i] = 0;
                    self.step_pos[i] = 0;
                }
                SlotState::Retained(rs) => {
                    // Park the lane on the pending feed position: whatever
                    // the lockstep decode writes there is overwritten by
                    // the resume's first real feed before it is ever
                    // attended (see `Backend::retain_slot`'s contract).
                    self.step_tokens[i] = 0;
                    self.step_pos[i] = rs.pos;
                }
            }
        }

        let t_step = Instant::now();
        self.backend.decode_into(&self.step_tokens, &self.step_pos, &mut self.logits_buf)?;
        let dur = t_step.elapsed().as_secs_f64();
        self.decode_steps += 1;

        for i in 0..s {
            let SlotState::Busy(b) = &mut self.slots[i] else { continue };
            b.pos += 1;
            self.kv_resident += 1;
            if b.replay_fed < b.item.resume.len() {
                // We just fed resume[replay_fed]; keep replaying.
                b.replay_fed += 1;
                b.replayed += 1;
                self.replayed_tokens += 1;
                if b.replay_fed < b.item.resume.len() {
                    b.next_token = b.item.resume[b.replay_fed];
                    continue;
                }
                // Replay complete: this step's logits sample the first new
                // token (fall through).
            }
            let row = &self.logits_buf[i * v..(i + 1) * v];
            let (tok, lp) =
                sample_token_with(row, &b.item.sampling, &mut self.rng, &mut self.scratch);
            b.generated.push(tok);
            b.logprobs.push(lp);
            let total_len = b.item.prompt.len() + b.item.resume.len() + b.generated.len();
            let reason = if tok == tokenizer::EOS {
                Some(FinishReason::Eos)
            } else if total_len >= b.item.max_total {
                Some(FinishReason::LengthCap)
            } else {
                None
            };
            match reason {
                Some(r) => {
                    let b = self.vacate(i).expect("busy slot");
                    events.push(EngineEvent::Done { engine: self.id, result: finish(*b, r) });
                }
                None => b.next_token = tok,
            }
        }

        events.push(EngineEvent::Trace(StepTrace {
            engine: self.id,
            t_wall: self.t0.elapsed().as_secs_f64(),
            dur,
            active: self.busy_count,
            slots: s,
            kv_tokens: self.kv_resident,
            preemptions: self.preemptions,
        }));
        Ok(())
    }

    /// First retained slot matching an affinity hint exactly: same request,
    /// same retention token, and a resume prefix of exactly the retained
    /// generated length (the trajectory cannot have grown in between, but
    /// the triple check makes the fast path impossible to hit by accident).
    fn find_retained(&self, item: &WorkItem) -> Option<usize> {
        let token = item.retain?;
        self.slots.iter().position(|s| {
            matches!(s, SlotState::Retained(rs)
                if rs.token == token
                    && rs.request_id == item.request_id
                    && rs.generated_len == item.resume.len())
        })
    }

    /// Most recently admitted retained slot (LIFO eviction victim).
    fn latest_retained(&self) -> Option<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| match s {
                SlotState::Retained(rs) => Some((i, rs.admitted_seq)),
                _ => None,
            })
            .max_by_key(|&(_, seq)| seq)
            .map(|(i, _)| i)
    }

    /// Re-activate retained slot `i` for `item`: the pending next-token
    /// feed picks up exactly where the flushed slot left off, so the token
    /// stream is bit-identical to an uninterrupted run (and to the replay
    /// path) — with zero recompute.
    ///
    /// Strictly best-effort, like every other retention path: if the
    /// backend fails to restore the slot, the retained state is dropped
    /// and the item is handed back for ordinary replay admission — a
    /// retention problem must never kill the engine thread (`step` errors
    /// are fatal to it).
    fn admit_from_retained(&mut self, i: usize, item: WorkItem) -> Option<WorkItem> {
        let SlotState::Retained(rs) = std::mem::replace(&mut self.slots[i], SlotState::Idle)
        else {
            unreachable!("admit_from_retained on a non-retained slot");
        };
        // Release the retained charge first so the counters stay consistent
        // on every exit path; `occupy` re-adds the identical pos+1.
        self.retained_count -= 1;
        self.kv_resident -= rs.pos as usize + 1;
        if let Err(e) = self.backend.resume_retained(i) {
            self.retained_evictions += 1;
            let _ = self.backend.release_retained(i);
            eprintln!(
                "engine-{}: resume_retained failed ({e:#}); falling back to replay",
                self.id
            );
            return Some(item);
        }
        self.admission_counter += 1;
        // Only NEW tokens land in `generated`; reserve the worst case so
        // the decode loop's push() never reallocates mid-generation.
        let out_cap = item.max_total.saturating_sub(item.prompt.len() + item.resume.len());
        let busy = BusySlot {
            generated: Vec::with_capacity(out_cap),
            logprobs: Vec::with_capacity(out_cap),
            replay_fed: item.resume.len(),
            replayed: 0,
            resumed_from_kv: true,
            next_token: rs.next_token,
            pos: rs.pos,
            admitted_seq: self.admission_counter,
            item,
        };
        self.retained_resumes += 1;
        self.occupy(i, Box::new(busy));
        None
    }

    /// Admission-pressure eviction victim: LIFO among retained slots, but
    /// slots a queued item's hint still targets are spared when possible —
    /// evicting one of those forces the imminent resume to replay its
    /// whole prefix, the exact cost retention exists to avoid. If every
    /// retained slot is targeted, plain LIFO applies: queued work must
    /// still never starve behind parked KV.
    fn admission_eviction_victim(&self) -> Option<usize> {
        let mut untargeted: Option<(usize, u64)> = None;
        let mut any: Option<(usize, u64)> = None;
        for (i, s) in self.slots.iter().enumerate() {
            let SlotState::Retained(rs) = s else { continue };
            let seq = rs.admitted_seq;
            if any.map_or(true, |(_, b)| seq > b) {
                any = Some((i, seq));
            }
            let targeted = self.pending.iter().any(|it| {
                it.retain == Some(rs.token) && it.request_id == rs.request_id
            });
            if !targeted && untargeted.map_or(true, |(_, b)| seq > b) {
                untargeted = Some((i, seq));
            }
        }
        untargeted.or(any).map(|(i, _)| i)
    }

    fn admit(&mut self, events: &mut Vec<EngineEvent>) -> Result<()> {
        loop {
            let Some(front) = self.pending.front() else { break };
            // 1. Affinity fast path: the hint names a live retained slot.
            if let Some(i) = self.find_retained(front) {
                let item = self.pending.pop_front().unwrap();
                if let Some(item) = self.admit_from_retained(i, item) {
                    // Backend restore failed; the retained state is gone —
                    // requeue at the front for ordinary replay admission.
                    self.pending.push_front(item);
                }
                continue;
            }
            // 2. Ordinary admission into the first idle slot; if none is
            //    idle but retained slots exist, evict one (LIFO, sparing
            //    slots that queued hints still target) — queued work must
            //    never starve behind parked KV.
            let idle = self.slots.iter().position(|s| matches!(s, SlotState::Idle));
            let i = match idle {
                Some(i) => i,
                None => match self.admission_eviction_victim() {
                    Some(victim) => {
                        self.drop_retained_slot(victim, events);
                        continue;
                    }
                    None => break, // every slot busy — wait for a finish
                },
            };
            let item = self.pending.pop_front().unwrap();
            self.admission_counter += 1;
            let seq = self.admission_counter;
            let plen = item.prompt.len();
            if plen >= item.max_total {
                // No room to generate anything: report an empty LengthCap.
                events.push(EngineEvent::Done {
                    engine: self.id,
                    result: WorkResult {
                        request_id: item.request_id,
                        new_tokens: vec![],
                        new_logprobs: vec![],
                        reason: FinishReason::LengthCap,
                        replayed: 0,
                        retained: None,
                        resumed_from_kv: false,
                    },
                });
                continue;
            }
            let logits = self.backend.prefill(i, &item.prompt)?;
            // Reserve the worst-case output length up front so the decode
            // loop's push() never reallocates mid-generation.
            let out_cap = item.max_total.saturating_sub(plen);
            let mut busy = BusySlot {
                generated: Vec::with_capacity(out_cap),
                logprobs: Vec::with_capacity(out_cap),
                replay_fed: 0,
                replayed: 0,
                resumed_from_kv: false,
                next_token: 0,
                pos: plen as i32,
                admitted_seq: seq,
                item,
            };
            if busy.item.resume.is_empty() {
                // Sample the first new token from the prefill logits.
                let (tok, lp) = sample_token_with(
                    &logits,
                    &busy.item.sampling,
                    &mut self.rng,
                    &mut self.scratch,
                );
                busy.generated.push(tok);
                busy.logprobs.push(lp);
                if tok == tokenizer::EOS {
                    events.push(EngineEvent::Done {
                        engine: self.id,
                        result: finish(busy, FinishReason::Eos),
                    });
                    continue;
                }
                if plen + 1 >= busy.item.max_total {
                    events.push(EngineEvent::Done {
                        engine: self.id,
                        result: finish(busy, FinishReason::LengthCap),
                    });
                    continue;
                }
                busy.next_token = tok;
            } else {
                // Chunked replay (vLLM-style parallel re-prefill of the
                // buffered partial); falls back to per-token decode when
                // the backend declines (mock backend, near-horizon).
                let resume = busy.item.resume.clone();
                let pmax = self.backend.p_max();
                let mut fed = 0usize;
                let mut last_logits: Option<Vec<f32>> = None;
                while fed < resume.len() {
                    let end = (fed + pmax).min(resume.len());
                    match self.backend.replay(i, &resume[fed..end], plen + fed)? {
                        Some(logits) => {
                            last_logits = Some(logits);
                            fed = end;
                        }
                        None => break,
                    }
                }
                self.replayed_tokens += fed as u64;
                busy.replay_fed = fed;
                busy.replayed = fed;
                busy.pos = (plen + fed) as i32;
                if fed == resume.len() {
                    // Replay complete: sample the next new token now.
                    let logits = last_logits.expect("non-empty resume");
                    let (tok, lp) = sample_token_with(
                        &logits,
                        &busy.item.sampling,
                        &mut self.rng,
                        &mut self.scratch,
                    );
                    busy.generated.push(tok);
                    busy.logprobs.push(lp);
                    let total = plen + resume.len() + 1;
                    if tok == tokenizer::EOS {
                        events.push(EngineEvent::Done {
                            engine: self.id,
                            result: finish(busy, FinishReason::Eos),
                        });
                        continue;
                    }
                    if total >= busy.item.max_total {
                        events.push(EngineEvent::Done {
                            engine: self.id,
                            result: finish(busy, FinishReason::LengthCap),
                        });
                        continue;
                    }
                    busy.next_token = tok;
                } else {
                    busy.next_token = resume[fed];
                }
            }
            self.occupy(i, Box::new(busy));
        }
        Ok(())
    }

    /// Enforce the KV budget. Retained slots are a cache: they are evicted
    /// first (LIFO) — only then are live slots preempted (LIFO, like vLLM).
    /// O(S) victim scan per eviction against O(1) counters.
    fn enforce_kv_budget(&mut self, events: &mut Vec<EngineEvent>) {
        if self.kv_budget == 0 {
            return;
        }
        while self.kv_resident > self.kv_budget && self.retained_count > 0 {
            let victim = self.latest_retained().unwrap();
            self.drop_retained_slot(victim, events);
        }
        while self.kv_resident > self.kv_budget && self.busy_count > 1 {
            let victim = self
                .slots
                .iter()
                .enumerate()
                .filter_map(|(i, s)| match s {
                    SlotState::Busy(b) => Some((i, b.admitted_seq)),
                    _ => None,
                })
                .max_by_key(|&(_, seq)| seq)
                .map(|(i, _)| i)
                .unwrap();
            if let Some(b) = self.vacate(victim) {
                self.preemptions += 1;
                events.push(EngineEvent::Done {
                    engine: self.id,
                    result: finish(*b, FinishReason::Preempted),
                });
            }
        }
    }
}

fn finish(b: BusySlot, reason: FinishReason) -> WorkResult {
    WorkResult {
        request_id: b.item.request_id,
        new_tokens: b.generated,
        new_logprobs: b.logprobs,
        reason,
        replayed: b.replayed,
        retained: None,
        resumed_from_kv: b.resumed_from_kv,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::backend::MockBackend;

    fn item(id: u64, prompt: Vec<i32>) -> WorkItem {
        WorkItem {
            request_id: id,
            prompt: prompt.into(),
            resume: vec![],
            max_total: 96,
            sampling: SamplingParams::greedy(),
            retain: None,
        }
    }

    fn run_to_completion(
        eng: &mut Engine<MockBackend>,
        max_steps: usize,
    ) -> Vec<WorkResult> {
        let mut out = Vec::new();
        for _ in 0..max_steps {
            if !eng.has_work() {
                break;
            }
            let mut ev = Vec::new();
            eng.step(&mut ev).unwrap();
            for e in ev {
                if let EngineEvent::Done { result, .. } = e {
                    out.push(result);
                }
            }
        }
        out
    }

    /// Recompute the counters from first principles (test-only O(S) scan).
    fn scan_counters(eng: &Engine<MockBackend>) -> (usize, usize, usize) {
        let busy = eng.slots.iter().filter(|s| matches!(s, SlotState::Busy(_))).count();
        let retained =
            eng.slots.iter().filter(|s| matches!(s, SlotState::Retained(_))).count();
        let kv = eng
            .slots
            .iter()
            .map(|s| match s {
                SlotState::Busy(b) => b.pos as usize + 1,
                SlotState::Retained(rs) => rs.pos as usize + 1,
                SlotState::Idle => 0,
            })
            .sum();
        (busy, retained, kv)
    }

    #[test]
    fn greedy_generation_matches_script() {
        let be = MockBackend::new(4, 96);
        let prompt = vec![1, 9, 9];
        let want_len = be.scripted_len(&prompt);
        let mut eng = Engine::new(0, be, 0, 1);
        eng.submit(item(1, prompt)).unwrap();
        let results = run_to_completion(&mut eng, 200);
        assert_eq!(results.len(), 1);
        let r = &results[0];
        assert_eq!(r.reason, FinishReason::Eos);
        // scripted_len digits + the EOS token itself
        assert_eq!(r.new_tokens.len(), want_len + 1);
        assert_eq!(*r.new_tokens.last().unwrap(), tokenizer::EOS);
        assert_eq!(r.new_logprobs.len(), r.new_tokens.len());
    }

    #[test]
    fn multiple_slots_progress_concurrently() {
        let be = MockBackend::new(4, 96);
        let mut eng = Engine::new(0, be, 0, 1);
        for i in 0..4 {
            eng.submit(item(i, vec![1, i as i32 + 4, 7])).unwrap();
        }
        let results = run_to_completion(&mut eng, 300);
        assert_eq!(results.len(), 4);
        let mut ids: Vec<u64> = results.iter().map(|r| r.request_id).collect();
        ids.sort();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn queue_admits_when_slots_free() {
        let be = MockBackend::new(2, 96);
        let mut eng = Engine::new(0, be, 0, 1);
        for i in 0..6 {
            eng.submit(item(i, vec![1, i as i32 + 4])).unwrap();
        }
        assert_eq!(eng.queued(), 6);
        let results = run_to_completion(&mut eng, 500);
        assert_eq!(results.len(), 6);
        assert_eq!(eng.queued(), 0);
    }

    #[test]
    fn length_cap_respected() {
        let mut be = MockBackend::new(1, 96);
        be.min_len = 50;
        be.spread = 1; // script wants 50 tokens
        let mut eng = Engine::new(0, be, 0, 1);
        let mut it = item(7, vec![1, 5, 6]);
        it.max_total = 10; // 3 prompt + 7 generated
        eng.submit(it).unwrap();
        let results = run_to_completion(&mut eng, 100);
        assert_eq!(results[0].reason, FinishReason::LengthCap);
        assert_eq!(results[0].new_tokens.len(), 7);
    }

    #[test]
    fn stop_generation_flushes_partials() {
        let mut be = MockBackend::new(2, 96);
        be.min_len = 40;
        be.spread = 1;
        let mut eng = Engine::new(0, be, 0, 1);
        eng.submit(item(1, vec![1, 4])).unwrap();
        eng.submit(item(2, vec![1, 5])).unwrap();
        let mut ev = Vec::new();
        for _ in 0..5 {
            eng.step(&mut ev).unwrap();
        }
        ev.clear();
        let unstarted = eng.stop_generation(&mut ev, false);
        assert!(unstarted.is_empty());
        let partials: Vec<&WorkResult> = ev
            .iter()
            .filter_map(|e| match e {
                EngineEvent::Done { result, .. } => Some(result),
                _ => None,
            })
            .collect();
        assert_eq!(partials.len(), 2);
        for p in partials {
            assert_eq!(p.reason, FinishReason::Stopped);
            assert!(p.retained.is_none(), "retain=false must not retain");
            assert!(!p.new_tokens.is_empty());
            assert!(p.new_tokens.len() < 40);
        }
        assert!(matches!(ev.last(), Some(EngineEvent::Flushed { .. })));
        assert_eq!(eng.busy(), 0);
        assert_eq!(eng.retained(), 0);
        assert_eq!(eng.kv_tokens(), 0);
    }

    #[test]
    fn stop_returns_unstarted_queue() {
        let be = MockBackend::new(1, 96);
        let mut eng = Engine::new(0, be, 0, 1);
        for i in 0..5 {
            eng.submit(item(i, vec![1, i as i32 + 4])).unwrap();
        }
        let mut ev = Vec::new();
        eng.step(&mut ev).unwrap(); // admits exactly 1
        ev.clear();
        let unstarted = eng.stop_generation(&mut ev, false);
        assert_eq!(unstarted.len(), 4);
    }

    #[test]
    fn resume_replays_then_continues() {
        let be = MockBackend::new(1, 96);
        let prompt = vec![1, 8, 8];
        let mut eng = Engine::new(0, be, 0, 1);
        let mut it = item(3, prompt);
        it.resume = vec![5, 6, 7]; // 3 tokens to replay
        eng.submit(it).unwrap();
        let results = run_to_completion(&mut eng, 200);
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].replayed, 3);
        assert!(!results[0].resumed_from_kv);
        assert!(!results[0].new_tokens.is_empty());
        assert_eq!(eng.replayed_tokens, 3);
    }

    #[test]
    fn kv_budget_triggers_lifo_preemption() {
        let mut be = MockBackend::new(4, 96);
        be.min_len = 60;
        be.spread = 1; // long outputs to build KV pressure
        let mut eng = Engine::new(0, be, 30, 1); // tight budget
        for i in 0..4 {
            eng.submit(item(i, vec![1, i as i32 + 4, 9, 9])).unwrap();
        }
        let mut preempted = Vec::new();
        for _ in 0..40 {
            let mut ev = Vec::new();
            eng.step(&mut ev).unwrap();
            for e in ev {
                if let EngineEvent::Done { result, .. } = e {
                    if result.reason == FinishReason::Preempted {
                        preempted.push(result.request_id);
                    }
                }
            }
        }
        assert!(!preempted.is_empty(), "tight budget must preempt");
        assert!(eng.preemptions() as usize >= preempted.len());
        // LIFO: the latest admissions (higher ids) are evicted first.
        assert!(preempted.contains(&3) || preempted.contains(&2), "{preempted:?}");
        // Under a tight budget the engine converges to few busy slots (a
        // single long sequence may legitimately exceed the budget alone —
        // the last slot is never preempted).
        assert!(eng.busy() <= 2, "busy {}", eng.busy());
    }

    /// The incremental busy/retained/kv counters must agree with a
    /// from-scratch slot scan at every point of a run that exercises
    /// admission, decode, finish, preemption, retention, and
    /// stop_generation.
    #[test]
    fn incremental_counters_match_slot_scans() {
        let mut be = MockBackend::new(4, 96);
        be.min_len = 30;
        be.spread = 6;
        let mut eng = Engine::new(0, be, 40, 9); // budget tight enough to preempt
        for i in 0..8 {
            eng.submit(item(i, vec![1, i as i32 + 4, 9])).unwrap();
        }
        let mut ev = Vec::new();
        for _ in 0..60 {
            eng.step(&mut ev).unwrap();
            let (busy, retained, kv) = scan_counters(&eng);
            assert_eq!(eng.busy(), busy, "busy counter drifted");
            assert_eq!(eng.retained(), retained, "retained counter drifted");
            assert_eq!(eng.kv_tokens(), kv, "kv counter drifted");
            ev.clear();
            if !eng.has_work() {
                break;
            }
        }
        eng.stop_generation(&mut ev, true);
        let (busy, retained, kv) = scan_counters(&eng);
        assert_eq!(
            (eng.busy(), eng.retained(), eng.kv_tokens()),
            (busy, retained, kv)
        );
        assert_eq!(busy, 0);
        // Retained slots (if any) still charge KV.
        assert_eq!(kv > 0, retained > 0);
        ev.clear();
        eng.invalidate_retained(&mut ev);
        assert_eq!((eng.retained(), eng.kv_tokens()), (0, 0));
    }

    #[test]
    fn immediate_eos_on_prefill_is_handled() {
        let mut be = MockBackend::new(1, 96);
        be.min_len = 0;
        be.spread = 1; // script = EOS immediately
        let mut eng = Engine::new(0, be, 0, 1);
        eng.submit(item(1, vec![1, 4])).unwrap();
        let results = run_to_completion(&mut eng, 10);
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].reason, FinishReason::Eos);
        assert_eq!(results[0].new_tokens, vec![tokenizer::EOS]);
    }

    #[test]
    fn trace_reports_active_slots() {
        let be = MockBackend::new(4, 96);
        let mut eng = Engine::new(0, be, 0, 1);
        eng.submit(item(1, vec![1, 4])).unwrap();
        let mut ev = Vec::new();
        eng.step(&mut ev).unwrap();
        let trace = ev
            .iter()
            .find_map(|e| match e {
                EngineEvent::Trace(t) => Some(t.clone()),
                _ => None,
            })
            .expect("trace emitted");
        assert_eq!(trace.slots, 4);
        assert!(trace.active <= 1); // may have finished already
        assert!(trace.dur >= 0.0);
    }

    #[test]
    fn rejects_oversized_prompt() {
        let be = MockBackend::new(1, 96); // p_max = 24
        let mut eng = Engine::new(0, be, 0, 1);
        assert!(eng.submit(item(1, vec![1; 25])).is_err());
    }

    // -- KV retention -------------------------------------------------------

    /// Full stream of one request run uninterrupted on a fresh engine
    /// (tokens ++ logprob bits) — the oracle every retention test compares
    /// against. The mock script is positional, so any resume strategy that
    /// is correct must reproduce exactly this stream.
    fn uninterrupted_stream(prompt: &[i32]) -> (Vec<i32>, Vec<u32>) {
        let mut be = MockBackend::new(1, 96);
        be.min_len = 20;
        be.spread = 1;
        let mut eng = Engine::new(9, be, 0, 1);
        eng.submit(item(1, prompt.to_vec())).unwrap();
        let results = run_to_completion(&mut eng, 200);
        assert_eq!(results.len(), 1);
        assert!(results[0].reason.is_complete());
        (
            results[0].new_tokens.clone(),
            results[0].new_logprobs.iter().map(|l| l.to_bits()).collect(),
        )
    }

    fn retention_engine() -> Engine<MockBackend> {
        let mut be = MockBackend::new(1, 96);
        be.min_len = 20;
        be.spread = 1; // 20-token scripts: long enough to stop mid-way
        Engine::new(9, be, 0, 1)
    }

    /// Stop a running request mid-generation with retention; returns the
    /// flushed partial (with its token) after asserting the slot retained.
    fn stop_retaining(eng: &mut Engine<MockBackend>, steps: usize) -> WorkResult {
        let mut ev = Vec::new();
        for _ in 0..steps {
            eng.step(&mut ev).unwrap();
        }
        ev.clear();
        eng.stop_generation(&mut ev, true);
        let partial = ev
            .iter()
            .find_map(|e| match e {
                EngineEvent::Done { result, .. } => Some(result.clone()),
                _ => None,
            })
            .expect("flushed partial");
        assert_eq!(partial.reason, FinishReason::Stopped);
        assert_eq!(eng.retained(), 1);
        partial
    }

    /// The tentpole contract at engine level: a retained-KV resume replays
    /// nothing and produces the bit-identical stream an uninterrupted run
    /// (and therefore the replay path) produces.
    #[test]
    fn retained_resume_is_bit_identical_with_zero_replay() {
        let prompt = vec![1, 8, 8];
        let (want_toks, want_lps) = uninterrupted_stream(&prompt);

        let mut eng = retention_engine();
        eng.submit(item(1, prompt.clone())).unwrap();
        let partial = stop_retaining(&mut eng, 5);
        let token = partial.retained.expect("caught-up slot must retain");
        assert!(!partial.new_tokens.is_empty());
        assert!(eng.kv_tokens() > 0, "retained KV stays resident");

        // Resume with the affinity hint.
        let mut it = item(1, prompt);
        it.resume = partial.new_tokens.clone();
        it.retain = Some(token);
        eng.submit(it).unwrap();
        let results = run_to_completion(&mut eng, 200);
        assert_eq!(results.len(), 1);
        let r = &results[0];
        assert!(r.resumed_from_kv, "hint matched — must resume from KV");
        assert_eq!(r.replayed, 0, "retained resume replays nothing");
        assert_eq!(eng.replayed_tokens, 0);
        assert_eq!(eng.retained_resumes, 1);
        assert_eq!(eng.retained(), 0);

        let full_toks: Vec<i32> =
            partial.new_tokens.iter().chain(r.new_tokens.iter()).copied().collect();
        let full_lps: Vec<u32> = partial
            .new_logprobs
            .iter()
            .chain(r.new_logprobs.iter())
            .map(|l| l.to_bits())
            .collect();
        assert_eq!(full_toks, want_toks, "token stream diverged from oracle");
        assert_eq!(full_lps, want_lps, "logprob bits diverged from oracle");
    }

    /// A stale hint (slot evicted in between) falls back to replay and
    /// still reproduces the oracle stream.
    #[test]
    fn stale_hint_falls_back_to_replay_bit_identically() {
        let prompt_a = vec![1, 8, 8];
        let (want_toks, want_lps) = uninterrupted_stream(&prompt_a);

        let mut eng = retention_engine();
        eng.submit(item(1, prompt_a.clone())).unwrap();
        let partial = stop_retaining(&mut eng, 5);
        let token = partial.retained.unwrap();

        // Fresh work on the single-slot engine evicts the retained slot
        // (admission must never starve behind parked KV).
        let mut ev = Vec::new();
        eng.submit(item(2, vec![1, 4, 4])).unwrap();
        eng.step(&mut ev).unwrap();
        assert_eq!(eng.retained(), 0, "admission pressure evicts retained KV");
        assert!(
            ev.iter().any(|e| matches!(
                e,
                EngineEvent::RetainedDropped { request_id: 1, .. }
            )),
            "eviction must notify the coordinator"
        );
        assert_eq!(eng.retained_evictions, 1);
        let _ = run_to_completion(&mut eng, 300); // drain request 2

        // Resume request 1 with the now-stale hint: replay fallback.
        let mut it = item(1, prompt_a);
        it.resume = partial.new_tokens.clone();
        it.retain = Some(token);
        eng.submit(it).unwrap();
        let results = run_to_completion(&mut eng, 300);
        assert_eq!(results.len(), 1);
        let r = &results[0];
        assert!(!r.resumed_from_kv);
        assert_eq!(r.replayed, partial.new_tokens.len());

        let full_toks: Vec<i32> =
            partial.new_tokens.iter().chain(r.new_tokens.iter()).copied().collect();
        let full_lps: Vec<u32> = partial
            .new_logprobs
            .iter()
            .chain(r.new_logprobs.iter())
            .map(|l| l.to_bits())
            .collect();
        assert_eq!(full_toks, want_toks);
        assert_eq!(full_lps, want_lps);
    }

    /// Weight-sync invalidation: after `invalidate_retained` the hint is
    /// stale and the resume replays (under whatever params are current).
    #[test]
    fn invalidation_clears_retention_and_resume_replays() {
        let prompt = vec![1, 8, 8];
        let mut eng = retention_engine();
        eng.submit(item(1, prompt.clone())).unwrap();
        let partial = stop_retaining(&mut eng, 5);
        let token = partial.retained.unwrap();

        let mut ev = Vec::new();
        eng.invalidate_retained(&mut ev);
        assert_eq!(eng.retained(), 0);
        assert_eq!(eng.kv_tokens(), 0);
        assert!(ev
            .iter()
            .any(|e| matches!(e, EngineEvent::RetainedDropped { request_id: 1, .. })));

        let mut it = item(1, prompt);
        it.resume = partial.new_tokens.clone();
        it.retain = Some(token);
        eng.submit(it).unwrap();
        let results = run_to_completion(&mut eng, 300);
        assert!(!results[0].resumed_from_kv);
        assert_eq!(results[0].replayed, partial.new_tokens.len());
    }

    /// Under KV pressure, retained slots are evicted before any live slot
    /// is preempted.
    #[test]
    fn budget_evicts_retained_before_live() {
        let mut be = MockBackend::new(2, 96);
        be.min_len = 40;
        be.spread = 1;
        let mut eng = Engine::new(0, be, 25, 1); // tight budget, 2 slots
        eng.submit(item(1, vec![1, 8, 8])).unwrap();
        let mut ev = Vec::new();
        for _ in 0..5 {
            eng.step(&mut ev).unwrap();
        }
        ev.clear();
        eng.stop_generation(&mut ev, true);
        assert_eq!(eng.retained(), 1);

        // A long-running live sequence pushes kv over budget; the retained
        // slot must fall before the live one is touched.
        eng.submit(item(2, vec![1, 9, 9])).unwrap();
        let mut dropped = false;
        let mut preempted = false;
        for _ in 0..40 {
            let mut ev = Vec::new();
            eng.step(&mut ev).unwrap();
            for e in &ev {
                match e {
                    EngineEvent::RetainedDropped { request_id: 1, .. } => dropped = true,
                    EngineEvent::Done { result, .. }
                        if result.reason == FinishReason::Preempted =>
                    {
                        preempted = true
                    }
                    _ => {}
                }
            }
            if !eng.has_work() {
                break;
            }
        }
        assert!(dropped, "retained slot must be evicted under budget pressure");
        assert!(!preempted, "live slot preempted while retained KV was parked");
        assert_eq!(eng.retained(), 0);
    }

    /// `ReleaseRetained` semantics: a matching (request, token) drops the
    /// slot; stale tokens are ignored.
    #[test]
    fn release_retained_request_validates_token() {
        let prompt = vec![1, 8, 8];
        let mut eng = retention_engine();
        eng.submit(item(1, prompt)).unwrap();
        let partial = stop_retaining(&mut eng, 5);
        let token = partial.retained.unwrap();

        let mut ev = Vec::new();
        eng.release_retained_request(1, token + 99, &mut ev); // stale token
        assert_eq!(eng.retained(), 1);
        assert!(ev.is_empty());
        eng.release_retained_request(1, token, &mut ev);
        assert_eq!(eng.retained(), 0);
        assert_eq!(eng.kv_tokens(), 0);
        assert_eq!(ev.len(), 1);
    }

    /// Admission-pressure eviction spares retained slots that a queued
    /// item's hint still targets: with both slots retained and the queue
    /// holding [fresh, hinted-resume], the fresh item must evict the
    /// UNtargeted slot (even though the targeted one is LIFO-latest) so
    /// the resume still lands on its retained KV.
    #[test]
    fn admission_eviction_spares_hint_targeted_slots() {
        let mut be = MockBackend::new(2, 96);
        be.min_len = 20;
        be.spread = 1;
        let mut eng = Engine::new(0, be, 0, 1);
        eng.submit(item(1, vec![1, 8, 8])).unwrap();
        eng.submit(item(2, vec![1, 4, 4])).unwrap();
        let mut ev = Vec::new();
        for _ in 0..5 {
            eng.step(&mut ev).unwrap();
        }
        ev.clear();
        eng.stop_generation(&mut ev, true);
        assert_eq!(eng.retained(), 2);
        // Request 2 admitted after request 1 → its slot is LIFO-latest,
        // i.e. the default eviction victim.
        let p2 = ev
            .iter()
            .find_map(|e| match e {
                EngineEvent::Done { result, .. } if result.request_id == 2 => {
                    Some(result.clone())
                }
                _ => None,
            })
            .expect("request 2 partial");
        let tok2 = p2.retained.expect("retained token");

        eng.submit(item(3, vec![1, 9, 9])).unwrap(); // fresh, needs a slot
        let mut resume = item(2, vec![1, 4, 4]);
        resume.resume = p2.new_tokens.clone();
        resume.retain = Some(tok2);
        eng.submit(resume).unwrap();

        ev.clear();
        eng.step(&mut ev).unwrap();
        assert!(
            ev.iter().any(|e| matches!(
                e,
                EngineEvent::RetainedDropped { request_id: 1, .. }
            )),
            "the UNtargeted slot (request 1) must be the eviction victim"
        );
        assert_eq!(eng.retained_resumes, 1, "hinted resume must hit its slot");
        assert_eq!(eng.retained(), 0);
        assert_eq!(eng.busy(), 2);
    }

    /// Mid-replay slots (KV covering only part of the resume prefix) must
    /// NOT retain — the (token, length) validation cannot describe them.
    #[test]
    fn mid_replay_slots_flush_without_retention() {
        let mut be = MockBackend::new(1, 96);
        be.min_len = 40;
        be.spread = 1;
        let mut eng = Engine::new(0, be, 0, 1);
        let mut it = item(1, vec![1, 8, 8]);
        it.resume = vec![5; 30]; // long replay: still replaying after 4 steps
        eng.submit(it).unwrap();
        let mut ev = Vec::new();
        for _ in 0..4 {
            eng.step(&mut ev).unwrap();
        }
        ev.clear();
        eng.stop_generation(&mut ev, true);
        let partial = ev
            .iter()
            .find_map(|e| match e {
                EngineEvent::Done { result, .. } => Some(result),
                _ => None,
            })
            .unwrap();
        assert!(partial.retained.is_none(), "mid-replay slot must not retain");
        assert_eq!(eng.retained(), 0);
        assert_eq!(eng.kv_tokens(), 0);
    }
}
