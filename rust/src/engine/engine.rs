//! The engine proper: S decode slots driven in lockstep (continuous
//! batching), an admission queue, paged KV-budget enforcement, partial-
//! result flushing for early termination, and a KV-retention ledger for
//! affinity-resumed partials.
//!
//! `Engine` is synchronous and backend-generic so the full coordinator
//! stack is testable with `MockBackend`; `pool.rs` wraps it in a thread and
//! channels for production use.
//!
//! The decode step is the innermost loop of the whole system, so it is
//! steady-state allocation-free and O(1) in its bookkeeping: `tokens`/`pos`
//! staging and the S×V logits buffer persist across steps
//! (`Backend::decode_into`), sampling runs through a persistent
//! [`SamplerScratch`], per-slot output vectors and block chains are
//! pre-reserved at admission, and `busy`/`kv_tokens`/block counters are
//! incremental, maintained on admit/finish/preempt instead of O(S) slot
//! scans per query.
//!
//! # Continuous batching with chunked prefill (the packed step)
//!
//! With `engine.step_token_budget > 0`, each engine step is assembled
//! against a token budget instead of admitting work per slot: every
//! caught-up sequence contributes one decode token, and whatever budget
//! remains is spent feeding *chunked prefill* slices of newly admitted
//! prompts ([`Backend::prefill_chunk`]) and replay slices of resumed
//! partials ([`Backend::replay`]) — so a long prompt (or a buffered
//! partial's replay) interleaves with decoding instead of stalling every
//! co-resident sequence for a whole admission prefill. Admission then
//! reserves a slot (and attaches any shared prompt prefix) but no longer
//! implies a same-step first token; block charging follows the chunks
//! (per-chunk, not per-admission). Chunking changes *when* tokens are
//! computed, never *which* tokens: greedy streams are bit-identical with
//! the budget on or off (pinned by `tests/continuous_batching.rs` against
//! the frozen reference oracle). A budget of 0 keeps the legacy
//! slot-admission schedule — the baseline arm
//! `benches/continuous_batching.rs` measures against.
//!
//! # Paged KV (the block economy)
//!
//! KV residency is charged in fixed-size refcounted blocks
//! ([`kvcache`](super::kvcache)): every busy or retained slot owns a
//! [`PageTable`] chain, the budget (`KvCacheConfig::budget_blocks`) is
//! enforced against [`BlockAllocator::blocks_in_use`], and a group's
//! shared prompt prefix is allocated once — later samples presenting the
//! same [`WorkItem::prefix`] handle attach the registered blocks with a
//! refcount bump ([`PrefixCache`]) and copy the partial tail only on their
//! first divergent write (COW). Under budget pressure the engine sheds
//! residency cheapest-first: prefix-registry entries (pure cache), then
//! retained slots (LIFO), then live preemption (LIFO, never the last
//! slot); fresh admission backpressures cleanly when the budget has no
//! headroom instead of admit-then-preempt thrashing. Eviction frees only
//! refs that drop to zero, so evicting a retained partial whose prefix is
//! still live for siblings costs near nothing.
//!
//! # KV retention (the resume-affinity fast path)
//!
//! Early termination normally discards a flushed slot's KV, so resuming the
//! buffered partial later re-prefills every generated token (the paper's
//! recomputation overhead, §5.4.1). With retention, `stop_generation`
//! leaves the slot in `SlotState::Retained`: the KV (its block chain)
//! stays resident, the `Stopped` result carries a retention token, and a
//! future [`WorkItem`] presenting that token resumes decoding directly
//! from the retained state — zero replayed tokens. The ledger is strictly
//! best-effort:
//!
//! - retained slots are evicted LIFO under KV-budget pressure (after
//!   prefix-registry entries, before any live sequence is preempted —
//!   they are a cache, not work) and when the admission queue needs a
//!   slot;
//! - a weight sync invalidates all retained state — retained slots AND
//!   the prefix registry — unless the coordinator opts into cross-sync
//!   retention (`SetParams::invalidate_retained`);
//! - a resume whose token no longer names a live retained entry — or whose
//!   backend-side restore fails — silently falls back to the ordinary
//!   replay path, so correctness never depends on the coordinator's
//!   affinity map (or the backend's ledger) being current.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{ensure, Result};

use super::backend::Backend;
use super::kvcache::{
    BlockAllocator, KvCacheConfig, KvDtype, PageTable, PrefixCache, DEFAULT_BLOCK_SIZE,
};
use super::sampler::{sample_token_dispatched, SamplerScratch, SamplingParams};
use super::simd::SamplerDispatch;
use crate::tokenizer;
use crate::util::Rng;

/// A unit of generation work. `resume` carries previously generated tokens
/// of a buffered partial trajectory; the engine replays them through decode
/// to rebuild KV state — the *recomputation cost* of off-policy partials
/// the paper's §5.4.1 ablates — unless `retain` names a live retained slot,
/// in which case the resident KV is reused and nothing is replayed.
///
/// The prompt is shared (`Arc`) with the coordinator's `Trajectory`, so
/// re-dispatching a buffered partial never deep-copies the prompt.
#[derive(Clone, Debug)]
pub struct WorkItem {
    /// Coordinator-side trajectory id; echoed back in [`WorkResult`].
    pub request_id: u64,
    /// Prompt tokens (shared with the coordinator's trajectory).
    pub prompt: std::sync::Arc<[i32]>,
    /// Previously generated tokens to rebuild KV state for (empty for
    /// fresh work).
    pub resume: Vec<i32>,
    /// Cap on total sequence length (prompt + replay + new tokens).
    pub max_total: usize,
    /// Sampling parameters for this request.
    pub sampling: SamplingParams,
    /// Affinity hint: a retention token from a previous `Stopped` flush on
    /// THIS engine ([`WorkResult::retained`]). When it still names a live
    /// retained slot matching `request_id` and `resume.len()`, the engine
    /// resumes from resident KV with zero replay; otherwise it silently
    /// falls back to the replay path. `None` = plain dispatch.
    pub retain: Option<u64>,
    /// Shared prompt-prefix handle (the coordinator's GRPO group id): all
    /// samples of one group carry the same handle and the same prompt, so
    /// the engine charges the prompt's KV blocks once per group
    /// ([`PrefixCache`]) instead of once per sample. At the engine level
    /// this is purely an accounting optimization: for the same admission
    /// schedule, token/logprob streams are bit-identical with the handle
    /// absent (no backend call changes). Note the coordinator-level knob
    /// (`engine.prefix_sharing`) also affects *scheduling* — group-home
    /// routing and budget-gated admission timing — so, like any
    /// scheduling knob, toggling it can reorder sampling across engines
    /// in stochastic multi-engine runs. `None` = private prompt
    /// residency.
    pub prefix: Option<u64>,
}

/// Why a slot's result was reported back to the coordinator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Sampled EOS — trajectory complete.
    Eos,
    /// Hit the length cap — complete (graded as-is, like the paper's
    /// truncated responses).
    LengthCap,
    /// Evicted under KV pressure; coordinator should re-queue.
    Preempted,
    /// Early termination flush — partial, goes to the CoPRIS buffer.
    Stopped,
}

impl FinishReason {
    /// Did the trajectory reach a terminal state (vs partial)?
    pub fn is_complete(&self) -> bool {
        matches!(self, FinishReason::Eos | FinishReason::LengthCap)
    }
}

/// New tokens generated under THIS engine assignment (excludes replayed
/// resume tokens — the coordinator owns the full trajectory).
#[derive(Clone, Debug)]
pub struct WorkResult {
    /// The [`WorkItem::request_id`] this result answers.
    pub request_id: u64,
    /// Tokens generated under this assignment (excludes replayed prefix).
    pub new_tokens: Vec<i32>,
    /// Behaviour log-prob of each new token (same length as `new_tokens`).
    pub new_logprobs: Vec<f32>,
    /// Why the slot was released.
    pub reason: FinishReason,
    /// Resume tokens actually recomputed before new generation began (the
    /// recompute cost; 0 when the resume was served from retained KV).
    pub replayed: usize,
    /// Set on `Stopped` flushes whose KV stayed resident in the engine:
    /// the retention token the coordinator must echo in
    /// [`WorkItem::retain`] to resume from the retained slot.
    pub retained: Option<u64>,
    /// True when this assignment resumed from retained KV (affinity hit —
    /// the whole `resume` prefix was NOT replayed).
    pub resumed_from_kv: bool,
}

/// Per-decode-step utilization sample (Fig. 1b data, plus the paged-KV
/// gauges).
#[derive(Clone, Debug)]
pub struct StepTrace {
    /// Engine id the sample came from.
    pub engine: usize,
    /// Seconds since engine start.
    pub t_wall: f64,
    /// Decode step duration (seconds).
    pub dur: f64,
    /// Busy slots this step.
    pub active: usize,
    /// Total decode slots.
    pub slots: usize,
    /// KV tokens resident after this step (live + retained; shared prompt
    /// prefixes count once per *sequence* — the logical view).
    pub kv_tokens: usize,
    /// KV blocks in use after this step (live + retained + prefix
    /// registry; shared blocks count ONCE — the physical residency the
    /// budget is enforced against).
    pub kv_blocks: usize,
    /// Internal fragmentation of the slots' block chains: the fraction of
    /// allocated block capacity (per-sequence view) not covering a
    /// resident token. 0.0 when nothing is resident.
    pub kv_frag: f64,
    /// Cumulative prompt tokens attached from a shared prefix instead of
    /// freshly charged (engine lifetime; the coordinator differences
    /// per-stage deltas).
    pub prefix_tokens_shared: u64,
    /// Cumulative copy-on-write block copies (engine lifetime).
    pub cow_copies: u64,
    /// Cumulative preemption count.
    pub preemptions: u64,
    /// Tokens this step actually computed: one per decode lane plus every
    /// prefill-chunk / replay-slice token the ingestion pump fed.
    pub step_tokens: usize,
    /// The step-token budget the step was packed against (0 = legacy slot
    /// admission — no packing; `step_tokens` is then just the lane count).
    pub step_budget: usize,
    /// Cumulative chunked-ingestion backend calls (engine lifetime; the
    /// coordinator differences per-stage deltas).
    pub prefill_chunks: u64,
    /// Cumulative seconds of chunk compute overlapped with live decode
    /// lanes (engine lifetime) — the admission-prefill stall the packed
    /// schedule avoided imposing on co-resident decodes.
    pub prefill_stall_saved: f64,
    /// Cumulative transient-backend-error retries the supervisor performed
    /// for this engine (engine lifetime; the coordinator differences
    /// per-stage deltas).
    pub retries: u64,
    /// Real bytes of KV resident after this step: `kv_blocks` ×
    /// [`super::kvcache::KvCacheConfig::block_bytes`] at the engine's KV
    /// dtype — what `kv_budget_blocks` maps to in memory.
    pub kv_bytes: usize,
    /// The sampler SIMD arm this engine decodes with
    /// ([`super::SamplerDispatch::name`]: "scalar" / "avx2" / "avx512").
    pub sampler_dispatch: &'static str,
    /// Work items still waiting for admission after this step — the
    /// engine-local queue-depth gauge the open-loop SLO harness folds
    /// into its backpressure accounting.
    pub queued: usize,
}

/// Events flowing from engine threads back to the coordinator.
#[derive(Clone, Debug)]
pub enum EngineEvent {
    /// A slot finished (terminal, preempted, or flushed).
    Done {
        /// Engine id that produced the result.
        engine: usize,
        /// The slot's output.
        result: WorkResult,
    },
    /// Per-step utilization sample.
    Trace(StepTrace),
    /// All slots flushed after StopGeneration.
    Flushed {
        /// Engine id that finished flushing.
        engine: usize,
        /// Backend `retain_slot` errors swallowed during this flush (the
        /// affected slots flushed plainly; the coordinator accounts them
        /// in `RolloutStats::retain_errors`).
        retain_errors: u64,
    },
    /// Engine thread exited.
    ShutDown {
        /// Engine id that shut down.
        engine: usize,
    },
    /// The engine thread failed — a backend error that survived the
    /// transient-retry budget, a panic caught by the supervisor, or a
    /// backend that never initialized — and is shutting down. Carries
    /// everything the coordinator needs to recover: the request ids still
    /// in flight on the engine (busy slots plus the unstarted admission
    /// queue; their generation since dispatch is lost) and the ids whose
    /// KV was retained there (their affinity hints are now stale).
    EngineFailed {
        /// Engine id that failed.
        engine: usize,
        /// Human-readable failure cause (error chain or panic payload).
        error: String,
        /// Request ids whose work died with the engine.
        inflight: Vec<u64>,
        /// Request ids whose retained KV died with the engine.
        retained: Vec<u64>,
    },
    /// A retained slot was dropped (budget/admission eviction or explicit
    /// release) — the coordinator clears its affinity entry so future
    /// resumes of that request dispatch by load instead of affinity.
    RetainedDropped {
        /// Engine id that dropped the retained slot.
        engine: usize,
        /// Request whose retained KV is gone.
        request_id: u64,
    },
    /// One step's events delivered in a single channel send (see
    /// `pool::flush`); the coordinator unpacks in `handle_event`.
    Batch(Vec<EngineEvent>),
}

/// Commands from the coordinator (used by the threaded pool).
pub enum EngineCmd {
    /// Queue a work item for admission.
    Assign(WorkItem),
    /// Weight sync: install a new parameter vector.
    SetParams {
        /// Policy version the params correspond to (trainer step).
        version: u64,
        /// The full parameter vector (shared across engines).
        params: std::sync::Arc<Vec<f32>>,
        /// Drop all retained KV (and the shared-prefix registry) first:
        /// retained prefixes were computed under the OLD params, so unless
        /// the coordinator explicitly opts into stale-KV continuation
        /// (`rollout.retain_kv_across_sync`) they must not survive the
        /// sync.
        invalidate_retained: bool,
    },
    /// Early termination: flush every busy slot as a partial; when `retain`
    /// is set, leave each flushed slot's KV resident for affinity resume.
    StopGeneration {
        /// Retain flushed slots' KV (see [`Engine::stop_generation`]).
        retain: bool,
    },
    /// Early-terminate ONE in-flight request as a partial, leaving every
    /// other slot decoding (fully-async staleness enforcement / active
    /// partial rollout — see [`Engine::stop_request`]). Unknown ids are
    /// ignored: the request may have finished (its `Done` is already in
    /// flight toward the coordinator) or died with a failed engine.
    StopRequest {
        /// The [`WorkItem::request_id`] to flush.
        request_id: u64,
        /// Retain the flushed slot's KV (same semantics as
        /// [`EngineCmd::StopGeneration`]).
        retain: bool,
    },
    /// Drop one retained slot (the coordinator decided the partial will
    /// resume elsewhere, or never).
    ReleaseRetained {
        /// Request whose retained slot should be freed.
        request_id: u64,
        /// Retention token (stale tokens are ignored).
        token: u64,
    },
    /// Release one shared-prefix registry entry (the coordinator observed
    /// the group complete — no more samples will attach it). Unknown keys
    /// are ignored: the engine may have pressure-evicted the entry already.
    ReleasePrefix {
        /// The [`WorkItem::prefix`] handle whose registry entry to free.
        key: u64,
    },
    /// Terminate the engine thread.
    Shutdown,
}

struct BusySlot {
    item: WorkItem,
    generated: Vec<i32>,
    logprobs: Vec<f32>,
    /// Resume tokens fed so far (mechanical replay cursor; starts at
    /// `resume.len()` for retained-KV resumes, which feed nothing).
    replay_fed: usize,
    /// Resume tokens actually recomputed this assignment (the true replay
    /// cost — 0 for retained-KV resumes).
    replayed: usize,
    /// This assignment began from a retained slot (metrics).
    resumed_from_kv: bool,
    /// Token to feed at the next decode step, at position `pos`. During
    /// chunked ingestion, `pos` is the backend's next WRITE position
    /// instead (0 mid-prompt — the prefill launch rewrites `[0, plen)` —
    /// then `plen + replay_fed` while slicing replay).
    next_token: i32,
    pos: i32,
    /// KV block chain covering the slot's resident tokens: exactly
    /// `pos + 1` tokens once decoding, the ingested span while a chunked
    /// prefill is still in flight (per-chunk block charging).
    pages: PageTable,
    /// Admission order (LIFO preemption victim selection, like vLLM).
    admitted_seq: u64,
    /// Prompt tokens fed to the backend so far. Legacy (unchunked)
    /// admission ingests the whole prompt synchronously, so this equals
    /// `prompt.len()` from the start; under continuous batching it
    /// advances one budgeted chunk at a time.
    prompt_fed: usize,
    /// Resume replay is still being (or about to be) slice-fed through
    /// `Backend::replay` by the chunked scheduler. Cleared when the
    /// backend declines a slice (the slot then rides per-token decode
    /// replay exactly like the legacy path) or when replay completes.
    slice_replay: bool,
}

impl BusySlot {
    fn plen(&self) -> usize {
        self.item.prompt.len()
    }

    /// Still ingesting (prompt chunks or replay slices pending) — not yet
    /// decode-eligible. Always false in legacy (unchunked) mode.
    fn ingesting(&self) -> bool {
        self.prompt_fed < self.item.prompt.len() || self.slice_replay
    }
}

/// Ledger entry for a flushed slot whose KV stayed resident. Everything a
/// later resume needs to continue decoding without replay: the pending
/// next-token feed and its position, the retained block chain, plus the
/// validation triple (request id, token, generated length) the resume item
/// must match.
struct RetainedSlot {
    request_id: u64,
    /// Monotonic retention token; the coordinator must echo it in
    /// [`WorkItem::retain`] (guards against slot reuse between stop and
    /// resume).
    token: u64,
    /// Pending feed position (the KV holds positions `0..pos`).
    pos: i32,
    /// Last sampled token — not yet fed; the resume's first decode feeds
    /// it at `pos`, exactly where the busy slot left off.
    next_token: i32,
    /// Total generated tokens at flush time (`resume.len() + new`); a
    /// resume item must present exactly this many resume tokens.
    generated_len: usize,
    /// The retained KV's block chain — still charged against the budget,
    /// but shared prefix blocks cost nothing extra while siblings (or the
    /// registry) keep them live.
    pages: PageTable,
    /// Original admission order (LIFO eviction among retained slots).
    admitted_seq: u64,
}

enum SlotState {
    Idle,
    Busy(Box<BusySlot>),
    Retained(RetainedSlot),
}

/// One inference engine: S decode slots over a [`Backend`], an admission
/// queue, paged KV-budget enforcement, the shared-prefix registry, and the
/// retention ledger.
pub struct Engine<B: Backend> {
    /// Engine id (stamped on every event).
    pub id: usize,
    backend: B,
    slots: Vec<SlotState>,
    pending: VecDeque<WorkItem>,
    rng: Rng,
    /// Paged-KV configuration: block size, blocks-denominated budget
    /// (0 = unlimited), prefix sharing.
    kv_cfg: KvCacheConfig,
    /// The block arena every page table and registry entry draws from.
    /// Unbounded (budget is enforced by eviction, matching the old soft
    /// token-budget semantics) and pre-reserved for the slot horizon so
    /// steady-state decode never allocates.
    kv: BlockAllocator,
    /// Shared prompt-prefix registry (see [`WorkItem::prefix`]).
    prefix_cache: PrefixCache,
    /// Cumulative prompt tokens attached from a shared prefix instead of
    /// freshly charged.
    pub prefix_tokens_shared: u64,
    admission_counter: u64,
    retain_counter: u64,
    preemptions: u64,
    t0: Instant,
    /// Per-step token budget for continuous batching: each engine step
    /// packs one decode token per running sequence plus chunked-prefill /
    /// replay slices of admitted work, up to this many tokens. 0 = legacy
    /// slot admission (whole-prompt prefill at admission — the baseline
    /// arm `benches/continuous_batching.rs` compares against).
    step_budget: usize,
    /// Cumulative chunked-ingestion backend calls (prompt chunks + replay
    /// slices) — 0 in legacy mode.
    pub prefill_chunks: u64,
    /// Cumulative seconds of prefill/replay-chunk compute that ran while
    /// live decode lanes also made progress this step — the stall the
    /// legacy design would have imposed on those co-resident decodes by
    /// prefilling whole prompts at admission.
    pub prefill_stall_saved: f64,
    /// Cumulative decode steps (cost accounting).
    pub decode_steps: u64,
    /// Cumulative replayed (recomputed) tokens.
    pub replayed_tokens: u64,
    /// Cumulative resumes served from retained KV (affinity hits).
    pub retained_resumes: u64,
    /// Cumulative retained-slot drops (budget/admission eviction, release,
    /// weight-sync invalidation).
    pub retained_evictions: u64,
    /// Cumulative transient-backend-error retries (incremented by the pool
    /// supervisor between attempts; reported through [`StepTrace`]).
    pub retries: u64,
    /// Cumulative backend `retain_slot` errors (each flushed its slot
    /// plainly instead of retaining; see [`Engine::stop_generation`]).
    pub retain_errors: u64,
    // -- incremental bookkeeping (invariants maintained by occupy/vacate) --
    /// Busy slot count (== slots.iter().filter(Busy).count()).
    busy_count: usize,
    /// Retained slot count (== slots.iter().filter(Retained).count()).
    retained_count: usize,
    /// KV tokens resident (== Σ busy (pos + 1) + Σ retained (pos + 1) ==
    /// Σ page-table tokens; shared blocks count per sequence here).
    kv_resident: usize,
    // -- persistent step scratch (no per-step heap allocation) --------------
    step_tokens: Vec<i32>,
    step_pos: Vec<i32>,
    /// Decode-lane membership snapshot for the current step (slots that
    /// were caught up when the step was assembled; slots finishing
    /// ingestion mid-step start decoding next step).
    step_lane: Vec<bool>,
    /// FIFO scratch for the ingestion pump: (admitted_seq, slot).
    ingest_scratch: Vec<(u64, usize)>,
    /// Reusable copy of the slot-under-pump's resume tokens, so backend
    /// replay calls can borrow them while the slot table stays untouched
    /// (`b.item.resume` is never moved out — an error mid-pump cannot
    /// corrupt slot state).
    resume_scratch: Vec<i32>,
    logits_buf: Vec<f32>,
    scratch: SamplerScratch,
    /// The sampler SIMD arm, detected once at construction (CPU features ∩
    /// the `COPRIS_SIMD` override) — every sample call this engine makes
    /// goes through it. Bit-identical to scalar by contract (see
    /// [`super::simd`]).
    dispatch: SamplerDispatch,
}

/// Engine scheduling + KV options bundle ([`Engine::with_opts`] /
/// `EnginePool::spawn_opts`): the paged-KV configuration plus the
/// continuous-batching step-token budget.
#[derive(Clone, Copy, Debug)]
pub struct EngineOpts {
    /// Paged-KV configuration (block size, blocks budget, prefix sharing).
    pub kv: KvCacheConfig,
    /// Per-step token budget for continuous batching with chunked prefill
    /// (0 = legacy slot admission). See `EngineConfig::step_token_budget`.
    pub step_token_budget: usize,
}

impl<B: Backend> Engine<B> {
    /// Back-compat constructor: a TOKEN-denominated budget (0 = unlimited)
    /// converted to blocks of [`DEFAULT_BLOCK_SIZE`] via
    /// [`KvCacheConfig::from_token_budget`]. New call sites should use
    /// [`Engine::with_kv`].
    pub fn new(id: usize, backend: B, kv_budget_tokens: usize, seed: u64) -> Engine<B> {
        Self::with_kv(
            id,
            backend,
            KvCacheConfig::from_token_budget(kv_budget_tokens, DEFAULT_BLOCK_SIZE),
            seed,
        )
    }

    /// Build an engine with an explicit paged-KV configuration and a
    /// per-engine-derived RNG seed (legacy slot admission; see
    /// [`Engine::with_opts`] for the continuous-batching scheduler).
    pub fn with_kv(id: usize, backend: B, kv_cfg: KvCacheConfig, seed: u64) -> Engine<B> {
        Self::with_opts(id, backend, EngineOpts { kv: kv_cfg, step_token_budget: 0 }, seed)
    }

    /// Build an engine with full scheduling options: paged-KV config plus
    /// the continuous-batching step-token budget.
    pub fn with_opts(id: usize, backend: B, opts: EngineOpts, seed: u64) -> Engine<B> {
        let kv_cfg = opts.kv;
        let mut backend = backend;
        // Stage the KV dtype before any prefill; the narrow-dtype budget
        // multiplier itself is enforced engine-side (effective_budget_blocks).
        backend.set_kv_dtype(kv_cfg.dtype);
        let s = backend.slots();
        let mut slots = Vec::with_capacity(s);
        for _ in 0..s {
            slots.push(SlotState::Idle);
        }
        let mut kv = BlockAllocator::new(kv_cfg.block_size, 0);
        // Pre-reserve the full slot horizon plus registry slack so block
        // allocation on the decode hot path never grows the arena.
        let per_slot = backend.max_seq().div_ceil(kv_cfg.block_size) + 1;
        kv.reserve_arena(s * (per_slot + 2));
        Engine {
            id,
            backend,
            slots,
            pending: VecDeque::new(),
            rng: Rng::new(seed ^ (id as u64).wrapping_mul(0x9E3779B97F4A7C15)),
            kv_cfg,
            kv,
            prefix_cache: PrefixCache::new(),
            prefix_tokens_shared: 0,
            admission_counter: 0,
            retain_counter: 0,
            preemptions: 0,
            t0: Instant::now(),
            step_budget: opts.step_token_budget,
            prefill_chunks: 0,
            prefill_stall_saved: 0.0,
            decode_steps: 0,
            replayed_tokens: 0,
            retained_resumes: 0,
            retained_evictions: 0,
            retries: 0,
            retain_errors: 0,
            busy_count: 0,
            retained_count: 0,
            kv_resident: 0,
            step_tokens: vec![0; s],
            step_pos: vec![0; s],
            step_lane: vec![false; s],
            ingest_scratch: Vec::with_capacity(s),
            resume_scratch: Vec::new(),
            logits_buf: Vec::new(),
            scratch: SamplerScratch::new(),
            dispatch: SamplerDispatch::detect(),
        }
    }

    /// The continuous-batching step-token budget (0 = legacy slot
    /// admission).
    pub fn step_token_budget(&self) -> usize {
        self.step_budget
    }

    /// The generation backend (test inspection).
    pub fn backend(&self) -> &B {
        &self.backend
    }

    /// Actively decoding slots (O(1) counter).
    pub fn busy(&self) -> usize {
        self.busy_count
    }

    /// Slots holding retained KV for flushed partials (O(1) counter).
    pub fn retained(&self) -> usize {
        self.retained_count
    }

    /// Work items waiting for admission.
    pub fn queued(&self) -> usize {
        self.pending.len()
    }

    /// Slots neither busy nor retained.
    pub fn free_slots(&self) -> usize {
        self.slots.len() - self.busy_count - self.retained_count
    }

    /// Is there anything to decode or admit? (Retained slots alone are not
    /// work — the engine idles on its command channel with KV parked.)
    pub fn has_work(&self) -> bool {
        self.busy_count > 0 || !self.pending.is_empty()
    }

    /// Cumulative live-slot preemptions.
    pub fn preemptions(&self) -> u64 {
        self.preemptions
    }

    /// Tokens resident in the KV cache across busy AND retained slots
    /// (O(1) counter; the logical per-sequence view — shared prompt
    /// prefixes count once per sequence).
    pub fn kv_tokens(&self) -> usize {
        self.kv_resident
    }

    /// KV blocks in use (live + retained + prefix registry; shared blocks
    /// count once — the physical residency the budget governs).
    pub fn kv_blocks(&self) -> usize {
        self.kv.blocks_in_use()
    }

    /// Cumulative copy-on-write block copies.
    pub fn cow_copies(&self) -> u64 {
        self.kv.cow_copies()
    }

    /// Tokens per KV block.
    pub fn kv_block_size(&self) -> usize {
        self.kv_cfg.block_size
    }

    /// KV budget in blocks (0 = unlimited), as configured —
    /// f32-denominated; see [`Engine::kv_effective_budget_blocks`] for
    /// what is actually enforced under a narrow KV dtype.
    pub fn kv_budget_blocks(&self) -> usize {
        self.kv_cfg.budget_blocks
    }

    /// The block budget actually enforced: the configured budget scaled by
    /// the KV dtype's capacity multiplier (f16 2×, int8 4×; 0 stays
    /// unlimited).
    pub fn kv_effective_budget_blocks(&self) -> usize {
        self.kv_cfg.effective_budget_blocks()
    }

    /// The KV storage dtype this engine runs with.
    pub fn kv_dtype(&self) -> KvDtype {
        self.kv_cfg.dtype
    }

    /// Real bytes of KV currently resident: blocks in use × per-block
    /// bytes at the configured dtype (incl. int8 scale metadata).
    pub fn kv_bytes(&self) -> usize {
        self.kv.blocks_in_use() * self.kv_cfg.block_bytes()
    }

    /// The sampler SIMD arm this engine decodes with ("scalar" / "avx2" /
    /// "avx512").
    pub fn sampler_dispatch(&self) -> SamplerDispatch {
        self.dispatch
    }

    /// Live shared-prefix registry entries (test inspection).
    pub fn prefix_entries(&self) -> usize {
        self.prefix_cache.len()
    }

    /// Per-busy-slot generation progress: `(request_id, tokens generated
    /// under the current assignment)`, replayed resume tokens excluded.
    /// The lockstep SLO harness diffs consecutive snapshots to timestamp
    /// token emission on its virtual clock (at most one new token per
    /// decode lane per step).
    pub fn slot_progress(&self) -> Vec<(u64, usize)> {
        self.slots
            .iter()
            .filter_map(|s| match s {
                SlotState::Busy(b) => Some((b.item.request_id, b.generated.len())),
                _ => None,
            })
            .collect()
    }

    /// Install `b` into slot `i`, maintaining the incremental counters.
    /// Residency is charged from the page table: `pos + 1` tokens for a
    /// decoding slot, the ingested span while chunked prefill is in
    /// flight.
    fn occupy(&mut self, i: usize, b: Box<BusySlot>) {
        debug_assert!(matches!(self.slots[i], SlotState::Idle));
        debug_assert!(
            b.ingesting() || b.pages.tokens() == b.pos as usize + 1,
            "page/pos drift"
        );
        self.busy_count += 1;
        self.kv_resident += b.pages.tokens();
        self.slots[i] = SlotState::Busy(b);
    }

    /// Clear a busy slot `i`, maintaining the incremental counters. The
    /// returned slot still owns its block chain — the caller either frees
    /// it ([`Engine::free_slot_kv`]) or moves it into a retained ledger
    /// entry.
    fn vacate(&mut self, i: usize) -> Option<Box<BusySlot>> {
        match std::mem::replace(&mut self.slots[i], SlotState::Idle) {
            SlotState::Busy(b) => {
                self.busy_count -= 1;
                self.kv_resident -= b.pages.tokens();
                Some(b)
            }
            other => {
                self.slots[i] = other;
                None
            }
        }
    }

    /// Release a vacated slot's block chain and reset the backend-side
    /// block table for slot `i`.
    fn free_slot_kv(&mut self, i: usize, pages: &mut PageTable) {
        pages.release_all(&mut self.kv);
        let _ = self.backend.set_block_table(i, &[], 0, self.kv_cfg.block_size);
    }

    /// Un-admit after a backend error mid-admission: release whatever
    /// blocks the aborted admission charged, clear the backend's slot
    /// mapping, and put the item back at the queue head — a supervisor
    /// retry (transient) or the failure snapshot (fatal) must still see
    /// the request, never silently drop it. The admission counter is
    /// rewound so a retried admission gets the same sequence number
    /// (bit-exact transient recovery).
    fn unadmit(&mut self, i: usize, mut pages: PageTable, item: WorkItem) {
        pages.release_all(&mut self.kv);
        let _ = self.backend.set_block_table(i, &[], 0, self.kv_cfg.block_size);
        self.admission_counter -= 1;
        self.pending.push_front(item);
    }

    /// Drop retained slot `i` back to Idle, releasing its block refs (only
    /// refs that drop to zero actually free residency — a retained partial
    /// whose prefix is still live costs near nothing to evict) and telling
    /// the coordinator (so stale affinity entries get cleared).
    fn drop_retained_slot(&mut self, i: usize, events: &mut Vec<EngineEvent>) {
        let SlotState::Retained(_) = self.slots[i] else { return };
        let SlotState::Retained(mut rs) = std::mem::replace(&mut self.slots[i], SlotState::Idle)
        else {
            unreachable!()
        };
        self.retained_count -= 1;
        self.kv_resident -= rs.pages.tokens();
        self.retained_evictions += 1;
        self.free_slot_kv(i, &mut rs.pages);
        let _ = self.backend.release_retained(i);
        events.push(EngineEvent::RetainedDropped { engine: self.id, request_id: rs.request_id });
    }

    /// Drop ALL retained slots and the shared-prefix registry (weight-sync
    /// invalidation: every retained prefix was computed under the old
    /// params).
    pub fn invalidate_retained(&mut self, events: &mut Vec<EngineEvent>) {
        for i in 0..self.slots.len() {
            if matches!(self.slots[i], SlotState::Retained(_)) {
                self.drop_retained_slot(i, events);
            }
        }
        self.prefix_cache.clear(&mut self.kv);
    }

    /// Release one shared-prefix registry entry (coordinator observed the
    /// group complete). Unknown keys are ignored.
    pub fn release_prefix(&mut self, key: u64) {
        self.prefix_cache.remove(key, &mut self.kv);
    }

    /// Explicit coordinator-side release of one retained slot (the partial
    /// is resuming on another engine, or was evicted from the buffer).
    /// Stale (request, token) pairs are ignored.
    pub fn release_retained_request(
        &mut self,
        request_id: u64,
        token: u64,
        events: &mut Vec<EngineEvent>,
    ) {
        let found = self.slots.iter().position(|s| {
            matches!(s, SlotState::Retained(rs)
                if rs.request_id == request_id && rs.token == token)
        });
        if let Some(i) = found {
            self.drop_retained_slot(i, events);
        }
    }

    /// Queue a work item (admitted to a slot on the next step).
    pub fn submit(&mut self, item: WorkItem) -> Result<()> {
        ensure!(!item.prompt.is_empty(), "empty prompt");
        ensure!(item.prompt.len() <= self.backend.p_max(), "prompt exceeds p_max");
        ensure!(item.max_total <= self.backend.max_seq(), "max_total exceeds horizon");
        self.pending.push_back(item);
        Ok(())
    }

    /// Weight sync.
    pub fn set_params(&mut self, params: &[f32]) -> Result<()> {
        self.backend.set_params(params)
    }

    /// Early termination: flush every busy slot as a partial and drop the
    /// admission queue back to the caller (unstarted items are NOT partial
    /// trajectories — the coordinator re-queues them as fresh work).
    ///
    /// With `retain`, a flushed slot that is fully caught up (its replay —
    /// if any — finished and it generated at least one token) keeps its KV
    /// block chain resident as `SlotState::Retained`; its `Stopped` result
    /// carries the retention token ([`WorkResult::retained`]). Slots
    /// stopped mid-replay flush plainly — their KV covers only part of the
    /// resume prefix, which the simple (token, length) validation cannot
    /// describe.
    pub fn stop_generation(
        &mut self,
        events: &mut Vec<EngineEvent>,
        retain: bool,
    ) -> Vec<WorkItem> {
        let mut flush_retain_errors = 0u64;
        for i in 0..self.slots.len() {
            // All busy/kv counter maintenance goes through vacate(); the
            // retain branch re-installs the identical KV charge below.
            let Some(mut b) = self.vacate(i) else { continue };
            let caught_up = b.replay_fed >= b.item.resume.len() && !b.generated.is_empty();
            // A retain_slot error is not a flush failure — the slot just
            // loses the fast path and flushes plainly (its resume replays).
            // But it is not silently dropped either: counted per flush and
            // cumulatively, and warned once per occurrence.
            let can_retain = retain
                && caught_up
                && match self.backend.retain_slot(i) {
                    Ok(ok) => ok,
                    Err(e) => {
                        flush_retain_errors += 1;
                        self.retain_errors += 1;
                        eprintln!(
                            "engine-{}: retain_slot({i}) failed, flushing plainly: {e:#}",
                            self.id
                        );
                        false
                    }
                };
            if can_retain {
                self.retain_counter += 1;
                let token = self.retain_counter;
                let rs = RetainedSlot {
                    request_id: b.item.request_id,
                    token,
                    pos: b.pos,
                    next_token: b.next_token,
                    generated_len: b.item.resume.len() + b.generated.len(),
                    pages: std::mem::take(&mut b.pages),
                    admitted_seq: b.admitted_seq,
                };
                // The retained slot keeps the vacated slot's exact KV
                // residency (tokens AND block refs) charged against the
                // budget.
                self.retained_count += 1;
                self.kv_resident += rs.pages.tokens();
                let mut result = finish(*b, FinishReason::Stopped);
                result.retained = Some(token);
                events.push(EngineEvent::Done { engine: self.id, result });
                self.slots[i] = SlotState::Retained(rs);
            } else {
                self.free_slot_kv(i, &mut b.pages);
                events.push(EngineEvent::Done {
                    engine: self.id,
                    result: finish(*b, FinishReason::Stopped),
                });
            }
        }
        let unstarted: Vec<WorkItem> = self.pending.drain(..).collect();
        events
            .push(EngineEvent::Flushed { engine: self.id, retain_errors: flush_retain_errors });
        unstarted
    }

    /// Early-terminate ONE request (fully-async staleness enforcement and
    /// APRIL-style active partial rollout pick individual victims while the
    /// rest of the batch keeps decoding — the surgical sibling of
    /// [`Engine::stop_generation`]).
    ///
    /// Three cases, all closed by exactly one `Done` per known id:
    /// * busy slot → flushed as a `Stopped` partial, with the same
    ///   retain-if-caught-up rule as a full flush;
    /// * still queued (never admitted) → removed from the admission queue
    ///   and answered with an EMPTY `Stopped` result, so the coordinator's
    ///   wait-for-cut loop terminates without special-casing unstarted
    ///   work (an empty partial re-buffers as a zero-progress resume);
    /// * unknown → no-op (the request raced its own completion or failure
    ///   recovery moved it to another engine).
    ///
    /// No `Flushed` event is emitted: that event means "every slot on this
    /// engine is now idle", which a single-request stop does not establish.
    pub fn stop_request(
        &mut self,
        events: &mut Vec<EngineEvent>,
        request_id: u64,
        retain: bool,
    ) {
        let busy = self.slots.iter().position(|s| {
            matches!(s, SlotState::Busy(b) if b.item.request_id == request_id)
        });
        if let Some(i) = busy {
            let Some(mut b) = self.vacate(i) else { return };
            let caught_up = b.replay_fed >= b.item.resume.len() && !b.generated.is_empty();
            let can_retain = retain
                && caught_up
                && match self.backend.retain_slot(i) {
                    Ok(ok) => ok,
                    Err(e) => {
                        self.retain_errors += 1;
                        eprintln!(
                            "engine-{}: retain_slot({i}) failed, flushing plainly: {e:#}",
                            self.id
                        );
                        false
                    }
                };
            if can_retain {
                self.retain_counter += 1;
                let token = self.retain_counter;
                let rs = RetainedSlot {
                    request_id: b.item.request_id,
                    token,
                    pos: b.pos,
                    next_token: b.next_token,
                    generated_len: b.item.resume.len() + b.generated.len(),
                    pages: std::mem::take(&mut b.pages),
                    admitted_seq: b.admitted_seq,
                };
                self.retained_count += 1;
                self.kv_resident += rs.pages.tokens();
                let mut result = finish(*b, FinishReason::Stopped);
                result.retained = Some(token);
                events.push(EngineEvent::Done { engine: self.id, result });
                self.slots[i] = SlotState::Retained(rs);
            } else {
                self.free_slot_kv(i, &mut b.pages);
                events.push(EngineEvent::Done {
                    engine: self.id,
                    result: finish(*b, FinishReason::Stopped),
                });
            }
            return;
        }
        // Never admitted: drop from the queue and answer with an empty
        // Stopped result so the coordinator's cut bookkeeping closes.
        if let Some(qi) = self.pending.iter().position(|w| w.request_id == request_id) {
            let item = self.pending.remove(qi).expect("position just found");
            events.push(EngineEvent::Done {
                engine: self.id,
                result: WorkResult {
                    request_id: item.request_id,
                    new_tokens: Vec::new(),
                    new_logprobs: Vec::new(),
                    reason: FinishReason::Stopped,
                    replayed: 0,
                    retained: None,
                    resumed_from_kv: false,
                },
            });
        }
    }

    /// Request ids whose work would be lost if this engine died right now:
    /// every busy slot (including mid-ingestion) plus the unstarted
    /// admission queue. The supervisor snapshots this into
    /// [`EngineEvent::EngineFailed`] so the coordinator can re-dispatch.
    pub fn inflight_request_ids(&self) -> Vec<u64> {
        let mut ids: Vec<u64> = self
            .slots
            .iter()
            .filter_map(|s| match s {
                SlotState::Busy(b) => Some(b.item.request_id),
                _ => None,
            })
            .collect();
        ids.extend(self.pending.iter().map(|w| w.request_id));
        ids.sort_unstable();
        ids
    }

    /// Request ids whose KV is retained on this engine (affinity hints the
    /// coordinator must drop when the engine fails).
    pub fn retained_request_ids(&self) -> Vec<u64> {
        let mut ids: Vec<u64> = self
            .slots
            .iter()
            .filter_map(|s| match s {
                SlotState::Retained(rs) => Some(rs.request_id),
                _ => None,
            })
            .collect();
        ids.sort_unstable();
        ids
    }

    /// One scheduler iteration: admit pending work, enforce the KV budget,
    /// run one packed step — a decode token for every caught-up sequence,
    /// plus (under a step-token budget) chunked prefill and replay slices
    /// for mid-ingestion slots — and process sampled tokens. Steady state
    /// (all slots mid-generation) performs no heap allocation in
    /// engine/sampler code.
    pub fn step(&mut self, events: &mut Vec<EngineEvent>) -> Result<()> {
        self.admit(events)?;
        self.enforce_kv_budget(events);
        if self.busy_count == 0 {
            return Ok(());
        }

        let s = self.slots.len();
        let v = self.backend.vocab();
        let bs = self.kv_cfg.block_size;
        // -- assemble the packed step: decode lanes ------------------------
        // Lane membership is snapshotted BEFORE the ingestion pump runs: a
        // slot whose ingestion completes this step samples its first token
        // from the chunk logits and starts decoding NEXT step — the same
        // step boundary legacy admission has between its prefill-time
        // sample and the first decode feed.
        let mut decode_lanes = 0usize;
        for (i, slot) in self.slots.iter().enumerate() {
            match slot {
                SlotState::Busy(b) if !b.ingesting() => {
                    self.step_tokens[i] = b.next_token;
                    self.step_pos[i] = b.pos;
                    self.step_lane[i] = true;
                    decode_lanes += 1;
                }
                SlotState::Busy(b) => {
                    // Mid-ingestion: park the lane at the backend's next
                    // write position — the next prefill-chunk / replay
                    // launch overwrites whatever the lockstep decode put
                    // there before it is ever attended.
                    self.step_tokens[i] = 0;
                    self.step_pos[i] = b.pos;
                    self.step_lane[i] = false;
                }
                SlotState::Idle => {
                    self.step_tokens[i] = 0;
                    self.step_pos[i] = 0;
                    self.step_lane[i] = false;
                }
                SlotState::Retained(rs) => {
                    // Park the lane on the pending feed position: whatever
                    // the lockstep decode writes there is overwritten by
                    // the resume's first real feed before it is ever
                    // attended (see `Backend::retain_slot`'s contract).
                    self.step_tokens[i] = 0;
                    self.step_pos[i] = rs.pos;
                    self.step_lane[i] = false;
                }
            }
        }

        let t_step = Instant::now();
        let mut dur = 0.0;
        if decode_lanes > 0 {
            self.backend.decode_into(&self.step_tokens, &self.step_pos, &mut self.logits_buf)?;
            dur = t_step.elapsed().as_secs_f64();
            self.decode_steps += 1;

            for i in 0..s {
                if !self.step_lane[i] {
                    continue;
                }
                let SlotState::Busy(b) = &mut self.slots[i] else { continue };
                b.pos += 1;
                self.kv_resident += 1;
                // Charge the new position's block: a fresh block at a
                // boundary, a COW copy when the tail is shared — either
                // re-installs the backend block table; the common
                // within-block case is free.
                let changed = b
                    .pages
                    .append_one(&mut self.kv)
                    .expect("engine block arena is unbounded");
                if changed {
                    self.backend.set_block_table(i, b.pages.block_ids(), b.pages.tokens(), bs)?;
                }
                if b.replay_fed < b.item.resume.len() {
                    // We just fed resume[replay_fed]; keep replaying.
                    b.replay_fed += 1;
                    b.replayed += 1;
                    self.replayed_tokens += 1;
                    if b.replay_fed < b.item.resume.len() {
                        b.next_token = b.item.resume[b.replay_fed];
                        continue;
                    }
                    // Replay complete: this step's logits sample the first
                    // new token (fall through).
                }
                let row = &self.logits_buf[i * v..(i + 1) * v];
                let (tok, lp) = sample_token_dispatched(
                    row,
                    &b.item.sampling,
                    &mut self.rng,
                    &mut self.scratch,
                    self.dispatch,
                );
                b.generated.push(tok);
                b.logprobs.push(lp);
                let total_len = b.item.prompt.len() + b.item.resume.len() + b.generated.len();
                let reason = if tok == tokenizer::EOS {
                    Some(FinishReason::Eos)
                } else if total_len >= b.item.max_total {
                    Some(FinishReason::LengthCap)
                } else {
                    None
                };
                match reason {
                    Some(r) => {
                        let mut b = self.vacate(i).expect("busy slot");
                        self.free_slot_kv(i, &mut b.pages);
                        events.push(EngineEvent::Done { engine: self.id, result: finish(*b, r) });
                    }
                    None => b.next_token = tok,
                }
            }
        }

        // -- chunked ingestion: spend the budget's remainder ---------------
        // Runs AFTER the decode so a slot finishing ingestion here is not
        // double-advanced by this step's lockstep decode (it was parked in
        // the lane snapshot above). Decode lanes take budget priority: a
        // running sequence always gets its token; prefill waits.
        let mut step_tokens_done = decode_lanes;
        if self.step_budget > 0 {
            let mut budget_left = self.step_budget.saturating_sub(decode_lanes);
            self.pump_ingestion(
                &mut budget_left,
                &mut step_tokens_done,
                decode_lanes > 0,
                events,
            )?;
        }

        // Per-sequence block-chain total (shared blocks count per chain)
        // for the fragmentation gauge — scanned AFTER the processing loop
        // so it is consistent with `kv_resident` at trace time (a slot
        // that finished this step contributes to neither).
        let mut page_blocks = 0usize;
        for slot in &self.slots {
            match slot {
                SlotState::Busy(b) => page_blocks += b.pages.num_blocks(),
                SlotState::Retained(rs) => page_blocks += rs.pages.num_blocks(),
                SlotState::Idle => {}
            }
        }
        let kv_frag = if page_blocks == 0 {
            0.0
        } else {
            (1.0 - self.kv_resident as f64 / (page_blocks * bs) as f64).max(0.0)
        };
        events.push(EngineEvent::Trace(StepTrace {
            engine: self.id,
            t_wall: self.t0.elapsed().as_secs_f64(),
            dur,
            active: self.busy_count,
            slots: s,
            kv_tokens: self.kv_resident,
            kv_blocks: self.kv.blocks_in_use(),
            kv_frag,
            prefix_tokens_shared: self.prefix_tokens_shared,
            cow_copies: self.kv.cow_copies(),
            preemptions: self.preemptions,
            step_tokens: step_tokens_done,
            step_budget: self.step_budget,
            prefill_chunks: self.prefill_chunks,
            prefill_stall_saved: self.prefill_stall_saved,
            retries: self.retries,
            kv_bytes: self.kv.blocks_in_use() * self.kv_cfg.block_bytes(),
            sampler_dispatch: self.dispatch.name(),
            queued: self.pending.len(),
        }));
        Ok(())
    }

    /// Grow slot `i`'s chain to cover `tokens` resident tokens (per-chunk
    /// block charging), maintaining the incremental KV counter and
    /// re-installing the backend block table when the chain changed (a
    /// fresh block, or a COW replacement of a shared partial tail). No-op
    /// when the chain already covers `tokens` — e.g. chunks landing inside
    /// an attached shared prompt prefix.
    fn charge_ingested(&mut self, i: usize, tokens: usize) -> Result<()> {
        let bs = self.kv_cfg.block_size;
        let SlotState::Busy(b) = &mut self.slots[i] else { return Ok(()) };
        let before_tokens = b.pages.tokens();
        if before_tokens >= tokens {
            return Ok(());
        }
        let before_blocks = b.pages.num_blocks();
        let before_last = b.pages.block_ids().last().copied();
        b.pages.grow_to(tokens, &mut self.kv).expect("engine block arena is unbounded");
        self.kv_resident += b.pages.tokens() - before_tokens;
        let changed = b.pages.num_blocks() != before_blocks
            || b.pages.block_ids().last().copied() != before_last;
        if changed {
            self.backend.set_block_table(i, b.pages.block_ids(), b.pages.tokens(), bs)?;
        }
        Ok(())
    }

    /// The chunked-ingestion pump: spend up to `budget_left` step-budget
    /// tokens feeding prompt chunks ([`Backend::prefill_chunk`]) and
    /// resume-replay slices ([`Backend::replay`]) to mid-ingestion slots,
    /// FIFO by admission order. A slot whose prompt completes with no
    /// resume pending samples its first token from the chunk logits (and
    /// may finish outright on EOS / length cap); a resume whose backend
    /// declines slicing falls back to per-token decode replay, exactly
    /// like the legacy path. `overlapped` notes whether live decode lanes
    /// also ran this step — chunk compute that ran alongside them is
    /// "stall saved": work the legacy admission prefill would have
    /// serialized in front of those decodes.
    fn pump_ingestion(
        &mut self,
        budget_left: &mut usize,
        step_tokens_done: &mut usize,
        overlapped: bool,
        events: &mut Vec<EngineEvent>,
    ) -> Result<()> {
        self.ingest_scratch.clear();
        for (i, slot) in self.slots.iter().enumerate() {
            if let SlotState::Busy(b) = slot {
                if b.ingesting() {
                    self.ingest_scratch.push((b.admitted_seq, i));
                }
            }
        }
        if self.ingest_scratch.is_empty() {
            return Ok(());
        }
        self.ingest_scratch.sort_unstable();
        let order = std::mem::take(&mut self.ingest_scratch);
        let pmax = self.backend.p_max();
        let bs = self.kv_cfg.block_size;
        for &(_, i) in &order {
            if *budget_left == 0 {
                break;
            }
            // Clone the prompt handle (Arc — cheap) and COPY the resume
            // into the reusable scratch so backend calls can borrow them
            // while the slot table is free. `b.item.resume` itself is
            // never moved out: an error propagating from any backend call
            // mid-pump leaves the slot fully intact.
            let prompt = {
                let SlotState::Busy(b) = &mut self.slots[i] else { continue };
                self.resume_scratch.clear();
                self.resume_scratch.extend_from_slice(&b.item.resume);
                b.item.prompt.clone()
            };
            let resume = std::mem::take(&mut self.resume_scratch);
            let plen = prompt.len();
            loop {
                if *budget_left == 0 {
                    break;
                }
                let (prompt_fed, replay_fed, slice_replay) = {
                    let SlotState::Busy(b) = &self.slots[i] else { break };
                    (b.prompt_fed, b.replay_fed, b.slice_replay)
                };
                if prompt_fed < plen {
                    // ---- prompt chunk ----------------------------------
                    // First chunk: attach the group's registered prompt
                    // prefix if a sibling has completed and registered it
                    // by now (refcount bump — the whole prompt region is
                    // then pre-charged and per-chunk charging no-ops
                    // inside it). The prompt is still FED to the backend:
                    // sharing is an accounting optimization on this
                    // substrate, not a compute skip.
                    if prompt_fed == 0 && self.kv_cfg.prefix_sharing {
                        let key = {
                            let SlotState::Busy(b) = &self.slots[i] else { break };
                            if b.pages.is_empty() { b.item.prefix } else { None }
                        };
                        if let Some(key) = key {
                            if let Some(e) = self.prefix_cache.get(key) {
                                if e.tokens == plen {
                                    let SlotState::Busy(b) = &mut self.slots[i] else {
                                        break;
                                    };
                                    b.pages.attach_shared(e.blocks(), e.tokens, &mut self.kv);
                                    self.kv_resident += plen;
                                    self.prefix_tokens_shared += plen as u64;
                                    self.backend.set_block_table(
                                        i,
                                        b.pages.block_ids(),
                                        b.pages.tokens(),
                                        bs,
                                    )?;
                                }
                            }
                        }
                    }
                    let take = pmax.min(*budget_left).min(plen - prompt_fed);
                    let end = prompt_fed + take;
                    let t0 = Instant::now();
                    let logits = self.backend.prefill_chunk(
                        i,
                        &prompt[prompt_fed..end],
                        prompt_fed,
                        end == plen,
                    )?;
                    let dt = t0.elapsed().as_secs_f64();
                    self.prefill_chunks += 1;
                    if overlapped {
                        self.prefill_stall_saved += dt;
                    }
                    *budget_left -= take;
                    *step_tokens_done += take;
                    {
                        let SlotState::Busy(b) = &mut self.slots[i] else { break };
                        b.prompt_fed = end;
                    }
                    // Per-chunk block charging for the ingested span
                    // (no-op inside an attached shared prefix).
                    self.charge_ingested(i, end)?;
                    let Some(logits) = logits else { continue };
                    // Prompt complete. Register the prompt-pure chain for
                    // the group's remaining siblings (first completer
                    // wins; slots that attached an existing entry skip).
                    if self.kv_cfg.prefix_sharing {
                        let key = {
                            let SlotState::Busy(b) = &self.slots[i] else { break };
                            b.item.prefix.filter(|_| b.pages.tokens() == plen)
                        };
                        if let Some(key) = key {
                            if self.prefix_cache.get(key).is_none() {
                                if let SlotState::Busy(b) = &self.slots[i] {
                                    self.prefix_cache.insert(
                                        key,
                                        b.pages.block_ids(),
                                        plen,
                                        &mut self.kv,
                                    );
                                }
                            }
                        }
                    }
                    if !resume.is_empty() {
                        // Replay slices continue below; the next backend
                        // write lands at `plen`.
                        let SlotState::Busy(b) = &mut self.slots[i] else { break };
                        b.pos = plen as i32;
                        continue;
                    }
                    // Cover the pending feed position, then sample the
                    // first token from the prefill logits (the legacy
                    // admission path, spread across steps).
                    self.charge_ingested(i, plen + 1)?;
                    self.sample_after_ingest(i, &logits, plen + 1, plen as i32, events);
                    break;
                }
                if slice_replay && replay_fed < resume.len() {
                    // ---- resume replay slice ---------------------------
                    let take = pmax.min(*budget_left).min(resume.len() - replay_fed);
                    let end = replay_fed + take;
                    let t0 = Instant::now();
                    match self.backend.replay(
                        i,
                        &resume[replay_fed..end],
                        plen + replay_fed,
                    )? {
                        Some(logits) => {
                            let dt = t0.elapsed().as_secs_f64();
                            self.prefill_chunks += 1;
                            if overlapped {
                                self.prefill_stall_saved += dt;
                            }
                            *budget_left -= take;
                            *step_tokens_done += take;
                            self.replayed_tokens += take as u64;
                            self.charge_ingested(i, plen + end)?;
                            let done = end == resume.len();
                            {
                                let SlotState::Busy(b) = &mut self.slots[i] else { break };
                                b.replay_fed = end;
                                b.replayed = end;
                                b.pos = (plen + end) as i32;
                                if done {
                                    b.slice_replay = false;
                                }
                            }
                            if !done {
                                continue;
                            }
                            // Replay complete: cover the pending feed and
                            // sample the next new token from the final
                            // slice's logits (mirrors the legacy
                            // replay-complete admission path).
                            self.charge_ingested(i, plen + end + 1)?;
                            self.sample_after_ingest(
                                i,
                                &logits,
                                plen + resume.len() + 1,
                                (plen + end) as i32,
                                events,
                            );
                            break;
                        }
                        None => {
                            // Backend declined: ride per-token decode
                            // replay from the next step (legacy
                            // mechanism). Cover the pending feed position.
                            self.charge_ingested(i, plen + replay_fed + 1)?;
                            let SlotState::Busy(b) = &mut self.slots[i] else { break };
                            b.slice_replay = false;
                            b.next_token = resume[replay_fed];
                            b.pos = (plen + replay_fed) as i32;
                            break;
                        }
                    }
                }
                break; // nothing left to ingest for this slot
            }
            // Hand the scratch buffer back for the next slot / next step.
            self.resume_scratch = resume;
        }
        self.ingest_scratch = order;
        Ok(())
    }

    /// Shared tail of both ingestion-completion paths (prompt done with no
    /// resume; final replay slice done): sample the next token for slot
    /// `i` from `logits`, then either arm the slot for decoding from the
    /// next step or finish it outright (EOS / length cap at `total_len` =
    /// prompt + resume + this sample). Returns true when the slot
    /// finished and was vacated.
    fn sample_after_ingest(
        &mut self,
        i: usize,
        logits: &[f32],
        total_len: usize,
        pos: i32,
        events: &mut Vec<EngineEvent>,
    ) -> bool {
        let (tok, lp) = {
            let SlotState::Busy(b) = &self.slots[i] else { return false };
            sample_token_dispatched(
                logits,
                &b.item.sampling,
                &mut self.rng,
                &mut self.scratch,
                self.dispatch,
            )
        };
        let reason = {
            let SlotState::Busy(b) = &mut self.slots[i] else { return false };
            b.generated.push(tok);
            b.logprobs.push(lp);
            b.pos = pos;
            if tok == tokenizer::EOS {
                Some(FinishReason::Eos)
            } else if total_len >= b.item.max_total {
                Some(FinishReason::LengthCap)
            } else {
                b.next_token = tok;
                None
            }
        };
        if let Some(r) = reason {
            let mut b = self.vacate(i).expect("busy slot");
            self.free_slot_kv(i, &mut b.pages);
            events.push(EngineEvent::Done { engine: self.id, result: finish(*b, r) });
            return true;
        }
        false
    }

    /// First retained slot matching an affinity hint exactly: same request,
    /// same retention token, and a resume prefix of exactly the retained
    /// generated length (the trajectory cannot have grown in between, but
    /// the triple check makes the fast path impossible to hit by accident).
    fn find_retained(&self, item: &WorkItem) -> Option<usize> {
        let token = item.retain?;
        self.slots.iter().position(|s| {
            matches!(s, SlotState::Retained(rs)
                if rs.token == token
                    && rs.request_id == item.request_id
                    && rs.generated_len == item.resume.len())
        })
    }

    /// Most recently admitted retained slot (LIFO eviction victim).
    fn latest_retained(&self) -> Option<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| match s {
                SlotState::Retained(rs) => Some((i, rs.admitted_seq)),
                _ => None,
            })
            .max_by_key(|&(_, seq)| seq)
            .map(|(i, _)| i)
    }

    /// Re-activate retained slot `i` for `item`: the pending next-token
    /// feed picks up exactly where the flushed slot left off, so the token
    /// stream is bit-identical to an uninterrupted run (and to the replay
    /// path) — with zero recompute. The retained block chain transfers to
    /// the busy slot as-is: no blocks are charged or freed.
    ///
    /// Strictly best-effort, like every other retention path: if the
    /// backend fails to restore the slot, the retained state is dropped
    /// and the item is handed back for ordinary replay admission — a
    /// retention problem must never kill the engine thread (`step` errors
    /// are fatal to it).
    fn admit_from_retained(&mut self, i: usize, item: WorkItem) -> Option<WorkItem> {
        let SlotState::Retained(mut rs) =
            std::mem::replace(&mut self.slots[i], SlotState::Idle)
        else {
            unreachable!("admit_from_retained on a non-retained slot");
        };
        // Release the retained charge first so the counters stay consistent
        // on every exit path; `occupy` re-adds the identical chain charge.
        self.retained_count -= 1;
        self.kv_resident -= rs.pages.tokens();
        if let Err(e) = self.backend.resume_retained(i) {
            self.retained_evictions += 1;
            self.free_slot_kv(i, &mut rs.pages);
            let _ = self.backend.release_retained(i);
            eprintln!(
                "engine-{}: resume_retained failed ({e:#}); falling back to replay",
                self.id
            );
            return Some(item);
        }
        self.admission_counter += 1;
        // Only NEW tokens land in `generated`; reserve the worst case so
        // the decode loop's push() never reallocates mid-generation.
        let out_cap = item.max_total.saturating_sub(item.prompt.len() + item.resume.len());
        let busy = BusySlot {
            generated: Vec::with_capacity(out_cap),
            logprobs: Vec::with_capacity(out_cap),
            replay_fed: item.resume.len(),
            replayed: 0,
            resumed_from_kv: true,
            next_token: rs.next_token,
            pos: rs.pos,
            pages: std::mem::take(&mut rs.pages),
            admitted_seq: self.admission_counter,
            prompt_fed: item.prompt.len(),
            slice_replay: false,
            item,
        };
        self.retained_resumes += 1;
        self.occupy(i, Box::new(busy));
        None
    }

    /// Admission-pressure eviction victim: LIFO among retained slots, but
    /// slots a queued item's hint still targets are spared when possible —
    /// evicting one of those forces the imminent resume to replay its
    /// whole prefix, the exact cost retention exists to avoid. If every
    /// retained slot is targeted, plain LIFO applies: queued work must
    /// still never starve behind parked KV.
    fn admission_eviction_victim(&self) -> Option<usize> {
        let mut untargeted: Option<(usize, u64)> = None;
        let mut any: Option<(usize, u64)> = None;
        for (i, s) in self.slots.iter().enumerate() {
            let SlotState::Retained(rs) = s else { continue };
            let seq = rs.admitted_seq;
            if any.map_or(true, |(_, b)| seq > b) {
                any = Some((i, seq));
            }
            let targeted = self.pending.iter().any(|it| {
                it.retain == Some(rs.token) && it.request_id == rs.request_id
            });
            if !targeted && untargeted.map_or(true, |(_, b)| seq > b) {
                untargeted = Some((i, seq));
            }
        }
        untargeted.or(any).map(|(i, _)| i)
    }

    /// Blocks the in-flight chunked ingestions will still charge before
    /// they are caught up (their chains grow per chunk, so
    /// `blocks_in_use` under-reports what admitted work has already been
    /// promised). 0 in legacy mode — admission charges the whole span
    /// synchronously there.
    fn committed_ingest_blocks(&self) -> usize {
        self.slots
            .iter()
            .map(|s| match s {
                SlotState::Busy(b) if b.ingesting() => {
                    let plen = b.plen();
                    let target = plen + b.item.resume.len() + 1;
                    let mut need =
                        self.kv.blocks_for(target).saturating_sub(b.pages.num_blocks());
                    // A not-yet-started slot that will attach a registered
                    // group prefix at first-chunk time only adds the
                    // private tail past the shared full blocks — the same
                    // discount the admission gate applies to its own
                    // shared-hit candidate. (Once attached, the chain
                    // itself reflects the shared blocks and the plain
                    // subtraction above is already right.)
                    if self.kv_cfg.prefix_sharing && b.prompt_fed == 0 && b.pages.is_empty()
                    {
                        if let Some(key) = b.item.prefix {
                            if self.prefix_cache.get(key).is_some_and(|e| e.tokens == plen) {
                                need = need.saturating_sub(plen / self.kv_cfg.block_size);
                            }
                        }
                    }
                    need
                }
                _ => 0,
            })
            .sum()
    }

    /// Block-budget admission gate: make headroom for a fresh/replay
    /// admission (a `plen`-token prompt plus `resume_len` tokens to
    /// rebuild — the chain reaches `plen + resume_len + 1` tokens whether
    /// ingestion is synchronous at admission, chunked over later steps,
    /// or per-token through decode) by evicting caches (prefix registry
    /// entries first — sparing the one this admission is about to attach
    /// — then retained slots, sparing hint-targeted ones), and report
    /// whether admission may proceed. `false` = clean backpressure: the
    /// item stays queued until running work frees blocks. An idle engine
    /// always admits (a single sequence may legitimately exceed the whole
    /// budget — mirroring "the last live slot is never preempted").
    fn ensure_block_headroom(
        &mut self,
        plen: usize,
        resume_len: usize,
        prefix_key: Option<u64>,
        events: &mut Vec<EngineEvent>,
    ) -> bool {
        // The enforced budget is dtype-scaled: the configured blocks are
        // f32-byte-denominated, so f16/int8 fit 2×/4× as many real blocks.
        let budget = self.kv_cfg.effective_budget_blocks();
        if budget == 0 {
            return true;
        }
        // Blocks already promised to mid-ingestion slots (chunked mode):
        // counted alongside blocks_in_use so two admissions in one step
        // cannot both claim the same headroom before either has charged
        // its chain.
        let pending = self.committed_ingest_blocks();
        let shared_hit = self.kv_cfg.prefix_sharing
            && prefix_key
                .and_then(|k| self.prefix_cache.get(k))
                .map_or(false, |e| e.tokens == plen);
        let total = plen + resume_len + 1;
        // A shared admission attaches the registered prefix, keeping its
        // FULL blocks shared; the partial prompt tail (if any) is COW'd,
        // so it counts on the private side along with the resume/feed
        // growth.
        let needed = if shared_hit {
            self.kv
                .blocks_for(total)
                .saturating_sub(plen / self.kv_cfg.block_size)
                .max(1)
        } else {
            self.kv.blocks_for(total)
        };
        if self.kv.blocks_in_use() + pending + needed > budget {
            // Feasibility pre-check before sacrificing any cache: an UPPER
            // bound on what evicting every registry entry and retained
            // slot could possibly free (refs shared with busy chains free
            // nothing, so the true yield is ≤ this). If even that cannot
            // make room, backpressure WITHOUT destroying the zero-replay
            // caches — the admission must wait for busy slots to drain
            // either way.
            let max_freeable: usize = self.prefix_cache.total_blocks()
                + self
                    .slots
                    .iter()
                    .map(|s| match s {
                        SlotState::Retained(rs) => rs.pages.num_blocks(),
                        _ => 0,
                    })
                    .sum::<usize>();
            if (self.kv.blocks_in_use() + pending).saturating_sub(max_freeable) + needed
                > budget
            {
                return self.busy_count == 0;
            }
        }
        loop {
            // Recompute the in-flight commitment every iteration: evicting
            // a registry entry below can GROW it (a not-yet-started
            // sibling that would have attached that entry now needs its
            // full private chain), so a stale snapshot would let this
            // admission proceed under-counted and push the budget into
            // live-slot preemption instead of clean backpressure.
            let pending = self.committed_ingest_blocks();
            if self.kv.blocks_in_use() + pending + needed <= budget {
                return true;
            }
            if let Some(key) = self.prefix_cache.eviction_victim(&self.kv, prefix_key) {
                self.prefix_cache.remove(key, &mut self.kv);
                continue;
            }
            if self.retained_count > 0 {
                if let Some(victim) = self.admission_eviction_victim() {
                    self.drop_retained_slot(victim, events);
                    continue;
                }
            }
            return self.busy_count == 0;
        }
    }

    fn admit(&mut self, events: &mut Vec<EngineEvent>) -> Result<()> {
        loop {
            let Some(front) = self.pending.front() else { break };
            // 0. Degenerate item: no room to generate anything — report an
            //    empty LengthCap without consuming a slot or any blocks
            //    (and before the budget gate, so it cannot trigger cache
            //    eviction on its behalf).
            if front.prompt.len() >= front.max_total {
                let item = self.pending.pop_front().unwrap();
                events.push(EngineEvent::Done {
                    engine: self.id,
                    result: WorkResult {
                        request_id: item.request_id,
                        new_tokens: vec![],
                        new_logprobs: vec![],
                        reason: FinishReason::LengthCap,
                        replayed: 0,
                        retained: None,
                        resumed_from_kv: false,
                    },
                });
                continue;
            }
            // 1. Affinity fast path: the hint names a live retained slot.
            //    No blocks are charged — the chain transfers as-is.
            if let Some(i) = self.find_retained(front) {
                let item = self.pending.pop_front().unwrap();
                if let Some(item) = self.admit_from_retained(i, item) {
                    // Backend restore failed; the retained state is gone —
                    // requeue at the front for ordinary replay admission.
                    self.pending.push_front(item);
                }
                continue;
            }
            // 2. Is a slot even obtainable? (Idle, or a retained slot that
            //    COULD be evicted.) If every slot is busy, stop — without
            //    letting the budget gate below shed caches for an
            //    admission that has no slot to go to.
            if self.free_slots() == 0 && self.retained_count == 0 {
                break; // every slot busy — wait for a finish
            }
            // 3. Block-budget gate, BEFORE any slot-scarcity eviction:
            //    backpressure cleanly when the budget has no headroom
            //    (head-of-line: the queue stays FIFO), so an infeasible
            //    admission never costs a retained slot.
            let (front_plen, front_resume, front_prefix) =
                (front.prompt.len(), front.resume.len(), front.prefix);
            if !self.ensure_block_headroom(front_plen, front_resume, front_prefix, events) {
                break;
            }
            // 4. Slot resolution: first idle slot (the gate's evictions may
            //    have opened one), else evict a retained slot (LIFO,
            //    sparing slots that queued hints still target) — queued
            //    work must never starve behind parked KV.
            let idle = self.slots.iter().position(|s| matches!(s, SlotState::Idle));
            let i = match idle {
                Some(i) => i,
                None => match self.admission_eviction_victim() {
                    Some(victim) => {
                        self.drop_retained_slot(victim, events);
                        continue;
                    }
                    None => break, // every slot busy — wait for a finish
                },
            };
            let item = self.pending.pop_front().unwrap();
            self.admission_counter += 1;
            let seq = self.admission_counter;
            let plen = item.prompt.len();
            // Page-table setup. Registration happens at exactly `plen`
            // tokens in both schedules, so registry chains are
            // prompt-pure — the owner's own first append COWs the partial
            // tail like any other sibling.
            let bs = self.kv_cfg.block_size;
            let mut pages = PageTable::new();
            pages.reserve(self.kv.blocks_for(item.max_total) + 1);
            // Continuous batching: admission only reserves the slot — the
            // prompt (and any resume replay) is ingested by the packed
            // per-step scheduler in budgeted chunks, so admission no
            // longer implies a same-step first token. Shared-prefix
            // attach happens at FIRST-CHUNK time instead of here: a whole
            // group can admit in one step, before any sibling has
            // completed its prompt and registered the chain.
            if self.step_budget > 0 {
                let out_cap = item.max_total.saturating_sub(plen);
                let busy = BusySlot {
                    generated: Vec::with_capacity(out_cap),
                    logprobs: Vec::with_capacity(out_cap),
                    replay_fed: 0,
                    replayed: 0,
                    resumed_from_kv: false,
                    next_token: 0,
                    pos: 0,
                    pages,
                    admitted_seq: seq,
                    prompt_fed: 0,
                    slice_replay: !item.resume.is_empty(),
                    item,
                };
                self.occupy(i, Box::new(busy));
                continue;
            }
            // Legacy slot admission: attach the group's registered prompt
            // prefix when the handle matches (refcount bump, zero fresh
            // residency), then whole-prompt prefill right now.
            let mut shared_tokens = 0usize;
            if self.kv_cfg.prefix_sharing {
                if let Some(key) = item.prefix {
                    if let Some(e) = self.prefix_cache.get(key) {
                        if e.tokens == plen {
                            pages.attach_shared(e.blocks(), e.tokens, &mut self.kv);
                            shared_tokens = plen;
                        }
                    }
                }
            }
            let logits = match self.backend.prefill(i, &item.prompt) {
                Ok(l) => l,
                Err(e) => {
                    self.unadmit(i, pages, item);
                    return Err(e);
                }
            };
            if shared_tokens == 0 {
                pages
                    .grow_to(plen, &mut self.kv)
                    .expect("engine block arena is unbounded");
                if self.kv_cfg.prefix_sharing {
                    if let Some(key) = item.prefix {
                        self.prefix_cache.insert(key, pages.block_ids(), plen, &mut self.kv);
                    }
                }
            }
            self.prefix_tokens_shared += shared_tokens as u64;
            // Reserve the worst-case output length up front so the decode
            // loop's push() never reallocates mid-generation.
            let out_cap = item.max_total.saturating_sub(plen);
            let mut busy = BusySlot {
                generated: Vec::with_capacity(out_cap),
                logprobs: Vec::with_capacity(out_cap),
                replay_fed: 0,
                replayed: 0,
                resumed_from_kv: false,
                next_token: 0,
                pos: plen as i32,
                pages,
                admitted_seq: seq,
                prompt_fed: plen,
                slice_replay: false,
                item,
            };
            if busy.item.resume.is_empty() {
                // Cover the pending feed position (pos = plen): the first
                // divergent write — COWs a shared partial tail.
                busy.pages
                    .grow_to(plen + 1, &mut self.kv)
                    .expect("engine block arena is unbounded");
                if let Err(e) =
                    self.backend.set_block_table(i, busy.pages.block_ids(), busy.pages.tokens(), bs)
                {
                    self.unadmit(i, busy.pages, busy.item);
                    return Err(e);
                }
                // Sample the first new token from the prefill logits.
                let (tok, lp) = sample_token_dispatched(
                    &logits,
                    &busy.item.sampling,
                    &mut self.rng,
                    &mut self.scratch,
                    self.dispatch,
                );
                busy.generated.push(tok);
                busy.logprobs.push(lp);
                if tok == tokenizer::EOS {
                    self.free_slot_kv(i, &mut busy.pages);
                    events.push(EngineEvent::Done {
                        engine: self.id,
                        result: finish(busy, FinishReason::Eos),
                    });
                    continue;
                }
                if plen + 1 >= busy.item.max_total {
                    self.free_slot_kv(i, &mut busy.pages);
                    events.push(EngineEvent::Done {
                        engine: self.id,
                        result: finish(busy, FinishReason::LengthCap),
                    });
                    continue;
                }
                busy.next_token = tok;
            } else {
                // Chunked replay (vLLM-style parallel re-prefill of the
                // buffered partial); falls back to per-token decode when
                // the backend declines (mock backend, near-horizon).
                let resume = busy.item.resume.clone();
                let pmax = self.backend.p_max();
                let mut fed = 0usize;
                let mut last_logits: Option<Vec<f32>> = None;
                while fed < resume.len() {
                    let end = (fed + pmax).min(resume.len());
                    match self.backend.replay(i, &resume[fed..end], plen + fed) {
                        Ok(Some(logits)) => {
                            last_logits = Some(logits);
                            fed = end;
                        }
                        Ok(None) => break,
                        Err(e) => {
                            self.unadmit(i, busy.pages, busy.item);
                            return Err(e);
                        }
                    }
                }
                self.replayed_tokens += fed as u64;
                busy.replay_fed = fed;
                busy.replayed = fed;
                busy.pos = (plen + fed) as i32;
                // Cover the replayed region plus the pending feed position
                // (pos = plen + fed). The first append past a shared
                // prompt tail COWs it.
                busy.pages
                    .grow_to(plen + fed + 1, &mut self.kv)
                    .expect("engine block arena is unbounded");
                if let Err(e) =
                    self.backend.set_block_table(i, busy.pages.block_ids(), busy.pages.tokens(), bs)
                {
                    self.unadmit(i, busy.pages, busy.item);
                    return Err(e);
                }
                if fed == resume.len() {
                    // Replay complete: sample the next new token now.
                    let logits = last_logits.expect("non-empty resume");
                    let (tok, lp) = sample_token_dispatched(
                        &logits,
                        &busy.item.sampling,
                        &mut self.rng,
                        &mut self.scratch,
                        self.dispatch,
                    );
                    busy.generated.push(tok);
                    busy.logprobs.push(lp);
                    let total = plen + resume.len() + 1;
                    if tok == tokenizer::EOS {
                        self.free_slot_kv(i, &mut busy.pages);
                        events.push(EngineEvent::Done {
                            engine: self.id,
                            result: finish(busy, FinishReason::Eos),
                        });
                        continue;
                    }
                    if total >= busy.item.max_total {
                        self.free_slot_kv(i, &mut busy.pages);
                        events.push(EngineEvent::Done {
                            engine: self.id,
                            result: finish(busy, FinishReason::LengthCap),
                        });
                        continue;
                    }
                    busy.next_token = tok;
                } else {
                    busy.next_token = resume[fed];
                }
            }
            self.occupy(i, Box::new(busy));
        }
        Ok(())
    }

    /// Enforce the KV budget in BLOCKS. Residency is shed cheapest-first:
    /// shared-prefix registry entries (pure cache — live sharers keep
    /// their blocks), then retained slots (LIFO — a cache of work), then
    /// live slots are preempted (LIFO, like vLLM; never the last one).
    /// Each eviction removes one entry, so the loops terminate even when
    /// shared refs mean an eviction frees zero blocks.
    fn enforce_kv_budget(&mut self, events: &mut Vec<EngineEvent>) {
        // Dtype-scaled, like admission headroom: narrow KV raises the
        // number of real blocks the configured byte budget holds.
        let budget = self.kv_cfg.effective_budget_blocks();
        if budget == 0 {
            return;
        }
        while self.kv.blocks_in_use() > budget && !self.prefix_cache.is_empty() {
            let key = self
                .prefix_cache
                .eviction_victim(&self.kv, None)
                .expect("non-empty cache has a victim");
            self.prefix_cache.remove(key, &mut self.kv);
        }
        while self.kv.blocks_in_use() > budget && self.retained_count > 0 {
            let victim = self.latest_retained().unwrap();
            self.drop_retained_slot(victim, events);
        }
        while self.kv.blocks_in_use() > budget && self.busy_count > 1 {
            let victim = self
                .slots
                .iter()
                .enumerate()
                .filter_map(|(i, s)| match s {
                    SlotState::Busy(b) => Some((i, b.admitted_seq)),
                    _ => None,
                })
                .max_by_key(|&(_, seq)| seq)
                .map(|(i, _)| i)
                .unwrap();
            if let Some(mut b) = self.vacate(victim) {
                self.free_slot_kv(victim, &mut b.pages);
                self.preemptions += 1;
                events.push(EngineEvent::Done {
                    engine: self.id,
                    result: finish(*b, FinishReason::Preempted),
                });
            }
        }
    }
}

fn finish(b: BusySlot, reason: FinishReason) -> WorkResult {
    WorkResult {
        request_id: b.item.request_id,
        new_tokens: b.generated,
        new_logprobs: b.logprobs,
        reason,
        replayed: b.replayed,
        retained: None,
        resumed_from_kv: b.resumed_from_kv,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::backend::MockBackend;

    fn item(id: u64, prompt: Vec<i32>) -> WorkItem {
        WorkItem {
            request_id: id,
            prompt: prompt.into(),
            resume: vec![],
            max_total: 96,
            sampling: SamplingParams::greedy(),
            retain: None,
            prefix: None,
        }
    }

    fn run_to_completion(
        eng: &mut Engine<MockBackend>,
        max_steps: usize,
    ) -> Vec<WorkResult> {
        let mut out = Vec::new();
        for _ in 0..max_steps {
            if !eng.has_work() {
                break;
            }
            let mut ev = Vec::new();
            eng.step(&mut ev).unwrap();
            for e in ev {
                if let EngineEvent::Done { result, .. } = e {
                    out.push(result);
                }
            }
        }
        out
    }

    /// Recompute the counters from first principles (test-only O(S) scan):
    /// busy/retained slot counts, resident tokens, and the per-chain block
    /// total that must equal the allocator's in-use count when nothing is
    /// shared (no prefix handles, empty registry).
    fn scan_counters(eng: &Engine<MockBackend>) -> (usize, usize, usize, usize) {
        let busy = eng.slots.iter().filter(|s| matches!(s, SlotState::Busy(_))).count();
        let retained =
            eng.slots.iter().filter(|s| matches!(s, SlotState::Retained(_))).count();
        let kv = eng
            .slots
            .iter()
            .map(|s| match s {
                SlotState::Busy(b) => b.pages.tokens(),
                SlotState::Retained(rs) => rs.pages.tokens(),
                SlotState::Idle => 0,
            })
            .sum();
        let blocks = eng
            .slots
            .iter()
            .map(|s| match s {
                SlotState::Busy(b) => b.pages.num_blocks(),
                SlotState::Retained(rs) => rs.pages.num_blocks(),
                SlotState::Idle => 0,
            })
            .sum();
        (busy, retained, kv, blocks)
    }

    #[test]
    fn greedy_generation_matches_script() {
        let be = MockBackend::new(4, 96);
        let prompt = vec![1, 9, 9];
        let want_len = be.scripted_len(&prompt);
        let mut eng = Engine::new(0, be, 0, 1);
        eng.submit(item(1, prompt)).unwrap();
        let results = run_to_completion(&mut eng, 200);
        assert_eq!(results.len(), 1);
        let r = &results[0];
        assert_eq!(r.reason, FinishReason::Eos);
        // scripted_len digits + the EOS token itself
        assert_eq!(r.new_tokens.len(), want_len + 1);
        assert_eq!(*r.new_tokens.last().unwrap(), tokenizer::EOS);
        assert_eq!(r.new_logprobs.len(), r.new_tokens.len());
        // All KV (tokens and blocks) released at completion.
        assert_eq!(eng.kv_tokens(), 0);
        assert_eq!(eng.kv_blocks(), 0);
    }

    #[test]
    fn multiple_slots_progress_concurrently() {
        let be = MockBackend::new(4, 96);
        let mut eng = Engine::new(0, be, 0, 1);
        for i in 0..4 {
            eng.submit(item(i, vec![1, i as i32 + 4, 7])).unwrap();
        }
        let results = run_to_completion(&mut eng, 300);
        assert_eq!(results.len(), 4);
        let mut ids: Vec<u64> = results.iter().map(|r| r.request_id).collect();
        ids.sort();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn queue_admits_when_slots_free() {
        let be = MockBackend::new(2, 96);
        let mut eng = Engine::new(0, be, 0, 1);
        for i in 0..6 {
            eng.submit(item(i, vec![1, i as i32 + 4])).unwrap();
        }
        assert_eq!(eng.queued(), 6);
        let results = run_to_completion(&mut eng, 500);
        assert_eq!(results.len(), 6);
        assert_eq!(eng.queued(), 0);
    }

    /// A backend error mid-admission must not lose the request: the item
    /// is re-queued at the head (so a failure snapshot still reports it)
    /// with no KV leaked, and an in-place retry — what the supervisor does
    /// for transient errors — produces the exact fault-free stream.
    #[test]
    fn failed_admission_prefill_requeues_item() {
        use crate::testkit::faulty::{FaultKind, FaultOp, FaultPlan, FaultyBackend};
        let mut clean_eng = Engine::new(0, MockBackend::new(1, 96), 0, 1);
        clean_eng.submit(item(1, vec![1, 5, 9])).unwrap();
        let want: Vec<Vec<i32>> = run_to_completion(&mut clean_eng, 200)
            .into_iter()
            .map(|r| r.new_tokens)
            .collect();

        let be = FaultyBackend::new(
            MockBackend::new(1, 96),
            vec![FaultPlan {
                op: FaultOp::Prefill,
                at_call: 1,
                kind: FaultKind::Transient { times: 1 },
            }],
        );
        let mut eng = Engine::new(0, be, 0, 1);
        eng.submit(item(1, vec![1, 5, 9])).unwrap();
        let mut ev = Vec::new();
        let err = eng.step(&mut ev).unwrap_err();
        assert!(crate::engine::is_transient(&err));
        assert_eq!(eng.inflight_request_ids(), vec![1], "faulted admission lost the request");
        assert_eq!(eng.kv_blocks(), 0, "aborted admission leaked blocks");
        let mut out = Vec::new();
        for _ in 0..200 {
            if !eng.has_work() {
                break;
            }
            let mut ev = Vec::new();
            eng.step(&mut ev).unwrap();
            for e in ev {
                if let EngineEvent::Done { result, .. } = e {
                    out.push(result.new_tokens);
                }
            }
        }
        assert_eq!(out, want, "retry after un-admit must be bit-identical");
    }

    #[test]
    fn length_cap_respected() {
        let mut be = MockBackend::new(1, 96);
        be.min_len = 50;
        be.spread = 1; // script wants 50 tokens
        let mut eng = Engine::new(0, be, 0, 1);
        let mut it = item(7, vec![1, 5, 6]);
        it.max_total = 10; // 3 prompt + 7 generated
        eng.submit(it).unwrap();
        let results = run_to_completion(&mut eng, 100);
        assert_eq!(results[0].reason, FinishReason::LengthCap);
        assert_eq!(results[0].new_tokens.len(), 7);
    }

    #[test]
    fn stop_generation_flushes_partials() {
        let mut be = MockBackend::new(2, 96);
        be.min_len = 40;
        be.spread = 1;
        let mut eng = Engine::new(0, be, 0, 1);
        eng.submit(item(1, vec![1, 4])).unwrap();
        eng.submit(item(2, vec![1, 5])).unwrap();
        let mut ev = Vec::new();
        for _ in 0..5 {
            eng.step(&mut ev).unwrap();
        }
        ev.clear();
        let unstarted = eng.stop_generation(&mut ev, false);
        assert!(unstarted.is_empty());
        let partials: Vec<&WorkResult> = ev
            .iter()
            .filter_map(|e| match e {
                EngineEvent::Done { result, .. } => Some(result),
                _ => None,
            })
            .collect();
        assert_eq!(partials.len(), 2);
        for p in partials {
            assert_eq!(p.reason, FinishReason::Stopped);
            assert!(p.retained.is_none(), "retain=false must not retain");
            assert!(!p.new_tokens.is_empty());
            assert!(p.new_tokens.len() < 40);
        }
        assert!(matches!(ev.last(), Some(EngineEvent::Flushed { .. })));
        assert_eq!(eng.busy(), 0);
        assert_eq!(eng.retained(), 0);
        assert_eq!(eng.kv_tokens(), 0);
        assert_eq!(eng.kv_blocks(), 0);
    }

    #[test]
    fn stop_returns_unstarted_queue() {
        let be = MockBackend::new(1, 96);
        let mut eng = Engine::new(0, be, 0, 1);
        for i in 0..5 {
            eng.submit(item(i, vec![1, i as i32 + 4])).unwrap();
        }
        let mut ev = Vec::new();
        eng.step(&mut ev).unwrap(); // admits exactly 1
        ev.clear();
        let unstarted = eng.stop_generation(&mut ev, false);
        assert_eq!(unstarted.len(), 4);
    }

    #[test]
    fn resume_replays_then_continues() {
        let be = MockBackend::new(1, 96);
        let prompt = vec![1, 8, 8];
        let mut eng = Engine::new(0, be, 0, 1);
        let mut it = item(3, prompt);
        it.resume = vec![5, 6, 7]; // 3 tokens to replay
        eng.submit(it).unwrap();
        let results = run_to_completion(&mut eng, 200);
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].replayed, 3);
        assert!(!results[0].resumed_from_kv);
        assert!(!results[0].new_tokens.is_empty());
        assert_eq!(eng.replayed_tokens, 3);
    }

    /// Block-budget pressure: a tight budget first backpressures fresh
    /// admission (queued work stays queued — no admit-then-preempt
    /// thrash), then preempts the LIFO-latest live slot once the admitted
    /// sequences outgrow the budget. The last live slot is never touched.
    #[test]
    fn kv_budget_triggers_backpressure_then_lifo_preemption() {
        let mut be = MockBackend::new(4, 96);
        be.min_len = 60;
        be.spread = 1; // long outputs to build KV pressure
        // 30 tokens -> 2 blocks of 16: room to admit exactly 2 short
        // prompts (1 block each).
        let mut eng = Engine::new(0, be, 30, 1);
        assert_eq!(eng.kv_budget_blocks(), 2);
        for i in 0..4 {
            eng.submit(item(i, vec![1, i as i32 + 4, 9, 9])).unwrap();
        }
        let mut preempted = Vec::new();
        for _ in 0..40 {
            let mut ev = Vec::new();
            eng.step(&mut ev).unwrap();
            for e in ev {
                if let EngineEvent::Done { result, .. } = e {
                    if result.reason == FinishReason::Preempted {
                        preempted.push(result.request_id);
                    }
                }
            }
        }
        assert!(!preempted.is_empty(), "tight budget must preempt");
        assert!(eng.preemptions() as usize >= preempted.len());
        // LIFO among the ADMITTED slots: requests 2/3 were backpressured
        // at admission, so the latest admitted (request 1) is the victim.
        assert!(preempted.contains(&1), "{preempted:?}");
        assert_eq!(eng.queued(), 2, "budget headroom gate must hold 2/3 back");
        // Under a tight budget the engine converges to few busy slots (a
        // single long sequence may legitimately exceed the budget alone —
        // the last slot is never preempted).
        assert!(eng.busy() <= 2, "busy {}", eng.busy());
    }

    /// The incremental busy/retained/kv counters — and the allocator's
    /// block count — must agree with a from-scratch slot scan at every
    /// point of a run that exercises admission, decode, finish,
    /// backpressure, preemption, retention, and stop_generation. (No
    /// prefix handles here, so chain blocks are all distinct and the
    /// allocator count equals the per-slot sum.)
    #[test]
    fn incremental_counters_match_slot_scans() {
        let mut be = MockBackend::new(4, 96);
        be.min_len = 30;
        be.spread = 6;
        let mut eng = Engine::new(0, be, 40, 9); // 3 blocks: tight
        for i in 0..8 {
            eng.submit(item(i, vec![1, i as i32 + 4, 9])).unwrap();
        }
        let mut ev = Vec::new();
        for _ in 0..60 {
            eng.step(&mut ev).unwrap();
            let (busy, retained, kv, blocks) = scan_counters(&eng);
            assert_eq!(eng.busy(), busy, "busy counter drifted");
            assert_eq!(eng.retained(), retained, "retained counter drifted");
            assert_eq!(eng.kv_tokens(), kv, "kv token counter drifted");
            assert_eq!(eng.kv_blocks(), blocks, "block counter drifted");
            ev.clear();
            if !eng.has_work() {
                break;
            }
        }
        eng.stop_generation(&mut ev, true);
        let (busy, retained, kv, blocks) = scan_counters(&eng);
        assert_eq!(
            (eng.busy(), eng.retained(), eng.kv_tokens(), eng.kv_blocks()),
            (busy, retained, kv, blocks)
        );
        assert_eq!(busy, 0);
        // Retained slots (if any) still charge KV.
        assert_eq!(kv > 0, retained > 0);
        ev.clear();
        eng.invalidate_retained(&mut ev);
        assert_eq!((eng.retained(), eng.kv_tokens(), eng.kv_blocks()), (0, 0, 0));
    }

    #[test]
    fn immediate_eos_on_prefill_is_handled() {
        let mut be = MockBackend::new(1, 96);
        be.min_len = 0;
        be.spread = 1; // script = EOS immediately
        let mut eng = Engine::new(0, be, 0, 1);
        eng.submit(item(1, vec![1, 4])).unwrap();
        let results = run_to_completion(&mut eng, 10);
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].reason, FinishReason::Eos);
        assert_eq!(results[0].new_tokens, vec![tokenizer::EOS]);
        assert_eq!(eng.kv_blocks(), 0, "prefill-EOS path must free its blocks");
    }

    #[test]
    fn trace_reports_active_slots_and_block_gauges() {
        let be = MockBackend::new(4, 96);
        let mut eng = Engine::new(0, be, 0, 1);
        eng.submit(item(1, vec![1, 4])).unwrap();
        let mut ev = Vec::new();
        eng.step(&mut ev).unwrap();
        let trace = ev
            .iter()
            .find_map(|e| match e {
                EngineEvent::Trace(t) => Some(t.clone()),
                _ => None,
            })
            .expect("trace emitted");
        assert_eq!(trace.slots, 4);
        assert!(trace.active <= 1); // may have finished already
        assert!(trace.dur >= 0.0);
        assert!(trace.kv_blocks <= 2, "3-token prompt fits 1-2 blocks");
        assert!((0.0..=1.0).contains(&trace.kv_frag));
        assert_eq!(trace.prefix_tokens_shared, 0);
        // New gauges: resident bytes at the (f32 default) dtype, and the
        // detected sampler arm.
        assert_eq!(trace.kv_bytes, trace.kv_blocks * 16 * super::super::KV_ELEMS_PER_TOKEN * 4);
        assert_eq!(trace.sampler_dispatch, eng.sampler_dispatch().name());
        assert!(["scalar", "avx2", "avx512"].contains(&trace.sampler_dispatch));
    }

    // -- quantized KV dtypes ------------------------------------------------

    fn dtype_engine(dtype: KvDtype, budget_blocks: usize, seed: u64) -> Engine<MockBackend> {
        let be = MockBackend::new(2, 96);
        let kv = KvCacheConfig { budget_blocks, dtype, ..KvCacheConfig::default() };
        Engine::with_kv(0, be, kv, seed)
    }

    fn stream_of(
        eng: &mut Engine<MockBackend>,
        sampling: SamplingParams,
    ) -> Vec<(Vec<i32>, Vec<u32>)> {
        for i in 0..4u64 {
            let mut it = item(i, vec![1, i as i32 + 4, 7]);
            it.sampling = sampling;
            eng.submit(it).unwrap();
        }
        let mut results = run_to_completion(eng, 300);
        results.sort_by_key(|r| r.request_id);
        results
            .into_iter()
            .map(|r| {
                let lp_bits = r.new_logprobs.iter().map(|l| l.to_bits()).collect();
                (r.new_tokens, lp_bits)
            })
            .collect()
    }

    /// The mock's logit alphabet is exactly binary16-representable, so f16
    /// KV produces BIT-IDENTICAL token and log-prob streams to f32 — this
    /// is the f16 golden the issue asks for, and it is why the existing
    /// engine goldens pass unchanged at f16.
    #[test]
    fn f16_kv_streams_are_bit_identical_to_f32() {
        let sampling = SamplingParams::default(); // stochastic path
        let a = stream_of(&mut dtype_engine(KvDtype::F32, 0, 11), sampling);
        let b = stream_of(&mut dtype_engine(KvDtype::F16, 0, 11), sampling);
        assert_eq!(a, b, "f16 quantization must be invisible on the mock alphabet");
    }

    /// Int8 KV perturbs logits (per-row scale quantization) but stays
    /// fully deterministic — two runs are bit-identical — and greedy
    /// streams still match f32 exactly because every argmax survives
    /// quantization. These two invariants are the int8 golden.
    #[test]
    fn int8_kv_streams_are_deterministic_and_greedy_matches_f32() {
        let a = stream_of(&mut dtype_engine(KvDtype::Int8, 0, 11), SamplingParams::default());
        let b = stream_of(&mut dtype_engine(KvDtype::Int8, 0, 11), SamplingParams::default());
        assert_eq!(a, b, "int8 quantization must be deterministic");

        let g32 = stream_of(&mut dtype_engine(KvDtype::F32, 0, 13), SamplingParams::greedy());
        let g8 = stream_of(&mut dtype_engine(KvDtype::Int8, 0, 13), SamplingParams::greedy());
        assert_eq!(g32, g8, "int8 preserves every argmax on the mock alphabet");
    }

    /// `kv_bytes` maps blocks to real memory at the configured dtype: for
    /// the same workload, f16 halves and int8 quarters (modulo per-block
    /// scale metadata) the peak bytes f32 reports.
    #[test]
    fn kv_bytes_scale_down_with_narrow_dtypes() {
        let peak_bytes = |dtype: KvDtype| {
            let mut eng = dtype_engine(dtype, 0, 11);
            for i in 0..4u64 {
                eng.submit(item(i, vec![1, i as i32 + 4, 7])).unwrap();
            }
            let mut peak = 0usize;
            let mut peak_blocks = 0usize;
            for _ in 0..300 {
                if !eng.has_work() {
                    break;
                }
                let mut ev = Vec::new();
                eng.step(&mut ev).unwrap();
                for e in &ev {
                    if let EngineEvent::Trace(t) = e {
                        peak = peak.max(t.kv_bytes);
                        peak_blocks = peak_blocks.max(t.kv_blocks);
                    }
                }
            }
            (peak, peak_blocks)
        };
        let (f32_bytes, f32_blocks) = peak_bytes(KvDtype::F32);
        let (f16_bytes, f16_blocks) = peak_bytes(KvDtype::F16);
        let (i8_bytes, i8_blocks) = peak_bytes(KvDtype::Int8);
        assert!(f32_bytes > 0);
        // Compare per-block bytes rather than raw peaks so the assertion
        // stays valid even if a dtype's schedule diverges.
        let per_block = 16 * super::super::KV_ELEMS_PER_TOKEN;
        assert_eq!(f32_bytes, f32_blocks * per_block * 4);
        assert_eq!(f16_bytes, f16_blocks * per_block * 2);
        assert_eq!(i8_bytes, i8_blocks * (per_block + 4));
    }

    /// The same configured block budget admits more concurrent work at a
    /// narrow dtype: `budget_blocks` is f32-byte-denominated, so int8
    /// quadruples the enforced block count.
    #[test]
    fn narrow_kv_dtype_widens_the_effective_budget() {
        let mk = |dtype: KvDtype| {
            let mut be = MockBackend::new(4, 96);
            be.min_len = 60;
            be.spread = 1;
            let kv = KvCacheConfig { budget_blocks: 2, dtype, ..KvCacheConfig::default() };
            let mut eng = Engine::with_kv(0, be, kv, 1);
            for i in 0..4 {
                eng.submit(item(i, vec![1, i as i32 + 4, 9, 9])).unwrap();
            }
            let mut ev = Vec::new();
            for _ in 0..6 {
                eng.step(&mut ev).unwrap();
            }
            eng
        };
        let f32_eng = mk(KvDtype::F32);
        assert_eq!(f32_eng.kv_effective_budget_blocks(), 2);
        assert_eq!(f32_eng.queued(), 2, "f32: 2-block budget admits only 2 prompts");
        let i8_eng = mk(KvDtype::Int8);
        assert_eq!(i8_eng.kv_budget_blocks(), 2, "configured budget unchanged");
        assert_eq!(i8_eng.kv_effective_budget_blocks(), 8);
        assert_eq!(i8_eng.queued(), 0, "int8: the same bytes admit all 4 prompts");
        assert_eq!(i8_eng.preemptions(), 0);
    }

    #[test]
    fn rejects_oversized_prompt() {
        let be = MockBackend::new(1, 96); // p_max = 24
        let mut eng = Engine::new(0, be, 0, 1);
        assert!(eng.submit(item(1, vec![1; 25])).is_err());
    }

    // -- paged KV / prefix sharing ------------------------------------------

    fn sharing_engine(slots: usize, block_size: usize, sharing: bool) -> Engine<MockBackend> {
        let mut be = MockBackend::new(slots, 96);
        be.min_len = 20;
        be.spread = 1;
        let kv = KvCacheConfig {
            block_size,
            budget_blocks: 0,
            prefix_sharing: sharing,
            ..KvCacheConfig::default()
        };
        Engine::with_kv(0, be, kv, 1)
    }

    /// THE tentpole accounting contract: a group of G=4 samples sharing a
    /// block-aligned prompt holds exactly ONE refcounted copy of the
    /// prompt-prefix blocks — 1 shared block + G private tails = G+1
    /// blocks, vs 2·G without sharing.
    #[test]
    fn group_prefix_blocks_are_shared_once() {
        let g = 4u64;
        let prompt = vec![1, 7, 7, 9]; // 4 tokens == exactly 1 block of 4

        let mut on = sharing_engine(4, 4, true);
        for i in 0..g {
            let mut it = item(i, prompt.clone());
            it.prefix = Some(42);
            on.submit(it).unwrap();
        }
        let mut ev = Vec::new();
        on.step(&mut ev).unwrap();
        assert_eq!(on.busy(), 4);
        assert_eq!(on.prefix_entries(), 1, "one registry entry per group");
        // 3 later siblings each attached the 4-token prompt.
        assert_eq!(on.prefix_tokens_shared, 12);
        // 1 shared prompt block + 4 private continuation blocks.
        assert_eq!(on.kv_blocks(), 5);
        assert_eq!(on.cow_copies(), 0, "block-aligned prompt never COWs");

        let mut off = sharing_engine(4, 4, false);
        for i in 0..g {
            let mut it = item(i, prompt.clone());
            it.prefix = Some(42); // handle present but sharing disabled
            off.submit(it).unwrap();
        }
        let mut ev = Vec::new();
        off.step(&mut ev).unwrap();
        assert_eq!(off.prefix_entries(), 0);
        assert_eq!(off.prefix_tokens_shared, 0);
        assert_eq!(off.kv_blocks(), 8, "private copies: 2 blocks x 4 samples");
    }

    /// Non-aligned prompts share the partial tail block until the first
    /// divergent write copies it (COW) — once per group member, and the
    /// registry's prompt-pure original is never mutated.
    #[test]
    fn partial_prompt_tail_is_copied_on_first_write() {
        let g = 3u64;
        let prompt = vec![1, 7, 9]; // 3 tokens: 1 partial block of 4
        let mut eng = sharing_engine(4, 4, true);
        for i in 0..g {
            let mut it = item(i, prompt.clone());
            it.prefix = Some(7);
            eng.submit(it).unwrap();
        }
        let mut ev = Vec::new();
        eng.step(&mut ev).unwrap();
        // Every member's first append past the shared partial tail COWs.
        assert_eq!(eng.cow_copies(), g);
        assert_eq!(eng.prefix_tokens_shared, (g - 1) * 3);
        // Registry keeps the prompt-pure original; each member owns a
        // COW'd tail plus the fresh block its first decode step opened
        // (5 resident tokens = 2 blocks of 4 per chain).
        assert_eq!(eng.kv_blocks(), 1 + 2 * g as usize);
    }

    /// Sharing is accounting-only: token and logprob streams are
    /// bit-identical with sharing on vs off.
    #[test]
    fn sharing_streams_are_bit_identical_to_private_baseline() {
        let collect = |sharing: bool| -> Vec<(u64, Vec<i32>, Vec<u32>)> {
            let mut eng = sharing_engine(2, 4, sharing);
            // Two groups with distinct prompts (and therefore distinct
            // scripts), two samples each.
            for i in 0..4 {
                let (prompt, key) =
                    if i < 2 { (vec![1, 8, 8], 9) } else { (vec![1, 5, 6, 7], 10) };
                let mut it = item(i, prompt);
                it.prefix = Some(key);
                eng.submit(it).unwrap();
            }
            let mut out: Vec<(u64, Vec<i32>, Vec<u32>)> = run_to_completion(&mut eng, 400)
                .into_iter()
                .map(|r| {
                    (
                        r.request_id,
                        r.new_tokens,
                        r.new_logprobs.iter().map(|l| l.to_bits()).collect(),
                    )
                })
                .collect();
            out.sort();
            out
        };
        let a = collect(true);
        let b = collect(false);
        assert_eq!(a.len(), 4);
        assert_eq!(a, b, "prefix sharing changed a stream");
    }

    /// `ReleasePrefix` frees the registry entry (and its blocks once no
    /// live chain shares them); unknown keys are ignored.
    #[test]
    fn release_prefix_frees_registry_refs() {
        let mut eng = sharing_engine(2, 4, true);
        let mut it = item(1, vec![1, 5, 5, 5]);
        it.prefix = Some(3);
        eng.submit(it).unwrap();
        let mut ev = Vec::new();
        eng.step(&mut ev).unwrap();
        assert_eq!(eng.prefix_entries(), 1);
        let blocks_before = eng.kv_blocks();
        eng.release_prefix(99); // unknown key: no-op
        assert_eq!(eng.prefix_entries(), 1);
        eng.release_prefix(3);
        assert_eq!(eng.prefix_entries(), 0);
        // The live chain still holds the (formerly shared) prompt block.
        assert_eq!(eng.kv_blocks(), blocks_before);
        let _ = run_to_completion(&mut eng, 200);
        assert_eq!(eng.kv_blocks(), 0, "all refs released at completion");
    }

    /// Admission backpressure under a bounded budget: the second item
    /// waits cleanly in the queue while the first runs, then admits once
    /// the first completes and frees its blocks. Nothing deadlocks and
    /// nothing thrashes.
    #[test]
    fn budget_backpressure_defers_admission_without_thrash() {
        let mut be = MockBackend::new(2, 96);
        be.min_len = 8;
        be.spread = 1;
        let kv = KvCacheConfig {
            block_size: 16,
            budget_blocks: 1,
            prefix_sharing: true,
            ..KvCacheConfig::default()
        };
        let mut eng = Engine::with_kv(0, be, kv, 1);
        eng.submit(item(1, vec![1, 4, 4])).unwrap();
        eng.submit(item(2, vec![1, 5, 5])).unwrap();
        let mut ev = Vec::new();
        eng.step(&mut ev).unwrap();
        assert_eq!(eng.busy(), 1, "budget admits exactly one");
        assert_eq!(eng.queued(), 1, "second item backpressured, not dropped");
        let results = run_to_completion(&mut eng, 300);
        assert_eq!(results.len(), 2, "backpressured item admitted after free");
        assert!(results.iter().all(|r| r.reason.is_complete()));
        assert_eq!(eng.preemptions(), 0, "backpressure must not thrash via preemption");
    }

    /// An INFEASIBLE admission (even evicting every cache could not make
    /// room) must backpressure without touching the caches: destroying
    /// the retained slot would force a full replay later while the item
    /// still cannot admit.
    #[test]
    fn infeasible_admission_spares_caches() {
        let mut be = MockBackend::new(4, 96);
        be.min_len = 30;
        be.spread = 1;
        let kv = KvCacheConfig {
            block_size: 4,
            budget_blocks: 6,
            prefix_sharing: true,
            ..KvCacheConfig::default()
        };
        let mut eng = Engine::with_kv(0, be, kv, 1);
        // Retain req1 mid-generation: 2 blocks parked.
        eng.submit(item(1, vec![1, 8, 8, 8])).unwrap();
        let mut ev = Vec::new();
        for _ in 0..2 {
            eng.step(&mut ev).unwrap();
        }
        ev.clear();
        eng.stop_generation(&mut ev, true);
        assert_eq!(eng.retained(), 1);
        assert_eq!(eng.kv_blocks(), 2);

        // Two fresh 4-token prompts fill the budget to exactly 6 blocks;
        // an 8-token prompt then needs 3 blocks — infeasible even if the
        // retained 2 blocks were freed (6 - 2 + 3 > 6).
        eng.submit(item(2, vec![1, 4, 4, 4])).unwrap();
        eng.submit(item(3, vec![1, 5, 5, 5])).unwrap();
        eng.submit(item(4, vec![1, 9, 9, 9, 9, 9, 9, 9])).unwrap();
        ev.clear();
        eng.step(&mut ev).unwrap();
        assert_eq!(eng.busy(), 2, "feasible admissions proceed");
        assert_eq!(eng.queued(), 1, "infeasible admission backpressures");
        assert_eq!(eng.retained(), 1, "retained cache must be spared");
        assert!(
            !ev.iter().any(|e| matches!(e, EngineEvent::RetainedDropped { .. })),
            "no cache eviction for an admission that cannot proceed"
        );
    }

    /// Budget pressure evicts prefix-registry entries before retained
    /// slots: the registry is the cheapest cache to shed.
    #[test]
    fn budget_evicts_prefix_registry_before_retained() {
        let mut be = MockBackend::new(2, 96);
        be.min_len = 30;
        be.spread = 1;
        let kv = KvCacheConfig {
            block_size: 4,
            budget_blocks: 6,
            prefix_sharing: true,
            ..KvCacheConfig::default()
        };
        let mut eng = Engine::with_kv(0, be, kv, 1);
        // One retained partial + its registry entry.
        let mut it = item(1, vec![1, 8, 8, 8]);
        it.prefix = Some(5);
        eng.submit(it).unwrap();
        let mut ev = Vec::new();
        for _ in 0..4 {
            eng.step(&mut ev).unwrap();
        }
        ev.clear();
        eng.stop_generation(&mut ev, true);
        assert_eq!(eng.retained(), 1);
        assert_eq!(eng.prefix_entries(), 1);

        // A long-running fresh sequence pushes blocks over budget: the
        // registry entry must fall before the retained slot.
        eng.submit(item(2, vec![1, 9, 9, 9])).unwrap();
        for _ in 0..20 {
            let mut ev = Vec::new();
            eng.step(&mut ev).unwrap();
            if eng.prefix_entries() == 0 {
                break;
            }
            assert_eq!(
                eng.retained(),
                1,
                "retained slot dropped while the registry still had entries"
            );
        }
        assert_eq!(eng.prefix_entries(), 0, "registry entry must be shed first");
    }

    // -- KV retention -------------------------------------------------------

    /// Full stream of one request run uninterrupted on a fresh engine
    /// (tokens ++ logprob bits) — the oracle every retention test compares
    /// against. The mock script is positional, so any resume strategy that
    /// is correct must reproduce exactly this stream.
    fn uninterrupted_stream(prompt: &[i32]) -> (Vec<i32>, Vec<u32>) {
        let mut be = MockBackend::new(1, 96);
        be.min_len = 20;
        be.spread = 1;
        let mut eng = Engine::new(9, be, 0, 1);
        eng.submit(item(1, prompt.to_vec())).unwrap();
        let results = run_to_completion(&mut eng, 200);
        assert_eq!(results.len(), 1);
        assert!(results[0].reason.is_complete());
        (
            results[0].new_tokens.clone(),
            results[0].new_logprobs.iter().map(|l| l.to_bits()).collect(),
        )
    }

    fn retention_engine() -> Engine<MockBackend> {
        let mut be = MockBackend::new(1, 96);
        be.min_len = 20;
        be.spread = 1; // 20-token scripts: long enough to stop mid-way
        Engine::new(9, be, 0, 1)
    }

    /// Stop a running request mid-generation with retention; returns the
    /// flushed partial (with its token) after asserting the slot retained.
    fn stop_retaining(eng: &mut Engine<MockBackend>, steps: usize) -> WorkResult {
        let mut ev = Vec::new();
        for _ in 0..steps {
            eng.step(&mut ev).unwrap();
        }
        ev.clear();
        eng.stop_generation(&mut ev, true);
        let partial = ev
            .iter()
            .find_map(|e| match e {
                EngineEvent::Done { result, .. } => Some(result.clone()),
                _ => None,
            })
            .expect("flushed partial");
        assert_eq!(partial.reason, FinishReason::Stopped);
        assert_eq!(eng.retained(), 1);
        partial
    }

    /// The tentpole contract at engine level: a retained-KV resume replays
    /// nothing and produces the bit-identical stream an uninterrupted run
    /// (and therefore the replay path) produces.
    #[test]
    fn retained_resume_is_bit_identical_with_zero_replay() {
        let prompt = vec![1, 8, 8];
        let (want_toks, want_lps) = uninterrupted_stream(&prompt);

        let mut eng = retention_engine();
        eng.submit(item(1, prompt.clone())).unwrap();
        let partial = stop_retaining(&mut eng, 5);
        let token = partial.retained.expect("caught-up slot must retain");
        assert!(!partial.new_tokens.is_empty());
        assert!(eng.kv_tokens() > 0, "retained KV stays resident");
        assert!(eng.kv_blocks() > 0, "retained blocks stay charged");

        // Resume with the affinity hint.
        let mut it = item(1, prompt);
        it.resume = partial.new_tokens.clone();
        it.retain = Some(token);
        eng.submit(it).unwrap();
        let results = run_to_completion(&mut eng, 200);
        assert_eq!(results.len(), 1);
        let r = &results[0];
        assert!(r.resumed_from_kv, "hint matched — must resume from KV");
        assert_eq!(r.replayed, 0, "retained resume replays nothing");
        assert_eq!(eng.replayed_tokens, 0);
        assert_eq!(eng.retained_resumes, 1);
        assert_eq!(eng.retained(), 0);

        let full_toks: Vec<i32> =
            partial.new_tokens.iter().chain(r.new_tokens.iter()).copied().collect();
        let full_lps: Vec<u32> = partial
            .new_logprobs
            .iter()
            .chain(r.new_logprobs.iter())
            .map(|l| l.to_bits())
            .collect();
        assert_eq!(full_toks, want_toks, "token stream diverged from oracle");
        assert_eq!(full_lps, want_lps, "logprob bits diverged from oracle");
    }

    /// A stale hint (slot evicted in between) falls back to replay and
    /// still reproduces the oracle stream.
    #[test]
    fn stale_hint_falls_back_to_replay_bit_identically() {
        let prompt_a = vec![1, 8, 8];
        let (want_toks, want_lps) = uninterrupted_stream(&prompt_a);

        let mut eng = retention_engine();
        eng.submit(item(1, prompt_a.clone())).unwrap();
        let partial = stop_retaining(&mut eng, 5);
        let token = partial.retained.unwrap();

        // Fresh work on the single-slot engine evicts the retained slot
        // (admission must never starve behind parked KV).
        let mut ev = Vec::new();
        eng.submit(item(2, vec![1, 4, 4])).unwrap();
        eng.step(&mut ev).unwrap();
        assert_eq!(eng.retained(), 0, "admission pressure evicts retained KV");
        assert!(
            ev.iter().any(|e| matches!(
                e,
                EngineEvent::RetainedDropped { request_id: 1, .. }
            )),
            "eviction must notify the coordinator"
        );
        assert_eq!(eng.retained_evictions, 1);
        let _ = run_to_completion(&mut eng, 300); // drain request 2

        // Resume request 1 with the now-stale hint: replay fallback.
        let mut it = item(1, prompt_a);
        it.resume = partial.new_tokens.clone();
        it.retain = Some(token);
        eng.submit(it).unwrap();
        let results = run_to_completion(&mut eng, 300);
        assert_eq!(results.len(), 1);
        let r = &results[0];
        assert!(!r.resumed_from_kv);
        assert_eq!(r.replayed, partial.new_tokens.len());

        let full_toks: Vec<i32> =
            partial.new_tokens.iter().chain(r.new_tokens.iter()).copied().collect();
        let full_lps: Vec<u32> = partial
            .new_logprobs
            .iter()
            .chain(r.new_logprobs.iter())
            .map(|l| l.to_bits())
            .collect();
        assert_eq!(full_toks, want_toks);
        assert_eq!(full_lps, want_lps);
    }

    /// Weight-sync invalidation: after `invalidate_retained` the hint is
    /// stale and the resume replays (under whatever params are current).
    #[test]
    fn invalidation_clears_retention_and_resume_replays() {
        let prompt = vec![1, 8, 8];
        let mut eng = retention_engine();
        eng.submit(item(1, prompt.clone())).unwrap();
        let partial = stop_retaining(&mut eng, 5);
        let token = partial.retained.unwrap();

        let mut ev = Vec::new();
        eng.invalidate_retained(&mut ev);
        assert_eq!(eng.retained(), 0);
        assert_eq!(eng.kv_tokens(), 0);
        assert_eq!(eng.kv_blocks(), 0);
        assert!(ev
            .iter()
            .any(|e| matches!(e, EngineEvent::RetainedDropped { request_id: 1, .. })));

        let mut it = item(1, prompt);
        it.resume = partial.new_tokens.clone();
        it.retain = Some(token);
        eng.submit(it).unwrap();
        let results = run_to_completion(&mut eng, 300);
        assert!(!results[0].resumed_from_kv);
        assert_eq!(results[0].replayed, partial.new_tokens.len());
    }

    /// Under KV pressure, retained slots are evicted before any live slot
    /// is preempted.
    #[test]
    fn budget_evicts_retained_before_live() {
        let mut be = MockBackend::new(2, 96);
        be.min_len = 40;
        be.spread = 1;
        let mut eng = Engine::new(0, be, 25, 1); // 2 blocks of 16: tight
        eng.submit(item(1, vec![1, 8, 8])).unwrap();
        let mut ev = Vec::new();
        for _ in 0..5 {
            eng.step(&mut ev).unwrap();
        }
        ev.clear();
        eng.stop_generation(&mut ev, true);
        assert_eq!(eng.retained(), 1);

        // A long-running live sequence pushes blocks over budget; the
        // retained slot must fall before the live one is touched.
        eng.submit(item(2, vec![1, 9, 9])).unwrap();
        let mut dropped = false;
        let mut preempted = false;
        for _ in 0..40 {
            let mut ev = Vec::new();
            eng.step(&mut ev).unwrap();
            for e in &ev {
                match e {
                    EngineEvent::RetainedDropped { request_id: 1, .. } => dropped = true,
                    EngineEvent::Done { result, .. }
                        if result.reason == FinishReason::Preempted =>
                    {
                        preempted = true
                    }
                    _ => {}
                }
            }
            if !eng.has_work() {
                break;
            }
        }
        assert!(dropped, "retained slot must be evicted under budget pressure");
        assert!(!preempted, "live slot preempted while retained KV was parked");
        assert_eq!(eng.retained(), 0);
    }

    /// `ReleaseRetained` semantics: a matching (request, token) drops the
    /// slot; stale tokens are ignored.
    #[test]
    fn release_retained_request_validates_token() {
        let prompt = vec![1, 8, 8];
        let mut eng = retention_engine();
        eng.submit(item(1, prompt)).unwrap();
        let partial = stop_retaining(&mut eng, 5);
        let token = partial.retained.unwrap();

        let mut ev = Vec::new();
        eng.release_retained_request(1, token + 99, &mut ev); // stale token
        assert_eq!(eng.retained(), 1);
        assert!(ev.is_empty());
        eng.release_retained_request(1, token, &mut ev);
        assert_eq!(eng.retained(), 0);
        assert_eq!(eng.kv_tokens(), 0);
        assert_eq!(eng.kv_blocks(), 0);
        assert_eq!(ev.len(), 1);
    }

    /// Admission-pressure eviction spares retained slots that a queued
    /// item's hint still targets: with both slots retained and the queue
    /// holding [fresh, hinted-resume], the fresh item must evict the
    /// UNtargeted slot (even though the targeted one is LIFO-latest) so
    /// the resume still lands on its retained KV.
    #[test]
    fn admission_eviction_spares_hint_targeted_slots() {
        let mut be = MockBackend::new(2, 96);
        be.min_len = 20;
        be.spread = 1;
        let mut eng = Engine::new(0, be, 0, 1);
        eng.submit(item(1, vec![1, 8, 8])).unwrap();
        eng.submit(item(2, vec![1, 4, 4])).unwrap();
        let mut ev = Vec::new();
        for _ in 0..5 {
            eng.step(&mut ev).unwrap();
        }
        ev.clear();
        eng.stop_generation(&mut ev, true);
        assert_eq!(eng.retained(), 2);
        // Request 2 admitted after request 1 → its slot is LIFO-latest,
        // i.e. the default eviction victim.
        let p2 = ev
            .iter()
            .find_map(|e| match e {
                EngineEvent::Done { result, .. } if result.request_id == 2 => {
                    Some(result.clone())
                }
                _ => None,
            })
            .expect("request 2 partial");
        let tok2 = p2.retained.expect("retained token");

        eng.submit(item(3, vec![1, 9, 9])).unwrap(); // fresh, needs a slot
        let mut resume = item(2, vec![1, 4, 4]);
        resume.resume = p2.new_tokens.clone();
        resume.retain = Some(tok2);
        eng.submit(resume).unwrap();

        ev.clear();
        eng.step(&mut ev).unwrap();
        assert!(
            ev.iter().any(|e| matches!(
                e,
                EngineEvent::RetainedDropped { request_id: 1, .. }
            )),
            "the UNtargeted slot (request 1) must be the eviction victim"
        );
        assert_eq!(eng.retained_resumes, 1, "hinted resume must hit its slot");
        assert_eq!(eng.retained(), 0);
        assert_eq!(eng.busy(), 2);
    }

    /// Mid-replay slots (KV covering only part of the resume prefix) must
    /// NOT retain — the (token, length) validation cannot describe them.
    #[test]
    fn mid_replay_slots_flush_without_retention() {
        let mut be = MockBackend::new(1, 96);
        be.min_len = 40;
        be.spread = 1;
        let mut eng = Engine::new(0, be, 0, 1);
        let mut it = item(1, vec![1, 8, 8]);
        it.resume = vec![5; 30]; // long replay: still replaying after 4 steps
        eng.submit(it).unwrap();
        let mut ev = Vec::new();
        for _ in 0..4 {
            eng.step(&mut ev).unwrap();
        }
        ev.clear();
        eng.stop_generation(&mut ev, true);
        let partial = ev
            .iter()
            .find_map(|e| match e {
                EngineEvent::Done { result, .. } => Some(result),
                _ => None,
            })
            .unwrap();
        assert!(partial.retained.is_none(), "mid-replay slot must not retain");
        assert_eq!(eng.retained(), 0);
        assert_eq!(eng.kv_tokens(), 0);
        assert_eq!(eng.kv_blocks(), 0);
    }

    // -- continuous batching / chunked prefill ------------------------------

    fn chunked_engine(slots: usize, budget: usize) -> Engine<MockBackend> {
        let mut be = MockBackend::new(slots, 96);
        be.min_len = 12;
        be.spread = 6;
        let kv = KvCacheConfig {
            block_size: 4,
            budget_blocks: 0,
            prefix_sharing: true,
            ..KvCacheConfig::default()
        };
        Engine::with_opts(0, be, EngineOpts { kv, step_token_budget: budget }, 1)
    }

    fn streams(results: Vec<WorkResult>) -> Vec<(u64, Vec<i32>, Vec<u32>)> {
        let mut out: Vec<(u64, Vec<i32>, Vec<u32>)> = results
            .into_iter()
            .map(|r| {
                (
                    r.request_id,
                    r.new_tokens,
                    r.new_logprobs.iter().map(|l| l.to_bits()).collect(),
                )
            })
            .collect();
        out.sort();
        out
    }

    /// The tentpole contract: a tight step-token budget spreads prompt
    /// ingestion across steps (admission no longer implies a same-step
    /// first token) yet every greedy stream is bit-identical to the
    /// legacy slot-admission schedule.
    #[test]
    fn chunked_prefill_streams_match_slot_admission_bit_exactly() {
        let collect = |budget: usize| -> (Vec<(u64, Vec<i32>, Vec<u32>)>, u64) {
            let mut eng = chunked_engine(4, budget);
            for i in 0..6u64 {
                // Long prompts (up to p_max = 24) force multi-step chunking
                // under budget 5.
                let plen = 10 + (i as usize * 3) % 14;
                let prompt: Vec<i32> = (0..plen).map(|t| 1 + ((i as i32 + t as i32) % 9)).collect();
                eng.submit(item(i, prompt)).unwrap();
            }
            let res = run_to_completion(&mut eng, 800);
            (streams(res), eng.prefill_chunks)
        };
        let (chunked, chunks) = collect(5);
        let (legacy, legacy_chunks) = collect(0);
        assert_eq!(chunked.len(), 6);
        assert_eq!(chunked, legacy, "chunking changed a stream");
        assert!(chunks > 6, "long prompts must split into several chunks: {chunks}");
        assert_eq!(legacy_chunks, 0, "legacy mode must not chunk");
    }

    /// With the budget on, a freshly admitted long prompt does NOT emit its
    /// first token in the admission step, and per-step packed tokens never
    /// exceed the budget (given budget ≥ slots, so decode lanes fit).
    #[test]
    fn budget_packs_steps_and_defers_first_token() {
        let mut eng = chunked_engine(2, 6);
        let prompt: Vec<i32> = (0..20).map(|t| 1 + (t % 9)).collect();
        eng.submit(item(1, prompt)).unwrap();
        let mut ev = Vec::new();
        eng.step(&mut ev).unwrap();
        assert_eq!(eng.busy(), 1, "slot reserved at admission");
        let done_early = ev.iter().any(|e| matches!(e, EngineEvent::Done { .. }));
        assert!(!done_early);
        {
            let SlotState::Busy(b) = &eng.slots[0] else { panic!("busy") };
            assert!(b.generated.is_empty(), "no same-step first token for a 20-tok prompt");
            assert_eq!(b.prompt_fed, 6, "one budget's worth of prompt ingested");
            assert_eq!(b.pages.tokens(), 6, "blocks charged per chunk");
        }
        // Drive to completion; every packed step obeys the budget.
        ev.clear();
        let mut max_step_tokens = 0usize;
        for _ in 0..300 {
            if !eng.has_work() {
                break;
            }
            eng.step(&mut ev).unwrap();
        }
        for e in &ev {
            if let EngineEvent::Trace(t) = e {
                max_step_tokens = max_step_tokens.max(t.step_tokens);
                assert_eq!(t.step_budget, 6);
            }
        }
        assert!(max_step_tokens <= 6, "packed step exceeded budget: {max_step_tokens}");
        assert!(eng.prefill_chunks >= 4, "20 tokens / 6-budget ≥ 4 chunks");
    }

    /// Chunked replay slices (mock opt-in, like the PJRT backend): a
    /// resume is slice-fed through `Backend::replay` under the budget and
    /// reproduces the uninterrupted oracle stream bit-exactly.
    #[test]
    fn chunked_resume_slices_replay_bit_identically() {
        let prompt = vec![1, 8, 8];
        let (want_toks, want_lps) = uninterrupted_stream(&prompt);

        // Stop an uninterrupted run part-way (no retention) to get a real
        // partial whose resume we can replay chunked.
        let mut eng = retention_engine();
        eng.submit(item(1, prompt.clone())).unwrap();
        let mut ev = Vec::new();
        for _ in 0..5 {
            eng.step(&mut ev).unwrap();
        }
        ev.clear();
        eng.stop_generation(&mut ev, false);
        let partial = ev
            .iter()
            .find_map(|e| match e {
                EngineEvent::Done { result, .. } => Some(result.clone()),
                _ => None,
            })
            .expect("flushed partial");
        assert!(partial.new_tokens.len() >= 3);

        // Resume on a fresh CHUNKED engine with slice replay enabled and a
        // budget smaller than the resume, so it takes several slices.
        let mut be = MockBackend::new(1, 96);
        be.min_len = 20;
        be.spread = 1;
        be.chunked_replay = true;
        let kv = KvCacheConfig {
            block_size: 4,
            budget_blocks: 0,
            prefix_sharing: true,
            ..KvCacheConfig::default()
        };
        let mut eng2 =
            Engine::with_opts(9, be, EngineOpts { kv, step_token_budget: 2 }, 1);
        let mut it = item(1, prompt);
        it.resume = partial.new_tokens.clone();
        eng2.submit(it).unwrap();
        let results = run_to_completion(&mut eng2, 400);
        assert_eq!(results.len(), 1);
        let r = &results[0];
        assert_eq!(r.replayed, partial.new_tokens.len(), "whole resume recomputed");
        assert!(eng2.backend().replay_calls >= 2, "budget 2 must take several slices");
        assert!(!r.resumed_from_kv);

        let full_toks: Vec<i32> =
            partial.new_tokens.iter().chain(r.new_tokens.iter()).copied().collect();
        let full_lps: Vec<u32> = partial
            .new_logprobs
            .iter()
            .chain(r.new_logprobs.iter())
            .map(|l| l.to_bits())
            .collect();
        assert_eq!(full_toks, want_toks, "sliced replay diverged from oracle");
        assert_eq!(full_lps, want_lps);
    }

    /// Counter exactness under chunked mode: the incremental busy/kv/block
    /// counters agree with a from-scratch slot scan at every step of a run
    /// that mixes mid-ingestion slots, decode lanes, flushes and resumes.
    #[test]
    fn chunked_counters_match_slot_scans() {
        let mut eng = chunked_engine(4, 5);
        for i in 0..8u64 {
            let plen = 6 + (i as usize * 5) % 18;
            let prompt: Vec<i32> = (0..plen).map(|t| 1 + ((i as i32 + t as i32) % 9)).collect();
            eng.submit(item(i, prompt)).unwrap();
        }
        let mut ev = Vec::new();
        for _ in 0..200 {
            eng.step(&mut ev).unwrap();
            let (busy, retained, kv, _blocks) = scan_counters(&eng);
            assert_eq!(eng.busy(), busy, "busy counter drifted");
            assert_eq!(eng.retained(), retained);
            assert_eq!(eng.kv_tokens(), kv, "kv token counter drifted");
            ev.clear();
            if !eng.has_work() {
                break;
            }
        }
        assert!(!eng.has_work(), "run did not complete");
        assert_eq!(eng.kv_tokens(), 0);
    }

    /// Mid-chunk early termination: a slot stopped while its prompt is
    /// still ingesting flushes plainly (nothing generated → no retention,
    /// the coordinator re-queues it as fresh work), every block is
    /// released, and the slot admits new work cleanly afterwards — the
    /// mock's staging reset + boundary validation would fail loudly if any
    /// partial stage leaked across occupants.
    #[test]
    fn mid_chunk_stop_releases_cleanly_and_slot_is_reusable() {
        let mut eng = chunked_engine(1, 3);
        let prompt: Vec<i32> = (0..20).map(|t| 1 + (t % 9)).collect();
        eng.submit(item(1, prompt)).unwrap();
        let mut ev = Vec::new();
        eng.step(&mut ev).unwrap(); // 3 of 20 prompt tokens ingested
        ev.clear();
        eng.stop_generation(&mut ev, true);
        let partial = ev
            .iter()
            .find_map(|e| match e {
                EngineEvent::Done { result, .. } => Some(result.clone()),
                _ => None,
            })
            .expect("stopped slot reports");
        assert!(partial.new_tokens.is_empty(), "nothing was generated yet");
        assert!(partial.retained.is_none(), "mid-ingestion slots must not retain");
        assert_eq!(eng.retained(), 0);
        assert_eq!(eng.kv_tokens(), 0, "partial ingestion charge released");
        assert_eq!(eng.kv_blocks(), 0);
        // The slot takes fresh work; chunk boundary validation passes.
        eng.submit(item(2, vec![1, 5, 6, 7, 8])).unwrap();
        let results = run_to_completion(&mut eng, 200);
        assert_eq!(results.len(), 1);
        assert!(results[0].reason.is_complete());
        assert_eq!(eng.kv_blocks(), 0);
    }

    /// Group prefix sharing still holds under chunked prefill: the first
    /// sibling to complete its prompt registers the chain, later siblings
    /// attach at admission, and streams match the sharing-off baseline.
    #[test]
    fn chunked_prefill_shares_group_prefix() {
        let run = |sharing: bool| -> (Vec<(u64, Vec<i32>, Vec<u32>)>, u64) {
            let mut be = MockBackend::new(4, 96);
            be.min_len = 10;
            be.spread = 1;
            let kv = KvCacheConfig {
                block_size: 4,
                budget_blocks: 0,
                prefix_sharing: sharing,
                ..KvCacheConfig::default()
            };
            let mut eng =
                Engine::with_opts(0, be, EngineOpts { kv, step_token_budget: 6 }, 1);
            let prompt = vec![1, 7, 7, 9, 2, 3, 4, 5]; // 8 tokens = 2 blocks
            for i in 0..4u64 {
                let mut it = item(i, prompt.clone());
                it.prefix = Some(42);
                eng.submit(it).unwrap();
            }
            let res = run_to_completion(&mut eng, 400);
            (streams(res), eng.prefix_tokens_shared)
        };
        let (on, shared_on) = run(true);
        let (off, shared_off) = run(false);
        assert_eq!(on, off, "sharing changed a chunked stream");
        assert!(shared_on > 0, "later siblings must attach the registered prefix");
        assert_eq!(shared_off, 0);
    }
}
