//! The fixed-size block allocator: a free-list arena with per-block
//! refcounts. Blocks are the unit the engine's KV budget is denominated
//! in; sharing (prompt prefixes, retained partials whose prefix is still
//! live) is expressed as refcounts > 1, and a block's residency is charged
//! exactly once no matter how many sequences reference it.
//!
//! Invariants (pinned by the property tests below and re-checked by the
//! engine's counter-consistency test):
//! - `blocks_in_use() == |{b : refcount(b) > 0}|`;
//! - the free list holds exactly the arena slots with refcount 0, each
//!   once (no double free — `release` on a free block is a checked no-op);
//! - a bounded allocator never hands out more than `capacity` blocks
//!   (`alloc` returns `None` → the engine backpressures admission);
//! - an unbounded allocator (`capacity == 0`) grows its arena on demand
//!   (growth can be pre-reserved via [`BlockAllocator::reserve_arena`] so
//!   the decode hot path stays allocation-free).

/// Identifier of one fixed-size KV block (index into the arena).
pub type BlockId = u32;

/// Free-list block arena with refcounts. See the module docs for the
/// invariants.
#[derive(Debug)]
pub struct BlockAllocator {
    block_size: usize,
    /// Per-block reference count (0 = on the free list).
    refcounts: Vec<u32>,
    /// LIFO free list of arena slots with refcount 0.
    free: Vec<BlockId>,
    /// Per-block dequantization scale (int8 KV; 1.0 for float dtypes and
    /// freshly (re)allocated blocks). Parallel to `refcounts`.
    scales: Vec<f32>,
    /// Blocks with refcount > 0.
    in_use: usize,
    /// Cumulative copy-on-write block copies (see [`super::PageTable`]).
    cow_copies: u64,
    /// Hard arena cap in blocks (0 = unbounded, grow on demand).
    capacity: usize,
}

impl BlockAllocator {
    /// New allocator with `block_size` tokens per block and a hard arena
    /// cap of `capacity_blocks` (0 = unbounded).
    pub fn new(block_size: usize, capacity_blocks: usize) -> BlockAllocator {
        assert!(block_size >= 1, "block_size must be >= 1");
        BlockAllocator {
            block_size,
            refcounts: Vec::new(),
            free: Vec::new(),
            scales: Vec::new(),
            in_use: 0,
            cow_copies: 0,
            capacity: capacity_blocks,
        }
    }

    /// Tokens per block.
    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// Hard arena cap in blocks (0 = unbounded).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Blocks currently referenced by at least one page table or cache
    /// entry — the number the KV budget is enforced against.
    pub fn blocks_in_use(&self) -> usize {
        self.in_use
    }

    /// Total arena slots ever created (in use + free).
    pub fn arena_size(&self) -> usize {
        self.refcounts.len()
    }

    /// Cumulative copy-on-write block copies.
    pub fn cow_copies(&self) -> u64 {
        self.cow_copies
    }

    /// Blocks needed to hold `tokens` tokens (ceil division).
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    /// Pre-grow the free list so the next `blocks` allocations perform no
    /// heap allocation (decode-hot-path discipline; unbounded arenas only).
    pub fn reserve_arena(&mut self, blocks: usize) {
        let want = self.refcounts.len() + blocks;
        self.refcounts.reserve(blocks);
        self.scales.reserve(blocks);
        if self.free.capacity() < want {
            self.free.reserve(want - self.free.len());
        }
        while self.refcounts.len() < want {
            let id = self.refcounts.len() as BlockId;
            self.refcounts.push(0);
            self.scales.push(1.0);
            self.free.push(id);
        }
    }

    /// Allocate one block with refcount 1. `None` when a bounded arena is
    /// exhausted — the caller's clean-backpressure signal.
    pub fn alloc(&mut self) -> Option<BlockId> {
        if let Some(b) = self.free.pop() {
            debug_assert_eq!(self.refcounts[b as usize], 0);
            self.refcounts[b as usize] = 1;
            self.scales[b as usize] = 1.0; // fresh block, neutral scale
            self.in_use += 1;
            return Some(b);
        }
        if self.capacity != 0 && self.refcounts.len() >= self.capacity {
            return None;
        }
        let id = self.refcounts.len() as BlockId;
        self.refcounts.push(1);
        self.scales.push(1.0);
        // Keep the free list's CAPACITY tracking the arena size (it can
        // hold at most one entry per arena slot), so later releases never
        // reallocate mid-decode — growth cost is paid here, on the cold
        // arena-growth path.
        let arena = self.refcounts.len();
        if self.free.capacity() < arena {
            self.free.reserve(arena - self.free.len());
        }
        self.in_use += 1;
        Some(id)
    }

    /// Add one reference to a live block (prefix attach, registry insert).
    pub fn retain(&mut self, b: BlockId) {
        debug_assert!(self.refcounts[b as usize] > 0, "retain of a free block");
        self.refcounts[b as usize] += 1;
    }

    /// Drop one reference; returns true when the block's refcount reached
    /// zero and it went back on the free list. Releasing an already-free
    /// block is a checked no-op (debug assert; `false` in release builds)
    /// — the no-double-free invariant.
    pub fn release(&mut self, b: BlockId) -> bool {
        let rc = &mut self.refcounts[b as usize];
        debug_assert!(*rc > 0, "double free of block {b}");
        if *rc == 0 {
            return false;
        }
        *rc -= 1;
        if *rc == 0 {
            self.free.push(b);
            self.in_use -= 1;
            true
        } else {
            false
        }
    }

    /// Current refcount of `b`.
    pub fn ref_count(&self, b: BlockId) -> u32 {
        self.refcounts[b as usize]
    }

    /// Dequantization scale of block `b` (1.0 for float KV dtypes).
    pub fn scale(&self, b: BlockId) -> f32 {
        self.scales[b as usize]
    }

    /// Set block `b`'s dequantization scale (int8 KV writes; a COW copy
    /// carries the source block's scale — see
    /// [`super::PageTable::append_one`]).
    pub fn set_scale(&mut self, b: BlockId, scale: f32) {
        debug_assert!(self.refcounts[b as usize] > 0, "scale write to a free block");
        self.scales[b as usize] = scale;
    }

    /// Record one copy-on-write block copy (called by
    /// [`super::PageTable::append_one`]).
    pub(crate) fn note_cow(&mut self) {
        self.cow_copies += 1;
    }

    /// Recompute every invariant from scratch (tests only).
    #[cfg(test)]
    pub fn check_invariants(&self) {
        let live = self.refcounts.iter().filter(|&&r| r > 0).count();
        assert_eq!(live, self.in_use, "in_use counter drifted");
        assert_eq!(self.scales.len(), self.refcounts.len(), "scales arena drifted");
        assert_eq!(
            self.free.len() + self.in_use,
            self.refcounts.len(),
            "free list + live != arena"
        );
        let mut seen = vec![false; self.refcounts.len()];
        for &b in &self.free {
            assert_eq!(self.refcounts[b as usize], 0, "live block on free list");
            assert!(!seen[b as usize], "block {b} on free list twice");
            seen[b as usize] = true;
        }
        if self.capacity != 0 {
            assert!(self.refcounts.len() <= self.capacity, "arena exceeded capacity");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::prop_check;
    use crate::util::Rng;

    #[test]
    fn alloc_release_roundtrip() {
        let mut a = BlockAllocator::new(16, 4);
        let b0 = a.alloc().unwrap();
        let b1 = a.alloc().unwrap();
        assert_ne!(b0, b1);
        assert_eq!(a.blocks_in_use(), 2);
        assert!(a.release(b0));
        assert_eq!(a.blocks_in_use(), 1);
        // LIFO reuse.
        assert_eq!(a.alloc().unwrap(), b0);
        assert!(a.release(b0));
        assert!(a.release(b1));
        assert_eq!(a.blocks_in_use(), 0);
        a.check_invariants();
    }

    #[test]
    fn refcounts_share_and_release_in_order() {
        let mut a = BlockAllocator::new(8, 0);
        let b = a.alloc().unwrap();
        a.retain(b);
        a.retain(b);
        assert_eq!(a.ref_count(b), 3);
        assert!(!a.release(b));
        assert!(!a.release(b));
        assert_eq!(a.blocks_in_use(), 1, "shared block charged once");
        assert!(a.release(b), "last ref frees");
        assert_eq!(a.blocks_in_use(), 0);
        a.check_invariants();
    }

    #[test]
    fn bounded_arena_exhausts_cleanly() {
        let mut a = BlockAllocator::new(16, 2);
        let b0 = a.alloc().unwrap();
        let _b1 = a.alloc().unwrap();
        assert!(a.alloc().is_none(), "capacity must cap the arena");
        a.release(b0);
        assert!(a.alloc().is_some(), "freed capacity is reusable");
        a.check_invariants();
    }

    #[test]
    fn release_of_free_block_is_a_noop_in_release_builds() {
        let mut a = BlockAllocator::new(16, 0);
        let b = a.alloc().unwrap();
        assert!(a.release(b));
        // Double free: debug builds assert; release builds must not
        // corrupt the free list. Run the check only without debug asserts.
        if !cfg!(debug_assertions) {
            assert!(!a.release(b));
            a.check_invariants();
        }
    }

    #[test]
    fn reserve_arena_pregrows_free_list() {
        let mut a = BlockAllocator::new(16, 0);
        a.reserve_arena(8);
        assert_eq!(a.arena_size(), 8);
        assert_eq!(a.blocks_in_use(), 0);
        for _ in 0..8 {
            assert!(a.alloc().is_some());
        }
        a.check_invariants();
    }

    #[test]
    fn scales_default_to_neutral_and_reset_on_realloc() {
        let mut a = BlockAllocator::new(16, 0);
        a.reserve_arena(2);
        let b = a.alloc().unwrap();
        assert_eq!(a.scale(b), 1.0, "fresh block starts neutral");
        a.set_scale(b, 0.125);
        assert_eq!(a.scale(b), 0.125);
        assert!(a.release(b));
        let b2 = a.alloc().unwrap();
        assert_eq!(b2, b, "LIFO reuse");
        assert_eq!(a.scale(b2), 1.0, "stale scale must not leak across reuse");
        a.check_invariants();
    }

    #[test]
    fn blocks_for_is_ceil() {
        let a = BlockAllocator::new(16, 0);
        assert_eq!(a.blocks_for(0), 0);
        assert_eq!(a.blocks_for(1), 1);
        assert_eq!(a.blocks_for(16), 1);
        assert_eq!(a.blocks_for(17), 2);
    }

    /// Property: arbitrary interleavings of alloc/retain/release keep every
    /// structural invariant intact — no double free, no free-list
    /// duplicates, in_use exact, bounded arenas never over-allocate.
    #[test]
    fn prop_random_op_sequences_keep_invariants() {
        prop_check(
            "block-allocator-invariants",
            16,
            |rng: &mut Rng| {
                let capacity = if rng.below(2) == 0 { 0 } else { 2 + rng.below(14) as usize };
                let ops = 40 + rng.below(160) as usize;
                (capacity, ops, rng.next_u64())
            },
            |&(capacity, ops, seed)| {
                let mut rng = Rng::new(seed);
                let mut a = BlockAllocator::new(4, capacity);
                // Model state: outstanding refs per block, as a multiset.
                let mut refs: Vec<BlockId> = Vec::new();
                for _ in 0..ops {
                    match rng.below(3) {
                        0 => {
                            if let Some(b) = a.alloc() {
                                refs.push(b);
                            } else if capacity == 0 {
                                return Err("unbounded alloc returned None".into());
                            }
                        }
                        1 => {
                            if !refs.is_empty() {
                                let b = refs[rng.below(refs.len() as u64) as usize];
                                a.retain(b);
                                refs.push(b);
                            }
                        }
                        _ => {
                            if !refs.is_empty() {
                                let i = rng.below(refs.len() as u64) as usize;
                                let b = refs.swap_remove(i);
                                let freed = a.release(b);
                                let still_referenced = refs.contains(&b);
                                if freed == still_referenced {
                                    return Err(format!(
                                        "block {b}: freed={freed} but model still_referenced={still_referenced}"
                                    ));
                                }
                            }
                        }
                    }
                    a.check_invariants();
                    let model_in_use =
                        refs.iter().collect::<std::collections::HashSet<_>>().len();
                    if a.blocks_in_use() != model_in_use {
                        return Err(format!(
                            "in_use {} != model {model_in_use}",
                            a.blocks_in_use()
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}
