//! Per-sequence page tables (block chains) with copy-on-write appends,
//! and the engine's shared prompt-prefix registry.
//!
//! A [`PageTable`] owns one reference to each block in its chain; the
//! chain covers `tokens()` resident tokens. Appending a token either lands
//! inside the (exclusively owned) last partial block, opens a fresh block
//! at a block boundary, or — when the last partial block is *shared*
//! (refcount > 1) — copies it first ([`PageTable::append_one`]). A shared
//! block is therefore never written through: the COW rule the property
//! tests pin.
//!
//! A [`PrefixCache`] entry holds its own +1 reference on every block of a
//! registered prompt prefix, so the prefix stays attachable while the
//! group's remaining samples trickle in — even if the sample that
//! allocated it already finished. Entries are pure cache: the coordinator
//! releases them when a group completes (`EngineCmd::ReleasePrefix`), and
//! the engine evicts them first under KV-budget pressure.

use std::collections::HashMap;

use super::allocator::{BlockAllocator, BlockId};

/// One sequence's chain of KV-block references plus its resident token
/// count. Every block id in the chain is distinct, and the table holds
/// exactly one allocator reference per entry.
#[derive(Debug, Default)]
pub struct PageTable {
    blocks: Vec<BlockId>,
    tokens: usize,
}

impl PageTable {
    /// Empty table (no blocks, no tokens).
    pub fn new() -> PageTable {
        PageTable::default()
    }

    /// Resident tokens covered by the chain.
    pub fn tokens(&self) -> usize {
        self.tokens
    }

    /// The block chain, in position order.
    pub fn block_ids(&self) -> &[BlockId] {
        &self.blocks
    }

    /// Chain length in blocks.
    pub fn num_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// True when nothing is resident.
    pub fn is_empty(&self) -> bool {
        self.tokens == 0
    }

    /// Pre-reserve chain capacity so decode-time appends never reallocate
    /// (hot-path discipline; call at admission with the worst case).
    pub fn reserve(&mut self, blocks: usize) {
        if self.blocks.capacity() < blocks {
            self.blocks.reserve(blocks - self.blocks.len());
        }
    }

    /// Append one resident token. Returns `Some(changed)` where `changed`
    /// is true when the block chain changed (fresh block at a boundary, or
    /// a copy-on-write replacement of a shared partial tail) — the signal
    /// to re-install the backend block table. `None` = the (bounded)
    /// allocator is exhausted; the table is left unchanged.
    pub fn append_one(&mut self, alloc: &mut BlockAllocator) -> Option<bool> {
        let bs = alloc.block_size();
        let changed = if self.tokens % bs == 0 {
            // Block boundary: open a fresh, exclusively owned block.
            let b = alloc.alloc()?;
            self.blocks.push(b);
            true
        } else {
            let last = *self.blocks.last().expect("partial block must exist");
            if alloc.ref_count(last) > 1 {
                // COW: the partial tail is shared (prompt-prefix attach or
                // registry ref) — copy it before the divergent write. The
                // shared original is never mutated. A block copy carries
                // the source's dequantization scale (int8 KV): the copied
                // payload is still encoded at the donor's scale.
                let scale = alloc.scale(last);
                let nb = alloc.alloc()?;
                alloc.release(last);
                alloc.set_scale(nb, scale);
                *self.blocks.last_mut().unwrap() = nb;
                alloc.note_cow();
                true
            } else {
                false
            }
        };
        self.tokens += 1;
        Some(changed)
    }

    /// Grow the chain to cover `tokens` resident tokens (admission /
    /// replay cold path). `None` on allocator exhaustion — partially grown
    /// state remains valid (release it via [`PageTable::release_all`]).
    pub fn grow_to(&mut self, tokens: usize, alloc: &mut BlockAllocator) -> Option<()> {
        while self.tokens < tokens {
            self.append_one(alloc)?;
        }
        Some(())
    }

    /// Attach a shared prefix to an empty table: one retained reference
    /// per donor block, covering `tokens` resident tokens. The caller
    /// guarantees `donor` covers exactly `tokens` (registry entries do by
    /// construction).
    pub fn attach_shared(
        &mut self,
        donor: &[BlockId],
        tokens: usize,
        alloc: &mut BlockAllocator,
    ) {
        debug_assert!(self.is_empty() && self.blocks.is_empty(), "attach to non-empty table");
        debug_assert_eq!(donor.len(), alloc.blocks_for(tokens), "donor/token mismatch");
        for &b in donor {
            alloc.retain(b);
            self.blocks.push(b);
        }
        self.tokens = tokens;
    }

    /// Release every block reference and reset to empty.
    pub fn release_all(&mut self, alloc: &mut BlockAllocator) {
        for &b in &self.blocks {
            alloc.release(b);
        }
        self.blocks.clear();
        self.tokens = 0;
    }
}

/// One registered shared prompt prefix: the block chain covering exactly
/// `tokens` prompt tokens, with one registry-owned reference per block.
#[derive(Debug)]
pub struct PrefixEntry {
    blocks: Vec<BlockId>,
    /// Prompt tokens the chain covers (== the registering prompt length).
    pub tokens: usize,
    /// Registration order (deterministic eviction).
    seq: u64,
}

impl PrefixEntry {
    /// The registered block chain.
    pub fn blocks(&self) -> &[BlockId] {
        &self.blocks
    }
}

/// Registry of shared prompt prefixes, keyed by the coordinator's group
/// handle. Holds its own block references (see the module docs).
#[derive(Debug, Default)]
pub struct PrefixCache {
    entries: HashMap<u64, PrefixEntry>,
    seq: u64,
}

impl PrefixCache {
    /// Empty registry.
    pub fn new() -> PrefixCache {
        PrefixCache::default()
    }

    /// Registered prefix count.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Is the registry empty?
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Look up a registered prefix.
    pub fn get(&self, key: u64) -> Option<&PrefixEntry> {
        self.entries.get(&key)
    }

    /// Total block references held across all entries (an upper bound on
    /// what clearing the registry could free — shared refs free nothing).
    pub fn total_blocks(&self) -> usize {
        self.entries.values().map(|e| e.blocks.len()).sum()
    }

    /// Register `blocks` (covering `tokens` prompt tokens) under `key`,
    /// retaining one reference per block. An existing entry under the same
    /// key is released first.
    pub fn insert(
        &mut self,
        key: u64,
        blocks: &[BlockId],
        tokens: usize,
        alloc: &mut BlockAllocator,
    ) {
        // Retain the new refs BEFORE releasing a displaced entry, so an
        // overlapping chain can never transiently drop to refcount 0.
        for &b in blocks {
            alloc.retain(b);
        }
        self.remove(key, alloc);
        self.seq += 1;
        self.entries.insert(
            key,
            PrefixEntry { blocks: blocks.to_vec(), tokens, seq: self.seq },
        );
    }

    /// Release the entry under `key` (refcount drop on each block);
    /// returns whether an entry existed. Safe for unknown keys — the
    /// coordinator's `ReleasePrefix` may race an engine-side eviction.
    pub fn remove(&mut self, key: u64, alloc: &mut BlockAllocator) -> bool {
        let Some(e) = self.entries.remove(&key) else { return false };
        for &b in &e.blocks {
            alloc.release(b);
        }
        true
    }

    /// Deterministic eviction victim under KV pressure: prefer entries no
    /// live sequence still shares (every block refcount == 1, so eviction
    /// actually frees blocks), oldest first; otherwise the oldest entry
    /// outright. `exclude` guards the prefix an imminent admission is
    /// about to attach.
    pub fn eviction_victim(
        &self,
        alloc: &BlockAllocator,
        exclude: Option<u64>,
    ) -> Option<u64> {
        let mut registry_only: Option<(u64, u64)> = None;
        let mut any: Option<(u64, u64)> = None;
        for (&key, e) in &self.entries {
            if Some(key) == exclude {
                continue;
            }
            if any.map_or(true, |(_, s)| e.seq < s) {
                any = Some((key, e.seq));
            }
            let unshared = e.blocks.iter().all(|&b| alloc.ref_count(b) == 1);
            if unshared && registry_only.map_or(true, |(_, s)| e.seq < s) {
                registry_only = Some((key, e.seq));
            }
        }
        registry_only.or(any).map(|(k, _)| k)
    }

    /// Release every entry (weight-sync invalidation: registered prefixes
    /// were computed under the old params).
    pub fn clear(&mut self, alloc: &mut BlockAllocator) {
        for (_, e) in self.entries.drain() {
            for &b in &e.blocks {
                alloc.release(b);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::prop_check;
    use crate::util::Rng;

    fn alloc4() -> BlockAllocator {
        BlockAllocator::new(4, 0)
    }

    #[test]
    fn append_opens_blocks_at_boundaries() {
        let mut a = alloc4();
        let mut p = PageTable::new();
        for t in 1..=9 {
            assert_eq!(p.append_one(&mut a), Some(t % 4 == 1), "token {t}");
            assert_eq!(p.tokens(), t);
        }
        assert_eq!(p.num_blocks(), 3); // ceil(9/4)
        assert_eq!(a.blocks_in_use(), 3);
        p.release_all(&mut a);
        assert_eq!(a.blocks_in_use(), 0);
        a.check_invariants();
    }

    #[test]
    fn attach_shares_and_cow_copies_partial_tail() {
        let mut a = alloc4();
        // Donor: 6 tokens = 1 full block + 1 partial tail.
        let mut donor = PageTable::new();
        donor.grow_to(6, &mut a).unwrap();
        let donor_blocks = donor.block_ids().to_vec();
        assert_eq!(a.blocks_in_use(), 2);

        let mut sib = PageTable::new();
        sib.attach_shared(&donor_blocks, 6, &mut a);
        assert_eq!(a.blocks_in_use(), 2, "attach charges nothing new");
        assert_eq!(a.ref_count(donor_blocks[0]), 2);
        assert_eq!(a.ref_count(donor_blocks[1]), 2);

        // First divergent write: the shared partial tail must be COPIED,
        // never mutated — the donor chain is untouched.
        assert_eq!(sib.append_one(&mut a), Some(true));
        assert_eq!(a.cow_copies(), 1);
        assert_eq!(sib.tokens(), 7);
        assert_eq!(sib.block_ids()[0], donor_blocks[0], "full block stays shared");
        assert_ne!(sib.block_ids()[1], donor_blocks[1], "tail copied on write");
        assert_eq!(donor.block_ids(), &donor_blocks[..], "donor never mutated");
        assert_eq!(a.ref_count(donor_blocks[1]), 1, "sibling dropped its tail ref");
        assert_eq!(a.blocks_in_use(), 3);

        // Donor keeps appending into its (again exclusive) tail: no COW.
        assert_eq!(donor.append_one(&mut a), Some(false));
        assert_eq!(a.cow_copies(), 1);

        sib.release_all(&mut a);
        donor.release_all(&mut a);
        assert_eq!(a.blocks_in_use(), 0);
        a.check_invariants();
    }

    #[test]
    fn cow_copy_carries_the_donor_blocks_quant_scale() {
        let mut a = alloc4();
        let mut donor = PageTable::new();
        donor.grow_to(6, &mut a).unwrap(); // full block + partial tail
        let donor_blocks = donor.block_ids().to_vec();
        // Int8 KV: the tail block was written at a specific scale.
        a.set_scale(donor_blocks[1], 0.25);

        let mut sib = PageTable::new();
        sib.attach_shared(&donor_blocks, 6, &mut a);
        assert_eq!(sib.append_one(&mut a), Some(true), "divergent write COWs");
        let copied = sib.block_ids()[1];
        assert_ne!(copied, donor_blocks[1]);
        assert_eq!(
            a.scale(copied),
            0.25,
            "copied payload is still encoded at the donor's scale"
        );
        assert_eq!(a.scale(donor_blocks[1]), 0.25, "donor scale untouched");
        sib.release_all(&mut a);
        donor.release_all(&mut a);
        a.check_invariants();
    }

    #[test]
    fn block_aligned_attach_needs_no_cow() {
        let mut a = alloc4();
        let mut donor = PageTable::new();
        donor.grow_to(8, &mut a).unwrap(); // exactly 2 blocks
        let blocks = donor.block_ids().to_vec();
        let mut sib = PageTable::new();
        sib.attach_shared(&blocks, 8, &mut a);
        assert_eq!(sib.append_one(&mut a), Some(true), "boundary opens a fresh block");
        assert_eq!(a.cow_copies(), 0, "aligned prefix never COWs");
        assert_eq!(a.blocks_in_use(), 3);
        sib.release_all(&mut a);
        donor.release_all(&mut a);
        a.check_invariants();
    }

    #[test]
    fn bounded_exhaustion_leaves_table_valid() {
        let mut a = BlockAllocator::new(4, 2);
        let mut p = PageTable::new();
        assert!(p.grow_to(8, &mut a).is_some());
        assert_eq!(p.append_one(&mut a), None, "arena exhausted");
        assert_eq!(p.tokens(), 8, "failed append must not charge");
        p.release_all(&mut a);
        a.check_invariants();
    }

    #[test]
    fn prefix_cache_holds_its_own_refs() {
        let mut a = alloc4();
        let mut owner = PageTable::new();
        owner.grow_to(4, &mut a).unwrap();
        let mut cache = PrefixCache::new();
        cache.insert(7, owner.block_ids(), 4, &mut a);
        assert_eq!(a.ref_count(owner.block_ids()[0]), 2);

        // The owner finishing does NOT free the registered prefix.
        owner.release_all(&mut a);
        assert_eq!(a.blocks_in_use(), 1, "registry keeps the prefix resident");

        // A later sibling can still attach it.
        let entry = cache.get(7).expect("entry");
        let donor = entry.blocks().to_vec();
        let mut sib = PageTable::new();
        sib.attach_shared(&donor, 4, &mut a);
        assert!(cache.remove(7, &mut a));
        assert!(!cache.remove(7, &mut a), "double release is a no-op");
        assert_eq!(a.blocks_in_use(), 1, "sibling still holds the prefix");
        sib.release_all(&mut a);
        assert_eq!(a.blocks_in_use(), 0);
        a.check_invariants();
    }

    #[test]
    fn eviction_prefers_unshared_entries_and_honors_exclude() {
        let mut a = alloc4();
        let mut cache = PrefixCache::new();
        let mut p1 = PageTable::new();
        p1.grow_to(4, &mut a).unwrap();
        cache.insert(1, p1.block_ids(), 4, &mut a);
        let mut p2 = PageTable::new();
        p2.grow_to(4, &mut a).unwrap();
        cache.insert(2, p2.block_ids(), 4, &mut a);
        // Entry 2's blocks drop to registry-only refs; entry 1 stays shared.
        p2.release_all(&mut a);
        assert_eq!(cache.eviction_victim(&a, None), Some(2));
        assert_eq!(cache.eviction_victim(&a, Some(2)), Some(1));
        cache.clear(&mut a);
        p1.release_all(&mut a);
        assert_eq!(a.blocks_in_use(), 0);
        a.check_invariants();
    }

    /// Property: random share/append/release interleavings never mutate a
    /// shared chain (donor chains stay identical while shared) and keep
    /// allocator invariants intact.
    #[test]
    fn prop_cow_never_mutates_shared_chains() {
        prop_check(
            "pagetable-cow-isolation",
            12,
            |rng: &mut Rng| (2 + rng.below(5) as usize, 1 + rng.below(11) as usize, rng.next_u64()),
            |&(bs, prefix_tokens, seed)| {
                let mut rng = Rng::new(seed);
                let mut a = BlockAllocator::new(bs, 0);
                let mut donor = PageTable::new();
                donor.grow_to(prefix_tokens, &mut a).unwrap();
                let frozen = donor.block_ids().to_vec();
                let mut sibs: Vec<PageTable> = Vec::new();
                for _ in 0..(10 + rng.below(30)) {
                    match rng.below(3) {
                        0 => {
                            let mut s = PageTable::new();
                            s.attach_shared(&frozen, prefix_tokens, &mut a);
                            sibs.push(s);
                        }
                        1 => {
                            if !sibs.is_empty() {
                                let i = rng.below(sibs.len() as u64) as usize;
                                if sibs[i].append_one(&mut a).is_none() {
                                    return Err("unbounded append failed".into());
                                }
                            }
                        }
                        _ => {
                            if !sibs.is_empty() {
                                let i = rng.below(sibs.len() as u64) as usize;
                                let mut s = sibs.swap_remove(i);
                                s.release_all(&mut a);
                            }
                        }
                    }
                    if donor.block_ids() != &frozen[..] {
                        return Err("donor chain mutated by sibling activity".into());
                    }
                    a.check_invariants();
                }
                for s in &mut sibs {
                    s.release_all(&mut a);
                }
                donor.release_all(&mut a);
                if a.blocks_in_use() != 0 {
                    return Err(format!("{} blocks leaked", a.blocks_in_use()));
                }
                Ok(())
            },
        );
    }
}
