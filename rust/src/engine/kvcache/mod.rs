//! Paged KV-cache subsystem: a fixed-size block allocator with refcounted
//! copy-on-write sharing (the vLLM block-manager idea, scaled to this
//! substrate).
//!
//! Before this subsystem the engine charged KV residency as a flat
//! per-slot token count: the G samples of a GRPO group each "held" a
//! private copy of the identical prompt prefix, and a retained partial was
//! evicted whole even when most of its KV was a prefix still resident for
//! live siblings. The block layer replaces that with vLLM-style paging:
//!
//! - [`BlockAllocator`] — a free-list arena of fixed-size blocks
//!   (`block_size` tokens each) with per-block refcounts; the engine's KV
//!   budget is denominated in blocks (`engine.kv_budget_blocks`).
//! - [`PageTable`] — one per sequence (busy or retained slot): the chain
//!   of block refs covering its resident tokens. Appending a token inside
//!   a *shared* partial block first copies it ([`PageTable::append_one`],
//!   the copy-on-write rule), so a shared block is never mutated.
//! - [`PrefixCache`] — the engine's registry of shared prompt prefixes,
//!   keyed by the coordinator's group handle ([`super::WorkItem::prefix`]):
//!   the first admission of a group allocates the prompt blocks once and
//!   registers them; every later sibling attaches the same blocks with a
//!   refcount bump instead of charging fresh residency.
//!
//! # What is (and is not) virtualized
//!
//! The backends in this repo keep *physically* slot-contiguous KV (the AOT
//! decode artifact has no paged-attention kernel, and the mock's "KV" is a
//! script cursor), so prefill still executes per slot. What the block layer
//! virtualizes is the **residency economy**: admission, the KV budget,
//! preemption, retention, and eviction are all charged in refcounted
//! blocks, so a group's shared prefix counts once, a retained partial
//! whose prefix is still live costs near nothing, and more rollouts fit a
//! given budget. [`super::Backend::set_block_table`] mirrors the logical
//! block chain to the backend — the mock enforces the mapping invariants
//! bit-exactly, the PJRT backend keeps a device-side table staged for a
//! future paged decode artifact.
//!
//! Everything here is synchronous, allocation-free on the decode hot path
//! (block chains and the free list are pre-reserved), and exhaustively
//! covered by property-style tests (`allocator.rs`, `pages.rs`).

pub mod allocator;
pub mod pages;

pub use allocator::{BlockAllocator, BlockId};
pub use pages::{PageTable, PrefixCache};

/// Default tokens per KV block (vLLM's default; `engine.kv_block_size`).
pub const DEFAULT_BLOCK_SIZE: usize = 16;

/// Engine-side KV-cache configuration: how residency is paged, budgeted
/// and shared. Assembled from [`crate::config::EngineConfig`] via
/// `kv_cache_config()`; the token-denominated legacy budget converts with
/// [`KvCacheConfig::from_token_budget`].
#[derive(Clone, Copy, Debug)]
pub struct KvCacheConfig {
    /// Tokens per block (must be ≥ 1).
    pub block_size: usize,
    /// KV budget in blocks (0 = unlimited). Enforced softly, like the old
    /// token budget: caches (prefix registry entries, retained slots) are
    /// evicted first, then live slots are preempted LIFO; admission of
    /// fresh work backpressures cleanly instead of thrashing.
    pub budget_blocks: usize,
    /// Honor [`super::WorkItem::prefix`] handles: share a group's prompt
    /// blocks across its samples via the [`PrefixCache`].
    pub prefix_sharing: bool,
}

impl Default for KvCacheConfig {
    fn default() -> Self {
        KvCacheConfig {
            block_size: DEFAULT_BLOCK_SIZE,
            budget_blocks: 0,
            prefix_sharing: true,
        }
    }
}

impl KvCacheConfig {
    /// Unlimited budget, default block size, sharing on.
    pub fn unlimited() -> Self {
        Self::default()
    }

    /// Back-compat conversion from the old token-denominated budget
    /// (`engine.kv_budget_tokens`): ceil(tokens / block_size) blocks, so a
    /// legacy budget never becomes *tighter* than it was.
    pub fn from_token_budget(tokens: usize, block_size: usize) -> Self {
        let bs = block_size.max(1);
        KvCacheConfig {
            block_size: bs,
            budget_blocks: tokens.div_ceil(bs), // 0 stays 0 (unlimited)
            prefix_sharing: true,
        }
    }

    /// The budget expressed back in tokens (0 = unlimited) — the "both
    /// forms" half of the Table-3 config echo.
    pub fn budget_tokens(&self) -> usize {
        self.budget_blocks * self.block_size
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_budget_converts_with_ceil() {
        let kv = KvCacheConfig::from_token_budget(30, 16);
        assert_eq!(kv.budget_blocks, 2);
        assert_eq!(kv.budget_tokens(), 32);
        let kv = KvCacheConfig::from_token_budget(32, 16);
        assert_eq!(kv.budget_blocks, 2);
        let kv = KvCacheConfig::from_token_budget(0, 16);
        assert_eq!(kv.budget_blocks, 0, "0 stays unlimited");
        assert_eq!(kv.budget_tokens(), 0);
    }

    #[test]
    fn defaults_share_with_unlimited_budget() {
        let kv = KvCacheConfig::default();
        assert_eq!(kv.block_size, DEFAULT_BLOCK_SIZE);
        assert_eq!(kv.budget_blocks, 0);
        assert!(kv.prefix_sharing);
    }
}
